"""Speculative-decoding subsystem tests: greedy verification semantics
(unit), token-identity of speculative greedy output with the non-speculative
scheduler (mixed online traffic, prefix sharing on AND off, int4 KV pool,
preemption, EOS mid-verify), rollback block accounting (allocator invariants
under seeded random speculative traffic), the draft-artifact load path,
segment-aware prefill packing (seg_width > 1 without speculation), and the
greedy-only temperature gate."""

import numpy as np
import pytest

import jax

from repro.configs.base import get_smoke_config
from repro.core.qlinear import QLinearConfig
from repro.core.quantspec import QuantSpec
from repro.models.model import build, quantize_model
from repro.serving.engine import ServeConfig, ServingEngine
from repro.serving.speculative import (DEFAULT_DRAFT_SPEC, SpeculativeConfig,
                                       greedy_verify)

QSPEC = QuantSpec(base=QLinearConfig(detection="none"))


@pytest.fixture(scope="module")
def small_lm():
    cfg = get_smoke_config("llama3_2_1b")
    model = build(cfg)
    params = model.init(jax.random.PRNGKey(0))
    return cfg, model, params, quantize_model(model, params, QSPEC)


@pytest.fixture(scope="module")
def w3_draft(small_lm):
    """The low-bit draft: the SAME model one QuantSpec away (W3/A4, int4 KV)."""
    cfg, model, params, _ = small_lm
    return model, quantize_model(model, params, DEFAULT_DRAFT_SPEC), \
        DEFAULT_DRAFT_SPEC


def _mk(model, qp, *, spec=None, draft=None, cache_len=64, block_size=8,
        slots=3, prefix_cache=True, kv_quant=False, n_blocks=0,
        token_budget=0, seg_width=1, temperature=0.0):
    return ServingEngine(
        model, qp,
        ServeConfig(cache_len=cache_len, cache_dtype="float32",
                    block_size=block_size, prefill_chunk=4, kv_quant=kv_quant,
                    n_blocks=n_blocks, token_budget=token_budget,
                    seg_width=seg_width, prefix_cache=prefix_cache,
                    temperature=temperature, speculative=spec),
        batch_slots=slots, draft=draft,
    )


# ---------------------------------------------------------------------------
# greedy verification rule (pure)
# ---------------------------------------------------------------------------

def test_greedy_verify_semantics():
    # full acceptance: k matches + the bonus token
    assert greedy_verify([5, 6, 7, 9], [5, 6, 7]) == [5, 6, 7, 9]
    # first mismatch stops: the correction is committed, the rest discarded
    assert greedy_verify([5, 8, 7, 9], [5, 6, 7]) == [5, 8]
    assert greedy_verify([4, 6, 7, 9], [5, 6, 7]) == [4]
    # k = 0 (no drafts): plain decode, one committed token
    assert greedy_verify([3], []) == [3]
    # EOS is absorbing even when it matches the draft
    assert greedy_verify([5, 0, 7, 9], [5, 0, 7], eos_id=0) == [5, 0]
    # EOS as the bonus token
    assert greedy_verify([5, 6, 7, 0], [5, 6, 7], eos_id=0) == [5, 6, 7, 0]
    # every committed prefix token equals its draft (cache-validity invariant)
    for targets, drafts in [([5, 6, 7, 9], [5, 6, 7]), ([5, 8, 7, 9], [5, 6, 7])]:
        committed = greedy_verify(targets, drafts)
        assert committed[:-1] == drafts[: len(committed) - 1]


# ---------------------------------------------------------------------------
# token identity: the tentpole acceptance criterion
# ---------------------------------------------------------------------------

def test_spec_identical_mixed_online_traffic_prefix_on_and_off(small_lm, w3_draft):
    """Greedy speculative output == non-speculative greedy on mixed traffic
    with online arrivals, with prefix sharing both ON and OFF — regardless
    of draft quality (the W3 draft rejects plenty on this untrained model)."""
    cfg, model, params, qp = small_lm
    system = [3, 1, 4, 1, 5, 9, 2, 6]  # one shared full block at bs=8
    prompts = [system + [40 + i, 50 + i] for i in range(3)] + \
              [[(7 * i + j) % cfg.vocab_size or 1 for j in range(n)]
               for i, n in enumerate([13, 2, 9])]
    budgets = [5, 8, 3, 6, 2, 7]
    for prefix_cache in (True, False):
        base = _mk(model, qp, prefix_cache=prefix_cache)
        sched = base.scheduler
        want, rid_of = {}, {}
        rid_of[sched.submit(prompts[0], budgets[0], salt=0)] = 0
        rid_of[sched.submit(prompts[1], budgets[1], salt=1)] = 1
        nxt, steps, res = 2, 0, {}
        while sched.step(res) or nxt < len(prompts):
            steps += 1
            if nxt < len(prompts) and steps % 2 == 0:
                rid_of[sched.submit(prompts[nxt], budgets[nxt], salt=nxt)] = nxt
                nxt += 1
        want = {rid_of[r]: v for r, v in res.items()}

        eng = _mk(model, qp, spec=SpeculativeConfig(k=3), draft=w3_draft,
                  prefix_cache=prefix_cache)
        sched = eng.scheduler
        rid_of, res = {}, {}
        rid_of[sched.submit(prompts[0], budgets[0], salt=0)] = 0
        rid_of[sched.submit(prompts[1], budgets[1], salt=1)] = 1
        nxt, steps = 2, 0
        while sched.step(res) or nxt < len(prompts):
            steps += 1
            if nxt < len(prompts) and steps % 2 == 0:
                rid_of[sched.submit(prompts[nxt], budgets[nxt], salt=nxt)] = nxt
                nxt += 1
        got = {rid_of[r]: v for r, v in res.items()}
        assert got == want, f"prefix_cache={prefix_cache}"
        st = eng.stats
        assert st["drafted_tokens"] > 0 and st["spec_rounds"] > 0
        if prefix_cache:
            assert st["prefix_hit_tokens"] > 0  # sharing really engaged


def test_spec_identity_draft_accepts_everything(small_lm):
    """A draft with the target's own params always agrees with the target's
    argmax, so every drafted token is accepted (acceptance rate 1.0) and each
    verify round commits k + 1 tokens."""
    cfg, model, params, qp = small_lm
    prompts = [[1, 2, 3, 4, 5], [6, 9], [7, 8, 9, 10]]
    want = _mk(model, qp).generate(prompts, max_new_tokens=8)
    eng = _mk(model, qp, spec=SpeculativeConfig(k=3), draft=(model, qp))
    assert eng.generate(prompts, max_new_tokens=8) == want
    st = eng.stats
    assert st["accepted_tokens"] == st["drafted_tokens"] > 0
    assert st["rolled_back_tokens"] == 0
    assert st["acceptance_rate"] == 1.0
    # full acceptance: decoding a budget of 8 takes ~2 verify rounds, not 8
    assert st["spec_rounds"] < 8 * len(prompts)


def test_spec_partial_acceptance_rolls_back(small_lm, w3_draft):
    """The W3 draft disagrees often on an untrained model: rollbacks must
    fire, counters must reconcile, and output must still be identical."""
    cfg, model, params, qp = small_lm
    prompts = [[1, 2, 3, 4, 5, 6, 7], [4, 5], [6, 9, 1]]
    want = _mk(model, qp).generate(prompts, max_new_tokens=10)
    eng = _mk(model, qp, spec=SpeculativeConfig(k=3), draft=w3_draft)
    got = eng.generate(prompts, max_new_tokens=10)
    assert got == want
    st = eng.stats
    assert st["rolled_back_tokens"] > 0, "W3 draft never disagreed (suspicious)"
    assert st["drafted_tokens"] == st["accepted_tokens"] + st["rolled_back_tokens"]
    # generated tokens reconcile: each request's first token is sampled at
    # prefill completion, then every verify round commits accepted + 1
    assert sum(len(o) for o in got) == \
        st["accepted_tokens"] + st["spec_rounds"] + len(prompts)
    assert 0.0 < st["acceptance_rate"] < 1.0


def test_spec_eos_mid_verify_is_absorbing(small_lm):
    """An EOS accepted (or corrected to) mid-segment finishes the request:
    outputs are exactly max_new_tokens, eos-padded, identical to non-spec."""
    cfg, model, params, qp = small_lm
    prompts = [[1, 2, 3], [5, 6], [9, 9, 9, 9]]
    # greedy on the untrained model repeats tokens; use each prompt's own
    # second greedy token as EOS so the stop fires mid-stream for some row
    base = _mk(model, qp)
    free = base.generate(prompts, max_new_tokens=6)
    eos = free[0][1]
    want = _mk(model, qp).generate(prompts, max_new_tokens=6, eos_id=eos)
    eng = _mk(model, qp, spec=SpeculativeConfig(k=3), draft=(model, qp))
    got = eng.generate(prompts, max_new_tokens=6, eos_id=eos)
    assert got == want
    for o in got:
        assert len(o) == 6
        if eos in o:
            assert all(t == eos for t in o[o.index(eos):])


def test_spec_int4_kv_pool_identical(small_lm, w3_draft):
    """Verification through the int4 K-Means target pool: deterministic
    assignment keeps speculative == non-speculative even with quantized KV."""
    cfg, model, params, qp = small_lm
    prompts = [[3, 1, 4, 1, 5], [9, 2, 6], [8, 8]]
    want = _mk(model, qp, kv_quant=True, cache_len=32).generate(
        prompts, max_new_tokens=6)
    eng = _mk(model, qp, kv_quant=True, cache_len=32,
              spec=SpeculativeConfig(k=2), draft=w3_draft)
    assert eng.generate(prompts, max_new_tokens=6) == want


def test_spec_preemption_deterministic(small_lm, w3_draft):
    """A pool too small for all slots forces preemption while verify segments
    grow blocks; draft state resets with the slot and outputs don't change."""
    cfg, model, params, qp = small_lm
    prompts = [[1, 2, 3, 4, 5, 6, 7], [4, 5], [6, 9, 1], [7, 8, 9, 10]]
    mk = lambda n_blocks: _mk(model, qp, cache_len=32, block_size=4,
                              n_blocks=n_blocks, prefix_cache=False,
                              spec=SpeculativeConfig(k=2), draft=w3_draft)
    big, small = mk(0), mk(8)
    a = big.generate(prompts, max_new_tokens=8)
    b = small.generate(prompts, max_new_tokens=8)
    assert small.scheduler.stats["preemptions"] > 0
    assert big.scheduler.stats["preemptions"] == 0
    assert a == b
    assert a == _mk(model, qp, cache_len=32, block_size=4,
                    prefix_cache=False).generate(prompts, max_new_tokens=8)


def test_spec_rollback_frees_blocks_and_invariants(small_lm, w3_draft):
    """Seeded random speculative traffic over a small pool with prefix
    sharing: after every step each block is held by exactly ``refcount``
    running requests and allocatable + live == pool — i.e. rollback's block
    frees are exact (no leak, no double-free), including when verify
    segments, COW, preemption, and prefix aliasing all interleave."""
    cfg, model, params, qp = small_lm
    eng = _mk(model, qp, cache_len=16, block_size=4, n_blocks=10,
              token_budget=24, slots=3, spec=SpeculativeConfig(k=2),
              draft=w3_draft)
    sched, alloc = eng.scheduler, eng.scheduler.allocator
    rng = np.random.RandomState(0)
    prefix = [7, 7, 7, 7]
    results: dict[int, list[int]] = {}
    pending = 12
    while pending or sched._running or sched._queue:
        if pending and (rng.rand() < 0.5
                        or not (sched._running or sched._queue)):
            tail = [int(t) for t in rng.randint(1, 200, int(rng.randint(1, 6)))]
            prompt = (list(prefix) if rng.rand() < 0.6 else []) + tail
            sched.submit(prompt, int(rng.randint(1, 7)))
            pending -= 1
        if sched._running or sched._queue:
            sched.step(results)
        held = [b for r in sched._running for b in r.blocks]
        for b in range(sched.pcfg.n_blocks):
            assert alloc.refcount(b) == held.count(b), (
                f"block {b}: {alloc.refcount(b)} refs, {held.count(b)} holders"
            )
        assert alloc.n_free + len(set(held)) == sched.pcfg.n_blocks
    assert len(results) == 12
    assert sched.stats["drafted_tokens"] > 0
    assert alloc.n_free == sched.pcfg.n_blocks  # drained: nothing leaked


def test_spec_draft_artifact_load_path(small_lm, tmp_path):
    """The production path: the draft rides in via
    ``speculative.draft_artifact`` and is loaded with load_quantized."""
    from repro.core.artifact import save_quantized

    cfg, model, params, qp = small_lm
    d = tmp_path / "draft_w3"
    save_quantized(d, cfg, DEFAULT_DRAFT_SPEC,
                   quantize_model(model, params, DEFAULT_DRAFT_SPEC))
    prompts = [[1, 2, 3, 4], [5, 6]]
    want = _mk(model, qp).generate(prompts, max_new_tokens=5)
    eng = _mk(model, qp,
              spec=SpeculativeConfig(k=2, draft_artifact=str(d)))
    assert eng.generate(prompts, max_new_tokens=5) == want
    assert eng.stats["drafted_tokens"] > 0
    # draft KV policy came from the artifact's spec (int4 draft pool)
    assert "pages_k_idx" in (eng.scheduler.draft.pools
                             if isinstance(eng.scheduler.draft.pools, dict)
                             else eng.scheduler.draft.pools[0])


# ---------------------------------------------------------------------------
# config validation
# ---------------------------------------------------------------------------

def test_spec_temperature_greedy_only_gate(small_lm, w3_draft):
    cfg, model, params, qp = small_lm
    with pytest.raises(NotImplementedError, match="rejection-sampling"):
        _mk(model, qp, spec=SpeculativeConfig(k=2), draft=w3_draft,
            temperature=1.0)


def test_spec_config_validation(small_lm, w3_draft):
    cfg, model, params, qp = small_lm
    with pytest.raises(ValueError, match="k must be >= 1"):
        SpeculativeConfig(k=0)
    with pytest.raises(ValueError, match="draft"):
        _mk(model, qp, spec=SpeculativeConfig(k=2))  # no draft, no artifact
    with pytest.raises(ValueError, match="token_budget"):
        # 2 rows of width 3 < 3 slots
        _mk(model, qp, spec=SpeculativeConfig(k=2), draft=w3_draft,
            token_budget=6, slots=3)


# ---------------------------------------------------------------------------
# segment-aware prefill packing (seg_width > 1, no speculation)
# ---------------------------------------------------------------------------

def test_seg_width_packing_matches_flat_layout(small_lm):
    """Prefill rows grouped seg_width tokens per kernel segment (one
    block-table gather per row) must be token-identical to the flat S=1
    packed layout, and still mix prefill with decode in one step."""
    cfg, model, params, qp = small_lm
    prompts = [[(5 * i + j) % cfg.vocab_size or 1 for j in range(n)]
               for i, n in enumerate([11, 3, 7, 14, 2])]
    budgets = [4, 6, 3, 5, 7]
    flat = _mk(model, qp, token_budget=12, seg_width=1)
    want = flat.generate(prompts, max_new_tokens=budgets)
    seg = _mk(model, qp, token_budget=12, seg_width=4)
    got = seg.generate(prompts, max_new_tokens=budgets)
    assert got == want
    assert seg.scheduler.seg_width == 4 and seg.scheduler.rows == 3
    assert seg.scheduler.stats["mixed_steps"] > 0
    # same cell budget, 4x fewer rows: every packed step does 3 block-table
    # gathers instead of 12 (the gather dedupe the segment layout buys)
    assert seg.scheduler.token_budget == flat.scheduler.token_budget == 12
    assert seg.scheduler.rows < flat.scheduler.rows


def test_seg_width_prefix_sharing_identical(small_lm):
    """seg_width > 1 composes with prefix sharing + COW (multi-token segment
    writes into shared blocks trigger the same copy-on-write pass)."""
    cfg, model, params, qp = small_lm
    system = [3, 1, 4, 1, 5, 9, 2, 6]
    prompts = [system + [40 + i] for i in range(3)] + [[80], [81, 82]]
    # slots=2: the third sharer is admitted after the leader's blocks are
    # registered, so the prefix cache actually gets hit
    want = _mk(model, qp, prefix_cache=False, seg_width=3, slots=2).generate(
        prompts, max_new_tokens=5)
    assert _mk(model, qp, prefix_cache=True, seg_width=1, slots=2).generate(
        prompts, max_new_tokens=5) == want
    eng = _mk(model, qp, prefix_cache=True, seg_width=3, slots=2)
    assert eng.generate(prompts, max_new_tokens=5) == want
    assert eng.stats["prefix_hit_tokens"] > 0
