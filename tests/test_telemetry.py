"""Serving telemetry tests: metric-type semantics, lifecycle timelines with
a fake clock, engine counter assertions against known traffic, Perfetto
export validity, the telemetry-off guard (identical jaxpr + dispatch count),
fallback-engine counters, and the StepMonitor/StreamingStats unification."""

import json

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs.base import get_smoke_config
from repro.core.qlinear import QLinearConfig
from repro.core.quantspec import QuantSpec
from repro.models.model import build, quantize_model
from repro.serving.engine import ServeConfig, ServingEngine
from repro.serving.paged_cache import BlockAllocator, chain_hash
from repro.serving.speculative import make_packed_fn
from repro.serving.telemetry import (
    NULL_TELEMETRY,
    Counter,
    Gauge,
    Histogram,
    MetricsRegistry,
    NullTelemetry,
    StreamingStats,
    Telemetry,
    TelemetryConfig,
    linear_buckets,
    log_buckets,
    make_telemetry,
)

QSPEC = QuantSpec(base=QLinearConfig(detection="none"))


@pytest.fixture(scope="module")
def small_lm():
    cfg = get_smoke_config("llama3_2_1b")
    model = build(cfg)
    params = model.init(jax.random.PRNGKey(0))
    return cfg, model, params, quantize_model(model, params, QSPEC)


def _fake_clock():
    t = [0.0]

    def clock():
        return t[0]

    return t, clock


# ---------------------------------------------------------------------------
# metric types
# ---------------------------------------------------------------------------

def test_counter_semantics():
    c = Counter("x")
    c.add()
    c.add(4)
    c.add(0.5)  # time totals are float counters
    assert c.value == 5.5
    c.reset()
    assert c.value == 0


def test_gauge_set_max_and_callback():
    g = Gauge("g")
    g.set(3.0)
    g.set_max(2.0)  # lower: ignored
    assert g.value == 3.0
    g.set_max(7.0)
    assert g.value == 7.0
    backing = [1, 2, 3]
    live = Gauge("live", fn=lambda: len(backing))
    assert live.value == 3
    backing.append(4)
    assert live.value == 4  # evaluated lazily, not captured


def test_histogram_observe_and_percentiles():
    h = Histogram("h", [1.0, 2.0, 4.0])
    for v in (0.5, 1.5, 1.5, 3.0, 100.0):  # 100 -> the +inf overflow bucket
        h.observe(v)
    assert h.count == 5 and h.counts == [1, 2, 1, 1]
    assert h.min == 0.5 and h.max == 100.0
    s = h.summary()
    assert s["count"] == 5 and s["sum"] == pytest.approx(106.5)
    # percentiles are interpolated but always clamped to observed min/max
    for q in (0, 50, 95, 99, 100):
        assert h.min <= h.percentile(q) <= h.max
    assert h.percentile(40) <= 2.0  # lands in the (1, 2] bucket


def test_histogram_constant_series_percentile_exact():
    h = Histogram("h", log_buckets(1e-3, 10.0))
    for _ in range(10):
        h.observe(0.25)
    # min == max == 0.25 so clamping makes every percentile exact
    assert h.percentile(50) == pytest.approx(0.25)
    assert h.percentile(99) == pytest.approx(0.25)
    assert h.summary()["count"] == 0 or h.summary()["mean"] == pytest.approx(0.25)


def test_histogram_empty_and_bad_bounds():
    h = Histogram("h", [1.0, 2.0])
    assert h.percentile(95) == 0.0 and h.summary() == {"count": 0}
    with pytest.raises(ValueError, match="ascending"):
        Histogram("bad", [2.0, 1.0])


def test_bucket_helpers():
    lb = log_buckets(1e-3, 1e3, per_decade=2)
    assert lb[0] == pytest.approx(1e-3) and lb[-1] == pytest.approx(1e3)
    assert all(b > a for a, b in zip(lb, lb[1:]))
    assert linear_buckets(0.0, 1.0, 4) == pytest.approx([0.25, 0.5, 0.75, 1.0])
    with pytest.raises(ValueError):
        log_buckets(1.0, 0.5)
    with pytest.raises(ValueError):
        linear_buckets(0.0, 1.0, 0)


def test_registry_get_or_create_and_snapshot():
    r = MetricsRegistry()
    assert r.counter("a") is r.counter("a")  # one object per name
    r.counter("a").add(3)
    r.gauge("g").set(1.5)
    r.histogram("h", [1.0]).observe(0.5)
    snap = r.snapshot()
    assert snap["counters"]["a"] == 3
    assert snap["gauges"]["g"] == 1.5
    assert snap["histograms"]["h"]["count"] == 1
    r.reset()
    assert r.counter("a").value == 0 and r.histogram("h").count == 0


# ---------------------------------------------------------------------------
# config + null object
# ---------------------------------------------------------------------------

def test_telemetry_config_parse():
    assert TelemetryConfig.parse(None).level == "off"
    assert TelemetryConfig.parse(False).level == "off"
    assert TelemetryConfig.parse(True).level == "metrics"
    assert TelemetryConfig.parse("trace").level == "trace"
    cfg = TelemetryConfig(level="metrics", fence=True, step_ring=8)
    assert TelemetryConfig.parse(cfg) is cfg
    with pytest.raises(ValueError, match="level"):
        TelemetryConfig(level="verbose")
    with pytest.raises(ValueError):
        TelemetryConfig(step_ring=0)
    with pytest.raises(TypeError):
        TelemetryConfig.parse(42)


def test_make_telemetry_levels():
    assert make_telemetry("off") is NULL_TELEMETRY
    assert make_telemetry(None) is NULL_TELEMETRY
    assert isinstance(make_telemetry("metrics"), Telemetry)
    assert make_telemetry("trace").tracing
    with pytest.raises(ValueError, match="NullTelemetry"):
        Telemetry(TelemetryConfig(level="off"))


def test_null_telemetry_is_inert(tmp_path):
    n = NullTelemetry()
    n.request_submitted(1, 5)
    n.first_token(1)
    n.tokens_committed(1, 3)
    n.request_finished(1)
    n.step_record(host_s=1, device_s=1, cells=1, budget=1)
    assert n.counter("x").value == 0
    n.counter("x").add(5)
    assert n.counter("x").value == 0  # no-op metric
    assert n.snapshot() == {"level": "off"}
    with n.annotate("span"):
        pass
    p = n.export_chrome_trace(tmp_path / "t.json")
    assert json.loads(p.read_text())["traceEvents"] == []


# ---------------------------------------------------------------------------
# lifecycle timeline semantics (fake clock)
# ---------------------------------------------------------------------------

def test_request_lifecycle_histograms_and_timeline():
    t, clock = _fake_clock()
    tel = Telemetry(TelemetryConfig(level="trace"), clock=clock)
    tel.request_submitted(7, n_prompt=4)
    t[0] = 1.0
    tel.request_admitted(7, prefix_hit_tokens=2)
    t[0] = 2.5
    tel.first_token(7)
    t[0] = 3.5  # a verify round commits 2 tokens simultaneously
    tel.tokens_committed(7, 2)
    t[0] = 4.0
    tel.request_finished(7, n_generated=3)
    assert tel.hist_queue.count == 1 and tel.hist_queue.sum == pytest.approx(1.0)
    assert tel.hist_ttft.count == 1 and tel.hist_ttft.sum == pytest.approx(2.5)
    # ITL amortizes the round over its committed tokens: two samples of 0.5
    assert tel.hist_itl.count == 2 and tel.hist_itl.sum == pytest.approx(1.0)
    assert tel.hist_e2e.sum == pytest.approx(4.0)
    [tr] = tel.completed
    assert tr.t_admit == 1.0 and tr.t_first_token == 2.5 and tr.t_finish == 4.0
    assert tr.n_generated == 3 and tr.prefix_hit_tokens == 2
    names = [name for _, name, _ in tr.events]
    assert names == ["enqueue", "admit", "first_token", "finish"]


def test_readmission_keeps_first_admit_and_ttft_idempotent():
    t, clock = _fake_clock()
    tel = Telemetry(TelemetryConfig(level="metrics"), clock=clock)
    tel.request_submitted(1, 2)
    t[0] = 1.0
    tel.request_admitted(1)
    tel.first_token(1)
    t[0] = 2.0
    tel.request_preempted(1)
    t[0] = 5.0
    tel.request_admitted(1)  # re-admission must not re-observe queue wait
    tel.first_token(1)  # nor TTFT
    assert tel.hist_queue.count == 1 and tel.hist_queue.sum == pytest.approx(1.0)
    assert tel.hist_ttft.count == 1 and tel.hist_ttft.sum == pytest.approx(1.0)
    assert tel.counter("serving_preemptions").value == 1


def test_step_ring_is_bounded():
    tel = Telemetry(TelemetryConfig(level="metrics", step_ring=4))
    for i in range(10):
        tel.step_record(host_s=0.1, device_s=0.2, cells=i, budget=16)
    assert len(tel.steps) == 4
    assert [s["cells"] for s in tel.steps] == [6, 7, 8, 9]  # newest kept
    assert tel.hist_step_util.count == 10  # histograms see every step


def test_telemetry_reset_clears_everything():
    tel = Telemetry(TelemetryConfig(level="trace"))
    tel.request_submitted(1, 3)
    tel.counter("c").add(5)
    tel.step_record(host_s=0.1, device_s=0.1, cells=1, budget=2)
    tel.reset()
    assert tel.counter("c").value == 0
    assert len(tel.steps) == 0 and len(tel._live) == 0
    assert tel.hist_step_util.count == 0


# ---------------------------------------------------------------------------
# StreamingStats / StepMonitor unification
# ---------------------------------------------------------------------------

def test_streaming_stats_window_and_summary():
    s = StreamingStats(window=4)
    for v in (1.0, 2.0, 3.0, 4.0, 5.0):
        s.record(v)
    assert s.times == [2.0, 3.0, 4.0, 5.0]  # windowed
    assert s.median() == pytest.approx(3.5)
    assert s.mean() == pytest.approx(3.5)
    assert s.percentile(95) == 5.0
    assert s.summary()["n"] == 4
    assert StreamingStats().summary() == {}


def test_step_monitor_built_on_streaming_stats():
    from repro.distributed import fault_tolerance as ft

    assert ft.StreamingStats is StreamingStats  # re-export, not a copy
    mon = ft.StepMonitor(window=16, straggler_factor=2.0)
    assert isinstance(mon.stats, StreamingStats)
    for _ in range(12):
        mon.record(0.1)
    assert not mon.is_straggler(0.15)
    assert mon.is_straggler(0.5)
    mon.record(0.5)
    assert mon.straggler_count == 1
    assert mon.summary()["median_s"] == pytest.approx(0.1)
    assert mon.times[-1] == 0.5 and mon.window == 16


# ---------------------------------------------------------------------------
# allocator gauges
# ---------------------------------------------------------------------------

def test_allocator_gauges_and_eviction_counter():
    tel = Telemetry(TelemetryConfig(level="metrics"))
    a = BlockAllocator(3, prefix_cache=True, telemetry=tel)
    got = a.alloc(2)
    g = tel.registry.snapshot()["gauges"]
    assert g["serving_blocks_free"] == 1
    assert g["serving_blocks_live"] == 2
    assert g["serving_blocks_cached"] == 0
    a.register(chain_hash(b"s", [1]), got[0])
    a.free(got)
    g = tel.registry.snapshot()["gauges"]
    assert g["serving_blocks_cached"] == 1 and g["serving_blocks_live"] == 0
    assert a.blocks_allocated == 2 and a.blocks_freed == 2
    a.alloc(3)  # must evict the cached block
    assert tel.counter("serving_block_evictions_pressure").value == 1
    assert a.evictions == 1  # legacy attribute stays in sync


# ---------------------------------------------------------------------------
# engine integration: counters vs known traffic, timelines, Perfetto
# ---------------------------------------------------------------------------

def _mk_engine(model, qp, level, **kw):
    kw.setdefault("cache_len", 32)
    kw.setdefault("block_size", 8)
    kw.setdefault("prefill_chunk", 4)
    return ServingEngine(model, qp,
                         ServeConfig(cache_dtype="float32", telemetry=level,
                                     **kw),
                         batch_slots=2)


def test_engine_counters_match_known_traffic(small_lm):
    cfg, model, params, qp = small_lm
    eng = _mk_engine(model, qp, "metrics")
    prompts = [[1, 2, 3], [4, 5], [6, 7, 8, 9]]
    outs = eng.generate(prompts, max_new_tokens=4)
    assert all(len(o) == 4 for o in outs)
    snap = eng.snapshot()
    c = snap["counters"]
    assert c["serving_requests_submitted"] == 3
    assert c["serving_requests_finished"] == 3
    assert c["serving_packed_steps"] > 0
    # first token per request comes from prefill logits; the rest decode
    assert c["serving_decode_slot_tokens"] == 3 * (4 - 1)
    # all prompt tokens prefilled (no prefix cache hits on distinct prompts)
    assert c["serving_prefill_tokens"] == sum(len(p) for p in prompts)
    # legacy dict is rebuilt from the same registry
    st = eng.stats
    assert st["packed_steps"] == c["serving_packed_steps"]
    assert st["prefill_tokens"] == c["serving_prefill_tokens"]
    assert snap["requests"]["ttft_s"]["count"] == 3
    assert snap["requests"]["itl_s"]["count"] == 3 * 3  # 3 post-first tokens
    assert snap["steps"]["recorded"] == c["serving_packed_steps"]


def test_engine_prefix_and_cow_counters_mid_run(small_lm):
    """Prefix/COW counters flow through the registry mid-run, matching the
    legacy stats keys exactly."""
    cfg, model, params, qp = small_lm
    eng = _mk_engine(model, qp, "metrics", block_size=4, prefix_cache=True)
    system = [3, 1, 4, 1, 5, 9, 2, 6]  # two full blocks
    prompts = [system + [40 + i] for i in range(3)]
    eng.generate(prompts, max_new_tokens=3)
    c = eng.snapshot()["counters"]
    # with 2 slots, the first two admit before any blocks are registered;
    # the late-admitted follower aliases the leader's cached system prefix
    assert c["serving_prefix_hits"] >= 1
    assert c["serving_prefix_hit_tokens"] >= len(system)
    st = eng.stats
    assert st["prefix_hits"] == c["serving_prefix_hits"]
    assert st["cow_copies"] == c["serving_cow_copies"]
    g = eng.snapshot()["gauges"]
    # after drain everything is reclaimable again
    assert g["serving_blocks_live"] == 0
    assert g["serving_queue_depth"] == 0 and g["serving_running_requests"] == 0


def test_trace_level_timelines_complete_and_perfetto_valid(small_lm, tmp_path):
    cfg, model, params, qp = small_lm
    eng = _mk_engine(model, qp, "trace")
    prompts = [[1, 2, 3], [4, 5]]
    eng.generate(prompts, max_new_tokens=3)
    tel = eng.telemetry
    assert len(tel.completed) == 2 and not tel._live
    for tr in tel.completed:  # timeline completeness
        assert tr.t_admit is not None
        assert tr.t_first_token is not None
        assert tr.t_finish is not None
        assert tr.t_enqueue <= tr.t_admit <= tr.t_first_token <= tr.t_finish
        assert tr.n_generated == 3
    p = eng.export_chrome_trace(tmp_path / "trace.json")
    data = json.loads(p.read_text())  # valid JSON is the gate
    ev = data["traceEvents"]
    assert isinstance(ev, list) and ev
    for e in ev:
        assert "ph" in e and "pid" in e
        if e["ph"] == "X":
            assert "ts" in e and "dur" in e and e["dur"] >= 0
    # engine packed-step lane + one lane per request
    assert any(e["ph"] == "X" and e["name"] == "packed_step" for e in ev)
    req_tids = {e["tid"] for e in ev if e["pid"] == 1 and e["ph"] == "X"}
    assert len(req_tids) == 2
    assert any(e["name"] == "decode" for e in ev if e["pid"] == 1)


def test_telemetry_off_identical_jaxpr_and_dispatch_count(small_lm):
    """The off guard: telemetry never wraps traced code, so the packed step
    lowers to the identical jaxpr and the scheduler issues exactly the same
    device dispatches with telemetry off as with the metrics default."""
    cfg, model, params, qp = small_lm
    prompts = [[1, 2, 3, 4, 5], [6, 7], [8, 9, 10]]

    def run(level):
        eng = _mk_engine(model, qp, level)
        sched = eng.scheduler
        inner, calls = sched._packed_fn, []
        sched._packed_fn = lambda *a: (calls.append(a), inner(*a))[1]
        out = eng.generate(prompts, max_new_tokens=4)
        return out, calls

    out_off, calls_off = run("off")
    out_on, calls_on = run("metrics")
    assert out_off == out_on  # telemetry never changes scheduling decisions
    assert len(calls_off) == len(calls_on) > 0  # same dispatch count
    # identical jaxpr for the packed step given the same first-call args
    fn = make_packed_fn(model)
    jx = [str(jax.make_jaxpr(fn)(*calls[0])) for calls in (calls_off, calls_on)]
    assert jx[0] == jx[1]


def test_telemetry_off_stats_all_zero(small_lm):
    cfg, model, params, qp = small_lm
    eng = _mk_engine(model, qp, "off")
    eng.generate([[1, 2, 3]], max_new_tokens=3)
    assert eng.telemetry is NULL_TELEMETRY
    st = eng.stats  # legacy keys still exist, all zero, never raising
    assert st["packed_steps"] == 0 and st["preemptions"] == 0
    assert eng.snapshot() == {"level": "off"}


def test_fallback_engine_counters(small_lm):
    cfg, model, params, qp = small_lm
    eng = ServingEngine(model, qp,
                        ServeConfig(cache_len=32, cache_dtype="float32",
                                    paged=False),
                        batch_slots=2)
    prompts = [[1, 2, 3, 4], [5, 6], [7]]  # > slots: two batches, padding
    outs = eng.generate(prompts, max_new_tokens=4)
    assert all(len(o) == 4 for o in outs)
    st = eng.stats
    assert st["prefills"] == 2  # ceil(3 prompts / 2 slots)
    assert st["steps"] == 2 * 3  # (max_new - 1) decode steps per batch
    assert st["tokens"] == 3 * 4  # served tokens count real requests only
    assert st["prompt_tokens"] > 0
    assert 0.0 <= st["pad_fraction"] < 1.0
    assert st["pad_tokens"] == 2 + 0  # [5,6] padded to 4, [7] alone
    # same registry as the paged path
    assert eng.snapshot()["counters"]["serving_fallback_prefills"] == 2


def test_speculative_counters_through_registry(small_lm):
    """A speculative engine's acceptance accounting flows through the
    registry (draft steps, acceptance histogram, per-round counters)."""
    cfg, model, params, qp = small_lm
    from repro.serving.speculative import SpeculativeConfig

    eng = ServingEngine(model, qp,
                        ServeConfig(cache_len=32, cache_dtype="float32",
                                    block_size=8, prefill_chunk=4,
                                    speculative=SpeculativeConfig(k=2),
                                    telemetry="metrics"),
                        batch_slots=2, draft=(model, params))
    eng.generate([[1, 2, 3], [4, 5]], max_new_tokens=5)
    snap = eng.snapshot()
    c = snap["counters"]
    assert c["serving_spec_rounds"] > 0
    assert c["serving_drafted_tokens"] == \
        c["serving_accepted_tokens"] + c["serving_rolled_back_tokens"]
    assert c["serving_draft_steps"] == eng.scheduler.draft.steps
    h = snap["histograms"]["serving_spec_accepted_per_round"]
    assert h["count"] == c["serving_spec_rounds"]
    assert snap["histograms"]["serving_draft_round_s"]["count"] > 0
    assert c["serving_draft_time_s"] > 0 and c["serving_target_time_s"] > 0
