"""Per-architecture smoke tests (assignment deliverable f).

Every assigned arch instantiates a REDUCED same-family config and runs:
  1. one forward pass (shape + finiteness)
  2. one train step (loss finite, params update)
  3. incremental decode == full forward (KV-cache correctness)
  4. quantized (W4A4 + outlier) forward (the paper's serving path)
FULL configs are only exercised via the dry-run (ShapeDtypeStruct, no alloc).
"""

import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs.base import SHAPES, get_config, get_smoke_config, list_archs
from repro.core.qlinear import QLinearConfig
from repro.models.model import build
from repro.optim.adamw import AdamWConfig
from repro.train.trainer import TrainConfig, init_train_state, make_train_step

ARCHS = list_archs()


def _batch(cfg, b=2, s=16, seed=0):
    key = jax.random.PRNGKey(seed)
    out = {"tokens": jax.random.randint(key, (b, s), 0, cfg.vocab_size)}
    if cfg.family == "vlm":
        out["image_embeds"] = jax.random.normal(key, (b, cfg.n_img_tokens, cfg.d_model))
    return out


@pytest.fixture(scope="module")
def smoke_models():
    cache = {}
    for arch in ARCHS:
        cfg = get_smoke_config(arch)
        m = build(cfg)
        cache[arch] = (cfg, m, m.init(jax.random.PRNGKey(0)))
    return cache


@pytest.mark.parametrize("arch", ARCHS)
def test_forward_shapes_and_finite(arch, smoke_models):
    cfg, m, params = smoke_models[arch]
    out = m.apply(params, _batch(cfg))
    assert out.logits.shape == (2, 16, cfg.vocab_padded)
    assert bool(jnp.isfinite(out.logits).all())
    if cfg.family == "moe":
        assert out.aux_loss is not None and bool(jnp.isfinite(out.aux_loss))


@pytest.mark.parametrize("arch", ARCHS)
def test_train_step(arch, smoke_models):
    cfg, m, _ = smoke_models[arch]
    tc = TrainConfig(optimizer=AdamWConfig(lr=1e-3), microbatches=2)
    state = init_train_state(m, jax.random.PRNGKey(1), tc)
    step = jax.jit(make_train_step(m, tc))
    batch = _batch(cfg, b=4, s=17)  # 16 + 1 label shift
    new_state, metrics = step(state, batch)
    assert bool(jnp.isfinite(metrics["loss"]))
    # at least one parameter changed
    diffs = jax.tree.map(
        lambda a, b: float(jnp.max(jnp.abs(a.astype(jnp.float32) - b.astype(jnp.float32)))),
        state["params"], new_state["params"],
    )
    assert max(jax.tree.leaves(diffs)) > 0


@pytest.mark.parametrize("arch", ARCHS)
def test_incremental_decode_matches_full(arch, smoke_models):
    cfg, m, params = smoke_models[arch]
    b, s = 2, 8
    full = _batch(cfg, b, s + 1, seed=3)
    out_full = m.apply(params, full)
    caches = m.init_caches(b, cache_len=32, dtype=jnp.float32)
    pre = {**full, "tokens": full["tokens"][:, :s]}
    out_p = m.apply(params, pre, positions=jnp.arange(s, dtype=jnp.int32), caches=caches)
    dec = {**full, "tokens": full["tokens"][:, s : s + 1]}
    out_d = m.apply(params, dec, positions=jnp.arange(s, s + 1, dtype=jnp.int32),
                    caches=out_p.caches)
    np.testing.assert_allclose(
        out_d.logits[:, 0], out_full.logits[:, s], rtol=1e-4, atol=1e-4
    )


@pytest.mark.parametrize("arch", ARCHS)
def test_quantized_forward(arch, smoke_models):
    from repro.core.quantspec import QuantSpec
    from repro.models.model import quantize_model

    cfg, m, params = smoke_models[arch]
    qp = quantize_model(m, params, QuantSpec(base=QLinearConfig(outlier_frac=0.01)))
    out = m.apply(qp, _batch(cfg))
    assert bool(jnp.isfinite(out.logits).all())


@pytest.mark.parametrize("arch", ARCHS)
def test_full_config_matches_assignment(arch):
    """The FULL configs carry the exact published dimensions."""
    cfg = get_config(arch)
    spec = {
        "granite_moe_3b_a800m": (32, 1536, 24, 8, 512, 49155),
        "qwen2_moe_a2_7b": (24, 2048, 16, 16, 1408, 151936),
        "h2o_danube_1_8b": (24, 2560, 32, 8, 6912, 32000),
        "llama3_2_1b": (16, 2048, 32, 8, 8192, 128256),
        "command_r_plus_104b": (64, 12288, 96, 8, 33792, 256000),
        "nemotron_4_15b": (32, 6144, 48, 8, 24576, 256000),
        "llama3_2_vision_11b": (40, 4096, 32, 8, 14336, 128256),
        "falcon_mamba_7b": (64, 4096, 1, 1, 0, 65024),
        "musicgen_large": (48, 2048, 32, 32, 8192, 2048),
        "recurrentgemma_2b": (26, 2560, 10, 1, 7680, 256000),
        "oasis_7b": (32, 4096, 32, 32, 11008, 32000),
    }[arch]
    got = (cfg.n_layers, cfg.d_model, cfg.n_heads, cfg.n_kv_heads, cfg.d_ff, cfg.vocab_size)
    assert got == spec, f"{arch}: {got} != {spec}"


def test_moe_extras():
    g = get_config("granite_moe_3b_a800m")
    assert (g.n_experts, g.experts_per_token) == (40, 8)
    q = get_config("qwen2_moe_a2_7b")
    assert (q.n_experts, q.experts_per_token, q.n_shared_experts) == (60, 4, 4)


def test_long_context_support_flags():
    """long_500k runs exactly for the sub-quadratic archs (DESIGN.md §5)."""
    expected_runnable = {"h2o_danube_1_8b", "falcon_mamba_7b", "recurrentgemma_2b"}
    for arch in list_archs(assigned_only=True):
        cfg = get_config(arch)
        assert cfg.supports_long_context() == (arch in expected_runnable), arch


def test_sliding_window_attention_differs_from_full():
    """SWA must actually mask: long-range logits differ from full attention."""
    cfg = get_smoke_config("h2o_danube_1_8b")
    m = build(cfg)
    params = m.init(jax.random.PRNGKey(0))
    batch = _batch(cfg, 1, 48, seed=9)
    out_swa = m.apply(params, batch)
    cfg_full = dataclasses.replace(cfg, sliding_window=0)
    out_full = build(cfg_full).apply(params, batch)
    # early positions identical (inside window), late positions diverge
    assert np.allclose(out_swa.logits[:, : cfg.sliding_window - 1],
                       out_full.logits[:, : cfg.sliding_window - 1], atol=1e-4)
    assert not np.allclose(out_swa.logits[:, -1], out_full.logits[:, -1], atol=1e-4)


def test_quantized_kv_cache_decode_close_to_fp():
    cfg = get_smoke_config("oasis_7b")
    m = build(cfg)
    params = m.init(jax.random.PRNGKey(0))
    b, s = 2, 8
    batch = _batch(cfg, b, s, seed=5)
    pos = jnp.arange(s, dtype=jnp.int32)
    out_fp = m.apply(params, batch, positions=pos,
                     caches=m.init_caches(b, 32, jnp.float32))
    out_q = m.apply(params, batch, positions=pos,
                    caches=m.init_caches(b, 32, jnp.float32, quantized=True))
    # int4 K-Means KV introduces bounded error, not garbage (random-init tiny
    # model with head_dim=16 is the worst case for per-head RMS scaling)
    err = float(jnp.max(jnp.abs(out_fp.logits - out_q.logits)))
    scale = float(jnp.max(jnp.abs(out_fp.logits)))
    assert err < 0.5 * scale and bool(jnp.isfinite(out_q.logits).all())
