"""Allocator-safety fuzz: random admit / alias / grow / truncate / decref /
double-free sequences against the refcounted prefix-sharing
``BlockAllocator``.

The op interpreter (``_run_ops``) checks, after EVERY operation, the two
invariants refcounted sharing depends on (ISSUE 4):

  * a block held by two live requests always has refcount > 1 — verified in
    the strong form ``refcount(b) == number of holders of b``;
  * allocatable + live == pool: ``n_free + |distinct held ids| == n_blocks``
    (``n_free`` counts truly-free AND cached refcount-0 prefix blocks).

It is driven twice: a seeded exhaustive sweep that needs nothing beyond the
standard deps (runs everywhere, including CI), and a hypothesis ``@given``
property over arbitrary op lists when hypothesis is installed. The
scheduler-level randomized-traffic invariant test (real model, preemption +
prefix aliasing + COW) lives in tests/test_serving.py.
"""

from __future__ import annotations

import numpy as np
import pytest

from repro.serving.paged_cache import BlockAllocator, chain_hash, prefix_seed

_SEED = prefix_seed(pool="alloc-fuzz")


def _run_ops(n_blocks: int, ops: list[tuple[int, int]]) -> None:
    """Interpret (op, x) pairs against one allocator, checking invariants
    after every op. ``x`` selects the request / block / size the op acts on
    (modulo whatever is currently legal), so any int sequence is valid."""
    a = BlockAllocator(n_blocks, prefix_cache=True)
    live: dict[int, list[int]] = {}  # rid -> block ids it holds (one ref each)
    published: list[tuple[bytes, int]] = []  # (hash, block) ever registered
    next_rid, next_tok = 0, 0
    for op, x in ops:
        if op == 0:  # admit: all-or-nothing allocation of 1..3 blocks
            got = a.alloc(x % 3 + 1)
            if got is not None:
                held = {b for ids in live.values() for b in ids}
                assert len(set(got)) == len(got), "alloc handed out duplicates"
                assert all(0 <= b < n_blocks for b in got)
                assert not set(got) & held, "alloc handed out a live block"
                live[next_rid] = got
                next_rid += 1
        elif op == 1 and live:  # finish / preempt: decref everything held
            rid = sorted(live)[x % len(live)]
            a.free(list(reversed(live.pop(rid))))
        elif op == 2 and live:  # grow a live request by one block
            rid = sorted(live)[x % len(live)]
            got = a.alloc(1)
            if got is not None:
                assert got[0] not in {b for ids in live.values() for b in ids}
                live[rid] += got
        elif op == 3 and any(live.values()):  # register a held block under
            # a fresh hash (windowed release can leave a request holding
            # zero blocks — skip those, a real request with an empty table
            # has nothing registrable)
            holders = sorted(r for r in live if live[r])
            rid = holders[x % len(holders)]
            bid = live[rid][x % len(live[rid])]
            h = chain_hash(_SEED, [next_tok])
            next_tok += 1
            try:
                fresh = a.register(h, bid)
            except ValueError:
                fresh = False  # already published under an older hash — fine
            if fresh:
                published.append((h, bid))
        elif op == 4 and published:  # alias: a new request joins a prefix
            h, bid = published[x % len(published)]
            if a.lookup(h) == bid:  # still cached/live (not LRU-evicted)
                a.incref(bid)
                live[next_rid] = [bid]
                next_rid += 1
        elif op == 5:  # freeing an unheld block must raise, not corrupt
            held = {b for ids in live.values() for b in ids}
            unheld = [b for b in range(n_blocks) if b not in held]
            if unheld:
                before = a.n_free
                with pytest.raises(ValueError):
                    a.free([unheld[x % len(unheld)]])
                assert a.n_free == before, "rejected free mutated the pool"
        elif op == 6 and live:  # speculative rollback: truncate a suffix
            rid = sorted(live)[x % len(live)]
            keep = x % (len(live[rid]) + 1)
            dropped = live[rid][keep:]
            # rollback may reach INTO a shared (refcount > 1) block — the
            # truncate must only drop this holder's reference, never the
            # donor's; a registered dropped block must stay matchable
            shared = [b for b in dropped if a.refcount(b) > 1]
            live[rid] = a.truncate(live[rid], keep)
            assert len(live[rid]) == keep
            for b in shared:
                assert a.refcount(b) >= 1, (
                    f"truncate killed shared block {b} out from under a holder"
                )
        elif op == 7 and live:  # windowed release: free the OLDEST held block
            # (scheduler._release_windowed frees leading blocks once they slide
            # out of the attention window; the allocator sees a plain decref
            # of a block that is not the tail — order must not matter)
            rid = sorted(live)[x % len(live)]
            if live[rid]:
                a.free([live[rid].pop(0)])
        held = [b for ids in live.values() for b in ids]
        for b in range(n_blocks):
            assert a.refcount(b) == held.count(b), (
                f"block {b}: refcount {a.refcount(b)} != {held.count(b)} holders"
            )
        assert a.n_free + len(set(held)) == n_blocks
    # drain: every reference returned -> the whole pool is allocatable again
    for rid in sorted(live):
        a.free(list(reversed(live[rid])))
    assert a.n_free == n_blocks


def test_allocator_fuzz_seeded_sweep():
    """Deterministic sweep over many pool sizes and op mixes (no optional
    deps): the CI-everywhere arm of the fuzz."""
    for seed in range(25):
        rng = np.random.RandomState(seed)
        n_blocks = int(rng.randint(2, 13))
        ops = [(int(rng.randint(0, 8)), int(rng.randint(0, 256)))
               for _ in range(120)]
        _run_ops(n_blocks, ops)


def test_allocator_fuzz_hypothesis():
    """Property form over arbitrary op lists (shrinks on failure)."""
    hypothesis = pytest.importorskip("hypothesis")  # property tests need it
    from hypothesis import given, settings
    from hypothesis import strategies as st

    @settings(max_examples=150, deadline=None)
    @given(st.integers(2, 12),
           st.lists(st.tuples(st.integers(0, 7), st.integers(0, 255)),
                    max_size=100))
    def prop(n_blocks, ops):
        _run_ops(n_blocks, ops)

    prop()
