"""Quantization-numerics observability tests (core/numerics + the `quality`
telemetry level): probe reductions vs numpy oracles (bit-equal histograms),
pathological-codebook health gauges, the off/metrics jaxpr + dispatch identity
guard, quality-vs-off greedy-token identity under prefix sharing +
speculation, calibration-drift alarms on a shifted distribution, the
self-referencing shadow probe (agreement == 1.0), artifact calib-stats
round-trip, and the Prometheus expfmt / Perfetto counter-track exports."""

import json

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs.base import get_smoke_config
from repro.core import numerics as nx
from repro.core.artifact import load_calib_stats, save_quantized
from repro.core.qlinear import QLinearConfig, qlinear_apply, quantize_linear
from repro.core.quantize import (
    dequantize_activation,
    quantize_activation,
    token_scale,
)
from repro.core.quantspec import QuantSpec
from repro.models.model import build, quantize_model
from repro.serving.engine import ServeConfig, ServingEngine
from repro.serving.speculative import SpeculativeConfig, make_packed_fn
from repro.serving.telemetry import Telemetry, TelemetryConfig, make_telemetry

QSPEC = QuantSpec(base=QLinearConfig(detection="none"))


@pytest.fixture(scope="module")
def small_lm():
    cfg = get_smoke_config("llama3_2_1b")
    model = build(cfg)
    params = model.init(jax.random.PRNGKey(0))
    return cfg, model, params, quantize_model(model, params, QSPEC)


def _mk_engine(model, qp, level, **kw):
    eng_kw = {k: kw.pop(k) for k in ("calib_stats", "shadow_params", "draft")
              if k in kw}
    kw.setdefault("cache_len", 32)
    kw.setdefault("block_size", 8)
    kw.setdefault("prefill_chunk", 4)
    return ServingEngine(model, qp,
                         ServeConfig(cache_dtype="float32", telemetry=level,
                                     **kw),
                         batch_slots=2, **eng_kw)


def _qtel(sample_every=1, shadow_every=2, **kw):
    return TelemetryConfig(level="quality", quality_sample_every=sample_every,
                           quality_shadow_every=shadow_every, **kw)


def _qlp(detection="none", a_bits=4, w_bits=4, frac=0.0, seed=0,
         k_in=32, n_out=24):
    rng = np.random.RandomState(seed)
    w = jnp.asarray(rng.randn(k_in, n_out).astype(np.float32))
    calib = jnp.asarray(rng.randn(128, k_in).astype(np.float32))
    cfg = QLinearConfig(w_bits=w_bits, a_bits=a_bits, detection=detection,
                        outlier_frac=frac)
    return quantize_linear(w, calib, cfg), cfg


# ---------------------------------------------------------------------------
# collector mechanics
# ---------------------------------------------------------------------------

def test_collector_inactive_by_default():
    assert not nx.collecting()
    p, _ = _qlp()
    x = jnp.ones((2, 32), jnp.float32)
    qlinear_apply(p, x)  # no collector: the hook must be a pure no-op
    assert not nx.collecting()
    with nx.collect() as col:
        assert nx.collecting()
    assert not nx.collecting() and isinstance(col.out, dict)


def test_collector_site_naming_and_announce():
    col = nx.ProbeCollector()
    col.announce("attn.q")
    col.emit({"a": 1.0})
    col.emit({"b": 2.0})  # un-announced: falls back to a numbered site
    col.announce("attn.q")  # same tap again -> new forward-order prefix
    col.emit({"a": 3.0})
    assert set(col.out) == {"000.attn.q/a", "001.proj/b", "002.attn.q/a"}
    assert nx.site_tap("000.attn.q") == "attn.q"
    assert nx.site_tap("017.mlp.wi") == "mlp.wi"
    assert nx.site_tap("noprefix") == "noprefix"


def test_probe_flag_mutes_sites():
    p, _ = _qlp()
    import dataclasses

    muted = dataclasses.replace(p, cfg=dataclasses.replace(p.cfg, probe=False))
    x = jnp.ones((2, 32), jnp.float32)
    with nx.collect() as col:
        qlinear_apply(muted, x)
    assert col.out == {}
    with nx.collect() as col:
        qlinear_apply(p, x)
    assert col.out  # probe=True (default) emits


# ---------------------------------------------------------------------------
# probe reductions vs numpy oracles
# ---------------------------------------------------------------------------

def test_probe_values_match_numpy_oracle():
    p, cfg = _qlp(detection="dynamic", frac=0.1, seed=3)
    rng = np.random.RandomState(1)
    xs = rng.randn(5, 32).astype(np.float32)
    x = jnp.asarray(xs)
    with nx.collect() as col:
        qlinear_apply(p, x)
    out = {k: np.asarray(jax.device_get(v)) for k, v in col.out.items()}
    (site,) = {k.rpartition("/")[0] for k in out}

    # activation index histogram: bit-equal to np.bincount
    qa = quantize_activation(x, p.act_codebook, cfg.scale_mode)
    idx = np.asarray(jax.device_get(qa.idx)).astype(np.int64)
    n = int(p.act_codebook.shape[0])
    hist = np.bincount(idx.reshape(-1), minlength=n).astype(np.float32)
    np.testing.assert_array_equal(out[f"{site}/a_hist"], hist)
    assert out[f"{site}/a_util"] == pytest.approx((hist > 0).mean())
    assert out[f"{site}/a_dead"] == (hist == 0).sum()
    pr = hist / hist.sum()
    pr = pr[pr > 0]
    assert out[f"{site}/a_entropy"] == pytest.approx(
        -(pr * np.log(pr)).sum() / np.log(n), rel=1e-5)

    # weight index histogram: bit-equal
    widx = np.asarray(jax.device_get(p.qw.indices)).astype(np.int64)
    wn = int(p.qw.codebook.shape[0])
    whist = np.bincount(widx.reshape(-1), minlength=wn).astype(np.float32)
    np.testing.assert_array_equal(out[f"{site}/w_hist"], whist)
    assert out[f"{site}/w_dead"] == (whist == 0).sum()

    # SQNR of the main branch
    deq = np.asarray(jax.device_get(dequantize_activation(qa)))
    sq = 10.0 * np.log10(np.square(xs).sum() / np.square(xs - deq).sum())
    assert out[f"{site}/sqnr_db"] == pytest.approx(sq, rel=1e-4)

    # saturation vs the codebook range
    s = np.asarray(jax.device_get(token_scale(x, cfg.scale_mode)))
    xn = xs / s
    book = np.asarray(jax.device_get(p.act_codebook))
    assert out[f"{site}/a_sat"] == pytest.approx(
        ((xn < book[0]) | (xn > book[-1])).mean(), abs=1e-6)

    # live activation moments (the drift inputs)
    am = np.abs(xs).max(-1)
    assert out[f"{site}/act_mean"] == pytest.approx(xs.mean(), abs=1e-6)
    assert out[f"{site}/act_rms"] == pytest.approx(
        np.sqrt(np.square(xs).mean()), rel=1e-5)
    assert out[f"{site}/act_absmax_mean"] == pytest.approx(am.mean(), rel=1e-5)
    assert out[f"{site}/act_absmax_max"] == pytest.approx(am.max(), rel=1e-6)
    assert out[f"{site}/act_tokens"] == 5.0

    # Orizuru effectiveness: energy fraction in [0,1]; the jnp dynamic route
    # IS exact lax.top_k, so overlap with the exact detector must be 1.0
    assert 0.0 < out[f"{site}/out_energy"] <= 1.0
    assert out[f"{site}/out_overlap"] == pytest.approx(1.0)


def test_probe_oracle_under_jit_matches_eager():
    p, _ = _qlp(seed=5)
    x = jnp.asarray(np.random.RandomState(2).randn(3, 32).astype(np.float32))

    def probed(x):
        with nx.collect() as col:
            qlinear_apply(p, x)
        return col.out

    eager = {k: np.asarray(v) for k, v in probed(x).items()}
    jitted = {k: np.asarray(v) for k, v in jax.jit(probed)(x).items()}
    assert set(eager) == set(jitted)
    for k in eager:
        np.testing.assert_allclose(jitted[k], eager[k], rtol=1e-5, atol=1e-6)


def test_probe_mask_drops_padded_tokens():
    p, cfg = _qlp(seed=7)
    rng = np.random.RandomState(4)
    x_valid = rng.randn(3, 32).astype(np.float32)
    x_pad = np.concatenate([x_valid, 1e3 * rng.randn(2, 32).astype(np.float32)])
    mask = jnp.asarray([1.0, 1.0, 1.0, 0.0, 0.0])
    with nx.collect(mask=mask) as col:
        qlinear_apply(p, jnp.asarray(x_pad))
    masked = {k.rpartition("/")[-1]: np.asarray(v) for k, v in col.out.items()}
    with nx.collect() as col2:
        qlinear_apply(p, jnp.asarray(x_valid))
    clean = {k.rpartition("/")[-1]: np.asarray(v) for k, v in col2.out.items()}
    # every activation stat must equal the run that never saw the pad tokens
    for stat in ("a_hist", "a_util", "a_dead", "a_entropy", "a_sat", "sqnr_db",
                 "act_mean", "act_rms", "act_absmax_mean", "act_absmax_max",
                 "act_tokens"):
        np.testing.assert_allclose(masked[stat], clean[stat], rtol=1e-5,
                                   atol=1e-6, err_msg=stat)


def test_dead_centroids_and_saturation_on_pathological_codebook():
    # a codebook whose extreme centroids sit far outside the data: the far
    # bins never win an assignment (dead), and a tight codebook saturates
    idx = jnp.asarray([[0, 1, 1, 0], [1, 0, 0, 1]])
    st = {k: np.asarray(v) for k, v in nx.index_stats(idx, 8).items()}
    assert st["dead"] == 6 and st["util"] == pytest.approx(2 / 8)
    np.testing.assert_array_equal(st["hist"],
                                  np.array([4, 4, 0, 0, 0, 0, 0, 0], np.float32))
    assert st["entropy"] == pytest.approx(np.log(2) / np.log(8))

    xs = np.random.RandomState(0).randn(16, 32).astype(np.float32)
    x = jnp.asarray(xs)
    wide = jnp.asarray(np.linspace(-8.0, 8.0, 16), jnp.float32)
    tight = jnp.asarray(np.linspace(-0.2, 0.2, 16), jnp.float32)
    assert float(nx.saturation_rate(x, wide, "rms")) == 0.0
    sat = float(nx.saturation_rate(x, tight, "rms"))
    xn = xs / np.sqrt(np.square(xs).mean(-1, keepdims=True))
    assert sat == pytest.approx((np.abs(xn) > 0.2).mean(), abs=1e-6)
    assert sat > 0.5  # most of a unit-RMS gaussian sits outside +-0.2


# ---------------------------------------------------------------------------
# drift scoring + alarms
# ---------------------------------------------------------------------------

def test_activation_stats_and_drift_score():
    acts = np.random.RandomState(0).randn(256, 32).astype(np.float32)
    st = nx.activation_stats(acts)
    assert st["tokens"] == 256 and st["dim"] == 32
    assert st["rms"] == pytest.approx(1.0, abs=0.05)
    assert nx.drift_score(st, st) == 0.0
    shifted = {**st, "rms": st["rms"] * 5.0, "absmax_mean": st["absmax_mean"] * 5.0}
    assert nx.drift_score(shifted, st) > 3.0  # 5x scale = 4 rms units of drift
    assert nx.drift_score(st, shifted) > 0.5  # and it is not symmetric-blind


def _fake_probes(rms, site="000.attn.q"):
    return {f"{site}/act_mean": 0.0, f"{site}/act_rms": rms,
            f"{site}/act_absmax_mean": 3.0 * rms,
            f"{site}/act_absmax_max": 5.0 * rms, f"{site}/act_tokens": 8.0,
            f"{site}/sqnr_db": 20.0, f"{site}/a_util": 1.0,
            f"{site}/a_hist": np.ones(16, np.float32)}


def test_quality_monitor_alarms_on_shifted_distribution():
    calib = {"attn.q": {"mean": 0.0, "rms": 1.0, "absmax_mean": 3.0,
                        "absmax_max": 5.0}}
    tel = make_telemetry(_qtel())
    mon = nx.QualityMonitor(tel, calib_stats=calib, drift_threshold=0.5)
    sites = mon.ingest(_fake_probes(rms=1.0))  # matches calibration
    assert sites["000.attn.q"]["drift"] == pytest.approx(0.0)
    assert tel.counter("numerics_drift_alarms").value == 0
    sites = mon.ingest(_fake_probes(rms=5.0))  # 5x live scale: alarm
    assert sites["000.attn.q"]["drift"] > 3.0
    assert tel.counter("numerics_drift_alarms").value == 1
    snap = tel.snapshot()
    assert snap["gauges"]["numerics_drift.000.attn.q"] > 3.0
    assert snap["gauges"]["numerics_drift_max"] > 3.0
    assert snap["gauges"]["numerics_a_codebook_util.000.attn.q"] == 1.0
    assert snap["counters"]["numerics_probe_steps"] == 2


def test_quality_monitor_self_baseline_without_calib():
    tel = make_telemetry(_qtel())
    mon = nx.QualityMonitor(tel, calib_stats=None, drift_threshold=0.5)
    sites = mon.ingest(_fake_probes(rms=2.0))  # first step seeds the baseline
    assert sites["000.attn.q"]["drift"] == 0.0
    assert tel.counter("numerics_drift_alarms").value == 0
    mon.ingest(_fake_probes(rms=2.1))  # mild wobble: no alarm
    assert tel.counter("numerics_drift_alarms").value == 0
    sites = mon.ingest(_fake_probes(rms=10.0))  # 5x the seeded baseline
    assert sites["000.attn.q"]["drift"] > 3.0
    assert tel.counter("numerics_drift_alarms").value == 1


def test_calib_stats_artifact_round_trip(small_lm, tmp_path):
    cfg, model, params, qp = small_lm
    acts = np.random.RandomState(1).randn(64, cfg.d_model).astype(np.float32)
    d = tmp_path / "art"
    save_quantized(d, cfg, QSPEC, qp,
                   calib_stats={"attn.q": acts,  # raw: summarized at save
                                "mlp.wi": nx.activation_stats(acts)})
    stats = load_calib_stats(d)
    assert set(stats) == {"attn.q", "mlp.wi"}
    assert stats["attn.q"] == pytest.approx(nx.activation_stats(acts))
    assert json.loads((d / "manifest.json").read_text())["calib_stats"]
    # artifacts saved without stats read back None (every pre-quality save)
    d2 = tmp_path / "plain"
    save_quantized(d2, cfg, QSPEC, qp)
    assert load_calib_stats(d2) is None


# ---------------------------------------------------------------------------
# serving integration: identity guards
# ---------------------------------------------------------------------------

def test_off_metrics_trace_jaxpr_and_dispatch_identity(small_lm):
    """The tentpole guard: levels below `quality` trace the packed step with
    NO collector installed, so the jaxpr — and the dispatch count — are
    identical to a probe-free build."""
    cfg, model, params, qp = small_lm
    prompts = [[1, 2, 3, 4, 5], [6, 7], [8, 9, 10]]

    def run(level):
        eng = _mk_engine(model, qp, level)
        sched = eng.scheduler
        inner, calls = sched._packed_fn, []
        sched._packed_fn = lambda *a: (calls.append(a), inner(*a))[1]
        out = eng.generate(prompts, max_new_tokens=4)
        return out, calls

    outs, calls = zip(*(run(level) for level in ("off", "metrics", "trace")))
    assert outs[0] == outs[1] == outs[2]
    assert len(calls[0]) == len(calls[1]) == len(calls[2]) > 0
    fn = make_packed_fn(model)
    jx = [str(jax.make_jaxpr(fn)(*c[0])) for c in calls]
    assert jx[0] == jx[1] == jx[2]


def test_quality_tokens_identical_with_prefix_sharing_and_speculation(small_lm):
    """Acceptance criterion: at `quality` — probing EVERY step, shadow every
    other step, prefix sharing AND speculation on — greedy tokens are
    identical to telemetry=off. Observation never perturbs serving."""
    cfg, model, params, qp = small_lm
    system = [3, 1, 4, 1, 5, 9, 2, 6]  # one full block at block_size=8
    prompts = [system + [40 + i] for i in range(3)]

    def run(level):
        eng = _mk_engine(model, qp, level, prefix_cache=True,
                         speculative=SpeculativeConfig(k=2),
                         draft=(model, params))
        return eng, eng.generate(prompts, max_new_tokens=5)

    eng_off, out_off = run("off")
    eng_q, out_q = run(_qtel(sample_every=1, shadow_every=2))
    assert out_q == out_off
    assert eng_q.stats["accepted_tokens"] > 0, "speculation was not exercised"
    snap = eng_q.snapshot()
    assert snap["counters"]["numerics_probe_steps"] > 0
    g = snap["gauges"]
    assert any(k.startswith("numerics_a_codebook_util.") for k in g)
    assert any(k.startswith("numerics_sqnr_db.") for k in g)
    assert any(k.startswith("numerics_drift.") for k in g)
    # acceptance attribution histogram exists (observes only on rejections)
    assert "numerics_spec_first_reject_pos" in snap["histograms"]


def test_quality_probed_step_matches_packed_logits(small_lm):
    """The probed packed step serves bit-identical logits and pools to the
    scanned packed step: its authoritative outputs COME from that exact
    step, with the probe-only (scan-unrolled) forward's outputs discarded.
    The unrolled forward fuses differently under XLA (last-ulp logit
    diffs), which is why probes must not replace the serving outputs."""
    from repro.serving.speculative import make_probed_packed_fn

    cfg, model, params, qp = small_lm
    eng = _mk_engine(model, qp, "off")
    sched = eng.scheduler
    calls = []
    inner = sched._packed_fn
    sched._packed_fn = lambda *a: (calls.append(a), inner(*a))[1]
    eng.generate([[1, 2, 3, 4], [5, 6]], max_new_tokens=3)
    probed = make_probed_packed_fn(model)
    plain = make_packed_fn(model)
    for args in calls[:3]:
        pools_p, logits_p, extras_p, probes = probed(*args)
        pools, logits, extras = plain(*args)
        np.testing.assert_array_equal(np.asarray(logits_p), np.asarray(logits))
        jax.tree.map(lambda a, b: np.testing.assert_array_equal(
            np.asarray(a), np.asarray(b)), pools_p, pools)
        assert probes and all(k.count("/") >= 1 for k in probes)


def test_shadow_probe_self_reference_agreement(small_lm):
    """Self-referencing shadow spec (shadow_params=None -> serving params):
    the reference forward replays the very distribution being served, so
    greedy token agreement must be exactly 1.0 and the logit KL ~ 0."""
    cfg, model, params, qp = small_lm
    eng = _mk_engine(model, qp, _qtel(sample_every=1, shadow_every=1))
    eng.generate([[1, 2, 3, 4, 5]], max_new_tokens=8)
    snap = eng.snapshot()
    c = snap["counters"]
    assert c["numerics_shadow_probes"] >= 1
    kl = snap["histograms"]["numerics_shadow_logit_kl"]
    assert kl["count"] >= 1
    g = snap["gauges"]
    assert g["numerics_shadow_token_agreement"] == 1.0
    assert g["numerics_shadow_top1_agreement"] == 1.0
    assert kl["max"] < 1e-3  # same params, same context: KL is numerics noise


# ---------------------------------------------------------------------------
# telemetry plumbing: level, exports
# ---------------------------------------------------------------------------

def test_quality_level_config_and_parse():
    assert TelemetryConfig.parse("quality").level == "quality"
    t = make_telemetry("quality")
    assert isinstance(t, Telemetry) and t.quality and t.tracing
    assert not make_telemetry("trace").quality
    with pytest.raises(ValueError):
        TelemetryConfig(level="quality", quality_sample_every=0)
    with pytest.raises(ValueError):
        TelemetryConfig(level="quality", quality_drift_threshold=0.0)


def test_quality_counter_series_and_perfetto_counter_track(tmp_path):
    tel = make_telemetry(_qtel())
    tel.step_record(host_s=0.01, device_s=0.02, cells=2, budget=4)
    tel.quality_counter("numerics_drift_max", 0.25)
    tel.quality_counter("numerics_drift_max", 0.75)
    assert [v for _, _, v in tel.quality_series] == [0.25, 0.75]
    p = tel.export_chrome_trace(tmp_path / "t.json")
    ev = json.loads(p.read_text())["traceEvents"]
    counters = [e for e in ev if e.get("ph") == "C"]
    assert len(counters) == 2 and counters[0]["pid"] == 2
    assert counters[0]["args"]["value"] == 0.25
    assert any(e.get("ph") == "M" and e.get("pid") == 2 for e in ev)
    tel.reset()
    assert len(tel.quality_series) == 0


def test_expfmt_prometheus_text():
    tel = make_telemetry("metrics")
    tel.counter("serving_packed_steps").add(3)
    tel.gauge("numerics_drift.000.attn.q").set(0.5)
    tel.histogram("lat", [1.0, 2.0]).observe(1.5)
    text = tel.expfmt()
    assert "# TYPE serving_packed_steps counter" in text
    assert "serving_packed_steps 3" in text
    # metric names are sanitized to the Prometheus charset
    assert "numerics_drift_000_attn_q 0.5" in text
    assert 'lat_bucket{le="2"} 1' in text and 'lat_bucket{le="+Inf"} 1' in text
    assert "lat_count 1" in text and "lat_sum 1.5" in text
    from repro.serving.telemetry import NULL_TELEMETRY

    assert NULL_TELEMETRY.expfmt() == ""
    assert NULL_TELEMETRY.quality is False
