"""Distribution machinery tests on a small host-platform mesh.

The main pytest session must keep seeing ONE device (smoke tests, benches),
so anything needing multiple devices runs in a subprocess that sets
XLA_FLAGS=--xla_force_host_platform_device_count before importing jax —
the same pattern as the production dry-run, scaled down to a (2, 4) mesh.
"""

import subprocess
import sys
import textwrap

import pytest

_SCRIPT = textwrap.dedent(
    """
    import os
    os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
    import dataclasses
    import jax, jax.numpy as jnp
    from jax.sharding import NamedSharding, PartitionSpec as P

    from repro.configs.base import get_smoke_config
    from repro.distributed.param_sharding import build_param_specs, spec_tree_to_shardings
    from repro.distributed.sharding import use_rules
    from repro.models.model import build
    from repro.optim.adamw import AdamWConfig
    from repro.train.trainer import TrainConfig, init_train_state, make_train_step

    assert jax.device_count() == 8
    mesh = jax.make_mesh((2, 4), ("data", "model"))

    # rules sized for the small mesh (model axis = 4)
    rules = {
        "batch": "data", "seq": None, "seq_sp": None, "d_model": None,
        "heads_flat": "model", "kv_heads": None, "d_ff": "model",
        "vocab": "model", "experts": None, "dispatch_groups": "data",
        "d_inner": "model", "state": None,
    }

    for arch in ("llama3_2_1b", "granite_moe_3b_a800m", "falcon_mamba_7b"):
        cfg = dataclasses.replace(get_smoke_config(arch), moe_dispatch_groups=2)
        model = build(cfg)
        tc = TrainConfig(optimizer=AdamWConfig(lr=1e-3), microbatches=2)
        state = init_train_state(model, jax.random.PRNGKey(0), tc)
        specs = build_param_specs(jax.eval_shape(lambda: state["params"]), model_size=4)
        shardings = {
            "params": spec_tree_to_shardings(specs, mesh),
            "opt": {
                "m": spec_tree_to_shardings(build_param_specs(
                    jax.eval_shape(lambda: state["opt"]["m"]), 4), mesh),
                "v": spec_tree_to_shardings(build_param_specs(
                    jax.eval_shape(lambda: state["opt"]["v"]), 4), mesh),
                "step": NamedSharding(mesh, P()),
            },
        }
        batch = {"tokens": jax.random.randint(jax.random.PRNGKey(1), (4, 17), 0, cfg.vocab_size)}
        bspec = {"tokens": NamedSharding(mesh, P("data", None))}
        with mesh:
            with use_rules(rules):
                step = jax.jit(make_train_step(model, tc),
                               in_shardings=(shardings, bspec))
                state_s = jax.device_put(state, shardings)
                batch_s = jax.device_put(batch, bspec)
                new_state, metrics = step(state_s, batch_s)
        loss = float(metrics["loss"])
        assert loss == loss and loss > 0, (arch, loss)  # finite
        # sharded result must equal the single-device result numerically
        step1 = jax.jit(make_train_step(model, tc))
        _, metrics1 = step1(state, batch)
        assert abs(loss - float(metrics1["loss"])) < 1e-3, (arch, loss, float(metrics1["loss"]))
        print(f"{arch}: sharded loss {loss:.4f} == unsharded {float(metrics1['loss']):.4f}")
    print("DISTRIBUTION_OK")
    """
)


@pytest.mark.slow
def test_sharded_train_step_matches_unsharded():
    """Full train_step on a (2,4) mesh: compiles, runs, matches 1-device loss."""
    out = subprocess.run(
        [sys.executable, "-c", _SCRIPT],
        capture_output=True, text=True, timeout=900,
        env={"PYTHONPATH": "src", "PATH": "/usr/bin:/bin"},
    )
    assert "DISTRIBUTION_OK" in out.stdout, out.stdout[-2000:] + out.stderr[-3000:]
