"""QuantSpec API tests: per-layer rule resolution (later rules win, skip
leaves a layer dense), config-in-params apply behaviour (comp_auto_tokens
cutover), the quantized-model artifact layer (bit-exact save/load round trip,
calibration-free load path), and mixed-precision serving end-to-end.
``Model.quantize`` was a DeprecationWarning shim for one release; it is gone —
``quantize_model(model, params, spec)`` is the only entry point."""

import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs.base import get_smoke_config
from repro.core.artifact import load_quantized, save_quantized
from repro.core.qlinear import QLinearConfig, QLinearParams, qlinear_apply, quantize_linear
from repro.core.quantspec import QuantRule, QuantSpec
from repro.models.model import build, quantize_model
from repro.serving.engine import ServeConfig, ServingEngine


@pytest.fixture(scope="module")
def small_lm():
    cfg = get_smoke_config("llama3_2_1b")
    model = build(cfg)
    params = model.init(jax.random.PRNGKey(0))
    return cfg, model, params


MIXED = QuantSpec(
    base=QLinearConfig(detection="none"),
    rules=[("mlp/wd", {"w_bits": 8})],  # W8 down-proj, W4 elsewhere
    kv_dtype="float32",
)


# ---------------------------------------------------------------------------
# rule resolution
# ---------------------------------------------------------------------------

def test_rule_precedence_later_wins():
    spec = QuantSpec(
        base=QLinearConfig(w_bits=4, detection="none"),
        rules=[
            ("attn/*", {"w_bits": 3}),
            ("attn/wq", {"w_bits": 8, "outlier_frac": 0.02}),
            ("mlp/*", "skip"),
            # un-skips wi, wd stays dense (A3 needs detection != "none")
            ("mlp/wi", {"a_bits": 3, "detection": "dynamic"}),
        ],
    )
    assert spec.resolve("blocks/attn/wq").w_bits == 8
    assert spec.resolve("blocks/attn/wq").outlier_frac == 0.02
    assert spec.resolve("blocks/attn/wk").w_bits == 3
    assert spec.resolve("blocks/mlp/wd") is None  # skip
    assert spec.resolve("blocks/mlp/wi").a_bits == 3  # later rule un-skips
    assert spec.resolve("blocks/mlp/wi").w_bits == 4  # base preserved


def test_rule_suffix_matching_and_layer_index():
    spec = QuantSpec(rules=[("blocks/0/*", "skip"), ("wd", {"w_bits": 8})])
    assert spec.resolve("blocks/0/attn/wq") is None  # per-index rule (unscanned)
    assert spec.resolve("blocks/1/attn/wq") is not None
    assert spec.resolve("blocks/1/mlp/wd").w_bits == 8  # bare-leaf suffix match


def test_rule_rejects_unknown_field_and_bad_body():
    with pytest.raises(ValueError, match="unknown QLinearConfig field"):
        QuantSpec(rules=[("attn/*", {"bits": 4})])
    with pytest.raises(ValueError, match="skip"):
        QuantSpec(rules=[("attn/*", "dense")])
    with pytest.raises(ValueError, match="kv_bits"):
        QuantSpec(kv_bits=8)


def test_spec_json_roundtrip():
    spec = QuantSpec(
        base=QLinearConfig(w_bits=4, a_bits=3, detection="static",
                           compute_dtype=jnp.float32),
        rules=[("mlp/wd", {"w_bits": 8, "compute_dtype": jnp.float32}),
               ("attn/wo", "skip")],
        kv_bits=4, kv_dtype="float32",
    )
    back = QuantSpec.from_json_dict(spec.to_json_dict())
    assert back.base == dataclasses.replace(spec.base,
                                            compute_dtype=jnp.dtype("float32"))
    assert back.kv_bits == 4 and back.kv_dtype == "float32"
    assert [r.pattern for r in back.rules] == ["mlp/wd", "attn/wo"]
    assert back.rules[1].skip
    assert back.resolve("blocks/mlp/wd").w_bits == 8


def test_quantize_model_applies_rules(small_lm):
    cfg, model, params = small_lm
    spec = QuantSpec(base=QLinearConfig(detection="none"),
                     rules=[("mlp/wd", {"w_bits": 8}), ("attn/wo", "skip")])
    qp = quantize_model(model, params, spec)
    blk = qp["blocks"]
    assert isinstance(blk["attn"]["wo"], dict), "skip must leave the layer dense"
    assert isinstance(blk["mlp"]["wd"], QLinearParams)
    assert blk["mlp"]["wd"].qw.nbits == 8
    assert blk["mlp"]["wd"].cfg.w_bits == 8  # resolved cfg travels with params
    assert blk["attn"]["wq"].qw.nbits == 4
    # head / embed never quantized regardless of spec
    assert isinstance(qp["embed"], dict)


# ---------------------------------------------------------------------------
# W8 weight tier (byte packing)
# ---------------------------------------------------------------------------

def test_w8_weights_pack_bytewise_and_beat_w4():
    from repro.core.quantize import dequantize_weight, quantize_weight

    w = jax.random.normal(jax.random.PRNGKey(1), (64, 32))
    q4, q8 = quantize_weight(w, nbits=4), quantize_weight(w, nbits=8)
    assert q4.packed.shape == (64, 16) and q8.packed.shape == (64, 32)
    assert q8.codebook.shape == (256,)
    e4 = float(jnp.linalg.norm(dequantize_weight(q4) - w))
    e8 = float(jnp.linalg.norm(dequantize_weight(q8) - w))
    assert e8 < e4 / 4, (e4, e8)
    assert q8.hbm_bytes() > q4.hbm_bytes()  # honest byte accounting


# ---------------------------------------------------------------------------
# comp_mode="auto" cutover (satellite: configurable gather/scatter boundary)
# ---------------------------------------------------------------------------

def test_comp_auto_tokens_cutover_both_sides(monkeypatch):
    import repro.core.outlier as ol

    calls = []
    real_g, real_s = ol.compensate_gather, ol.compensate_scatter
    monkeypatch.setattr(ol, "compensate_gather",
                        lambda *a, **k: calls.append("gather") or real_g(*a, **k))
    monkeypatch.setattr(ol, "compensate_scatter",
                        lambda *a, **k: calls.append("scatter") or real_s(*a, **k))

    cfg = QLinearConfig(detection="dynamic", outlier_frac=0.05, comp_auto_tokens=4)
    w = jax.random.normal(jax.random.PRNGKey(0), (32, 16)) * 0.5
    calib = jax.random.normal(jax.random.PRNGKey(1), (8, 32))
    p = quantize_linear(w, calib, cfg)
    assert p.cfg.comp_auto_tokens == 4

    x_at = jax.random.normal(jax.random.PRNGKey(2), (4, 32))  # == boundary
    x_above = jax.random.normal(jax.random.PRNGKey(3), (5, 32))  # boundary + 1
    y_at, y_above = qlinear_apply(p, x_at), qlinear_apply(p, x_above)
    assert calls == ["gather", "scatter"], calls
    # both routes compute the same compensation (numerics-level equivalence)
    np.testing.assert_allclose(
        np.asarray(y_at), np.asarray(qlinear_apply(
            p, x_at, dataclasses.replace(cfg, comp_mode="scatter"))),
        rtol=1e-4, atol=1e-4)
    np.testing.assert_allclose(
        np.asarray(y_above), np.asarray(qlinear_apply(
            p, x_above, dataclasses.replace(cfg, comp_mode="gather"))),
        rtol=1e-4, atol=1e-4)


# ---------------------------------------------------------------------------
# artifact round trip
# ---------------------------------------------------------------------------

def _packed_leaves(tree, out=None):
    out = [] if out is None else out
    if isinstance(tree, dict):
        for v in tree.values():
            _packed_leaves(v, out)
    elif isinstance(tree, list):
        for v in tree:
            _packed_leaves(v, out)
    elif isinstance(tree, QLinearParams):
        out.append(np.asarray(tree.qw.packed))
    return out


def test_artifact_roundtrip_bitexact(small_lm, tmp_path):
    cfg, model, params = small_lm
    qp = quantize_model(model, params, MIXED)
    save_quantized(tmp_path / "art", cfg, MIXED, qp)
    art = load_quantized(tmp_path / "art")

    assert art.model.cfg == cfg
    assert art.spec == MIXED
    # identical packed bytes...
    a, b = _packed_leaves(qp), _packed_leaves(art.params)
    assert len(a) == len(b) > 0
    for x, y in zip(a, b):
        assert x.dtype == y.dtype and x.tobytes() == y.tobytes()
    # ...and identical logits
    batch = {"tokens": jnp.arange(6, dtype=jnp.int32)[None] % cfg.vocab_size}
    la = model.apply(qp, batch).logits
    lb = art.model.apply(art.params, batch).logits
    np.testing.assert_array_equal(np.asarray(la), np.asarray(lb))


def test_artifact_detects_corruption_and_partial_saves(small_lm, tmp_path):
    cfg, model, params = small_lm
    qp = quantize_model(model, params, MIXED)
    d = save_quantized(tmp_path / "art", cfg, MIXED, qp)
    with pytest.raises(FileNotFoundError, match="manifest"):
        load_quantized(tmp_path / "nowhere")
    # flip one tensor byte -> sha mismatch
    import json

    mf = json.loads((d / "manifest.json").read_text())
    name = next(k for k in mf["tensors"] if k.endswith("qw.packed"))
    mf["tensors"][name]["sha256"] = "0" * 16
    (d / "manifest.json").write_text(json.dumps(mf))
    with pytest.raises(IOError, match="corruption"):
        load_quantized(d)
    assert load_quantized(d, verify=False) is not None  # escape hatch


def test_load_path_runs_no_calibration_and_serves_identically(
        small_lm, tmp_path, monkeypatch):
    """Acceptance: a saved W4A4(+W8) model reloaded in a 'fresh process'
    produces token-identical greedy output through ServingEngine.generate vs
    the in-process quantized model, with quantization/calibration entry
    points poisoned during load + serve."""
    cfg, model, params = small_lm
    qp = quantize_model(model, params, MIXED)
    prompts = [[1, 2, 3, 4, 5], [7, 8], [9]]
    mk = lambda m, p: ServingEngine(
        m, p, ServeConfig.from_spec(MIXED, cache_len=32, block_size=4,
                                    prefill_chunk=4), batch_slots=2)
    want = mk(model, qp).generate(prompts, max_new_tokens=6)

    save_quantized(tmp_path / "art", cfg, MIXED, qp)

    def boom(*a, **k):
        raise AssertionError("calibration/quantization code ran on the load path")

    import repro.core.codebook as codebook
    import repro.models.model as mm
    monkeypatch.setattr(codebook, "kmeans_fit", boom)
    monkeypatch.setattr(mm, "quantize_weight", boom)
    monkeypatch.setattr(mm, "fit_activation_codebook", boom)
    monkeypatch.setattr(mm, "quantize_params", boom)

    art = load_quantized(tmp_path / "art")
    got = mk(art.model, art.params).generate(prompts, max_new_tokens=6)
    assert got == want


# ---------------------------------------------------------------------------
# mixed-precision serving end-to-end
# ---------------------------------------------------------------------------

def test_mixed_precision_serving_paged_matches_ring(small_lm):
    """W8 down-proj + W4 elsewhere through ServingEngine.generate: the paged
    continuous-batching path and the ring fallback agree token-for-token."""
    cfg, model, params = small_lm
    qp = quantize_model(model, params, MIXED)
    assert qp["blocks"]["mlp"]["wd"].qw.nbits == 8
    assert qp["blocks"]["attn"]["wq"].qw.nbits == 4
    prompts = [[1, 2, 3], [4, 5, 6, 7], [8]]
    paged = ServingEngine(model, qp,
                          ServeConfig.from_spec(MIXED, cache_len=32, block_size=4,
                                                prefill_chunk=4), batch_slots=2)
    ring = ServingEngine(model, qp,
                         ServeConfig.from_spec(MIXED, cache_len=32, paged=False),
                         batch_slots=1)
    want = [ring.generate([p], max_new_tokens=5)[0] for p in prompts]
    assert paged.generate(prompts, max_new_tokens=5) == want


def test_serve_config_from_spec_kv_policy():
    sc = ServeConfig.from_spec(QuantSpec(kv_bits=4, kv_dtype="float32"), cache_len=64)
    assert sc.kv_quant and sc.cache_dtype == "float32" and sc.cache_len == 64
    sc2 = ServeConfig.from_spec(QuantSpec(), kv_quant=True)  # explicit kw wins
    assert sc2.kv_quant and sc2.cache_dtype == "bfloat16"


# ---------------------------------------------------------------------------
# deprecation shim retirement
# ---------------------------------------------------------------------------

def test_model_quantize_shim_is_retired(small_lm):
    """The ``Model.quantize`` DeprecationWarning shim shipped for one release
    and is now removed: the attribute must not exist (a leftover shim would
    silently shadow the real entry point), and ``quantize_model`` remains the
    way in."""
    cfg, model, params = small_lm
    assert not hasattr(model, "quantize")
    qp = quantize_model(model, params, QuantSpec(base=QLinearConfig(detection="none")))
    assert jax.tree.leaves(qp)
