"""Per-layer cache policies (ISSUE 9): one packed scheduler serving
paged-KV transformers, windowed-paged SWA stacks, and recurrent-state
(Mamba / RG-LRU) stacks through the SAME jitted step.

Covers the policy descriptors themselves (``cache_policies`` per family,
``release_horizon`` / ``windowed_block_cap`` helpers), greedy token identity
of the paged engine against the ring reference for every new family —
including forced preemption, speculative decoding, both combined, and
K-Means int4 quantized recurrent state — plus the per-policy resource
accounting (recurrent layers pin zero blocks; prefix sharing auto-disables
unless every layer is plain paged-KV; the engine widens ``seg_width`` so a
recurrent + speculative stack fits one verify row).

Ring references are only constructed where the ring fallback is exact:
prompts no longer than the sliding window (one-shot ring prefill clobbers
older keys past capacity) and equal-length prompts for recurrent stacks
(the fixed-slot batcher's left-padding pollutes recurrent state — a
documented fallback caveat, not a paged-path bug).
"""

import dataclasses

import jax
import numpy as np
import pytest

from repro.configs.base import get_smoke_config
from repro.models.model import build
from repro.serving.engine import ServeConfig, ServingEngine
from repro.serving.paged_cache import (CachePolicy, release_horizon,
                                       windowed_block_cap)
from repro.serving.speculative import SpeculativeConfig

FAMILIES = ["h2o_danube_1_8b", "recurrentgemma_2b", "falcon_mamba_7b"]


@pytest.fixture(scope="module")
def lm(request):
    cfg = get_smoke_config(request.param)
    model = build(cfg)
    params = model.init(jax.random.PRNGKey(0))
    return cfg, model, params


def _prompts(cfg, n=3, length=11):
    # <= sliding window and equal length: the regime where the ring
    # fallback is an exact reference (see module docstring)
    rng = np.random.RandomState(0)
    return [list(rng.randint(1, cfg.vocab_size, size=length)) for _ in range(n)]


def _ring(model, params, prompts, new, quantized=False):
    sc = ServeConfig(cache_len=96, cache_dtype="float32",
                     quantized=quantized, paged=False)
    return ServingEngine(model, params, sc,
                         batch_slots=len(prompts)).generate(prompts, new)


# ---------------------------------------------------------------------------
# policy descriptors
# ---------------------------------------------------------------------------

def test_cache_policies_per_family():
    """Each family reports its layer stack; the helpers derive the release
    horizon (0 unless every attention layer is windowed) and the live-block
    cap for a windowed layer."""
    kinds = {
        "oasis_7b": {"paged_kv"},
        "h2o_danube_1_8b": {"windowed_paged"},
        "falcon_mamba_7b": {"recurrent"},
        "recurrentgemma_2b": {"recurrent", "windowed_paged"},
    }
    for name, want in kinds.items():
        cfg = get_smoke_config(name)
        policies = build(cfg).cache_policies()
        assert policies is not None and len(policies) == cfg.n_layers
        assert {p.kind for p in policies} == want

    full = [CachePolicy("paged_kv")]
    swa = [CachePolicy("windowed_paged", window=16)]
    rec = [CachePolicy("recurrent")]
    assert release_horizon(full) == 0
    assert release_horizon(full + swa) == 0  # a full-attn layer pins history
    assert release_horizon(swa + rec) == 16
    assert release_horizon(rec) == 0  # nothing paged: nothing to release
    assert windowed_block_cap(16, 16) == 2  # partial head + partial tail
    assert windowed_block_cap(17, 16) == 3


# ---------------------------------------------------------------------------
# engine identity: paged (per-layer policies) vs ring reference
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("lm", FAMILIES, indirect=True)
def test_paged_matches_ring_greedy(lm):
    cfg, model, params = lm
    prompts = _prompts(cfg)
    ref = _ring(model, params, prompts, 24)
    eng = ServingEngine(
        model, params,
        ServeConfig(cache_len=96, cache_dtype="float32", quantized=False,
                    paged=True),
        batch_slots=2)  # 3 prompts > 2 slots: exercises queueing too
    assert eng.generate(prompts, 24) == ref
    # recurrent layers cost zero blocks; windowed layers stay under the cap
    peak = eng.stats["peak_live_blocks_per_seq"]
    if all(p.kind == "recurrent" for p in model.cache_policies()):
        assert peak == 0
    elif any(p.kind == "windowed_paged" for p in model.cache_policies()):
        assert peak <= windowed_block_cap(cfg.sliding_window, 16)


@pytest.mark.parametrize("lm", ["h2o_danube_1_8b", "recurrentgemma_2b"],
                         indirect=True)
def test_paged_preemption_identity(lm):
    """A pool small enough to force preemption mid-decode: restart replays
    the committed tokens (attention blocks re-prefilled, recurrent state
    rebuilt from scratch) and the output is still token-identical."""
    cfg, model, params = lm
    prompts = _prompts(cfg)
    ref = _ring(model, params, prompts, 24)
    eng = ServingEngine(
        model, params,
        ServeConfig(cache_len=96, cache_dtype="float32", quantized=False,
                    paged=True, n_blocks=5, prefix_cache=False),
        batch_slots=3)
    assert eng.generate(prompts, 24) == ref
    assert eng.stats["preemptions"] > 0, "pool was meant to force preemption"


@pytest.mark.parametrize("lm", ["h2o_danube_1_8b", "recurrentgemma_2b"],
                         indirect=True)
def test_paged_speculative_identity(lm):
    """Draft-propose / target-verify over per-layer policies: recurrent
    verify rows scatter state at the last cell and the scheduler's
    corrective commit rewinds to the acceptance point, so greedy output is
    bit-identical — with and without a starved pool underneath."""
    cfg, model, params = lm
    prompts = _prompts(cfg)
    ref = _ring(model, params, prompts, 24)
    for extra in ({}, {"n_blocks": 5, "prefix_cache": False}):
        eng = ServingEngine(
            model, params,
            ServeConfig(cache_len=96, cache_dtype="float32", quantized=False,
                        paged=True, speculative=SpeculativeConfig(k=3),
                        **extra),
            batch_slots=3, draft=(model, params))
        assert eng.generate(prompts, 24) == ref, extra


@pytest.mark.parametrize("lm", ["falcon_mamba_7b", "recurrentgemma_2b"],
                         indirect=True)
def test_quantized_recurrent_state_identity(lm):
    """K-Means int4 recurrent state: the per-token requantizing scan makes
    state at position t a function of the token stream only, so ring decode
    and packed multi-token rows agree bit-for-bit."""
    cfg, model, params = lm
    prompts = _prompts(cfg)
    ref = _ring(model, params, prompts, 24, quantized=True)
    eng = ServingEngine(
        model, params,
        ServeConfig(cache_len=96, cache_dtype="float32", quantized=True,
                    paged=True),
        batch_slots=3)
    assert eng.generate(prompts, 24) == ref


# ---------------------------------------------------------------------------
# per-policy resource plumbing
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("lm", ["falcon_mamba_7b"], indirect=True)
def test_prefix_cache_disabled_unless_all_paged(lm):
    """Prefix sharing is a paged-KV concept: asking for it on a stack with
    any non-paged_kv layer silently serves without it (block hashes would
    alias recurrent state that is NOT a pure function of the prefix
    blocks)."""
    cfg, model, params = lm
    eng = ServingEngine(
        model, params,
        ServeConfig(cache_len=96, cache_dtype="float32", quantized=False,
                    paged=True, prefix_cache=True),
        batch_slots=2)
    assert eng.scheduler.allocator.prefix_cache is False
    prompts = _prompts(cfg)
    assert eng.generate(prompts, 12) == _ring(model, params, prompts, 12)


@pytest.mark.parametrize("lm", ["recurrentgemma_2b"], indirect=True)
def test_seg_width_auto_bumped_for_recurrent_speculation(lm):
    """Recurrent verify needs the k+1 cells of one request in ONE row (state
    is sequential): the engine widens seg_width instead of failing."""
    cfg, model, params = lm
    eng = ServingEngine(
        model, params,
        ServeConfig(cache_len=96, cache_dtype="float32", quantized=False,
                    paged=True, seg_width=1, speculative=SpeculativeConfig(k=3)),
        batch_slots=2, draft=(model, params))
    assert eng.sc.seg_width >= 4


@pytest.mark.parametrize("lm", ["recurrentgemma_2b"], indirect=True)
def test_recurrent_seg_width_prefill_identity(lm):
    """seg_width > 1 without speculation: prefill packs multi-token rows
    (one row per request per step for recurrent stacks — a slot's cells may
    never split across rows), decode stays one cell per slot. Output is
    token-identical to the ring reference."""
    cfg, model, params = lm
    prompts = _prompts(cfg)
    ref = _ring(model, params, prompts, 16)
    eng = ServingEngine(
        model, params,
        ServeConfig(cache_len=96, cache_dtype="float32", quantized=False,
                    paged=True, seg_width=3),
        batch_slots=2)
    assert eng.generate(prompts, 16) == ref


@pytest.mark.parametrize("lm", ["h2o_danube_1_8b"], indirect=True)
def test_windowed_freed_blocks_are_reused(lm):
    """Long decode past the window with a pool SMALLER than unreleased
    demand finishes with zero preemptions: out-of-window blocks really
    return to the allocator (the long-form version lives in
    tests/test_long_decode.py)."""
    cfg, model, params = lm
    prompts = _prompts(cfg, n=2, length=8)
    new = cfg.sliding_window * 3
    ref = _ring(model, params, prompts, new)
    cap = windowed_block_cap(cfg.sliding_window, 16)
    eng = ServingEngine(
        model, params,
        ServeConfig(cache_len=128, cache_dtype="float32", quantized=False,
                    paged=True, n_blocks=2 * cap + 1, prefix_cache=False),
        batch_slots=2)
    assert eng.generate(prompts, new) == ref
    assert eng.stats["preemptions"] == 0
    assert eng.stats["peak_live_blocks_per_seq"] <= cap
