"""Long-decode correctness: ring-buffer wraparound + chunked-CE equivalence.

The long_500k cells rely on the ring-buffer KV cache discarding old tokens
exactly at the sliding-window boundary — these tests decode PAST the window
and check equality with a full-recompute reference, token by token.
"""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs.base import get_smoke_config
from repro.models.model import build
from repro.train.trainer import TrainConfig, _chunked_ce, loss_fn


@pytest.mark.parametrize("arch", ["h2o_danube_1_8b", "recurrentgemma_2b", "falcon_mamba_7b"])
def test_decode_past_window_matches_full_forward(arch):
    """Decode 2x past the SWA window through the ring cache == running the
    full model on the whole prefix each step (the window applies in both)."""
    cfg = get_smoke_config(arch)
    m = build(cfg)
    params = m.init(jax.random.PRNGKey(0))
    b = 2
    total = (cfg.sliding_window or 16) * 2 + 5  # decode well past the window
    toks = jax.random.randint(jax.random.PRNGKey(1), (b, total), 0, cfg.vocab_size)

    # incremental: prefill 4 tokens, then decode one at a time through the ring
    s0 = 4
    caches = m.init_caches(b, cache_len=total, dtype=jnp.float32)
    out = m.apply(params, {"tokens": toks[:, :s0]},
                  positions=jnp.arange(s0, dtype=jnp.int32), caches=caches)
    caches = out.caches
    for pos in range(s0, total):
        out = m.apply(params, {"tokens": toks[:, pos : pos + 1]},
                      positions=jnp.arange(pos, pos + 1, dtype=jnp.int32),
                      caches=caches)
        caches = out.caches
    incremental_last = out.logits[:, 0]

    # reference: one full forward over the whole sequence
    full = m.apply(params, {"tokens": toks})
    np.testing.assert_allclose(incremental_last, full.logits[:, -1], rtol=2e-4, atol=2e-4)


def test_swa_paged_long_decode_frees_blocks():
    """Windowed-paged policy: decoding far past the sliding window keeps at
    most ceil(window/block_size)+1 blocks live per sequence, and the freed
    blocks are genuinely re-allocatable — the pool is sized BELOW what an
    unreleased decode would need, so finishing without preemption proves
    out-of-window blocks were recycled. Output stays token-identical to the
    ring reference (prompt <= window, so the ring prefill is exact)."""
    from repro.serving.engine import ServeConfig, ServingEngine

    cfg = get_smoke_config("h2o_danube_1_8b")
    m = build(cfg)
    params = m.init(jax.random.PRNGKey(0))
    rng = np.random.RandomState(7)
    prompts = [list(rng.randint(1, cfg.vocab_size, size=8)) for _ in range(2)]
    new = cfg.sliding_window * 6 + 4  # decode well past the window

    ref = ServingEngine(
        m, params,
        ServeConfig(cache_len=256, cache_dtype="float32", quantized=False,
                    paged=False),
        batch_slots=2).generate(prompts, new)

    bs = 16
    cap = -(-cfg.sliding_window // bs) + 1  # partial head + partial tail
    unreleased = -(-(8 + new) // bs)  # blocks one seq would pin without release
    n_blocks = 2 * cap + 1
    assert n_blocks < unreleased, "pool must be smaller than unreleased demand"
    eng = ServingEngine(
        m, params,
        ServeConfig(cache_len=256, cache_dtype="float32", quantized=False,
                    paged=True, block_size=bs, n_blocks=n_blocks,
                    prefix_cache=False),
        batch_slots=2)
    out = eng.generate(prompts, new)
    assert out == ref, "windowed-paged decode diverged from the ring reference"
    st = eng.stats
    assert st["peak_live_blocks_per_seq"] <= cap, st["peak_live_blocks_per_seq"]
    assert st["preemptions"] == 0, (
        "pool below unreleased demand forced preemption: freed blocks "
        "were not re-allocatable"
    )


def test_chunked_ce_equals_plain_ce():
    """_chunked_ce (the big-vocab memory path) == direct softmax CE."""
    cfg = get_smoke_config("llama3_2_1b")
    m = build(cfg)
    params = m.init(jax.random.PRNGKey(0))
    tc = TrainConfig(z_loss=1e-4, aux_weight=0.0)
    batch = {"tokens": jax.random.randint(jax.random.PRNGKey(3), (3, 33), 0, cfg.vocab_size)}
    loss, metrics = loss_fn(m, params, batch, tc)

    # direct reference
    out = m.apply(params, {"tokens": batch["tokens"][:, :-1]})
    labels = batch["tokens"][:, 1:]
    lse = jax.nn.logsumexp(out.logits, axis=-1)
    ll = jnp.take_along_axis(out.logits, labels[..., None], axis=-1)[..., 0]
    ce_ref = jnp.mean(lse - ll)
    loss_ref = ce_ref + tc.z_loss * jnp.mean(jnp.square(lse))
    np.testing.assert_allclose(metrics["ce"], ce_ref, rtol=1e-5, atol=1e-5)
    np.testing.assert_allclose(loss, loss_ref, rtol=1e-5, atol=1e-5)


def test_chunked_ce_handles_ragged_token_count():
    """Padding path: token count not divisible by the chunk size."""
    h = jax.random.normal(jax.random.PRNGKey(4), (1, 7, 16))
    w = jax.random.normal(jax.random.PRNGKey(5), (16, 32))
    y = jax.random.randint(jax.random.PRNGKey(6), (1, 7), 0, 32)
    ce, _ = _chunked_ce(h, w, y, z_loss=0.0)
    logits = h.reshape(-1, 16) @ w
    lse = jax.nn.logsumexp(logits, axis=-1)
    ll = jnp.take_along_axis(logits, y.reshape(-1, 1), axis=-1)[:, 0]
    np.testing.assert_allclose(ce, jnp.mean(lse - ll), rtol=1e-5, atol=1e-5)
