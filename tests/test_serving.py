"""Serving subsystem tests: ring-buffer wraparound, quantized-KV parity,
paged-cache equivalence with the dense path, the packed token-budget
scheduler (mixed prefill+decode steps, decode-reservation accounting,
admission / slot refill / preemption determinism), the fixed-slot
fallback's pad masking, and the Pallas paged-attention kernel (single-token
and query-segment contracts) vs its jnp oracles."""

import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs.base import get_smoke_config
from repro.core.qlinear import QLinearConfig
from repro.core.quantspec import QuantSpec
from repro.models import layers as L
from repro.models.model import build, quantize_model
from repro.serving.engine import ServeConfig, ServingEngine, make_serve_step
from repro.serving.paged_cache import BlockAllocator, attach_tables, detach_tables

QSPEC = QuantSpec(base=QLinearConfig(detection="none"))


@pytest.fixture(scope="module")
def small_lm():
    cfg = get_smoke_config("llama3_2_1b")
    model = build(cfg)
    params = model.init(jax.random.PRNGKey(0))
    return cfg, model, params, quantize_model(model, params, QSPEC)


# ---------------------------------------------------------------------------
# ring-buffer KV cache
# ---------------------------------------------------------------------------

def test_ring_wraparound_at_cache_len(small_lm):
    """Full-attention decode PAST cache_len through the ring == a full
    forward with an equivalent sliding window (the ring physically keeps
    exactly the last cache_len tokens)."""
    cfg, model, params, _ = small_lm
    c, total, b = 8, 21, 2
    toks = jax.random.randint(jax.random.PRNGKey(1), (b, total), 0, cfg.vocab_size)
    caches = model.init_caches(b, cache_len=c, dtype=jnp.float32)
    out = model.apply(params, {"tokens": toks[:, :4]},
                      positions=jnp.arange(4, dtype=jnp.int32), caches=caches)
    caches = out.caches
    for pos in range(4, total):
        out = model.apply(params, {"tokens": toks[:, pos : pos + 1]},
                          positions=jnp.arange(pos, pos + 1, dtype=jnp.int32),
                          caches=caches)
        caches = out.caches
    windowed = build(dataclasses.replace(cfg, sliding_window=c))
    full = windowed.apply(params, {"tokens": toks})
    np.testing.assert_allclose(out.logits[:, 0], full.logits[:, -1],
                               rtol=2e-4, atol=2e-4)


def test_kv_quant_cache_roundtrip(small_lm):
    """int4 K-Means KV storage reconstructs K/V within the codebook's
    resolution (documented tolerance: ~15% RMS rel. error, corr > 0.97)."""
    cfg = small_lm[0]
    k = jax.random.normal(jax.random.PRNGKey(0), (2, 16, cfg.n_kv_heads, cfg.head_dim))
    cache = L.init_kv_cache(cfg, 2, 16, jnp.float32, quantized=True)
    cache = L._cache_write(cache, k, k, jnp.arange(16, dtype=jnp.int32))
    kd, vd = L._cache_read(cache, jnp.float32)
    rel = float(jnp.linalg.norm(kd - k) / jnp.linalg.norm(k))
    corr = float(jnp.corrcoef(kd.ravel(), k.ravel())[0, 1])
    assert rel < 0.25 and corr > 0.97, (rel, corr)
    np.testing.assert_allclose(kd, vd)  # same input -> same reconstruction


def test_kv_quant_vs_bf16_short_decode_bounded(small_lm):
    """Quantized (kv_quant=True) vs fp ring cache on a short decode: logits
    stay finite and within the int4 cache's documented divergence bound."""
    cfg, model, params, _ = small_lm
    toks = jax.random.randint(jax.random.PRNGKey(3), (2, 6), 0, cfg.vocab_size)

    def decode(quant):
        caches = model.init_caches(2, cache_len=32, dtype=jnp.float32, quantized=quant)
        out = model.apply(params, {"tokens": toks},
                          positions=jnp.arange(6, dtype=jnp.int32), caches=caches)
        caches = out.caches
        tok = jnp.argmax(out.logits[:, -1, : cfg.vocab_size], -1)[:, None]
        logs = []
        for pos in range(6, 10):
            out = model.apply(params, {"tokens": tok},
                              positions=jnp.arange(pos, pos + 1, dtype=jnp.int32),
                              caches=caches)
            caches = out.caches
            tok = jnp.argmax(out.logits[:, -1, : cfg.vocab_size], -1)[:, None]
            logs.append(out.logits[:, 0])
        return jnp.stack(logs)

    lb, lq = decode(False), decode(True)
    assert bool(jnp.isfinite(lq).all())
    # untrained-random logits are near zero, so the bound is absolute:
    # int4 KV reconstruction error (~14% RMS) must not blow up through attn
    assert float(jnp.abs(lb - lq).mean()) < 5 * float(lb.std())


# ---------------------------------------------------------------------------
# paged cache vs dense ring
# ---------------------------------------------------------------------------

def _paged_prefill_logits(model, params, toks, block_size, quantized=False):
    """Manual paged prefill+decode at the model level with one request."""
    cfg = model.cfg
    plen = toks.shape[1]
    n_blocks = -(-((plen + 8)) // block_size)
    pools = model.init_caches(1, plen + 8, jnp.dtype("float32"), quantized=quantized,
                              layout="paged", block_size=block_size,
                              n_blocks=n_blocks)
    bt = jnp.arange(n_blocks, dtype=jnp.int32)[None]
    caches = attach_tables(pools, bt, jnp.array([plen], jnp.int32),
                           cfg.n_layers, cfg.scan_layers)
    out = model.apply(params, {"tokens": toks},
                      positions=jnp.arange(plen, dtype=jnp.int32), caches=caches)
    logs = [out.logits[:, -1]]
    pools = detach_tables(out.caches)
    tok = jnp.argmax(out.logits[:, -1, : cfg.vocab_size], -1)[:, None]
    for pos in range(plen, plen + 4):
        caches = attach_tables(pools, bt, jnp.array([pos + 1], jnp.int32),
                               cfg.n_layers, cfg.scan_layers)
        out = model.apply(params, {"tokens": tok},
                          positions=jnp.array([[pos]], jnp.int32), caches=caches)
        pools = detach_tables(out.caches)
        tok = jnp.argmax(out.logits[:, -1, : cfg.vocab_size], -1)[:, None]
        logs.append(out.logits[:, -1])
    return jnp.concatenate(logs, 0)


def test_paged_vs_dense_logits_equivalence(small_lm):
    """Model-level: prefill + 4 greedy decode steps, paged block pool vs the
    dense ring buffer — logits must agree to float tolerance."""
    cfg, model, params, _ = small_lm
    toks = jax.random.randint(jax.random.PRNGKey(5), (1, 7), 0, cfg.vocab_size)

    caches = model.init_caches(1, cache_len=32, dtype=jnp.float32)
    out = model.apply(params, {"tokens": toks},
                      positions=jnp.arange(7, dtype=jnp.int32), caches=caches)
    caches = out.caches
    dense = [out.logits[:, -1]]
    tok = jnp.argmax(out.logits[:, -1, : cfg.vocab_size], -1)[:, None]
    for pos in range(7, 11):
        out = model.apply(params, {"tokens": tok},
                          positions=jnp.arange(pos, pos + 1, dtype=jnp.int32),
                          caches=caches)
        caches = out.caches
        tok = jnp.argmax(out.logits[:, -1, : cfg.vocab_size], -1)[:, None]
        dense.append(out.logits[:, -1])
    dense = jnp.concatenate(dense, 0)

    paged = _paged_prefill_logits(model, params, toks, block_size=4)
    np.testing.assert_allclose(paged, dense, rtol=2e-4, atol=2e-4)


def test_paged_engine_matches_ring_engine_greedy(small_lm):
    """Engine-level acceptance: paged scheduler output is token-identical to
    the ring-buffer path run without cross-request padding (one prompt at a
    time), bf16->f32 cache, greedy."""
    cfg, model, params, qp = small_lm
    prompts = [[1, 2, 3], [4, 5], [6], [7, 8, 9, 10], [11, 12]]
    ring = ServingEngine(model, qp, ServeConfig(cache_len=64,
                                                cache_dtype="float32", paged=False),
                         batch_slots=4)
    paged = ServingEngine(model, qp, ServeConfig(cache_len=64,
                                                 cache_dtype="float32", block_size=8,
                                                 prefill_chunk=4),
                          batch_slots=4)
    want = [ring.generate([p], max_new_tokens=6)[0] for p in prompts]
    got = paged.generate(prompts, max_new_tokens=6)
    assert got == want


def test_paged_int4_matches_ring_int4(small_lm):
    """kv_quant=True: the paged pool quantizes tokens exactly like the ring
    cache (same codebook, per-token scale), so greedy tokens are identical."""
    cfg, model, params, qp = small_lm
    prompts = [[1, 2, 3, 4, 5], [6, 9], [7, 8, 9, 10]]
    mk = lambda paged: ServingEngine(
        model, qp,
        ServeConfig(cache_len=32, cache_dtype="float32",
                    kv_quant=True, paged=paged, block_size=4, prefill_chunk=4),
        batch_slots=3,
    )
    want = [mk(False).generate([p], max_new_tokens=5)[0] for p in prompts]
    assert mk(True).generate(prompts, max_new_tokens=5) == want


# ---------------------------------------------------------------------------
# scheduler behaviour
# ---------------------------------------------------------------------------

def test_packed_mixed_traffic_matches_sequential_reference(small_lm):
    """Tentpole acceptance: packed mixed prefill+decode steps (online
    arrivals landing while other requests decode) produce greedy tokens
    identical to unbatched per-prompt generation. A tiny token budget forces
    prompts to span several packed steps."""
    cfg, model, params, qp = small_lm
    prompts = [[(7 * i + j) % cfg.vocab_size or 1 for j in range(n)]
               for i, n in enumerate([13, 2, 9, 5, 1, 17, 4])]
    budgets = [5, 8, 3, 6, 2, 4, 7]
    ring = ServingEngine(model, qp, ServeConfig(cache_len=64,
                                                cache_dtype="float32", paged=False),
                         batch_slots=1)
    want = {i: ring.generate([p], max_new_tokens=b)[0]
            for i, (p, b) in enumerate(zip(prompts, budgets))}

    eng = ServingEngine(model, qp,
                        ServeConfig(cache_len=64,
                                    cache_dtype="float32", block_size=8,
                                    prefill_chunk=4, token_budget=8),
                        batch_slots=3)
    sched = eng.scheduler
    results: dict[int, list[int]] = {}
    rid_of = {}
    # two requests up front, the rest arrive online every other step — their
    # prompts must prefill INSIDE steps that also decode the running slots
    rid_of[sched.submit(prompts[0], budgets[0], salt=0)] = 0
    rid_of[sched.submit(prompts[1], budgets[1], salt=1)] = 1
    nxt, steps = 2, 0
    while sched.step(results) or nxt < len(prompts):
        steps += 1
        if nxt < len(prompts) and steps % 2 == 0:
            rid_of[sched.submit(prompts[nxt], budgets[nxt], salt=nxt)] = nxt
            nxt += 1
    assert sched.stats["mixed_steps"] > 0, "no mixed prefill+decode step exercised"
    assert {rid_of[r]: v for r, v in results.items()} == want


def test_packed_budget_decode_never_starved(small_lm):
    """Token-budget accounting: while a long prompt admits and prefills over
    several packed steps, every already-decoding request still generates
    exactly one token per step (decode rows are reserved before prefill)."""
    cfg, model, params, qp = small_lm
    eng = ServingEngine(model, qp,
                        ServeConfig(cache_len=64,
                                    cache_dtype="float32", block_size=8,
                                    prefill_chunk=4, token_budget=6),
                        batch_slots=3)
    sched = eng.scheduler
    results: dict[int, list[int]] = {}
    ra = sched.submit([1, 2, 3], 32, salt=0)
    while not any(r.rid == ra and r.decoding for r in sched._running):
        sched.step(results)
    # long prompt: at budget 6 with one decode row reserved, 5 prefill
    # tokens/step -> at least 5 mixed steps before rb decodes
    rb = sched.submit([2] * 30, 4, salt=1)
    a = next(r for r in sched._running if r.rid == ra)
    while any(r.rid == rb and not r.decoding for r in sched._running) \
            or not any(r.rid == rb for r in sched._running):
        before = len(a.generated)
        sched.step(results)
        assert len(a.generated) == before + 1, "decode starved by admission"
    assert sched.stats["mixed_steps"] >= 5
    results.update(sched.run())
    assert len(results[ra]) == 32 and len(results[rb]) == 4


def test_packed_step_rejects_budget_below_slots(small_lm):
    cfg, model, params, qp = small_lm
    with pytest.raises(ValueError, match="token_budget"):
        ServingEngine(model, qp,
                      ServeConfig(cache_len=32,
                                  cache_dtype="float32", token_budget=2),
                      batch_slots=4)


def test_fallback_padding_not_attended(small_lm):
    """Fixed-slot fallback regression: left-pad tokens used to be written to
    the KV cache at real positions and attended — mixed-length batched
    generation must match unpadded per-prompt generation."""
    cfg, model, params, qp = small_lm
    eng = ServingEngine(model, qp,
                        ServeConfig(cache_len=32,
                                    cache_dtype="float32", paged=False),
                        batch_slots=4)
    prompts = [[1, 2, 3, 4, 5, 6, 7], [4, 5], [6], [7, 8, 9, 10]]
    batched = eng.generate(prompts, max_new_tokens=6)
    single = [eng.generate([p], max_new_tokens=6)[0] for p in prompts]
    assert batched == single


def test_scheduler_queue_overflow_and_slot_refill(small_lm):
    """More requests than slots: all are served through the queue (iterative
    admission, not recursive chunking) with per-request budgets."""
    cfg, model, params, qp = small_lm
    eng = ServingEngine(model, qp,
                        ServeConfig(cache_len=32,
                                    cache_dtype="float32", block_size=4),
                        batch_slots=2)
    prompts = [[i + 1, i + 2] for i in range(7)]
    budgets = [3, 1, 4, 2, 5, 1, 2]
    outs = eng.generate(prompts, max_new_tokens=budgets)
    assert [len(o) for o in outs] == budgets
    assert all(0 <= t < cfg.vocab_size for o in outs for t in o)
    assert eng.scheduler.stats["decode_steps"] > 0
    # pool fully reclaimed after drain
    assert eng.scheduler.allocator.n_free == eng.scheduler.pcfg.n_blocks


def test_scheduler_prefill_only_burst(small_lm):
    """Budget-1 requests finish AT prefill; the queue must keep draining
    (regression: this used to trip the pool-capacity error)."""
    cfg, model, params, qp = small_lm
    eng = ServingEngine(model, qp,
                        ServeConfig(cache_len=16,
                                    cache_dtype="float32", block_size=4),
                        batch_slots=2)
    outs = eng.generate([[i + 1] for i in range(5)], max_new_tokens=1)
    assert [len(o) for o in outs] == [1] * 5


def test_scheduler_preemption_is_deterministic(small_lm):
    """A pool too small for all slots forces preemption-by-eviction; the
    recomputed K-Means KV is bit-identical so outputs don't change."""
    cfg, model, params, qp = small_lm
    prompts = [[1, 2, 3, 4, 5, 6, 7], [4, 5], [6, 9, 1], [7, 8, 9, 10]]
    mk = lambda n_blocks: ServingEngine(
        model, qp,
        ServeConfig(cache_len=32, cache_dtype="float32",
                    block_size=4, prefill_chunk=4, n_blocks=n_blocks),
        batch_slots=3,
    )
    big, small = mk(0), mk(7)
    a = big.generate(prompts, max_new_tokens=8)
    b = small.generate(prompts, max_new_tokens=8)
    assert small.scheduler.stats["preemptions"] > 0
    assert big.scheduler.stats["preemptions"] == 0
    assert a == b


def test_scheduler_rejects_oversized_request(small_lm):
    cfg, model, params, qp = small_lm
    eng = ServingEngine(model, qp,
                        ServeConfig(cache_len=16,
                                    cache_dtype="float32", block_size=4),
                        batch_slots=2)
    with pytest.raises(ValueError, match="exceeds"):
        eng.generate([[1] * 12], max_new_tokens=8)


def test_engine_eos_padding_both_paths(small_lm):
    """eos_id handling: outputs are exactly max_new_tokens, eos-padded."""
    cfg, model, params, qp = small_lm
    for paged in (True, False):
        eng = ServingEngine(model, qp,
                            ServeConfig(cache_len=32,
                                        cache_dtype="float32", paged=paged),
                            batch_slots=2)
        outs = eng.generate([[1, 2, 3], [5, 6]], max_new_tokens=6, eos_id=0)
        assert all(len(o) == 6 for o in outs)
        for o in outs:
            if 0 in o:
                assert all(t == 0 for t in o[o.index(0):])  # eos is absorbing


def test_temperature_sampling_seed_reproducible(small_lm):
    """Same seed + same request set -> identical samples on BOTH paths
    (regression: paged keys used to depend on the engine-global rid)."""
    cfg, model, params, qp = small_lm
    for paged in (True, False):
        eng = ServingEngine(model, qp,
                            ServeConfig(cache_len=32,
                                        cache_dtype="float32", temperature=1.0,
                                        paged=paged),
                            batch_slots=2)
        a = eng.generate([[1, 2, 3], [7, 8]], max_new_tokens=6, seed=1)
        b = eng.generate([[1, 2, 3], [7, 8]], max_new_tokens=6, seed=1)
        c = eng.generate([[1, 2, 3], [7, 8]], max_new_tokens=6, seed=2)
        assert a == b and a != c, ("paged" if paged else "ring")


def test_serve_step_returns_current_logits(small_lm):
    """The stale-logits fix: make_serve_step's logits are THIS step's
    distribution (match a direct model.apply at the same position)."""
    cfg, model, params, _ = small_lm
    sc = ServeConfig(cache_len=16, cache_dtype="float32")
    step = make_serve_step(model, sc)
    caches = model.init_caches(2, sc.cache_len, jnp.float32)
    toks = jax.random.randint(jax.random.PRNGKey(7), (2, 1), 0, cfg.vocab_size)
    tok, new_caches, logits = step(params, caches, toks, jnp.int32(0))
    direct = model.apply(params, {"tokens": toks},
                         positions=jnp.arange(1, dtype=jnp.int32),
                         caches=model.init_caches(2, sc.cache_len, jnp.float32))
    np.testing.assert_allclose(logits, direct.logits[:, -1, : cfg.vocab_size],
                               rtol=1e-5, atol=1e-5)
    np.testing.assert_array_equal(tok, jnp.argmax(logits, -1))


def test_block_allocator_zero_alloc_and_empty_prompt(small_lm):
    """alloc(0) must not hand out the whole free list (regression), and the
    scheduler rejects empty prompts (whose block need is 0)."""
    a = BlockAllocator(4)
    assert a.alloc(0) == [] and a.n_free == 4
    cfg, model, params, qp = small_lm
    eng = ServingEngine(model, qp,
                        ServeConfig(cache_len=16,
                                    cache_dtype="float32", block_size=4),
                        batch_slots=2)
    with pytest.raises(ValueError, match="empty prompt"):
        eng.generate([[]], max_new_tokens=4)


def test_block_allocator_invariants():
    a = BlockAllocator(6)
    got = a.alloc(4)
    assert len(got) == 4 and len(set(got)) == 4 and a.n_free == 2
    assert a.alloc(3) is None and a.n_free == 2  # all-or-nothing
    a.free(got[:2])
    assert a.n_free == 4 and a.occupancy == pytest.approx(2 / 6)
    more = a.alloc(4)
    assert a.n_free == 0 and a.alloc(1) is None
    assert sorted(got[2:] + more) == sorted(set(got[2:] + more))  # ids unique
    a.free(got[2:] + more)
    assert a.n_free == 6


# ---------------------------------------------------------------------------
# Pallas paged-attention kernel vs jnp oracles
# ---------------------------------------------------------------------------

def _paged_fixture():
    b, kv, g, hd, bs, max_blk, n_blocks = 3, 2, 2, 8, 4, 5, 16
    q = jax.random.normal(jax.random.PRNGKey(0), (b, 1, kv, g, hd))
    kp = jax.random.normal(jax.random.PRNGKey(1), (n_blocks, bs, kv, hd))
    vp = jax.random.normal(jax.random.PRNGKey(2), (n_blocks, bs, kv, hd))
    bt = np.full((b, max_blk), -1, np.int32)
    ids = np.random.RandomState(0).permutation(n_blocks)
    ctx = np.array([7, 1, 18], np.int32)
    off = 0
    for i in range(b):
        need = -(-int(ctx[i]) // bs)
        bt[i, :need] = ids[off : off + need]
        off += need
    return q, kp, vp, jnp.array(bt), jnp.array(ctx)


def test_paged_attn_kernel_matches_ref():
    from repro.kernels.paged_attn import paged_attn_kernel_call
    from repro.kernels.ref import paged_attn_ref

    q, kp, vp, bt, ctx = _paged_fixture()
    q_pos = (ctx - 1)[:, None]
    ref = paged_attn_ref(q, kp, vp, bt, ctx, q_pos)
    ker = paged_attn_kernel_call(q, kp, vp, block_tables=bt, ctx_lens=ctx,
                                 q_pos=q_pos, interpret=True)
    np.testing.assert_allclose(ker, ref, rtol=1e-5, atol=1e-5)


def _segment_fixture(seg: int):
    """Ragged query segments: each request's segment covers its last
    min(seg, ctx) positions; shorter segments are padded with q_pos = -1."""
    _, kp, vp, bt, ctx = _paged_fixture()
    b, (kv, hd) = bt.shape[0], kp.shape[2:]
    g = 2
    q = jax.random.normal(jax.random.PRNGKey(7), (b, seg, kv, g, hd))
    q_pos = np.full((b, seg), -1, np.int32)
    for i in range(b):
        n = min(seg, int(ctx[i]))
        q_pos[i, :n] = np.arange(int(ctx[i]) - n, int(ctx[i]))
    return q, kp, vp, bt, ctx, jnp.array(q_pos)


def test_paged_attn_kernel_query_segments_match_ref():
    """Multi-token query segments (the packed/chunked-prefill shape): kernel
    matches the oracle on every valid row; padded rows (q_pos = -1) are
    ignored."""
    from repro.kernels.paged_attn import paged_attn_kernel_call
    from repro.kernels.ref import paged_attn_ref

    q, kp, vp, bt, ctx, q_pos = _segment_fixture(seg=4)
    ref = paged_attn_ref(q, kp, vp, bt, ctx, q_pos)
    ker = paged_attn_kernel_call(q, kp, vp, block_tables=bt, ctx_lens=ctx,
                                 q_pos=q_pos, interpret=True)
    valid = np.asarray(q_pos) >= 0
    np.testing.assert_allclose(np.asarray(ker)[valid], np.asarray(ref)[valid],
                               rtol=1e-5, atol=1e-5)
    assert bool(jnp.isfinite(ker).all())  # padded rows garbage but finite


def _quant_pages(kp, vp):
    from repro.core.codebook import assign_via_boundaries
    from repro.core.quantize import pack_int4
    from repro.models.model import _default_codebook

    book = _default_codebook(4)

    def quant(x):
        s = jnp.maximum(jnp.sqrt(jnp.mean(jnp.square(x), -1, keepdims=True)), 1e-12)
        return pack_int4(assign_via_boundaries((x / s).astype(jnp.float32), book)), s

    ki, ks = quant(kp)
    vi, vs = quant(vp)
    return ki, ks, vi, vs, book


def test_paged_attn_quant_kernel_matches_ref():
    from repro.kernels.paged_attn import paged_attn_kernel_call
    from repro.kernels.ref import paged_attn_quant_ref

    q, kp, vp, bt, ctx = _paged_fixture()
    ki, ks, vi, vs, book = _quant_pages(kp, vp)
    q_pos = (ctx - 1)[:, None]
    ref = paged_attn_quant_ref(q, ki, ks, vi, vs, book, bt, ctx, q_pos)
    ker = paged_attn_kernel_call(q, ki, ks, vi, vs, book, block_tables=bt,
                                 ctx_lens=ctx, q_pos=q_pos, interpret=True)
    np.testing.assert_allclose(ker, ref, rtol=1e-5, atol=1e-5)


def test_paged_attn_quant_kernel_query_segments_match_ref():
    from repro.kernels.paged_attn import paged_attn_kernel_call
    from repro.kernels.ref import paged_attn_quant_ref

    q, kp, vp, bt, ctx, q_pos = _segment_fixture(seg=5)
    ki, ks, vi, vs, book = _quant_pages(kp, vp)
    ref = paged_attn_quant_ref(q, ki, ks, vi, vs, book, bt, ctx, q_pos)
    ker = paged_attn_kernel_call(q, ki, ks, vi, vs, book, block_tables=bt,
                                 ctx_lens=ctx, q_pos=q_pos, interpret=True)
    valid = np.asarray(q_pos) >= 0
    np.testing.assert_allclose(np.asarray(ker)[valid], np.asarray(ref)[valid],
                               rtol=1e-5, atol=1e-5)


def test_paged_kernel_path_in_model_decode(small_lm, monkeypatch):
    """Kernel routing: prefill (query segment) + decode through the Pallas
    kernel produce the same logits as the jnp gather path."""
    cfg, model, params, _ = small_lm
    toks = jax.random.randint(jax.random.PRNGKey(11), (1, 6), 0, cfg.vocab_size)
    a = _paged_prefill_logits(model, params, toks, block_size=4)
    monkeypatch.setattr(L, "_USE_PAGED_KERNEL", True)
    b = _paged_prefill_logits(model, params, toks, block_size=4)
    np.testing.assert_allclose(a, b, rtol=1e-4, atol=1e-4)


def test_paged_kernel_default_routing(monkeypatch):
    """REPRO_PAGED_KERNEL is opt-OUT on TPU, opt-in elsewhere."""
    on_tpu = jax.default_backend() == "tpu"
    monkeypatch.delenv("REPRO_PAGED_KERNEL", raising=False)
    assert L._paged_kernel_default() == on_tpu
    monkeypatch.setenv("REPRO_PAGED_KERNEL", "0")
    assert L._paged_kernel_default() is False
    monkeypatch.setenv("REPRO_PAGED_KERNEL", "off")
    assert L._paged_kernel_default() is False
    monkeypatch.setenv("REPRO_PAGED_KERNEL", "1")
    assert L._paged_kernel_default() is True
    monkeypatch.setenv("REPRO_PAGED_KERNEL", "auto")
    assert L._paged_kernel_default() == on_tpu


def test_packed_scheduler_through_kernel(small_lm, monkeypatch):
    """The full packed token-budget step routed through the Pallas kernel
    (interpret mode) generates the same greedy tokens as the jnp path."""
    cfg, model, params, qp = small_lm
    mk = lambda: ServingEngine(
        model, qp,
        ServeConfig(cache_len=32, cache_dtype="float32",
                    block_size=4, prefill_chunk=2, token_budget=4),
        batch_slots=2,
    )
    prompts = [[1, 2, 3, 4, 5], [6, 9]]
    want = mk().generate(prompts, max_new_tokens=3)
    monkeypatch.setattr(L, "_USE_PAGED_KERNEL", True)
    got = mk().generate(prompts, max_new_tokens=3)
    assert got == want


def test_paged_ref_respects_block_table_permutation():
    """The same logical sequence stored under two different physical block
    layouts must attend identically (storage location is invisible)."""
    from repro.kernels.ref import paged_attn_ref

    q, kp, vp, bt, ctx = _paged_fixture()
    n_blocks = kp.shape[0]
    perm = jnp.array(np.random.RandomState(3).permutation(n_blocks))
    inv = jnp.argsort(perm)
    kp2, vp2 = kp[perm], vp[perm]
    bt2 = jnp.where(bt >= 0, inv[jnp.clip(bt, 0, n_blocks - 1)], -1)
    a = paged_attn_ref(q, kp, vp, bt, ctx, (ctx - 1)[:, None])
    b = paged_attn_ref(q, kp2, vp2, bt2, ctx, (ctx - 1)[:, None])
    np.testing.assert_allclose(a, b, rtol=1e-6, atol=1e-6)
