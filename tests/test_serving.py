"""Serving subsystem tests: ring-buffer wraparound, quantized-KV parity,
paged-cache equivalence with the dense path, the packed token-budget
scheduler (mixed prefill+decode steps, decode-reservation accounting,
admission / slot refill / preemption determinism), prefix sharing
(refcounted content-hashed blocks, copy-on-write, LRU eviction of cached
prefixes, token-identity with sharing off), allocator safety (double-free
validation, admission block reservation, padded-row write masking), the
fixed-slot fallback's pad masking, and the Pallas paged-attention kernel
(single-token and query-segment contracts) vs its jnp oracles."""

import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs.base import get_smoke_config
from repro.core.qlinear import QLinearConfig
from repro.core.quantspec import QuantSpec
from repro.models import layers as L
from repro.models.model import build, quantize_model
from repro.serving.engine import ServeConfig, ServingEngine, make_serve_step
from repro.serving.paged_cache import (BlockAllocator, attach_tables,
                                       chain_hash, detach_tables, prefix_seed)

QSPEC = QuantSpec(base=QLinearConfig(detection="none"))


@pytest.fixture(scope="module")
def small_lm():
    cfg = get_smoke_config("llama3_2_1b")
    model = build(cfg)
    params = model.init(jax.random.PRNGKey(0))
    return cfg, model, params, quantize_model(model, params, QSPEC)


# ---------------------------------------------------------------------------
# ring-buffer KV cache
# ---------------------------------------------------------------------------

def test_ring_wraparound_at_cache_len(small_lm):
    """Full-attention decode PAST cache_len through the ring == a full
    forward with an equivalent sliding window (the ring physically keeps
    exactly the last cache_len tokens)."""
    cfg, model, params, _ = small_lm
    c, total, b = 8, 21, 2
    toks = jax.random.randint(jax.random.PRNGKey(1), (b, total), 0, cfg.vocab_size)
    caches = model.init_caches(b, cache_len=c, dtype=jnp.float32)
    out = model.apply(params, {"tokens": toks[:, :4]},
                      positions=jnp.arange(4, dtype=jnp.int32), caches=caches)
    caches = out.caches
    for pos in range(4, total):
        out = model.apply(params, {"tokens": toks[:, pos : pos + 1]},
                          positions=jnp.arange(pos, pos + 1, dtype=jnp.int32),
                          caches=caches)
        caches = out.caches
    windowed = build(dataclasses.replace(cfg, sliding_window=c))
    full = windowed.apply(params, {"tokens": toks})
    np.testing.assert_allclose(out.logits[:, 0], full.logits[:, -1],
                               rtol=2e-4, atol=2e-4)


def test_kv_quant_cache_roundtrip(small_lm):
    """int4 K-Means KV storage reconstructs K/V within the codebook's
    resolution (documented tolerance: ~15% RMS rel. error, corr > 0.97)."""
    cfg = small_lm[0]
    k = jax.random.normal(jax.random.PRNGKey(0), (2, 16, cfg.n_kv_heads, cfg.head_dim))
    cache = L.init_kv_cache(cfg, 2, 16, jnp.float32, quantized=True)
    cache = L._cache_write(cache, k, k, jnp.arange(16, dtype=jnp.int32))
    kd, vd = L._cache_read(cache, jnp.float32)
    rel = float(jnp.linalg.norm(kd - k) / jnp.linalg.norm(k))
    corr = float(jnp.corrcoef(kd.ravel(), k.ravel())[0, 1])
    assert rel < 0.25 and corr > 0.97, (rel, corr)
    np.testing.assert_allclose(kd, vd)  # same input -> same reconstruction


def test_kv_quant_vs_bf16_short_decode_bounded(small_lm):
    """Quantized (kv_quant=True) vs fp ring cache on a short decode: logits
    stay finite and within the int4 cache's documented divergence bound."""
    cfg, model, params, _ = small_lm
    toks = jax.random.randint(jax.random.PRNGKey(3), (2, 6), 0, cfg.vocab_size)

    def decode(quant):
        caches = model.init_caches(2, cache_len=32, dtype=jnp.float32, quantized=quant)
        out = model.apply(params, {"tokens": toks},
                          positions=jnp.arange(6, dtype=jnp.int32), caches=caches)
        caches = out.caches
        tok = jnp.argmax(out.logits[:, -1, : cfg.vocab_size], -1)[:, None]
        logs = []
        for pos in range(6, 10):
            out = model.apply(params, {"tokens": tok},
                              positions=jnp.arange(pos, pos + 1, dtype=jnp.int32),
                              caches=caches)
            caches = out.caches
            tok = jnp.argmax(out.logits[:, -1, : cfg.vocab_size], -1)[:, None]
            logs.append(out.logits[:, 0])
        return jnp.stack(logs)

    lb, lq = decode(False), decode(True)
    assert bool(jnp.isfinite(lq).all())
    # untrained-random logits are near zero, so the bound is absolute:
    # int4 KV reconstruction error (~14% RMS) must not blow up through attn
    assert float(jnp.abs(lb - lq).mean()) < 5 * float(lb.std())


# ---------------------------------------------------------------------------
# paged cache vs dense ring
# ---------------------------------------------------------------------------

def _paged_prefill_logits(model, params, toks, block_size, quantized=False):
    """Manual paged prefill+decode at the model level with one request."""
    cfg = model.cfg
    plen = toks.shape[1]
    n_blocks = -(-((plen + 8)) // block_size)
    pools = model.init_caches(1, plen + 8, jnp.dtype("float32"), quantized=quantized,
                              layout="paged", block_size=block_size,
                              n_blocks=n_blocks)
    bt = jnp.arange(n_blocks, dtype=jnp.int32)[None]
    caches = attach_tables(pools, bt, jnp.array([plen], jnp.int32),
                           cfg.n_layers, cfg.scan_layers)
    out = model.apply(params, {"tokens": toks},
                      positions=jnp.arange(plen, dtype=jnp.int32), caches=caches)
    logs = [out.logits[:, -1]]
    pools = detach_tables(out.caches)
    tok = jnp.argmax(out.logits[:, -1, : cfg.vocab_size], -1)[:, None]
    for pos in range(plen, plen + 4):
        caches = attach_tables(pools, bt, jnp.array([pos + 1], jnp.int32),
                               cfg.n_layers, cfg.scan_layers)
        out = model.apply(params, {"tokens": tok},
                          positions=jnp.array([[pos]], jnp.int32), caches=caches)
        pools = detach_tables(out.caches)
        tok = jnp.argmax(out.logits[:, -1, : cfg.vocab_size], -1)[:, None]
        logs.append(out.logits[:, -1])
    return jnp.concatenate(logs, 0)


def test_paged_vs_dense_logits_equivalence(small_lm):
    """Model-level: prefill + 4 greedy decode steps, paged block pool vs the
    dense ring buffer — logits must agree to float tolerance."""
    cfg, model, params, _ = small_lm
    toks = jax.random.randint(jax.random.PRNGKey(5), (1, 7), 0, cfg.vocab_size)

    caches = model.init_caches(1, cache_len=32, dtype=jnp.float32)
    out = model.apply(params, {"tokens": toks},
                      positions=jnp.arange(7, dtype=jnp.int32), caches=caches)
    caches = out.caches
    dense = [out.logits[:, -1]]
    tok = jnp.argmax(out.logits[:, -1, : cfg.vocab_size], -1)[:, None]
    for pos in range(7, 11):
        out = model.apply(params, {"tokens": tok},
                          positions=jnp.arange(pos, pos + 1, dtype=jnp.int32),
                          caches=caches)
        caches = out.caches
        tok = jnp.argmax(out.logits[:, -1, : cfg.vocab_size], -1)[:, None]
        dense.append(out.logits[:, -1])
    dense = jnp.concatenate(dense, 0)

    paged = _paged_prefill_logits(model, params, toks, block_size=4)
    np.testing.assert_allclose(paged, dense, rtol=2e-4, atol=2e-4)


def test_paged_engine_matches_ring_engine_greedy(small_lm):
    """Engine-level acceptance: paged scheduler output is token-identical to
    the ring-buffer path run without cross-request padding (one prompt at a
    time), bf16->f32 cache, greedy."""
    cfg, model, params, qp = small_lm
    prompts = [[1, 2, 3], [4, 5], [6], [7, 8, 9, 10], [11, 12]]
    ring = ServingEngine(model, qp, ServeConfig(cache_len=64,
                                                cache_dtype="float32", paged=False),
                         batch_slots=4)
    paged = ServingEngine(model, qp, ServeConfig(cache_len=64,
                                                 cache_dtype="float32", block_size=8,
                                                 prefill_chunk=4),
                          batch_slots=4)
    want = [ring.generate([p], max_new_tokens=6)[0] for p in prompts]
    got = paged.generate(prompts, max_new_tokens=6)
    assert got == want


def test_paged_int4_matches_ring_int4(small_lm):
    """kv_quant=True: the paged pool quantizes tokens exactly like the ring
    cache (same codebook, per-token scale), so greedy tokens are identical."""
    cfg, model, params, qp = small_lm
    prompts = [[1, 2, 3, 4, 5], [6, 9], [7, 8, 9, 10]]
    mk = lambda paged: ServingEngine(
        model, qp,
        ServeConfig(cache_len=32, cache_dtype="float32",
                    kv_quant=True, paged=paged, block_size=4, prefill_chunk=4),
        batch_slots=3,
    )
    want = [mk(False).generate([p], max_new_tokens=5)[0] for p in prompts]
    assert mk(True).generate(prompts, max_new_tokens=5) == want


# ---------------------------------------------------------------------------
# scheduler behaviour
# ---------------------------------------------------------------------------

def test_packed_mixed_traffic_matches_sequential_reference(small_lm):
    """Tentpole acceptance: packed mixed prefill+decode steps (online
    arrivals landing while other requests decode) produce greedy tokens
    identical to unbatched per-prompt generation. A tiny token budget forces
    prompts to span several packed steps."""
    cfg, model, params, qp = small_lm
    prompts = [[(7 * i + j) % cfg.vocab_size or 1 for j in range(n)]
               for i, n in enumerate([13, 2, 9, 5, 1, 17, 4])]
    budgets = [5, 8, 3, 6, 2, 4, 7]
    ring = ServingEngine(model, qp, ServeConfig(cache_len=64,
                                                cache_dtype="float32", paged=False),
                         batch_slots=1)
    want = {i: ring.generate([p], max_new_tokens=b)[0]
            for i, (p, b) in enumerate(zip(prompts, budgets))}

    eng = ServingEngine(model, qp,
                        ServeConfig(cache_len=64,
                                    cache_dtype="float32", block_size=8,
                                    prefill_chunk=4, token_budget=8),
                        batch_slots=3)
    sched = eng.scheduler
    results: dict[int, list[int]] = {}
    rid_of = {}
    # two requests up front, the rest arrive online every other step — their
    # prompts must prefill INSIDE steps that also decode the running slots
    rid_of[sched.submit(prompts[0], budgets[0], salt=0)] = 0
    rid_of[sched.submit(prompts[1], budgets[1], salt=1)] = 1
    nxt, steps = 2, 0
    while sched.step(results) or nxt < len(prompts):
        steps += 1
        if nxt < len(prompts) and steps % 2 == 0:
            rid_of[sched.submit(prompts[nxt], budgets[nxt], salt=nxt)] = nxt
            nxt += 1
    assert sched.stats["mixed_steps"] > 0, "no mixed prefill+decode step exercised"
    assert {rid_of[r]: v for r, v in results.items()} == want


def test_packed_budget_decode_never_starved(small_lm):
    """Token-budget accounting: while a long prompt admits and prefills over
    several packed steps, every already-decoding request still generates
    exactly one token per step (decode rows are reserved before prefill)."""
    cfg, model, params, qp = small_lm
    eng = ServingEngine(model, qp,
                        ServeConfig(cache_len=64,
                                    cache_dtype="float32", block_size=8,
                                    prefill_chunk=4, token_budget=6),
                        batch_slots=3)
    sched = eng.scheduler
    results: dict[int, list[int]] = {}
    ra = sched.submit([1, 2, 3], 32, salt=0)
    while not any(r.rid == ra and r.decoding for r in sched._running):
        sched.step(results)
    # long prompt: at budget 6 with one decode row reserved, 5 prefill
    # tokens/step -> at least 5 mixed steps before rb decodes
    rb = sched.submit([2] * 30, 4, salt=1)
    a = next(r for r in sched._running if r.rid == ra)
    while any(r.rid == rb and not r.decoding for r in sched._running) \
            or not any(r.rid == rb for r in sched._running):
        before = len(a.generated)
        sched.step(results)
        assert len(a.generated) == before + 1, "decode starved by admission"
    assert sched.stats["mixed_steps"] >= 5
    results.update(sched.run())
    assert len(results[ra]) == 32 and len(results[rb]) == 4


def test_packed_step_rejects_budget_below_slots(small_lm):
    cfg, model, params, qp = small_lm
    with pytest.raises(ValueError, match="token_budget"):
        ServingEngine(model, qp,
                      ServeConfig(cache_len=32,
                                  cache_dtype="float32", token_budget=2),
                      batch_slots=4)


def test_fallback_padding_not_attended(small_lm):
    """Fixed-slot fallback regression: left-pad tokens used to be written to
    the KV cache at real positions and attended — mixed-length batched
    generation must match unpadded per-prompt generation."""
    cfg, model, params, qp = small_lm
    eng = ServingEngine(model, qp,
                        ServeConfig(cache_len=32,
                                    cache_dtype="float32", paged=False),
                        batch_slots=4)
    prompts = [[1, 2, 3, 4, 5, 6, 7], [4, 5], [6], [7, 8, 9, 10]]
    batched = eng.generate(prompts, max_new_tokens=6)
    single = [eng.generate([p], max_new_tokens=6)[0] for p in prompts]
    assert batched == single


def test_scheduler_queue_overflow_and_slot_refill(small_lm):
    """More requests than slots: all are served through the queue (iterative
    admission, not recursive chunking) with per-request budgets."""
    cfg, model, params, qp = small_lm
    eng = ServingEngine(model, qp,
                        ServeConfig(cache_len=32,
                                    cache_dtype="float32", block_size=4),
                        batch_slots=2)
    prompts = [[i + 1, i + 2] for i in range(7)]
    budgets = [3, 1, 4, 2, 5, 1, 2]
    outs = eng.generate(prompts, max_new_tokens=budgets)
    assert [len(o) for o in outs] == budgets
    assert all(0 <= t < cfg.vocab_size for o in outs for t in o)
    assert eng.scheduler.stats["decode_steps"] > 0
    # pool fully reclaimed after drain
    assert eng.scheduler.allocator.n_free == eng.scheduler.pcfg.n_blocks


def test_scheduler_prefill_only_burst(small_lm):
    """Budget-1 requests finish AT prefill; the queue must keep draining
    (regression: this used to trip the pool-capacity error)."""
    cfg, model, params, qp = small_lm
    eng = ServingEngine(model, qp,
                        ServeConfig(cache_len=16,
                                    cache_dtype="float32", block_size=4),
                        batch_slots=2)
    outs = eng.generate([[i + 1] for i in range(5)], max_new_tokens=1)
    assert [len(o) for o in outs] == [1] * 5


def test_scheduler_preemption_is_deterministic(small_lm):
    """A pool too small for all slots forces preemption-by-eviction; the
    recomputed K-Means KV is bit-identical so outputs don't change."""
    cfg, model, params, qp = small_lm
    prompts = [[1, 2, 3, 4, 5, 6, 7], [4, 5], [6, 9, 1], [7, 8, 9, 10]]
    mk = lambda n_blocks: ServingEngine(
        model, qp,
        ServeConfig(cache_len=32, cache_dtype="float32",
                    block_size=4, prefill_chunk=4, n_blocks=n_blocks),
        batch_slots=3,
    )
    big, small = mk(0), mk(7)
    a = big.generate(prompts, max_new_tokens=8)
    b = small.generate(prompts, max_new_tokens=8)
    assert small.scheduler.stats["preemptions"] > 0
    assert big.scheduler.stats["preemptions"] == 0
    assert a == b


def test_scheduler_rejects_oversized_request(small_lm):
    cfg, model, params, qp = small_lm
    eng = ServingEngine(model, qp,
                        ServeConfig(cache_len=16,
                                    cache_dtype="float32", block_size=4),
                        batch_slots=2)
    with pytest.raises(ValueError, match="exceeds"):
        eng.generate([[1] * 12], max_new_tokens=8)


def test_engine_eos_padding_both_paths(small_lm):
    """eos_id handling: outputs are exactly max_new_tokens, eos-padded."""
    cfg, model, params, qp = small_lm
    for paged in (True, False):
        eng = ServingEngine(model, qp,
                            ServeConfig(cache_len=32,
                                        cache_dtype="float32", paged=paged),
                            batch_slots=2)
        outs = eng.generate([[1, 2, 3], [5, 6]], max_new_tokens=6, eos_id=0)
        assert all(len(o) == 6 for o in outs)
        for o in outs:
            if 0 in o:
                assert all(t == 0 for t in o[o.index(0):])  # eos is absorbing


def test_temperature_sampling_seed_reproducible(small_lm):
    """Same seed + same request set -> identical samples on BOTH paths
    (regression: paged keys used to depend on the engine-global rid)."""
    cfg, model, params, qp = small_lm
    for paged in (True, False):
        eng = ServingEngine(model, qp,
                            ServeConfig(cache_len=32,
                                        cache_dtype="float32", temperature=1.0,
                                        paged=paged),
                            batch_slots=2)
        a = eng.generate([[1, 2, 3], [7, 8]], max_new_tokens=6, seed=1)
        b = eng.generate([[1, 2, 3], [7, 8]], max_new_tokens=6, seed=1)
        c = eng.generate([[1, 2, 3], [7, 8]], max_new_tokens=6, seed=2)
        assert a == b and a != c, ("paged" if paged else "ring")


def test_serve_step_returns_current_logits(small_lm):
    """The stale-logits fix: make_serve_step's logits are THIS step's
    distribution (match a direct model.apply at the same position)."""
    cfg, model, params, _ = small_lm
    sc = ServeConfig(cache_len=16, cache_dtype="float32")
    step = make_serve_step(model, sc)
    caches = model.init_caches(2, sc.cache_len, jnp.float32)
    toks = jax.random.randint(jax.random.PRNGKey(7), (2, 1), 0, cfg.vocab_size)
    tok, new_caches, logits = step(params, caches, toks, jnp.int32(0))
    direct = model.apply(params, {"tokens": toks},
                         positions=jnp.arange(1, dtype=jnp.int32),
                         caches=model.init_caches(2, sc.cache_len, jnp.float32))
    np.testing.assert_allclose(logits, direct.logits[:, -1, : cfg.vocab_size],
                               rtol=1e-5, atol=1e-5)
    np.testing.assert_array_equal(tok, jnp.argmax(logits, -1))


def test_block_allocator_zero_alloc_and_empty_prompt(small_lm):
    """alloc(0) must not hand out the whole free list (regression), and the
    scheduler rejects empty prompts (whose block need is 0)."""
    a = BlockAllocator(4)
    assert a.alloc(0) == [] and a.n_free == 4
    cfg, model, params, qp = small_lm
    eng = ServingEngine(model, qp,
                        ServeConfig(cache_len=16,
                                    cache_dtype="float32", block_size=4),
                        batch_slots=2)
    with pytest.raises(ValueError, match="empty prompt"):
        eng.generate([[]], max_new_tokens=4)


def test_block_allocator_invariants():
    a = BlockAllocator(6)
    got = a.alloc(4)
    assert len(got) == 4 and len(set(got)) == 4 and a.n_free == 2
    assert a.alloc(3) is None and a.n_free == 2  # all-or-nothing
    a.free(got[:2])
    assert a.n_free == 4 and a.occupancy == pytest.approx(2 / 6)
    more = a.alloc(4)
    assert a.n_free == 0 and a.alloc(1) is None
    assert sorted(got[2:] + more) == sorted(set(got[2:] + more))  # ids unique
    a.free(got[2:] + more)
    assert a.n_free == 6


# ---------------------------------------------------------------------------
# allocator safety: validation, refcounts, prefix LRU
# ---------------------------------------------------------------------------

def test_block_allocator_double_free_raises():
    """Regression (ISSUE 4): free used to silently accept duplicate or
    out-of-range ids, corrupting the free list so one block was later handed
    to two requests — now every bad id raises and the pool stays intact."""
    a = BlockAllocator(4)
    got = a.alloc(2)
    a.free(got)
    with pytest.raises(ValueError, match="double free"):
        a.free([got[0]])
    with pytest.raises(ValueError, match="out of range"):
        a.free([4])
    with pytest.raises(ValueError, match="out of range"):
        a.free([-1])
    [b] = a.alloc(1)
    with pytest.raises(ValueError, match="double free"):
        a.free([b, b])  # more frees than refs in ONE call: rejected whole
    assert a.refcount(b) == 1  # validation precedes mutation: b still held
    # the rejected frees corrupted nothing: exactly 4 distinct blocks exist
    rest = a.alloc(a.n_free)
    assert b not in rest
    assert sorted(set(rest)) == sorted(rest) and a.alloc(1) is None
    a.free([b] + rest)
    assert a.n_free == 4


def test_block_allocator_refcount_and_prefix_lru():
    a = BlockAllocator(3, prefix_cache=True)
    [b0] = a.alloc(1)
    h0 = chain_hash(prefix_seed(pool="t"), [1, 2])
    assert a.register(h0, b0) is True
    a.incref(b0)  # a second request aliases the block
    a.free([b0])
    assert a.refcount(b0) == 1  # still live: one holder left
    a.free([b0])  # last ref: parks in the LRU, still matchable
    assert a.refcount(b0) == 0 and a.lookup(h0) == b0
    assert a.n_free == 3 and a.n_cached == 1  # cached counts as allocatable
    with pytest.raises(ValueError, match="double free"):
        a.free([b0])  # cached is not held: a decref would go negative
    a.incref(b0)  # revive from the LRU
    assert a.n_cached == 0 and a.refcount(b0) == 1
    a.free([b0])
    # exhausting the pool evicts the cached block and drops its hash
    got = a.alloc(3)
    assert sorted(got) == [0, 1, 2]
    assert a.lookup(h0) is None and a.evictions == 1
    a.free(got)
    with pytest.raises(ValueError, match="non-live"):
        a.register(h0, 0)  # registering a freed block would publish garbage


def test_block_allocator_lru_evicts_oldest_first():
    a = BlockAllocator(2, prefix_cache=True)
    [x] = a.alloc(1)
    [y] = a.alloc(1)
    hx, hy = chain_hash(b"s", [1]), chain_hash(b"s", [2])
    a.register(hx, x)
    a.register(hy, y)
    a.free([x])  # parked first -> oldest
    a.free([y])
    [z] = a.alloc(1)  # free list empty: must evict x, keep y matchable
    assert z == x and a.lookup(hx) is None and a.lookup(hy) == y


def test_admission_reserves_first_decode_block_no_thrash(small_lm):
    """Regression (ISSUE 4): a prompt whose length is a multiple of
    block_size admitted into an exactly-full pool used to be thrashed by its
    own first ``_grow`` — admission now reserves blocks for context + 1."""
    cfg, model, params, qp = small_lm
    mk = lambda n_blocks: ServingEngine(
        model, qp,
        ServeConfig(cache_len=8, cache_dtype="float32", block_size=4,
                    n_blocks=n_blocks, prefix_cache=False),
        batch_slots=2,
    )
    prompts = [[1, 2, 3, 4], [5, 6, 7, 8]]  # each exactly one block
    want = mk(0).generate(prompts, max_new_tokens=4)
    small = mk(2)  # room for ONE admitted request (1 ctx block + 1 decode)
    got = small.generate(prompts, max_new_tokens=4)
    assert got == want
    assert small.scheduler.stats["preemptions"] == 0, (
        "admission under an exactly-full pool preempted its own admittee"
    )


def _block_rows(sched, bid):
    """One block's pool contents as {leaf: (block_size, ...) array} with the
    token-row axis leading (layers folded behind), for byte comparisons."""
    pools = sched.pools
    if isinstance(pools, dict):  # scanned: (L, n_blocks, bs, ...)
        return {k: np.moveaxis(np.asarray(v[:, bid]), 1, 0)
                for k, v in pools.items() if k.startswith("pages_")}
    return {f"{i}/{k}": np.asarray(layer[k][bid])
            for i, layer in enumerate(pools)
            for k in layer if k.startswith("pages_")}


def test_packed_padded_rows_leave_slot0_blocks_untouched(small_lm):
    """Regression guard (ISSUE 4): padded rows in a partially-filled packed
    step carry slot_ids=0 with pos=-1 — they must be masked out of the
    scatter, leaving slot 0's pool blocks byte-identical except the one row
    its own decode token legitimately wrote."""
    cfg, model, params, qp = small_lm
    eng = ServingEngine(model, qp,
                        ServeConfig(cache_len=32, cache_dtype="float32",
                                    block_size=4, token_budget=8,
                                    prefix_cache=False),
                        batch_slots=2)
    sched = eng.scheduler
    results: dict[int, list[int]] = {}
    ra = sched.submit([1, 2, 3, 4, 5, 6], 8, salt=0)
    sched.step(results)  # 6 prefill rows + 2 padded rows
    a = next(r for r in sched._running if r.rid == ra)
    assert a.slot == 0 and a.decoding
    before = {bid: _block_rows(sched, bid) for bid in a.blocks}
    sched.step(results)  # 1 decode row (pos 6) + 7 padded rows aimed at slot 0
    bs = sched.pcfg.block_size
    wrote_blk, wrote_row = 6 // bs, 6 % bs
    for j, bid in enumerate(a.blocks):
        after = _block_rows(sched, bid)
        for key, b4 in before[bid].items():
            for row in range(bs):
                if j == wrote_blk and row == wrote_row:
                    continue  # the decode token's own slot: expected to change
                np.testing.assert_array_equal(
                    b4[row], after[key][row],
                    err_msg=f"padded row corrupted block {bid} row {row} ({key})",
                )


# ---------------------------------------------------------------------------
# prefix sharing / copy-on-write
# ---------------------------------------------------------------------------

def _mk_prefix_engine(model, qp, pc, *, kv_quant=False, slots=2, cache_len=64,
                      n_blocks=0):
    return ServingEngine(
        model, qp,
        ServeConfig(cache_len=cache_len, cache_dtype="float32", block_size=4,
                    prefill_chunk=4, kv_quant=kv_quant, n_blocks=n_blocks,
                    prefix_cache=pc),
        batch_slots=slots,
    )


def test_prefix_sharing_token_identical_mixed_workload(small_lm):
    """Tentpole acceptance: greedy outputs with prefix sharing enabled are
    token-identical to the non-sharing scheduler on a mixed workload (shared
    system prompt + distinct tails + unrelated prompts), and the shared
    engine actually skips prefill for aliased full blocks."""
    cfg, model, params, qp = small_lm
    system = [3, 1, 4, 1, 5, 9, 2, 6]  # two full blocks at block_size=4
    prompts = [system + [40 + i, 50 + i] for i in range(4)] + \
              [[80 + i] for i in range(2)]
    budgets = [5, 3, 6, 4, 2, 5]
    want = _mk_prefix_engine(model, qp, False).generate(prompts, budgets)
    eng = _mk_prefix_engine(model, qp, True)
    got = eng.generate(prompts, budgets)
    assert got == want
    st = eng.scheduler.stats
    assert st["prefix_hits"] > 0 and st["prefix_hit_tokens"] > 0
    assert st["prefill_skipped"] > 0
    # the skipped tokens really were never computed
    base = _mk_prefix_engine(model, qp, False)
    base.generate(prompts, budgets)
    assert st["prefill_tokens"] == \
        base.scheduler.stats["prefill_tokens"] - st["prefill_skipped"]


def test_prefix_sharing_warm_cache_second_call(small_lm):
    """The prefix cache persists across generate() calls: a re-served
    workload hits on every shared prompt and stays token-identical."""
    cfg, model, params, qp = small_lm
    system = [3, 1, 4, 1, 5, 9, 2, 6]
    prompts = [system + [40 + i] for i in range(3)]
    eng = _mk_prefix_engine(model, qp, True)
    first = eng.generate(prompts, max_new_tokens=4)
    hits0 = eng.scheduler.stats["prefix_hits"]
    second = eng.generate(prompts, max_new_tokens=4)
    assert second == first
    assert eng.scheduler.stats["prefix_hits"] - hits0 == len(prompts)


def test_prefix_cow_on_shared_exact_multiple_prompt(small_lm):
    """A prompt that is an exact block multiple and fully cached aliases ALL
    its blocks; recomputing only the last token writes into a shared block,
    which must copy-on-write (not corrupt the donor) — outputs of both the
    donor and the follower match solo runs."""
    cfg, model, params, qp = small_lm
    p = [3, 1, 4, 1, 5, 9, 2, 6]  # exactly two blocks
    solo_long = _mk_prefix_engine(model, qp, False, cache_len=32).generate(
        [p], max_new_tokens=12)[0]
    solo_short = _mk_prefix_engine(model, qp, False, cache_len=32).generate(
        [p], max_new_tokens=4)[0]
    eng = _mk_prefix_engine(model, qp, True, cache_len=32)
    sched = eng.scheduler
    results: dict[int, list[int]] = {}
    lead = sched.submit(p, 12, salt=0)  # long-lived donor
    while not any(r.rid == lead and r.decoding for r in sched._running):
        sched.step(results)
    fol = sched.submit(p, 4, salt=1)  # same prompt while donor still holds it
    results.update(sched.run())
    assert results[lead] == solo_long and results[fol] == solo_short
    assert sched.stats["cow_copies"] >= 1
    assert sched.stats["prefill_skipped"] >= len(p) - 1


def test_prefix_sharing_int4_pool_token_identical(small_lm):
    """Shared K-Means int4 blocks: aliasing quantized pages is exact (the
    paper's memory win compounds — one physical int4 block, many requests)."""
    cfg, model, params, qp = small_lm
    system = [3, 1, 4, 1, 5, 9, 2, 6]
    prompts = [system + [40 + i] for i in range(3)]
    want = _mk_prefix_engine(model, qp, False, kv_quant=True,
                             cache_len=32).generate(prompts, max_new_tokens=4)
    eng = _mk_prefix_engine(model, qp, True, kv_quant=True, cache_len=32)
    assert eng.generate(prompts, max_new_tokens=4) == want
    assert eng.scheduler.stats["prefix_hit_tokens"] > 0


def test_prefix_cache_eviction_under_pressure(small_lm):
    """Cached refcount-0 prefix blocks are reclaimed (LRU) for new
    admissions instead of refusing them: many distinct prompts stream
    through a pool far smaller than their combined footprint."""
    cfg, model, params, qp = small_lm
    prompts = [[10 * i + j for j in range(1, 9)] for i in range(1, 5)]
    mk = lambda pc: _mk_prefix_engine(model, qp, pc, slots=1, cache_len=16,
                                      n_blocks=4)
    want = mk(False).generate(prompts, max_new_tokens=3)
    eng = mk(True)
    assert eng.generate(prompts, max_new_tokens=3) == want
    assert eng.scheduler.allocator.evictions > 0
    assert eng.stats["prefix_evictions"] > 0  # engine stats plumbing


def test_scheduler_random_traffic_preserves_allocator_invariants(small_lm):
    """Seeded random arrivals/budgets over a small pool (preemption, prefix
    aliasing, and COW all fire): after every step each block is held by
    exactly ``refcount`` many running requests, and allocatable + live
    always equals the pool size."""
    cfg, model, params, qp = small_lm
    eng = ServingEngine(model, qp,
                        ServeConfig(cache_len=16, cache_dtype="float32",
                                    block_size=4, n_blocks=10, token_budget=8,
                                    prefill_chunk=4, prefix_cache=True),
                        batch_slots=3)
    sched = eng.scheduler
    alloc = sched.allocator
    rng = np.random.RandomState(0)
    prefix = [7, 7, 7, 7]  # one shared full block
    results: dict[int, list[int]] = {}
    pending = 14
    while pending or sched._running or sched._queue:
        if pending and (rng.rand() < 0.5
                        or not (sched._running or sched._queue)):
            tail = [int(t) for t in rng.randint(1, 200, int(rng.randint(1, 6)))]
            prompt = (list(prefix) if rng.rand() < 0.6 else []) + tail
            sched.submit(prompt, int(rng.randint(1, 5)))
            pending -= 1
        if sched._running or sched._queue:
            sched.step(results)
        held = [b for r in sched._running for b in r.blocks]
        for b in range(sched.pcfg.n_blocks):
            assert alloc.refcount(b) == held.count(b), (
                f"block {b}: {alloc.refcount(b)} refs, {held.count(b)} holders"
            )
        assert alloc.n_free + len(set(held)) == sched.pcfg.n_blocks
    assert len(results) == 14
    assert sched.stats["prefix_hit_tokens"] > 0
    assert alloc.n_free == sched.pcfg.n_blocks


# ---------------------------------------------------------------------------
# Pallas paged-attention kernel vs jnp oracles
# ---------------------------------------------------------------------------

def _paged_fixture():
    b, kv, g, hd, bs, max_blk, n_blocks = 3, 2, 2, 8, 4, 5, 16
    q = jax.random.normal(jax.random.PRNGKey(0), (b, 1, kv, g, hd))
    kp = jax.random.normal(jax.random.PRNGKey(1), (n_blocks, bs, kv, hd))
    vp = jax.random.normal(jax.random.PRNGKey(2), (n_blocks, bs, kv, hd))
    bt = np.full((b, max_blk), -1, np.int32)
    ids = np.random.RandomState(0).permutation(n_blocks)
    ctx = np.array([7, 1, 18], np.int32)
    off = 0
    for i in range(b):
        need = -(-int(ctx[i]) // bs)
        bt[i, :need] = ids[off : off + need]
        off += need
    return q, kp, vp, jnp.array(bt), jnp.array(ctx)


def test_paged_attn_kernel_matches_ref():
    from repro.kernels.paged_attn import paged_attn_kernel_call
    from repro.kernels.ref import paged_attn_ref

    q, kp, vp, bt, ctx = _paged_fixture()
    q_pos = (ctx - 1)[:, None]
    ref = paged_attn_ref(q, kp, vp, bt, ctx, q_pos)
    ker = paged_attn_kernel_call(q, kp, vp, block_tables=bt, ctx_lens=ctx,
                                 q_pos=q_pos, interpret=True)
    np.testing.assert_allclose(ker, ref, rtol=1e-5, atol=1e-5)


def _segment_fixture(seg: int):
    """Ragged query segments: each request's segment covers its last
    min(seg, ctx) positions; shorter segments are padded with q_pos = -1."""
    _, kp, vp, bt, ctx = _paged_fixture()
    b, (kv, hd) = bt.shape[0], kp.shape[2:]
    g = 2
    q = jax.random.normal(jax.random.PRNGKey(7), (b, seg, kv, g, hd))
    q_pos = np.full((b, seg), -1, np.int32)
    for i in range(b):
        n = min(seg, int(ctx[i]))
        q_pos[i, :n] = np.arange(int(ctx[i]) - n, int(ctx[i]))
    return q, kp, vp, bt, ctx, jnp.array(q_pos)


def test_paged_attn_kernel_query_segments_match_ref():
    """Multi-token query segments (the packed/chunked-prefill shape): kernel
    matches the oracle on every valid row; padded rows (q_pos = -1) are
    ignored."""
    from repro.kernels.paged_attn import paged_attn_kernel_call
    from repro.kernels.ref import paged_attn_ref

    q, kp, vp, bt, ctx, q_pos = _segment_fixture(seg=4)
    ref = paged_attn_ref(q, kp, vp, bt, ctx, q_pos)
    ker = paged_attn_kernel_call(q, kp, vp, block_tables=bt, ctx_lens=ctx,
                                 q_pos=q_pos, interpret=True)
    valid = np.asarray(q_pos) >= 0
    np.testing.assert_allclose(np.asarray(ker)[valid], np.asarray(ref)[valid],
                               rtol=1e-5, atol=1e-5)
    assert bool(jnp.isfinite(ker).all())  # padded rows garbage but finite


def _quant_pages(kp, vp):
    from repro.core.codebook import assign_via_boundaries
    from repro.core.quantize import pack_int4
    from repro.models.model import _default_codebook

    book = _default_codebook(4)

    def quant(x):
        s = jnp.maximum(jnp.sqrt(jnp.mean(jnp.square(x), -1, keepdims=True)), 1e-12)
        return pack_int4(assign_via_boundaries((x / s).astype(jnp.float32), book)), s

    ki, ks = quant(kp)
    vi, vs = quant(vp)
    return ki, ks, vi, vs, book


def test_paged_attn_quant_kernel_matches_ref():
    from repro.kernels.paged_attn import paged_attn_kernel_call
    from repro.kernels.ref import paged_attn_quant_ref

    q, kp, vp, bt, ctx = _paged_fixture()
    ki, ks, vi, vs, book = _quant_pages(kp, vp)
    q_pos = (ctx - 1)[:, None]
    ref = paged_attn_quant_ref(q, ki, ks, vi, vs, book, bt, ctx, q_pos)
    ker = paged_attn_kernel_call(q, ki, ks, vi, vs, book, block_tables=bt,
                                 ctx_lens=ctx, q_pos=q_pos, interpret=True)
    np.testing.assert_allclose(ker, ref, rtol=1e-5, atol=1e-5)


def test_paged_attn_quant_kernel_query_segments_match_ref():
    from repro.kernels.paged_attn import paged_attn_kernel_call
    from repro.kernels.ref import paged_attn_quant_ref

    q, kp, vp, bt, ctx, q_pos = _segment_fixture(seg=5)
    ki, ks, vi, vs, book = _quant_pages(kp, vp)
    ref = paged_attn_quant_ref(q, ki, ks, vi, vs, book, bt, ctx, q_pos)
    ker = paged_attn_kernel_call(q, ki, ks, vi, vs, book, block_tables=bt,
                                 ctx_lens=ctx, q_pos=q_pos, interpret=True)
    valid = np.asarray(q_pos) >= 0
    np.testing.assert_allclose(np.asarray(ker)[valid], np.asarray(ref)[valid],
                               rtol=1e-5, atol=1e-5)


def test_paged_kernel_path_in_model_decode(small_lm, monkeypatch):
    """Kernel routing: prefill (query segment) + decode through the Pallas
    kernel produce the same logits as the jnp gather path."""
    cfg, model, params, _ = small_lm
    toks = jax.random.randint(jax.random.PRNGKey(11), (1, 6), 0, cfg.vocab_size)
    a = _paged_prefill_logits(model, params, toks, block_size=4)
    monkeypatch.setattr(L, "_USE_PAGED_KERNEL", True)
    b = _paged_prefill_logits(model, params, toks, block_size=4)
    np.testing.assert_allclose(a, b, rtol=1e-4, atol=1e-4)


def test_paged_kernel_default_routing(monkeypatch):
    """REPRO_PAGED_KERNEL is opt-OUT on TPU, opt-in elsewhere."""
    on_tpu = jax.default_backend() == "tpu"
    monkeypatch.delenv("REPRO_PAGED_KERNEL", raising=False)
    assert L._paged_kernel_default() == on_tpu
    monkeypatch.setenv("REPRO_PAGED_KERNEL", "0")
    assert L._paged_kernel_default() is False
    monkeypatch.setenv("REPRO_PAGED_KERNEL", "off")
    assert L._paged_kernel_default() is False
    monkeypatch.setenv("REPRO_PAGED_KERNEL", "1")
    assert L._paged_kernel_default() is True
    monkeypatch.setenv("REPRO_PAGED_KERNEL", "auto")
    assert L._paged_kernel_default() == on_tpu


def test_packed_scheduler_through_kernel(small_lm, monkeypatch):
    """The full packed token-budget step routed through the Pallas kernel
    (interpret mode) generates the same greedy tokens as the jnp path."""
    cfg, model, params, qp = small_lm
    mk = lambda: ServingEngine(
        model, qp,
        ServeConfig(cache_len=32, cache_dtype="float32",
                    block_size=4, prefill_chunk=2, token_budget=4),
        batch_slots=2,
    )
    prompts = [[1, 2, 3, 4, 5], [6, 9]]
    want = mk().generate(prompts, max_new_tokens=3)
    monkeypatch.setattr(L, "_USE_PAGED_KERNEL", True)
    got = mk().generate(prompts, max_new_tokens=3)
    assert got == want


def test_paged_ref_respects_block_table_permutation():
    """The same logical sequence stored under two different physical block
    layouts must attend identically (storage location is invisible)."""
    from repro.kernels.ref import paged_attn_ref

    q, kp, vp, bt, ctx = _paged_fixture()
    n_blocks = kp.shape[0]
    perm = jnp.array(np.random.RandomState(3).permutation(n_blocks))
    inv = jnp.argsort(perm)
    kp2, vp2 = kp[perm], vp[perm]
    bt2 = jnp.where(bt >= 0, inv[jnp.clip(bt, 0, n_blocks - 1)], -1)
    a = paged_attn_ref(q, kp, vp, bt, ctx, (ctx - 1)[:, None])
    b = paged_attn_ref(q, kp2, vp2, bt2, ctx, (ctx - 1)[:, None])
    np.testing.assert_allclose(a, b, rtol=1e-6, atol=1e-6)
