"""End-to-end system behaviour tests: training convergence, PTQ quality
ordering (the paper's Table-III claim in miniature), trainer resume, and the
dry-run spec machinery."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs.base import SHAPES, get_config, get_smoke_config
from repro.core.qlinear import QLinearConfig
from repro.data.pipeline import ByteCorpus, DataConfig, TokenPipeline
from repro.models.model import build
from repro.optim.adamw import AdamWConfig
from repro.train.trainer import TrainConfig, Trainer, loss_fn, make_eval_step


@pytest.fixture(scope="module")
def trained_small_lm():
    """Train a small byte-LM for a few hundred steps (real text = repo source)."""
    cfg = get_smoke_config("oasis_7b")
    model = build(cfg)
    corpus = ByteCorpus()
    pipe = TokenPipeline(corpus.tokens, DataConfig(seq_len=48, global_batch=16, seed=0))
    tc = TrainConfig(optimizer=AdamWConfig(lr=2e-3), microbatches=1,
                     warmup_steps=20, total_steps=300)
    trainer = Trainer(model, tc, pipe)
    trainer.run(300, log_every=10_000, log=lambda *_: None)
    return cfg, model, trainer.state["params"], pipe, tc


def test_training_reduces_loss(trained_small_lm):
    cfg, model, params, pipe, tc = trained_small_lm
    eval_step = jax.jit(make_eval_step(model, tc))
    batch = {k: jnp.asarray(v) for k, v in pipe.next_batch().items()}
    loss_trained = float(eval_step(params, batch)["ce"])
    fresh = model.init(jax.random.PRNGKey(99))
    loss_fresh = float(eval_step(fresh, batch)["ce"])
    assert loss_trained < loss_fresh - 1.0, (loss_trained, loss_fresh)


def test_ptq_quality_ordering(trained_small_lm):
    """Paper Table III in miniature: OASIS (K-Means + dynamic outliers)
    degrades a TRAINED model less than cruder quantization settings."""
    cfg, model, params, pipe, tc = trained_small_lm
    eval_step = jax.jit(make_eval_step(model, tc))
    batch = {k: jnp.asarray(v) for k, v in pipe.next_batch().items()}
    ce_fp = float(eval_step(params, batch)["ce"])

    from repro.core.quantspec import QuantSpec
    from repro.models.model import quantize_model

    def ce_with(qcfg):
        # apply-time behaviour travels inside the QLinearParams (p.cfg)
        qp = quantize_model(model, params, QuantSpec(base=qcfg))
        step = jax.jit(make_eval_step(model, tc))
        return float(step(qp, batch)["ce"])

    ce_oasis = ce_with(QLinearConfig(detection="dynamic", outlier_frac=0.01))
    ce_no_outlier = ce_with(QLinearConfig(detection="none"))
    ce_a3 = ce_with(QLinearConfig(a_bits=3, detection="dynamic", outlier_frac=0.01))

    assert ce_oasis >= ce_fp - 0.05  # quantization cannot beat fp (tolerance)
    assert ce_oasis <= ce_no_outlier + 1e-5  # outlier compensation helps
    assert ce_oasis <= ce_a3 + 0.05  # 4-bit activations >= 3-bit
    assert ce_oasis - ce_fp < 1.0  # bounded degradation on a trained model


def test_trainer_resume_bitexact(tmp_path, trained_small_lm):
    """kill -9 resume: same final state as an uninterrupted run."""
    cfg, model, *_ = trained_small_lm
    corpus = ByteCorpus()
    mk_pipe = lambda: TokenPipeline(corpus.tokens, DataConfig(seq_len=16, global_batch=4, seed=5))
    tc = TrainConfig(optimizer=AdamWConfig(lr=1e-3), checkpoint_every=5, total_steps=100)

    t1 = Trainer(model, tc, mk_pipe(), ckpt_dir=str(tmp_path / "a"), seed=1)
    t1.run(10, log_every=10_000, log=lambda *_: None)
    w_straight = t1.state["params"]

    t2 = Trainer(model, tc, mk_pipe(), ckpt_dir=str(tmp_path / "b"), seed=1)
    t2.run(5, log_every=10_000, log=lambda *_: None)
    # "crash": new trainer object resumes from disk
    t3 = Trainer(model, tc, mk_pipe(), ckpt_dir=str(tmp_path / "b"), seed=1)
    assert t3.step == 5
    t3.run(5, log_every=10_000, log=lambda *_: None)
    jax.tree.map(
        lambda a, b: np.testing.assert_allclose(a, b, rtol=1e-6, atol=1e-6),
        w_straight, t3.state["params"],
    )


def test_loss_fn_adds_moe_aux():
    cfg = get_smoke_config("granite_moe_3b_a800m")
    model = build(cfg)
    params = model.init(jax.random.PRNGKey(0))
    tc = TrainConfig(aux_weight=0.5, z_loss=0.0)
    batch = {"tokens": jax.random.randint(jax.random.PRNGKey(1), (2, 17), 0, cfg.vocab_size)}
    loss, metrics = loss_fn(model, params, batch, tc)
    assert float(loss) > float(metrics["ce"])  # aux added


# ---------------------------------------------------------------------------
# dry-run spec machinery (single-device checks; the 512-dev run is a launcher)
# ---------------------------------------------------------------------------

def test_cell_setup_shapes_for_each_kind():
    from repro.launch.specs import input_specs

    cfg = get_config("llama3_2_1b")
    for shape_name, cols in [("train_4k", 4097), ("prefill_32k", 32768), ("decode_32k", 1)]:
        specs = input_specs(cfg, SHAPES[shape_name])
        assert specs["tokens"].dtype == jnp.int32
        assert specs["tokens"].shape == (SHAPES[shape_name].global_batch, cols)


def test_skip_matrix_matches_design():
    from repro.launch.specs import skip_reason

    assert skip_reason(get_config("llama3_2_1b"), SHAPES["long_500k"]) is not None
    assert skip_reason(get_config("falcon_mamba_7b"), SHAPES["long_500k"]) is None
    assert skip_reason(get_config("h2o_danube_1_8b"), SHAPES["long_500k"]) is None
    assert skip_reason(get_config("recurrentgemma_2b"), SHAPES["long_500k"]) is None
    for s in ("train_4k", "prefill_32k", "decode_32k"):
        assert skip_reason(get_config("musicgen_large"), SHAPES[s]) is None


def test_param_spec_divisibility_fallbacks():
    """24-head / 10-head archs must fall back to replicated attention dims on
    the fixed 16-way model axis rather than producing invalid specs."""
    from jax.sharding import PartitionSpec as P

    from repro.distributed.param_sharding import build_param_specs

    for arch in ("granite_moe_3b_a800m", "recurrentgemma_2b"):
        cfg = get_smoke_config(arch)
        model = build(cfg)
        params = jax.eval_shape(lambda m=model: m.init(jax.random.PRNGKey(0)))
        specs = build_param_specs(params, model_size=16)
        leaves_p = jax.tree.leaves(params)
        leaves_s = jax.tree.leaves(specs, is_leaf=lambda s: isinstance(s, P))
        for leaf, spec in zip(leaves_p, leaves_s):
            for dim, axis in zip(leaf.shape, tuple(spec)):
                if axis == "model":
                    assert dim % 16 == 0, f"invalid spec {spec} for shape {leaf.shape}"


def test_roofline_hlo_analyzer_on_known_graph():
    """Analyzer ground truth: scanned matmul with known trip count and flops."""
    from repro.launch.roofline import analyze_hlo

    def f(w, x):
        def body(c, wi):
            return jnp.tanh(c @ wi), None
        y, _ = jax.lax.scan(body, x, w)
        return y.sum()

    w = jax.ShapeDtypeStruct((6, 64, 64), jnp.float32)
    x = jax.ShapeDtypeStruct((8, 64), jnp.float32)
    hlo = jax.jit(f).lower(w, x).compile().as_text()
    a = analyze_hlo(hlo)
    expect = 2 * 8 * 64 * 64 * 6  # 2MNK x 6 scan iterations
    assert a["dot_flops"] == pytest.approx(expect, rel=0.05), a["dot_flops"]
    assert 6 in a["while_trip_counts"].values()


def test_serve_step_last_only_logits():
    """Prefill computes logits only for the final position (32k-prefill memory)."""
    cfg = get_smoke_config("llama3_2_1b")
    model = build(cfg)
    params = model.init(jax.random.PRNGKey(0))
    batch = {"tokens": jax.random.randint(jax.random.PRNGKey(2), (2, 12), 0, cfg.vocab_size)}
    full = model.apply(params, batch)
    last = model.apply(params, batch, last_only=True)
    assert last.logits.shape == (2, 1, cfg.vocab_padded)
    np.testing.assert_allclose(last.logits[:, 0], full.logits[:, -1], rtol=1e-5, atol=1e-5)
