"""Substrate tests: data pipeline, optimizer, checkpointing, fault tolerance,
gradient compression, serving engine."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

hypothesis = pytest.importorskip("hypothesis")  # property tests need it
from hypothesis import given, settings
from hypothesis import strategies as st

from repro.checkpoint.checkpointer import CheckpointManager, load_checkpoint, save_checkpoint
from repro.configs.base import get_smoke_config
from repro.core.qlinear import QLinearConfig
from repro.data.pipeline import ByteCorpus, DataConfig, TokenPipeline, synthetic_corpus
from repro.distributed.collectives import (
    compress_decompress_tree,
    dequantize_blockwise,
    init_error_state,
    quantize_blockwise,
)
from repro.distributed.fault_tolerance import (
    Heartbeat,
    StepMonitor,
    elastic_plan,
    find_resumable_step,
)
from repro.core.quantspec import QuantSpec
from repro.models.model import build, quantize_model
from repro.optim.adamw import AdamWConfig, adamw_init, adamw_update, cosine_schedule
from repro.serving.engine import ServeConfig, ServingEngine


# ---------------------------------------------------------------------------
# data pipeline
# ---------------------------------------------------------------------------

def test_pipeline_deterministic_and_resumable():
    toks = synthetic_corpus(vocab=97, length=10_000, seed=3)
    cfg = DataConfig(seq_len=16, global_batch=4, seed=7)
    p1 = TokenPipeline(toks, cfg)
    batches = [p1.next_batch()["tokens"] for _ in range(5)]
    # restore mid-stream
    p2 = TokenPipeline(toks, cfg)
    p2.restore({"step": 3, "seed": 7})
    np.testing.assert_array_equal(p2.next_batch()["tokens"], batches[3])
    # full replay identical
    p3 = TokenPipeline(toks, cfg)
    np.testing.assert_array_equal(p3.next_batch()["tokens"], batches[0])


def test_pipeline_host_sharding_partitions_batch():
    toks = synthetic_corpus(vocab=50, length=5_000, seed=1)
    full = TokenPipeline(toks, DataConfig(seq_len=8, global_batch=8, seed=2)).next_batch()
    part0 = TokenPipeline(
        toks, DataConfig(seq_len=8, global_batch=8, seed=2, process_index=0, process_count=2)
    ).next_batch()
    part1 = TokenPipeline(
        toks, DataConfig(seq_len=8, global_batch=8, seed=2, process_index=1, process_count=2)
    ).next_batch()
    np.testing.assert_array_equal(
        np.concatenate([part0["tokens"], part1["tokens"]]), full["tokens"]
    )


def test_byte_corpus_nonempty():
    c = ByteCorpus()
    assert c.tokens.size > 1 << 16
    assert c.tokens.max() < 256


# ---------------------------------------------------------------------------
# optimizer
# ---------------------------------------------------------------------------

def test_adamw_converges_on_quadratic():
    params = {"w": jnp.array([5.0, -3.0])}
    opt = adamw_init(params)
    cfg = AdamWConfig(lr=0.3, weight_decay=0.0)
    for _ in range(150):
        grads = {"w": 2 * params["w"]}
        params, opt, _ = adamw_update(grads, opt, params, cfg, jnp.float32(0.3))
    assert float(jnp.abs(params["w"]).max()) < 0.05


def test_adamw_clipping_and_decay():
    params = {"w": jnp.ones((4, 4))}
    opt = adamw_init(params)
    cfg = AdamWConfig(lr=0.1, clip_norm=1.0, weight_decay=0.1)
    _, _, m = adamw_update({"w": 100 * jnp.ones((4, 4))}, opt, params, cfg, jnp.float32(0.1))
    assert float(m["grad_norm"]) == pytest.approx(400.0)


def test_cosine_schedule_shape():
    lr = cosine_schedule(1.0, warmup=10, total=110)
    assert float(lr(0)) == 0.0
    assert float(lr(10)) == pytest.approx(1.0)
    assert float(lr(110)) == pytest.approx(0.1, abs=1e-3)
    assert float(lr(5)) == pytest.approx(0.5)


# ---------------------------------------------------------------------------
# checkpointing
# ---------------------------------------------------------------------------

def _state_tree():
    return {
        "params": {"w": jnp.arange(12.0).reshape(3, 4), "b": jnp.zeros(4)},
        "step": jnp.int32(7),
    }


def test_checkpoint_roundtrip(tmp_path):
    t = _state_tree()
    save_checkpoint(str(tmp_path), 7, t)
    back = load_checkpoint(str(tmp_path), 7, jax.eval_shape(lambda: t))
    jax.tree.map(lambda a, b: np.testing.assert_array_equal(a, b), t, back)


def test_checkpoint_corruption_detected(tmp_path):
    t = _state_tree()
    d = save_checkpoint(str(tmp_path), 1, t)
    shard = next(d.glob("shard_*.msgpack.zst"))
    raw = bytearray(shard.read_bytes())
    raw[len(raw) // 2] ^= 0xFF
    shard.write_bytes(bytes(raw))
    with pytest.raises(Exception):
        load_checkpoint(str(tmp_path), 1, jax.eval_shape(lambda: t))


def test_manager_retention_and_latest(tmp_path):
    mgr = CheckpointManager(str(tmp_path), keep=2)
    for s in (1, 2, 3, 4):
        mgr.save(_state_tree(), step=s)
    assert mgr.steps() == [3, 4]
    got = mgr.restore_latest(jax.eval_shape(_state_tree))
    assert int(got["step"]) == 7


def test_uncommitted_checkpoint_ignored(tmp_path):
    mgr = CheckpointManager(str(tmp_path), keep=5)
    mgr.save(_state_tree(), step=1)
    # simulate crash mid-write of step 2: directory without COMMIT
    (tmp_path / "step_00000002").mkdir()
    assert mgr.steps() == [1]
    assert find_resumable_step(str(tmp_path)) == 1


def test_quantized_params_checkpoint_roundtrip(tmp_path):
    """QuantizedWeight dataclass pytrees survive save/restore."""
    from repro.core.quantize import quantize_weight

    qw = quantize_weight(jax.random.normal(jax.random.PRNGKey(0), (32, 16)), 4)
    save_checkpoint(str(tmp_path), 0, {"qw": qw})
    back = load_checkpoint(str(tmp_path), 0, jax.eval_shape(lambda: {"qw": qw}))
    np.testing.assert_array_equal(back["qw"].packed, qw.packed)
    np.testing.assert_array_equal(back["qw"].codebook, qw.codebook)


# ---------------------------------------------------------------------------
# fault tolerance
# ---------------------------------------------------------------------------

def test_step_monitor_straggler_detection():
    mon = StepMonitor(straggler_factor=2.0)
    for _ in range(20):
        mon.record(0.1)
    assert not mon.is_straggler(0.15)
    assert mon.is_straggler(0.5)
    assert mon.summary()["median_s"] == pytest.approx(0.1)


def test_heartbeat_liveness(tmp_path):
    hb = Heartbeat(str(tmp_path), host_id=3)
    hb.beat(step=10)
    assert Heartbeat.live_hosts(str(tmp_path)) == [3]
    assert Heartbeat.live_hosts(str(tmp_path), stale_after_s=0.0) == []


def test_elastic_plan_preserves_tp():
    plan = elastic_plan(
        surviving_chips=384, model_parallel=16, old_global_batch=256, old_chips=512
    )
    assert plan.mesh_shape[-1] == 16
    total = 1
    for d in plan.mesh_shape:
        total *= d
    assert total <= 384 and total % 16 == 0
    assert plan.global_batch == 192  # proportional to surviving chips


def test_elastic_plan_rejects_sub_tp():
    with pytest.raises(ValueError):
        elastic_plan(surviving_chips=8, model_parallel=16, old_global_batch=256, old_chips=512)


# ---------------------------------------------------------------------------
# gradient compression
# ---------------------------------------------------------------------------

@settings(max_examples=20, deadline=None)
@given(seed=st.integers(0, 2**31 - 1), n=st.integers(1, 1000))
def test_blockwise_quant_error_bound(seed, n):
    x = jax.random.normal(jax.random.PRNGKey(seed), (n,)) * 10
    q, s = quantize_blockwise(x)
    back = dequantize_blockwise(q, s, x.shape)
    blocks = np.asarray(jnp.pad(jnp.abs(x), (0, (-n) % 256)).reshape(-1, 256))
    tol = blocks.max(axis=1, keepdims=True) / 127.0
    err = np.abs(np.asarray(back) - np.asarray(x))
    tol_flat = np.repeat(tol, 256, axis=1).reshape(-1)[:n]
    assert np.all(err <= tol_flat * 0.5001 + 1e-7)


def test_error_feedback_is_unbiased_over_time():
    """Sum of compressed grads + final error == sum of true grads (telescoping)."""
    key = jax.random.PRNGKey(0)
    grads = [{"w": jax.random.normal(jax.random.fold_in(key, i), (300,))} for i in range(20)]
    err = init_error_state(grads[0])
    total_sent = jnp.zeros(300)
    for g in grads:
        sent, err = compress_decompress_tree(g, err)
        total_sent = total_sent + sent["w"]
    total_true = sum(g["w"] for g in grads)
    residual = float(jnp.max(jnp.abs(total_true - (total_sent + err["w"]))))
    assert residual < 1e-4


def test_compressed_psum_exact_protocol():
    """shard_map int8 psum: shared-scale protocol reconstructs the sum within
    n_ranks * scale/2 per element."""
    from jax.experimental.shard_map import shard_map
    from jax.sharding import PartitionSpec as P

    n_dev = jax.device_count()
    if n_dev < 2:
        pytest.skip("needs >1 device (the dry-run uses 512 host devices)")
    mesh = jax.make_mesh((n_dev,), ("d",))
    x = jax.random.normal(jax.random.PRNGKey(1), (n_dev, 512))

    from repro.distributed.collectives import compressed_psum

    f = shard_map(
        lambda a: compressed_psum(a[0], "d")[None],
        mesh=mesh, in_specs=P("d", None), out_specs=P("d", None),
    )
    got = jax.jit(f)(x)[0]
    want = x.sum(0)
    scale = np.abs(np.asarray(x)).reshape(n_dev, -1, 256).max(axis=(0, 1)) / 127.0
    assert np.max(np.abs(np.asarray(got) - np.asarray(want))) <= n_dev * scale.max()


# ---------------------------------------------------------------------------
# serving engine
# ---------------------------------------------------------------------------

def test_serving_engine_batched_generation():
    cfg = get_smoke_config("oasis_7b")
    m = build(cfg)
    params = m.init(jax.random.PRNGKey(0))
    qp = quantize_model(m, params, QuantSpec(base=QLinearConfig(outlier_frac=0.01)))
    sc = ServeConfig(cache_len=64, cache_dtype="float32")
    eng = ServingEngine(m, qp, sc, batch_slots=4)
    prompts = [[1, 2, 3], [4, 5], [6], [7, 8, 9, 10], [11, 12]]  # > slots: chunks
    outs = eng.generate(prompts, max_new_tokens=6)
    assert len(outs) == 5 and all(len(o) == 6 for o in outs)
    assert all(0 <= t < cfg.vocab_size for o in outs for t in o)


def test_serving_greedy_deterministic():
    cfg = get_smoke_config("llama3_2_1b")
    m = build(cfg)
    params = m.init(jax.random.PRNGKey(0))
    sc = ServeConfig(cache_len=64, cache_dtype="float32")
    qp = quantize_model(m, params, QuantSpec(base=QLinearConfig(detection="none")))
    eng = ServingEngine(m, qp, sc, batch_slots=2)
    a = eng.generate([[1, 2, 3]], max_new_tokens=5)
    b = eng.generate([[1, 2, 3]], max_new_tokens=5)
    assert a == b
