"""Per-kernel validation: shape/dtype sweeps + property tests vs ref.py oracles."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

try:  # property tests need hypothesis; the parity sweeps below do not
    from hypothesis import given, settings
    from hypothesis import strategies as st

    HAVE_HYPOTHESIS = True
except ImportError:  # pragma: no cover - env-dependent
    HAVE_HYPOTHESIS = False

    def given(**kw):  # stub decorators so the defs still parse
        return lambda fn: pytest.mark.skip(reason="hypothesis not installed")(fn)

    def settings(**kw):
        return lambda fn: fn

    class st:  # noqa: N801 - stand-in for hypothesis.strategies
        @staticmethod
        def integers(*a, **kw):
            return None

        @staticmethod
        def data():
            return None

from repro.core.codebook import boundaries_from_centroids
from repro.core.outlier import detect_outliers_topk
from repro.core.quantize import (
    fit_activation_codebook,
    quantize_activation,
    quantize_weight,
)
from repro.kernels import ops, ref
from repro.kernels.bucketize import bucketize_kernel_call
from repro.kernels.lut_gemm import lut_gemm_kernel_call
from repro.kernels.topk_outlier import topk_outlier_kernel_call


def _books(seed, n_a=16, n_w=16):
    a = jnp.sort(jax.random.normal(jax.random.PRNGKey(seed), (n_a,)))
    w = jnp.sort(jax.random.normal(jax.random.PRNGKey(seed + 1), (n_w,)))
    return a, w


# ---------------------------------------------------------------------------
# lut_gemm kernel
# ---------------------------------------------------------------------------

@pytest.mark.parametrize(
    "m,k,n,bm,bn,bk",
    [
        (1, 128, 64, 8, 32, 128),     # decode-like M=1
        (24, 256, 48, 16, 16, 128),   # ragged M/N vs blocks (padding path)
        (128, 512, 128, 128, 128, 512),  # exactly one MXU-aligned block
        (33, 384, 130, 32, 64, 128),  # everything ragged
        (7, 128, 2, 8, 2, 64),        # tiny N
    ],
)
def test_lut_gemm_kernel_shapes(m, k, n, bm, bn, bk):
    key = jax.random.PRNGKey(m * 7 + n)
    a_idx = jax.random.randint(key, (m, k), 0, 16)
    w_packed = jax.random.randint(jax.random.PRNGKey(1), (k, n // 2), 0, 256).astype(jnp.uint8)
    a_book, w_book = _books(2)
    y = lut_gemm_kernel_call(a_idx, w_packed, a_book, w_book, block_m=bm, block_n=bn, block_k=bk)
    np.testing.assert_allclose(y, ref.lut_gemm_ref(a_idx, w_packed, a_book, w_book),
                               rtol=1e-5, atol=1e-4)


def test_lut_gemm_kernel_3bit_activations():
    """3-bit activation codebook (W4A3, the paper's OASIS-A3 config)."""
    a_book, w_book = _books(3, n_a=8)
    a_idx = jax.random.randint(jax.random.PRNGKey(0), (16, 128), 0, 8)
    w_packed = jax.random.randint(jax.random.PRNGKey(1), (128, 32), 0, 256).astype(jnp.uint8)
    y = lut_gemm_kernel_call(a_idx, w_packed, a_book, w_book, block_m=8, block_n=32, block_k=64)
    np.testing.assert_allclose(y, ref.lut_gemm_ref(a_idx, w_packed, a_book, w_book),
                               rtol=1e-5, atol=1e-4)


def test_lut_gemm_kernel_unaligned_k():
    """K not divisible by block_k is PADDED (used to raise): padding columns
    must contribute exactly zero, not book[0]*book[0] garbage."""
    a_book, w_book = _books(4)
    a_idx = jax.random.randint(jax.random.PRNGKey(0), (4, 100), 0, 16)
    w_packed = jax.random.randint(jax.random.PRNGKey(1), (100, 8), 0, 256).astype(jnp.uint8)
    y = lut_gemm_kernel_call(a_idx, w_packed, a_book, w_book, block_k=64)
    np.testing.assert_allclose(y, ref.lut_gemm_ref(a_idx, w_packed, a_book, w_book),
                               rtol=1e-5, atol=1e-4)


def test_lut_gemm_kernel_rejects_odd_block_n():
    """Nibble tier packs two columns per byte: odd block_n cannot tile it."""
    a_book, w_book = _books(4)
    a_idx = jnp.zeros((4, 128), jnp.int32)
    w_packed = jnp.zeros((128, 8), jnp.uint8)
    with pytest.raises(ValueError):
        lut_gemm_kernel_call(a_idx, w_packed, a_book, w_book, block_n=7)


def test_ops_lut_gemm_matches_core_and_counting():
    """Kernel path == factorized jnp == counting-form oracle, with scales."""
    from repro.core.lut_gemm import lut_gemm as lut_jnp
    from repro.core.lut_gemm import lut_gemm_counting

    w = jax.random.normal(jax.random.PRNGKey(11), (256, 64))
    x = jax.random.normal(jax.random.PRNGKey(12), (10, 256))
    qw = quantize_weight(w, 4)
    qa = quantize_activation(x, fit_activation_codebook(x, 4))
    y_kernel = ops.lut_gemm(qa, qw)
    np.testing.assert_allclose(y_kernel, lut_jnp(qa, qw), rtol=1e-5, atol=1e-5)
    np.testing.assert_allclose(y_kernel, lut_gemm_counting(qa, qw), rtol=1e-4, atol=1e-4)


@settings(max_examples=25, deadline=None)
@given(
    m=st.integers(1, 40),
    kb=st.integers(1, 4),
    n=st.integers(1, 40),
    seed=st.integers(0, 2**31 - 1),
)
def test_lut_gemm_kernel_property(m, kb, n, seed):
    k = kb * 64
    key = jax.random.PRNGKey(seed)
    a_idx = jax.random.randint(key, (m, k), 0, 16)
    w_packed = jax.random.randint(jax.random.fold_in(key, 1), (k, n), 0, 256).astype(jnp.uint8)
    a_book, w_book = _books(seed % 1000)
    y = lut_gemm_kernel_call(a_idx, w_packed, a_book, w_book, block_m=16, block_n=32, block_k=64)
    np.testing.assert_allclose(y, ref.lut_gemm_ref(a_idx, w_packed, a_book, w_book),
                               rtol=1e-4, atol=1e-4)


# ---------------------------------------------------------------------------
# byte-packed weight tier (W5-W8) + W3
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("w_nbits", [5, 6, 7, 8])
def test_lut_gemm_kernel_byte_tier(w_nbits):
    """One-hot-matmul 256-entry lookup == gather oracle, every byte tier."""
    n_w = 2 ** w_nbits
    a_book, w_book = _books(w_nbits, n_w=n_w)
    a_idx = jax.random.randint(jax.random.PRNGKey(0), (9, 256), 0, 16)
    w_idx = jax.random.randint(jax.random.PRNGKey(1), (256, 40), 0, n_w).astype(jnp.uint8)
    y = lut_gemm_kernel_call(a_idx, w_idx, a_book, w_book, byte_packed=True,
                             block_m=8, block_n=32, block_k=128)
    np.testing.assert_allclose(y, ref.lut_gemm_byte_ref(a_idx, w_idx, a_book, w_book),
                               rtol=1e-5, atol=1e-4)


@pytest.mark.parametrize("m,k,n", [(1, 64, 7), (33, 300, 130), (8, 512, 64)])
def test_lut_gemm_kernel_byte_padding(m, k, n):
    """Odd/unaligned M, K, N on the byte tier: padding must contribute zero."""
    a_book, w_book = _books(9, n_w=256)
    a_idx = jax.random.randint(jax.random.PRNGKey(m), (m, k), 0, 16)
    w_idx = jax.random.randint(jax.random.PRNGKey(n), (k, n), 0, 256).astype(jnp.uint8)
    y = lut_gemm_kernel_call(a_idx, w_idx, a_book, w_book, byte_packed=True)
    np.testing.assert_allclose(y, ref.lut_gemm_byte_ref(a_idx, w_idx, a_book, w_book),
                               rtol=2e-5, atol=1e-4)


@pytest.mark.parametrize("w_nbits", [3, 8])
def test_ops_lut_gemm_matches_counting_w3_w8(w_nbits):
    """ops.lut_gemm (nibble W3 / byte W8 dispatch) == counting-form oracle."""
    from repro.core.lut_gemm import lut_gemm_counting

    w = jax.random.normal(jax.random.PRNGKey(21), (192, 80))
    x = jax.random.normal(jax.random.PRNGKey(22), (6, 192))
    qw = quantize_weight(w, w_nbits)
    qa = quantize_activation(x, fit_activation_codebook(x, 4))
    np.testing.assert_allclose(ops.lut_gemm(qa, qw), lut_gemm_counting(qa, qw),
                               rtol=1e-4, atol=1e-4)


# ---------------------------------------------------------------------------
# fused quantize+GEMM kernel
# ---------------------------------------------------------------------------

def _fused_case(seed, m, k, n, w_nbits, dtype):
    key = jax.random.PRNGKey(seed)
    x = (jax.random.normal(key, (m, k)) * 2).astype(dtype)
    w = jax.random.normal(jax.random.fold_in(key, 1), (k, n))
    qw = quantize_weight(w, w_nbits)
    book = fit_activation_codebook(jax.random.normal(jax.random.fold_in(key, 2), (64, k)), 4)
    return x, qw, book


@pytest.mark.parametrize("m,k,n,w_nbits", [
    (1, 128, 64, 4),      # decode M=1, nibble
    (24, 300, 130, 4),    # everything ragged, nibble
    (8, 256, 48, 8),      # byte tier
    (33, 190, 66, 3),     # W3 nibble, odd K
])
def test_fused_kernel_matches_ref(m, k, n, w_nbits):
    from repro.kernels.lut_gemm import fused_lut_gemm_kernel_call

    x, qw, book = _fused_case(m * 31 + n, m, k, n, w_nbits, jnp.float32)
    s = jnp.max(jnp.abs(x), axis=-1, keepdims=True) / 3 + 1e-6
    b = boundaries_from_centroids(book)
    y = fused_lut_gemm_kernel_call(x, s, qw.packed, b, book, qw.codebook,
                                   byte_packed=w_nbits > 4, mul_form=False)
    want = ref.fused_lut_gemm_ref(x, s, qw.packed, b, book, qw.codebook,
                                  byte_packed=w_nbits > 4, mul_form=False)
    np.testing.assert_allclose(y, want, rtol=2e-5, atol=1e-4)


@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
def test_fused_ops_bit_identical_indices(dtype):
    """lut_gemm_fused == quantize_activation + lut_gemm for both dtype forms
    (f32 searchsorted form, bf16 sum-of-compares mul form) — the property
    that makes kernel routing token-identical in serving."""
    from repro.core.lut_gemm import lut_gemm as lut_jnp

    x, qw, book = _fused_case(5, 16, 256, 64, 4, dtype)
    y_fused = ops.lut_gemm_fused(x, book, qw)
    qa = quantize_activation(x, book)
    y_two = lut_jnp(qa, qw, out_dtype=jnp.float32, compute_dtype=jnp.float32)
    np.testing.assert_allclose(y_fused, y_two, rtol=2e-5, atol=1e-4)


def test_fused_leading_batch_dims():
    x, qw, book = _fused_case(6, 12, 128, 32, 4, jnp.float32)
    x3 = x.reshape(3, 4, 128)
    y3 = ops.lut_gemm_fused(x3, book, qw)
    assert y3.shape == (3, 4, 32)
    np.testing.assert_allclose(y3.reshape(12, 32), ops.lut_gemm_fused(x, book, qw),
                               rtol=1e-6, atol=1e-6)


# ---------------------------------------------------------------------------
# bucketize kernel
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("m,k,nbits", [(4, 64, 4), (37, 130, 4), (128, 512, 3), (1, 16, 4)])
def test_bucketize_kernel(m, k, nbits):
    x = jax.random.normal(jax.random.PRNGKey(m), (m, k)) * 2
    book = jnp.sort(jax.random.normal(jax.random.PRNGKey(5), (2**nbits,)))
    b = boundaries_from_centroids(book)
    got = bucketize_kernel_call(x, b, block_m=16, block_k=64)
    np.testing.assert_array_equal(got, ref.bucketize_ref(x, b))


@settings(max_examples=25, deadline=None)
@given(seed=st.integers(0, 2**31 - 1), m=st.integers(1, 64), k=st.integers(1, 256))
def test_bucketize_is_nearest_centroid(seed, m, k):
    """Property: boundary bucketize == argmin |x - c| (the K-Means assignment)."""
    x = jax.random.normal(jax.random.PRNGKey(seed), (m, k)) * 3
    book = jnp.sort(jax.random.normal(jax.random.PRNGKey(seed + 1), (16,)))
    got = bucketize_kernel_call(x, boundaries_from_centroids(book))
    nearest = jnp.argmin(jnp.abs(x[..., None] - book), axis=-1)
    np.testing.assert_array_equal(got, nearest)


# ---------------------------------------------------------------------------
# topk_outlier kernel (Orizuru)
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("m,n,k", [(1, 64, 5), (13, 64, 5), (32, 128, 9), (5, 16, 8), (8, 4096, 20)])
def test_topk_kernel_random(m, n, k):
    x = jax.random.normal(jax.random.PRNGKey(m + n), (m, n))
    hv, hi, lv, li = topk_outlier_kernel_call(x, k, block_m=4)
    rhv, rhi, rlv, rli = ref.topk_outlier_ref(x, k)
    np.testing.assert_array_equal(hv, rhv)
    np.testing.assert_array_equal(hi, rhi)
    np.testing.assert_array_equal(lv, rlv)
    np.testing.assert_array_equal(li, rli)


def test_topk_kernel_ties_deterministic():
    """Heavy ties: integer-valued activations (the paper's ~2%-of-tokens case)."""
    x = jax.random.randint(jax.random.PRNGKey(7), (13, 64), -5, 6).astype(jnp.float32)
    hv, hi, lv, li = topk_outlier_kernel_call(x, 6, block_m=4)
    rhv, rhi, rlv, rli = ref.topk_outlier_ref(x, 6)
    np.testing.assert_array_equal(hi, rhi)
    np.testing.assert_array_equal(li, rli)


def test_topk_kernel_exhausts_pairs():
    """k > N/2: some pairs fully popped (both leaves) — tree maintenance must
    fall back through B to -inf without corrupting order; k > N must raise."""
    x = jax.random.normal(jax.random.PRNGKey(3), (4, 16))
    hv, hi, lv, li = topk_outlier_kernel_call(x, 10, block_m=4)
    rhv, rhi, rlv, rli = ref.topk_outlier_ref(x, 10)
    np.testing.assert_array_equal(hi, rhi)
    np.testing.assert_array_equal(li, rli)
    with pytest.raises(ValueError):
        topk_outlier_kernel_call(x, 17, block_m=4)


def test_topk_kernel_full_n():
    x = jax.random.normal(jax.random.PRNGKey(3), (4, 16))
    hv, hi, lv, li = topk_outlier_kernel_call(x, 16, block_m=4)
    rhv, rhi, rlv, rli = ref.topk_outlier_ref(x, 16)
    np.testing.assert_array_equal(hi, rhi)
    np.testing.assert_array_equal(li, rli)


@settings(max_examples=20, deadline=None)
@given(seed=st.integers(0, 2**31 - 1), m=st.integers(1, 16), half_n=st.integers(1, 64),
       data=st.data())
def test_topk_kernel_property(seed, m, half_n, data):
    n = 2 * half_n
    k = data.draw(st.integers(1, n))
    x = jax.random.normal(jax.random.PRNGKey(seed), (m, n))
    hv, hi, lv, li = topk_outlier_kernel_call(x, k, block_m=8)
    rhv, rhi, rlv, rli = ref.topk_outlier_ref(x, k)
    np.testing.assert_array_equal(hi, rhi)
    np.testing.assert_array_equal(li, rli)


def test_ops_topk_matches_core():
    x = jax.random.normal(jax.random.PRNGKey(12), (6, 10, 64))
    o = ops.topk_outlier(x, 3)
    o2 = detect_outliers_topk(x, 3)
    np.testing.assert_array_equal(o.values, o2.values)
    np.testing.assert_array_equal(o.channels, o2.channels)
