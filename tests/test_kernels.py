"""Per-kernel validation: shape/dtype sweeps + property tests vs ref.py oracles."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

hypothesis = pytest.importorskip("hypothesis")  # property tests need it
from hypothesis import given, settings
from hypothesis import strategies as st

from repro.core.codebook import boundaries_from_centroids
from repro.core.outlier import detect_outliers_topk
from repro.core.quantize import (
    fit_activation_codebook,
    quantize_activation,
    quantize_weight,
)
from repro.kernels import ops, ref
from repro.kernels.bucketize import bucketize_kernel_call
from repro.kernels.lut_gemm import lut_gemm_kernel_call
from repro.kernels.topk_outlier import topk_outlier_kernel_call


def _books(seed, n_a=16, n_w=16):
    a = jnp.sort(jax.random.normal(jax.random.PRNGKey(seed), (n_a,)))
    w = jnp.sort(jax.random.normal(jax.random.PRNGKey(seed + 1), (n_w,)))
    return a, w


# ---------------------------------------------------------------------------
# lut_gemm kernel
# ---------------------------------------------------------------------------

@pytest.mark.parametrize(
    "m,k,n,bm,bn,bk",
    [
        (1, 128, 64, 8, 32, 128),     # decode-like M=1
        (24, 256, 48, 16, 16, 128),   # ragged M/N vs blocks (padding path)
        (128, 512, 128, 128, 128, 512),  # exactly one MXU-aligned block
        (33, 384, 130, 32, 64, 128),  # everything ragged
        (7, 128, 2, 8, 2, 64),        # tiny N
    ],
)
def test_lut_gemm_kernel_shapes(m, k, n, bm, bn, bk):
    key = jax.random.PRNGKey(m * 7 + n)
    a_idx = jax.random.randint(key, (m, k), 0, 16)
    w_packed = jax.random.randint(jax.random.PRNGKey(1), (k, n // 2), 0, 256).astype(jnp.uint8)
    a_book, w_book = _books(2)
    y = lut_gemm_kernel_call(a_idx, w_packed, a_book, w_book, block_m=bm, block_n=bn, block_k=bk)
    np.testing.assert_allclose(y, ref.lut_gemm_ref(a_idx, w_packed, a_book, w_book),
                               rtol=1e-5, atol=1e-4)


def test_lut_gemm_kernel_3bit_activations():
    """3-bit activation codebook (W4A3, the paper's OASIS-A3 config)."""
    a_book, w_book = _books(3, n_a=8)
    a_idx = jax.random.randint(jax.random.PRNGKey(0), (16, 128), 0, 8)
    w_packed = jax.random.randint(jax.random.PRNGKey(1), (128, 32), 0, 256).astype(jnp.uint8)
    y = lut_gemm_kernel_call(a_idx, w_packed, a_book, w_book, block_m=8, block_n=32, block_k=64)
    np.testing.assert_allclose(y, ref.lut_gemm_ref(a_idx, w_packed, a_book, w_book),
                               rtol=1e-5, atol=1e-4)


def test_lut_gemm_kernel_rejects_bad_k():
    a_book, w_book = _books(4)
    a_idx = jnp.zeros((4, 100), jnp.int32)
    w_packed = jnp.zeros((100, 8), jnp.uint8)
    with pytest.raises(ValueError):
        lut_gemm_kernel_call(a_idx, w_packed, a_book, w_book, block_k=64)


def test_ops_lut_gemm_matches_core_and_counting():
    """Kernel path == factorized jnp == counting-form oracle, with scales."""
    from repro.core.lut_gemm import lut_gemm as lut_jnp
    from repro.core.lut_gemm import lut_gemm_counting

    w = jax.random.normal(jax.random.PRNGKey(11), (256, 64))
    x = jax.random.normal(jax.random.PRNGKey(12), (10, 256))
    qw = quantize_weight(w, 4)
    qa = quantize_activation(x, fit_activation_codebook(x, 4))
    y_kernel = ops.lut_gemm(qa, qw)
    np.testing.assert_allclose(y_kernel, lut_jnp(qa, qw), rtol=1e-5, atol=1e-5)
    np.testing.assert_allclose(y_kernel, lut_gemm_counting(qa, qw), rtol=1e-4, atol=1e-4)


@settings(max_examples=25, deadline=None)
@given(
    m=st.integers(1, 40),
    kb=st.integers(1, 4),
    n=st.integers(1, 40),
    seed=st.integers(0, 2**31 - 1),
)
def test_lut_gemm_kernel_property(m, kb, n, seed):
    k = kb * 64
    key = jax.random.PRNGKey(seed)
    a_idx = jax.random.randint(key, (m, k), 0, 16)
    w_packed = jax.random.randint(jax.random.fold_in(key, 1), (k, n), 0, 256).astype(jnp.uint8)
    a_book, w_book = _books(seed % 1000)
    y = lut_gemm_kernel_call(a_idx, w_packed, a_book, w_book, block_m=16, block_n=32, block_k=64)
    np.testing.assert_allclose(y, ref.lut_gemm_ref(a_idx, w_packed, a_book, w_book),
                               rtol=1e-4, atol=1e-4)


# ---------------------------------------------------------------------------
# bucketize kernel
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("m,k,nbits", [(4, 64, 4), (37, 130, 4), (128, 512, 3), (1, 16, 4)])
def test_bucketize_kernel(m, k, nbits):
    x = jax.random.normal(jax.random.PRNGKey(m), (m, k)) * 2
    book = jnp.sort(jax.random.normal(jax.random.PRNGKey(5), (2**nbits,)))
    b = boundaries_from_centroids(book)
    got = bucketize_kernel_call(x, b, block_m=16, block_k=64)
    np.testing.assert_array_equal(got, ref.bucketize_ref(x, b))


@settings(max_examples=25, deadline=None)
@given(seed=st.integers(0, 2**31 - 1), m=st.integers(1, 64), k=st.integers(1, 256))
def test_bucketize_is_nearest_centroid(seed, m, k):
    """Property: boundary bucketize == argmin |x - c| (the K-Means assignment)."""
    x = jax.random.normal(jax.random.PRNGKey(seed), (m, k)) * 3
    book = jnp.sort(jax.random.normal(jax.random.PRNGKey(seed + 1), (16,)))
    got = bucketize_kernel_call(x, boundaries_from_centroids(book))
    nearest = jnp.argmin(jnp.abs(x[..., None] - book), axis=-1)
    np.testing.assert_array_equal(got, nearest)


# ---------------------------------------------------------------------------
# topk_outlier kernel (Orizuru)
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("m,n,k", [(1, 64, 5), (13, 64, 5), (32, 128, 9), (5, 16, 8), (8, 4096, 20)])
def test_topk_kernel_random(m, n, k):
    x = jax.random.normal(jax.random.PRNGKey(m + n), (m, n))
    hv, hi, lv, li = topk_outlier_kernel_call(x, k, block_m=4)
    rhv, rhi, rlv, rli = ref.topk_outlier_ref(x, k)
    np.testing.assert_array_equal(hv, rhv)
    np.testing.assert_array_equal(hi, rhi)
    np.testing.assert_array_equal(lv, rlv)
    np.testing.assert_array_equal(li, rli)


def test_topk_kernel_ties_deterministic():
    """Heavy ties: integer-valued activations (the paper's ~2%-of-tokens case)."""
    x = jax.random.randint(jax.random.PRNGKey(7), (13, 64), -5, 6).astype(jnp.float32)
    hv, hi, lv, li = topk_outlier_kernel_call(x, 6, block_m=4)
    rhv, rhi, rlv, rli = ref.topk_outlier_ref(x, 6)
    np.testing.assert_array_equal(hi, rhi)
    np.testing.assert_array_equal(li, rli)


def test_topk_kernel_exhausts_pairs():
    """k > N/2: some pairs fully popped (both leaves) — tree maintenance must
    fall back through B to -inf without corrupting order; k > N must raise."""
    x = jax.random.normal(jax.random.PRNGKey(3), (4, 16))
    hv, hi, lv, li = topk_outlier_kernel_call(x, 10, block_m=4)
    rhv, rhi, rlv, rli = ref.topk_outlier_ref(x, 10)
    np.testing.assert_array_equal(hi, rhi)
    np.testing.assert_array_equal(li, rli)
    with pytest.raises(ValueError):
        topk_outlier_kernel_call(x, 17, block_m=4)


def test_topk_kernel_full_n():
    x = jax.random.normal(jax.random.PRNGKey(3), (4, 16))
    hv, hi, lv, li = topk_outlier_kernel_call(x, 16, block_m=4)
    rhv, rhi, rlv, rli = ref.topk_outlier_ref(x, 16)
    np.testing.assert_array_equal(hi, rhi)
    np.testing.assert_array_equal(li, rli)


@settings(max_examples=20, deadline=None)
@given(seed=st.integers(0, 2**31 - 1), m=st.integers(1, 16), half_n=st.integers(1, 64),
       data=st.data())
def test_topk_kernel_property(seed, m, half_n, data):
    n = 2 * half_n
    k = data.draw(st.integers(1, n))
    x = jax.random.normal(jax.random.PRNGKey(seed), (m, n))
    hv, hi, lv, li = topk_outlier_kernel_call(x, k, block_m=8)
    rhv, rhi, rlv, rli = ref.topk_outlier_ref(x, k)
    np.testing.assert_array_equal(hi, rhi)
    np.testing.assert_array_equal(li, rli)


def test_ops_topk_matches_core():
    x = jax.random.normal(jax.random.PRNGKey(12), (6, 10, 64))
    o = ops.topk_outlier(x, 3)
    o2 = detect_outliers_topk(x, 3)
    np.testing.assert_array_equal(o.values, o2.values)
    np.testing.assert_array_equal(o.channels, o2.channels)
