"""Orizuru outlier engine: detect-route resolution + env override, dispatch
accounting, explicit fallbacks, odd-N padding, tie-breaking parity vs
``lax.top_k`` (duplicate-heavy / all-equal / property-tested), streaming
quantize+detect bit-identity, the A3 legality rule, and detect-route parity
through the full dual-branch QLinear up to greedy serving token identity."""

import dataclasses
import warnings

import jax
import jax.numpy as jnp
import numpy as np
import pytest

try:  # property tests need hypothesis; the parity sweeps below do not
    from hypothesis import given, settings
    from hypothesis import strategies as st

    HAVE_HYPOTHESIS = True
except ImportError:  # pragma: no cover - env-dependent
    HAVE_HYPOTHESIS = False

    def given(**kw):  # stub decorators so the defs still parse
        return lambda fn: pytest.mark.skip(reason="hypothesis not installed")(fn)

    def settings(**kw):
        return lambda fn: fn

    class st:  # noqa: N801 - stand-in for hypothesis.strategies
        @staticmethod
        def integers(*a, **kw):
            return None

        @staticmethod
        def data():
            return None

import repro.core.kernel_routing as kr
import repro.core.outlier as ol
from repro.core.qlinear import (
    QLinearConfig,
    qlinear_apply,
    quantize_linear,
    with_detect_route,
    with_kernel_route,
)
from repro.core.quantize import fit_activation_codebook, quantize_activation
from repro.core.quantspec import QuantSpec
from repro.kernels import ops as kops
from repro.kernels.ref import streaming_quantize_outlier_ref, topk_outlier_ref
from repro.kernels.topk_outlier import (
    streaming_quantize_outlier_kernel_call,
    topk_outlier_kernel_call,
)


def _layer(cfg: QLinearConfig, k=128, n=48, seed=0, bias=True):
    key = jax.random.PRNGKey(seed)
    w = jax.random.normal(key, (k, n))
    calib = jax.random.normal(jax.random.fold_in(key, 1), (64, k)) * 1.5
    b = jax.random.normal(jax.random.fold_in(key, 2), (n,)) if bias else None
    return quantize_linear(w, calib, cfg, bias=b)


# ---------------------------------------------------------------------------
# detect-route resolution + config plumbing
# ---------------------------------------------------------------------------

def test_detect_kernel_field_validated():
    with pytest.raises(ValueError, match="detect_kernel"):
        QLinearConfig(detect_kernel="cuda")


def test_resolve_detect_route_passthrough():
    assert kr.resolve_detect_route("pallas") == "pallas"
    assert kr.resolve_detect_route("jnp") == "jnp"
    with pytest.raises(ValueError):
        kr.resolve_detect_route("bogus")


def test_detect_auto_route_env_override(monkeypatch):
    monkeypatch.setattr(kr, "_DETECT_AUTO_DEFAULT", None)
    monkeypatch.setenv("REPRO_TOPK_KERNEL", "1")
    assert kr.resolve_detect_route("auto") == "pallas"
    monkeypatch.setattr(kr, "_DETECT_AUTO_DEFAULT", None)
    monkeypatch.setenv("REPRO_TOPK_KERNEL", "off")
    assert kr.resolve_detect_route("auto") == "jnp"
    monkeypatch.setattr(kr, "_DETECT_AUTO_DEFAULT", None)
    monkeypatch.setenv("REPRO_TOPK_KERNEL", "auto")
    want = "pallas" if jax.default_backend() == "tpu" else "jnp"
    assert kr.resolve_detect_route("auto") == want
    # the GEMM env var must NOT leak into the detection route
    monkeypatch.setattr(kr, "_DETECT_AUTO_DEFAULT", None)
    monkeypatch.setenv("REPRO_LUT_KERNEL", "1")
    monkeypatch.setenv("REPRO_TOPK_KERNEL", "off")
    assert kr.resolve_detect_route("auto") == "jnp"


def test_quantspec_detect_rule_and_json_roundtrip():
    spec = QuantSpec(base=QLinearConfig(),
                     rules=[("attn/*", {"detect_kernel": "pallas"})])
    assert spec.resolve("blocks/attn/wq").detect_kernel == "pallas"
    assert spec.resolve("blocks/mlp/wi").detect_kernel == "auto"
    assert QuantSpec.from_json_dict(spec.to_json_dict()) == spec
    # pre-Orizuru artifacts (no "detect_kernel" key) load with the auto default
    d = spec.to_json_dict()
    d["base"].pop("detect_kernel")
    assert QuantSpec.from_json_dict(d).base.detect_kernel == "auto"


def test_with_detect_route_flips_tree():
    p = _layer(QLinearConfig())
    tree = {"a": p, "b": [p, jnp.ones(3)]}
    out = with_detect_route(tree, "pallas")
    assert out["a"].cfg.detect_kernel == "pallas"
    assert out["b"][0].cfg.detect_kernel == "pallas"
    assert out["a"].cfg.kernel == "auto"  # GEMM route untouched
    assert p.cfg.detect_kernel == "auto"  # original untouched
    np.testing.assert_array_equal(out["b"][1], tree["b"][1])


# ---------------------------------------------------------------------------
# A3 tier legality
# ---------------------------------------------------------------------------

def test_a3_requires_detection():
    cfg = QLinearConfig(a_bits=3, detection="none")  # constructible...
    with pytest.raises(ValueError, match="A3"):
        cfg.validate()  # ...but not applicable
    with pytest.raises(ValueError, match="A3"):
        QuantSpec(base=cfg).resolve("blocks/mlp/wi")
    with pytest.raises(ValueError, match="A3"):
        _layer(cfg)
    x = jax.random.normal(jax.random.PRNGKey(0), (2, 128))
    with pytest.raises(ValueError, match="A3"):
        qlinear_apply(_layer(QLinearConfig()), x, cfg=cfg)


def test_a3_legal_with_detection_and_rule_unlock():
    for detection in ("dynamic", "static", "static_dense"):
        QLinearConfig(a_bits=3, detection=detection).validate()
    # a rule chain may pass THROUGH an illegal intermediate state as long as
    # the final per-layer config is legal
    spec = QuantSpec(base=QLinearConfig(detection="none"),
                     rules=[("mlp/*", {"a_bits": 3}),
                            ("mlp/*", {"detection": "dynamic"})])
    assert spec.resolve("blocks/mlp/wi").a_bits == 3
    with pytest.raises(ValueError, match="A3"):
        QuantSpec(base=QLinearConfig(detection="none"),
                  rules=[("mlp/*", {"a_bits": 3})]).resolve("blocks/mlp/wi")


def test_a3_uniform_grid_exempt():
    # the RTN/INT-WAQ A3 grid is the deliberate collapse baseline
    # (bench_ppl's rtn_w4a3 row) — not gated by the K-Means rule
    QLinearConfig(a_bits=3, method="uniform", detection="none").validate()


def test_bit_width_ranges_checked():
    with pytest.raises(ValueError, match="a_bits"):
        QLinearConfig(a_bits=2)
    with pytest.raises(ValueError, match="a_bits"):
        QLinearConfig(a_bits=9)
    with pytest.raises(ValueError, match="w_bits"):
        QLinearConfig(w_bits=1)
    with pytest.raises(ValueError, match="w_bits"):
        QLinearConfig(w_bits=9)


def test_a3_qlinear_end_to_end():
    """An A3 dual-branch layer runs and the outlier branch visibly repairs
    the 8-entry codebook's tail error."""
    cfg = QLinearConfig(a_bits=3, detection="dynamic", outlier_frac=0.02)
    p = _layer(cfg, k=192, n=64, seed=3)
    x = jax.random.normal(jax.random.PRNGKey(11), (5, 192)) * 2
    y = qlinear_apply(p, x)
    assert y.shape == (5, 64) and jnp.all(jnp.isfinite(y))
    y_none = qlinear_apply(p, x, cfg=dataclasses.replace(
        cfg, detection="static", outlier_frac=0.0))
    assert not jnp.array_equal(y, y_none)  # compensation actually fired


# ---------------------------------------------------------------------------
# kernel: odd-N padding + tie-breaking vs lax.top_k
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("m,n,k", [(4, 7, 2), (2, 9, 9), (3, 129, 5),
                                   (1, 3, 1), (5, 31, 4)])
def test_topk_kernel_odd_n_matches_oracle(m, n, k):
    """Odd N is padded in-kernel (-inf max side / +inf min side), not
    rejected; with k <= N the pads are never popped."""
    x = jax.random.normal(jax.random.PRNGKey(n * 7 + k), (m, n))
    got = topk_outlier_kernel_call(x, k)
    want = topk_outlier_ref(x, k)
    for g, w in zip(got, want):
        np.testing.assert_array_equal(g, w)


def test_topk_kernel_k_above_n_still_raises():
    x = jax.random.normal(jax.random.PRNGKey(0), (2, 7))
    with pytest.raises(ValueError):
        topk_outlier_kernel_call(x, 8)
    with pytest.raises(ValueError):
        topk_outlier_kernel_call(x, 0)


@pytest.mark.parametrize("n", [16, 17])
def test_topk_kernel_duplicate_heavy_ties(n):
    """lax.top_k breaks value ties lowest-index-first; the tournament's
    left-child rule must agree exactly, or greedy serving tokens diverge."""
    vals = jnp.array([3.0, -3.0, 0.0, 1.0])
    x = vals[jax.random.randint(jax.random.PRNGKey(5), (6, n), 0, 4)]
    got = topk_outlier_kernel_call(x, 3)
    want = topk_outlier_ref(x, 3)
    for g, w in zip(got, want):
        np.testing.assert_array_equal(g, w)


@pytest.mark.parametrize("n", [8, 13])
def test_topk_kernel_all_equal(n):
    x = jnp.full((3, n), 2.5)
    hi_v, hi_i, lo_v, lo_i = topk_outlier_kernel_call(x, 2)
    np.testing.assert_array_equal(hi_v, jnp.full((3, 2), 2.5))
    np.testing.assert_array_equal(lo_v, jnp.full((3, 2), 2.5))
    # all-equal: both sides must pick indices 0..k-1 (lowest-index-first)
    np.testing.assert_array_equal(hi_i, jnp.broadcast_to(jnp.arange(2), (3, 2)))
    np.testing.assert_array_equal(lo_i, jnp.broadcast_to(jnp.arange(2), (3, 2)))


@pytest.mark.skipif(not HAVE_HYPOTHESIS, reason="hypothesis not installed")
@settings(max_examples=30, deadline=None)
@given(n=st.integers(min_value=2, max_value=70), data=st.data())
def test_topk_kernel_property(n, data):
    """Any (N, k, dtype) — odd N included, values drawn from a small integer
    set to force heavy ties — matches the sort-based counting oracle."""
    k = data.draw(st.integers(min_value=1, max_value=n))
    dtype = (jnp.float32, jnp.bfloat16)[data.draw(st.integers(0, 1))]
    seed = data.draw(st.integers(min_value=0, max_value=2**16))
    vals = jnp.arange(-2, 3, dtype=jnp.float32)
    x = vals[jax.random.randint(jax.random.PRNGKey(seed), (2, n), 0, 5)]
    x = x.astype(dtype).astype(jnp.float32)  # kernel contract: f32 in
    got = topk_outlier_kernel_call(x, k, block_m=2)
    want = topk_outlier_ref(x, k)
    for g, w in zip(got, want):
        np.testing.assert_array_equal(g, w)


# ---------------------------------------------------------------------------
# streaming quantize+detect
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("n,mul_form", [(32, False), (33, False), (32, True)])
def test_streaming_kernel_matches_ref(n, mul_form):
    key = jax.random.PRNGKey(n)
    x = jax.random.normal(key, (5, n))
    scale = jnp.abs(jax.random.normal(jax.random.fold_in(key, 1), (5, 1))) + 0.5
    boundaries = jnp.sort(jax.random.normal(jax.random.fold_in(key, 2), (15,)))
    got = streaming_quantize_outlier_kernel_call(
        x, scale, boundaries, 3, mul_form=mul_form)
    want = streaming_quantize_outlier_ref(
        x, scale, boundaries, 3, mul_form=mul_form)
    for g, w in zip(got, want):
        np.testing.assert_array_equal(g, w)


@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
@pytest.mark.parametrize("a_bits", [4, 3])
def test_ops_streaming_bit_identity(dtype, a_bits):
    """quantize_outlier_streaming == quantize_activation + detect_outliers_topk
    bit-for-bit: idx (dtype included), scale, outlier values and channels —
    the contract that makes detect routes token-identical under serving."""
    key = jax.random.PRNGKey(a_bits)
    calib = jax.random.normal(key, (64, 96))
    book = fit_activation_codebook(calib, a_bits)
    x = (jax.random.normal(jax.random.fold_in(key, 1), (7, 96)) * 2).astype(dtype)
    qa, outs = kops.quantize_outlier_streaming(x, book, 2)
    qa_ref = quantize_activation(x, book)
    det_ref = ol.detect_outliers_topk(x.astype(jnp.float32), 2)
    assert qa.idx.dtype == qa_ref.idx.dtype
    np.testing.assert_array_equal(qa.idx, qa_ref.idx)
    np.testing.assert_array_equal(qa.scale, qa_ref.scale)
    np.testing.assert_array_equal(outs.values, det_ref.values)
    np.testing.assert_array_equal(outs.channels, det_ref.channels)


# ---------------------------------------------------------------------------
# dispatch accounting + explicit fallback
# ---------------------------------------------------------------------------

def test_detect_dispatch_counters_record_routes():
    p = _layer(QLinearConfig())
    x = jax.random.normal(jax.random.PRNGKey(3), (4, 128))
    kr.reset()
    qlinear_apply(with_detect_route(p, "jnp"), x)
    qlinear_apply(with_detect_route(p, "pallas"), x)
    counts = kr.detect_dispatch_counts()
    assert counts["w4a4/jnp"] == 1
    assert counts["w4a4/pallas"] == 1
    assert kr.detect_kernel_calls() == 1 and kr.detect_jnp_calls() == 1
    assert kr.detect_calls() == 2
    # the dual branch also resolved a compensation route each time
    assert sum(kr.comp_route_counts().values()) == 2
    snap = kr.snapshot()
    assert snap["_detect_kernel_calls"] == 1 and snap["_detect_fallbacks"] == 0


def test_static_pallas_detect_fallback_is_explicit():
    """Static (OASIS-S) detection has no tournament: a requested pallas
    detect route is demoted — warned once, counted, bit-equal to jnp."""
    cfg = QLinearConfig(detection="static", detect_kernel="pallas")
    p = _layer(cfg, seed=9)
    x = jax.random.normal(jax.random.PRNGKey(4), (4, 128))
    kr.reset()
    kr._WARNED.clear()
    with pytest.warns(RuntimeWarning, match="falling back"):
        y = qlinear_apply(p, x)
    assert kr.detect_fallback_count() == 1
    y_jnp = qlinear_apply(with_detect_route(p, "jnp"), x)
    np.testing.assert_array_equal(y, y_jnp)  # same path -> bit-equal
    # second apply: counted again, but no warning spam
    with warnings.catch_warnings():
        warnings.simplefilter("error")
        qlinear_apply(p, x)
    assert kr.detect_fallback_count() == 2
    assert kr.detect_calls() == 3  # fallback rows still count as detections


# ---------------------------------------------------------------------------
# detect-route parity through the full dual-branch layer
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("gemm_route", ["jnp", "pallas"])
@pytest.mark.parametrize("a_bits", [3, 4, 5])
def test_qlinear_detect_route_parity(gemm_route, a_bits):
    """pallas detection (streaming on the jnp GEMM route, detection-only on
    the fused route / A>4) is BIT-equal to the lax.top_k route — not just
    allclose — so greedy tokens cannot diverge."""
    cfg = QLinearConfig(a_bits=a_bits, detection="dynamic", outlier_frac=0.02,
                        kernel=gemm_route)
    p = _layer(cfg, k=192, n=64, seed=a_bits)
    x = jax.random.normal(jax.random.PRNGKey(7), (5, 192)) * 2
    y_jnp = qlinear_apply(with_detect_route(p, "jnp"), x)
    y_pal = qlinear_apply(with_detect_route(p, "pallas"), x)
    np.testing.assert_array_equal(y_pal, y_jnp)


def test_qlinear_detect_route_parity_bf16():
    cfg = QLinearConfig(detection="dynamic", outlier_frac=0.02, kernel="jnp")
    p = _layer(cfg, k=128, n=32, seed=2)
    x = (jax.random.normal(jax.random.PRNGKey(9), (4, 128)) * 2).astype(jnp.bfloat16)
    np.testing.assert_array_equal(
        qlinear_apply(with_detect_route(p, "pallas"), x),
        qlinear_apply(with_detect_route(p, "jnp"), x))


# ---------------------------------------------------------------------------
# serving token identity: detect route flipped, prefix + speculation on
# ---------------------------------------------------------------------------

@pytest.mark.slow
def test_serving_token_identity_across_detect_routes():
    from repro.configs.base import get_smoke_config
    from repro.models.model import build, quantize_model
    from repro.serving.engine import ServeConfig, ServingEngine
    from repro.serving.speculative import DEFAULT_DRAFT_SPEC, SpeculativeConfig

    cfg = get_smoke_config("llama3_2_1b")
    model = build(cfg)
    params = model.init(jax.random.PRNGKey(0))
    spec = QuantSpec(base=QLinearConfig(a_bits=3, detection="dynamic",
                                        outlier_frac=0.01))
    qp = quantize_model(model, params, spec)
    dqp = quantize_model(model, params, DEFAULT_DRAFT_SPEC)
    prompts = [[1, 2, 3, 4, 5, 6, 7, 8], [9, 8, 7], [1, 2, 3, 4, 5, 6, 20, 21]]

    def serve(route):
        eng = ServingEngine(
            model, with_detect_route(qp, route),
            ServeConfig(cache_len=64, cache_dtype="float32", block_size=8,
                        prefill_chunk=4, prefix_cache=True,
                        speculative=SpeculativeConfig(k=2)),
            batch_slots=3,
            draft=(model, with_detect_route(dqp, route), DEFAULT_DRAFT_SPEC))
        out = eng.generate(prompts, max_new_tokens=6)
        return out, eng.stats

    kr.reset()
    out_jnp, _ = serve("jnp")
    out_pal, stats = serve("pallas")
    assert out_jnp == out_pal
    assert stats["outlier_kernel_calls"] > 0  # Orizuru really ran in serving
    assert stats["outlier_detect_calls"] > 0
    assert stats["outlier_fallbacks"] == 0
    assert stats["outlier_comp_gather"] + stats["outlier_comp_scatter"] > 0
