"""Kernel routing policy: resolution, QuantSpec plumbing, dispatch
accounting, explicit fallbacks, and pallas-vs-jnp parity through the full
dual-branch QLinear (bias + outlier compensation composed in)."""

import dataclasses
import warnings

import jax
import jax.numpy as jnp
import numpy as np
import pytest

import repro.core.kernel_routing as kr
from repro.core.qlinear import (
    QLinearConfig,
    qlinear_apply,
    quantize_linear,
    with_kernel_route,
)
from repro.core.quantspec import QuantSpec


def _layer(cfg: QLinearConfig, k=128, n=48, seed=0, bias=True):
    key = jax.random.PRNGKey(seed)
    w = jax.random.normal(key, (k, n))
    calib = jax.random.normal(jax.random.fold_in(key, 1), (64, k)) * 1.5
    b = jax.random.normal(jax.random.fold_in(key, 2), (n,)) if bias else None
    return quantize_linear(w, calib, cfg, bias=b)


# ---------------------------------------------------------------------------
# route resolution + config validation
# ---------------------------------------------------------------------------

def test_kernel_field_validated():
    with pytest.raises(ValueError, match="kernel"):
        QLinearConfig(kernel="cuda")


def test_resolve_route_passthrough_and_legacy():
    assert kr.resolve_route("pallas") == "pallas"
    assert kr.resolve_route("jnp") == "jnp"
    assert kr.resolve_route("jnp", use_kernel=True) == "jnp"  # explicit wins
    assert kr.resolve_route("auto", use_kernel=True) == "pallas"  # legacy opt-in
    with pytest.raises(ValueError):
        kr.resolve_route("bogus")


def test_auto_route_env_override(monkeypatch):
    monkeypatch.setattr(kr, "_AUTO_DEFAULT", None)
    monkeypatch.setenv("REPRO_LUT_KERNEL", "1")
    assert kr.resolve_route("auto") == "pallas"
    monkeypatch.setattr(kr, "_AUTO_DEFAULT", None)
    monkeypatch.setenv("REPRO_LUT_KERNEL", "off")
    assert kr.resolve_route("auto") == "jnp"
    monkeypatch.setattr(kr, "_AUTO_DEFAULT", None)
    monkeypatch.setenv("REPRO_LUT_KERNEL", "auto")
    want = "pallas" if jax.default_backend() == "tpu" else "jnp"
    assert kr.resolve_route("auto") == want


def test_quantspec_kernel_rule_and_json_roundtrip():
    spec = QuantSpec(base=QLinearConfig(detection="none"),
                     rules=[("mlp/*", {"kernel": "pallas"})])
    assert spec.resolve("blocks/mlp/wi").kernel == "pallas"
    assert spec.resolve("blocks/attn/wq").kernel == "auto"
    spec2 = QuantSpec.from_json_dict(spec.to_json_dict())
    assert spec2 == spec
    # pre-routing artifacts (no "kernel" key in the stored config) load with
    # the auto default rather than failing
    d = spec.to_json_dict()
    d["base"].pop("kernel")
    assert QuantSpec.from_json_dict(d).base.kernel == "auto"


def test_with_kernel_route_flips_tree():
    p = _layer(QLinearConfig(detection="none"))
    tree = {"a": p, "b": [p, jnp.ones(3)]}
    out = with_kernel_route(tree, "pallas")
    assert out["a"].cfg.kernel == "pallas"
    assert out["b"][0].cfg.kernel == "pallas"
    assert p.cfg.kernel == "auto"  # original untouched
    np.testing.assert_array_equal(out["b"][1], tree["b"][1])


# ---------------------------------------------------------------------------
# dispatch accounting + explicit fallback
# ---------------------------------------------------------------------------

def test_dispatch_counters_record_routes():
    p = _layer(QLinearConfig(detection="dynamic"))
    x = jax.random.normal(jax.random.PRNGKey(3), (4, 128))
    kr.reset()
    qlinear_apply(with_kernel_route(p, "jnp"), x)
    qlinear_apply(with_kernel_route(p, "pallas"), x)
    counts = kr.dispatch_counts()
    assert counts["w4a4/jnp"] == 1
    assert counts["w4a4/pallas"] == 1
    assert kr.kernel_calls() == 1 and kr.jnp_calls() == 1
    snap = kr.snapshot()
    assert snap["_kernel_calls"] == 1 and snap["_fallbacks"] == 0


def test_w8_activation_fallback_is_explicit():
    """a_bits > 4 on a requested pallas route: warned once, counted, and the
    result is exactly the jnp route's (the pre-routing code fell back
    silently)."""
    cfg = QLinearConfig(a_bits=5, detection="dynamic", kernel="pallas")
    p = _layer(cfg, seed=9)
    x = jax.random.normal(jax.random.PRNGKey(4), (4, 128))
    kr.reset()
    kr._WARNED.clear()
    before = kr.fallback_count()
    with pytest.warns(RuntimeWarning, match="falling back"):
        y = qlinear_apply(p, x)
    assert kr.fallback_count() == before + 1
    y_jnp = qlinear_apply(with_kernel_route(p, "jnp"), x)
    np.testing.assert_array_equal(y, y_jnp)  # same path -> bit-equal
    # second apply: counted again, but no warning spam
    with warnings.catch_warnings():
        warnings.simplefilter("error")
        qlinear_apply(p, x)
    assert kr.fallback_count() == before + 2


# ---------------------------------------------------------------------------
# pallas vs jnp parity through the full dual-branch layer
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("detection", ["none", "dynamic", "static", "static_dense"])
@pytest.mark.parametrize("w_bits", [4, 8])
def test_qlinear_parity_pallas_vs_jnp(detection, w_bits):
    cfg = QLinearConfig(w_bits=w_bits, detection=detection, outlier_frac=0.01)
    p = _layer(cfg, k=192, n=64, seed=w_bits * 10 + len(detection))
    x = jax.random.normal(jax.random.PRNGKey(7), (5, 192)) * 2
    y_jnp = qlinear_apply(with_kernel_route(p, "jnp"), x)
    y_pal = qlinear_apply(with_kernel_route(p, "pallas"), x)
    np.testing.assert_allclose(y_pal, y_jnp, rtol=2e-5, atol=1e-4)


def test_qlinear_parity_w3_draft_tier():
    """The speculative draft's W3A4 tier through the kernel route."""
    cfg = QLinearConfig(w_bits=3, detection="none")
    p = _layer(cfg, k=128, n=32, seed=5)
    x = jax.random.normal(jax.random.PRNGKey(8), (3, 128))
    np.testing.assert_allclose(
        qlinear_apply(with_kernel_route(p, "pallas"), x),
        qlinear_apply(with_kernel_route(p, "jnp"), x),
        rtol=2e-5, atol=1e-4)


# ---------------------------------------------------------------------------
# block autotune
# ---------------------------------------------------------------------------

def test_autotune_lut_blocks_caches_winner():
    from repro.kernels import ops
    from repro.core.quantize import fit_activation_codebook, quantize_weight

    x = jax.random.normal(jax.random.PRNGKey(0), (8, 128))
    qw = quantize_weight(jax.random.normal(jax.random.PRNGKey(1), (128, 32)), 4)
    book = fit_activation_codebook(x, 4)
    cands = ((8, 32, 64), (8, 32, 128))
    best = ops.autotune_lut_blocks(x, book, qw, candidates=cands, reps=1)
    assert best in cands
    hit = ops._cached_blocks(8, 128, 32, 4, 4, True)
    assert (hit["block_m"], hit["block_n"], hit["block_k"]) == best
    # the cached blocks produce the same result as the defaults
    y = ops.lut_gemm_fused(x, book, qw)
    np.testing.assert_allclose(y, ops.lut_gemm_fused(x, book, qw, blocks=best),
                               rtol=1e-6, atol=1e-6)


# ---------------------------------------------------------------------------
# serving token identity: kernel route on vs off (speculation + prefix on)
# ---------------------------------------------------------------------------

@pytest.mark.slow
def test_serving_token_identity_across_routes():
    from repro.configs.base import get_smoke_config
    from repro.models.model import build, quantize_model
    from repro.serving.engine import ServeConfig, ServingEngine
    from repro.serving.speculative import DEFAULT_DRAFT_SPEC, SpeculativeConfig

    cfg = get_smoke_config("llama3_2_1b")
    model = build(cfg)
    params = model.init(jax.random.PRNGKey(0))
    qp = quantize_model(model, params,
                        QuantSpec(base=QLinearConfig(detection="none")))
    dqp = quantize_model(model, params, DEFAULT_DRAFT_SPEC)
    prompts = [[1, 2, 3, 4, 5, 6, 7, 8], [9, 8, 7], [1, 2, 3, 4, 5, 6, 20, 21]]

    def serve(route):
        eng = ServingEngine(
            model, with_kernel_route(qp, route),
            ServeConfig(cache_len=64, cache_dtype="float32", block_size=8,
                        prefill_chunk=4, prefix_cache=True,
                        speculative=SpeculativeConfig(k=2)),
            batch_slots=3,
            draft=(model, with_kernel_route(dqp, route), DEFAULT_DRAFT_SPEC))
        out = eng.generate(prompts, max_new_tokens=6)
        return out, eng.stats

    kr.reset()
    out_jnp, _ = serve("jnp")
    out_pal, stats = serve("pallas")
    assert out_jnp == out_pal
    assert stats["lut_kernel_calls"] > 0  # the engine really took the kernel
    assert stats["lut_fallbacks"] == 0
