"""Core quantization invariants: codebooks, packing, LUT-GEMM forms,
outlier look-ahead exactness. Unit + hypothesis property tests."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

hypothesis = pytest.importorskip("hypothesis")  # property tests need it
from hypothesis import given, settings
from hypothesis import strategies as st

from repro.core import (
    assign,
    assign_via_boundaries,
    boundaries_from_centroids,
    build_lut,
    compensate_gather,
    compensate_scatter,
    dequantize_activation,
    dequantize_weight,
    detect_outliers_static,
    detect_outliers_topk,
    fit_activation_codebook,
    kmeans_fit,
    lut_gemm,
    lut_gemm_counting,
    num_outliers,
    orizuru_comparisons,
    outlier_residuals,
    pack_int4,
    quantize_activation,
    quantize_weight,
    static_thresholds,
    token_scale,
    unpack_int4,
)
from repro.core.qlinear import QLinearConfig, qlinear_apply, quantize_linear


def _rand(shape, seed=0, scale=1.0):
    return jax.random.normal(jax.random.PRNGKey(seed), shape) * scale


# ---------------------------------------------------------------------------
# codebook
# ---------------------------------------------------------------------------

def test_kmeans_sorted_and_within_range():
    x = _rand((4096,), 1)
    c = kmeans_fit(x, 16)
    assert np.all(np.diff(c) >= 0)
    assert c.min() >= x.min() and c.max() <= x.max()


def test_kmeans_beats_rtn_on_gaussian():
    """The paper's premise: learned centroids < uniform grid on real dists."""
    x = _rand((8192,), 2)
    km = kmeans_fit(x, 16)
    grid = jnp.linspace(x.min(), x.max(), 16)
    err_km = jnp.mean((x - km[assign(x, km)]) ** 2)
    err_grid = jnp.mean((x - grid[assign(x, grid)]) ** 2)
    assert float(err_km) < float(err_grid)


def test_weighted_kmeans_shifts_centroids():
    """Fisher-weighted fit must allocate resolution to high-weight samples."""
    x = jnp.concatenate([_rand((1000,), 3), 5.0 + 0.1 * _rand((50,), 4)])
    w_hi = jnp.concatenate([jnp.ones(1000), 100.0 * jnp.ones(50)])
    c_plain = kmeans_fit(x, 8)
    c_wtd = kmeans_fit(x, 8, w=w_hi)
    # weighted codebook has more centroids near the heavy cluster at ~5
    near = lambda c: int(jnp.sum(c > 4.0))
    assert near(c_wtd) >= near(c_plain)


@settings(max_examples=30, deadline=None)
@given(seed=st.integers(0, 2**31 - 1), n=st.sampled_from([8, 16]))
def test_boundary_assign_equals_argmin(seed, n):
    x = jax.random.normal(jax.random.PRNGKey(seed), (257,)) * 2
    book = kmeans_fit(jax.random.normal(jax.random.PRNGKey(seed + 1), (512,)), n)
    np.testing.assert_array_equal(assign_via_boundaries(x, book), assign(x, book))


# ---------------------------------------------------------------------------
# packing / containers
# ---------------------------------------------------------------------------

@settings(max_examples=30, deadline=None)
@given(seed=st.integers(0, 2**31 - 1), m=st.integers(1, 17), k2=st.integers(1, 33))
def test_pack_unpack_roundtrip(seed, m, k2):
    idx = jax.random.randint(jax.random.PRNGKey(seed), (m, 2 * k2), 0, 16)
    np.testing.assert_array_equal(unpack_int4(pack_int4(idx)), idx)


def test_quantized_weight_hbm_bytes():
    qw = quantize_weight(_rand((128, 64)), 4)
    assert qw.hbm_bytes() == 128 * 64 // 2 + 16 * 4 + 64 * 4
    assert qw.packed.dtype == jnp.uint8 and qw.packed.shape == (128, 32)


def test_weight_quantization_error_bounded():
    w = _rand((256, 128), 7)
    deq = dequantize_weight(quantize_weight(w, 4))
    rel = jnp.linalg.norm(deq - w) / jnp.linalg.norm(w)
    assert float(rel) < 0.1  # 4-bit K-Means on gaussian ~ 4-5% typical


# ---------------------------------------------------------------------------
# LUT-GEMM equivalences (the paper's core mathematical claim)
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("a_bits", [3, 4])
def test_counting_equals_factorized_equals_dequant(a_bits):
    w = _rand((64, 32), 3, 0.5)
    x = _rand((8, 64), 4)
    qw = quantize_weight(w, 4)
    qa = quantize_activation(x, fit_activation_codebook(x, a_bits))
    y_count = lut_gemm_counting(qa, qw)
    y_fact = lut_gemm(qa, qw)
    y_deq = dequantize_activation(qa) @ dequantize_weight(qw)
    np.testing.assert_allclose(y_count, y_fact, rtol=1e-4, atol=1e-4)
    np.testing.assert_allclose(y_fact, y_deq, rtol=1e-4, atol=1e-4)


def test_lut_is_cartesian_product():
    a = jnp.array([1.0, 2.0])
    w = jnp.array([3.0, 5.0, 7.0])
    np.testing.assert_array_equal(build_lut(a, w), jnp.outer(a, w))


@settings(max_examples=15, deadline=None)
@given(seed=st.integers(0, 2**31 - 1), m=st.integers(1, 9), k=st.sampled_from([32, 64]),
       n=st.sampled_from([2, 16, 30]))
def test_lut_gemm_property(seed, m, k, n):
    w = jax.random.normal(jax.random.PRNGKey(seed), (k, n))
    x = jax.random.normal(jax.random.PRNGKey(seed + 1), (m, k))
    qw = quantize_weight(w, 4)
    qa = quantize_activation(x, fit_activation_codebook(x, 4))
    np.testing.assert_allclose(
        lut_gemm_counting(qa, qw), lut_gemm(qa, qw), rtol=1e-3, atol=1e-3
    )


# ---------------------------------------------------------------------------
# outliers: look-ahead + compensation exactness (paper Fig. 4/7)
# ---------------------------------------------------------------------------

def _outlier_setup(seed=0, m=8, k=64, n=32, frac=0.05):
    w = _rand((k, n), seed, 0.5)
    x = _rand((m, k), seed + 1)
    x = x.at[0, 3].set(9.0).at[2, 10].set(-7.0)  # inject outliers
    cfg = QLinearConfig(detection="dynamic", outlier_frac=frac)
    p = quantize_linear(w, x, cfg)
    return w, x, cfg, p


def test_lookahead_equals_detect_then_split():
    """Y* + Y' == (quantized inliers + FP outliers) @ W~  — bit-level claim."""
    w, x, cfg, p = _outlier_setup()
    y = qlinear_apply(p, x, cfg)
    k = num_outliers(x.shape[-1], cfg.outlier_frac)
    outs = detect_outliers_topk(x, k)
    deq_a = dequantize_activation(quantize_activation(x, p.act_codebook))
    onehot = jax.nn.one_hot(outs.channels, x.shape[-1]).sum(-2)
    x_split = jnp.where(onehot > 0, x, deq_a)
    y_split = x_split @ dequantize_weight(p.qw)
    np.testing.assert_allclose(y, y_split, rtol=1e-4, atol=1e-4)


def test_gather_equals_scatter_compensation():
    w, x, cfg, p = _outlier_setup()
    y_g = qlinear_apply(p, x, QLinearConfig(outlier_frac=0.05, comp_mode="gather"))
    y_s = qlinear_apply(p, x, QLinearConfig(outlier_frac=0.05, comp_mode="scatter"))
    np.testing.assert_allclose(y_g, y_s, rtol=1e-4, atol=1e-4)


def test_outlier_compensation_improves_accuracy():
    w, x, cfg, p = _outlier_setup(frac=0.05)
    y_ref = x @ w
    y_with = qlinear_apply(p, x, cfg)
    y_without = qlinear_apply(p, x, QLinearConfig(detection="none"))
    err_with = float(jnp.linalg.norm(y_with - y_ref))
    err_without = float(jnp.linalg.norm(y_without - y_ref))
    assert err_with < err_without


def test_static_detection_masks_non_violations():
    x = _rand((4, 64), 5)
    lo, hi = static_thresholds(x, 0.02)
    outs = detect_outliers_static(x, lo, hi, k=4)
    # masked slots contribute exactly zero residual
    qa = quantize_activation(x, fit_activation_codebook(x, 4))
    r = outlier_residuals(outs, qa)
    assert np.all(np.asarray(r)[np.asarray(outs.mask) == 0] == 0)


def test_orizuru_comparison_count_beats_spatten():
    from repro.core.outlier import naive_topk_comparisons

    for n in (1024, 4096, 12288):
        k = max(1, n // 200)
        assert orizuru_comparisons(n, k) < naive_topk_comparisons(n)


def test_dynamic_beats_static_on_shifted_distribution():
    """Paper Fig. 3: offline thresholds transfer poorly across datasets ->
    dynamic detection compensates more error than static."""
    w = _rand((64, 32), 11, 0.5)
    calib = _rand((64, 64), 12)  # offline calibration data
    online = _rand((16, 64), 13) * 2.0 + 0.5  # shifted online distribution
    cfg_d = QLinearConfig(detection="dynamic", outlier_frac=0.05)
    cfg_s = QLinearConfig(detection="static", outlier_frac=0.05)
    p_d = quantize_linear(w, calib, cfg_d)
    p_s = quantize_linear(w, calib, cfg_s)
    y_ref = online @ w
    err_d = float(jnp.linalg.norm(qlinear_apply(p_d, online, cfg_d) - y_ref))
    err_s = float(jnp.linalg.norm(qlinear_apply(p_s, online, cfg_s) - y_ref))
    assert err_d <= err_s * 1.05  # dynamic at least matches static


def test_static_dense_compensation_matches_semantics():
    """static_dense (prefill path): dense masked compensation == exact
    correction of every threshold-violating activation."""
    w = _rand((64, 32), 21, 0.5)
    x = _rand((8, 64), 22)
    x = x.at[1, 5].set(7.0)
    cfg = QLinearConfig(detection="static_dense", outlier_frac=0.02)
    p = quantize_linear(w, x, cfg)
    y = qlinear_apply(p, x, cfg)
    # manual: lookahead + dense masked residual
    qa = quantize_activation(x, p.act_codebook)
    deq = dequantize_activation(qa)
    mask = (x > p.thr_hi) | (x < p.thr_lo)
    y_ref = deq @ dequantize_weight(p.qw) + jnp.where(mask, x - deq, 0) @ dequantize_weight(p.qw)
    np.testing.assert_allclose(y, y_ref, rtol=1e-4, atol=1e-4)
    assert bool(mask.any())  # the injected outlier is actually compensated


def test_bf16_fused_quantize_close_to_f32_path():
    """Production bf16 sum-of-compares bucketize agrees with the exact f32
    searchsorted path on all but boundary-rounding ties."""
    x32 = _rand((64, 128), 31)
    book = fit_activation_codebook(x32, 4)
    qa32 = quantize_activation(x32, book)
    qa16 = quantize_activation(x32.astype(jnp.bfloat16), book)
    assert qa16.idx.dtype == jnp.int8
    mismatch = float(jnp.mean((qa16.idx.astype(jnp.int32) != qa32.idx).astype(jnp.float32)))
    assert mismatch < 0.02, mismatch  # bf16 rounding flips only boundary ties
