"""Quickstart: the paper's technique end-to-end on one linear layer.

  1. K-Means-quantize a weight matrix (W4, per-out-channel scales)
  2. learn an offline activation codebook (A4) on calibration data
  3. run the Cartesian-product LUT-GEMM three ways (counting oracle,
     factorized jnp, Pallas kernel) and check they agree
  4. add dynamic outlier detection + look-ahead error compensation and see
     the accuracy recovered
  5. scale it to a whole model with the declarative QuantSpec API:
     quantize -> save_quantized -> load_quantized -> token-identical logits

Run: PYTHONPATH=src python examples/quickstart.py
"""

import tempfile

import jax
import jax.numpy as jnp

from repro.core import (
    QuantSpec,
    detect_outliers_topk,
    fit_activation_codebook,
    load_quantized,
    lut_gemm,
    lut_gemm_counting,
    num_outliers,
    quantize_activation,
    quantize_model,
    quantize_weight,
    save_quantized,
)
from repro.core.qlinear import QLinearConfig, qlinear_apply, quantize_linear
from repro.kernels import ops


def main() -> None:
    key = jax.random.PRNGKey(0)
    k_dim, n_dim, m = 512, 256, 32
    w = jax.random.normal(key, (k_dim, n_dim)) * 0.4
    x = jax.random.normal(jax.random.PRNGKey(1), (m, k_dim))
    # heavy-tailed activations: inject the outliers LLMs exhibit
    x = x.at[0, 7].set(12.0).at[5, 100].set(-9.0)

    print("== 1. quantize weights (W4 K-Means, per-out-channel scale)")
    qw = quantize_weight(w, nbits=4)
    print(f"   packed {qw.packed.shape} uint8 + 16-entry codebook -> "
          f"{qw.hbm_bytes()/w.size/4:.2%} of fp32 bytes")

    print("== 2. offline activation codebook (A4 K-Means on calibration set)")
    book = fit_activation_codebook(x, nbits=4)
    qa = quantize_activation(x, book)

    print("== 3. LUT-GEMM three ways")
    y_ref = x @ w
    y_counting = lut_gemm_counting(qa, qw)  # paper Fig. 6 histogram form
    y_factorized = lut_gemm(qa, qw)  # TPU-native factorized form
    y_kernel = ops.lut_gemm(qa, qw)  # Pallas kernel (interpret on CPU)
    print(f"   counting vs factorized : {jnp.max(jnp.abs(y_counting - y_factorized)):.2e}")
    print(f"   factorized vs kernel   : {jnp.max(jnp.abs(y_factorized - y_kernel)):.2e}")

    print("== 4. outlier look-ahead + error compensation")
    err_plain = float(jnp.linalg.norm(y_factorized - y_ref) / jnp.linalg.norm(y_ref))
    cfg = QLinearConfig(detection="dynamic", outlier_frac=0.01)
    p = quantize_linear(w, x, cfg)
    y_oasis = qlinear_apply(p, x, cfg)
    err_oasis = float(jnp.linalg.norm(y_oasis - y_ref) / jnp.linalg.norm(y_ref))
    k = num_outliers(k_dim, cfg.outlier_frac)
    outs = detect_outliers_topk(x, k)
    print(f"   detected {outs.channels.shape[-1]} outliers/token "
          f"(top-{k} + bottom-{k}), rel.err {err_plain:.4f} -> {err_oasis:.4f}")
    assert err_oasis < err_plain

    print("== 5. whole model: QuantSpec -> quantize_model -> save -> load")
    from repro.configs.base import get_smoke_config
    from repro.models.model import build

    mcfg = get_smoke_config("llama3_2_1b")
    model = build(mcfg)
    params = model.init(jax.random.PRNGKey(0))
    spec = QuantSpec(
        base=QLinearConfig(detection="dynamic", outlier_frac=0.005),
        rules=[("mlp/wd", {"w_bits": 8}),   # per-layer precision: W8 down-proj
               ("attn/wk", "skip")],        # ...and leave wk dense entirely
        kv_bits=4,
    )
    qparams = quantize_model(model, params, spec)
    batch = {"tokens": jnp.arange(8, dtype=jnp.int32)[None] % mcfg.vocab_size}
    logits = model.apply(qparams, batch).logits
    with tempfile.TemporaryDirectory() as d:
        save_quantized(d, mcfg, spec, qparams)
        loaded = load_quantized(d)  # fresh process stand-in: no calibration
        logits2 = loaded.model.apply(loaded.params, batch).logits
    assert bool(jnp.all(logits == logits2)), "artifact must be bit-exact"
    print(f"   per-layer spec applied ({spec.rules[0].pattern} -> W8, "
          f"{spec.rules[1].pattern} dense), artifact round-trip bit-exact")
    print("OK")


if __name__ == "__main__":
    main()
