"""Serving driver: batched requests against a K-Means-quantized model,
served from a saved quantized artifact.

Trains a tiny LM briefly (so generations aren't pure noise), quantizes it
under a declarative per-layer QuantSpec (W4A4 + dynamic outliers everywhere,
W8 down-projections, int4 K-Means KV cache), SAVES the quantized model with
``save_quantized``, then — like a production serving process — LOADS the
artifact and serves a batch of prompts through the paged continuous-batching
engine. No calibration or K-Means code runs on the load path.

Pass ``--speculative`` to also quantize the SAME model under the default
W3/A4 draft policy (``repro.serving.speculative.DEFAULT_DRAFT_SPEC`` — the
per-layer sensitivity sweep in benchmarks/bench_sensitivity.py is what picks
its W4 guard), save it as a second artifact, and re-serve the prompts with
draft-propose / target-verify speculative decoding: token-identical output,
several tokens committed per target step (acceptance rate printed).

Pass ``--telemetry quality`` to additionally run the quantization-numerics
probes (codebook utilization / SQNR / outlier-energy gauges, calibration
drift, shadow-reference logit KL; see ``repro.core.numerics``), and
``--metrics-json PATH`` to dump the final metric snapshot as JSON (with a
Prometheus text rendering alongside it under the ``"expfmt"`` key).

Run: PYTHONPATH=src python examples/serve_quantized.py [--steps 200]
     [--smoke] [--speculative] [--telemetry quality] [--metrics-json out.json]
"""

import argparse
import json
import sys
import tempfile

import jax

from repro.configs.base import get_smoke_config
from repro.core import QLinearConfig, QuantSpec, quantize_model
from repro.core.artifact import load_calib_stats, load_quantized, save_quantized
from repro.data.pipeline import ByteCorpus, DataConfig, TokenPipeline
from repro.models.model import build
from repro.optim.adamw import AdamWConfig
from repro.serving.engine import ServeConfig, ServingEngine
from repro.train.trainer import TrainConfig, Trainer


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--steps", type=int, default=200, help="warmup train steps")
    ap.add_argument("--smoke", action="store_true", help="CI: minimal training")
    ap.add_argument("--speculative", action="store_true",
                    help="also serve with a W3 draft artifact + verification")
    ap.add_argument("--config", default="oasis_7b",
                    help="smoke config to serve (e.g. oasis_7b, "
                         "h2o_danube_1_8b, recurrentgemma_2b, falcon_mamba_7b)")
    ap.add_argument("--telemetry", default="metrics",
                    choices=["off", "metrics", "trace", "quality"],
                    help="telemetry level ('quality' adds numerics probes)")
    ap.add_argument("--metrics-json", default=None, metavar="PATH",
                    help="write the final telemetry snapshot (JSON) + "
                         "Prometheus text rendering to PATH")
    args = ap.parse_args()
    steps = 30 if args.smoke else args.steps
    telemetry = args.telemetry
    if telemetry == "quality":
        # default cadences (16/32) are tuned for long-running servers; this
        # example serves ~30 packed steps, so sample tighter to populate
        # every gauge and land >= 1 shadow probe
        from repro.serving.telemetry import TelemetryConfig
        telemetry = TelemetryConfig(level="quality", quality_sample_every=4,
                                    quality_shadow_every=8)

    cfg = get_smoke_config(args.config)
    model = build(cfg)
    corpus = ByteCorpus()
    print(f"== warm up the model on repo text ({steps} steps) so decode is non-trivial")
    trainer = Trainer(
        model,
        TrainConfig(optimizer=AdamWConfig(lr=2e-3), warmup_steps=min(20, steps),
                    total_steps=steps),
        TokenPipeline(corpus.tokens, DataConfig(seq_len=64, global_batch=16, seed=0)),
    )
    trainer.run(steps, log_every=100)
    params = trainer.state["params"]

    print("== quantize under a per-layer QuantSpec "
          "(W4A4 + outliers; W8 down-proj; int4 KV)")
    spec = QuantSpec(
        base=QLinearConfig(detection="dynamic", outlier_frac=0.005),
        rules=[("mlp/wd", {"w_bits": 8})],  # precision where accuracy lives
        kv_bits=4, kv_dtype="float32",
    )
    qparams = quantize_model(model, params, spec)

    with tempfile.TemporaryDirectory() as artifact_dir:
        print(f"== save_quantized -> {artifact_dir} (packed npz + JSON manifest)")
        save_quantized(artifact_dir, cfg, spec, qparams)

        print("== load_quantized (fresh objects; zero calibration on this path)")
        served_model, served_params, served_spec = load_quantized(artifact_dir)

        engine = ServingEngine(
            served_model,
            served_params,
            ServeConfig.from_spec(served_spec, cache_len=128, block_size=16,
                                  prefill_chunk=16, telemetry=telemetry),
            batch_slots=4,
            calib_stats=load_calib_stats(artifact_dir),
        )
        prompts_text = ["def quantize(", "import jax", "class Model", "# The paper",
                        "return x @ w"]
        prompts = [[b for b in t.encode()] for t in prompts_text]
        print(f"== serving {len(prompts)} byte-level prompts through {engine.slots} "
              f"slots (paged={engine.paged}: int4 block pool + continuous batching)")
        outs = engine.generate(prompts, max_new_tokens=24)
        for text, toks in zip(prompts_text, outs):
            cont = bytes(t for t in toks if t < 256).decode(errors="replace")
            print(f"   {text!r} -> {cont!r}")
        if engine.paged:
            st = engine.scheduler.stats
            print(f"   scheduler: {st['packed_steps']} packed steps "
                  f"({st['mixed_steps']} mixed prefill+decode), "
                  f"{st['prefill_tokens']} prefill tokens in {st['prefill_chunks']} segments, "
                  f"peak pool occupancy {st['peak_occupancy']:.0%}, "
                  f"{st['preemptions']} preemptions")
            # every engine carries a telemetry snapshot: SLO histograms
            # (TTFT / inter-token latency) measured at the engine, plus the
            # packed-step host/device time split
            snap = engine.snapshot()
            ttft, itl = snap["requests"]["ttft_s"], snap["requests"]["itl_s"]
            steps = snap["steps"]
            print(f"   telemetry: TTFT p50 {ttft['p50'] * 1e3:.1f} ms / "
                  f"p95 {ttft['p95'] * 1e3:.1f} ms, "
                  f"ITL p50 {itl['p50'] * 1e3:.2f} ms over {itl['count']} tokens, "
                  f"step split host {steps['host_s']['mean'] * 1e3:.1f} ms / "
                  f"device {steps['device_s']['mean'] * 1e3:.1f} ms, "
                  f"mean budget util {steps['util']['mean']:.0%}")
            if args.telemetry == "quality":
                g = snap.get("gauges", {})
                utils = [v for k, v in g.items()
                         if k.startswith("numerics_a_codebook_util.")]
                sqnrs = [v for k, v in g.items()
                         if k.startswith("numerics_sqnr_db.")]
                print(f"   quality: {len(utils)} probed sites, "
                      f"mean codebook util {sum(utils) / max(len(utils), 1):.0%}, "
                      f"mean SQNR {sum(sqnrs) / max(len(sqnrs), 1):.1f} dB, "
                      f"drift alarms "
                      f"{snap.get('counters', {}).get('numerics_drift_alarms', 0)}")

        if args.speculative:
            from repro.serving.speculative import (DEFAULT_DRAFT_SPEC,
                                                   SpeculativeConfig)

            with tempfile.TemporaryDirectory() as draft_dir:
                print("== quantize the SAME model under the default W3 draft "
                      "policy and save the draft artifact")
                save_quantized(draft_dir, cfg, DEFAULT_DRAFT_SPEC,
                               quantize_model(model, params, DEFAULT_DRAFT_SPEC))
                spec_engine = ServingEngine(
                    served_model, served_params,
                    ServeConfig.from_spec(
                        served_spec, cache_len=128, block_size=16,
                        prefill_chunk=16,
                        speculative=SpeculativeConfig(k=2,
                                                      draft_artifact=draft_dir)),
                    batch_slots=4,
                )
            spec_outs = spec_engine.generate(prompts, max_new_tokens=24)
            assert spec_outs == outs, "speculative greedy must be token-identical"
            st = spec_engine.stats
            print(f"== speculative serving: token-identical in "
                  f"{st['packed_steps']} target steps "
                  f"(non-speculative took {engine.scheduler.stats['packed_steps']}), "
                  f"acceptance {st['acceptance_rate']:.0%} "
                  f"({st['accepted_tokens']}/{st['drafted_tokens']} drafts, "
                  f"{st['rolled_back_tokens']} rolled back, "
                  f"{st['draft_steps']} draft dispatches)")

        if args.metrics_json:
            dump = engine.snapshot()
            dump["expfmt"] = engine.telemetry.expfmt()
            with open(args.metrics_json, "w") as f:
                json.dump(dump, f, indent=1, default=float)
            print(f"== metrics snapshot -> {args.metrics_json} "
                  f"(JSON + Prometheus text under 'expfmt')")
    print("OK (QuantSpec-quantized artifact saved, reloaded, and served: "
          "W4/W8 weights + A4 activations + int4 paged KV, continuous batching"
          + (", speculative decoding verified" if args.speculative else "") + ")")


if __name__ == "__main__":
    sys.exit(main())
