"""Serving driver: batched requests against a K-Means-quantized model.

Trains a tiny LM briefly (so generations aren't pure noise), quantizes it
W4A4 + dynamic outliers + int4 K-Means KV cache, and serves a batch of
prompts through the prefill/decode engine — the paper's full inference path.

Run: PYTHONPATH=src python examples/serve_quantized.py
"""

import jax
import jax.numpy as jnp

from repro.configs.base import get_smoke_config
from repro.core.qlinear import QLinearConfig
from repro.data.pipeline import ByteCorpus, DataConfig, TokenPipeline
from repro.models.model import build
from repro.optim.adamw import AdamWConfig
from repro.serving.engine import ServeConfig, ServingEngine
from repro.train.trainer import TrainConfig, Trainer


def main() -> None:
    cfg = get_smoke_config("oasis_7b")
    model = build(cfg)
    corpus = ByteCorpus()
    print("== warm up the model on repo text (200 steps) so decode is non-trivial")
    trainer = Trainer(
        model,
        TrainConfig(optimizer=AdamWConfig(lr=2e-3), warmup_steps=20, total_steps=200),
        TokenPipeline(corpus.tokens, DataConfig(seq_len=64, global_batch=16, seed=0)),
    )
    trainer.run(200, log_every=100)
    params = trainer.state["params"]

    print("== quantize: W4A4 K-Means + dynamic outliers (paper serving config)")
    qcfg = QLinearConfig(detection="dynamic", outlier_frac=0.005)
    qparams = model.quantize(params, qcfg)

    engine = ServingEngine(
        model,
        qparams,
        ServeConfig(cache_len=128, qconfig=qcfg, kv_quant=True, cache_dtype="float32",
                    block_size=16, prefill_chunk=16),
        batch_slots=4,
    )
    prompts_text = ["def quantize(", "import jax", "class Model", "# The paper",
                    "return x @ w"]
    prompts = [[b for b in t.encode()] for t in prompts_text]
    print(f"== serving {len(prompts)} byte-level prompts through {engine.slots} slots "
          f"(paged={engine.paged}: int4 block pool + continuous batching)")
    outs = engine.generate(prompts, max_new_tokens=24)
    for text, toks in zip(prompts_text, outs):
        cont = bytes(t for t in toks if t < 256).decode(errors="replace")
        print(f"   {text!r} -> {cont!r}")
    if engine.paged:
        st = engine.scheduler.stats
        print(f"   scheduler: {st['packed_steps']} packed steps "
              f"({st['mixed_steps']} mixed prefill+decode), "
              f"{st['prefill_tokens']} prefill tokens in {st['prefill_chunks']} segments, "
              f"peak pool occupancy {st['peak_occupancy']:.0%}, "
              f"{st['preemptions']} preemptions")
    print("OK (quantized weights + activations + int4 paged KV, continuous batching)")


if __name__ == "__main__":
    main()
