"""End-to-end driver: train a ~small LM for a few hundred steps on real text
(this repo's sources), checkpoint + resume, then post-training-quantize it
with the paper's recipe (calibrated codebooks + Fisher-weighted K-Means) and
compare held-out perplexity.

Run: PYTHONPATH=src python examples/train_and_quantize.py [--steps 400]
"""

import argparse
import math
import tempfile

import jax
import jax.numpy as jnp

from repro.configs.base import get_smoke_config
from repro.core import calibration
from repro.core.qlinear import QLinearConfig
from repro.data.pipeline import ByteCorpus, DataConfig, TokenPipeline
from repro.models.model import build
from repro.optim.adamw import AdamWConfig
from repro.train.trainer import TrainConfig, Trainer, make_eval_step


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--steps", type=int, default=400)
    ap.add_argument("--arch", default="llama3_2_1b", help="smoke config family")
    args = ap.parse_args()

    cfg = get_smoke_config(args.arch)
    model = build(cfg)
    corpus = ByteCorpus()
    pipe = TokenPipeline(corpus.tokens, DataConfig(seq_len=64, global_batch=16, seed=0))
    tc = TrainConfig(optimizer=AdamWConfig(lr=2e-3), warmup_steps=25,
                     total_steps=args.steps, checkpoint_every=100)

    with tempfile.TemporaryDirectory() as ckpt_dir:
        print(f"== training {args.arch} smoke config for {args.steps} steps "
              f"(checkpoints in {ckpt_dir})")
        trainer = Trainer(model, tc, pipe, ckpt_dir=ckpt_dir)
        trainer.run(args.steps, log_every=50)

        # simulate preemption + auto-resume
        resumed = Trainer(model, tc,
                          TokenPipeline(corpus.tokens, DataConfig(64, 16, 0)),
                          ckpt_dir=ckpt_dir)
        print(f"== auto-resume check: restored at step {resumed.step}")
        params = trainer.state["params"]

    print("== calibration: capture activations + Fisher weights, fit codebooks")
    from repro.models.model import unstack_for_capture

    model_u, params_u = unstack_for_capture(model, params)
    calib_pipe = TokenPipeline(corpus.tokens, DataConfig(seq_len=64, global_batch=4, seed=9))
    with calibration.capture() as store:
        for _ in range(4):
            b = calib_pipe.next_batch()
            model_u.apply(params_u, {"tokens": jnp.asarray(b["tokens"][:, :-1])})
    acts = calibration.captured(store)
    print(f"   captured {len(acts)} tapped projections, "
          f"{next(iter(acts.values())).shape[0]} tokens each")

    eval_step = jax.jit(make_eval_step(model, tc))
    hold = TokenPipeline(corpus.tokens, DataConfig(seq_len=64, global_batch=16, seed=777))
    batch = {k: jnp.asarray(v) for k, v in hold.next_batch().items()}

    ce_fp = float(eval_step(params, batch)["ce"])
    rows = [("fp32", ce_fp)]
    from repro.core.quantspec import QuantSpec
    from repro.models.model import quantize_model

    for name, spec in [
        ("rtn_w4a4", QuantSpec(base=QLinearConfig(method="uniform", detection="none"))),
        ("kmeans_w4a4_no_outlier", QuantSpec(base=QLinearConfig(detection="none"))),
        ("oasis_w4a4", QuantSpec(base=QLinearConfig(detection="dynamic",
                                                    outlier_frac=0.005))),
        ("oasis_w4a4_w8_down", QuantSpec(
            base=QLinearConfig(detection="dynamic", outlier_frac=0.005),
            rules=[("mlp/wd", {"w_bits": 8})])),
    ]:
        qp = quantize_model(model, params, spec, calib=acts)
        # apply-time behaviour rides inside each QLinearParams (spec-resolved)
        rows.append((name, float(eval_step(qp, batch)["ce"])))

    print("\nmethod                     CE      PPL     dCE")
    for name, ce in rows:
        print(f"{name:26s} {ce:.4f}  {math.exp(ce):7.2f}  {ce-ce_fp:+.4f}")


if __name__ == "__main__":
    main()
