"""Paper Fig. 17: calibration sample count / dataset sensitivity.

Activation codebooks are fit on N calibration batches from dataset A (repo
.py sources) and evaluated on dataset B (repo .md sources) — the paper's
C4-vs-PTB cross-dataset setting. Expectation: CE converges by ~16 samples;
codebooks are robust across datasets (RMSE ~1e-2), unlike outlier thresholds
(bench_offline_online.py)."""

from __future__ import annotations

import math

from benchmarks.common import capture_activations, emit, eval_ce, trained_lm
from repro.core.qlinear import QLinearConfig


def run() -> None:
    cfg, model, params, corpus = trained_lm()
    full_acts = capture_activations(model, params, corpus, n_batches=8)

    print("# Fig 17 analog — CE vs number of calibration samples")
    print("n_samples,ce,ppl")
    ces = {}
    for n in (4, 8, 16, 32):
        calib = {k: v[: n * 64] for k, v in full_acts.items()}  # n seqs of 64 tokens
        ce = eval_ce(model, params, corpus,
                     QLinearConfig(detection="dynamic", outlier_frac=0.005),
                     batches=3, calib=calib)
        ces[n] = ce
        print(f"{n},{ce:.4f},{math.exp(ce):.2f}")

    assert ces[32] <= ces[4] + 0.05, "more calibration data must not hurt"
    emit("fig17_convergence_by_16", 0.0,
         f"ce4={ces[4]:.4f} ce16={ces[16]:.4f} ce32={ces[32]:.4f}")


if __name__ == "__main__":
    run()
