"""Benchmark harness — one module per paper table/figure (DESIGN.md §6).

Emits ``name,us_per_call,derived`` CSV lines (plus each module's own tables).
Run: PYTHONPATH=src python -m benchmarks.run [module ...]
"""

from __future__ import annotations

import sys
import time
import traceback

MODULES = [
    "bench_lut_config",        # Table I + Fig 16
    "bench_ppl",               # Table III/IV
    "bench_throughput",        # Fig 11/12/13
    "bench_pipeline",          # Fig 14 + Fig 15(b,c)
    "bench_outlier_sensitivity",  # Fig 15(a)
    "bench_calibration",       # Fig 17
    "bench_offline_online",    # Fig 3 + Fig 5
    "bench_orizuru",           # §IV-D comparison counts
    "bench_serving",           # paged continuous batching vs seed engine
]


def main() -> None:
    only = set(sys.argv[1:])
    failures = []
    for name in MODULES:
        if only and name not in only:
            continue
        print(f"\n=== {name} " + "=" * max(0, 60 - len(name)))
        t0 = time.time()
        try:
            mod = __import__(f"benchmarks.{name}", fromlist=["run"])
            mod.run()
            print(f"--- {name} ok in {time.time()-t0:.1f}s")
        except Exception:  # noqa: BLE001 — report, continue, fail at end
            failures.append(name)
            traceback.print_exc()
    if failures:
        print(f"\nFAILED benchmarks: {failures}")
        sys.exit(1)
    print("\nall benchmarks passed")


if __name__ == "__main__":
    main()
