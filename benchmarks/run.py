"""Benchmark harness — one module per paper table/figure (DESIGN.md §6).

Emits ``name,us_per_call,derived`` CSV lines (plus each module's own tables)
AND, per module, a machine-readable ``BENCH_<name>.json`` in the repo root
(status, elapsed, every ``common.emit``/``common.record`` result) so the
perf trajectory is tracked across PRs instead of living in scrollback.

Run: PYTHONPATH=src python -m benchmarks.run [module ...] [--summary]

``--summary`` (after the selected modules run — or alone, to merge results
from earlier runs) collects every ``BENCH_*.json`` in the repo root into one
``BENCH_summary.json``: per-module status/elapsed plus all records, keyed by
module name.
"""

from __future__ import annotations

import json
import pathlib
import platform
import sys
import time
import traceback

ROOT = pathlib.Path(__file__).resolve().parents[1]

MODULES = [
    "bench_lut_config",        # Table I + Fig 16
    "bench_ppl",               # Table III/IV
    "bench_throughput",        # Fig 11/12/13
    "bench_pipeline",          # Fig 14 + Fig 15(b,c)
    "bench_outlier_sensitivity",  # Fig 15(a)
    "bench_sensitivity",       # per-layer W-bits sweep -> draft-spec choice
    "bench_calibration",       # Fig 17
    "bench_offline_online",    # Fig 3 + Fig 5
    "bench_orizuru",           # §IV-D comparison counts
    "bench_serving",           # paged continuous batching vs seed engine
]


def _write_result(name: str, ok: bool, elapsed: float, records: list[dict],
                  error: str | None = None) -> None:
    import jax

    payload = {
        "module": name,
        "ok": ok,
        "elapsed_s": round(elapsed, 2),
        "config": {
            "backend": jax.default_backend(),
            "device_count": jax.device_count(),
            "python": platform.python_version(),
            "jax": jax.__version__,
        },
        "records": records,
    }
    if error:
        payload["error"] = error
    (ROOT / f"BENCH_{name}.json").write_text(json.dumps(payload, indent=1))


def write_summary() -> pathlib.Path:
    """Merge every BENCH_<module>.json in the repo root into
    BENCH_summary.json (status/elapsed per module + all records)."""
    modules = {}
    for p in sorted(ROOT.glob("BENCH_*.json")):
        if p.name == "BENCH_summary.json":
            continue
        try:
            d = json.loads(p.read_text())
        except (json.JSONDecodeError, OSError):
            continue
        modules[d.get("module", p.stem[len("BENCH_"):])] = {
            "ok": d.get("ok"),
            "elapsed_s": d.get("elapsed_s"),
            "n_records": len(d.get("records", [])),
            "records": d.get("records", []),
            **({"error": d["error"]} if "error" in d else {}),
        }
    out = ROOT / "BENCH_summary.json"
    out.write_text(json.dumps({
        "modules": modules,
        "n_modules": len(modules),
        "all_ok": all(m["ok"] for m in modules.values()) if modules else False,
    }, indent=1))
    return out


def main() -> None:
    from benchmarks import common

    argv = sys.argv[1:]
    summary = "--summary" in argv
    only = {a for a in argv if not a.startswith("-")}
    if summary and not only:  # merge-only invocation: no modules re-run
        out = write_summary()
        print(f"merged {json.loads(out.read_text())['n_modules']} module "
              f"results -> {out.name}")
        return
    failures = []
    for name in MODULES:
        if only and name not in only:
            continue
        print(f"\n=== {name} " + "=" * max(0, 60 - len(name)))
        t0 = time.time()
        common.RECORDS.clear()
        try:
            mod = __import__(f"benchmarks.{name}", fromlist=["run"])
            mod.run()
            elapsed = time.time() - t0
            _write_result(name, True, elapsed, list(common.RECORDS))
            print(f"--- {name} ok in {elapsed:.1f}s -> BENCH_{name}.json")
        except Exception:  # noqa: BLE001 — report, continue, fail at end
            failures.append(name)
            _write_result(name, False, time.time() - t0, list(common.RECORDS),
                          error=traceback.format_exc(limit=5))
            traceback.print_exc()
    if summary:
        out = write_summary()
        print(f"\nmerged BENCH_*.json -> {out.name}")
    if failures:
        print(f"\nFAILED benchmarks: {failures}")
        sys.exit(1)
    print("\nall benchmarks passed")


if __name__ == "__main__":
    main()
