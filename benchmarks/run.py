"""Benchmark harness — one module per paper table/figure (DESIGN.md §6).

Emits ``name,us_per_call,derived`` CSV lines (plus each module's own tables)
AND, per module, a machine-readable ``BENCH_<name>.json`` in the repo root
(status, elapsed, every ``common.emit``/``common.record`` result) so the
perf trajectory is tracked across PRs instead of living in scrollback.

Run: PYTHONPATH=src python -m benchmarks.run [module ...]
"""

from __future__ import annotations

import json
import pathlib
import platform
import sys
import time
import traceback

ROOT = pathlib.Path(__file__).resolve().parents[1]

MODULES = [
    "bench_lut_config",        # Table I + Fig 16
    "bench_ppl",               # Table III/IV
    "bench_throughput",        # Fig 11/12/13
    "bench_pipeline",          # Fig 14 + Fig 15(b,c)
    "bench_outlier_sensitivity",  # Fig 15(a)
    "bench_sensitivity",       # per-layer W-bits sweep -> draft-spec choice
    "bench_calibration",       # Fig 17
    "bench_offline_online",    # Fig 3 + Fig 5
    "bench_orizuru",           # §IV-D comparison counts
    "bench_serving",           # paged continuous batching vs seed engine
]


def _write_result(name: str, ok: bool, elapsed: float, records: list[dict],
                  error: str | None = None) -> None:
    import jax

    payload = {
        "module": name,
        "ok": ok,
        "elapsed_s": round(elapsed, 2),
        "config": {
            "backend": jax.default_backend(),
            "device_count": jax.device_count(),
            "python": platform.python_version(),
            "jax": jax.__version__,
        },
        "records": records,
    }
    if error:
        payload["error"] = error
    (ROOT / f"BENCH_{name}.json").write_text(json.dumps(payload, indent=1))


def main() -> None:
    from benchmarks import common

    only = set(sys.argv[1:])
    failures = []
    for name in MODULES:
        if only and name not in only:
            continue
        print(f"\n=== {name} " + "=" * max(0, 60 - len(name)))
        t0 = time.time()
        common.RECORDS.clear()
        try:
            mod = __import__(f"benchmarks.{name}", fromlist=["run"])
            mod.run()
            elapsed = time.time() - t0
            _write_result(name, True, elapsed, list(common.RECORDS))
            print(f"--- {name} ok in {elapsed:.1f}s -> BENCH_{name}.json")
        except Exception:  # noqa: BLE001 — report, continue, fail at end
            failures.append(name)
            _write_result(name, False, time.time() - t0, list(common.RECORDS),
                          error=traceback.format_exc(limit=5))
            traceback.print_exc()
    if failures:
        print(f"\nFAILED benchmarks: {failures}")
        sys.exit(1)
    print("\nall benchmarks passed")


if __name__ == "__main__":
    main()
