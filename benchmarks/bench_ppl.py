"""Paper Table III/IV analog: held-out CE/PPL across quantization methods.

No pretrained LLaMA offline, so the study runs on the in-repo byte-LM trained
to convergence on real text (the repo's sources). Methods mirror the paper's
columns:

  fp32            — the FP16 baseline row
  rtn_w4a4        — RTN INT-WAQ (uniform grids, no outliers)
  smooth_w4a4     — SmoothQuant-style: per-channel scale migration, then RTN
  kmeans_w4a4     — NU-WAQ K-Means, NO outlier handling (ablation)
  oasis_s_w4a4    — K-Means + STATIC thresholds (OASIS-S)
  oasis_w4a4      — K-Means + dynamic Orizuru outliers (OASIS)  <- the paper
  oasis_w4a3      — 3-bit activations (OASIS-A3)
  rtn_w4a3        — RTN at A3 (collapses, as in Table III)

Expected ordering (asserted): fp <= oasis <= oasis_s <= kmeans-no-outlier
and oasis strictly better than RTN; A3 degrades everything but OASIS-A3
stays usable while RTN-A3 collapses.
"""

from __future__ import annotations

import math

import jax.numpy as jnp

from benchmarks.common import capture_activations, emit, eval_ce, record, trained_lm
from repro.core.qlinear import QLinearConfig
from repro.core.quantspec import QuantSpec


def _smoothquant_ce(model, params, corpus, acts):
    """SmoothQuant-style: migrate activation scale into weights, then RTN.

    s_j = sqrt(max|X_j| / max|W_j|) per input channel; W' = s*W, X' = X/s.
    Implemented as a param transform: equivalent since our per-token scale
    re-normalizes X (the migration changes the effective distribution)."""
    import jax

    # fold a global smoothing vector into every quantizable weight using the
    # captured input activations of matching width
    amax = {k: jnp.max(jnp.abs(v), axis=0) for k, v in acts.items()}

    def smooth(path_w):
        w = path_w
        k_dim = w.shape[-2] if w.ndim >= 2 else None
        for a in amax.values():
            if k_dim is not None and a.shape[0] == k_dim:
                wmax = jnp.maximum(jnp.max(jnp.abs(w), axis=-1, keepdims=True), 1e-6)
                s = jnp.sqrt(jnp.maximum(a[:, None], 1e-6) / wmax)
                return w * s.astype(w.dtype)
        return w

    smoothed = jax.tree.map(
        lambda x: smooth(x) if getattr(x, "ndim", 0) >= 2 else x, params
    )
    return eval_ce(model, smoothed, corpus,
                   QLinearConfig(method="uniform", detection="none",
                                 scale_mode="absmax"))


def run() -> None:
    cfg, model, params, corpus = trained_lm()
    acts = capture_activations(model, params, corpus)

    rows = {}
    rows["fp32"] = eval_ce(model, params, corpus, None)
    rows["rtn_w4a4"] = eval_ce(model, params, corpus,
                               QLinearConfig(method="uniform", detection="none"))
    rows["smooth_w4a4"] = _smoothquant_ce(model, params, corpus, acts)
    rows["kmeans_w4a4"] = eval_ce(model, params, corpus, QLinearConfig(detection="none"))
    rows["oasis_s_w4a4"] = eval_ce(model, params, corpus,
                                   QLinearConfig(detection="static", outlier_frac=0.005))
    rows["oasis_w4a4"] = eval_ce(model, params, corpus,
                                 QLinearConfig(detection="dynamic", outlier_frac=0.005))
    rows["oasis_w4a3"] = eval_ce(model, params, corpus,
                                 QLinearConfig(a_bits=3, detection="dynamic",
                                               outlier_frac=0.005))
    rows["rtn_w4a3"] = eval_ce(model, params, corpus,
                               QLinearConfig(a_bits=3, method="uniform", detection="none"))
    # per-layer mixed precision (the QuantSpec tentpole): down-proj is the
    # best-known accuracy-critical matrix (FineQuant) — give it W8
    rows["mixed_w8_down"] = eval_ce(
        model, params, corpus,
        QuantSpec(base=QLinearConfig(detection="dynamic", outlier_frac=0.005),
                  rules=[("mlp/wd", {"w_bits": 8})]))

    print("# Table III analog — held-out CE / PPL by quantization method")
    print("method,ce,ppl,delta_vs_fp")
    for k, ce in rows.items():
        print(f"{k},{ce:.4f},{math.exp(ce):.2f},{ce - rows['fp32']:+.4f}")
        record(f"ppl_{k}", ce=round(ce, 4), ppl=round(math.exp(ce), 2),
               delta_vs_fp=round(ce - rows["fp32"], 4))

    # ---- the paper's ordering claims ----------------------------------------
    assert rows["oasis_w4a4"] <= rows["kmeans_w4a4"] + 1e-6, "outliers must help"
    assert rows["oasis_w4a4"] <= rows["rtn_w4a4"], "NU-WAQ must beat INT-WAQ"
    assert rows["oasis_w4a3"] <= rows["rtn_w4a3"], "OASIS-A3 must beat RTN-A3"
    assert rows["oasis_w4a4"] >= rows["fp32"] - 0.05
    assert rows["mixed_w8_down"] <= rows["oasis_w4a4"] + 0.02, \
        "W8 down-proj must not degrade vs all-W4"
    emit("table3_oasis_w4a4_delta", 0.0, f"ce_delta={rows['oasis_w4a4']-rows['fp32']:.4f}")
    emit("table3_ordering", 0.0, "oasis<=kmeans_no_outlier<=?rtn verified")
    return rows


if __name__ == "__main__":
    run()
