"""Paper Fig. 3 + Fig. 5: offline-vs-online thresholds and centroids.

The paper's central empirical motivation:
  Fig 5 — activation CENTROIDS transfer across datasets (RMSE ~ 0.01)
           -> offline codebooks are safe;
  Fig 3 — outlier THRESHOLDS do NOT transfer (RMSE ~ 0.3)
           -> outliers must be detected dynamically (Orizuru).

Reproduced on the trained byte-LM's first-projection activations with two
disjoint text distributions (repo .py vs .md files)."""

from __future__ import annotations

import jax.numpy as jnp
import numpy as np

from benchmarks.common import emit, trained_lm
from repro.core import calibration
from repro.core.quantize import fit_activation_codebook, token_scale
from repro.data.pipeline import ByteCorpus, DataConfig, TokenPipeline


def _acts_for(model, params, tokens, n_batches=4, seed=0):
    from repro.models.model import unstack_for_capture

    model_u, params_u = unstack_for_capture(model, params)
    pipe = TokenPipeline(tokens, DataConfig(seq_len=64, global_batch=4, seed=seed))
    with calibration.capture() as store:
        for _ in range(n_batches):
            b = pipe.next_batch()
            model_u.apply(params_u, {"tokens": jnp.asarray(b["tokens"][:, :-1])})
    acts = calibration.captured(store)
    name = sorted(acts)[0]  # first attention q-projection input
    return acts[name]


def _norm01(x):
    x = np.asarray(x, dtype=np.float64)
    return (x - x.min()) / max(x.max() - x.min(), 1e-12)


def run() -> None:
    cfg, model, params, _ = trained_lm()
    corpus_a = ByteCorpus(suffixes=(".py",))
    corpus_b = ByteCorpus(suffixes=(".md",))
    xa = _acts_for(model, params, corpus_a.tokens, seed=1)
    xb = _acts_for(model, params, corpus_b.tokens, seed=2)

    # ---- Fig 5: centroids --------------------------------------------------
    ca = fit_activation_codebook(xa, 4)
    cb_ = fit_activation_codebook(xb, 4)
    rmse_centroids = float(np.sqrt(np.mean((_norm01(ca) - _norm01(cb_)) ** 2)))

    # ---- Fig 3: top-0.5% thresholds per token ------------------------------
    def thresholds(x):
        k = max(1, int(0.005 * x.shape[-1]))
        return np.sort(np.asarray(x), axis=-1)[:, -k]

    n = min(xa.shape[0], xb.shape[0])
    ta, tb = thresholds(xa[:n]), thresholds(xb[:n])
    rmse_thresholds = float(np.sqrt(np.mean((_norm01(ta) - _norm01(tb)) ** 2)))

    print("# Fig 3/5 analog — cross-dataset transfer (normalized RMSE)")
    print(f"centroids_rmse,{rmse_centroids:.4f}")
    print(f"thresholds_rmse,{rmse_thresholds:.4f}")
    assert rmse_centroids < rmse_thresholds, (
        "centroids must transfer better than outlier thresholds "
        "(the paper's motivation for dynamic detection)"
    )
    emit("fig5_centroid_transfer", 0.0, f"rmse={rmse_centroids:.4f} (paper: ~0.01)")
    emit("fig3_threshold_transfer", 0.0, f"rmse={rmse_thresholds:.4f} (paper: ~0.32-0.38)")


if __name__ == "__main__":
    run()
