"""Paper Fig. 11/12/13 analog: decode/prefill throughput, bf16 vs quantized.

The paper compares OASIS silicon against A100/FIGLUT. On TPU the equivalent
statement is roofline throughput from the memory term (single-batch decode is
HBM-bound): tokens/s = HBM_bw / bytes_moved_per_token. Bytes come from the
framework's own storage formats (bf16 vs int4-packed weights + codebooks +
scales, bf16 vs int4 KV), per assigned arch. Where dry-run artifacts exist
(results/dryrun/*.json), their measured per-device bytes are used instead of
the analytic model — keeping this benchmark tied to the compiled truth.
"""

from __future__ import annotations

import json
import pathlib

from benchmarks.common import emit
from repro.configs.base import get_config
from repro.launch.roofline import HW

RESULTS = pathlib.Path(__file__).resolve().parents[1] / "results" / "dryrun"
ARCHS = ["oasis_7b", "llama3_2_1b", "h2o_danube_1_8b", "musicgen_large"]


def _decode_bytes(cfg, ctx: int, batch: int, w_bits: int, kv_bits: int) -> float:
    """HBM bytes per decode step (whole model, all chips)."""
    n = cfg.n_params
    w_bytes = n * w_bits / 8 + (64 + 4 * cfg.d_model) * cfg.n_layers  # + books/scales
    kv_per_tok = 2 * cfg.n_layers * cfg.n_kv_heads * cfg.head_dim * kv_bits / 8
    return w_bytes + batch * ctx * kv_per_tok


def run() -> None:
    hw = HW()
    print("# Fig 11/12 analog — modeled decode tokens/s per chip-pod (ctx 2048)")
    print("arch,batch,bf16_tok_s,w4a4_tok_s,w4a4_kv4_tok_s,speedup_w4,speedup_w4kv4")
    for arch in ARCHS:
        cfg = get_config(arch)
        for batch in (1, 2, 4):
            t_bf16 = _decode_bytes(cfg, 2048, batch, 16, 16) / hw.hbm_bw
            t_w4 = _decode_bytes(cfg, 2048, batch, 4, 16) / hw.hbm_bw
            t_w4kv4 = _decode_bytes(cfg, 2048, batch, 4, 4) / hw.hbm_bw
            print(
                f"{arch},{batch},{batch/t_bf16:.0f},{batch/t_w4:.0f},{batch/t_w4kv4:.0f},"
                f"{t_bf16/t_w4:.2f},{t_bf16/t_w4kv4:.2f}"
            )

    cfg = get_config("oasis_7b")
    s_w4 = _decode_bytes(cfg, 2048, 1, 16, 16) / _decode_bytes(cfg, 2048, 1, 4, 16)
    emit("fig11_w4a4_vs_bf16_decode", 0.0, f"speedup={s_w4:.2f}x (paper: 3.00x vs FIGLUT)")
    assert s_w4 > 3.0, "4-bit weights must give >3x on memory-bound decode"

    # ---- Fig 13: prefill/decode pairs ---------------------------------------
    print("# Fig 13 analog — prefill(compute-bound) + decode(memory-bound) s/request")
    print("arch,prefill,decode,bf16_s,w4a4_s,speedup")
    for arch in ("oasis_7b",):
        cfg = get_config(arch)
        for p_len, d_len in ((512, 512), (1024, 1024), (2048, 2048)):
            flops_prefill = 2 * cfg.n_params * p_len
            t_pref = flops_prefill / hw.peak_flops  # compute-bound either way
            t_dec16 = sum(_decode_bytes(cfg, p_len + i, 1, 16, 16) for i in range(0, d_len, 64)) * 64 / hw.hbm_bw / 64
            t_dec4 = sum(_decode_bytes(cfg, p_len + i, 1, 4, 4) for i in range(0, d_len, 64)) * 64 / hw.hbm_bw / 64
            print(f"{arch},{p_len},{d_len},{t_pref + t_dec16:.2f},{t_pref + t_dec4:.2f},"
                  f"{(t_pref + t_dec16)/(t_pref + t_dec4):.2f}")

    # ---- tie to compiled dry-run where available ---------------------------
    for arch in ARCHS:
        f = RESULTS / f"{arch}__decode_32k__single.json"
        if f.exists():
            d = json.loads(f.read_text())
            if d.get("status") == "ok":
                m = d["roofline"]["memory_s"]
                emit(f"decode32k_compiled_{arch}", m * 1e6,
                     f"tokens_s_per_pod={128/m:.0f} bottleneck={d['roofline']['bottleneck']}")


if __name__ == "__main__":
    run()
