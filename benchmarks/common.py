"""Shared benchmark infrastructure.

``trained_lm()`` trains (once, then caches on disk) the small byte-level LM
that the accuracy benchmarks quantize — the in-repo stand-in for the paper's
LLaMA/OPT evaluations (no pretrained checkpoints offline). Text = this repo's
own sources (ByteCorpus); held-out evaluation uses a disjoint crop seed.
"""

from __future__ import annotations

import dataclasses
import pathlib
import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.checkpoint.checkpointer import CheckpointManager
from repro.configs.base import get_smoke_config
from repro.core.qlinear import QLinearConfig
from repro.core.quantspec import QuantSpec
from repro.data.pipeline import ByteCorpus, DataConfig, TokenPipeline
from repro.models.model import build, quantize_model
from repro.optim.adamw import AdamWConfig
from repro.train.trainer import TrainConfig, Trainer, make_eval_step

RESULTS = pathlib.Path(__file__).resolve().parents[1] / "results"
CKPT_DIR = RESULTS / "bench_lm"

# Machine-readable results registry: every emit()/record() lands here and
# benchmarks/run.py snapshots it to BENCH_<module>.json after each module.
RECORDS: list[dict] = []

_TC = TrainConfig(optimizer=AdamWConfig(lr=2e-3), microbatches=1,
                  warmup_steps=30, total_steps=800, checkpoint_every=400)


def bench_lm_config():
    cfg = get_smoke_config("oasis_7b")
    return dataclasses.replace(cfg, n_layers=3, d_model=128, n_heads=4,
                               n_kv_heads=4, head_dim=32, d_ff=256)


def trained_lm(steps: int = 800):
    """(cfg, model, params, corpus) — trained once, cached in results/."""
    cfg = bench_lm_config()
    model = build(cfg)
    corpus = ByteCorpus()
    pipe = TokenPipeline(corpus.tokens, DataConfig(seq_len=64, global_batch=16, seed=0))
    trainer = Trainer(model, _TC, pipe, ckpt_dir=str(CKPT_DIR))
    if trainer.step < steps:
        trainer.run(steps - trainer.step, log_every=100)
    return cfg, model, trainer.state["params"], corpus


def eval_ce(model, params, corpus, qcfg: QLinearConfig | QuantSpec | None = None,
            batches: int = 4, seed: int = 123, calib=None) -> float:
    """Held-out cross-entropy (PPL = exp(ce)); quantizes first if qcfg given.

    ``qcfg`` may be a bare QLinearConfig (rule-free spec) or a full
    QuantSpec. Apply-time behaviour (detection mode, outlier budget) rides
    inside the produced QLinearParams — nothing ambient to keep in sync."""
    if qcfg is not None:
        spec = qcfg if isinstance(qcfg, QuantSpec) else QuantSpec(base=qcfg)
        params = quantize_model(model, params, spec, calib=calib)
    eval_step = jax.jit(make_eval_step(model, _TC))
    pipe = TokenPipeline(corpus.tokens, DataConfig(seq_len=64, global_batch=16, seed=seed))
    ces = []
    for _ in range(batches):
        batch = {k: jnp.asarray(v) for k, v in pipe.next_batch().items()}
        ces.append(float(eval_step(params, batch)["ce"]))
    return float(np.mean(ces))


def capture_activations(model, params, corpus, n_batches: int = 2, seed: int = 7):
    """Run the tapped forward (non-jit, UNSCANNED) -> {tap_name: (tokens, K)}.

    Scan bodies are traced even outside jit, so taps only fire on the
    unrolled model variant (model.unstack_for_capture)."""
    from repro.core import calibration
    from repro.models.model import unstack_for_capture

    model_u, params_u = unstack_for_capture(model, params)
    pipe = TokenPipeline(corpus.tokens, DataConfig(seq_len=64, global_batch=4, seed=seed))
    with calibration.capture() as store:
        for _ in range(n_batches):
            batch = {k: jnp.asarray(v) for k, v in pipe.next_batch().items()}
            model_u.apply(params_u, {"tokens": batch["tokens"][:, :-1]})
    acts = calibration.captured(store)
    assert acts, "calibration capture returned nothing (tap plumbing broken)"
    return acts


def timed(fn, *args, reps: int = 3):
    fn(*args)  # warmup/compile
    t0 = time.perf_counter()
    for _ in range(reps):
        out = fn(*args)
    jax.block_until_ready(out)
    return (time.perf_counter() - t0) / reps * 1e6  # us


def emit(name: str, us_per_call: float, derived: str):
    print(f"{name},{us_per_call:.1f},{derived}")
    RECORDS.append({"name": name, "us_per_call": us_per_call, "derived": derived})


def record(name: str, **fields):
    """Structured (machine-readable) benchmark result; run.py writes these to
    BENCH_<module>.json so the perf trajectory is trackable across PRs."""
    RECORDS.append({"name": name, **fields})
