"""Paper Fig. 15(a): PPL vs outlier percentage (0.5% .. 10%).

More preserved outliers -> monotonically better CE (up to noise), with
diminishing returns — the accuracy half of the paper's accuracy/throughput
trade-off (the throughput half is bench_pipeline.py)."""

from __future__ import annotations

import math

from benchmarks.common import emit, eval_ce, trained_lm
from repro.core.qlinear import QLinearConfig


def run() -> None:
    cfg, model, params, corpus = trained_lm()
    print("# Fig 15a analog — CE/PPL vs outlier fraction (per side)")
    print("outlier_pct,ce,ppl")
    ces = {}
    for pct in (0.0, 0.5, 1.0, 2.0, 5.0, 10.0):
        ce = eval_ce(model, params, corpus,
                     QLinearConfig(detection="dynamic", outlier_frac=pct / 100))
        ces[pct] = ce
        print(f"{pct},{ce:.4f},{math.exp(ce):.2f}")
    assert ces[10.0] <= ces[0.5] + 0.02, "more outliers must not hurt CE"
    assert ces[0.5] <= ces[0.0] + 1e-6, "outlier handling must help vs none"
    emit("fig15a_gain_0.5pct_vs_none", 0.0, f"ce_gain={ces[0.0]-ces[0.5]:.4f}")
    emit("fig15a_gain_10pct_vs_0.5pct", 0.0, f"ce_gain={ces[0.5]-ces[10.0]:.4f}")


if __name__ == "__main__":
    run()
