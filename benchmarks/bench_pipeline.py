"""Paper Fig. 14 + Fig. 15(b,c) analog: dual-branch pipeline cycle model.

Cycle-accurate-style accounting of the OASIS ASIC's two branches for an
M-K-N GEMM at W4A4 (paper hardware configuration, Table II):

  main branch   : cluster (K/4 cyc, 4 units) -> broadcast -> concat
                  (K*N / (16 lines * 4096 units)) -> count (K/16 per counter
                  batch over 32 counters) -> MAC-tree weighted sum
                  (256-entry weighted sum per output, 32-input tree)
  outlier branch: Orizuru init (1.5*K/16 comparator cycles) + pops
                  (2k*log2 K) -> per-outlier weight fetch/dequant/MAC
                  (N/8 MACs per outlier row)

Reproduces the paper's observations: at 1% outliers the branches are
comparable (outlier branch finishes ~1/3 earlier); beyond ~1% the outlier
branch becomes the bottleneck and throughput falls (Fig. 15(b,c) shape).
"""

from __future__ import annotations

import math

from benchmarks.common import emit

# Table II configuration
PE_LINES = 16
CONCAT_PER_LINE = 4096
COUNTERS_PER_LINE = 32
COUNTER_WIDTH = 16
MACS_PER_LINE = 8
CLUSTER_UNITS = 4
ORIZURU_UNITS = 273
ORIZURU_WIDTH = 16


def main_branch_cycles(m: int, k: int, n: int) -> int:
    cluster = math.ceil(m * k / (CLUSTER_UNITS * 1))  # binary-search pipelined
    concat = math.ceil(m * k * n / (PE_LINES * CONCAT_PER_LINE))
    count = math.ceil(m * k * n / (PE_LINES * COUNTERS_PER_LINE * COUNTER_WIDTH))
    reduce_ = math.ceil(m * n * 256 / (PE_LINES * 32))  # 32-input MAC tree / line
    return cluster + concat + count + reduce_


def outlier_branch_cycles(m: int, k: int, n: int, frac: float) -> int:
    n_out = max(1, int(2 * frac / 2 * k)) * m  # top+bottom frac of K per token
    init = math.ceil(1.5 * k / ORIZURU_UNITS / ORIZURU_WIDTH) * m
    pops = n_out * math.ceil(math.log2(k))
    # one weight row fetched + dequantized + MAC'd per outlier, N/8 MACs/line
    comp = n_out * math.ceil(n / (PE_LINES * MACS_PER_LINE))
    return init + pops + comp


def run() -> None:
    m, k, n = 1, 4096, 4096
    print("# Fig 14/15bc analog — branch cycles for 1-4096-4096 W4A4 GEMM")
    print("outlier_pct,main_cycles,outlier_cycles,bottleneck,throughput_rel")
    base = None
    for pct in (0.5, 1.0, 2.0, 5.0, 10.0):
        mc = main_branch_cycles(m, k, n)
        oc = outlier_branch_cycles(m, k, n, pct / 100)
        total = max(mc, oc)
        base = base or total
        print(f"{pct},{mc},{oc},{'main' if mc >= oc else 'outlier'},{base/total:.2f}")

    mc = main_branch_cycles(m, k, n)
    oc1 = outlier_branch_cycles(m, k, n, 0.01)
    assert oc1 < mc, "at 1% outliers the outlier branch must NOT bottleneck (Fig 14)"
    ratio = (mc - oc1) / mc
    emit("fig14_outlier_branch_headroom_1pct", 0.0,
         f"outlier_branch_finishes_{ratio:.0%}_earlier (paper: ~33%)")
    oc10 = outlier_branch_cycles(m, k, n, 0.10)
    assert oc10 > mc, "at 10% outliers the outlier branch must dominate (Fig 15)"
    emit("fig15_throughput_knee", 0.0, "knee between 1% and 10% outliers reproduced")

    # look-ahead vs conventional (OASIS-C): detection serialized before GEMM
    conv = mc + outlier_branch_cycles(m, k, n, 0.01)
    lookahead = max(mc, oc1)
    emit("fig15_lookahead_gain", 0.0,
         f"throughput_gain={conv/lookahead - 1:.0%} (paper: 16-18%)")


if __name__ == "__main__":
    run()
