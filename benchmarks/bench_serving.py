"""Serving-subsystem benchmark: mixed-length traffic, seed engine vs the
packed token-budget scheduler.

Workload: ``N_REQUESTS`` requests with prompt lengths drawn from a clipped
lognormal over [16, 512] tokens and per-request decode budgets over [8, 32],
arriving as a Poisson process. Two engines serve the same trace:

  ring  : the seed fixed-slot batcher (paged=False) — slot-sized chunks,
          left-padded batch prefill, every chunk decodes the max budget
  paged : the block-pool scheduler — ONE packed token-budget step per
          iteration that mixes every running slot's decode token with
          admitting requests' prefill tokens (decode reserved first, so
          admission can never stall decode)

The clock is hybrid discrete-event: compute time is measured wall time, idle
gaps fast-forward to the next arrival, so latency percentiles are
arrival-aware without real sleeps. Emits tokens/s over *requested* tokens
(both engines are credited only for tokens the trace asked for), p50/p95
completion latency, peak block-pool occupancy, preemption count, and the
mixed-step share (packed steps serving prefill AND decode together — the
quantity that was zero when prefill serialized at batch=1).

A second, deterministic **shared-system-prompt phase** measures prefix
sharing: one leader prefills a multi-block system prompt, then a mixed
wave of followers (most sharing the prefix, some unrelated) is served
twice — prefix cache on vs off. It reports ``prefix_hit_tokens`` (tokens
aliased from cached blocks), ``prefill_skipped`` (prefill compute avoided),
COW copies and cached-prefix evictions, asserts the two runs are
token-identical, and asserts prefill tokens computed drop by at least the
shared full-block fraction.

A third, **speculative-decoding phase** serves decode-heavy Poisson traffic
on the TRAINED byte-LM (drafting needs a model whose argmaxes mean
something) twice — speculation off vs on (a W3 K-Means draft of the same
model, saved and loaded as a real artifact). It asserts the two runs are
token-identical (greedy verification is exact regardless of draft quality),
records tokens/s for both plus the acceptance rate and the
drafted / accepted / rolled-back token counters, and on the full trace
asserts speculative decode tokens/s beats the non-speculative baseline.

**Telemetry** (this PR's instrument panel): the paged engine runs at
``telemetry="trace"``, so TTFT / inter-token-latency / queue-wait / e2e
percentiles are **engine-sourced** (serving/telemetry.py histograms, wall
clock) rather than derived from the bench's hybrid sim clock — both are
reported; they answer different questions (sim latency is arrival-aware,
engine latency is compute-path truth). The run writes a Chrome/Perfetto
trace artifact to ``results/serving_trace.json`` (validated as trace-event
JSON here — the CI gate), and a final overhead phase serves one small trace
with ``telemetry="off"`` vs the histograms-on default and records the
wall-time delta.

A final **outlier phase** (``run_outlier_phase``) measures the Orizuru
online outlier engine on the serving path: held-out CE across detection
modes at A4 and the A3 tier (asserting A3+dynamic strictly beats
A3+static), decode tokens/s per mode, and detect-route token identity
(``detect_kernel`` jnp vs pallas) under prefix sharing + speculation with
the kernel-dispatch and fallback counters asserted.

A final **quality phase** (``run_quality_phase``) measures this PR's
quantization-numerics observability: telemetry="quality" vs "metrics" wall
time at the default 1/16 probe sampling (asserted <= 10% + 50 ms slack in
full runs; smoke gates a looser 2.5x canary — CPU dispatch floors dominate
the tiny trace),
the acceptance-criterion gauges (per-site codebook utilization, SQNR,
outlier-energy-captured, drift) populated on a probe-every-step engine,
shadow-reference logit-KL observations recorded, and an induced-drift
subphase: the same traffic served against calibration stats scale-shifted
3x must move the drift gauge past alarm threshold while greedy tokens stay
identical to a telemetry="off" engine.

``--smoke`` (or run(smoke=True)) shrinks all traces for CI; the smoke run
still asserts ``prefix_hit_tokens > 0`` (the prefix-sharing CI gate),
``accepted_tokens > 0`` + speculative/baseline token-identity (the
speculative gate), a non-empty engine TTFT histogram, that the trace
artifact parses (the telemetry gates), ``outlier_detect_calls > 0``
with zero fallbacks plus Orizuru-vs-lax.top_k token identity (the outlier
gates), and the quality gates above (gauges populated, >= 1 shadow KL
observation, drift alarm on the shifted stats, token identity at quality).
"""

from __future__ import annotations

import dataclasses
import json
import sys
import tempfile
import time

import jax
import numpy as np

from benchmarks.common import RESULTS, emit, record
from repro.configs.base import get_smoke_config
from repro.core.artifact import load_quantized, save_quantized
from repro.core.qlinear import QLinearConfig
from repro.core.quantspec import QuantSpec
from repro.models.model import build, quantize_model
from repro.serving.engine import ServeConfig, ServingEngine

N_REQUESTS = 32
SLOTS = 8
PROMPT_RANGE = (16, 512)
BUDGET_RANGE = (8, 32)
MEAN_INTERARRIVAL_S = 0.05


@dataclasses.dataclass
class Trace:
    prompt: list[int]
    budget: int
    arrival: float


def make_trace(vocab: int, seed: int = 0, n_requests: int = N_REQUESTS,
               prompt_range: tuple[int, int] = PROMPT_RANGE) -> list[Trace]:
    rng = np.random.RandomState(seed)
    lens = np.clip(np.exp(rng.normal(4.5, 1.0, n_requests)).astype(int),
                   *prompt_range)
    budgets = rng.randint(BUDGET_RANGE[0], BUDGET_RANGE[1] + 1, n_requests)
    arrivals = np.cumsum(rng.exponential(MEAN_INTERARRIVAL_S, n_requests))
    return [Trace(list(rng.randint(1, vocab, n)), int(b), float(t))
            for n, b, t in zip(lens, budgets, arrivals)]


def make_shared_trace(vocab: int, prefix_len: int, n_requests: int,
                      tail_range: tuple[int, int], seed: int = 1,
                      shared_frac: float = 0.75):
    """Shared-system-prompt mix: request 0 (the leader) and ~shared_frac of
    the rest start with one common ``prefix_len``-token system prompt; the
    others are unrelated. Returns (traces, is_shared flags)."""
    rng = np.random.RandomState(seed)
    prefix = list(rng.randint(1, vocab, prefix_len))
    traces, shared = [], []
    for i in range(n_requests):
        tail = list(rng.randint(1, vocab, rng.randint(*tail_range)))
        is_shared = i == 0 or rng.rand() < shared_frac
        prompt = prefix + tail if is_shared else \
            list(rng.randint(1, vocab, prefix_len // 2 + len(tail)))
        traces.append(Trace(prompt, int(rng.randint(4, 13)), 0.0))
        shared.append(is_shared)
    return traces, shared


def run_shared_prefix(eng: ServingEngine, trace: list[Trace]):
    """Deterministic warm-cache driver: serve the leader until it decodes
    (its prefix blocks are then registered), then submit the follower wave
    and drain. Returns ({rid: tokens}, elapsed seconds)."""
    sched = eng.scheduler
    results: dict[int, list[int]] = {}
    t0 = time.perf_counter()
    lead = sched.submit(trace[0].prompt, trace[0].budget)
    while not any(r.rid == lead and r.decoding for r in sched._running):
        sched.step(results)
    for t in trace[1:]:
        sched.submit(t.prompt, t.budget)
    results.update(sched.run())
    return results, time.perf_counter() - t0


def _percentiles(lat: list[float]) -> tuple[float, float]:
    return float(np.percentile(lat, 50)), float(np.percentile(lat, 95))


def run_ring(eng: ServingEngine, trace: list[Trace]):
    """Seed path: slot-sized chunks in arrival order; a chunk starts once all
    its requests have arrived and the previous chunk finished, and decodes
    the chunk-max budget (the engine API has one scalar budget)."""
    sim, lat, tokens = 0.0, [], 0
    for i in range(0, len(trace), eng.slots):
        chunk = trace[i : i + eng.slots]
        sim = max(sim, max(t.arrival for t in chunk))
        t0 = time.perf_counter()
        eng.generate([t.prompt for t in chunk],
                     max_new_tokens=max(t.budget for t in chunk))
        sim += time.perf_counter() - t0
        lat += [sim - t.arrival for t in chunk]
        tokens += sum(t.budget for t in chunk)  # only requested tokens count
    return tokens / sim, lat


def run_paged(eng: ServingEngine, trace: list[Trace]):
    sched = eng.scheduler
    results: dict[int, list[int]] = {}
    sim, lat, born = 0.0, {}, {}
    pending = sorted(trace, key=lambda t: t.arrival)
    i = 0
    while True:
        while i < len(pending) and pending[i].arrival <= sim:
            rid = sched.submit(pending[i].prompt, pending[i].budget)
            born[rid] = pending[i].arrival
            i += 1
        if i < len(pending) and not sched._queue and not sched._running:
            sim = pending[i].arrival  # idle: fast-forward to the next arrival
            continue
        t0 = time.perf_counter()
        more = sched.step(results)
        sim += time.perf_counter() - t0
        for rid in results:
            if rid not in lat:
                lat[rid] = sim - born[rid]
        if not more and i >= len(pending):
            break
    tokens = sum(len(v) for v in results.values())
    return tokens / sim, [lat[r] for r in sorted(lat)], results


def run(smoke: bool = False) -> None:
    cfg = get_smoke_config("llama3_2_1b")
    model = build(cfg)
    params = model.init(jax.random.PRNGKey(0))
    spec = QuantSpec(base=QLinearConfig(detection="none"), kv_dtype="float32")
    # serve from a saved artifact, like a production process: quantize once,
    # save, load — the engines below never touch calibration/K-Means again
    with tempfile.TemporaryDirectory() as d:
        save_quantized(d, cfg, spec, quantize_model(model, params, spec))
        model, qparams, spec = load_quantized(d)
    n_req = 8 if smoke else N_REQUESTS
    prompt_range = (8, 96) if smoke else PROMPT_RANGE
    trace = make_trace(cfg.vocab_size, n_requests=n_req, prompt_range=prompt_range)
    cache_len = prompt_range[1] + BUDGET_RANGE[1] + 16

    ring = ServingEngine(model, qparams,
                         ServeConfig.from_spec(spec, cache_len=cache_len,
                                               paged=False),
                         batch_slots=SLOTS)
    paged = ServingEngine(model, qparams,
                          ServeConfig.from_spec(spec, cache_len=cache_len,
                                                block_size=16, prefill_chunk=64,
                                                telemetry="trace"),
                          batch_slots=SLOTS)
    # warm the jit caches so the comparison measures steady-state serving
    ring.generate([[1, 2, 3]] * SLOTS, max_new_tokens=2)
    paged.generate([[1, 2, 3]] * SLOTS, max_new_tokens=2)
    paged.telemetry.reset()  # measurements start clean after warmup

    print("engine,tokens_s,p50_s,p95_s,extra")
    ring_tps, ring_lat = run_ring(ring, trace)
    p50, p95 = _percentiles(ring_lat)
    print(f"ring,{ring_tps:.1f},{p50:.2f},{p95:.2f},slot_chunks={-(-n_req // SLOTS)}")

    paged_tps, paged_lat, _ = run_paged(paged, trace)
    p50q, p95q = _percentiles(paged_lat)
    st = paged.scheduler.stats
    steps = max(st["packed_steps"], 1)
    budget = paged.scheduler.token_budget
    print(f"paged,{paged_tps:.1f},{p50q:.2f},{p95q:.2f},"
          f"peak_occupancy={st['peak_occupancy']:.2f} preemptions={st['preemptions']} "
          f"packed_steps={st['packed_steps']} "
          f"mixed_steps={st['mixed_steps']} "
          f"prefill_tokens={st['prefill_tokens']} "
          f"budget_util={st['packed_tokens'] / (steps * budget):.2f} "
          f"avg_decode_rows={st['decode_slot_tokens'] / steps:.2f}")

    # ---- engine-sourced SLO latencies + Perfetto trace artifact -----------
    snap = paged.telemetry.snapshot()
    ttft, itl = snap["requests"]["ttft_s"], snap["requests"]["itl_s"]
    assert ttft["count"] > 0, "engine TTFT histogram is empty (CI gate)"
    assert itl["count"] > 0, "engine ITL histogram is empty"
    print(f"engine_lat,-,-,-,"
          f"ttft_p50={ttft['p50'] * 1e3:.1f}ms ttft_p95={ttft['p95'] * 1e3:.1f}ms "
          f"itl_p50={itl['p50'] * 1e3:.1f}ms itl_p95={itl['p95'] * 1e3:.1f}ms "
          f"(wall clock, n={ttft['count']} requests)")
    trace_path = paged.telemetry.export_chrome_trace(RESULTS / "serving_trace.json")
    tdata = json.loads(trace_path.read_text())  # the CI gate: trace parses
    assert tdata.get("traceEvents"), "Perfetto trace has no events"
    emit("serving_trace_artifact", 0.0,
         f"{trace_path.name}: {len(tdata['traceEvents'])} trace events "
         f"(open at ui.perfetto.dev)")

    # ---- shared-system-prompt phase: prefix sharing on vs off -------------
    block_size = 16
    prefix_blocks = 4
    n_shared_req = 6 if smoke else 16
    tail_range = (8, 32) if smoke else (8, 96)
    shared_trace, shared_flags = make_shared_trace(
        cfg.vocab_size, prefix_blocks * block_size, n_shared_req, tail_range)
    shared_cache_len = max(len(t.prompt) for t in shared_trace) + 13 + block_size
    mk_shared = lambda pc: ServingEngine(
        model, qparams,
        ServeConfig.from_spec(spec, cache_len=shared_cache_len,
                              block_size=block_size, prefill_chunk=64,
                              prefix_cache=pc),
        batch_slots=SLOTS)
    on = mk_shared(True)
    got_on, dt_on = run_shared_prefix(on, shared_trace)
    off = mk_shared(False)
    got_off, dt_off = run_shared_prefix(off, shared_trace)
    assert got_on == got_off, "prefix sharing changed greedy outputs"
    st_on, st_off = on.stats, off.stats
    total_prompt = sum(len(t.prompt) for t in shared_trace)
    followers = sum(shared_flags) - 1  # every sharer after the leader hits
    expected_skip = followers * prefix_blocks * block_size
    assert st_on["prefix_hit_tokens"] >= expected_skip > 0, (
        f"prefix hits {st_on['prefix_hit_tokens']} < expected {expected_skip}"
    )
    # acceptance: prefill compute drops by >= the shared full-block fraction
    assert st_on["prefill_tokens"] <= st_off["prefill_tokens"] - expected_skip, (
        f"prefill computed {st_on['prefill_tokens']} vs {st_off['prefill_tokens']}"
        f" without sharing: expected a reduction of >= {expected_skip}"
    )
    print(f"prefix,{sum(t.budget for t in shared_trace) / dt_on:.1f},-,-,"
          f"prefix_hit_tokens={st_on['prefix_hit_tokens']} "
          f"prefill_skipped={st_on['prefill_skipped']} "
          f"prefill_tokens={st_on['prefill_tokens']} (off={st_off['prefill_tokens']}) "
          f"cow_copies={st_on['cow_copies']} "
          f"prefix_evictions={st_on['prefix_evictions']}")
    emit("serving_prefix_hit_tokens", 0.0,
         f"{st_on['prefix_hit_tokens']} tokens aliased / {st_on['prefill_skipped']} "
         f"prefill skipped of {total_prompt} prompt tokens "
         f"({followers}/{n_shared_req - 1} followers shared {prefix_blocks} blocks)")

    emit("serving_paged_vs_ring_tokens_s", 0.0,
         f"speedup={paged_tps / ring_tps:.2f}x (paged {paged_tps:.1f} vs ring {ring_tps:.1f} tok/s)")
    # the value rides the generic us_per_call field but the name's unit wins:
    # seconds (this used to multiply by 1e6, recording microseconds as _s)
    emit("serving_paged_p95_latency_s", p95q, f"ring_p95={p95:.2f}s")
    emit("serving_mixed_step_share", 0.0,
         f"{st['mixed_steps']}/{st['packed_steps']} packed steps served prefill+decode together")
    bench_cfg = {"smoke": smoke, "n_requests": n_req, "slots": SLOTS,
                 "prompt_range": list(prompt_range), "cache_len": cache_len,
                 "token_budget": paged.scheduler.token_budget,
                 "w_bits": spec.base.w_bits, "a_bits": spec.base.a_bits,
                 "kv_bits": spec.kv_bits, "served_from_artifact": True}
    record("serving_ring", tokens_s=round(ring_tps, 1), p50_s=round(p50, 3),
           p95_s=round(p95, 3), config=bench_cfg)
    record("serving_paged", tokens_s=round(paged_tps, 1), p50_s=round(p50q, 3),
           p95_s=round(p95q, 3), speedup=round(paged_tps / ring_tps, 2),
           mixed_steps=st["mixed_steps"], packed_steps=st["packed_steps"],
           preemptions=st["preemptions"],
           peak_occupancy=round(st["peak_occupancy"], 3),
           budget_util=round(st["packed_tokens"] / (steps * budget), 3),
           config=bench_cfg)
    record("serving_latency_engine",  # wall-clock, from the engine telemetry
           ttft_p50_s=round(ttft["p50"], 4), ttft_p95_s=round(ttft["p95"], 4),
           ttft_p99_s=round(ttft["p99"], 4),
           itl_p50_s=round(itl["p50"], 5), itl_p95_s=round(itl["p95"], 5),
           itl_p99_s=round(itl["p99"], 5),
           e2e_p95_s=round(snap["requests"]["e2e_s"].get("p95", 0.0), 4),
           queue_wait_p95_s=round(
               snap["requests"]["queue_wait_s"].get("p95", 0.0), 4),
           n_requests=ttft["count"], trace_events=len(tdata["traceEvents"]),
           trace_file=trace_path.name, config=bench_cfg)
    record("serving_prefix",
           prefix_hit_tokens=st_on["prefix_hit_tokens"],
           prefill_skipped=st_on["prefill_skipped"],
           prefill_tokens=st_on["prefill_tokens"],
           prefill_tokens_no_sharing=st_off["prefill_tokens"],
           total_prompt_tokens=total_prompt,
           prefix_hits=st_on["prefix_hits"], cow_copies=st_on["cow_copies"],
           prefix_evictions=st_on["prefix_evictions"],
           shared_requests=sum(shared_flags), n_requests=n_shared_req,
           elapsed_on_s=round(dt_on, 2), elapsed_off_s=round(dt_off, 2),
           config={"smoke": smoke, "prefix_blocks": prefix_blocks,
                   "block_size": block_size, "tail_range": list(tail_range),
                   "slots": SLOTS, "token_identical_vs_off": True})
    # Wall-clock assertions only on the full trace: the 8-request --smoke run
    # on a shared CI box is timing-noise territory (the smoke still gates
    # functional regressions by running the whole path; the deterministic
    # mixed-step property is covered by tests/test_serving.py).
    if not smoke:
        assert paged_tps > ring_tps, (
            f"continuous batching must beat slot-chunked serving on mixed-length "
            f"traffic: paged {paged_tps:.1f} <= ring {ring_tps:.1f} tok/s"
        )
        # the tentpole property: admissions overlap decode inside one jitted
        # step (the PR-1 scheduler serialized every prefill chunk at batch=1)
        assert st["mixed_steps"] > 0, "no packed step mixed prefill with decode"

    run_overhead_phase(model, qparams, spec, cache_len, smoke)
    run_kernel_route_phase(model, qparams, spec, smoke)
    run_speculative_phase(smoke)
    run_outlier_phase(smoke)
    run_heterogeneous_phase(smoke)
    run_quality_phase(smoke)


def run_heterogeneous_phase(smoke: bool) -> None:
    """Per-layer cache policies under traffic: the SAME decode-heavy trace
    served by the SWA stack (``windowed_paged`` policies — out-of-window
    blocks freed as decode advances) and by the same weights with the
    window lifted to full attention (``paged_kv`` policies — history
    pinned). Records decode tokens/s and peak live blocks per sequence for
    both. The block-release cap is asserted (it is the memory headline and
    deterministic); throughput is recorded, not asserted — CPU smoke wall
    time is noise."""
    from repro.serving.paged_cache import windowed_block_cap

    cfg = get_smoke_config("h2o_danube_1_8b")
    model = build(cfg)
    params = model.init(jax.random.PRNGKey(0))
    # same weights, window lifted: sliding_window=0 flips every layer's
    # policy from windowed_paged to paged_kv (outputs differ — full
    # attention sees more history; this phase compares resources, not
    # tokens)
    full_model = build(dataclasses.replace(cfg, sliding_window=0))

    n_req = 6 if smoke else 16
    budget = (24, 33) if smoke else (48, 81)
    rng = np.random.RandomState(11)
    # short prompts + budgets well past the window: steady-state decode is
    # where windowed release pays
    traces = [Trace(list(rng.randint(1, cfg.vocab_size, rng.randint(6, 13))),
                    int(rng.randint(*budget)), float(t))
              for t in np.cumsum(rng.exponential(0.03, n_req))]
    bs = 16
    cache_len = 16 + budget[1] + bs
    mk = lambda m: ServingEngine(
        m, params,
        ServeConfig(cache_len=cache_len, cache_dtype="float32",
                    quantized=False, paged=True, block_size=bs,
                    prefill_chunk=16),
        batch_slots=4)
    swa, full = mk(model), mk(full_model)
    warm = [t.prompt for t in traces[:2]]
    swa.generate(warm, max_new_tokens=2)
    full.generate(warm, max_new_tokens=2)
    for eng in (swa, full):
        eng.telemetry.reset()

    swa_tps, _, _ = run_paged(swa, traces)
    full_tps, _, _ = run_paged(full, traces)
    cap = windowed_block_cap(cfg.sliding_window, bs)
    swa_peak = swa.stats["peak_live_blocks_per_seq"]
    full_peak = full.stats["peak_live_blocks_per_seq"]
    assert swa_peak <= cap, (
        f"windowed release broke its cap: {swa_peak} > {cap}"
    )
    assert full_peak > cap, (
        "full attention pinned fewer blocks than the windowed cap — the "
        "trace never decoded past the window, phase measures nothing"
    )
    print(f"swa_on,{swa_tps:.1f},-,-,peak_live_blocks={swa_peak} cap={cap}")
    print(f"swa_as_full,{full_tps:.1f},-,-,peak_live_blocks={full_peak}")
    emit("serving_heterogeneous_tokens_s", 0.0,
         f"SWA {swa_tps:.1f} vs full-attn {full_tps:.1f} tok/s; peak live "
         f"blocks/seq {swa_peak} (cap {cap}) vs {full_peak}")
    record("serving_heterogeneous",
           swa_tokens_s=round(swa_tps, 1),
           full_attn_tokens_s=round(full_tps, 1),
           swa_peak_live_blocks_per_seq=swa_peak,
           full_attn_peak_live_blocks_per_seq=full_peak,
           windowed_block_cap=cap,
           config={"smoke": smoke, "arch": "h2o_danube_1_8b",
                   "sliding_window": cfg.sliding_window, "block_size": bs,
                   "n_requests": n_req, "budget_range": list(budget),
                   "slots": 4, "cache_len": cache_len})


def run_kernel_route_phase(model, qparams, spec, smoke: bool) -> None:
    """Serve one trace through both GEMM routes: ``kernel=pallas`` (fused
    Pallas quantize+index-GEMM) vs ``kernel=jnp`` (factorized form).

    The CI gate (runs in --smoke too): outputs are token-identical — index
    selection is bit-equal across routes — and the pallas engine's stats
    prove the kernel path actually compiled in (``lut_kernel_calls > 0``,
    zero fallbacks). Wall time is recorded, not asserted: off-TPU the
    kernel runs in interpret mode and loses to XLA by design (kernel=auto
    picks jnp on CPU for exactly that reason)."""
    import repro.core.kernel_routing as kr
    from repro.core.qlinear import with_kernel_route

    cfg = get_smoke_config("llama3_2_1b")
    trace = make_trace(cfg.vocab_size, seed=11, n_requests=3 if smoke else 8,
                       prompt_range=(8, 32))
    cache_len = 32 + BUDGET_RANGE[1] + 16
    outs, times, calls = {}, {}, {}
    for route in ("jnp", "pallas"):
        eng = ServingEngine(
            model, with_kernel_route(qparams, route),
            ServeConfig.from_spec(spec, cache_len=cache_len, block_size=16,
                                  prefill_chunk=32),
            batch_slots=SLOTS)
        before = kr.snapshot()
        t0 = time.perf_counter()
        for t in trace:
            eng.scheduler.submit(t.prompt, t.budget)
        outs[route] = eng.scheduler.run()
        times[route] = time.perf_counter() - t0
        calls[route] = kr.kernel_calls() - before.get("_kernel_calls", 0)
        st = eng.stats
    assert outs["pallas"] == outs["jnp"], \
        "kernel routing changed greedy outputs"
    assert calls["pallas"] > 0, \
        "kernel=pallas served without routing any projection to the kernel"
    assert calls["jnp"] == 0, "kernel=jnp route leaked onto the Pallas kernel"
    assert st["lut_kernel_calls"] > 0 and st["lut_fallbacks"] == 0, st
    print(f"kernel_route,-,-,-,pallas={times['pallas']:.2f}s "
          f"jnp={times['jnp']:.2f}s kernel_dispatches={calls['pallas']} "
          f"token_identical=True (interpret={jax.default_backend() != 'tpu'})")
    emit("serving_kernel_route", 0.0,
         f"pallas route token-identical to jnp; {calls['pallas']} projections "
         f"routed to the fused kernel, 0 fallbacks")
    record("serving_kernel_route",
           wall_s_pallas=round(times["pallas"], 2),
           wall_s_jnp=round(times["jnp"], 2),
           kernel_dispatches=calls["pallas"],
           fallbacks=st["lut_fallbacks"],
           token_identical=True,
           interpret=jax.default_backend() != "tpu",
           config={"smoke": smoke, "n_requests": len(trace), "slots": SLOTS})


def run_overhead_phase(model, qparams, spec, cache_len: int, smoke: bool) -> None:
    """telemetry="off" vs the histograms-on default on one small trace.

    Telemetry never wraps traced code (identical jaxpr — asserted in
    tests/test_telemetry.py), so any delta is pure host-side bookkeeping
    (~10 us/step measured in isolation). A single cold pass per level is
    dominated by whichever engine runs first paying the process-global
    dispatch-cache warmup, so the trace is replayed interleaved and each
    level keeps its best pass. Reported, not asserted: wall-time deltas on
    a shared CI box sit inside scheduler-loop noise (the < 2% claim is
    checked on the recorded numbers across runs)."""
    cfg = get_smoke_config("llama3_2_1b")
    trace = make_trace(cfg.vocab_size, seed=3, n_requests=4 if smoke else 12,
                       prompt_range=(8, 64))
    engines = {}
    for level in ("metrics", "off"):
        engines[level] = ServingEngine(
            model, qparams,
            ServeConfig.from_spec(spec, cache_len=cache_len, block_size=16,
                                  prefill_chunk=64, telemetry=level),
            batch_slots=SLOTS)
        engines[level].generate([[1, 2, 3]] * SLOTS, max_new_tokens=2)  # jit
    times = {"metrics": [], "off": []}
    for _rep in range(3):
        for level, eng in engines.items():
            eng.telemetry.reset()
            t0 = time.perf_counter()
            for t in trace:
                eng.scheduler.submit(t.prompt, t.budget)
            eng.scheduler.run()
            times[level].append(time.perf_counter() - t0)
    assert engines["off"].stats["packed_steps"] == 0, \
        "telemetry=off must read all-zero legacy stats"
    times = {level: min(ts) for level, ts in times.items()}
    overhead = (times["metrics"] - times["off"]) / times["off"]
    print(f"tel_overhead,-,-,-,metrics={times['metrics']:.3f}s "
          f"off={times['off']:.3f}s overhead={overhead * 100:+.1f}%")
    record("serving_telemetry_overhead",
           wall_s_metrics=round(times["metrics"], 4),
           wall_s_off=round(times["off"], 4),
           overhead_pct=round(overhead * 100, 2),
           config={"smoke": smoke, "n_requests": len(trace), "slots": SLOTS})


def run_speculative_phase(smoke: bool) -> None:
    """Decode-heavy Poisson traffic, speculation off vs on (W3 draft).

    Runs on the TRAINED byte-LM (benchmarks.common.trained_lm): greedy
    verification is token-identical no matter the draft, but the acceptance
    rate — what turns verification into throughput — needs a model whose
    argmaxes are structured, which a random-init model's are not.
    """
    from benchmarks.common import trained_lm
    from repro.serving.speculative import DEFAULT_DRAFT_SPEC, SpeculativeConfig

    cfg, model, params, corpus = trained_lm(300 if smoke else 800)
    tspec = QuantSpec(base=QLinearConfig(detection="none"), kv_dtype="float32")
    qparams = quantize_model(model, params, tspec)
    # the draft: W3 weights per the shipped policy; fp32 draft KV here — the
    # int4-KV default trades CPU quantize/dequant time for HBM bytes, the
    # right trade on TPU but not on a CPU smoke box
    draft_spec = dataclasses.replace(DEFAULT_DRAFT_SPEC,
                                     kv_bits=None, kv_dtype="float32")
    spec_k = 2
    n_req = 6 if smoke else 16
    # decode-heavy by construction: short prompts, long generations (the
    # full trace doubly so — speculation is a steady-state decode property,
    # and admission-time draft catch-up amortizes over the budget)
    budget_range = (16, 32) if smoke else (48, 96)
    rng = np.random.RandomState(7)
    crops = rng.randint(0, len(corpus.tokens) - 24, n_req)
    traces = [Trace(list(map(int, corpus.tokens[c : c + int(rng.randint(8, 20))])),
                    int(rng.randint(*budget_range)),
                    float(t))
              for c, t in zip(crops, np.cumsum(rng.exponential(0.03, n_req)))]
    cache_len = 24 + budget_range[1] + 16

    with tempfile.TemporaryDirectory() as d:
        save_quantized(d, cfg, draft_spec,
                       quantize_model(model, params, draft_spec))
        mk = lambda sp: ServingEngine(
            model, qparams,
            ServeConfig.from_spec(tspec, cache_len=cache_len, block_size=16,
                                  prefill_chunk=32, speculative=sp),
            batch_slots=SLOTS)
        base = mk(None)
        specd = mk(SpeculativeConfig(k=spec_k, draft_artifact=d,
                                     draft_token_budget=16))
    warm = [t.prompt for t in traces[:2]]
    base.generate(warm, max_new_tokens=2)
    specd.generate(warm, max_new_tokens=2)
    for eng in (base, specd):
        eng.telemetry.reset()
    specd.scheduler.draft.steps = 0

    base_tps, _, base_out = run_paged(base, traces)
    spec_tps, _, spec_out = run_paged(specd, traces)
    assert spec_out == base_out, \
        "speculative greedy output diverged from the non-speculative baseline"
    st = specd.stats
    assert st["accepted_tokens"] > 0, "no drafted token was ever accepted"
    assert st["drafted_tokens"] == \
        st["accepted_tokens"] + st["rolled_back_tokens"]
    print(f"spec_off,{base_tps:.1f},-,-,packed_steps={base.stats['packed_steps']}")
    print(f"spec_on,{spec_tps:.1f},-,-,"
          f"speedup={spec_tps / base_tps:.2f}x k={spec_k} "
          f"acceptance={st['acceptance_rate']:.2f} "
          f"drafted={st['drafted_tokens']} accepted={st['accepted_tokens']} "
          f"rolled_back={st['rolled_back_tokens']} "
          f"packed_steps={st['packed_steps']} draft_steps={st['draft_steps']}")
    emit("serving_speculative_tokens_s", 0.0,
         f"speedup={spec_tps / base_tps:.2f}x (spec {spec_tps:.1f} vs "
         f"baseline {base_tps:.1f} tok/s) acceptance={st['acceptance_rate']:.2f}")
    record("serving_speculative",
           tokens_s=round(spec_tps, 1), baseline_tokens_s=round(base_tps, 1),
           speedup=round(spec_tps / base_tps, 2),
           acceptance_rate=round(st["acceptance_rate"], 3),
           drafted_tokens=st["drafted_tokens"],
           accepted_tokens=st["accepted_tokens"],
           rolled_back_tokens=st["rolled_back_tokens"],
           spec_rounds=st["spec_rounds"], draft_steps=st["draft_steps"],
           packed_steps=st["packed_steps"],
           packed_steps_baseline=base.stats["packed_steps"],
           token_identical_vs_baseline=True,
           config={"smoke": smoke, "k": spec_k, "n_requests": n_req,
                   "budget_range": list(budget_range), "slots": SLOTS,
                   "draft_w_bits": draft_spec.base.w_bits,
                   "draft_kv_bits": draft_spec.kv_bits,
                   "served_draft_from_artifact": True})
    # the speculative win is a steady-state decode property; the tiny smoke
    # trace is dominated by admissions + timing noise on shared CI boxes
    if not smoke:
        assert spec_tps > base_tps, (
            f"speculative decoding must beat the non-speculative baseline on "
            f"decode-heavy traffic: {spec_tps:.1f} <= {base_tps:.1f} tok/s "
            f"(acceptance {st['acceptance_rate']:.2f})"
        )


def run_outlier_phase(smoke: bool) -> None:
    """Orizuru online outlier engine on the serving path (ROADMAP item 4).

    Three measurements on the TRAINED byte-LM:

    1. **CE table** — detection none/static/dynamic at A4 plus static/dynamic
       at the A3 tier (static thresholds calibrated from captured
       activations). Asserts the paper's accuracy ordering: dynamic <= none
       at A4 (outlier compensation helps) and A3+dynamic STRICTLY better
       than A3+static — online detection is what makes the 8-entry codebook
       usable (the acceptance criterion).
    2. **Decode tokens/s** — one decode-heavy trace served under each
       detection mode (recorded, not asserted: CPU wall time, and off-TPU
       the Orizuru kernel runs in interpret mode).
    3. **Route identity + counters** — an A3+dynamic engine (target AND
       draft) serves a shared-prefix speculative trace under
       ``detect_kernel=jnp`` vs ``pallas``: greedy tokens must be identical,
       the Orizuru kernel must actually dispatch on the serving hot path
       (``detect_kernel_calls`` delta > 0), and the engine's outlier gauges
       must show detections with ZERO fallbacks — the --smoke CI gates.
    """
    import repro.core.kernel_routing as kr
    from benchmarks.common import capture_activations, eval_ce, trained_lm
    from repro.core.qlinear import with_detect_route
    from repro.serving.speculative import DEFAULT_DRAFT_SPEC, SpeculativeConfig

    cfg, model, params, corpus = trained_lm(300 if smoke else 800)
    calib = capture_activations(model, params, corpus)
    # the paper's per-side budget: d_model=128 -> k=1 per side. The tiny
    # budget is WHERE dynamic detection earns its keep — with one channel
    # per side, picking each token's true extreme (vs a global calibration
    # quantile that leaves mild tokens uncompensated) is the whole game;
    # at generous budgets both modes cover the important channels and the
    # ordering washes out (measured on the trained byte-LM).
    frac = 0.005
    ce_batches = 2 if smoke else 4

    # ---- 1. CE across detection mode x activation tier ---------------------
    combos = {
        "a4_none": QLinearConfig(detection="none"),
        "a4_static": QLinearConfig(detection="static", outlier_frac=frac),
        "a4_dynamic": QLinearConfig(detection="dynamic", outlier_frac=frac),
        "a3_static": QLinearConfig(a_bits=3, detection="static",
                                   outlier_frac=frac),
        "a3_dynamic": QLinearConfig(a_bits=3, detection="dynamic",
                                    outlier_frac=frac),
    }
    ce = {name: eval_ce(model, params, corpus, qc, batches=ce_batches,
                        calib=calib)
          for name, qc in combos.items()}
    for name, v in ce.items():
        print(f"outlier_ce,{name},-,-,ce={v:.4f}")
    assert ce["a4_dynamic"] <= ce["a4_none"] + 1e-6, (
        f"dynamic outlier compensation must not hurt A4 CE: "
        f"{ce['a4_dynamic']:.4f} vs none {ce['a4_none']:.4f}")
    assert ce["a3_dynamic"] < ce["a3_static"], (
        f"A3+dynamic must be strictly better than A3+static on the trained "
        f"LM: {ce['a3_dynamic']:.4f} vs {ce['a3_static']:.4f}")

    # ---- 2. decode tokens/s per detection mode -----------------------------
    n_req = 4 if smoke else 10
    budget_range = (8, 16) if smoke else (24, 48)
    rng = np.random.RandomState(17)
    crops = rng.randint(0, len(corpus.tokens) - 24, n_req)
    traces = [Trace(list(map(int, corpus.tokens[c:c + int(rng.randint(8, 20))])),
                    int(rng.randint(*budget_range)), float(t))
              for c, t in zip(crops, np.cumsum(rng.exponential(0.03, n_req)))]
    cache_len = 24 + budget_range[1] + 16
    tps = {}
    for name in ("a4_none", "a4_static", "a4_dynamic", "a3_dynamic"):
        mspec = QuantSpec(base=combos[name], kv_dtype="float32")
        qp = quantize_model(model, params, mspec, calib=calib)
        eng = ServingEngine(model, qp,
                            ServeConfig.from_spec(mspec, cache_len=cache_len,
                                                  block_size=16,
                                                  prefill_chunk=32),
                            batch_slots=SLOTS)
        eng.generate([traces[0].prompt], max_new_tokens=2)  # warm the jit
        tps[name], _, _ = run_paged(eng, traces)
        print(f"outlier_tps,{name},-,-,tokens_s={tps[name]:.1f}")

    # ---- 3. detect-route identity under prefix sharing + speculation -------
    ospec = QuantSpec(base=combos["a3_dynamic"], kv_dtype="float32")
    oqp = quantize_model(model, params, ospec, calib=calib)
    draft_spec = dataclasses.replace(DEFAULT_DRAFT_SPEC,
                                     kv_bits=None, kv_dtype="float32")
    dqp = quantize_model(model, params, draft_spec, calib=calib)
    block_size = 16
    prefix = list(map(int, corpus.tokens[100:100 + 2 * block_size]))
    n_shared = 4 if smoke else 8
    otrace = []
    for i in range(n_shared):
        c = int(rng.randint(0, len(corpus.tokens) - 24))
        tail = list(map(int, corpus.tokens[c:c + int(rng.randint(6, 14))]))
        otrace.append(Trace(prefix + tail, int(rng.randint(8, 13)), 0.0))
    ocache_len = max(len(t.prompt) for t in otrace) + 13 + block_size
    outs, dts, kcalls = {}, {}, {}
    st = None
    for route in ("jnp", "pallas"):
        eng = ServingEngine(
            model, with_detect_route(oqp, route),
            ServeConfig.from_spec(ospec, cache_len=ocache_len,
                                  block_size=block_size, prefill_chunk=32,
                                  prefix_cache=True,
                                  speculative=SpeculativeConfig(
                                      k=2, draft_token_budget=16)),
            batch_slots=SLOTS,
            draft=(model, with_detect_route(dqp, route), draft_spec))
        before = kr.snapshot()
        outs[route], dts[route] = run_shared_prefix(eng, otrace)
        kcalls[route] = (kr.detect_kernel_calls()
                         - before.get("_detect_kernel_calls", 0))
        st = eng.stats
    assert outs["pallas"] == outs["jnp"], \
        "detection routing changed greedy serving outputs"
    assert kcalls["pallas"] > 0, (
        "detect_kernel=pallas served without dispatching the Orizuru kernel")
    assert kcalls["jnp"] == 0, \
        "detect_kernel=jnp route leaked onto the Orizuru kernel"
    # the --smoke CI gates: detection live on the hot path, zero fallbacks
    assert st["outlier_detect_calls"] > 0 and st["outlier_fallbacks"] == 0, st
    assert st["outlier_comp_gather"] + st["outlier_comp_scatter"] > 0, st
    assert st["prefix_hit_tokens"] > 0, "prefix sharing was not exercised"
    assert st["accepted_tokens"] > 0, "speculation was not exercised"
    print(f"outlier_route,-,-,-,pallas={dts['pallas']:.2f}s "
          f"jnp={dts['jnp']:.2f}s orizuru_dispatches={kcalls['pallas']} "
          f"detect_calls={st['outlier_detect_calls']} "
          f"fallbacks={st['outlier_fallbacks']} "
          f"comp_gather={st['outlier_comp_gather']} "
          f"comp_scatter={st['outlier_comp_scatter']} "
          f"token_identical=True (interpret={jax.default_backend() != 'tpu'})")
    emit("serving_outlier_ce_a3", 0.0,
         f"A3 dynamic {ce['a3_dynamic']:.4f} < static {ce['a3_static']:.4f} "
         f"(A4 none {ce['a4_none']:.4f}, dynamic {ce['a4_dynamic']:.4f})")
    emit("serving_outlier_route", 0.0,
         f"Orizuru route token-identical to lax.top_k; {kcalls['pallas']} "
         f"detections dispatched to the kernel, 0 fallbacks "
         f"(prefix sharing + speculation on, A3 target+draft)")
    record("serving_outlier",
           ce={k: round(v, 4) for k, v in ce.items()},
           tokens_s={k: round(v, 1) for k, v in tps.items()},
           orizuru_dispatches=kcalls["pallas"],
           outlier_detect_calls=st["outlier_detect_calls"],
           outlier_kernel_calls=st["outlier_kernel_calls"],
           outlier_jnp_calls=st["outlier_jnp_calls"],
           outlier_fallbacks=st["outlier_fallbacks"],
           comp_gather=st["outlier_comp_gather"],
           comp_scatter=st["outlier_comp_scatter"],
           token_identical=True,
           a3_dynamic_beats_a3_static=True,
           config={"smoke": smoke, "outlier_frac": frac,
                   "ce_batches": ce_batches, "n_requests": n_req,
                   "route_trace_requests": n_shared, "slots": SLOTS,
                   "prefix_sharing": True, "speculative_k": 2,
                   "a3_bits": 3, "detect_routes": ["jnp", "pallas"]})


def run_quality_phase(smoke: bool) -> None:
    """Quantization-numerics observability on the serving path (this PR).

    Four measurements on the TRAINED byte-LM, quantized W4/A4 + dynamic
    Orizuru outliers (so every probe family has something to measure):

    1. **Overhead** — the same decode trace served at telemetry="metrics"
       vs "quality" at the DEFAULT 1/16 probe sampling, interleaved, 3 reps,
       best pass each. Asserted: quality <= metrics * 1.10 + 50 ms — the
       probes ride a separately-jitted sampled step, so the budget is one
       extra (unrolled) forward every 16 steps plus host-side ingestion.
    2. **Gauge population** (the --smoke CI gates) — a probe-every-step
       engine (sample_every=1, shadow_every=4, calibration stats captured
       from the model itself) must populate per-site codebook-utilization /
       SQNR / outlier-energy-captured / drift gauges and record >= 1
       shadow-reference logit-KL observation.
    3. **Induced drift** — the SAME traffic served against calibration
       stats scale-shifted 3x (live activations then sit ~3x off the
       recorded distribution): the drift gauge must move past the control
       engine and the 0.5 alarm threshold, and the alarm counter must fire.
    4. **Token identity** — the drifted quality engine (probing EVERY step,
       i.e. maximal exposure of the unrolled probed path) must produce
       greedy tokens identical to a telemetry="off" engine: observation
       never perturbs serving numerics.
    """
    from benchmarks.common import capture_activations, trained_lm
    from repro.core import numerics as nx
    from repro.serving.telemetry import TelemetryConfig

    cfg, model, params, corpus = trained_lm(300 if smoke else 800)
    spec = QuantSpec(base=QLinearConfig(detection="dynamic", outlier_frac=0.005),
                     kv_dtype="float32")
    calib = capture_activations(model, params, corpus)
    qparams = quantize_model(model, params, spec, calib=calib)
    calib_stats = {t: nx.activation_stats(a) for t, a in calib.items()}

    n_req = 4 if smoke else 10
    budget_range = (12, 24) if smoke else (24, 48)
    rng = np.random.RandomState(23)
    crops = rng.randint(0, len(corpus.tokens) - 24, n_req)
    traces = [Trace(list(map(int, corpus.tokens[c:c + int(rng.randint(8, 20))])),
                    int(rng.randint(*budget_range)), float(t))
              for c, t in zip(crops, np.cumsum(rng.exponential(0.03, n_req)))]
    cache_len = 24 + budget_range[1] + 16
    mk = lambda tel, **kw: ServingEngine(
        model, qparams,
        ServeConfig.from_spec(spec, cache_len=cache_len, block_size=16,
                              prefill_chunk=32, telemetry=tel),
        batch_slots=SLOTS, **kw)

    # ---- 1. overhead at the default 1/16 sampling --------------------------
    engines = {"metrics": mk("metrics"), "quality": mk("quality")}
    for eng in engines.values():  # warm: compiles the probed step too (step 0)
        eng.generate([traces[0].prompt] * 2, max_new_tokens=2)
    times = {k: [] for k in engines}
    for _rep in range(3):
        for level, eng in engines.items():
            eng.telemetry.reset()
            t0 = time.perf_counter()
            for t in traces:
                eng.scheduler.submit(t.prompt, t.budget)
            eng.scheduler.run()
            times[level].append(time.perf_counter() - t0)
    times = {k: min(v) for k, v in times.items()}
    overhead = (times["quality"] - times["metrics"]) / times["metrics"]
    # the probed step costs ~one extra forward, so 1/16 sampling amortizes
    # to <10% wherever compute dominates dispatch (accelerators / full runs).
    # CPU smoke steps are a few ms of host dispatch each, so the extra
    # UNROLLED forward's dispatch floor dominates — gate smoke loosely as a
    # regression canary (catches probe-every-step / recompile-per-step bugs)
    # and hold the 10% contract in full runs.
    limit = 2.5 if smoke else 1.10
    assert times["quality"] <= times["metrics"] * limit + 0.05, (
        f"quality probes cost too much at 1/16 sampling: "
        f"{times['quality']:.3f}s vs metrics {times['metrics']:.3f}s "
        f"({overhead * 100:+.1f}%, limit {limit:.0%})")
    print(f"quality_overhead,-,-,-,quality={times['quality']:.3f}s "
          f"metrics={times['metrics']:.3f}s overhead={overhead * 100:+.1f}% "
          f"(sample_every=16, smoke_limit={smoke})")

    # ---- 2 + 3 + 4: gauges / induced drift / token identity ----------------
    qtel = lambda: TelemetryConfig(level="quality", quality_sample_every=1,
                                   quality_shadow_every=4)
    shifted_stats = {
        t: {**st, "mean": st["mean"] / 3.0, "rms": st["rms"] / 3.0,
            "absmax_mean": st["absmax_mean"] / 3.0,
            "absmax_q50": st["absmax_q50"] / 3.0,
            "absmax_q99": st["absmax_q99"] / 3.0,
            "absmax_max": st["absmax_max"] / 3.0}
        for t, st in calib_stats.items()}
    runs = {}
    for name, tel, cs in (("off", "off", None),
                          ("control", qtel(), calib_stats),
                          ("drifted", qtel(), shifted_stats)):
        eng = mk(tel, calib_stats=cs)
        for t in traces:
            eng.scheduler.submit(t.prompt, t.budget)
        runs[name] = (eng, eng.scheduler.run())
    assert runs["drifted"][1] == runs["off"][1] == runs["control"][1], \
        "quality probes changed greedy serving outputs vs telemetry=off"

    snap = runs["control"][0].snapshot()
    g = snap["gauges"]
    util = [v for k, v in g.items()
            if k.startswith("numerics_a_codebook_util.")]
    sqnr = [v for k, v in g.items() if k.startswith("numerics_sqnr_db.")]
    oe = [v for k, v in g.items()
          if k.startswith("numerics_outlier_energy_captured.")]
    drift_g = [v for k, v in g.items() if k.startswith("numerics_drift.")]
    assert util and all(0.0 < v <= 1.0 for v in util), \
        f"codebook-utilization gauges missing/out of range ({len(util)} sites)"
    assert sqnr and max(sqnr) > 0.0, "per-site SQNR gauges not populated"
    assert oe and max(oe) > 0.0, \
        "outlier-energy-captured gauges not populated (dynamic detection on)"
    assert drift_g, "per-site drift gauges not populated"
    kl = snap["histograms"]["numerics_shadow_logit_kl"]
    assert kl["count"] >= 1, "shadow probe recorded no logit-KL observation"

    dsnap = runs["drifted"][0].snapshot()
    d_ctl = g.get("numerics_drift_max", 0.0)
    d_drift = dsnap["gauges"].get("numerics_drift_max", 0.0)
    alarms = dsnap["counters"].get("numerics_drift_alarms", 0)
    assert d_drift > max(1.0, d_ctl), (
        f"3x-shifted calibration stats must move the drift gauge: "
        f"drifted {d_drift:.2f} vs control {d_ctl:.2f}")
    assert alarms > 0, "induced drift raised no alarm"
    print(f"quality_gauges,-,-,-,sites={len(util)} "
          f"mean_util={sum(util) / len(util):.2f} "
          f"mean_sqnr={sum(sqnr) / len(sqnr):.1f}dB "
          f"outlier_energy_max={max(oe):.3f} shadow_kl_n={kl['count']} "
          f"top1={g.get('numerics_shadow_top1_agreement', -1):.2f}")
    print(f"quality_drift,-,-,-,control_max={d_ctl:.2f} "
          f"drifted_max={d_drift:.2f} alarms={alarms} token_identical=True")
    emit("serving_quality_overhead", 0.0,
         f"quality {times['quality']:.3f}s vs metrics {times['metrics']:.3f}s "
         f"({overhead * 100:+.1f}% at 1/16 sampling)")
    emit("serving_quality_drift", 0.0,
         f"induced 3x drift: gauge {d_drift:.2f} (control {d_ctl:.2f}), "
         f"{alarms} alarms, greedy tokens identical to telemetry=off")
    record("serving_quality",
           wall_s_quality=round(times["quality"], 4),
           wall_s_metrics=round(times["metrics"], 4),
           overhead_pct=round(overhead * 100, 2),
           probed_sites=len(util),
           mean_codebook_util=round(sum(util) / len(util), 4),
           mean_sqnr_db=round(sum(sqnr) / len(sqnr), 2),
           outlier_energy_max=round(max(oe), 4),
           shadow_kl_count=kl["count"],
           shadow_kl_p50=round(kl.get("p50", 0.0), 8),
           shadow_top1_agreement=g.get("numerics_shadow_top1_agreement"),
           shadow_token_agreement=g.get("numerics_shadow_token_agreement"),
           drift_max_control=round(d_ctl, 4),
           drift_max_drifted=round(d_drift, 4),
           drift_alarms_control=snap["counters"].get("numerics_drift_alarms", 0),
           drift_alarms_drifted=alarms,
           token_identical_vs_off=True,
           config={"smoke": smoke, "n_requests": n_req,
                   "budget_range": list(budget_range), "slots": SLOTS,
                   "sample_every_overhead": 16, "sample_every_gates": 1,
                   "shadow_every_gates": 4, "drift_shift": 3.0,
                   "detection": "dynamic", "outlier_frac": 0.005})


if __name__ == "__main__":
    # Standalone entry (CI smoke) writes the same BENCH json run.py would,
    # so the records + trace pointer are uploadable as workflow artifacts.
    from benchmarks import common
    from benchmarks.run import _write_result

    _t0 = time.time()
    run(smoke="--smoke" in sys.argv[1:])
    _write_result("bench_serving", True, time.time() - _t0,
                  list(common.RECORDS))
