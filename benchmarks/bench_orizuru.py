"""Orizuru engine benchmark (paper §IV-D + the 1.5N + 2k*log2N claim).

Three phases:

1. Comparison-count accounting vs the SpAtten-style 6N baseline (the
   paper's analytical claim — asserted).
2. Measured routed-kernel-vs-``lax.top_k`` wall time at decode and prefill
   shapes, with the sort-based counting oracle asserted EXACTLY on every
   shape first (interpret mode on CPU — correctness-grade timing; real
   timing is a TPU run, same as ``bench_lut_config``'s measured phase).
3. The streaming form: one-pass quantize+detect
   (``kernels/ops.quantize_outlier_streaming``) vs the two-pass
   ``quantize_activation`` + ``lax.top_k`` chain, bit-identity asserted on
   indices, scales, and outlier values.

Standalone (``python -m benchmarks.bench_orizuru``) writes
``BENCH_bench_orizuru.json`` exactly like a ``benchmarks.run`` invocation,
so CI can upload the records as an artifact.
"""

from __future__ import annotations

import time

import jax
import jax.numpy as jnp

from benchmarks.common import emit, record, timed
from repro.core import outlier as ol
from repro.core.outlier import naive_topk_comparisons, orizuru_comparisons
from repro.core.quantize import quantize_activation
from repro.kernels import ops as kops
from repro.kernels.ref import topk_outlier_ref
from repro.kernels.topk_outlier import topk_outlier_kernel_call

# (label, M, N) — decode: a packed token-budget step's worth of rows over a
# model-dim-wide activation; prefill: a chunk of rows. k is the paper's
# ~0.5%-per-side budget (floored at 1 by num_outliers).
SHAPES = (("decode", 8, 2048), ("prefill", 128, 1024))


def run() -> None:
    print("# Orizuru comparison counts — ours vs SpAtten-style 6N")
    print("N,k,orizuru,naive6N,ratio")
    for n in (1024, 4096, 12288):
        k = max(1, int(0.005 * n))
        o, s = orizuru_comparisons(n, k), naive_topk_comparisons(n)
        print(f"{n},{k},{o},{s},{s/o:.2f}")
        assert o < s
        record(f"orizuru_comparisons_n{n}", n=n, k=k, orizuru=o, naive_6n=s,
               ratio=round(s / o, 2))
    emit("orizuru_comparisons_4096", 0.0,
         f"{orizuru_comparisons(4096, 20)} vs 6N={naive_topk_comparisons(4096)}")

    # ---- measured: routed kernel vs lax.top_k, oracle asserted -------------
    interpret = jax.default_backend() != "tpu"
    print("shape,M,N,k,kernel_us,lax_top_k_us")
    for label, m, n in SHAPES:
        k = ol.num_outliers(n, 0.005)
        x = jax.random.normal(jax.random.PRNGKey(0), (m, n))
        got = topk_outlier_kernel_call(x, k)
        want = topk_outlier_ref(x, k)
        for g, w in zip(got, want):
            assert jnp.array_equal(g, w), f"{label}: kernel != counting oracle"
        us_kernel = timed(lambda a: topk_outlier_kernel_call(a, k)[0], x, reps=2)
        us_lax = timed(lambda a: jax.lax.top_k(a, k)[0], x, reps=2)
        print(f"{label},{m},{n},{k},{us_kernel:.0f},{us_lax:.0f}")
        record(f"orizuru_kernel_{label}", m=m, n=n, k=k,
               kernel_us=round(us_kernel, 1), lax_top_k_us=round(us_lax, 1),
               oracle_exact=True, interpret=interpret)
    emit("orizuru_kernel_interpret_us", us_kernel,
         f"lax_top_k_us={us_lax:.0f} ({'CPU interpret' if interpret else 'TPU'})")

    # ---- streaming: one-pass quantize+detect vs the two-pass chain ---------
    m, n, k = 8, 2048, ol.num_outliers(2048, 0.005)
    book = jnp.sort(jax.random.normal(jax.random.PRNGKey(1), (16,)))
    x = jax.random.normal(jax.random.PRNGKey(2), (m, n))
    qa, outs = kops.quantize_outlier_streaming(x, book, k)
    qa_ref = quantize_activation(x, book)
    det_ref = ol.detect_outliers_topk(x.astype(jnp.float32), k)
    assert jnp.array_equal(qa.idx, qa_ref.idx), "streaming idx != quantize_activation"
    assert jnp.array_equal(qa.scale, qa_ref.scale)
    assert jnp.array_equal(outs.values, det_ref.values)
    assert jnp.array_equal(outs.channels, det_ref.channels)
    us_stream = timed(
        lambda a: kops.quantize_outlier_streaming(a, book, k)[0].idx, x, reps=2)
    us_twopass = timed(
        lambda a: (quantize_activation(a, book).idx,
                   ol.detect_outliers_topk(a.astype(jnp.float32), k))[0],
        x, reps=2)
    print(f"streaming,{m},{n},{k},{us_stream:.0f},{us_twopass:.0f}")
    record("orizuru_streaming", m=m, n=n, k=k,
           streaming_us=round(us_stream, 1), two_pass_us=round(us_twopass, 1),
           bit_identical=True, interpret=interpret)
    emit("orizuru_streaming_us", us_stream,
         f"two_pass_us={us_twopass:.0f} bit-identical idx/scale/outliers")


if __name__ == "__main__":
    # Standalone entry writes the same BENCH json run.py would (the other
    # __main__ benches do; this one only printed before).
    from benchmarks import common
    from benchmarks.run import _write_result

    _t0 = time.time()
    run()
    _write_result("bench_orizuru", True, time.time() - _t0,
                  list(common.RECORDS))
