"""Orizuru engine benchmark (paper §IV-D + the 1.5N + 2k*log2N claim).

Comparison-count accounting vs the SpAtten-style 6N baseline, plus kernel
wall-time of the Pallas Orizuru (interpret mode — correctness-grade timing on
CPU; real timing is a TPU run) against jax.lax.top_k."""

from __future__ import annotations

import jax
import jax.numpy as jnp

from benchmarks.common import emit, timed
from repro.core.outlier import naive_topk_comparisons, orizuru_comparisons
from repro.kernels.topk_outlier import topk_outlier_kernel_call


def run() -> None:
    print("# Orizuru comparison counts — ours vs SpAtten-style 6N")
    print("N,k,orizuru,naive6N,ratio")
    for n in (1024, 4096, 12288):
        k = max(1, int(0.005 * n))
        o, s = orizuru_comparisons(n, k), naive_topk_comparisons(n)
        print(f"{n},{k},{o},{s},{s/o:.2f}")
        assert o < s
    emit("orizuru_comparisons_4096", 0.0,
         f"{orizuru_comparisons(4096, 20)} vs 6N={naive_topk_comparisons(4096)}")

    x = jax.random.normal(jax.random.PRNGKey(0), (8, 4096))
    us_kernel = timed(lambda a: topk_outlier_kernel_call(a, 20, block_m=8)[0], x, reps=2)
    us_lax = timed(lambda a: jax.lax.top_k(a, 20)[0], x, reps=2)
    emit("orizuru_kernel_interpret_us", us_kernel, f"lax_top_k_us={us_lax:.0f} (CPU interpret)")


if __name__ == "__main__":
    run()
