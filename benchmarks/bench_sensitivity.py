"""Per-layer sensitivity sweep: which projections can afford fewer bits?

The QuantSpec API makes a sensitivity study a loop: quantize the trained
byte-LM under N single-rule specs — each drops ONE projection class to a
stress bit-width while everything else stays at the W4A4 baseline — and rank
the projections by held-out CE impact. This is the measurement behind the
repo's mixed-precision defaults (W8 down-proj in bench_ppl) and behind the
**default speculative-draft spec** (`repro.serving.speculative.
DEFAULT_DRAFT_SPEC`): the draft model wants the cheapest weights that keep
its argmaxes agreeing with the target, so it takes W3 everywhere EXCEPT a
W4 guard on the most CE-sensitive projection found here.

Outputs (BENCH_bench_sensitivity.json):
  sensitivity_<proj>       CE at the stress width + delta vs the W4 baseline
  sensitivity_ranking      projections most- to least-sensitive
  draft_spec_*             candidate draft policies (all-W3, W3 + guard on
                           the top-ranked projection, the shipped default)
                           evaluated at the same held-out CE
"""

from __future__ import annotations

import math

from benchmarks.common import capture_activations, emit, eval_ce, record, trained_lm
from repro.core.qlinear import QLinearConfig
from repro.core.quantspec import QuantSpec
from repro.serving.speculative import DEFAULT_DRAFT_SPEC

# one rule per quantizable projection class of the dense family (scan-stacked
# models share one path per projection, which is exactly the granularity a
# global draft policy can act on)
PROJECTIONS = ["attn/wq", "attn/wk", "attn/wv", "attn/wo", "mlp/wi", "mlp/wd"]
STRESS_BITS = 2  # stress width for the ranking (strong, low-noise signal)
DRAFT_BITS = 3  # the draft regime the candidates are evaluated at

BASE = QLinearConfig(detection="dynamic", outlier_frac=0.005)


def run() -> None:
    cfg, model, params, corpus = trained_lm()
    calib = capture_activations(model, params, corpus)

    ce_fp = eval_ce(model, params, corpus, None)
    ce_base = eval_ce(model, params, corpus, QuantSpec(base=BASE), calib=calib)
    print(f"# per-projection sensitivity (base W4A4 ce={ce_base:.4f}, "
          f"fp ce={ce_fp:.4f})")
    print(f"projection,ce_w{STRESS_BITS},delta_vs_w4")

    deltas: dict[str, float] = {}
    for proj in PROJECTIONS:
        spec = QuantSpec(base=BASE, rules=[(proj, {"w_bits": STRESS_BITS})])
        ce = eval_ce(model, params, corpus, spec, calib=calib)
        deltas[proj] = ce - ce_base
        assert math.isfinite(ce), f"{proj} at W{STRESS_BITS} diverged"
        print(f"{proj},{ce:.4f},{deltas[proj]:+.4f}")
        record(f"sensitivity_{proj.replace('/', '_')}",
               ce=round(ce, 4), delta_vs_w4=round(deltas[proj], 4),
               stress_bits=STRESS_BITS)

    ranking = sorted(deltas, key=deltas.get, reverse=True)
    print(f"ranking (most sensitive first): {ranking}")
    record("sensitivity_ranking", ranking=ranking,
           deltas={p: round(d, 4) for p, d in deltas.items()})

    # ---- pick the draft policy: W3 base, W4 guard on the top-ranked --------
    w3_plain = QuantSpec(base=QLinearConfig(w_bits=DRAFT_BITS, a_bits=4,
                                            detection="none"))
    w3_guard = QuantSpec(base=w3_plain.base,
                         rules=[(ranking[0], {"w_bits": 4})])
    ce_plain = eval_ce(model, params, corpus, w3_plain, calib=calib)
    ce_guard = eval_ce(model, params, corpus, w3_guard, calib=calib)
    ce_shipped = eval_ce(model, params, corpus, DEFAULT_DRAFT_SPEC, calib=calib)
    print("draft_candidate,ce,ppl,delta_vs_base_w4")
    for name, ce in [("w3_plain", ce_plain), ("w3_guard", ce_guard),
                     ("shipped_default", ce_shipped)]:
        print(f"{name},{ce:.4f},{math.exp(ce):.2f},{ce - ce_base:+.4f}")
        record(f"draft_spec_{name}", ce=round(ce, 4),
               ppl=round(math.exp(ce), 2),
               delta_vs_base_w4=round(ce - ce_base, 4))
    shipped_guards = [r.pattern for r in DEFAULT_DRAFT_SPEC.rules if not r.skip]
    record("draft_spec_chosen", guard_projection=ranking[0],
           shipped_guards=shipped_guards,
           shipped_matches_ranking=ranking[0] in shipped_guards)

    # guarding the most sensitive projection must not hurt the draft, and
    # the shipped default (whose guard this sweep picked) must stay usable —
    # the draft only has to propose argmaxes
    assert ce_guard <= ce_plain + 0.05, (
        f"W4 guard on {ranking[0]} degraded the W3 draft: "
        f"{ce_guard:.4f} vs {ce_plain:.4f}"
    )
    assert math.isfinite(ce_shipped) and ce_shipped <= ce_plain + 0.10, (
        f"shipped DEFAULT_DRAFT_SPEC ce {ce_shipped:.4f} worse than the "
        f"unguarded W3 baseline {ce_plain:.4f}"
    )
    emit("sensitivity_top", 0.0,
         f"most_sensitive={ranking[0]} (+{deltas[ranking[0]]:.4f} ce at "
         f"W{STRESS_BITS}); draft w3_guard ce={ce_guard:.4f} vs w4 base "
         f"{ce_base:.4f}")


if __name__ == "__main__":
    run()
