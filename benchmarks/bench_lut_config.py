"""Paper Table I + Fig. 16: LUT sizes and reduction FLOPs, ours vs WOQ LUT-GEMM.

Two phases:

**Analytic** — the paper's formulas (Table I):
  WOQ inner-product LUT : size 2^mu * K/mu entries, reduction K/mu * n_W FLOPs/output
  Ours (Cartesian)      : size 2^(nA+nW) entries (K-independent),
                          reduction 2^(nA+nW) FLOPs/output
Checked claims (K=N=4096, W4A4): 64x LUT reduction, 1024x group size,
16x reduction-FLOPs — asserted, not just printed.

**Measured** — the index-based GEMM implementations on real arrays, per tier
(W4A4 / W3A4 / W8A4) at decode- and prefill-shaped M:

  kernel  : Pallas index-GEMM on pre-quantized indices (ops.lut_gemm)
  jnp     : the factorized jnp form (core.lut_gemm — what ``kernel=jnp`` runs)
  fused   : ONE Pallas dispatch, bucketize-in-VMEM + index-GEMM
            (ops.lut_gemm_fused — the serving hot path)
  unfused : the same work as two dispatches — bucketize kernel writes idx to
            HBM, index-GEMM kernel reads it back (what the fused kernel
            replaces)

Every variant is asserted against the counting-form oracle
(``lut_gemm_counting``) before it is timed, and the fused path must beat the
unfused two-dispatch pipeline at the decode shape (the PR's perf gate —
holds in interpret mode on CPU and on real TPUs, where the win is the
eliminated idx HBM roundtrip). The block autotune sweep runs on the decode
shape and its winning blocks are recorded.

Off-TPU these run the kernels in interpret mode, so absolute numbers are
NOT TPU-representative (the jnp row in particular wins on CPU); relative
fused-vs-unfused structure is what's asserted.
"""

from __future__ import annotations

import time

import jax
import jax.numpy as jnp
import numpy as np

from benchmarks.common import emit, record, timed
from repro.core.lut_gemm import (
    lut_gemm as lut_gemm_jnp,
    lut_gemm_counting,
    reduction_flops_counting,
    waq_lut_size,
    woq_lut_size,
)

# q_proj GEMM dims per LLaMA size (Fig. 16): K = d_model
LLAMA_DIMS = {"7B": 4096, "13B": 5120, "30B": 6656, "65B/70B": 8192}
MU = 4  # WOQ group size (FIGLUT / LUT Tensor Core setting)
N_W = N_A = 4

# measured phase: one attention-ish GEMM, small enough for interpret mode
MEAS_K, MEAS_N = 256, 128
TIERS = {"w4a4": (4, 4), "w3a4": (3, 4), "w8a4": (8, 4)}
SHAPES = {"decode": 8, "prefill": 128}


def run_analytic() -> None:
    print("# Table I / Fig 16 — LUT size (bytes) and reduction FLOPs per output column")
    print("model,K,woq_lut_B,ours_lut_B,lut_ratio,woq_red_flops,ours_red_flops,flops_ratio")
    for name, k in LLAMA_DIMS.items():
        woq_b = woq_lut_size(MU, k)
        ours_b = waq_lut_size(N_A, N_W)
        woq_fl = (k // MU) * N_W  # K/mu * n_W per output (Table I, N=1 column)
        ours_fl = 2 ** (N_A + N_W)
        print(f"{name},{k},{woq_b},{ours_b},{woq_b/ours_b:.0f},{woq_fl},{ours_fl},{woq_fl/ours_fl:.1f}")

    # --- the paper's three headline ratios at K=N=4096 -----------------------
    k = 4096
    lut_ratio = woq_lut_size(MU, k) / waq_lut_size(N_A, N_W)
    group_ratio = k / MU  # our group size = K vs mu
    flops_ratio = ((k // MU) * N_W) / (2 ** (N_A + N_W))
    assert lut_ratio == 64.0, lut_ratio
    assert group_ratio == 1024.0, group_ratio
    assert flops_ratio == 16.0, flops_ratio
    emit("table1_lut_ratio_K4096", 0.0, f"64x_claim_verified={lut_ratio:.0f}x")
    emit("table1_group_ratio_K4096", 0.0, f"1024x_claim_verified={group_ratio:.0f}x")
    emit("table1_flops_ratio_K4096", 0.0, f"16x_claim_verified={flops_ratio:.0f}x")

    # reduction-FLOPs growth with model scale (Fig. 16 trend: ours ~constant)
    growth_woq = ((8192 // MU) * N_W) / ((4096 // MU) * N_W)
    growth_ours = 1.0
    emit("fig16_flops_growth_7B_to_70B", 0.0,
         f"woq={growth_woq:.1f}x ours={growth_ours:.1f}x (K-independent LUT)")


def _unfused_pipeline(x, book, qw):
    """Bucketize kernel -> idx in HBM -> index-GEMM kernel: the two-dispatch
    pipeline the fused kernel replaces (scale handling identical)."""
    from repro.core.quantize import QuantizedActivation, token_scale
    from repro.kernels import ops

    s = token_scale(x, "rms")
    idx = ops.bucketize((x / s).astype(jnp.float32), book)
    qa = QuantizedActivation(idx=idx, scale=s, codebook=book,
                             nbits=int(book.shape[0]).bit_length() - 1)
    return ops.lut_gemm(qa, qw)


def run_measured() -> None:
    from repro.core.quantize import quantize_activation, quantize_weight
    from repro.core.quantize import fit_activation_codebook
    from repro.kernels import ops

    interp = jax.default_backend() != "tpu"
    print(f"\n# measured index-GEMM, K={MEAS_K} N={MEAS_N}"
          f" (interpret={interp}; absolute us not TPU-representative off-TPU)")
    print("tier,shape,kernel_us,jnp_us,fused_us,unfused_us,fused_speedup")
    calib = jax.random.normal(jax.random.PRNGKey(2), (64, MEAS_K))
    for tier, (wb, ab) in TIERS.items():
        qw = quantize_weight(jax.random.normal(jax.random.PRNGKey(1), (MEAS_K, MEAS_N)), wb)
        book = fit_activation_codebook(calib, ab)
        for shape, m in SHAPES.items():
            x = jax.random.normal(jax.random.PRNGKey(m), (m, MEAS_K))
            qa = quantize_activation(x, book)
            # exactness first: every timed variant vs the counting oracle
            oracle = lut_gemm_counting(qa, qw)
            np.testing.assert_allclose(ops.lut_gemm(qa, qw), oracle, rtol=1e-4, atol=1e-4)
            np.testing.assert_allclose(jax.jit(lut_gemm_jnp)(qa, qw), oracle, rtol=1e-4, atol=1e-4)
            np.testing.assert_allclose(ops.lut_gemm_fused(x, book, qw), oracle,
                                       rtol=1e-4, atol=1e-4)
            np.testing.assert_allclose(_unfused_pipeline(x, book, qw), oracle,
                                       rtol=1e-4, atol=1e-4)

            t_kernel = timed(lambda: ops.lut_gemm(qa, qw))
            t_jnp = timed(lambda: jax.jit(lut_gemm_jnp)(qa, qw))
            t_fused = timed(lambda: ops.lut_gemm_fused(x, book, qw))
            t_unfused = timed(lambda: _unfused_pipeline(x, book, qw))
            win = t_unfused / t_fused
            print(f"{tier},{shape},{t_kernel:.0f},{t_jnp:.0f},{t_fused:.0f},"
                  f"{t_unfused:.0f},{win:.2f}x")
            record("lut_gemm_measured", tier=tier, shape=shape, m=m,
                   k=MEAS_K, n=MEAS_N, kernel_us=round(t_kernel, 1),
                   jnp_us=round(t_jnp, 1), fused_us=round(t_fused, 1),
                   unfused_us=round(t_unfused, 1),
                   fused_speedup=round(win, 2), interpret=interp,
                   exact_vs_counting_oracle=True)
            if shape == "decode":
                # the fusion's reason to exist: kill the idx HBM roundtrip +
                # second dispatch on the latency-critical decode step
                assert t_fused < t_unfused, (
                    f"{tier}: fused quantize+GEMM ({t_fused:.0f}us) must beat "
                    f"the two-dispatch pipeline ({t_unfused:.0f}us) at decode")
    emit("lut_fused_vs_unfused_decode", 0.0,
         "fused single-dispatch beat bucketize+GEMM at decode for all tiers")

    # --- block autotune sweep on the decode shape ---------------------------
    qw = quantize_weight(jax.random.normal(jax.random.PRNGKey(1), (MEAS_K, MEAS_N)), 4)
    book = fit_activation_codebook(calib, 4)
    x = jax.random.normal(jax.random.PRNGKey(8), (SHAPES["decode"], MEAS_K))
    cands = ((8, 128, 256), (64, 128, 256), (128, 128, 256), (8, 128, 512))
    bm, bn, bk = ops.autotune_lut_blocks(x, book, qw, candidates=cands)
    print(f"autotune_decode,w4a4,block_m={bm},block_n={bn},block_k={bk}")
    record("lut_block_autotune", tier="w4a4", shape="decode",
           block_m=bm, block_n=bn, block_k=bk,
           candidates=[list(c) for c in cands], interpret=interp)


def run() -> None:
    run_analytic()
    run_measured()


if __name__ == "__main__":
    # Standalone entry writes the same BENCH json run.py would
    from benchmarks import common
    from benchmarks.run import _write_result

    _t0 = time.time()
    run()
    _write_result("bench_lut_config", True, time.time() - _t0,
                  list(common.RECORDS))
