"""Paper Table I + Fig. 16: LUT sizes and reduction FLOPs, ours vs WOQ LUT-GEMM.

Analytic reproduction with the paper's formulas (Table I):
  WOQ inner-product LUT : size 2^mu * K/mu entries, reduction K/mu * n_W FLOPs/output
  Ours (Cartesian)      : size 2^(nA+nW) entries (K-independent),
                          reduction 2^(nA+nW) FLOPs/output
Checked claims (K=N=4096, W4A4): 64x LUT reduction, 1024x group size,
16x reduction-FLOPs — asserted, not just printed.
"""

from __future__ import annotations

from benchmarks.common import emit
from repro.core.lut_gemm import reduction_flops_counting, waq_lut_size, woq_lut_size

# q_proj GEMM dims per LLaMA size (Fig. 16): K = d_model
LLAMA_DIMS = {"7B": 4096, "13B": 5120, "30B": 6656, "65B/70B": 8192}
MU = 4  # WOQ group size (FIGLUT / LUT Tensor Core setting)
N_W = N_A = 4


def run() -> None:
    print("# Table I / Fig 16 — LUT size (bytes) and reduction FLOPs per output column")
    print("model,K,woq_lut_B,ours_lut_B,lut_ratio,woq_red_flops,ours_red_flops,flops_ratio")
    for name, k in LLAMA_DIMS.items():
        woq_b = woq_lut_size(MU, k)
        ours_b = waq_lut_size(N_A, N_W)
        woq_fl = (k // MU) * N_W  # K/mu * n_W per output (Table I, N=1 column)
        ours_fl = 2 ** (N_A + N_W)
        print(f"{name},{k},{woq_b},{ours_b},{woq_b/ours_b:.0f},{woq_fl},{ours_fl},{woq_fl/ours_fl:.1f}")

    # --- the paper's three headline ratios at K=N=4096 -----------------------
    k = 4096
    lut_ratio = woq_lut_size(MU, k) / waq_lut_size(N_A, N_W)
    group_ratio = k / MU  # our group size = K vs mu
    flops_ratio = ((k // MU) * N_W) / (2 ** (N_A + N_W))
    assert lut_ratio == 64.0, lut_ratio
    assert group_ratio == 1024.0, group_ratio
    assert flops_ratio == 16.0, flops_ratio
    emit("table1_lut_ratio_K4096", 0.0, f"64x_claim_verified={lut_ratio:.0f}x")
    emit("table1_group_ratio_K4096", 0.0, f"1024x_claim_verified={group_ratio:.0f}x")
    emit("table1_flops_ratio_K4096", 0.0, f"16x_claim_verified={flops_ratio:.0f}x")

    # reduction-FLOPs growth with model scale (Fig. 16 trend: ours ~constant)
    growth_woq = ((8192 // MU) * N_W) / ((4096 // MU) * N_W)
    growth_ours = 1.0
    emit("fig16_flops_growth_7B_to_70B", 0.0,
         f"woq={growth_woq:.1f}x ours={growth_ours:.1f}x (K-independent LUT)")


if __name__ == "__main__":
    run()
