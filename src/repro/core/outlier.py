"""Outlier-aware quantization: detection + look-ahead error compensation (§III-C, §IV-D).

The paper keeps the top-0.5% largest and bottom-0.5% smallest activations per
token in FP16. Instead of detect-then-split (detection on the critical path,
Fig. 4(a)), the **look-ahead** scheme (Fig. 4(b)) runs two branches:

  main branch    : quantize EVERYTHING (outliers land on their nearest
                   centroid) and start the LUT-GEMM immediately;
  outlier branch : find the outliers, compute residuals r = x - q(x), and
                   compensate  Y' = r_outlier @ W~[outlier_channels, :].

Y* + Y' is mathematically identical to detect-then-split — asserted bit-level
(fp32) in tests.

TPU adaptation of Orizuru: the ASIC pops one (value, index) per cycle from a
two-fold tournament tree. On TPU we return the whole top-k/bottom-k at once
(``jax.lax.top_k`` here; the Pallas kernel in ``kernels/topk_outlier.py``
keeps the paper's shared-pairwise-comparison trick). The comparison-count
analytics (1.5N + 2k·log2 N vs 6N for SpAtten's engine) are reproduced in
``benchmarks/bench_orizuru.py``.
"""

from __future__ import annotations

import dataclasses
from functools import partial

import jax
import jax.numpy as jnp

from repro.core import codebook as cb
from repro.core.quantize import (
    QuantizedActivation,
    QuantizedWeight,
    dequantize_activation,
)

__all__ = [
    "OutlierSet",
    "num_outliers",
    "detect_outliers_topk",
    "detect_outliers_static",
    "static_thresholds",
    "outlier_residuals",
    "outlier_residuals_direct",
    "compensate_gather",
    "compensate_scatter",
    "orizuru_comparisons",
    "naive_topk_comparisons",
]


@partial(
    jax.tree_util.register_dataclass,
    data_fields=["values", "channels", "mask"],
    meta_fields=[],
)
@dataclasses.dataclass(frozen=True)
class OutlierSet:
    """Per-token outliers: FP values, channel indices, and a validity mask.

    values   : fp32 (..., T) original FP activation values
    channels : int32 (..., T) channel indices within the token
    mask     : fp32 (..., T) 1.0 where the slot holds a real outlier
               (static-threshold detection can yield < T genuine outliers;
               masked slots contribute exactly zero to compensation).
    """

    values: jax.Array
    channels: jax.Array
    mask: jax.Array


def num_outliers(k_channels: int, frac: float) -> int:
    """Outliers per side for a token of ``k_channels`` (paper: frac=0.005)."""
    return max(1, int(round(k_channels * frac)))


def detect_outliers_topk(x: jax.Array, k: int) -> OutlierSet:
    """Dynamic detection: top-k largest AND bottom-k smallest per token.

    This is the Orizuru contract: exactly k max + k min per token, determinism
    on ties inherited from ``lax.top_k`` (stable, lowest-index-first — the
    paper's left-child tie-break has the same "always exactly k" property).
    """
    hi_v, hi_i = jax.lax.top_k(x, k)
    lo_v_neg, lo_i = jax.lax.top_k(-x, k)
    values = jnp.concatenate([hi_v, -lo_v_neg], axis=-1).astype(jnp.float32)
    channels = jnp.concatenate([hi_i, lo_i], axis=-1).astype(jnp.int32)
    return OutlierSet(values=values, channels=channels, mask=jnp.ones_like(values))


def static_thresholds(calib_x: jax.Array, frac: float = 0.005) -> tuple[jax.Array, jax.Array]:
    """OASIS-S: offline thresholds from a calibration set (per layer).

    Returns scalar (lo, hi) = (frac, 1-frac) quantiles over all calibration
    activations. The paper's Fig. 3 shows these transfer poorly across
    datasets — which is exactly what the OASIS-vs-OASIS-S benchmark measures.
    """
    flat = calib_x.reshape(-1).astype(jnp.float32)
    lo = jnp.quantile(flat, frac)
    hi = jnp.quantile(flat, 1.0 - frac)
    return lo, hi


def detect_outliers_static(x: jax.Array, lo: jax.Array, hi: jax.Array, k: int) -> OutlierSet:
    """Static (OASIS-S) detection with fixed-shape output.

    Scores threshold violations, keeps the top-2k violators, masks the rest.
    (A token may have fewer than 2k violations — extra slots get mask=0 — or
    more — excess smallest violations are dropped, mirroring a fixed-budget
    outlier buffer in the ASIC.)
    """
    score = jnp.maximum(x - hi, 0.0) + jnp.maximum(lo - x, 0.0)
    sv, si = jax.lax.top_k(score, 2 * k)
    values = jnp.take_along_axis(x, si, axis=-1).astype(jnp.float32)
    return OutlierSet(
        values=values,
        channels=si.astype(jnp.int32),
        mask=(sv > 0).astype(jnp.float32),
    )


def outlier_residuals(out: OutlierSet, qa: QuantizedActivation) -> jax.Array:
    """r = x - q(x) at the outlier channels (paper's Error Calculation Unit)."""
    deq = dequantize_activation(qa)
    q_at = jnp.take_along_axis(deq, out.channels, axis=-1)
    return (out.values - q_at) * out.mask


def outlier_residuals_direct(
    out: OutlierSet, scale: jax.Array, codebook: jax.Array,
    mul_form: bool = False,
) -> jax.Array:
    """r = x - q(x) computed from the outlier VALUES alone — no full
    QuantizedActivation required.

    The fused-kernel route never materializes activation indices (they live
    only in VMEM), but quantization is elementwise, so q(x) at the 2k
    outlier channels per token can be recomputed from the gathered values
    and the per-token ``scale`` directly. Bit-identical to
    :func:`outlier_residuals` as long as the compare form matches the dtype
    ``quantize_activation`` would have used: ``mul_form=False`` for f32
    inputs (searchsorted on x/s), ``mul_form=True`` for bf16 (sum-of-
    compares against s*b_i).
    """
    v = out.values  # f32 (..., T), originals gathered at detection time
    if mul_form:
        b = cb.boundaries_from_centroids(codebook)
        idx = jnp.zeros(v.shape, jnp.int32)
        for i in range(b.shape[0]):
            idx += (v >= scale * b[i]).astype(jnp.int32)
    else:
        idx = cb.assign_via_boundaries((v / scale).astype(jnp.float32), codebook)
    deq = codebook[idx] * scale
    return (v - deq) * out.mask


def compensate_gather(
    residuals: jax.Array, out: OutlierSet, qw: QuantizedWeight, compute_dtype=jnp.float32
) -> jax.Array:
    """Y'[m, n] = Σ_t r[m, t] · W~[ch[m, t], n], via per-token weight-row gather.

    Mirrors the ASIC outlier branch: fetch one input channel of the weight
    index matrix per outlier, dequantize just those rows (Dequantization
    Unit), multiply-accumulate. Preferred when M (tokens) is small — decode.
    """
    w_idx_rows = jnp.take(qw.indices, out.channels, axis=0)  # (..., T, N)
    w_rows = (qw.codebook[w_idx_rows] * qw.scale).astype(compute_dtype)
    return jnp.einsum("...t,...tn->...n", residuals.astype(compute_dtype), w_rows)


def compensate_scatter(
    residuals: jax.Array, out: OutlierSet, qw: QuantizedWeight, compute_dtype=jnp.float32
) -> jax.Array:
    """Scatter residuals into a dense (..., K) matrix, one dense GEMM with W~.

    Preferred at prefill (large M): a dense MXU matmul at ~1% density beats
    M·T row gathers in HBM traffic once M is large. Selection logic lives in
    ``core/qlinear.py``.

    Implemented as a true scatter-add (O(M·K) memory). The obvious one-hot
    einsum is O(M·T·K) — measured 300+ GB/device at 32k prefill on
    nemotron-15b before this was rewritten.
    """
    k_channels = qw.shape[0]
    lead = residuals.shape[:-1]
    t = residuals.shape[-1]
    # Scatter with the leading (batch, seq) dims KEPT as explicit batch index
    # dims: GSPMD partitions batch-parallel scatters along sharded leading
    # dims, whereas the flattened (M, K) form was replicated per device
    # (observed ~73 GB/device of transients at 32k prefill — three concurrent
    # projections' scatter buffers, each fully replicated).
    idx = [
        jax.lax.broadcasted_iota(jnp.int32, (*lead, t), i) for i in range(len(lead))
    ]
    r_dense = jnp.zeros((*lead, k_channels), compute_dtype).at[
        (*idx, out.channels)
    ].add(residuals.astype(compute_dtype))
    w = (qw.codebook[qw.indices] * qw.scale[None, :]).astype(compute_dtype)
    return jnp.einsum("...k,kn->...n", r_dense, w)


# ---------------------------------------------------------------------------
# Orizuru comparison-count analytics (paper §IV-D)
# ---------------------------------------------------------------------------

def orizuru_comparisons(n: int, k: int) -> int:
    """1.5N + 2k·log2(N): init max tree (N-1 ≈ N), min tree reuses level-1
    comparisons (N/2 saved), each of 2k pops costs log2 N maintenance."""
    import math

    return int(1.5 * n + 2 * k * math.log2(n))


def naive_topk_comparisons(n: int) -> int:
    """SpAtten-style top-k engine baseline: ~6N comparisons."""
    return 6 * n
