"""QLinear: the paper's full dual-branch quantized linear layer.

    y = LUT-GEMM(quantize(x), Wq)            # main branch (look-ahead)
      + r_outlier @ W~[outlier_channels, :]  # outlier branch (compensation)
      + bias

This is the composable unit the model zoo uses for quantized inference. The
main branch routes per the ``kernel`` policy field (see
``repro.core.kernel_routing``): ``pallas`` runs the FUSED quantize+index-GEMM
Pallas kernel (activation indices never leave VMEM, no dequantized (K, N)
weight ever exists — W3/W4 nibble and W5-W8 byte tiers); ``jnp`` runs
quantize-then-factorized-GEMM; ``auto`` picks pallas on TPU, jnp on CPU.
Both routes are exact vs the counting-form oracle and token-identical to
each other under greedy serving (index selection is bit-equal; see
``kernels/ops.lut_gemm_fused``). Fallbacks off a requested pallas route are
explicit — counted in the dispatch registry and warned once — never silent.

The outlier branch routes independently (``detect_kernel``): dynamic (OASIS)
detection runs the Pallas Orizuru tournament kernel or ``lax.top_k``. On the
jnp GEMM route with Pallas detection the layer uses the STREAMING form —
bucketize + dual top-k in one pass over the activation tile
(``kernels/ops.quantize_outlier_streaming``) — so detection adds no extra
HBM roundtrip; on the fused GEMM route the detection-only kernel composes
via ``outlier_residuals_direct`` (q(x) recomputed at the 2k gathered
channels, indices never materialized). All four combinations are bit-
identical in their index/value selection, so greedy serving tokens match
across routes. The A3 activation tier (8-entry codebook) is legal only with
``detection != "none"`` (see ``QLinearConfig.validate``).
"""

from __future__ import annotations

import dataclasses
import math
from functools import partial
from typing import Literal

import jax
import jax.numpy as jnp

import repro.core.kernel_routing as kr
import repro.core.numerics as nx
import repro.core.outlier as ol
import repro.core.quantize as qz
from repro.core.lut_gemm import lut_gemm as _lut_gemm_jnp

__all__ = [
    "QLinearConfig",
    "QLinearParams",
    "quantize_linear",
    "qlinear_apply",
    "with_kernel_route",
    "with_detect_route",
]

Detection = Literal["dynamic", "static", "static_dense", "none"]
CompMode = Literal["auto", "gather", "scatter"]
KernelRoute = Literal["auto", "pallas", "jnp"]


@dataclasses.dataclass(frozen=True)
class QLinearConfig:
    """Static configuration of a quantized linear layer (hashable, jit-static)."""

    w_bits: int = 4
    a_bits: int = 4
    method: str = "kmeans"  # kmeans (paper) | uniform (RTN/INT-WAQ baseline)
    outlier_frac: float = 0.005  # per side; paper default 0.5% + 0.5%
    detection: Detection = "dynamic"  # OASIS='dynamic', OASIS-S='static'
    comp_mode: CompMode = "auto"
    comp_auto_tokens: int = 64  # comp_mode="auto": gather at <= this many tokens
    scale_mode: qz.ScaleMode = "rms"
    compute_dtype: object = jnp.float32
    use_kernel: bool = False  # legacy boolean opt-in; kernel="pallas" spelling
    # main-branch GEMM routing policy (kernel_routing.resolve_route):
    # auto = Pallas on TPU / jnp factorized on CPU (REPRO_LUT_KERNEL env
    # overrides the auto default, mirroring REPRO_PAGED_KERNEL)
    kernel: KernelRoute = "auto"
    # outlier-detection routing policy (kernel_routing.resolve_detect_route):
    # dynamic detection resolves to the Pallas Orizuru tournament kernel or
    # lax.top_k; independent of the GEMM route so they flip separately.
    # REPRO_TOPK_KERNEL env overrides the auto default.
    detect_kernel: KernelRoute = "auto"
    # quant-health probes (core/numerics): emitted only when a probe
    # collector is active at trace time (the `quality` telemetry level);
    # rule-addressable via QuantSpec so noisy layers can be muted.
    probe: bool = True

    def __post_init__(self):
        if self.kernel not in kr.ROUTES:
            raise ValueError(
                f"kernel must be one of {kr.ROUTES}, got {self.kernel!r}")
        if self.detect_kernel not in kr.ROUTES:
            raise ValueError(
                f"detect_kernel must be one of {kr.ROUTES}, "
                f"got {self.detect_kernel!r}")
        if not 2 <= self.w_bits <= 8:
            raise ValueError(f"w_bits must be in [2, 8], got {self.w_bits}")
        if not 3 <= self.a_bits <= 8:
            raise ValueError(f"a_bits must be in [3, 8], got {self.a_bits}")

    def validate(self) -> "QLinearConfig":
        """Cross-field legality, checked where a config is *applied* (QuantSpec
        resolution, quantize_linear, explicit qlinear_apply overrides) — not in
        ``__post_init__``, so per-rule ``dataclasses.replace`` chains may pass
        through transiently-illegal states.

        The A3 activation tier (8-entry K-Means codebook) is only legal with
        online outlier compensation: sub-4-bit codebooks have no headroom for
        the tails, so the outlier branch must carry them (KVQuant's sub-1%-
        outlier argument). The ``uniform`` (RTN/INT-WAQ) grid is exempt — it
        is the deliberate collapse baseline of the Table III analog, not the
        K-Means A3 tier.
        """
        if self.a_bits < 4 and self.detection == "none" and self.method == "kmeans":
            raise ValueError(
                f"a_bits={self.a_bits} (the A3 K-Means tier) requires online "
                "outlier compensation: set detection to 'dynamic', 'static', "
                "or 'static_dense' (A3 is only legal with detection != 'none')"
            )
        return self


@partial(
    jax.tree_util.register_dataclass,
    data_fields=["qw", "act_codebook", "bias", "thr_lo", "thr_hi"],
    meta_fields=["cfg"],
)
@dataclasses.dataclass(frozen=True)
class QLinearParams:
    """Quantized-linear parameters WITH their resolved apply-time config.

    ``cfg`` is a pytree *meta* field (static under jit): the per-layer
    :class:`QLinearConfig` a :class:`~repro.core.quantspec.QuantSpec` resolved
    for this projection. Apply-time behaviour (detection mode, outlier budget,
    kernel routing) travels with the params — there is no ambient/global
    apply config.
    """

    qw: qz.QuantizedWeight
    act_codebook: jax.Array  # fp32 (2^a_bits,) offline-learned
    bias: jax.Array | None
    thr_lo: jax.Array | None  # OASIS-S static thresholds (scalars)
    thr_hi: jax.Array | None
    cfg: QLinearConfig = QLinearConfig()


def quantize_linear(
    w: jax.Array,
    calib_acts: jax.Array,
    cfg: QLinearConfig,
    bias: jax.Array | None = None,
    fisher: jax.Array | None = None,
) -> QLinearParams:
    """PTQ a linear layer: weight K-Means + offline activation codebook.

    ``w``: (K, N). ``calib_acts``: (tokens, K) calibration activations for
    this layer (paper: 16 C4 samples). ``fisher``: optional per-element
    Fisher-information weights for weighted K-Means.
    """
    cfg.validate()
    qw = qz.quantize_weight(w, nbits=cfg.w_bits, method=cfg.method)
    book = qz.fit_activation_codebook(
        calib_acts, nbits=cfg.a_bits, fisher=fisher, scale_mode=cfg.scale_mode,
        method=cfg.method,
    )
    thr_lo = thr_hi = None
    if cfg.detection in ("static", "static_dense"):
        thr_lo, thr_hi = ol.static_thresholds(calib_acts, cfg.outlier_frac)
    return QLinearParams(qw=qw, act_codebook=book, bias=bias, thr_lo=thr_lo,
                         thr_hi=thr_hi, cfg=cfg)


def with_kernel_route(params, kernel: KernelRoute):
    """Return a copy of a (tree of) QLinearParams with the routing policy
    swapped — codebooks/indices untouched, so outputs stay comparable
    bit-for-bit across routes (tests + benchmarks flip routes this way
    instead of re-quantizing)."""
    def swap(p):
        if isinstance(p, QLinearParams):
            return dataclasses.replace(
                p, cfg=dataclasses.replace(p.cfg, kernel=kernel))
        return p

    return jax.tree_util.tree_map(
        swap, params, is_leaf=lambda p: isinstance(p, QLinearParams))


def with_detect_route(params, detect_kernel: KernelRoute):
    """Like :func:`with_kernel_route`, for the outlier-detection route: swap
    ``detect_kernel`` across a (tree of) QLinearParams without re-quantizing,
    so detection routes stay bit-comparable (the streaming/detection kernels
    are index- and value-identical to the lax.top_k path)."""
    def swap(p):
        if isinstance(p, QLinearParams):
            return dataclasses.replace(
                p, cfg=dataclasses.replace(p.cfg, detect_kernel=detect_kernel))
        return p

    return jax.tree_util.tree_map(
        swap, params, is_leaf=lambda p: isinstance(p, QLinearParams))


def _tokens(x: jax.Array) -> int:
    return math.prod(x.shape[:-1]) if x.ndim > 1 else 1


def qlinear_apply(p: QLinearParams, x: jax.Array, cfg: QLinearConfig | None = None) -> jax.Array:
    """Dual-branch forward (paper Fig. 7). Output dtype follows ``x``.

    ``cfg`` defaults to the config resolved at quantize time and stored in
    the params (``p.cfg``); pass one explicitly only to override it for an
    ablation (quantize-time artifacts — codebook size, static thresholds —
    obviously cannot be changed after the fact).
    """
    cfg = p.cfg if cfg is None else cfg.validate()
    out_dtype = x.dtype
    a_nbits = int(p.act_codebook.shape[0]).bit_length() - 1
    tier = f"w{p.qw.nbits}a{a_nbits}"
    mul_form = x.dtype == jnp.bfloat16

    route = kr.resolve_route(cfg.kernel, cfg.use_kernel)
    if route == "pallas" and a_nbits > 4:
        # the fused kernel's in-tile bucketize is a 2^a - 1 compare chain:
        # fine through A4 (15 compares), untenable for 256-entry activation
        # codebooks. EXPLICIT fallback — counted + warned, never silent.
        kr.record_fallback(tier, f"activation codebook has 2^{a_nbits} "
                                 "entries (> 16); fused bucketize supports "
                                 "a_bits <= 4")
        route = "jnp"
    kr.record_dispatch(tier, route)

    # ---- outlier detection routing (resolved BEFORE the main branch: the
    # streaming kernel fuses detection into the activation-quantize pass) ----
    detect_route = None
    k_out = 0
    if cfg.detection != "none" and cfg.outlier_frac > 0:
        k_out = ol.num_outliers(x.shape[-1], cfg.outlier_frac)
        if cfg.detection == "dynamic":
            detect_route = kr.resolve_detect_route(cfg.detect_kernel)
            kr.record_detect_dispatch(tier, detect_route)
        else:
            # static/static_dense score against offline thresholds — there is
            # no top-k tournament to run, so a requested Orizuru route is an
            # EXPLICIT demotion; auto resolves to jnp quietly.
            detect_route = "jnp"
            if cfg.detect_kernel == "pallas":
                kr.record_detect_fallback(
                    tier, f"detection={cfg.detection!r} scores against static "
                          "thresholds (no top-k tournament); only 'dynamic' "
                          "routes to the Orizuru kernel")
            else:
                kr.record_detect_dispatch(tier, "jnp")

    # ---- main branch: look-ahead LUT-GEMM over ALL activations ------------
    qa = None
    outs = None
    if route == "pallas":
        from repro.kernels import ops as kops

        # ONE fused Pallas dispatch: bucketize x in VMEM + index-GEMM.
        # Handles every weight tier (W<=4 nibble-packed, W5-8 byte-packed);
        # no QuantizedActivation and no dequantized (K, N) weight exist.
        y = kops.lut_gemm_fused(x, p.act_codebook, p.qw,
                                scale_mode=cfg.scale_mode,
                                out_dtype=cfg.compute_dtype)
    else:
        if (detect_route == "pallas" and cfg.detection == "dynamic"
                and a_nbits <= 4):
            from repro.kernels import ops as kops

            # streaming Orizuru: bucketize + dual top-k in ONE pass over the
            # activation tile — detection adds no extra HBM roundtrip. Bit-
            # identical to quantize_activation + lax.top_k (kernel contract).
            qa, outs = kops.quantize_outlier_streaming(
                x, p.act_codebook, k_out, cfg.scale_mode)
        else:
            qa = qz.quantize_activation(x, p.act_codebook, cfg.scale_mode)
        y = _lut_gemm_jnp(qa, p.qw, out_dtype=cfg.compute_dtype,
                          compute_dtype=cfg.compute_dtype)

    # ---- outlier branch: detect, residual, compensate ----------------------
    if cfg.detection == "static_dense" and cfg.outlier_frac > 0:
        # OASIS-S with dense masked compensation: zero sorts, one extra dense
        # matmul. Orizuru/lax.top_k at 32k-token prefill means a full sort per
        # projection (~12 GB/device of sort+gather workspace x concurrency —
        # EXPERIMENTS §Perf P1); thresholds are offline (paper's OASIS-S) and
        # the mask/residual chain fuses to nothing. Decode keeps the dynamic
        # Orizuru path (sorting 1 token is free; accuracy is higher).
        if qa is None:
            # kernel route: the dense residual needs q(x) at EVERY channel;
            # recompute it as the same elementwise chain (XLA fuses it into
            # the mask/where below — no idx roundtrip, main GEMM unaffected)
            qa = qz.quantize_activation(x, p.act_codebook, cfg.scale_mode)
        deq = qz.dequantize_activation(qa, dtype=cfg.compute_dtype)
        xf = x.astype(cfg.compute_dtype)
        mask = (xf > p.thr_hi) | (xf < p.thr_lo)
        r = jnp.where(mask, xf - deq, 0)
        w = (p.qw.codebook[p.qw.indices] * p.qw.scale[None, :]).astype(cfg.compute_dtype)
        y = y + jnp.einsum("...k,kn->...n", r, w)
    elif cfg.detection != "none" and cfg.outlier_frac > 0:
        if outs is None:
            if cfg.detection == "dynamic" and detect_route == "pallas":
                from repro.kernels import ops as kops

                # detection-only Orizuru kernel: the fused-GEMM main branch
                # (qa is None) composes via outlier_residuals_direct below;
                # a_bits > 4 on the jnp route lands here too (the streaming
                # form's compare chain, like fused bucketize, tops out at A4)
                outs = kops.topk_outlier(x.astype(jnp.float32), k_out)
            elif cfg.detection == "dynamic":
                outs = ol.detect_outliers_topk(x.astype(jnp.float32), k_out)
            else:
                outs = ol.detect_outliers_static(
                    x.astype(jnp.float32), p.thr_lo, p.thr_hi, k_out
                )
        if qa is None:
            # kernel route: q(x) at the 2k outlier channels, recomputed from
            # the gathered values (quantization is elementwise) — bit-equal
            # to the qa-based residual, without materializing indices
            r = ol.outlier_residuals_direct(
                outs, qz.token_scale(x, cfg.scale_mode), p.act_codebook,
                mul_form=mul_form)
        else:
            r = ol.outlier_residuals(outs, qa)
        mode = cfg.comp_mode
        if mode == "auto":
            # decode-ish (few tokens): row-gather; prefill-ish: scatter+dense GEMM
            mode = "gather" if _tokens(x) <= cfg.comp_auto_tokens else "scatter"
        kr.record_comp_route(mode)
        comp = (
            ol.compensate_gather(r, outs, p.qw, cfg.compute_dtype)
            if mode == "gather"
            else ol.compensate_scatter(r, outs, p.qw, cfg.compute_dtype)
        )
        y = y + comp

    if cfg.probe and nx.collecting():
        # quant-health probes (quality telemetry level only): pure reductions
        # on the intermediates above; `y` is never touched. Outside collect()
        # this is a no-op and the traced path is byte-identical.
        nx.probe_qlinear(
            p, x, qa=qa, outs=outs, k_out=k_out,
            dynamic=(cfg.detection == "dynamic" and cfg.outlier_frac > 0),
            scale_mode=cfg.scale_mode, tier=tier)

    if p.bias is not None:
        y = y + p.bias.astype(cfg.compute_dtype)
    return y.astype(out_dtype)
