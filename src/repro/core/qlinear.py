"""QLinear: the paper's full dual-branch quantized linear layer.

    y = LUT-GEMM(quantize(x), Wq)            # main branch (look-ahead)
      + r_outlier @ W~[outlier_channels, :]  # outlier branch (compensation)
      + bias

This is the composable unit the model zoo uses for quantized inference. The
main branch can run through the jnp factorized form or the Pallas kernel
(``repro.kernels.ops.lut_gemm``); both are exact vs the counting-form oracle.
"""

from __future__ import annotations

import dataclasses
import math
from functools import partial
from typing import Literal

import jax
import jax.numpy as jnp

import repro.core.outlier as ol
import repro.core.quantize as qz
from repro.core.lut_gemm import lut_gemm as _lut_gemm_jnp

__all__ = [
    "QLinearConfig",
    "QLinearParams",
    "quantize_linear",
    "qlinear_apply",
    "current_apply_config",
    "use_apply_config",
]

Detection = Literal["dynamic", "static", "static_dense", "none"]
CompMode = Literal["auto", "gather", "scatter"]


@dataclasses.dataclass(frozen=True)
class QLinearConfig:
    """Static configuration of a quantized linear layer (hashable, jit-static)."""

    w_bits: int = 4
    a_bits: int = 4
    method: str = "kmeans"  # kmeans (paper) | uniform (RTN/INT-WAQ baseline)
    outlier_frac: float = 0.005  # per side; paper default 0.5% + 0.5%
    detection: Detection = "dynamic"  # OASIS='dynamic', OASIS-S='static'
    comp_mode: CompMode = "auto"
    scale_mode: qz.ScaleMode = "rms"
    compute_dtype: object = jnp.float32
    use_kernel: bool = False  # route main branch through the Pallas kernel


@partial(
    jax.tree_util.register_dataclass,
    data_fields=["qw", "act_codebook", "bias", "thr_lo", "thr_hi"],
    meta_fields=[],
)
@dataclasses.dataclass(frozen=True)
class QLinearParams:
    qw: qz.QuantizedWeight
    act_codebook: jax.Array  # fp32 (2^a_bits,) offline-learned
    bias: jax.Array | None
    thr_lo: jax.Array | None  # OASIS-S static thresholds (scalars)
    thr_hi: jax.Array | None


def quantize_linear(
    w: jax.Array,
    calib_acts: jax.Array,
    cfg: QLinearConfig,
    bias: jax.Array | None = None,
    fisher: jax.Array | None = None,
) -> QLinearParams:
    """PTQ a linear layer: weight K-Means + offline activation codebook.

    ``w``: (K, N). ``calib_acts``: (tokens, K) calibration activations for
    this layer (paper: 16 C4 samples). ``fisher``: optional per-element
    Fisher-information weights for weighted K-Means.
    """
    qw = qz.quantize_weight(w, nbits=cfg.w_bits, method=cfg.method)
    book = qz.fit_activation_codebook(
        calib_acts, nbits=cfg.a_bits, fisher=fisher, scale_mode=cfg.scale_mode,
        method=cfg.method,
    )
    thr_lo = thr_hi = None
    if cfg.detection in ("static", "static_dense"):
        thr_lo, thr_hi = ol.static_thresholds(calib_acts, cfg.outlier_frac)
    return QLinearParams(qw=qw, act_codebook=book, bias=bias, thr_lo=thr_lo, thr_hi=thr_hi)


def _tokens(x: jax.Array) -> int:
    return math.prod(x.shape[:-1]) if x.ndim > 1 else 1


# Ambient apply-config: model code calls plain ``dense_apply`` on a tree that
# may hold QLinearParams; the serving engine selects the quantization behaviour
# (kernel on/off, detection mode, outlier budget) without threading a config
# through every layer. Static under jit (baked at trace time).
import contextlib
import contextvars

_APPLY_CFG: contextvars.ContextVar[QLinearConfig] = contextvars.ContextVar(
    "repro_qlinear_apply_cfg", default=QLinearConfig()
)


def current_apply_config() -> QLinearConfig:
    return _APPLY_CFG.get()


@contextlib.contextmanager
def use_apply_config(cfg: QLinearConfig):
    token = _APPLY_CFG.set(cfg)
    try:
        yield
    finally:
        _APPLY_CFG.reset(token)


def qlinear_apply(p: QLinearParams, x: jax.Array, cfg: QLinearConfig) -> jax.Array:
    """Dual-branch forward (paper Fig. 7). Output dtype follows ``x``."""
    out_dtype = x.dtype
    qa = qz.quantize_activation(x, p.act_codebook, cfg.scale_mode)

    # ---- main branch: look-ahead LUT-GEMM over ALL activations ------------
    if cfg.use_kernel:
        from repro.kernels import ops as kops

        y = kops.lut_gemm(qa, p.qw, out_dtype=cfg.compute_dtype)
    else:
        y = _lut_gemm_jnp(qa, p.qw, out_dtype=cfg.compute_dtype,
                          compute_dtype=cfg.compute_dtype)

    # ---- outlier branch: detect, residual, compensate ----------------------
    if cfg.detection == "static_dense" and cfg.outlier_frac > 0:
        # OASIS-S with dense masked compensation: zero sorts, one extra dense
        # matmul. Orizuru/lax.top_k at 32k-token prefill means a full sort per
        # projection (~12 GB/device of sort+gather workspace x concurrency —
        # EXPERIMENTS §Perf P1); thresholds are offline (paper's OASIS-S) and
        # the mask/residual chain fuses to nothing. Decode keeps the dynamic
        # Orizuru path (sorting 1 token is free; accuracy is higher).
        deq = qz.dequantize_activation(qa, dtype=cfg.compute_dtype)
        xf = x.astype(cfg.compute_dtype)
        mask = (xf > p.thr_hi) | (xf < p.thr_lo)
        r = jnp.where(mask, xf - deq, 0)
        w = (p.qw.codebook[p.qw.indices] * p.qw.scale[None, :]).astype(cfg.compute_dtype)
        y = y + jnp.einsum("...k,kn->...n", r, w)
    elif cfg.detection != "none" and cfg.outlier_frac > 0:
        k = ol.num_outliers(x.shape[-1], cfg.outlier_frac)
        if cfg.detection == "dynamic":
            outs = ol.detect_outliers_topk(x.astype(jnp.float32), k)
        else:
            outs = ol.detect_outliers_static(
                x.astype(jnp.float32), p.thr_lo, p.thr_hi, k
            )
        r = ol.outlier_residuals(outs, qa)
        mode = cfg.comp_mode
        if mode == "auto":
            # decode-ish (few tokens): row-gather; prefill-ish: scatter+dense GEMM
            mode = "gather" if _tokens(x) <= 64 else "scatter"
        comp = (
            ol.compensate_gather(r, outs, p.qw, cfg.compute_dtype)
            if mode == "gather"
            else ol.compensate_scatter(r, outs, p.qw, cfg.compute_dtype)
        )
        y = y + comp

    if p.bias is not None:
        y = y + p.bias.astype(cfg.compute_dtype)
    return y.astype(out_dtype)
