"""Offline calibration: activation capture, Fisher weights, codebook fitting.

Paper §III-A / §V-A: activation centroids are trained offline on 16 C4
calibration samples with *weighted* K-Means, weights from Fisher information
of the activations. Weight codebooks come straight from the pretrained
weights (no calibration data needed).

Capture mechanism: quantizable layers call :func:`tap` on their input
activations. Outside a capture context this is a zero-cost identity. Inside
one (plain-Python forward, not jit), activations are recorded per layer name.

Fisher mechanism: the empirical Fisher diagonal for an activation x is
E[(dL/dx)^2]. We obtain dL/dx exactly by differentiating w.r.t. a zero
perturbation injected at every tap point (``fisher_capture``) — no framework
hooks needed, pure JAX.
"""

from __future__ import annotations

import contextlib
import contextvars
from typing import Callable

import jax
import jax.numpy as jnp

from repro.core import quantize as qz

__all__ = ["tap", "capture", "captured", "fisher_capture", "calibrate_codebooks"]

_CAPTURE: contextvars.ContextVar[dict | None] = contextvars.ContextVar(
    "repro_calibration_capture", default=None
)
_EPS: contextvars.ContextVar[dict | None] = contextvars.ContextVar(
    "repro_calibration_eps", default=None
)


def tap(name: str, x: jax.Array) -> jax.Array:
    """Mark ``x`` as the input activation of quantizable layer ``name``.

    Identity outside calibration. Inside :func:`capture`, records ``x``;
    inside :func:`fisher_capture`'s traced forward, adds the named zero
    perturbation so its cotangent IS dL/dx.
    """
    eps = _EPS.get()
    if eps is not None and name in eps:
        x = x + eps[name].astype(x.dtype)
    store = _CAPTURE.get()
    if store is not None:
        store.setdefault(name, []).append(jax.device_get(x).reshape(-1, x.shape[-1]))
    return x


@contextlib.contextmanager
def capture():
    """Context manager: record all tapped activations. Yields the store dict."""
    store: dict[str, list] = {}
    token = _CAPTURE.set(store)
    try:
        yield store
    finally:
        _CAPTURE.reset(token)


def captured(store: dict[str, list]) -> dict[str, jnp.ndarray]:
    """Concatenate a capture store into (tokens, K) arrays per layer."""
    return {k: jnp.concatenate([jnp.asarray(v) for v in vs], axis=0) for k, vs in store.items()}


def fisher_capture(
    loss_fn: Callable[[], jax.Array],
    eps_shapes: dict[str, tuple[int, ...]],
) -> dict[str, jax.Array]:
    """Per-element Fisher proxy (dL/dx)^2 at every tap point.

    ``loss_fn`` must execute the tapped forward (closing over params/batch);
    ``eps_shapes`` gives the activation shape at each tap. Returns squared
    gradients per layer name.
    """

    def with_eps(eps: dict[str, jax.Array]) -> jax.Array:
        token = _EPS.set(eps)
        try:
            return loss_fn()
        finally:
            _EPS.reset(token)

    zeros = {k: jnp.zeros(s, jnp.float32) for k, s in eps_shapes.items()}
    grads = jax.grad(with_eps)(zeros)
    return {k: jnp.square(g) for k, g in grads.items()}


def calibrate_codebooks(
    acts: dict[str, jax.Array],
    a_bits: int = 4,
    fisher: dict[str, jax.Array] | None = None,
    scale_mode: qz.ScaleMode = "rms",
) -> dict[str, jax.Array]:
    """Fit one offline activation codebook per captured layer."""
    out = {}
    for name, x in acts.items():
        f = None if fisher is None else fisher.get(name)
        if f is not None:
            f = f.reshape(-1, x.shape[-1])[: x.shape[0]]
        out[name] = qz.fit_activation_codebook(x, nbits=a_bits, fisher=f, scale_mode=scale_mode)
    return out
