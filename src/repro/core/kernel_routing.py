"""Kernel routing policy + per-tier dispatch accounting (LUT-GEMM + Orizuru).

Every quantized projection resolves a route — ``pallas`` (the fused
quantize+index-GEMM Pallas kernel, ``repro/kernels/lut_gemm.py``) or ``jnp``
(the factorized ``core/lut_gemm.py`` form) — from its
``QLinearConfig.kernel`` field:

  auto   : Pallas on TPU backends, jnp elsewhere (interpret-mode Pallas is
           far slower than XLA's fused gather+einsum on CPU). The
           ``REPRO_LUT_KERNEL`` env var overrides the auto default with the
           same spelling as ``REPRO_PAGED_KERNEL``: "0"/"off"/"false" forces
           jnp, any other value forces the kernel.
  pallas : always the kernel (interpret mode off-TPU).
  jnp    : always the factorized jnp form.

**Outlier detection routes the same way** (``QLinearConfig.detect_kernel``):
the dual-branch layer's ``detection="dynamic"`` top-k/bottom-k resolves to
the Pallas Orizuru tournament kernel (``repro/kernels/topk_outlier.py`` —
on the jnp GEMM route as the STREAMING variant that emits (idx, scale,
OutlierSet) in the quantize pass) or to ``jax.lax.top_k``. The
``REPRO_TOPK_KERNEL`` env var overrides the auto default, mirroring
``REPRO_LUT_KERNEL``. Static (OASIS-S) detection is threshold scoring with
no tournament to run — it always resolves to jnp; requesting
``detect_kernel="pallas"`` for it is an explicit, warned fallback.

Route resolution happens at **trace time** (``qlinear_apply`` runs under
jit), so the dispatch counters here record which path was *compiled
into* each jaxpr — one count per projection per traced shape, not per
executed step. That is exactly the observability question ("which path
actually ran?") a trace-time decision can answer truthfully; incrementing
per execution would need a host callback on the serving hot path. The
serving scheduler surfaces these counts as lazy gauges in the PR-6
telemetry registry (``serving_lut_*`` and ``serving_outlier_*``) and in
``ServingEngine.stats``. Compensation-route choices (gather vs scatter,
``QLinearConfig.comp_mode`` resolution) are counted here too.

Fallbacks are never silent: an unsupported tier demoted from a requested
``pallas`` route increments a counter AND warns once per reason
(the pre-routing code silently dropped W8 to jnp even with
``use_kernel=True``).
"""

from __future__ import annotations

import os
import warnings
from collections import Counter

import jax

__all__ = [
    "resolve_route",
    "resolve_detect_route",
    "record_dispatch",
    "record_fallback",
    "record_detect_dispatch",
    "record_detect_fallback",
    "record_comp_route",
    "dispatch_counts",
    "kernel_calls",
    "jnp_calls",
    "fallback_count",
    "detect_dispatch_counts",
    "detect_calls",
    "detect_kernel_calls",
    "detect_jnp_calls",
    "detect_fallback_count",
    "comp_route_counts",
    "snapshot",
    "reset",
]

ROUTES = ("auto", "pallas", "jnp")

# (tier, route) -> number of trace-time route resolutions, e.g.
# ("w4a4", "pallas") -> 3. Process-global by design: qlinear_apply has no
# handle on an engine, and the telemetry registry reads these lazily.
_DISPATCH: Counter = Counter()
# reason -> count of explicit pallas->jnp demotions
_FALLBACKS: Counter = Counter()
_WARNED: set[str] = set()

# Outlier-detection routing state, mirroring the GEMM counters above:
# (tier, route) dispatches, explicit fallbacks, and the comp-route choice
# (gather vs scatter) that the dual branch resolves per trace.
_DETECT_DISPATCH: Counter = Counter()
_DETECT_FALLBACKS: Counter = Counter()
_COMP_ROUTES: Counter = Counter()

# Resolved on first use, NOT at import: jax.default_backend() initializes
# the backend, which would break platform overrides in programs that merely
# import the core stack. Tests monkeypatch this to force a route.
_AUTO_DEFAULT: bool | None = None
_DETECT_AUTO_DEFAULT: bool | None = None


def _auto_default() -> bool:
    """auto-route default: kernel on TPU, jnp elsewhere; env-overridable."""
    global _AUTO_DEFAULT
    if _AUTO_DEFAULT is None:
        env = os.environ.get("REPRO_LUT_KERNEL", "auto").strip().lower()
        if env in ("", "auto"):
            _AUTO_DEFAULT = jax.default_backend() == "tpu"
        else:
            _AUTO_DEFAULT = env not in ("0", "off", "false")
    return _AUTO_DEFAULT


def resolve_route(kernel: str, use_kernel: bool = False) -> str:
    """Resolve a ``QLinearConfig.kernel`` policy to a concrete route.

    ``use_kernel`` is the legacy boolean opt-in: under ``kernel="auto"`` it
    still forces the Pallas route so pre-policy configs keep their meaning.
    """
    if kernel == "pallas":
        return "pallas"
    if kernel == "jnp":
        return "jnp"
    if kernel != "auto":
        raise ValueError(f"kernel must be one of {ROUTES}, got {kernel!r}")
    if use_kernel:
        return "pallas"
    return "pallas" if _auto_default() else "jnp"


def _detect_auto_default() -> bool:
    """auto detect-route default: Orizuru kernel on TPU, lax.top_k elsewhere;
    overridable via ``REPRO_TOPK_KERNEL`` ("0"/"off"/"false" forces jnp)."""
    global _DETECT_AUTO_DEFAULT
    if _DETECT_AUTO_DEFAULT is None:
        env = os.environ.get("REPRO_TOPK_KERNEL", "auto").strip().lower()
        if env in ("", "auto"):
            _DETECT_AUTO_DEFAULT = jax.default_backend() == "tpu"
        else:
            _DETECT_AUTO_DEFAULT = env not in ("0", "off", "false")
    return _DETECT_AUTO_DEFAULT


def resolve_detect_route(detect_kernel: str) -> str:
    """Resolve a ``QLinearConfig.detect_kernel`` policy to a concrete route."""
    if detect_kernel == "pallas":
        return "pallas"
    if detect_kernel == "jnp":
        return "jnp"
    if detect_kernel != "auto":
        raise ValueError(
            f"detect_kernel must be one of {ROUTES}, got {detect_kernel!r}"
        )
    return "pallas" if _detect_auto_default() else "jnp"


def record_dispatch(tier: str, route: str) -> None:
    _DISPATCH[(tier, route)] += 1


def record_fallback(tier: str, reason: str) -> None:
    """Explicit pallas->jnp demotion: counted, warned once per reason."""
    _FALLBACKS[reason] += 1
    _DISPATCH[(tier, "fallback")] += 1
    if reason not in _WARNED:
        _WARNED.add(reason)
        warnings.warn(
            f"LUT-GEMM kernel route unavailable for tier {tier}: {reason}; "
            f"falling back to the jnp factorized path",
            RuntimeWarning,
            stacklevel=3,
        )


def record_detect_dispatch(tier: str, route: str) -> None:
    _DETECT_DISPATCH[(tier, route)] += 1


def record_detect_fallback(tier: str, reason: str) -> None:
    """Explicit detect pallas->jnp demotion: counted, warned once per reason."""
    _DETECT_FALLBACKS[reason] += 1
    _DETECT_DISPATCH[(tier, "fallback")] += 1
    key = f"detect:{reason}"
    if key not in _WARNED:
        _WARNED.add(key)
        warnings.warn(
            f"Orizuru detection kernel route unavailable for tier {tier}: "
            f"{reason}; falling back to the jnp (lax.top_k / threshold) path",
            RuntimeWarning,
            stacklevel=3,
        )


def record_comp_route(mode: str) -> None:
    """Count the resolved compensation route ("gather" or "scatter")."""
    _COMP_ROUTES[mode] += 1


def dispatch_counts() -> dict[str, int]:
    """``{"<tier>/<route>": count}`` snapshot of every recorded dispatch."""
    return {f"{tier}/{route}": n for (tier, route), n in sorted(_DISPATCH.items())}


def kernel_calls() -> int:
    """Total projections routed to the Pallas kernel (trace-time count)."""
    return sum(n for (_, route), n in _DISPATCH.items() if route == "pallas")


def jnp_calls() -> int:
    return sum(n for (_, route), n in _DISPATCH.items() if route == "jnp")


def fallback_count() -> int:
    return sum(_FALLBACKS.values())


def detect_dispatch_counts() -> dict[str, int]:
    """``{"<tier>/<route>": count}`` snapshot of detection dispatches."""
    return {
        f"{tier}/{route}": n
        for (tier, route), n in sorted(_DETECT_DISPATCH.items())
    }


def detect_calls() -> int:
    """Total outlier-branch detection resolutions (any route, incl. fallback
    demotions — every one of these compiled *some* detection into the jaxpr)."""
    return sum(_DETECT_DISPATCH.values())


def detect_kernel_calls() -> int:
    """Detections routed to the Pallas Orizuru kernel (trace-time count)."""
    return sum(n for (_, route), n in _DETECT_DISPATCH.items() if route == "pallas")


def detect_jnp_calls() -> int:
    return sum(n for (_, route), n in _DETECT_DISPATCH.items() if route == "jnp")


def detect_fallback_count() -> int:
    return sum(_DETECT_FALLBACKS.values())


def comp_route_counts() -> dict[str, int]:
    """``{"gather": n, "scatter": m}`` resolved compensation routes."""
    return dict(sorted(_COMP_ROUTES.items()))


def snapshot() -> dict[str, int]:
    """Flat copy for delta-based assertions (benchmarks, tests)."""
    d = dispatch_counts()
    for key, n in detect_dispatch_counts().items():
        d[f"detect:{key}"] = n
    for mode, n in comp_route_counts().items():
        d[f"comp:{mode}"] = n
    d["_kernel_calls"] = kernel_calls()
    d["_jnp_calls"] = jnp_calls()
    d["_fallbacks"] = fallback_count()
    d["_detect_calls"] = detect_calls()
    d["_detect_kernel_calls"] = detect_kernel_calls()
    d["_detect_jnp_calls"] = detect_jnp_calls()
    d["_detect_fallbacks"] = detect_fallback_count()
    return d


def reset() -> None:
    """Clear counters (tests). The one-time-warning set is kept — warning
    spam does not become useful again just because counters were zeroed."""
    _DISPATCH.clear()
    _FALLBACKS.clear()
    _DETECT_DISPATCH.clear()
    _DETECT_FALLBACKS.clear()
    _COMP_ROUTES.clear()
