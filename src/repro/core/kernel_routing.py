"""LUT-GEMM kernel routing policy + per-tier dispatch accounting.

Every quantized projection resolves a route — ``pallas`` (the fused
quantize+index-GEMM Pallas kernel, ``repro/kernels/lut_gemm.py``) or ``jnp``
(the factorized ``core/lut_gemm.py`` form) — from its
``QLinearConfig.kernel`` field:

  auto   : Pallas on TPU backends, jnp elsewhere (interpret-mode Pallas is
           far slower than XLA's fused gather+einsum on CPU). The
           ``REPRO_LUT_KERNEL`` env var overrides the auto default with the
           same spelling as ``REPRO_PAGED_KERNEL``: "0"/"off"/"false" forces
           jnp, any other value forces the kernel.
  pallas : always the kernel (interpret mode off-TPU).
  jnp    : always the factorized jnp form.

Route resolution happens at **trace time** (``qlinear_apply`` runs under
jit), so the dispatch counters here record which GEMM path was *compiled
into* each jaxpr — one count per projection per traced shape, not per
executed step. That is exactly the observability question ("which path
actually ran?") a trace-time decision can answer truthfully; incrementing
per execution would need a host callback on the serving hot path. The
serving scheduler surfaces these counts as lazy gauges in the PR-6
telemetry registry (``serving_lut_kernel_calls`` / ``serving_lut_jnp_calls``
/ ``serving_lut_fallbacks``) and in ``ServingEngine.stats``.

Fallbacks are never silent: an unsupported tier demoted from a requested
``pallas`` route increments a counter AND warns once per reason
(the pre-routing code silently dropped W8 to jnp even with
``use_kernel=True``).
"""

from __future__ import annotations

import os
import warnings
from collections import Counter

import jax

__all__ = [
    "resolve_route",
    "record_dispatch",
    "record_fallback",
    "dispatch_counts",
    "kernel_calls",
    "jnp_calls",
    "fallback_count",
    "snapshot",
    "reset",
]

ROUTES = ("auto", "pallas", "jnp")

# (tier, route) -> number of trace-time route resolutions, e.g.
# ("w4a4", "pallas") -> 3. Process-global by design: qlinear_apply has no
# handle on an engine, and the telemetry registry reads these lazily.
_DISPATCH: Counter = Counter()
# reason -> count of explicit pallas->jnp demotions
_FALLBACKS: Counter = Counter()
_WARNED: set[str] = set()

# Resolved on first use, NOT at import: jax.default_backend() initializes
# the backend, which would break platform overrides in programs that merely
# import the core stack. Tests monkeypatch this to force a route.
_AUTO_DEFAULT: bool | None = None


def _auto_default() -> bool:
    """auto-route default: kernel on TPU, jnp elsewhere; env-overridable."""
    global _AUTO_DEFAULT
    if _AUTO_DEFAULT is None:
        env = os.environ.get("REPRO_LUT_KERNEL", "auto").strip().lower()
        if env in ("", "auto"):
            _AUTO_DEFAULT = jax.default_backend() == "tpu"
        else:
            _AUTO_DEFAULT = env not in ("0", "off", "false")
    return _AUTO_DEFAULT


def resolve_route(kernel: str, use_kernel: bool = False) -> str:
    """Resolve a ``QLinearConfig.kernel`` policy to a concrete route.

    ``use_kernel`` is the legacy boolean opt-in: under ``kernel="auto"`` it
    still forces the Pallas route so pre-policy configs keep their meaning.
    """
    if kernel == "pallas":
        return "pallas"
    if kernel == "jnp":
        return "jnp"
    if kernel != "auto":
        raise ValueError(f"kernel must be one of {ROUTES}, got {kernel!r}")
    if use_kernel:
        return "pallas"
    return "pallas" if _auto_default() else "jnp"


def record_dispatch(tier: str, route: str) -> None:
    _DISPATCH[(tier, route)] += 1


def record_fallback(tier: str, reason: str) -> None:
    """Explicit pallas->jnp demotion: counted, warned once per reason."""
    _FALLBACKS[reason] += 1
    _DISPATCH[(tier, "fallback")] += 1
    if reason not in _WARNED:
        _WARNED.add(reason)
        warnings.warn(
            f"LUT-GEMM kernel route unavailable for tier {tier}: {reason}; "
            f"falling back to the jnp factorized path",
            RuntimeWarning,
            stacklevel=3,
        )


def dispatch_counts() -> dict[str, int]:
    """``{"<tier>/<route>": count}`` snapshot of every recorded dispatch."""
    return {f"{tier}/{route}": n for (tier, route), n in sorted(_DISPATCH.items())}


def kernel_calls() -> int:
    """Total projections routed to the Pallas kernel (trace-time count)."""
    return sum(n for (_, route), n in _DISPATCH.items() if route == "pallas")


def jnp_calls() -> int:
    return sum(n for (_, route), n in _DISPATCH.items() if route == "jnp")


def fallback_count() -> int:
    return sum(_FALLBACKS.values())


def snapshot() -> dict[str, int]:
    """Flat copy for delta-based assertions (benchmarks, tests)."""
    d = dispatch_counts()
    d["_kernel_calls"] = kernel_calls()
    d["_jnp_calls"] = jnp_calls()
    d["_fallbacks"] = fallback_count()
    return d


def reset() -> None:
    """Clear counters (tests). The one-time-warning set is kept — warning
    spam does not become useful again just because counters were zeroed."""
    _DISPATCH.clear()
    _FALLBACKS.clear()
