"""Quantized-model artifacts: save once, serve anywhere.

``save_quantized(dir, model_cfg, spec, qparams)`` persists a
QuantSpec-quantized parameter tree (packed-int4/int8 weights, codebooks,
scales, fp leaves) plus everything needed to rebuild and serve it:

    <dir>/manifest.json   format version, ModelConfig fields, QuantSpec
                          (base + rules + kv policy), per-tensor dtype/shape/
                          sha256, and the tree structure (dict/list/qlinear
                          nodes with each QLinearParams' resolved QLinearConfig
                          and QuantizedWeight meta)
    <dir>/tensors.npz     every array leaf as raw bytes (uint8 views), so any
                          dtype — including bfloat16 — round-trips bit-exactly

``load_quantized(dir)`` rebuilds the :class:`~repro.models.model.Model` and
the exact QLinearParams tree in a fresh process with **zero calibration or
K-Means code on the path** — a serving process loads a prepared artifact and
serves it instead of re-running PTQ at startup.

Write order is crash-aware: tensors first, ``manifest.json`` last — a
directory without a manifest is an incomplete save and refuses to load.
"""

from __future__ import annotations

import dataclasses
import hashlib
import json
import pathlib
from typing import Any, NamedTuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs.base import ModelConfig
from repro.core.qlinear import QLinearParams
from repro.core.quantize import QuantizedWeight
from repro.core.quantspec import QuantSpec, _cfg_from_json, _cfg_to_json

__all__ = ["save_quantized", "load_quantized", "load_calib_stats",
           "QuantizedArtifact", "FORMAT_VERSION"]

FORMAT_VERSION = 1


class QuantizedArtifact(NamedTuple):
    """What ``load_quantized`` returns (tuple-unpackable)."""

    model: Any  # repro.models.model.Model
    params: dict
    spec: QuantSpec


# ---------------------------------------------------------------------------
# dtype round-trip (bfloat16 et al. aren't np.save-serializable as-is)
# ---------------------------------------------------------------------------

def _np_dtype(name: str) -> np.dtype:
    try:
        return np.dtype(name)
    except TypeError:
        import ml_dtypes  # ships with jax

        return np.dtype(getattr(ml_dtypes, name))


def _to_host(leaf) -> np.ndarray:
    return np.asarray(jax.device_get(leaf))


# ---------------------------------------------------------------------------
# tree <-> (structure json, {tensor name: ndarray})
# ---------------------------------------------------------------------------

def _flatten(tree, path: str, tensors: dict[str, np.ndarray]):
    """Returns a JSON-able structure mirror; arrays go into ``tensors``."""
    if isinstance(tree, dict):
        return {"kind": "dict",
                "items": {k: _flatten(v, f"{path}/{k}" if path else k, tensors)
                          for k, v in tree.items()}}
    if isinstance(tree, list):
        return {"kind": "list",
                "items": [_flatten(v, f"{path}/{i}", tensors)
                          for i, v in enumerate(tree)]}
    if isinstance(tree, QLinearParams):
        qw = tree.qw
        node = {
            "kind": "qlinear",
            "cfg": _cfg_to_json(tree.cfg),
            "qw_shape": list(qw.shape),
            "qw_nbits": qw.nbits,
            "fields": {},
        }
        arrays = {"qw.packed": qw.packed, "qw.codebook": qw.codebook,
                  "qw.scale": qw.scale, "act_codebook": tree.act_codebook,
                  "bias": tree.bias, "thr_lo": tree.thr_lo, "thr_hi": tree.thr_hi}
        for f, v in arrays.items():
            if v is None:
                node["fields"][f] = None
            else:
                name = f"{path}.{f}"
                tensors[name] = _to_host(v)
                node["fields"][f] = name
        return node
    if tree is None:
        return {"kind": "none"}
    tensors[path] = _to_host(tree)
    return {"kind": "array", "tensor": path}


def _unflatten(node: dict, tensors: dict[str, jnp.ndarray]):
    kind = node["kind"]
    if kind == "dict":
        return {k: _unflatten(v, tensors) for k, v in node["items"].items()}
    if kind == "list":
        return [_unflatten(v, tensors) for v in node["items"]]
    if kind == "qlinear":
        f = {k: (None if v is None else tensors[v]) for k, v in node["fields"].items()}
        qw = QuantizedWeight(packed=f["qw.packed"], codebook=f["qw.codebook"],
                             scale=f["qw.scale"], shape=tuple(node["qw_shape"]),
                             nbits=node["qw_nbits"])
        return QLinearParams(qw=qw, act_codebook=f["act_codebook"], bias=f["bias"],
                             thr_lo=f["thr_lo"], thr_hi=f["thr_hi"],
                             cfg=_cfg_from_json(node["cfg"]))
    if kind == "none":
        return None
    return tensors[node["tensor"]]


# ---------------------------------------------------------------------------
# public API
# ---------------------------------------------------------------------------

def save_quantized(directory: str, model_cfg: ModelConfig, spec: QuantSpec,
                   qparams: dict, calib_stats: dict | None = None) -> pathlib.Path:
    """Persist a quantized model; returns the artifact directory.

    ``calib_stats``: optional per-tap calibration-time activation statistics
    for live drift detection (``core/numerics``) — ``{tap_name: stats}``
    where ``stats`` is either the dict :func:`repro.core.numerics.
    activation_stats` returns, or the raw (tokens, K) calibration activations
    (summarized here). Stored in the manifest; serving reads it back with
    :func:`load_calib_stats`."""
    d = pathlib.Path(directory)
    d.mkdir(parents=True, exist_ok=True)
    # invalidate any PREVIOUS save first: a stale manifest paired with new
    # tensors would pass the completeness check and misload
    (d / "manifest.json").unlink(missing_ok=True)
    tensors: dict[str, np.ndarray] = {}
    structure = _flatten(qparams, "", tensors)

    # raw-byte views make every dtype (incl. bfloat16) npz-safe + bit-exact;
    # stream the npz straight to disk (crash safety comes from manifest-last,
    # not from buffering) and hash the same byte views — one host copy total
    byte_arrays = {k: np.frombuffer(v.tobytes(), np.uint8) for k, v in tensors.items()}
    np.savez(d / "tensors.npz", **byte_arrays)

    manifest = {
        "format_version": FORMAT_VERSION,
        "model": dataclasses.asdict(model_cfg),
        "spec": spec.to_json_dict(),
        "structure": structure,
        "tensors": {
            k: {"dtype": str(v.dtype), "shape": list(v.shape),
                "sha256": hashlib.sha256(byte_arrays[k]).hexdigest()[:16]}
            for k, v in tensors.items()
        },
    }
    if calib_stats:
        from repro.core import numerics  # late: artifact stays import-light

        manifest["calib_stats"] = {
            tap: (dict(st) if isinstance(st, dict)
                  else numerics.activation_stats(st))
            for tap, st in calib_stats.items()
        }
    # manifest LAST, via rename so it appears atomically (crash -> no manifest
    # -> load_quantized refuses the incomplete directory)
    tmp = d / ".manifest.json.tmp"
    tmp.write_text(json.dumps(manifest, indent=1))
    tmp.replace(d / "manifest.json")
    return d


def load_calib_stats(directory: str) -> dict | None:
    """Per-tap calibration activation stats from an artifact manifest, or
    None for artifacts saved without them (every pre-quality artifact — the
    scheduler then self-baselines drift from the first probed step)."""
    mf = pathlib.Path(directory) / "manifest.json"
    if not mf.exists():
        raise FileNotFoundError(f"{directory} has no manifest.json")
    return json.loads(mf.read_text()).get("calib_stats")


def load_quantized(directory: str, verify: bool = True) -> QuantizedArtifact:
    """Load a saved artifact: (model, qparams, spec), ready to serve.

    No calibration, K-Means fitting, or weight quantization runs here — the
    tree is reconstructed byte-exact from the npz + manifest.
    """
    d = pathlib.Path(directory)
    mf = d / "manifest.json"
    if not mf.exists():
        raise FileNotFoundError(f"{d} has no manifest.json (not an artifact, "
                                "or an interrupted save)")
    manifest = json.loads(mf.read_text())
    if manifest["format_version"] != FORMAT_VERSION:
        raise ValueError(f"artifact format {manifest['format_version']} != "
                         f"supported {FORMAT_VERSION}")

    with np.load(d / "tensors.npz") as z:
        raw = {k: z[k] for k in z.files}
    tensors: dict[str, jnp.ndarray] = {}
    for name, meta in manifest["tensors"].items():
        b = raw[name].tobytes()
        if verify and hashlib.sha256(b).hexdigest()[:16] != meta["sha256"]:
            raise IOError(f"artifact corruption detected at tensor {name}")
        arr = np.frombuffer(b, _np_dtype(meta["dtype"])).reshape(meta["shape"])
        tensors[name] = jnp.asarray(arr)

    params = _unflatten(manifest["structure"], tensors)
    spec = QuantSpec.from_json_dict(manifest["spec"])
    mc = dict(manifest["model"])
    mc["block_pattern"] = tuple(mc.get("block_pattern", ()))
    from repro.models.model import build  # late: avoid core<->models import cycle

    model = build(ModelConfig(**mc))
    return QuantizedArtifact(model=model, params=params, spec=spec)
