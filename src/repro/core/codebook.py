"""K-Means codebook learning for KLLM/OASIS non-uniform quantization.

The paper (§III-A) quantizes weights and activations with learned-codebook
(K-Means) quantization [MacQueen'67]:

    x~_i = C_{idx_i},  idx_i = argmin_k || x_i - C_k ||^2        (Eq. 1)

Activation codebooks are fit with a *weighted* K-Means whose sample weights come
from Fisher information (sensitivity) estimates, so that centroids spend
resolution where the loss is most sensitive.

Everything here is pure JAX (jit-able, differentiable where meaningful) and
deterministic: initialization is quantile-based (no RNG), Lloyd iterations run a
fixed ``iters`` count under ``lax.fori_loop`` so the fit itself can be jitted
and reused inside calibration sweeps.
"""

from __future__ import annotations

from functools import partial

import jax
import jax.numpy as jnp

__all__ = [
    "quantile_init",
    "kmeans_fit",
    "assign",
    "boundaries_from_centroids",
    "assign_via_boundaries",
]


def quantile_init(x: jax.Array, n_centroids: int, w: jax.Array | None = None) -> jax.Array:
    """Deterministic centroid init at evenly spaced (weighted) quantiles.

    Using quantiles rather than uniform spacing matches the non-uniform
    density of LLM weight/activation distributions and makes Lloyd converge
    in a handful of iterations.
    """
    x = x.reshape(-1).astype(jnp.float32)
    qs = (jnp.arange(n_centroids, dtype=jnp.float32) + 0.5) / n_centroids
    if w is None:
        return jnp.quantile(x, qs)
    # Weighted quantiles: sort by value, walk the normalized cumulative weight.
    order = jnp.argsort(x)
    xs = x[order]
    ws = w.reshape(-1).astype(jnp.float32)[order]
    cw = jnp.cumsum(ws)
    cw = cw / jnp.maximum(cw[-1], 1e-30)
    pos = jnp.searchsorted(cw, qs)
    return xs[jnp.clip(pos, 0, x.shape[0] - 1)]


def assign(x: jax.Array, centroids: jax.Array) -> jax.Array:
    """Nearest-centroid assignment (Eq. 1). Returns int32 indices, shape of x.

    Note: centroids need not be sorted here.  The production inference path
    uses :func:`assign_via_boundaries` (the paper's Clustering-Unit binary
    search), which requires sorted centroids and is exactly equivalent —
    ``tests/test_codebook.py`` asserts the equivalence.
    """
    d = jnp.abs(x[..., None] - centroids)  # scalar data => L2 == |.|
    return jnp.argmin(d, axis=-1).astype(jnp.int32)


def boundaries_from_centroids(centroids: jax.Array) -> jax.Array:
    """Decision boundaries b_i = (c_i + c_{i+1})/2 of the paper's Clustering Unit.

    ``centroids`` must be sorted ascending; returns ``len(centroids) - 1``
    boundaries.
    """
    return 0.5 * (centroids[:-1] + centroids[1:])


def assign_via_boundaries(x: jax.Array, sorted_centroids: jax.Array) -> jax.Array:
    """Cluster via binary search over boundary values (paper Fig. 9(b)).

    For any x in [b_{i-1}, b_i) the index is i.  This is the TPU analogue of
    the Clustering Unit's log2(2^n) hierarchical comparisons, expressed as
    ``searchsorted`` (XLA lowers this to a vectorized binary search; the
    Pallas kernel in ``kernels/bucketize.py`` unrolls the 4 compare levels
    explicitly).
    """
    b = boundaries_from_centroids(sorted_centroids)
    return jnp.searchsorted(b, x, side="right").astype(jnp.int32)


@partial(jax.jit, static_argnames=("n_centroids", "iters"))
def kmeans_fit(
    x: jax.Array,
    n_centroids: int,
    w: jax.Array | None = None,
    iters: int = 25,
) -> jax.Array:
    """Fit a 1-D K-Means codebook with optional per-sample (Fisher) weights.

    Lloyd's algorithm with deterministic quantile init.  Empty clusters keep
    their previous centroid (no random restarts — determinism matters for
    reproducible checkpoints and multi-host consistency).

    Returns sorted centroids, shape ``(n_centroids,)``, float32.
    """
    xf = x.reshape(-1).astype(jnp.float32)
    wf = (
        jnp.ones_like(xf)
        if w is None
        else jnp.maximum(w.reshape(-1).astype(jnp.float32), 1e-12)
    )
    init = quantile_init(xf, n_centroids, None if w is None else wf)

    def step(_, c):
        idx = assign(xf, c)
        one_hot = jax.nn.one_hot(idx, n_centroids, dtype=jnp.float32)  # (S, C)
        wsum = one_hot.T @ wf  # (C,)
        wx = one_hot.T @ (wf * xf)  # (C,)
        new = jnp.where(wsum > 0, wx / jnp.maximum(wsum, 1e-30), c)
        return jnp.sort(new)

    return jax.lax.fori_loop(0, iters, step, jnp.sort(init))
