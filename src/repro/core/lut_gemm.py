"""WAQ LUT-based GEMM (paper §III-B).

Both operands are index-coded against learned codebooks, so every scalar
product is one of ``2^(nA+nW)`` values — the **Cartesian-product LUT**

    LUT[i, j] = cA[i] * cW[j].

The paper's ASIC reduces along K by (1) concatenating (aIdx, wIdx), (2)
histogramming the concatenated patterns, (3) taking a weighted sum of LUT
entries — K FP adds become 2^(nA+nW) FP adds, and the LUT is independent of
the reduction length (Table I).

On TPU we implement BOTH formulations:

* :func:`lut_gemm_counting` — the paper-faithful counting form, expressed with
  one-hot matmuls. It is the mathematical oracle for tests and the basis of
  the Table-I analytics. (On an MXU this form costs *more* FLOPs than the
  factorized form; it exists to prove equivalence, not for speed.)

* :func:`lut_gemm` — the TPU-native **factorized** form. Because the LUT is an
  outer product, the weighted LUT sum collapses algebraically:

      Y[m,n] = sA[m]·sW[n] · Σ_k cA[aIdx[m,k]] · cW[wIdx[k,n]]

  i.e. gather centroids (in VMEM, from 16-entry tables) and feed the MXU.
  No dequantized weight matrix ever exists in HBM — the paper's
  "no-dequantization" property survives on the memory side, which is the side
  that matters on TPU (decode GEMMs are HBM-bound). The perf-critical packed
  version lives in ``repro/kernels/lut_gemm.py`` (Pallas).

Equivalence of the two forms (and of both against dequantize-then-matmul) is
asserted by unit + hypothesis tests.
"""

from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.core.quantize import QuantizedActivation, QuantizedWeight

__all__ = [
    "build_lut",
    "lut_gemm_counting",
    "lut_gemm",
    "reduction_flops_counting",
    "woq_lut_size",
    "waq_lut_size",
]


def build_lut(act_codebook: jax.Array, wgt_codebook: jax.Array) -> jax.Array:
    """Precompute the Cartesian-product LUT, shape ``(2^nA, 2^nW)``.

    Offline (paper Fig. 6 step 0): both codebooks are known before inference,
    so the LUT is a constant that lives on-chip (it is 2^(nA+nW) fp32 values —
    1 KiB for W4A4; on TPU it is constant-folded into the program).
    """
    return jnp.outer(act_codebook, wgt_codebook)


def lut_gemm_counting(
    qa: QuantizedActivation, qw: QuantizedWeight, out_dtype=jnp.float32
) -> jax.Array:
    """Paper-faithful counting-form GEMM (Fig. 6 steps 1-3).

    Steps, vectorized: one-hot the activation indices (M,K,2^nA) and weight
    indices (K,N,2^nW); their contraction over K *is* the per-(m,n) histogram
    of concatenated indices; the weighted sum with the LUT finishes the GEMM.

      counts[m,n,i,j] = Σ_k 1[aIdx[m,k]=i] · 1[wIdx[k,n]=j]
      Y[m,n]          = sA[m]·sW[n] · Σ_ij counts[m,n,i,j] · LUT[i,j]

    Only used as an oracle / for analytics: O(M·N·2^(nA+nW)) memory.
    """
    lut = build_lut(qa.codebook, qw.codebook)
    a1h = jax.nn.one_hot(qa.idx, 2**qa.nbits, dtype=jnp.float32)  # (..., K, 2^nA)
    w1h = jax.nn.one_hot(qw.indices, 2**qw.nbits, dtype=jnp.float32)  # (K, N, 2^nW)
    counts = jnp.einsum("...ki,knj->...nij", a1h, w1h)  # histogram of concat indices
    y = jnp.einsum("...nij,ij->...n", counts, lut)
    return (y * qa.scale * qw.scale).astype(out_dtype)


def lut_gemm(
    qa: QuantizedActivation, qw: QuantizedWeight, out_dtype=jnp.float32,
    compute_dtype=jnp.float32,
) -> jax.Array:
    """Factorized LUT-GEMM — the TPU-native production form (jnp reference).

    Centroid gathers happen from 16-entry tables (VMEM-resident after
    constant hoisting); the reduction runs on the MXU. Bit-for-bit the same
    result as :func:`lut_gemm_counting` up to float summation order.
    """
    a = (qa.codebook[qa.idx]).astype(compute_dtype)  # (..., K)
    w = (qw.codebook[qw.indices]).astype(compute_dtype)  # (K, N)
    y = jnp.einsum("...k,kn->...n", a, w)
    return (y * qa.scale.astype(compute_dtype) * qw.scale.astype(compute_dtype)).astype(
        out_dtype
    )


# ---------------------------------------------------------------------------
# Table-I analytics (LUT sizes / reduction FLOPs), used by benchmarks
# ---------------------------------------------------------------------------

def woq_lut_size(mu: int, k: int, entry_bytes: int = 2) -> int:
    """WOQ inner-product LUT size in bytes: 2^mu entries per group, K/mu groups."""
    return (2**mu) * (k // mu) * entry_bytes


def waq_lut_size(n_a: int, n_w: int, entry_bytes: int = 2) -> int:
    """Ours: Cartesian-product LUT, 2^(nA+nW) entries, K-independent."""
    return (2 ** (n_a + n_w)) * entry_bytes


def reduction_flops_counting(n_a: int, n_w: int, n_out: int) -> int:
    """FP adds for reduction per output row in the counting form: 2^(nA+nW)·N."""
    return (2 ** (n_a + n_w)) * n_out
