"""QuantSpec: declarative per-layer quantization policy.

A spec is an ordered list of ``(path-glob pattern -> QLinearConfig
overrides)`` rules resolved against each quantizable projection's parameter
path during ``quantize_model``. This is what lets the repo express what the
quantization literature says matters — per-layer / per-projection precision
and outlier budgets (SKIM: any-bit per-layer assignment; FineQuant:
per-matrix granularity) — instead of one global config baked into every
layer.

Paths are ``/``-separated parameter-tree paths, e.g. ``blocks/attn/wq`` or
``blocks/3/mlp/wd`` for unscanned stacks. Patterns use ``fnmatch`` globs and
match either the full path or any trailing sub-path, so ``attn/*`` matches
``blocks/attn/wq`` and ``mlp/wd`` matches ``blocks/7/mlp/wd``.

Resolution semantics (**later rules win**):

* start from ``spec.base`` (a plain :class:`QLinearConfig`);
* walk the rules in order; every rule whose pattern matches the path is
  applied — ``"skip"`` marks the layer *dense* (left as fp), a dict of
  overrides un-skips it and updates the running config;
* the final state is the layer's resolved config (or ``None`` = keep dense).

KV-cache treatment is a first-class spec field (``kv_bits`` / ``kv_dtype``)
rather than a per-layer rule: the cache pool is one global allocation shared
by the serving scheduler, not a per-projection decision.

GEMM kernel routing IS a per-layer rule: ``kernel`` ("auto" | "pallas" |
"jnp") is a plain :class:`QLinearConfig` field, so
``rules=[("mlp/*", {"kernel": "pallas"})]`` routes just the MLP projections
through the fused Pallas quantize+index-GEMM while attention stays on the
jnp factorized form (see ``repro.core.kernel_routing`` for the auto
semantics and the dispatch counters). The same goes for outlier handling:
``detection`` / ``outlier_frac`` / ``detect_kernel`` are rule-addressable,
so ``rules=[("mlp/*", {"a_bits": 3, "detection": "dynamic"})]`` drops just
the MLP activations to the A3 tier with online Orizuru compensation.
Resolution validates the final per-layer config (``QLinearConfig.validate``)
— an A3 rule without online detection is rejected at resolve time, not at
some later trace.

Scan-stacked models (``cfg.scan_layers=True``) share one path per projection
(``blocks/attn/wq`` covers every layer in the stack), so per-layer-index
rules like ``blocks/0/*`` require ``scan_layers=False``.
"""

from __future__ import annotations

import dataclasses
from fnmatch import fnmatchcase
from typing import Any, Iterable, Mapping, Union

import jax.numpy as jnp

from repro.core.qlinear import QLinearConfig

__all__ = ["QuantRule", "QuantSpec"]

_CFG_FIELDS = {f.name for f in dataclasses.fields(QLinearConfig)}

# "skip" sentinel accepted wherever a rule's overrides go
RuleLike = Union["QuantRule", tuple]


@dataclasses.dataclass(frozen=True)
class QuantRule:
    """One policy rule: ``pattern`` glob -> config overrides or skip.

    ``overrides`` is stored as a sorted tuple of (field, value) pairs so the
    rule (and the spec) stays hashable; build rules through :class:`QuantSpec`
    with plain dicts.
    """

    pattern: str
    overrides: tuple = ()
    skip: bool = False

    def __post_init__(self):
        bad = [k for k, _ in self.overrides if k not in _CFG_FIELDS]
        if bad:
            raise ValueError(
                f"rule {self.pattern!r}: unknown QLinearConfig field(s) {bad}; "
                f"valid: {sorted(_CFG_FIELDS)}"
            )
        if self.skip and self.overrides:
            raise ValueError(f"rule {self.pattern!r}: 'skip' takes no overrides")

    def matches(self, path: str) -> bool:
        return fnmatchcase(path, self.pattern) or fnmatchcase(path, "*/" + self.pattern)


def _as_rule(r: RuleLike) -> QuantRule:
    if isinstance(r, QuantRule):
        return r
    pattern, body = r
    if isinstance(body, str):
        if body != "skip":
            raise ValueError(f"rule {pattern!r}: string body must be 'skip', got {body!r}")
        return QuantRule(pattern=pattern, skip=True)
    if isinstance(body, Mapping):
        return QuantRule(pattern=pattern, overrides=tuple(sorted(body.items())))
    raise TypeError(f"rule {pattern!r}: body must be 'skip' or a dict of overrides")


@dataclasses.dataclass(frozen=True)
class QuantSpec:
    """Declarative quantization policy for a whole model.

    >>> spec = QuantSpec(
    ...     base=QLinearConfig(w_bits=4, a_bits=4),
    ...     rules=[("mlp/wd", {"w_bits": 8, "outlier_frac": 0.01}),
    ...            ("attn/wo", "skip")],
    ...     kv_bits=4,
    ... )

    ``kv_bits``: None = fp KV cache at ``kv_dtype``; 4 = K-Means int4 blocks.
    """

    base: QLinearConfig = QLinearConfig()
    rules: tuple = ()
    kv_bits: int | None = None
    kv_dtype: str = "bfloat16"

    def __post_init__(self):
        object.__setattr__(self, "rules", tuple(_as_rule(r) for r in self.rules))
        if self.kv_bits not in (None, 4):
            raise ValueError(f"kv_bits must be None or 4 (K-Means int4), got {self.kv_bits}")

    # ------------------------------------------------------------- resolution
    def resolve(self, path: str) -> QLinearConfig | None:
        """Resolved config for the projection at ``path`` (None = keep dense).

        ``path`` uses ``/`` separators (``quantize_model`` normalizes the
        parameter-tree path before calling this).
        """
        cfg, skip = self.base, False
        for rule in self.rules:
            if not rule.matches(path):
                continue
            if rule.skip:
                skip = True
            else:
                skip = False
                cfg = dataclasses.replace(cfg, **dict(rule.overrides))
        # cross-field legality (e.g. the A3 tier requires detection != none)
        # is checked HERE, on the final per-layer state: intermediate rule
        # applications may pass through transiently-illegal combinations.
        return None if skip else cfg.validate()

    # ---------------------------------------------------------- serialization
    def to_json_dict(self) -> dict:
        return {
            "base": _cfg_to_json(self.base),
            "rules": [
                {"pattern": r.pattern, "skip": r.skip, "overrides": _vals_to_json(r.overrides)}
                for r in self.rules
            ],
            "kv_bits": self.kv_bits,
            "kv_dtype": self.kv_dtype,
        }

    @classmethod
    def from_json_dict(cls, d: dict) -> "QuantSpec":
        rules = tuple(
            QuantRule(
                pattern=r["pattern"],
                skip=r.get("skip", False),
                overrides=tuple(sorted(_vals_from_json(r.get("overrides", {})).items())),
            )
            for r in d.get("rules", [])
        )
        return cls(base=_cfg_from_json(d["base"]), rules=rules,
                   kv_bits=d.get("kv_bits"), kv_dtype=d.get("kv_dtype", "bfloat16"))


# ---------------------------------------------------------------------------
# QLinearConfig <-> JSON (compute_dtype is a dtype object; store its name)
# ---------------------------------------------------------------------------

def _vals_to_json(items: Iterable[tuple[str, Any]] | Mapping) -> dict:
    items = items.items() if isinstance(items, Mapping) else items
    return {k: (jnp.dtype(v).name if k == "compute_dtype" else v) for k, v in items}


def _vals_from_json(d: Mapping) -> dict:
    return {k: (jnp.dtype(v) if k == "compute_dtype" else v) for k, v in d.items()}


def _cfg_to_json(cfg: QLinearConfig) -> dict:
    return _vals_to_json(dataclasses.asdict(cfg))


def _cfg_from_json(d: Mapping) -> QLinearConfig:
    return QLinearConfig(**_vals_from_json(d))
