"""Quantization-numerics observability: quant-health probes + drift detection.

The serving stack's telemetry (serving/telemetry.py) observes *performance*;
this module observes the *numerics* of the paper's K-Means quantization on
live traffic — the failure modes per-layer sensitivity analyses (KVQuant) and
outlier-aware dual-side quantization (OASIS) show dominate low-bit accuracy:

* **codebook health** — index utilization histograms, dead-centroid counts,
  and normalized index entropy for weight AND activation codebooks, plus the
  activation saturation rate against the codebook range;
* **per-layer SQNR** — signal-to-quantization-noise of the main branch
  (pre-compensation), in dB;
* **Orizuru effectiveness** — fraction of the pre-quantization tensor energy
  captured by the detected top-k outliers, and overlap of the detected
  channel set with exact ``lax.top_k`` under the dynamic route;
* **calibration drift** — live per-layer activation stats compared against
  calibration-time stats persisted in the artifact manifest
  (``core/artifact.py``), scored into per-layer drift gauges and an alarm
  counter wired through ``distributed/fault_tolerance.StepMonitor``.

Collection mechanism mirrors ``core/calibration``'s capture contextvar, but
for TRACED code: :func:`collect` installs a :class:`ProbeCollector`;
``qlinear_apply`` then emits device-side probe stats (pure jnp reductions on
the tensors it already has) into the collector, which the probed packed step
returns as an extra jit output. Outside :func:`collect` every hook is a
zero-cost no-op — the traced path is byte-identical, which is what keeps the
``off``/``metrics``/``trace`` telemetry levels jaxpr-identical to a build
without this module (asserted in tests/test_numerics.py). The probe flag is
therefore *jit-static*: whether a collector is active at trace time decides
which jaxpr is built; the ``quality`` telemetry level is the only one that
traces with a collector installed.

Every probe reduction here has a trivially checkable numpy oracle
(tests/test_numerics.py asserts bit-equality for the integer stats and tight
allclose for the float ones).
"""

from __future__ import annotations

import contextlib
import contextvars
import math

import jax
import jax.numpy as jnp
import numpy as np

import repro.core.quantize as qz

__all__ = [
    "ProbeCollector", "collect", "collecting", "announce", "probe_qlinear",
    "index_stats", "saturation_rate", "sqnr_db", "outlier_energy_fraction",
    "topk_overlap", "activation_moments", "activation_stats", "drift_score",
    "QualityMonitor", "site_tap",
]

_EPS = 1e-12

_COLLECT: contextvars.ContextVar["ProbeCollector | None"] = contextvars.ContextVar(
    "repro_numerics_collect", default=None
)


# ---------------------------------------------------------------------------
# collection context (trace-time; mirrors calibration._CAPTURE)
# ---------------------------------------------------------------------------

class ProbeCollector:
    """Accumulates per-projection probe stats during ONE (traced) forward.

    ``mask``: optional token-validity weights broadcastable to each
    activation's leading (token) dims — the packed serving grid passes
    ``positions >= 0`` so padded cells contribute exactly zero to every stat.
    ``out`` maps ``"<site>/<stat>"`` to (traced) scalars or small arrays;
    sites are ``"<NNN>.<tap>"`` in forward order (``announce`` numbers them),
    so a scan-unrolled model gets one site per layer per projection.
    """

    def __init__(self, mask=None):
        self.mask = mask
        self.out: dict[str, jax.Array] = {}
        self._site: str | None = None
        self._n = 0

    def announce(self, tap: str) -> None:
        self._site = f"{self._n:03d}.{tap}"
        self._n += 1

    def site(self) -> str:
        if self._site is None:  # direct qlinear_apply call (no dense_apply tap)
            self._site = f"{self._n:03d}.proj"
            self._n += 1
        return self._site

    def emit(self, stats: dict) -> None:
        site = self.site()
        for k, v in stats.items():
            self.out[f"{site}/{k}"] = v
        self._site = None  # one emit per announce


def collecting() -> bool:
    """True iff a probe collector is active (jit-static: decided at trace)."""
    return _COLLECT.get() is not None


@contextlib.contextmanager
def collect(mask=None):
    """Install a :class:`ProbeCollector` for the enclosed forward; yields it.
    Safe inside a traced function — the stats it accumulates are tracers the
    caller returns as jit outputs."""
    col = ProbeCollector(mask=mask)
    token = _COLLECT.set(col)
    try:
        yield col
    finally:
        _COLLECT.reset(token)


def announce(tap: str | None) -> None:
    """Name the NEXT probed projection (called by ``dense_apply`` with its
    calibration tap name). No-op outside :func:`collect` — and therefore
    invisible to the jaxpr of every non-quality build."""
    col = _COLLECT.get()
    if col is not None and tap is not None:
        col.announce(tap)


# ---------------------------------------------------------------------------
# pure probe reductions (device-side; each has a numpy oracle in tests)
# ---------------------------------------------------------------------------

def _token_weights(x: jax.Array, mask) -> jax.Array:
    """(leading token dims,) f32 validity weights for ``x`` (..., K)."""
    if mask is None:
        return jnp.ones(x.shape[:-1], jnp.float32)
    return jnp.broadcast_to(mask, x.shape[:-1]).astype(jnp.float32)


def index_stats(idx: jax.Array, n_bins: int, weights=None) -> dict:
    """Codebook-index health: occupancy histogram + derived gauges.

    ``weights``: optional per-element 0/1 weights (masked tokens drop out).
    Returns hist (n_bins,) f32 exact counts, util = fraction of bins hit,
    dead = bins never hit, entropy = index entropy normalized to [0, 1]
    (1 = uniform use of all 2^n centroids, 0 = single-centroid collapse).
    """
    from repro.kernels import ops as kops

    hist = kops.index_histogram(idx, n_bins, weights=weights)
    total = jnp.maximum(hist.sum(), _EPS)
    p = hist / total
    ent = -jnp.sum(p * jnp.log(jnp.maximum(p, _EPS)))
    norm = math.log(n_bins) if n_bins > 1 else 1.0
    return {
        "hist": hist,
        "util": (hist > 0).mean(),
        "dead": (hist == 0).sum().astype(jnp.float32),
        "entropy": (ent / norm).astype(jnp.float32),
    }


def saturation_rate(x: jax.Array, codebook: jax.Array,
                    scale_mode: str = "rms", mask=None) -> jax.Array:
    """Fraction of (masked) elements whose normalized value x/s falls outside
    the codebook's centroid range — the share of the tensor the codebook
    cannot represent without clipping to an extreme centroid."""
    wm = _token_weights(x, mask)
    xf = x.astype(jnp.float32)
    s = qz.token_scale(x, scale_mode)
    xn = xf / s
    book = codebook.astype(jnp.float32)
    sat = ((xn < book[0]) | (xn > book[-1])).astype(jnp.float32)
    denom = jnp.maximum(wm.sum() * x.shape[-1], _EPS)
    return (sat * wm[..., None]).sum() / denom


def sqnr_db(x: jax.Array, qa: qz.QuantizedActivation, mask=None) -> jax.Array:
    """Main-branch signal-to-quantization-noise ratio in dB (before outlier
    compensation): 10 log10(sum x^2 / sum (x - q(x))^2) over masked tokens."""
    wm = _token_weights(x, mask)[..., None]
    xf = x.astype(jnp.float32)
    err = xf - qz.dequantize_activation(qa)
    sig = (jnp.square(xf) * wm).sum()
    noise = jnp.maximum((jnp.square(err) * wm).sum(), _EPS)
    return (10.0 * jnp.log10(jnp.maximum(sig, _EPS) / noise)).astype(jnp.float32)


def outlier_energy_fraction(x: jax.Array, outs, mask=None) -> jax.Array:
    """Orizuru effectiveness: fraction of the pre-quantization tensor energy
    sitting in the detected top-k channels (paper budget: 0.5% + 0.5% per
    side should carry the heavy tails — this gauge says whether it does)."""
    wm = _token_weights(x, mask)
    xf = x.astype(jnp.float32)
    total = jnp.maximum((jnp.square(xf) * wm[..., None]).sum(), _EPS)
    captured = (jnp.square(outs.values) * outs.mask * wm[..., None]).sum()
    return (captured / total).astype(jnp.float32)


def topk_overlap(outs, x: jax.Array, k: int, mask=None) -> jax.Array:
    """Mean per-token overlap |detected ∩ exact lax.top_k| / 2k between the
    routed detector's channel set and the exact dual top-k — 1.0 when the
    detection kernel honours its bit-identity contract."""
    from repro.core import outlier as ol

    exact = ol.detect_outliers_topk(x.astype(jnp.float32), k)
    hit = (outs.channels[..., :, None] == exact.channels[..., None, :]).any(-1)
    wm = _token_weights(x, mask)
    per_tok = hit.astype(jnp.float32).mean(-1)
    return ((per_tok * wm).sum() / jnp.maximum(wm.sum(), _EPS)).astype(jnp.float32)


def activation_moments(x: jax.Array, mask=None) -> dict:
    """Live activation stats in the same vocabulary as the calibration-time
    :func:`activation_stats` (mask-weighted): mean, rms, mean/max per-token
    absmax, and the effective token count."""
    wm = _token_weights(x, mask)
    xf = x.astype(jnp.float32)
    n_el = jnp.maximum(wm.sum() * x.shape[-1], _EPS)
    am = jnp.max(jnp.abs(xf), axis=-1)  # per token
    n_tok = jnp.maximum(wm.sum(), _EPS)
    return {
        "act_mean": (xf * wm[..., None]).sum() / n_el,
        "act_rms": jnp.sqrt((jnp.square(xf) * wm[..., None]).sum() / n_el),
        "act_absmax_mean": (am * wm).sum() / n_tok,
        "act_absmax_max": jnp.max(am * wm),
        "act_tokens": wm.sum(),
    }


# ---------------------------------------------------------------------------
# the qlinear_apply hook (active only under collect())
# ---------------------------------------------------------------------------

def probe_qlinear(p, x: jax.Array, *, qa, outs, k_out: int, dynamic: bool,
                  scale_mode: str, tier: str) -> None:
    """Emit one projection's quant-health probes into the active collector.

    Called from ``qlinear_apply`` AFTER both branches ran, with whatever
    intermediates the routed path produced: ``qa`` may be None on the fused
    Pallas route (indices never left VMEM) — the probe recomputes it, which
    is extra work the ``quality`` level explicitly accepts; ``outs`` is None
    when the outlier branch is off. The layer's output is never touched.
    """
    col = _COLLECT.get()
    if col is None:
        return
    mask = col.mask
    if qa is None:
        qa = qz.quantize_activation(x, p.act_codebook, scale_mode)
    wm_el = _token_weights(x, mask)[..., None]
    n_act = p.act_codebook.shape[0]
    a = index_stats(qa.idx, n_act,
                    weights=jnp.broadcast_to(wm_el, qa.idx.shape))
    w = index_stats(p.qw.indices, p.qw.codebook.shape[0])
    stats = {
        "a_hist": a["hist"], "a_util": a["util"], "a_dead": a["dead"],
        "a_entropy": a["entropy"],
        "a_sat": saturation_rate(x, p.act_codebook, scale_mode, mask),
        "sqnr_db": sqnr_db(x, qa, mask),
        "w_hist": w["hist"], "w_util": w["util"], "w_dead": w["dead"],
        "w_entropy": w["entropy"],
        **activation_moments(x, mask),
    }
    if outs is not None and k_out > 0:
        stats["out_energy"] = outlier_energy_fraction(x, outs, mask)
        if dynamic:
            stats["out_overlap"] = topk_overlap(outs, x, k_out, mask)
    col.emit(stats)


# ---------------------------------------------------------------------------
# calibration-time stats + drift scoring (host-side)
# ---------------------------------------------------------------------------

def activation_stats(acts) -> dict:
    """Summary stats of a (tokens, K) calibration-activation tensor, in the
    JSON vocabulary the artifact manifest persists (``save_quantized``'s
    ``calib_stats``): mean/rms plus per-token absmax quantiles."""
    x = np.asarray(jax.device_get(acts), np.float32)
    x = x.reshape(-1, x.shape[-1])
    am = np.max(np.abs(x), axis=-1)
    return {
        "mean": float(x.mean()),
        "rms": float(np.sqrt(np.mean(np.square(x)))),
        "absmax_mean": float(am.mean()),
        "absmax_q50": float(np.quantile(am, 0.5)),
        "absmax_q99": float(np.quantile(am, 0.99)),
        "absmax_max": float(am.max()),
        "tokens": int(x.shape[0]),
        "dim": int(x.shape[1]),
    }


def drift_score(live: dict, calib: dict) -> float:
    """Scale-free distance between live and calibration activation stats:
    the worst of the mean / rms / absmax-mean shifts, each normalized by the
    calibration scale (rms for the central stats, absmax_mean for the tail).
    0 = distributions agree; ~1 = shifted by a full calibration scale."""
    rms_c = max(abs(float(calib.get("rms", 0.0))), 1e-6)
    am_c = max(abs(float(calib.get("absmax_mean", rms_c))), 1e-6)
    return max(
        abs(float(live.get("mean", 0.0)) - float(calib.get("mean", 0.0))) / rms_c,
        abs(float(live.get("rms", 0.0)) - float(calib.get("rms", 0.0))) / rms_c,
        abs(float(live.get("absmax_mean", 0.0))
            - float(calib.get("absmax_mean", 0.0))) / am_c,
    )


def site_tap(site: str) -> str:
    """``"003.attn.q" -> "attn.q"`` — strip the forward-order prefix so a
    live probe site can be matched against calibration tap names (which are
    projection-scoped, shared across a scanned stack's layers)."""
    head, _, tail = site.partition(".")
    return tail if head.isdigit() and tail else site


# stat key emitted by probe_qlinear -> registry gauge family (per-site gauges
# are named "<family>.<site>"; array-valued stats never become gauges)
_GAUGE_OF = {
    "a_util": "numerics_a_codebook_util",
    "a_dead": "numerics_a_dead_centroids",
    "a_entropy": "numerics_a_index_entropy",
    "a_sat": "numerics_a_saturation",
    "sqnr_db": "numerics_sqnr_db",
    "w_util": "numerics_w_codebook_util",
    "w_dead": "numerics_w_dead_centroids",
    "w_entropy": "numerics_w_index_entropy",
    "out_energy": "numerics_outlier_energy_captured",
    "out_overlap": "numerics_outlier_topk_overlap",
}


class QualityMonitor:
    """Host-side sink for probed packed steps: registry gauges + drift alarms.

    ``ingest`` takes one probed step's flat ``{site/stat: value}`` dict
    (device_get'd), publishes per-site gauges, scores per-site drift against
    calibration stats (or, absent those, against the first sampled step's
    own stats — a self-baseline, so a cold deployment still detects
    mid-flight shifts), and raises the alarm counter when a site's score
    exceeds ``drift_threshold`` OR spikes against its own running median
    (a :class:`repro.distributed.fault_tolerance.StepMonitor` per site — the
    same straggler rule the cluster posture uses for step times, applied to
    the drift series).
    """

    def __init__(self, telemetry, calib_stats: dict | None = None,
                 drift_threshold: float = 0.5, window: int = 64,
                 straggler_factor: float = 4.0, min_spike: float = 0.25):
        from repro.distributed.fault_tolerance import StepMonitor

        self.tel = telemetry
        self.calib = dict(calib_stats or {})
        self.baseline: dict[str, dict] = {}
        self.threshold = float(drift_threshold)
        self.min_spike = float(min_spike)
        self._mk_monitor = lambda: StepMonitor(
            window=window, straggler_factor=straggler_factor)
        self.monitors: dict[str, object] = {}
        t = telemetry
        self.c_steps = t.counter("numerics_probe_steps",
                                 "packed steps that ran with probes on")
        self.c_alarms = t.counter("numerics_drift_alarms",
                                  "per-site calibration-drift alarms")
        self.g_drift_max = t.gauge("numerics_drift_max",
                                   "worst per-site drift score, last probe")
        self.g_sqnr_min = t.gauge("numerics_sqnr_db_min",
                                  "worst per-site SQNR (dB), last probe")

    def _calib_for(self, site: str) -> dict | None:
        tap = site_tap(site)
        hit = self.calib.get(tap) or self.calib.get(site)
        if hit is not None:
            return hit
        for name, st in self.calib.items():
            if name.endswith(tap) or tap.endswith(name):
                return st
        return None

    def ingest(self, probes: dict) -> dict:
        """One probed step's host-side values -> gauges/alarms. Returns the
        per-site stat dicts (handy for tests and the bench's drift phase)."""
        sites: dict[str, dict] = {}
        for key, v in probes.items():
            site, _, stat = key.rpartition("/")
            arr = np.asarray(v)
            if arr.ndim:  # hist arrays stay probe-only (not gauge material)
                continue
            sites.setdefault(site, {})[stat] = float(arr)
        drift_max, sqnr_min = 0.0, math.inf
        for site, st in sorted(sites.items()):
            for stat, fam in _GAUGE_OF.items():
                if stat in st:
                    self.tel.gauge(f"{fam}.{site}").set(st[stat])
            if "sqnr_db" in st:
                sqnr_min = min(sqnr_min, st["sqnr_db"])
            live = {"mean": st.get("act_mean", 0.0),
                    "rms": st.get("act_rms", 0.0),
                    "absmax_mean": st.get("act_absmax_mean", 0.0),
                    "absmax_max": st.get("act_absmax_max", 0.0)}
            calib = self._calib_for(site)
            if calib is None:
                calib = self.baseline.setdefault(site, dict(live))
            d = drift_score(live, calib)
            st["drift"] = d
            self.tel.gauge(f"numerics_drift.{site}").set(d)
            mon = self.monitors.get(site)
            if mon is None:
                mon = self.monitors[site] = self._mk_monitor()
            spiked = mon.is_straggler(d) and d > self.min_spike
            mon.record(d)
            if d > self.threshold or spiked:
                self.c_alarms.add()
            drift_max = max(drift_max, d)
        self.c_steps.add()
        self.g_drift_max.set(drift_max)
        if sqnr_min < math.inf:
            self.g_sqnr_min.set(sqnr_min)
        qc = getattr(self.tel, "quality_counter", None)
        if qc is not None:  # Perfetto counter tracks (quality over time)
            qc("numerics_drift_max", drift_max)
            if sqnr_min < math.inf:
                qc("numerics_sqnr_db_min", sqnr_min)
        return sites
