"""Dual-side (weight + activation) K-Means quantization (paper §III-A).

Weights  : n-bit K-Means, ONE codebook per weight matrix, per-output-channel
           scale, no outlier protection.
Activations: n-bit K-Means, per-token scale, codebook learned OFFLINE on a
           calibration set (paper Fig. 5 shows offline==online centroids after
           normalization; per-token *scales* stay dynamic).

Storage formats are honest about bytes (this feeds the roofline): weight
indices are packed two-4-bit-per-uint8 in HBM; codebooks are 2^n fp32 scalars;
scales are fp32 vectors.

Interpretation note (recorded in DESIGN.md): the paper says "each token has its
own set of quantization centroids and scaling factors" learned offline. A
literal per-unseen-token offline codebook is impossible; following the paper's
own Fig. 5 evidence we use an offline codebook in *scale-normalized* space plus
a dynamic per-token scale. Default scale is the token RMS (robust to the very
outliers the outlier branch compensates); ``absmax`` is available for ablation.
"""

from __future__ import annotations

import dataclasses
from functools import partial
from typing import Literal

import jax
import jax.numpy as jnp

from repro.core import codebook as cb

__all__ = [
    "QuantizedWeight",
    "QuantizedActivation",
    "pack_int4",
    "unpack_int4",
    "quantize_weight",
    "dequantize_weight",
    "token_scale",
    "quantize_activation",
    "dequantize_activation",
    "fit_activation_codebook",
]

ScaleMode = Literal["rms", "absmax"]


# ---------------------------------------------------------------------------
# int4 packing
# ---------------------------------------------------------------------------

def pack_int4(idx: jax.Array) -> jax.Array:
    """Pack 4-bit indices pairwise along the last axis into uint8.

    Last axis must be even. ``packed[..., i] = idx[..., 2i] | idx[..., 2i+1]<<4``.
    """
    if idx.shape[-1] % 2:
        raise ValueError(f"last axis must be even for int4 packing, got {idx.shape}")
    lo = idx[..., 0::2].astype(jnp.uint8)
    hi = idx[..., 1::2].astype(jnp.uint8)
    return lo | (hi << 4)


def unpack_int4(packed: jax.Array) -> jax.Array:
    """Inverse of :func:`pack_int4`; returns int32 indices."""
    lo = (packed & 0xF).astype(jnp.int32)
    hi = (packed >> 4).astype(jnp.int32)
    return jnp.stack([lo, hi], axis=-1).reshape(*packed.shape[:-1], packed.shape[-1] * 2)


# ---------------------------------------------------------------------------
# Quantized containers (pytrees)
# ---------------------------------------------------------------------------

@partial(
    jax.tree_util.register_dataclass,
    data_fields=["packed", "codebook", "scale"],
    meta_fields=["shape", "nbits"],
)
@dataclasses.dataclass(frozen=True)
class QuantizedWeight:
    """K-Means-quantized weight matrix of logical shape ``shape = (K, N)``.

    packed   : uint8. nbits <= 4: (K, N//2) — two 4-bit codebook indices per
               byte (3-bit codebooks still use nibble packing; the wasted bit
               is accounted for in benchmarks). nbits in (5..8] — the
               mixed-precision W8 tier — stores one index per byte, (K, N).
    codebook : fp32 (2^nbits,) — sorted centroids, shared by the whole matrix.
    scale    : fp32 (N,)       — per-output-channel scale.
    """

    packed: jax.Array
    codebook: jax.Array
    scale: jax.Array
    shape: tuple[int, int]
    nbits: int

    @property
    def indices(self) -> jax.Array:
        """Unpacked int32 index matrix, shape ``(K, N)``."""
        if self.nbits <= 4:
            return unpack_int4(self.packed)
        return self.packed.astype(jnp.int32)

    def hbm_bytes(self) -> int:
        k, n = self.shape
        idx_bytes = k * n // 2 if self.nbits <= 4 else k * n
        return idx_bytes + self.codebook.size * 4 + n * 4


@partial(
    jax.tree_util.register_dataclass,
    data_fields=["idx", "scale", "codebook"],
    meta_fields=["nbits"],
)
@dataclasses.dataclass(frozen=True)
class QuantizedActivation:
    """Per-token quantized activations.

    idx      : int32 (..., K) codebook indices (kept unpacked here: in the
               fused inference path indices exist only in VMEM; packed storage
               is used by the quantized KV cache).
    scale    : fp32 (..., 1) per-token scale.
    codebook : fp32 (2^nbits,) shared offline-learned centroids
               (normalized space).
    """

    idx: jax.Array
    scale: jax.Array
    codebook: jax.Array
    nbits: int


# ---------------------------------------------------------------------------
# Weight quantization (PTQ)
# ---------------------------------------------------------------------------

@partial(jax.jit, static_argnames=("nbits", "iters", "method"))
def quantize_weight(w: jax.Array, nbits: int = 4, iters: int = 25,
                    method: str = "kmeans") -> QuantizedWeight:
    """Post-training quantization of a ``(K, N)`` weight matrix.

    Per-output-channel absmax scale; method="kmeans" fits a single learned
    codebook on the normalized entries (paper §III-A); method="uniform" uses
    an RTN-style evenly spaced grid (the INT-WAQ baseline of Table III).
    """
    k, n = w.shape
    if nbits > 8:
        raise ValueError(f"weight codebooks top out at 8 bits, got {nbits}")
    scale = jnp.maximum(jnp.max(jnp.abs(w), axis=0), 1e-12)  # (N,)
    wn = (w / scale[None, :]).astype(jnp.float32)
    if method == "kmeans":
        book = cb.kmeans_fit(wn, 2**nbits, iters=iters)
    elif method == "uniform":
        book = jnp.linspace(-1.0, 1.0, 2**nbits)
    else:
        raise ValueError(method)
    idx = cb.assign_via_boundaries(wn, book)
    if nbits <= 4:
        if n % 2:
            raise ValueError("N must be even to nibble-pack along output channels")
        packed = pack_int4(idx)
    else:  # 5..8 bits: one index per byte
        packed = idx.astype(jnp.uint8)
    return QuantizedWeight(
        packed=packed, codebook=book, scale=scale.astype(jnp.float32),
        shape=(k, n), nbits=nbits,
    )


def dequantize_weight(qw: QuantizedWeight, dtype=jnp.float32) -> jax.Array:
    """W~[k, n] = C[idx[k, n]] * scale[n]."""
    return (qw.codebook[qw.indices] * qw.scale[None, :]).astype(dtype)


# ---------------------------------------------------------------------------
# Activation quantization
# ---------------------------------------------------------------------------

def token_scale(x: jax.Array, mode: ScaleMode = "rms") -> jax.Array:
    """Per-token scale over the last (channel) axis, shape ``(..., 1)``."""
    if mode == "rms":
        s = jnp.sqrt(jnp.mean(jnp.square(x.astype(jnp.float32)), axis=-1, keepdims=True))
    elif mode == "absmax":
        s = jnp.max(jnp.abs(x.astype(jnp.float32)), axis=-1, keepdims=True)
    else:
        raise ValueError(mode)
    return jnp.maximum(s, 1e-12)


def quantize_activation(
    x: jax.Array,
    codebook: jax.Array,
    scale_mode: ScaleMode = "rms",
) -> QuantizedActivation:
    """Quantize ``(..., K)`` activations against an offline codebook.

    bf16 inputs (the production serving dtype) use the fused sum-of-compares
    rank — the SAME formulation as the Pallas Clustering-Unit kernel —
    against per-token-SCALED boundaries: a pure elementwise chain XLA fuses
    to zero intermediates, with an int8 index. The searchsorted path
    materialized f32 x/s + int32 idx + binary-search gathers: 3.2 GB/device
    PER PROJECTION at 32k prefill (EXPERIMENTS §Perf P1, 73 -> 20 GB).
    f32 inputs keep the exact searchsorted path (bit-equal to argmin, which
    the tests assert).
    """
    s = token_scale(x, scale_mode)
    nbits = int(codebook.shape[0]).bit_length() - 1
    if x.dtype == jnp.bfloat16:
        b = cb.boundaries_from_centroids(codebook)
        idx = jnp.zeros(x.shape, jnp.int8)
        xf = x.astype(jnp.float32)  # fused into the compares, never stored
        for i in range(b.shape[0]):
            idx += (xf >= s * b[i]).astype(jnp.int8)
        return QuantizedActivation(idx=idx, scale=s, codebook=codebook, nbits=nbits)
    idx = cb.assign_via_boundaries((x / s).astype(jnp.float32), codebook)
    return QuantizedActivation(idx=idx, scale=s, codebook=codebook, nbits=nbits)


def dequantize_activation(qa: QuantizedActivation, dtype=jnp.float32) -> jax.Array:
    return (qa.codebook[qa.idx] * qa.scale).astype(dtype)


def fit_activation_codebook(
    samples: jax.Array,
    nbits: int = 4,
    fisher: jax.Array | None = None,
    scale_mode: ScaleMode = "rms",
    iters: int = 25,
    method: str = "kmeans",
) -> jax.Array:
    """Offline activation-codebook learning (paper §III-A, Fig. 17).

    ``samples``: (tokens, K) calibration activations. ``fisher``: optional
    per-element Fisher-information weights (same shape) — the paper's
    weighted-K-Means. Centroids are fit in per-token-normalized space.
    method="uniform" gives the RTN/INT-WAQ activation grid baseline.
    """
    s = token_scale(samples, scale_mode)
    xn = (samples / s).astype(jnp.float32)
    if method == "uniform":
        lim = jnp.max(jnp.abs(xn))
        return jnp.linspace(-lim, lim, 2**nbits)
    w = None if fisher is None else fisher.astype(jnp.float32)
    return cb.kmeans_fit(xn, 2**nbits, w=w, iters=iters)
