"""KLLM/OASIS core: K-Means dual-side quantization + LUT-GEMM + outlier compensation.

Public API re-exports. See DESIGN.md §3 for the layer map.
"""

from repro.core.codebook import (
    assign,
    assign_via_boundaries,
    boundaries_from_centroids,
    kmeans_fit,
    quantile_init,
)
from repro.core.lut_gemm import build_lut, lut_gemm, lut_gemm_counting
from repro.core.outlier import (
    OutlierSet,
    compensate_gather,
    compensate_scatter,
    detect_outliers_static,
    detect_outliers_topk,
    num_outliers,
    orizuru_comparisons,
    outlier_residuals,
    static_thresholds,
)
from repro.core.artifact import (
    QuantizedArtifact,
    load_calib_stats,
    load_quantized,
    save_quantized,
)
from repro.core.numerics import (
    QualityMonitor,
    activation_stats,
    drift_score,
    probe_qlinear,
)
from repro.core.numerics import collect as collect_probes
from repro.core.qlinear import QLinearConfig, QLinearParams, qlinear_apply, quantize_linear
from repro.core.quantize import (
    QuantizedActivation,
    QuantizedWeight,
    dequantize_activation,
    dequantize_weight,
    fit_activation_codebook,
    pack_int4,
    quantize_activation,
    quantize_weight,
    token_scale,
    unpack_int4,
)
from repro.core.quantspec import QuantRule, QuantSpec

__all__ = [k for k in dir() if not k.startswith("_")] + ["quantize_model"]


def __getattr__(name):  # PEP 562: quantize_model lives in repro.models.model
    if name == "quantize_model":
        from repro.models.model import quantize_model

        return quantize_model
    raise AttributeError(f"module 'repro.core' has no attribute {name!r}")
