"""Pallas TPU kernel: activation clustering (the paper's Clustering Unit, Fig. 9b).

Maps each activation to its nearest centroid index using the boundary values
b_i = (c_i + c_{i+1})/2. The ASIC uses a log2(2^n)-level binary search tree to
minimize *comparator count*; on the TPU VPU the comparator is a full-width
vector op, so the adaptation that minimizes *instructions* is a sum of
boundary comparisons:

    idx = sum_i [x >= b_i]

— 2^n - 1 vectorized compares with no gathers or data-dependent control flow
(15 for 4-bit, 7 for 3-bit). This is exactly equivalent to the binary search
(both compute the rank of x among the boundaries); tests assert equality with
``searchsorted`` and with argmin-distance assignment.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

__all__ = ["bucketize_kernel_call"]


def _kernel(x_ref, b_ref, o_ref, *, n_boundaries: int):
    x = x_ref[...]
    b = b_ref[...]
    idx = jnp.zeros(x.shape, jnp.int32)
    for i in range(n_boundaries):
        idx += (x >= b[i]).astype(jnp.int32)
    o_ref[...] = idx


def bucketize_kernel_call(
    x: jax.Array,  # (M, K) f32
    boundaries: jax.Array,  # (2^n - 1,) f32 sorted
    *,
    block_m: int = 256,
    block_k: int = 512,
    interpret: bool = True,
) -> jax.Array:
    m, k = x.shape
    bm, bk = min(block_m, m), min(block_k, k)
    pm, pk = (-m) % bm, (-k) % bk
    if pm or pk:
        x = jnp.pad(x, ((0, pm), (0, pk)))
    out = pl.pallas_call(
        functools.partial(_kernel, n_boundaries=int(boundaries.shape[0])),
        grid=((m + pm) // bm, (k + pk) // bk),
        in_specs=[
            pl.BlockSpec((bm, bk), lambda i, j: (i, j)),
            pl.BlockSpec(boundaries.shape, lambda i, j: (0,)),
        ],
        out_specs=pl.BlockSpec((bm, bk), lambda i, j: (i, j)),
        out_shape=jax.ShapeDtypeStruct((m + pm, k + pk), jnp.int32),
        interpret=interpret,
    )(x.astype(jnp.float32), boundaries.astype(jnp.float32))
    return out[:m, :k]
