"""Jit'd public wrappers around the Pallas kernels.

These adapt the kernels to the core library's types (QuantizedActivation /
QuantizedWeight / OutlierSet), handle arbitrary leading batch dims, apply the
rank-1 scales, and auto-select interpret mode off-TPU (the container is
CPU-only; on a real TPU ``interpret=False`` compiles the same kernels).
"""

from __future__ import annotations

from functools import partial

import jax
import jax.numpy as jnp

from repro.core.codebook import boundaries_from_centroids
from repro.core.outlier import OutlierSet
from repro.core.quantize import QuantizedActivation, QuantizedWeight
from repro.kernels.bucketize import bucketize_kernel_call
from repro.kernels.lut_gemm import lut_gemm_kernel_call
from repro.kernels.topk_outlier import topk_outlier_kernel_call

__all__ = ["lut_gemm", "bucketize", "topk_outlier", "should_interpret"]


def should_interpret() -> bool:
    return jax.default_backend() != "tpu"


def _flatten_leading(x: jax.Array) -> tuple[jax.Array, tuple[int, ...]]:
    lead = x.shape[:-1]
    return x.reshape(-1, x.shape[-1]), lead


@partial(jax.jit, static_argnames=("out_dtype",))
def lut_gemm(qa: QuantizedActivation, qw: QuantizedWeight, out_dtype=jnp.float32) -> jax.Array:
    """Kernel-backed factorized LUT-GEMM with scales. Matches core.lut_gemm."""
    idx2d, lead = _flatten_leading(qa.idx)
    y = lut_gemm_kernel_call(
        idx2d.astype(jnp.int32),
        qw.packed,
        qa.codebook.astype(jnp.float32),
        qw.codebook.astype(jnp.float32),
        interpret=should_interpret(),
    )
    y = y.reshape(*lead, qw.shape[1])
    return (y * qa.scale * qw.scale).astype(out_dtype)


@jax.jit
def bucketize(x: jax.Array, codebook: jax.Array) -> jax.Array:
    """Nearest-centroid indices via the Clustering-Unit kernel."""
    x2d, lead = _flatten_leading(x)
    idx = bucketize_kernel_call(
        x2d, boundaries_from_centroids(codebook), interpret=should_interpret()
    )
    return idx.reshape(*lead, x.shape[-1])


@partial(jax.jit, static_argnames=("k",))
def topk_outlier(x: jax.Array, k: int) -> OutlierSet:
    """Orizuru kernel -> OutlierSet (top-k then bottom-k, mask all-ones)."""
    x2d, lead = _flatten_leading(x)
    hi_v, hi_i, lo_v, lo_i = topk_outlier_kernel_call(
        x2d, k, interpret=should_interpret()
    )
    values = jnp.concatenate([hi_v, lo_v], axis=-1).reshape(*lead, 2 * k)
    channels = jnp.concatenate([hi_i, lo_i], axis=-1).reshape(*lead, 2 * k)
    return OutlierSet(values=values, channels=channels, mask=jnp.ones_like(values))
