"""Jit'd public wrappers around the Pallas kernels.

These adapt the kernels to the core library's types (QuantizedActivation /
QuantizedWeight / OutlierSet), handle arbitrary leading batch dims, apply the
rank-1 scales, and auto-select interpret mode off-TPU (the container is
CPU-only; on a real TPU ``interpret=False`` compiles the same kernels).

``lut_gemm`` dispatches both weight tiers (nibble-packed <= 4 bits, byte-
packed 5..8 bits); ``lut_gemm_fused`` is the serving hot path: raw
activations in, quantization fused into the GEMM tile (no idx HBM
roundtrip). Block sizes come from explicit ``blocks=`` overrides, else from
the :func:`autotune_lut_blocks` cache (populated by an explicit sweep — run
it before the first traced call for a shape; benchmarks do), else kernel
defaults.
"""

from __future__ import annotations

import time
from functools import partial

import jax
import jax.numpy as jnp

from repro.core.codebook import boundaries_from_centroids
from repro.core.outlier import OutlierSet
from repro.core.quantize import QuantizedActivation, QuantizedWeight, token_scale
from repro.kernels.bucketize import bucketize_kernel_call
from repro.kernels.lut_gemm import fused_lut_gemm_kernel_call, lut_gemm_kernel_call
from repro.kernels.topk_outlier import (
    streaming_quantize_outlier_kernel_call,
    topk_outlier_kernel_call,
)

__all__ = ["lut_gemm", "lut_gemm_fused", "bucketize", "topk_outlier",
           "quantize_outlier_streaming", "should_interpret",
           "autotune_lut_blocks", "index_histogram"]


def should_interpret() -> bool:
    return jax.default_backend() != "tpu"


def _flatten_leading(x: jax.Array) -> tuple[jax.Array, tuple[int, ...]]:
    lead = x.shape[:-1]
    return x.reshape(-1, x.shape[-1]), lead


# ---------------------------------------------------------------------------
# block-size autotune (per (M, K, N, tier, fused) shape key)
# ---------------------------------------------------------------------------

# shape key -> (block_m, block_n, block_k). Consulted at TRACE time by the
# wrappers below when no explicit override is given; a jitted caller that
# traced before the sweep keeps its compiled defaults (jit caches by shape).
_BLOCK_CACHE: dict[tuple, tuple[int, int, int]] = {}

_CANDIDATES = (
    (128, 128, 512),
    (128, 128, 256),
    (128, 256, 256),
    (256, 128, 128),
    (64, 128, 256),
    (8, 128, 512),
)


def _block_key(m: int, k: int, n: int, w_nbits: int, a_nbits: int,
               fused: bool) -> tuple:
    return (m, k, n, w_nbits, a_nbits, fused)


def _cached_blocks(m, k, n, w_nbits, a_nbits, fused) -> dict:
    hit = _BLOCK_CACHE.get(_block_key(m, k, n, w_nbits, a_nbits, fused))
    if hit is None:
        return {}
    bm, bn, bk = hit
    return {"block_m": bm, "block_n": bn, "block_k": bk}


def autotune_lut_blocks(
    x: jax.Array,
    codebook: jax.Array,
    qw: QuantizedWeight,
    *,
    fused: bool = True,
    candidates: tuple[tuple[int, int, int], ...] = _CANDIDATES,
    reps: int = 3,
) -> tuple[int, int, int]:
    """Small grid sweep over (block_m, block_n, block_k) for one GEMM shape.

    Times each candidate end-to-end through the jitted wrapper (compile
    excluded via a warmup call) and caches the winner; subsequent
    ``lut_gemm``/``lut_gemm_fused`` traces for the same shape pick it up.
    Returns the winning (bm, bn, bk).
    """
    x2d, _ = _flatten_leading(x)
    m, k = x2d.shape
    n = qw.shape[1]
    a_nbits = int(codebook.shape[0]).bit_length() - 1
    best, best_t = None, float("inf")
    for bm, bn, bk in candidates:
        blocks = (bm, bn, bk)
        if fused:
            fn = partial(lut_gemm_fused, x, codebook, qw, blocks=blocks)
        else:
            qa = _quantize_for_tune(x2d, codebook)
            fn = partial(lut_gemm, qa, qw, blocks=blocks)
        jax.block_until_ready(fn())  # compile + warmup
        t0 = time.perf_counter()
        for _ in range(reps):
            out = fn()
        jax.block_until_ready(out)
        dt = (time.perf_counter() - t0) / reps
        if dt < best_t:
            best, best_t = blocks, dt
    _BLOCK_CACHE[_block_key(m, k, n, qw.nbits, a_nbits, fused)] = best
    return best


def _quantize_for_tune(x2d, codebook):
    from repro.core.quantize import quantize_activation

    return quantize_activation(x2d, codebook)


@partial(jax.jit, static_argnames=("out_dtype", "blocks"))
def lut_gemm(qa: QuantizedActivation, qw: QuantizedWeight,
             out_dtype=jnp.float32,
             blocks: tuple[int, int, int] | None = None) -> jax.Array:
    """Kernel-backed factorized LUT-GEMM with scales. Matches core.lut_gemm.

    Dispatches on the weight tier: nibble-packed (<= 4 bits) or byte-packed
    (5..8 bits, the mixed-precision W8 tier).
    """
    idx2d, lead = _flatten_leading(qa.idx)
    m, k = idx2d.shape
    kw = (dict(zip(("block_m", "block_n", "block_k"), blocks)) if blocks
          else _cached_blocks(m, k, qw.shape[1], qw.nbits, qa.nbits, False))
    y = lut_gemm_kernel_call(
        idx2d.astype(jnp.int32),
        qw.packed,
        qa.codebook.astype(jnp.float32),
        qw.codebook.astype(jnp.float32),
        byte_packed=qw.nbits > 4,
        interpret=should_interpret(),
        **kw,
    )
    y = y.reshape(*lead, qw.shape[1])
    return (y * qa.scale * qw.scale).astype(out_dtype)


@partial(jax.jit, static_argnames=("scale_mode", "out_dtype", "blocks"))
def lut_gemm_fused(x: jax.Array, codebook: jax.Array, qw: QuantizedWeight,
                   scale_mode: str = "rms", out_dtype=jnp.float32,
                   blocks: tuple[int, int, int] | None = None) -> jax.Array:
    """Fused quantize+index-GEMM: raw activations in, scaled output out.

    The per-token scale (a rank-1 full-K reduction XLA fuses) is computed
    here; bucketize + centroid lookup + GEMM happen inside the kernel tile.
    Index selection is bit-identical to ``quantize_activation`` for the
    input dtype (f32: searchsorted form; bf16: sum-of-compares mul form),
    so routing through this path preserves greedy token identity with the
    jnp factorized route.
    """
    x2d, lead = _flatten_leading(x)
    m, k = x2d.shape
    a_nbits = int(codebook.shape[0]).bit_length() - 1
    kw = (dict(zip(("block_m", "block_n", "block_k"), blocks)) if blocks
          else _cached_blocks(m, k, qw.shape[1], qw.nbits, a_nbits, True))
    s = token_scale(x2d, scale_mode)  # (M, 1) f32
    book = codebook.astype(jnp.float32)
    y = fused_lut_gemm_kernel_call(
        x2d, s, qw.packed,
        boundaries_from_centroids(book), book,
        qw.codebook.astype(jnp.float32),
        byte_packed=qw.nbits > 4,
        mul_form=x.dtype == jnp.bfloat16,
        interpret=should_interpret(),
        **kw,
    )
    y = y.reshape(*lead, qw.shape[1])
    return (y * s.reshape(*lead, 1) * qw.scale).astype(out_dtype)


@partial(jax.jit, static_argnames=("n_bins",))
def index_histogram(idx: jax.Array, n_bins: int, weights=None) -> jax.Array:
    """Occupancy histogram of codebook indices: (n_bins,) f32 scatter-add.

    ``weights`` (optional, broadcast-compatible with ``idx``) lets callers
    mask elements out with 0/1 weights; counts stay integer-exact in f32 up
    to 2^24 elements per bin (numpy oracle: ``np.bincount``). Serves the
    quality-probe layer (core/numerics) — the indices come straight from the
    bucketize/streaming kernels' output, so the histogram audits exactly
    what the LUT-GEMM consumed.
    """
    flat = idx.reshape(-1).astype(jnp.int32)
    if weights is None:
        w = jnp.ones(flat.shape, jnp.float32)
    else:
        w = jnp.broadcast_to(weights, idx.shape).reshape(-1).astype(jnp.float32)
    return jnp.zeros((n_bins,), jnp.float32).at[flat].add(w)


@jax.jit
def bucketize(x: jax.Array, codebook: jax.Array) -> jax.Array:
    """Nearest-centroid indices via the Clustering-Unit kernel."""
    x2d, lead = _flatten_leading(x)
    idx = bucketize_kernel_call(
        x2d, boundaries_from_centroids(codebook), interpret=should_interpret()
    )
    return idx.reshape(*lead, x.shape[-1])


@partial(jax.jit, static_argnames=("k",))
def topk_outlier(x: jax.Array, k: int) -> OutlierSet:
    """Orizuru kernel -> OutlierSet (top-k then bottom-k, mask all-ones)."""
    x2d, lead = _flatten_leading(x)
    hi_v, hi_i, lo_v, lo_i = topk_outlier_kernel_call(
        x2d, k, interpret=should_interpret()
    )
    values = jnp.concatenate([hi_v, lo_v], axis=-1).reshape(*lead, 2 * k)
    channels = jnp.concatenate([hi_i, lo_i], axis=-1).reshape(*lead, 2 * k)
    return OutlierSet(values=values, channels=channels, mask=jnp.ones_like(values))


@partial(jax.jit, static_argnames=("k", "scale_mode"))
def quantize_outlier_streaming(
    x: jax.Array, codebook: jax.Array, k: int, scale_mode: str = "rms"
) -> tuple[QuantizedActivation, OutlierSet]:
    """One-pass activation quantize + Orizuru detect (the streaming form).

    Emits the SAME ``QuantizedActivation`` as ``quantize_activation`` (bit-
    identical indices and scale for either input dtype) and the SAME
    ``OutlierSet`` as ``topk_outlier`` on the f32 activations — but reads the
    activation tile once, so dynamic detection adds no extra HBM roundtrip
    at decode shapes.
    """
    x2d, lead = _flatten_leading(x)
    s = token_scale(x2d, scale_mode)  # (M, 1) f32
    book = codebook.astype(jnp.float32)
    mul_form = x.dtype == jnp.bfloat16
    idx, hi_v, hi_i, lo_v, lo_i = streaming_quantize_outlier_kernel_call(
        x2d.astype(jnp.float32), s, boundaries_from_centroids(book), k,
        mul_form=mul_form, interpret=should_interpret(),
    )
    if mul_form:
        idx = idx.astype(jnp.int8)  # quantize_activation's bf16 index dtype
    nbits = int(codebook.shape[0]).bit_length() - 1
    qa = QuantizedActivation(
        idx=idx.reshape(*lead, x.shape[-1]),
        scale=s.reshape(*lead, 1), codebook=codebook, nbits=nbits,
    )
    values = jnp.concatenate([hi_v, lo_v], axis=-1).reshape(*lead, 2 * k)
    channels = jnp.concatenate([hi_i, lo_i], axis=-1).reshape(*lead, 2 * k)
    outs = OutlierSet(values=values, channels=channels,
                      mask=jnp.ones_like(values))
    return qa, outs
