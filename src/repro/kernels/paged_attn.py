"""Pallas TPU kernel: paged-attention gather for continuous-batching serving.

A query *segment* per sequence attends to that sequence's KV blocks through a
block table (vLLM-style paged KV cache, DESIGN.md §2 serving subsystem). The
segment generalizes the original 1-token decode contract: decode is S == 1,
a chunked-prefill slice is S == chunk, and the packed token-budget step runs
B == token_budget rows of S == 1 (each row is one token with its own table).
The kernel is the decode-side analogue of lut_gemm's no-dequantization
property:

  1. the grid is (sequence, block); the *block table is scalar-prefetched* so
     each step's BlockSpec index_map DMAs exactly the pool block the sequence
     owns — non-resident blocks are never touched,
  2. int4 K-Means blocks are unpacked (VPU bit ops) and dequantized via the
     16-way compare-select LUT *in VMEM*; HBM traffic stays bs x kv x hd / 2
     bytes of indices + scales per block,
  3. softmax runs online (flash-style) across a sequence's blocks in f32
     scratch, so per-step VMEM is one block x one segment, not the whole
     context.

Contract (both variants): q (B, S, KV, G, hd); q_pos (B, S) int32 absolute
query positions (< 0 = padded row, fully masked); block_tables (B, max_blk)
int32 with entries < 0 meaning unallocated (masked out via ctx_lens);
ctx_lens (B,) valid context length. Output (B, S, KV, G, hd) f32. Oracles:
``ref.paged_attn_ref`` / ``ref.paged_attn_quant_ref`` (same layout).
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from repro.kernels.lut_gemm import _deq_select

__all__ = ["paged_attn_kernel_call"]

_NEG_INF = float(jnp.finfo(jnp.float32).min)


def _flash_update(s, v, cl, qp, j, bs, window, m_ref, l_ref, acc_ref, o_ref,
                  last):
    """One online-softmax step over a (bs, KV, hd) value block for a whole
    query segment. s: (KV, G, S, bs) scores; qp: (S,) absolute positions.
    ``window > 0`` (static) adds the sliding-window mask term — keys at
    ``<= qp - window`` are dead, matching the ring cache's ``_mask``."""
    kpos = j * bs + jax.lax.broadcasted_iota(jnp.int32, s.shape, 3)
    valid = (kpos < cl) & (kpos <= qp[None, None, :, None])
    if window > 0:
        valid &= kpos > qp[None, None, :, None] - window
    s = jnp.where(valid, s, _NEG_INF)
    m_new = jnp.maximum(m_ref[...], jnp.max(s, axis=-1))
    p = jnp.exp(s - m_new[..., None])  # (KV, G, S, bs)
    alpha = jnp.exp(m_ref[...] - m_new)
    l_ref[...] = l_ref[...] * alpha + jnp.sum(p, axis=-1)
    acc_ref[...] = acc_ref[...] * alpha[..., None] + jnp.einsum(
        "kgst,tkh->kgsh", p, v, preferred_element_type=jnp.float32
    )
    m_ref[...] = m_new

    @pl.when(last)
    def _done():
        o = acc_ref[...] / jnp.maximum(l_ref[...], 1e-30)[..., None]  # (KV,G,S,hd)
        o_ref[0] = o.transpose(2, 0, 1, 3).astype(o_ref.dtype)  # (S,KV,G,hd)


def _init_scratch(m_ref, l_ref, acc_ref):
    @pl.when(pl.program_id(1) == 0)
    def _init():
        m_ref[...] = jnp.full(m_ref.shape, _NEG_INF, jnp.float32)
        l_ref[...] = jnp.zeros_like(l_ref)
        acc_ref[...] = jnp.zeros_like(acc_ref)


def _kernel_bf16(bt_ref, cl_ref, qp_ref, q_ref, k_ref, v_ref, o_ref,
                 m_ref, l_ref, acc_ref, *, bs: int, max_blk: int,
                 softcap: float, window: int):
    _init_scratch(m_ref, l_ref, acc_ref)
    b, j = pl.program_id(0), pl.program_id(1)
    q = q_ref[0].astype(jnp.float32)  # (S, KV, G, hd)
    k = k_ref[0].astype(jnp.float32)  # (bs, KV, hd)
    s = jnp.einsum("skgh,tkh->kgst", q, k, preferred_element_type=jnp.float32)
    s = s * (q.shape[-1] ** -0.5)
    if softcap > 0:
        s = softcap * jnp.tanh(s / softcap)
    _flash_update(s, v_ref[0].astype(jnp.float32), cl_ref[b], qp_ref[0], j, bs,
                  window, m_ref, l_ref, acc_ref, o_ref, j == max_blk - 1)


def _deq_block(idx, scale, book):
    """(bs, KV, hd//2) packed uint8 + (bs, KV, 1) scale -> (bs, KV, hd) f32."""
    lo = _deq_select((idx & 0xF).astype(jnp.int32), book, 16)
    hi = _deq_select((idx >> 4).astype(jnp.int32), book, 16)
    full = jnp.stack([lo, hi], axis=-1).reshape(*idx.shape[:-1], -1)
    return full * scale


def _kernel_quant(bt_ref, cl_ref, qp_ref, q_ref, ki_ref, ks_ref, vi_ref, vs_ref,
                  book_ref, o_ref, m_ref, l_ref, acc_ref,
                  *, bs: int, max_blk: int, softcap: float, window: int):
    _init_scratch(m_ref, l_ref, acc_ref)
    b, j = pl.program_id(0), pl.program_id(1)
    book = book_ref[...]
    q = q_ref[0].astype(jnp.float32)  # (S, KV, G, hd)
    k = _deq_block(ki_ref[0], ks_ref[0], book)  # dequantized in VMEM only
    s = jnp.einsum("skgh,tkh->kgst", q, k, preferred_element_type=jnp.float32)
    s = s * (q.shape[-1] ** -0.5)
    if softcap > 0:
        s = softcap * jnp.tanh(s / softcap)
    _flash_update(s, _deq_block(vi_ref[0], vs_ref[0], book), cl_ref[b], qp_ref[0],
                  j, bs, window, m_ref, l_ref, acc_ref, o_ref, j == max_blk - 1)


def paged_attn_kernel_call(
    q: jax.Array,  # (B, S, KV, G, hd) — a query segment per sequence
    *storage: jax.Array,  # (k_pages, v_pages) | (k_idx, k_scale, v_idx, v_scale, book)
    block_tables: jax.Array,  # (B, max_blk) int32
    ctx_lens: jax.Array,  # (B,) int32
    q_pos: jax.Array,  # (B, S) int32 absolute positions; < 0 = padded row
    softcap: float = 0.0,
    window: int = 0,  # static sliding window; 0 = full causal attention
    interpret: bool = True,
) -> jax.Array:
    """Segmented paged decode/prefill attention; see module docstring."""
    b, sq, kv, g, hd = q.shape
    max_blk = block_tables.shape[1]
    bs = storage[0].shape[1]
    quantized = len(storage) == 5
    if not quantized and len(storage) != 2:
        raise ValueError(f"expected 2 (bf16) or 5 (int4) storage arrays, got {len(storage)}")
    n_blocks = storage[0].shape[0]
    # entries < 0 are unallocated: clamp for the DMA, mask via ctx_lens/q_pos
    bt_flat = jnp.clip(block_tables, 0, n_blocks - 1).reshape(-1)

    block_spec = lambda shape: pl.BlockSpec(
        (1, *shape), lambda bi, j, bt, cl, _mb=max_blk: (bt[bi * _mb + j],) + (0,) * len(shape)
    )
    qp_spec = pl.BlockSpec((1, sq), lambda bi, j, bt, cl: (bi, 0))
    q_spec = pl.BlockSpec((1, sq, kv, g, hd), lambda bi, j, bt, cl: (bi, 0, 0, 0, 0))
    if quantized:
        kernel = _kernel_quant
        in_specs = [
            qp_spec,
            q_spec,
            block_spec((bs, kv, hd // 2)),  # k_idx
            block_spec((bs, kv, 1)),  # k_scale
            block_spec((bs, kv, hd // 2)),  # v_idx
            block_spec((bs, kv, 1)),  # v_scale
            pl.BlockSpec(storage[4].shape, lambda bi, j, bt, cl: (0,)),  # codebook
        ]
    else:
        kernel = _kernel_bf16
        in_specs = [qp_spec, q_spec, block_spec((bs, kv, hd)), block_spec((bs, kv, hd))]

    grid_spec = pltpu.PrefetchScalarGridSpec(
        num_scalar_prefetch=2,
        grid=(b, max_blk),
        in_specs=in_specs,
        out_specs=pl.BlockSpec((1, sq, kv, g, hd),
                               lambda bi, j, bt, cl: (bi, 0, 0, 0, 0)),
        scratch_shapes=[
            pltpu.VMEM((kv, g, sq), jnp.float32),  # running max
            pltpu.VMEM((kv, g, sq), jnp.float32),  # running denominator
            pltpu.VMEM((kv, g, sq, hd), jnp.float32),  # output accumulator
        ],
    )
    return pl.pallas_call(
        functools.partial(kernel, bs=bs, max_blk=max_blk, softcap=softcap,
                          window=window),
        grid_spec=grid_spec,
        out_shape=jax.ShapeDtypeStruct((b, sq, kv, g, hd), jnp.float32),
        interpret=interpret,
    )(bt_flat, ctx_lens, q_pos.astype(jnp.int32), q, *storage)
