"""Pallas TPU kernel: paged-attention gather for continuous-batching decode.

One query token per request attends to its KV blocks through a block table
(vLLM-style paged KV cache, DESIGN.md §2 serving subsystem). The kernel is
the decode-side analogue of lut_gemm's no-dequantization property:

  1. the grid is (request, block); the *block table is scalar-prefetched* so
     each step's BlockSpec index_map DMAs exactly the pool block the request
     owns — non-resident blocks are never touched,
  2. int4 K-Means blocks are unpacked (VPU bit ops) and dequantized via the
     16-way compare-select LUT *in VMEM*; HBM traffic stays bs x kv x hd / 2
     bytes of indices + scales per block,
  3. softmax runs online (flash-style) across a request's blocks in f32
     scratch, so per-step VMEM is one block, not the whole context.

Contract (both variants): q (B, KV, G, hd); block_tables (B, max_blk) int32
with entries < 0 meaning unallocated (masked out via ctx_lens); ctx_lens (B,)
valid context length. Output (B, KV, G, hd) f32. Oracles:
``ref.paged_attn_ref`` / ``ref.paged_attn_quant_ref`` (Sq=1 slice).
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from repro.kernels.lut_gemm import _deq_select

__all__ = ["paged_attn_kernel_call"]

_NEG_INF = float(jnp.finfo(jnp.float32).min)


def _flash_update(s, v, cl, j, bs, m_ref, l_ref, acc_ref, o_ref, last):
    """One online-softmax accumulation step over a (bs, KV, hd) value block."""
    kpos = j * bs + jax.lax.broadcasted_iota(jnp.int32, s.shape, 2)
    s = jnp.where(kpos < cl, s, _NEG_INF)
    m_new = jnp.maximum(m_ref[...], jnp.max(s, axis=-1))
    p = jnp.exp(s - m_new[..., None])  # (KV, G, bs)
    alpha = jnp.exp(m_ref[...] - m_new)
    l_ref[...] = l_ref[...] * alpha + jnp.sum(p, axis=-1)
    acc_ref[...] = acc_ref[...] * alpha[..., None] + jnp.einsum(
        "kgt,tkh->kgh", p, v, preferred_element_type=jnp.float32
    )
    m_ref[...] = m_new

    @pl.when(last)
    def _done():
        o_ref[0] = (acc_ref[...] / jnp.maximum(l_ref[...], 1e-30)[..., None]).astype(
            o_ref.dtype
        )


def _init_scratch(m_ref, l_ref, acc_ref):
    @pl.when(pl.program_id(1) == 0)
    def _init():
        m_ref[...] = jnp.full(m_ref.shape, _NEG_INF, jnp.float32)
        l_ref[...] = jnp.zeros_like(l_ref)
        acc_ref[...] = jnp.zeros_like(acc_ref)


def _kernel_bf16(bt_ref, cl_ref, q_ref, k_ref, v_ref, o_ref, m_ref, l_ref, acc_ref,
                 *, bs: int, max_blk: int, softcap: float):
    _init_scratch(m_ref, l_ref, acc_ref)
    b, j = pl.program_id(0), pl.program_id(1)
    q = q_ref[0].astype(jnp.float32)  # (KV, G, hd)
    k = k_ref[0].astype(jnp.float32)  # (bs, KV, hd)
    s = jnp.einsum("kgh,tkh->kgt", q, k, preferred_element_type=jnp.float32)
    s = s * (q.shape[-1] ** -0.5)
    if softcap > 0:
        s = softcap * jnp.tanh(s / softcap)
    _flash_update(s, v_ref[0].astype(jnp.float32), cl_ref[b], j, bs,
                  m_ref, l_ref, acc_ref, o_ref, j == max_blk - 1)


def _deq_block(idx, scale, book):
    """(bs, KV, hd//2) packed uint8 + (bs, KV, 1) scale -> (bs, KV, hd) f32."""
    lo = _deq_select((idx & 0xF).astype(jnp.int32), book, 16)
    hi = _deq_select((idx >> 4).astype(jnp.int32), book, 16)
    full = jnp.stack([lo, hi], axis=-1).reshape(*idx.shape[:-1], -1)
    return full * scale


def _kernel_quant(bt_ref, cl_ref, q_ref, ki_ref, ks_ref, vi_ref, vs_ref, book_ref,
                  o_ref, m_ref, l_ref, acc_ref,
                  *, bs: int, max_blk: int, softcap: float):
    _init_scratch(m_ref, l_ref, acc_ref)
    b, j = pl.program_id(0), pl.program_id(1)
    book = book_ref[...]
    q = q_ref[0].astype(jnp.float32)
    k = _deq_block(ki_ref[0], ks_ref[0], book)  # dequantized in VMEM only
    s = jnp.einsum("kgh,tkh->kgt", q, k, preferred_element_type=jnp.float32)
    s = s * (q.shape[-1] ** -0.5)
    if softcap > 0:
        s = softcap * jnp.tanh(s / softcap)
    _flash_update(s, _deq_block(vi_ref[0], vs_ref[0], book), cl_ref[b], j, bs,
                  m_ref, l_ref, acc_ref, o_ref, j == max_blk - 1)


def paged_attn_kernel_call(
    q: jax.Array,  # (B, KV, G, hd)
    *storage: jax.Array,  # (k_pages, v_pages) | (k_idx, k_scale, v_idx, v_scale, book)
    block_tables: jax.Array,  # (B, max_blk) int32
    ctx_lens: jax.Array,  # (B,) int32
    softcap: float = 0.0,
    interpret: bool = True,
) -> jax.Array:
    """Single-token paged decode attention; see module docstring."""
    b, kv, g, hd = q.shape
    max_blk = block_tables.shape[1]
    bs = storage[0].shape[1]
    quantized = len(storage) == 5
    if not quantized and len(storage) != 2:
        raise ValueError(f"expected 2 (bf16) or 5 (int4) storage arrays, got {len(storage)}")
    n_blocks = storage[0].shape[0]
    # entries < 0 are unallocated: clamp for the DMA, mask via ctx_lens
    bt_flat = jnp.clip(block_tables, 0, n_blocks - 1).reshape(-1)

    block_spec = lambda shape: pl.BlockSpec(
        (1, *shape), lambda bi, j, bt, cl, _mb=max_blk: (bt[bi * _mb + j],) + (0,) * len(shape)
    )
    q_spec = pl.BlockSpec((1, kv, g, hd), lambda bi, j, bt, cl: (bi, 0, 0, 0))
    if quantized:
        kernel = _kernel_quant
        in_specs = [
            q_spec,
            block_spec((bs, kv, hd // 2)),  # k_idx
            block_spec((bs, kv, 1)),  # k_scale
            block_spec((bs, kv, hd // 2)),  # v_idx
            block_spec((bs, kv, 1)),  # v_scale
            pl.BlockSpec(storage[4].shape, lambda bi, j, bt, cl: (0,)),  # codebook
        ]
    else:
        kernel = _kernel_bf16
        in_specs = [q_spec, block_spec((bs, kv, hd)), block_spec((bs, kv, hd))]

    grid_spec = pltpu.PrefetchScalarGridSpec(
        num_scalar_prefetch=2,
        grid=(b, max_blk),
        in_specs=in_specs,
        out_specs=pl.BlockSpec((1, kv, g, hd), lambda bi, j, bt, cl: (bi, 0, 0, 0)),
        scratch_shapes=[
            pltpu.VMEM((kv, g), jnp.float32),  # running max
            pltpu.VMEM((kv, g), jnp.float32),  # running denominator
            pltpu.VMEM((kv, g, hd), jnp.float32),  # output accumulator
        ],
    )
    return pl.pallas_call(
        functools.partial(kernel, bs=bs, max_blk=max_blk, softcap=softcap),
        grid_spec=grid_spec,
        out_shape=jax.ShapeDtypeStruct((b, kv, g, hd), jnp.float32),
        interpret=interpret,
    )(bt_flat, ctx_lens, q, *storage)
