"""Pallas TPU kernel: Orizuru — dual top-k/bottom-k outlier detection (§IV-D).

The ASIC Orizuru is a two-fold tournament tree (max tree + min tree) with
SHARED LEAF COMPARISONS: the N/2 pairwise compares that initialize the max
tree's first level are reused (reversed) for the min tree, giving
1.5N + 2k·log2(N) comparisons instead of ~3N (or 6N for SpAtten's engine).

TPU adaptation (DESIGN.md §2): the serial pop-one-per-cycle loop is an ASIC
latency trick with no TPU analogue — a vectorized argmax over a vreg-resident
array has O(log N) depth anyway. What we keep is the *shared-pairwise* trick
and the *pair-collapse* structure:

  phase 1 (shared): A = max(x_even, x_odd), B = min(x_even, x_odd)
                    — N/2 compares produce level-1 of BOTH trees;
  phase 2 (pop):    k iterations of argmax over the N/2-wide A-array; a popped
                    pair falls back to its other leaf (B) and then to -inf —
                    exactly the paper's tree-maintenance semantics, k·(N/2)
                    vector-lanes of work but only k sequential steps;
  min side:         the SAME pop routine on (-B, -A) — comparisons reused.

Odd N is handled by padding one lane that is −inf on the max side and +inf
on the min side, so the pad can never be selected while k <= N real values
remain (indices therefore never point at the pad). On the even path the two
sides still share one set of pairwise comparisons bit-for-bit.

Tie-breaking matches the paper: the left child wins in both trees, which
reproduces lax.top_k's ascending-index order on equal values (asserted in
tests against the sort-based oracle, including duplicate-heavy and
all-equal inputs).

``streaming_quantize_outlier_kernel_call`` is the serving decode form: one
pass over the (bm, N) tile emits the bucketized activation indices AND the
per-token outlier set, so dynamic detection adds no extra HBM roundtrip on
top of activation quantization (the tile is read once).
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

__all__ = ["topk_outlier_kernel_call", "streaming_quantize_outlier_kernel_call"]

_NEG_INF = float("-inf")  # plain literal: jnp constants would be captured consts in the kernel
_POS_INF = float("inf")


def _default_interpret(interpret: bool | None) -> bool:
    # mirrors ops.should_interpret(); kept local to avoid a kernels->ops cycle
    return jax.default_backend() != "tpu" if interpret is None else interpret


def _pop_topk(cur, fallback, idx_cur, idx_fb, k: int):
    """k pops from a pair-collapsed array with single-fallback maintenance.

    cur      : (bm, P) current per-pair front value (pair maxima)
    fallback : (bm, P) the other leaf of each pair
    idx_cur/idx_fb : original column indices of cur/fallback entries
    Returns (vals (bm, k) descending, idx (bm, k)).
    """
    bm, p = cur.shape
    lane = jax.lax.broadcasted_iota(jnp.int32, (bm, p), 1)
    col_k = jax.lax.broadcasted_iota(jnp.int32, (bm, k), 1)
    cnt = jnp.zeros((bm, p), jnp.int32)
    vals = jnp.full((bm, k), _NEG_INF)
    idxs = jnp.zeros((bm, k), jnp.int32)

    def body(t, carry):
        cur, cnt, vals, idxs = carry
        v = jnp.max(cur, axis=1)  # (bm,)
        # first-True argmax == lowest pair index on ties (left-child rule)
        is_max = cur == v[:, None]
        j = jnp.argmax(is_max, axis=1).astype(jnp.int32)  # (bm,)
        onehot = lane == j[:, None]
        cnt_j = jnp.sum(jnp.where(onehot, cnt, 0), axis=1)  # (bm,)
        first_pop = cnt_j == 0
        take = lambda a: jnp.sum(jnp.where(onehot, a, 0), axis=1)
        takef = lambda a: jnp.sum(jnp.where(onehot, a, 0.0), axis=1)
        orig = jnp.where(first_pop, take(idx_cur), take(idx_fb))
        repl = jnp.where(first_pop, takef(fallback), _NEG_INF)
        cur = jnp.where(onehot, repl[:, None], cur)
        cnt = cnt + onehot.astype(jnp.int32)
        write = col_k == t
        vals = jnp.where(write, v[:, None], vals)
        idxs = jnp.where(write, orig[:, None], idxs)
        return cur, cnt, vals, idxs

    _, _, vals, idxs = jax.lax.fori_loop(0, k, body, (cur, cnt, vals, idxs))
    return vals, idxs


def _dual_topk(x, k: int, n_valid: int):
    """Shared-pairwise dual top-k/bottom-k over a (bm, n) f32 tile.

    ``n_valid`` < n means the trailing lanes are padding: they become −inf on
    the max side and +inf on the min side, so with k <= n_valid and finite
    real data a pad lane is never popped (its fallback is the sign-flipped
    pad, i.e. worse than any real value on either side). With n_valid == n
    both trees read the SAME array and the pairwise comparisons are shared.
    Returns (hi_v desc, hi_i, lo_v asc, lo_i).
    """
    bm, n = x.shape
    if n_valid < n:
        col = jax.lax.broadcasted_iota(jnp.int32, (bm, n), 1)
        x_hi = jnp.where(col < n_valid, x, _NEG_INF)
        x_lo = jnp.where(col < n_valid, x, _POS_INF)
    else:
        x_hi = x_lo = x

    pair = jax.lax.broadcasted_iota(jnp.int32, (bm, n // 2), 1) * 2

    # --- shared pairwise comparisons (level-1 of both trees): N/2 compares ---
    xp = x_hi.reshape(bm, n // 2, 2)
    xe, xo = xp[..., 0], xp[..., 1]
    right_wins_max = xo > xe  # strict: ties go left (paper's rule)
    a = jnp.where(right_wins_max, xo, xe)  # pair maxima
    b = jnp.where(right_wins_max, xe, xo)  # pair minima (max-tree fallback)
    # Each tree keeps its own leaf mask (paper: m^(p) vs m^(q)), so primary and
    # fallback indices are complements PER TREE — on a tie both trees pick the
    # left child first and fall back to the right one.
    a_idx = jnp.where(right_wins_max, pair + 1, pair)
    a_fb_idx = jnp.where(right_wins_max, pair, pair + 1)

    xp = x_lo.reshape(bm, n // 2, 2)
    xe, xo = xp[..., 0], xp[..., 1]
    right_wins_min = xo < xe
    c = jnp.where(right_wins_min, xo, xe)  # pair minima
    d = jnp.where(right_wins_min, xe, xo)  # pair maxima (min-tree fallback)
    c_idx = jnp.where(right_wins_min, pair + 1, pair)
    c_fb_idx = jnp.where(right_wins_min, pair, pair + 1)

    hi_v, hi_i = _pop_topk(a, b, a_idx, a_fb_idx, k)
    neg_v, lo_i = _pop_topk(-c, -d, c_idx, c_fb_idx, k)
    return hi_v, hi_i, -neg_v, lo_i


def _kernel(x_ref, hi_v_ref, hi_i_ref, lo_v_ref, lo_i_ref, *, k: int,
            n_valid: int):
    hi_v, hi_i, lo_v, lo_i = _dual_topk(x_ref[...], k, n_valid)
    hi_v_ref[...] = hi_v
    hi_i_ref[...] = hi_i
    lo_v_ref[...] = lo_v
    lo_i_ref[...] = lo_i


def _streaming_kernel(x_ref, s_ref, b_ref, idx_ref, hi_v_ref, hi_i_ref,
                      lo_v_ref, lo_i_ref, *, k: int, n_valid: int,
                      n_boundaries: int, mul_form: bool):
    """Bucketize + dual top-k in ONE tile read (the Orizuru streaming form).

    Index selection is bit-identical to ``quantize_activation``: mul_form
    (bf16 origin) compares x >= s*b_i, f32 form counts (x/s) >= b_i — the
    same rank searchsorted computes. Detection runs on the raw (unscaled)
    f32 activations, exactly what the unfused path hands to lax.top_k.
    """
    x = x_ref[...]  # (bm, n) f32
    s = s_ref[...]  # (bm, 1) f32
    b = b_ref[...]
    idx = jnp.zeros(x.shape, jnp.int32)
    if mul_form:
        for i in range(n_boundaries):
            idx += (x >= s * b[i]).astype(jnp.int32)
    else:
        xd = x / s
        for i in range(n_boundaries):
            idx += (xd >= b[i]).astype(jnp.int32)
    idx_ref[...] = idx
    hi_v, hi_i, lo_v, lo_i = _dual_topk(x, k, n_valid)
    hi_v_ref[...] = hi_v
    hi_i_ref[...] = hi_i
    lo_v_ref[...] = lo_v
    lo_i_ref[...] = lo_i


def _pad_args(x: jax.Array, k: int, block_m: int):
    """Shared shape plumbing: pad odd N by one lane and M to a block multiple.

    Returns (x padded f32, bm, grid_m, mp (padded rows), n_valid, np (padded
    cols)). Pad lanes are zero here; the kernel masks them to ±inf per side.
    """
    m, n = x.shape
    if not 1 <= k <= n:
        raise ValueError(f"k={k} must be in [1, N={n}]")
    pn = n % 2
    bm = min(block_m, m)
    pm = (-m) % bm
    if pm or pn:
        x = jnp.pad(x, ((0, pm), (0, pn)))
    return x.astype(jnp.float32), bm, (m + pm) // bm, m + pm, n, n + pn


def topk_outlier_kernel_call(
    x: jax.Array,  # (M, N) f32
    k: int,
    *,
    block_m: int = 8,
    interpret: bool | None = None,
):
    """Returns (hi_vals desc, hi_idx, lo_vals asc, lo_idx), each (M, k).

    ``interpret=None`` auto-selects interpret mode off-TPU.
    """
    m = x.shape[0]
    x, bm, gm, mp, n_valid, n = _pad_args(x, k, block_m)
    shp = jax.ShapeDtypeStruct((mp, k), jnp.float32)
    shpi = jax.ShapeDtypeStruct((mp, k), jnp.int32)
    outs = pl.pallas_call(
        functools.partial(_kernel, k=k, n_valid=n_valid),
        grid=(gm,),
        in_specs=[pl.BlockSpec((bm, n), lambda i: (i, 0))],
        out_specs=[pl.BlockSpec((bm, k), lambda i: (i, 0))] * 4,
        out_shape=[shp, shpi, shp, shpi],
        interpret=_default_interpret(interpret),
    )(x)
    return tuple(o[:m] for o in outs)


def streaming_quantize_outlier_kernel_call(
    x: jax.Array,  # (M, N) f32 raw activations
    scale: jax.Array,  # (M, 1) f32 per-token scale, computed by the caller
    boundaries: jax.Array,  # (2^n - 1,) f32 sorted codebook boundaries
    k: int,
    *,
    mul_form: bool = False,
    block_m: int = 8,
    interpret: bool | None = None,
):
    """Fused quantize + detect: (idx (M, N) i32, hi_v, hi_i, lo_v, lo_i).

    The scale comes IN (same contract as the fused LUT-GEMM kernel) so the
    per-token scale is bit-identical to ``token_scale`` however it is
    consumed downstream.
    """
    m = x.shape[0]
    x, bm, gm, mp, n_valid, n = _pad_args(x, k, block_m)
    if scale.shape != (m, 1):
        raise ValueError(f"scale must be (M, 1) = ({m}, 1), got {scale.shape}")
    s = scale.astype(jnp.float32)
    if mp > m:
        # pad scales with ones: pad-row divisions stay finite, rows are cut
        s = jnp.concatenate([s, jnp.ones((mp - m, 1), jnp.float32)])
    shp = jax.ShapeDtypeStruct((mp, k), jnp.float32)
    shpi = jax.ShapeDtypeStruct((mp, k), jnp.int32)
    outs = pl.pallas_call(
        functools.partial(
            _streaming_kernel, k=k, n_valid=n_valid,
            n_boundaries=int(boundaries.shape[0]), mul_form=mul_form,
        ),
        grid=(gm,),
        in_specs=[
            pl.BlockSpec((bm, n), lambda i: (i, 0)),
            pl.BlockSpec((bm, 1), lambda i: (i, 0)),
            pl.BlockSpec(boundaries.shape, lambda i: (0,)),
        ],
        out_specs=[pl.BlockSpec((bm, n), lambda i: (i, 0))]
        + [pl.BlockSpec((bm, k), lambda i: (i, 0))] * 4,
        out_shape=[jax.ShapeDtypeStruct((mp, n), jnp.int32), shp, shpi, shp, shpi],
        interpret=_default_interpret(interpret),
    )(x, s, boundaries.astype(jnp.float32))
    idx, hi_v, hi_i, lo_v, lo_i = outs
    return (idx[:m, :n_valid], hi_v[:m], hi_i[:m], lo_v[:m], lo_i[:m])
