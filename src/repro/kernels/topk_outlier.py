"""Pallas TPU kernel: Orizuru — dual top-k/bottom-k outlier detection (§IV-D).

The ASIC Orizuru is a two-fold tournament tree (max tree + min tree) with
SHARED LEAF COMPARISONS: the N/2 pairwise compares that initialize the max
tree's first level are reused (reversed) for the min tree, giving
1.5N + 2k·log2(N) comparisons instead of ~3N (or 6N for SpAtten's engine).

TPU adaptation (DESIGN.md §2): the serial pop-one-per-cycle loop is an ASIC
latency trick with no TPU analogue — a vectorized argmax over a vreg-resident
array has O(log N) depth anyway. What we keep is the *shared-pairwise* trick
and the *pair-collapse* structure:

  phase 1 (shared): A = max(x_even, x_odd), B = min(x_even, x_odd)
                    — N/2 compares produce level-1 of BOTH trees;
  phase 2 (pop):    k iterations of argmax over the N/2-wide A-array; a popped
                    pair falls back to its other leaf (B) and then to -inf —
                    exactly the paper's tree-maintenance semantics, k·(N/2)
                    vector-lanes of work but only k sequential steps;
  min side:         the SAME pop routine on (-B, -A) — comparisons reused.

Tie-breaking matches the paper: the left child wins in both trees, which
reproduces lax.top_k's ascending-index order on equal values (asserted in
tests against the sort-based oracle).
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

__all__ = ["topk_outlier_kernel_call"]

_NEG_INF = float("-inf")  # plain literal: jnp constants would be captured consts in the kernel


def _pop_topk(cur, fallback, idx_cur, idx_fb, k: int):
    """k pops from a pair-collapsed array with single-fallback maintenance.

    cur      : (bm, P) current per-pair front value (pair maxima)
    fallback : (bm, P) the other leaf of each pair
    idx_cur/idx_fb : original column indices of cur/fallback entries
    Returns (vals (bm, k) descending, idx (bm, k)).
    """
    bm, p = cur.shape
    lane = jax.lax.broadcasted_iota(jnp.int32, (bm, p), 1)
    col_k = jax.lax.broadcasted_iota(jnp.int32, (bm, k), 1)
    cnt = jnp.zeros((bm, p), jnp.int32)
    vals = jnp.full((bm, k), _NEG_INF)
    idxs = jnp.zeros((bm, k), jnp.int32)

    def body(t, carry):
        cur, cnt, vals, idxs = carry
        v = jnp.max(cur, axis=1)  # (bm,)
        # first-True argmax == lowest pair index on ties (left-child rule)
        is_max = cur == v[:, None]
        j = jnp.argmax(is_max, axis=1).astype(jnp.int32)  # (bm,)
        onehot = lane == j[:, None]
        cnt_j = jnp.sum(jnp.where(onehot, cnt, 0), axis=1)  # (bm,)
        first_pop = cnt_j == 0
        take = lambda a: jnp.sum(jnp.where(onehot, a, 0), axis=1)
        takef = lambda a: jnp.sum(jnp.where(onehot, a, 0.0), axis=1)
        orig = jnp.where(first_pop, take(idx_cur), take(idx_fb))
        repl = jnp.where(first_pop, takef(fallback), _NEG_INF)
        cur = jnp.where(onehot, repl[:, None], cur)
        cnt = cnt + onehot.astype(jnp.int32)
        write = col_k == t
        vals = jnp.where(write, v[:, None], vals)
        idxs = jnp.where(write, orig[:, None], idxs)
        return cur, cnt, vals, idxs

    _, _, vals, idxs = jax.lax.fori_loop(0, k, body, (cur, cnt, vals, idxs))
    return vals, idxs


def _kernel(x_ref, hi_v_ref, hi_i_ref, lo_v_ref, lo_i_ref, *, k: int):
    x = x_ref[...]  # (bm, N)
    bm, n = x.shape
    xp = x.reshape(bm, n // 2, 2)
    xe, xo = xp[..., 0], xp[..., 1]

    # --- shared pairwise comparisons (level-1 of both trees): N/2 compares ---
    right_wins_max = xo > xe  # strict: ties go left (paper's rule)
    right_wins_min = xo < xe
    a = jnp.where(right_wins_max, xo, xe)  # pair maxima
    b = jnp.where(right_wins_max, xe, xo)  # pair minima
    pair = jax.lax.broadcasted_iota(jnp.int32, (bm, n // 2), 1) * 2
    # Each tree keeps its own leaf mask (paper: m^(p) vs m^(q)), so primary and
    # fallback indices are complements PER TREE — on a tie both trees pick the
    # left child first and fall back to the right one.
    a_idx = jnp.where(right_wins_max, pair + 1, pair)
    a_fb_idx = jnp.where(right_wins_max, pair, pair + 1)
    b_idx = jnp.where(right_wins_min, pair + 1, pair)
    b_fb_idx = jnp.where(right_wins_min, pair, pair + 1)

    hi_v, hi_i = _pop_topk(a, b, a_idx, a_fb_idx, k)
    neg_v, lo_i = _pop_topk(-b, -a, b_idx, b_fb_idx, k)

    hi_v_ref[...] = hi_v
    hi_i_ref[...] = hi_i
    lo_v_ref[...] = -neg_v
    lo_i_ref[...] = lo_i


def topk_outlier_kernel_call(
    x: jax.Array,  # (M, N) f32, N even
    k: int,
    *,
    block_m: int = 8,
    interpret: bool = True,
):
    """Returns (hi_vals desc, hi_idx, lo_vals asc, lo_idx), each (M, k)."""
    m, n = x.shape
    if n % 2:
        raise ValueError("N must be even (pairwise shared comparisons)")
    if not 1 <= k <= n:
        raise ValueError(f"k={k} must be in [1, N={n}]")
    bm = min(block_m, m)
    pm = (-m) % bm
    if pm:
        x = jnp.pad(x, ((0, pm), (0, 0)))
    gm = (m + pm) // bm
    shp = jax.ShapeDtypeStruct((m + pm, k), jnp.float32)
    shpi = jax.ShapeDtypeStruct((m + pm, k), jnp.int32)
    outs = pl.pallas_call(
        functools.partial(_kernel, k=k),
        grid=(gm,),
        in_specs=[pl.BlockSpec((bm, n), lambda i: (i, 0))],
        out_specs=[pl.BlockSpec((bm, k), lambda i: (i, 0))] * 4,
        out_shape=[shp, shpi, shp, shpi],
        interpret=interpret,
    )(x.astype(jnp.float32))
    return tuple(o[:m] for o in outs)
