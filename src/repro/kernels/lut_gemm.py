"""Pallas TPU kernels: K-Means index GEMMs (the paper's LUT-GEMM on MXU).

TPU-native formulation of the Cartesian-product LUT GEMM (DESIGN.md §2),
in three variants sharing one tiling scheme:

* :func:`lut_gemm_kernel_call` — index-in, W4A4-style **nibble tier**
  (``nbits <= 4``: two 4-bit weight indices per byte) and the byte-packed
  **W5–W8 tier** (``byte_packed=True``: one index per byte). Per 128-aligned
  VMEM tile we unpack indices with integer bit ops, look centroids up
  on-chip, and feed the MXU with the dequantized tile, accumulating f32
  partials across the K grid dimension.

* :func:`fused_lut_gemm_kernel_call` — **fused quantize+GEMM**: takes raw
  activations plus their per-token scale, bucketizes against the activation
  codebook's decision boundaries *inside the tile* (the Clustering-Unit
  sum-of-compares, same formulation as ``kernels/bucketize.py``), and
  immediately runs the index-GEMM. Activation indices exist only in VMEM —
  the separate quantize pass and its idx HBM roundtrip are gone.

Centroid lookup is tiered by codebook size:

  2^n <= 16 : compare-select chain — 15 vselects IS the LUT lookup, the
              codebook lives in registers (TPU analogue of the ASIC's
              on-chip LUT).
  2^n  > 16 : the chain is untenable at 256 entries (255 serial selects per
              element), so the byte tier splits each index into two nibbles
              and looks up ``book[16*hi + lo]`` via a one-hot matmul against
              the codebook laid out as a (16, 16) VMEM table:
              ``t[e, h] = book2d[h, lo[e]]`` (one (E,16)x(16,16) MXU dot),
              then a 16-wide masked row-sum selects ``t[e, hi[e]]`` — 2x16
              compares + one tiny matmul instead of 255 selects.

No dequantized weight matrix ever exists in HBM — HBM traffic is the packed
index bytes plus <= 1 KiB of codebook, i.e. the paper's "no-dequantization"
property on the side that bounds TPU decode throughput.

Scales (per-token, per-out-channel) are rank-1 and applied by the wrapper in
``ops.py`` — keeping the kernels pure index-GEMMs keeps the LUT math testable
in isolation. M/N/K are all padded here (K via in-kernel masking of the
activation tile, so padded columns contribute exactly zero regardless of
what ``book[0]`` is).
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

__all__ = ["lut_gemm_kernel_call", "fused_lut_gemm_kernel_call"]


def _deq_select(idx: jax.Array, book: jax.Array, n_entries: int) -> jax.Array:
    """Centroid lookup as a compare-select chain (VPU-friendly 16-way LUT).

    out[...] = book[idx[...]] without a hardware gather: for codebooks of
    <= 2^4 entries this is <= 15 vselects — cheap relative to the MXU dot it
    feeds, and it vectorizes perfectly on 8x128 vregs.
    """
    out = jnp.full(idx.shape, book[0], jnp.float32)
    for i in range(1, n_entries):
        out = jnp.where(idx == i, book[i], out)
    return out


def _lookup(idx: jax.Array, book2d: jax.Array, nbits: int) -> jax.Array:
    """book[idx] for a codebook stored as a padded (16, 16) VMEM table.

    nbits <= 4 uses the compare-select chain on the table's flat head;
    nbits in (5..8] uses the nibble-decomposed one-hot matmul (module
    docstring): ``book[idx] = sum_h 1[hi=h] * (onehot(lo) @ book2d.T)[h]``.
    """
    if nbits <= 4:
        return _deq_select(idx, book2d.reshape(-1), 2**nbits)
    hi = idx >> 4
    lo = idx & 0xF
    lane = jax.lax.broadcasted_iota(jnp.int32, (*idx.shape, 16), idx.ndim)
    oh_lo = (lo[..., None] == lane).astype(jnp.float32)  # (..., 16)
    t = jnp.dot(
        oh_lo.reshape(-1, 16), book2d.T, preferred_element_type=jnp.float32
    ).reshape(*idx.shape, 16)  # t[e, h] = book2d[h, lo[e]] = book[16h + lo[e]]
    oh_hi = (hi[..., None] == lane).astype(jnp.float32)
    return jnp.sum(oh_hi * t, axis=-1)


def _deq_weight_tile(w_vals: jax.Array, book2d: jax.Array, n_w: int,
                     byte_packed: bool) -> jax.Array:
    """Dequantize one (bk, ...) weight-index tile to (bk, bn) f32."""
    if byte_packed:  # (bk, bn) uint8, one index per byte
        return _lookup(w_vals.astype(jnp.int32), book2d, n_w)
    lo = _lookup((w_vals & 0xF).astype(jnp.int32), book2d, n_w)
    hi = _lookup((w_vals >> 4).astype(jnp.int32), book2d, n_w)
    # Interleave even/odd output channels on the minor axis: (bk, bn//2, 2) ->
    # (bk, bn). A minor-dim relayout on TPU; deinterleaved packing is the
    # documented alternative if this ever dominates (see EXPERIMENTS §Perf).
    return jnp.stack([lo, hi], axis=-1).reshape(w_vals.shape[0], -1)


def _mask_padded_k(a: jax.Array, block_k: int, k_true: int) -> jax.Array:
    """Zero activation columns past the true K (padded-K tiles only).

    Zeroing the activation side is sufficient: the padded weight rows then
    multiply exact zeros, so the pad index value (0 -> book[0] != 0) never
    leaks into the accumulator.
    """
    col = pl.program_id(2) * block_k + jax.lax.broadcasted_iota(
        jnp.int32, a.shape, 1
    )
    return jnp.where(col < k_true, a, 0.0)


def _pad_book_2d(book: jax.Array) -> jax.Array:
    """Codebook -> zero-padded 256-entry (16, 16) table (row = high nibble)."""
    book = book.astype(jnp.float32).reshape(-1)
    return jnp.pad(book, (0, 256 - book.shape[0])).reshape(16, 16)


def _index_kernel(a_idx_ref, w_ref, a_book_ref, w_book_ref, o_ref, *,
                  n_a: int, n_w: int, byte_packed: bool, block_k: int,
                  k_true: int, masked_k: bool):
    """Grid: (M/bm, N/bn, K/bk); K is the innermost (arbitrary) dimension."""
    @pl.when(pl.program_id(2) == 0)
    def _init():
        o_ref[...] = jnp.zeros_like(o_ref)

    a = _lookup(a_idx_ref[...], a_book_ref[...], n_a)  # (bm, bk) f32
    if masked_k:
        a = _mask_padded_k(a, block_k, k_true)
    w = _deq_weight_tile(w_ref[...], w_book_ref[...], n_w, byte_packed)
    o_ref[...] += jnp.dot(a, w, preferred_element_type=jnp.float32)


def _fused_kernel(x_ref, s_ref, w_ref, bounds_ref, a_book_ref, w_book_ref,
                  o_ref, *, n_a: int, n_w: int, byte_packed: bool,
                  mul_form: bool, block_k: int, k_true: int, masked_k: bool):
    """Bucketize-then-GEMM in one pass: activation indices never leave VMEM.

    ``mul_form`` selects the compare formulation so indices are bit-identical
    to ``core.quantize.quantize_activation`` for the matching input dtype:
    f32 compares ``x/s >= b_i`` (the searchsorted path), bf16 compares
    ``x >= s*b_i`` (the fused sum-of-compares path).
    """
    @pl.when(pl.program_id(2) == 0)
    def _init():
        o_ref[...] = jnp.zeros_like(o_ref)

    x = x_ref[...].astype(jnp.float32)  # (bm, bk)
    s = s_ref[...].astype(jnp.float32)  # (bm, 1) per-token scale
    b = bounds_ref[...]  # (2^n_a - 1,) decision boundaries
    idx = jnp.zeros(x.shape, jnp.int32)
    if mul_form:
        for i in range(2**n_a - 1):
            idx += (x >= s * b[i]).astype(jnp.int32)
    else:
        xn = x / s
        for i in range(2**n_a - 1):
            idx += (xn >= b[i]).astype(jnp.int32)

    a = _lookup(idx, a_book_ref[...], n_a)
    if masked_k:
        a = _mask_padded_k(a, block_k, k_true)
    w = _deq_weight_tile(w_ref[...], w_book_ref[...], n_w, byte_packed)
    o_ref[...] += jnp.dot(a, w, preferred_element_type=jnp.float32)


def _grid_geometry(m: int, n: int, k: int, block_m: int | None,
                   block_n: int | None, block_k: int | None,
                   byte_packed: bool):
    """Clamp block sizes and compute padded grid extents.

    Byte tiers default to a smaller K block: the one-hot lookup holds two
    (bk, bn, 16) f32 intermediates per tile (bk=256, bn=128 -> 4 MiB), and
    the default keeps the working set well inside the ~16 MiB/core VMEM.

    VMEM working set per step (nibble defaults, W4A4):
      a_idx 128x512 int32 = 256 KiB, w 512x64 uint8 = 32 KiB,
      deq tiles (128x512 + 512x128) f32 = 512 KiB, acc 128x128 f32 = 64 KiB
    -> < 1 MiB, comfortable with double-buffering.
    """
    bm = min(block_m or 128, m)
    bn = min(block_n or 128, n)
    bk = min(block_k or (256 if byte_packed else 512), k)
    if not byte_packed and bn % 2:
        raise ValueError("block_n must be even (nibble packing)")
    pm, pn, pk = (-m) % bm, (-n) % bn, (-k) % bk
    grid = ((m + pm) // bm, (n + pn) // bn, (k + pk) // bk)
    return bm, bn, bk, pm, pn, pk, grid


def lut_gemm_kernel_call(
    a_idx: jax.Array,  # (M, K) int32 activation codebook indices
    w_packed: jax.Array,  # nibble: (K, N//2) uint8; byte: (K, N) uint8
    a_book: jax.Array,  # (2^nA,) f32
    w_book: jax.Array,  # (2^nW,) f32
    *,
    byte_packed: bool = False,
    block_m: int | None = None,
    block_n: int | None = None,
    block_k: int | None = None,
    interpret: bool = True,
) -> jax.Array:
    """Tiled index-GEMM pallas_call; M, N and K are all padded here.

    Returns the unscaled (M, N) f32 index-GEMM
    ``Y[m,n] = sum_k aBook[aIdx[m,k]] * wBook[wIdx[k,n]]``.
    """
    m, k = a_idx.shape
    n = w_packed.shape[1] * (1 if byte_packed else 2)
    bm, bn, bk, pm, pn, pk, grid = _grid_geometry(
        m, n, k, block_m, block_n, block_k, byte_packed)
    if pm or pk:
        a_idx = jnp.pad(a_idx, ((0, pm), (0, pk)))
    if pn or pk:
        wn_pad = pn if byte_packed else pn // 2
        w_packed = jnp.pad(w_packed, ((0, pk), (0, wn_pad)))
    wn_block = bn if byte_packed else bn // 2

    out = pl.pallas_call(
        functools.partial(
            _index_kernel,
            n_a=int(a_book.shape[0]).bit_length() - 1,
            n_w=int(w_book.shape[0]).bit_length() - 1,
            byte_packed=byte_packed, block_k=bk, k_true=k, masked_k=pk > 0,
        ),
        grid=grid,
        in_specs=[
            pl.BlockSpec((bm, bk), lambda i, j, kk: (i, kk)),
            pl.BlockSpec((bk, wn_block), lambda i, j, kk: (kk, j)),
            pl.BlockSpec((16, 16), lambda i, j, kk: (0, 0)),
            pl.BlockSpec((16, 16), lambda i, j, kk: (0, 0)),
        ],
        out_specs=pl.BlockSpec((bm, bn), lambda i, j, kk: (i, j)),
        out_shape=jax.ShapeDtypeStruct((m + pm, n + pn), jnp.float32),
        interpret=interpret,
    )(a_idx, w_packed, _pad_book_2d(a_book), _pad_book_2d(w_book))
    return out[:m, :n]


def fused_lut_gemm_kernel_call(
    x: jax.Array,  # (M, K) raw activations (f32 or bf16)
    scale: jax.Array,  # (M, 1) f32 per-token scale (full-K reduction, rank-1)
    w_packed: jax.Array,  # nibble: (K, N//2) uint8; byte: (K, N) uint8
    bounds: jax.Array,  # (2^nA - 1,) f32 activation decision boundaries
    a_book: jax.Array,  # (2^nA,) f32
    w_book: jax.Array,  # (2^nW,) f32
    *,
    byte_packed: bool = False,
    mul_form: bool = False,
    block_m: int | None = None,
    block_n: int | None = None,
    block_k: int | None = None,
    interpret: bool = True,
) -> jax.Array:
    """Fused activation-quantize + index-GEMM (unscaled (M, N) f32 output).

    The per-token scale needs a full-K reduction so it is computed by the
    caller (a rank-1 pass XLA fuses); everything O(M*K) — bucketize, index,
    centroid lookup — happens inside the tile. Padded rows must carry a
    nonzero ``scale`` (the ops.py wrapper pads with ones) so the in-kernel
    division stays NaN-free; padded rows are sliced off regardless.
    """
    m, k = x.shape
    n = w_packed.shape[1] * (1 if byte_packed else 2)
    bm, bn, bk, pm, pn, pk, grid = _grid_geometry(
        m, n, k, block_m, block_n, block_k, byte_packed)
    if pm or pk:
        x = jnp.pad(x, ((0, pm), (0, pk)))
    if pm:
        scale = jnp.pad(scale, ((0, pm), (0, 0)), constant_values=1.0)
    if pn or pk:
        wn_pad = pn if byte_packed else pn // 2
        w_packed = jnp.pad(w_packed, ((0, pk), (0, wn_pad)))
    wn_block = bn if byte_packed else bn // 2

    out = pl.pallas_call(
        functools.partial(
            _fused_kernel,
            n_a=int(a_book.shape[0]).bit_length() - 1,
            n_w=int(w_book.shape[0]).bit_length() - 1,
            byte_packed=byte_packed, mul_form=mul_form,
            block_k=bk, k_true=k, masked_k=pk > 0,
        ),
        grid=grid,
        in_specs=[
            pl.BlockSpec((bm, bk), lambda i, j, kk: (i, kk)),
            pl.BlockSpec((bm, 1), lambda i, j, kk: (i, 0)),
            pl.BlockSpec((bk, wn_block), lambda i, j, kk: (kk, j)),
            pl.BlockSpec(bounds.shape, lambda i, j, kk: (0,)),
            pl.BlockSpec((16, 16), lambda i, j, kk: (0, 0)),
            pl.BlockSpec((16, 16), lambda i, j, kk: (0, 0)),
        ],
        out_specs=pl.BlockSpec((bm, bn), lambda i, j, kk: (i, j)),
        out_shape=jax.ShapeDtypeStruct((m + pm, n + pn), jnp.float32),
        interpret=interpret,
    )(x, scale.astype(jnp.float32), w_packed, bounds.astype(jnp.float32),
      _pad_book_2d(a_book), _pad_book_2d(w_book))
    return out[:m, :n]
