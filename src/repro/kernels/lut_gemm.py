"""Pallas TPU kernel: W4A4 K-Means index GEMM (the paper's LUT-GEMM on MXU).

TPU-native formulation of the Cartesian-product LUT GEMM (DESIGN.md §2):
weight indices stay int4-packed in HBM; per 128-aligned VMEM tile we

  1. unpack two 4-bit indices per byte (integer bit ops on the VPU),
  2. "gather" centroids from the 16-entry codebook via compare-select
     (a 16-way select IS the LUT lookup — the codebook lives in registers,
     the TPU analogue of the ASIC's on-chip LUT),
  3. feed the MXU with the dequantized tile; accumulate f32 partials across
     the K grid dimension in the output block.

No dequantized weight matrix ever exists in HBM — HBM traffic is
K·N/2 bytes of indices + 64 B of codebook, i.e. the paper's
"no-dequantization" property on the side that bounds TPU decode throughput.

Scales (per-token, per-out-channel) are rank-1 and applied by the wrapper in
``ops.py`` — keeping the kernel a pure index-GEMM keeps the LUT math testable
in isolation.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

__all__ = ["lut_gemm_kernel_call"]


def _deq_select(idx: jax.Array, book: jax.Array, n_entries: int) -> jax.Array:
    """Centroid lookup as a compare-select chain (VPU-friendly 16-way LUT).

    out[...] = book[idx[...]] without a hardware gather: for the 2^4-entry
    codebooks of W4A4 this is 15 vselects — cheap relative to the MXU dot it
    feeds, and it vectorizes perfectly on 8x128 vregs.
    """
    out = jnp.full(idx.shape, book[0], jnp.float32)
    for i in range(1, n_entries):
        out = jnp.where(idx == i, book[i], out)
    return out


def _kernel(a_idx_ref, w_packed_ref, a_book_ref, w_book_ref, o_ref, *, n_a: int, n_w: int):
    """Grid: (M/bm, N/bn, K/bk); K is the innermost (arbitrary) dimension."""
    @pl.when(pl.program_id(2) == 0)
    def _init():
        o_ref[...] = jnp.zeros_like(o_ref)

    a_book = a_book_ref[...]
    w_book = w_book_ref[...]

    a = _deq_select(a_idx_ref[...], a_book, 2**n_a)  # (bm, bk) f32

    packed = w_packed_ref[...]  # (bk, bn//2) uint8
    lo = _deq_select((packed & 0xF).astype(jnp.int32), w_book, 2**n_w)
    hi = _deq_select((packed >> 4).astype(jnp.int32), w_book, 2**n_w)
    # Interleave even/odd output channels on the minor axis: (bk, bn//2, 2) ->
    # (bk, bn). A minor-dim relayout on TPU; deinterleaved packing is the
    # documented alternative if this ever dominates (see EXPERIMENTS §Perf).
    w = jnp.stack([lo, hi], axis=-1).reshape(packed.shape[0], -1)

    o_ref[...] += jnp.dot(a, w, preferred_element_type=jnp.float32)


def lut_gemm_kernel_call(
    a_idx: jax.Array,  # (M, K) int32
    w_packed: jax.Array,  # (K, N//2) uint8
    a_book: jax.Array,  # (2^nA,) f32
    w_book: jax.Array,  # (2^nW,) f32
    *,
    block_m: int = 128,
    block_n: int = 128,
    block_k: int = 512,
    interpret: bool = True,
) -> jax.Array:
    """Tiled pallas_call. M/N are padded here; K must divide block_k-clamped.

    VMEM working set per step (defaults, W4A4):
      a_idx 128x512 int32 = 256 KiB, w 512x64 uint8 = 32 KiB,
      deq tiles (128x512 + 512x128) f32 = 512 KiB, acc 128x128 f32 = 64 KiB
    -> < 1 MiB, comfortably inside the ~16 MiB/core VMEM with double-buffering.
    """
    m, k = a_idx.shape
    n = w_packed.shape[1] * 2
    bm, bn, bk = min(block_m, m), min(block_n, n), min(block_k, k)
    if k % bk:
        raise ValueError(f"K={k} must be divisible by block_k={bk}")
    if bn % 2:
        raise ValueError("block_n must be even (nibble packing)")

    # pad M and N up to block multiples (garbage rows/cols sliced off below)
    pm = (-m) % bm
    pn = (-n) % bn
    if pm:
        a_idx = jnp.pad(a_idx, ((0, pm), (0, 0)))
    if pn:
        w_packed = jnp.pad(w_packed, ((0, 0), (0, pn // 2)))
    gm, gn, gk = (m + pm) // bm, (n + pn) // bn, k // bk

    out = pl.pallas_call(
        functools.partial(
            _kernel,
            n_a=int(a_book.shape[0]).bit_length() - 1,
            n_w=int(w_book.shape[0]).bit_length() - 1,
        ),
        grid=(gm, gn, gk),
        in_specs=[
            pl.BlockSpec((bm, bk), lambda i, j, kk: (i, kk)),
            pl.BlockSpec((bk, bn // 2), lambda i, j, kk: (kk, j)),
            pl.BlockSpec(a_book.shape, lambda i, j, kk: (0,)),
            pl.BlockSpec(w_book.shape, lambda i, j, kk: (0,)),
        ],
        out_specs=pl.BlockSpec((bm, bn), lambda i, j, kk: (i, j)),
        out_shape=jax.ShapeDtypeStruct((m + pm, n + pn), jnp.float32),
        interpret=interpret,
    )(a_idx, w_packed, a_book, w_book)
    return out[:m, :n]
