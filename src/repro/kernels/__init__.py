"""Pallas TPU kernels for the perf-critical compute of KLLM/OASIS.

- lut_gemm:      W4A4 K-Means index GEMM (dequant-in-VMEM -> MXU)
- bucketize:     activation clustering (Clustering Unit)
- topk_outlier:  Orizuru dual top-k/bottom-k detection
- paged_attn:    paged KV-cache decode attention (block-table gather,
                 int4 dequant-in-VMEM)

``ops`` holds the jit'd public wrappers, ``ref`` the pure-jnp oracles.
Kernels are validated in interpret mode on CPU and lower unchanged on TPU.
"""

from repro.kernels import ops, ref
from repro.kernels.bucketize import bucketize_kernel_call
from repro.kernels.lut_gemm import lut_gemm_kernel_call
from repro.kernels.paged_attn import paged_attn_kernel_call
from repro.kernels.topk_outlier import topk_outlier_kernel_call

__all__ = [
    "ops",
    "ref",
    "bucketize_kernel_call",
    "lut_gemm_kernel_call",
    "paged_attn_kernel_call",
    "topk_outlier_kernel_call",
]
