"""Pure-jnp oracles for every Pallas kernel in this package.

Each ``*_ref`` matches its kernel's contract exactly (same argument layout,
same dtypes); kernel tests sweep shapes/dtypes and assert allclose against
these.
"""

from __future__ import annotations

import jax
import jax.numpy as jnp

__all__ = ["lut_gemm_ref", "lut_gemm_byte_ref", "fused_lut_gemm_ref",
           "bucketize_ref", "topk_outlier_ref",
           "streaming_quantize_outlier_ref", "paged_attn_ref",
           "paged_attn_quant_ref"]

_NEG_INF = float(jnp.finfo(jnp.float32).min)


def lut_gemm_ref(
    a_idx: jax.Array,  # (M, K) int32 activation codebook indices
    w_packed: jax.Array,  # (K, N//2) uint8, two 4-bit weight indices per byte
    a_book: jax.Array,  # (2^nA,) f32
    w_book: jax.Array,  # (2^nW,) f32
) -> jax.Array:
    """Unscaled index-GEMM: Y[m,n] = sum_k aBook[aIdx[m,k]] * wBook[wIdx[k,n]]."""
    lo = (w_packed & 0xF).astype(jnp.int32)
    hi = (w_packed >> 4).astype(jnp.int32)
    w_idx = jnp.stack([lo, hi], axis=-1).reshape(w_packed.shape[0], -1)
    a = a_book[a_idx].astype(jnp.float32)
    w = w_book[w_idx].astype(jnp.float32)
    return a @ w


def lut_gemm_byte_ref(
    a_idx: jax.Array,  # (M, K) int32 activation codebook indices
    w_idx: jax.Array,  # (K, N) uint8, ONE weight index per byte (W5-W8 tier)
    a_book: jax.Array,  # (2^nA,) f32
    w_book: jax.Array,  # (2^nW,) f32
) -> jax.Array:
    """Byte-tier unscaled index-GEMM: Y[m,n] = Σ_k aBook[aIdx] * wBook[wIdx]."""
    a = a_book[a_idx].astype(jnp.float32)
    w = w_book[w_idx.astype(jnp.int32)].astype(jnp.float32)
    return a @ w


def fused_lut_gemm_ref(
    x: jax.Array,  # (M, K) raw activations
    scale: jax.Array,  # (M, 1) f32 per-token scale
    w_packed: jax.Array,  # nibble (K, N//2) or byte (K, N) uint8
    boundaries: jax.Array,  # (2^nA - 1,) f32
    a_book: jax.Array,
    w_book: jax.Array,
    *,
    byte_packed: bool = False,
    mul_form: bool = False,
) -> jax.Array:
    """Quantize-then-index-GEMM oracle matching the fused kernel's contract
    exactly: f32 inputs bucketize x/s (searchsorted form), bf16-style
    ``mul_form`` compares x >= s*b (the fused sum-of-compares form)."""
    xf = x.astype(jnp.float32)
    if mul_form:
        a_idx = jnp.sum(
            xf[..., None] >= scale[..., None] * boundaries, axis=-1
        ).astype(jnp.int32)
    else:
        a_idx = bucketize_ref(xf / scale, boundaries)
    if byte_packed:
        return lut_gemm_byte_ref(a_idx, w_packed, a_book, w_book)
    return lut_gemm_ref(a_idx, w_packed, a_book, w_book)


def bucketize_ref(x: jax.Array, boundaries: jax.Array) -> jax.Array:
    """Cluster assignment via boundaries (paper Clustering Unit): int32."""
    return jnp.searchsorted(boundaries, x, side="right").astype(jnp.int32)


def paged_attn_ref(
    q: jax.Array,  # (B, Sq, KV, G, hd)
    k_pages: jax.Array,  # (n_blocks, bs, KV, hd)
    v_pages: jax.Array,  # (n_blocks, bs, KV, hd)
    block_tables: jax.Array,  # (B, max_blocks_per_seq) int32; < 0 = unallocated
    ctx_lens: jax.Array,  # (B,) int32 valid context length per request
    q_pos: jax.Array,  # (B, Sq) int32 absolute query positions (< 0 = padded)
    *,
    softcap: float = 0.0,
    window: int = 0,
) -> jax.Array:
    """Paged causal GQA attention oracle: gather K/V blocks through the block
    table, attend with per-request masks. ``Sq`` is a query *segment* per
    sequence (decode: 1; chunked prefill: chunk; packed token-budget step:
    B = n_tokens rows of Sq = 1). Token position p of request b lives at
    ``(block_tables[b, p // bs], p % bs)``; keys at positions
    ``>= ctx_lens[b]`` or ``> q_pos[b, s]`` are masked, so a padded query row
    (q_pos < 0) sees no keys and returns garbage to be discarded by the
    caller. ``window > 0`` adds the sliding-window term (keys at
    ``<= q_pos - window`` masked — same rule as the ring cache's ``_mask``),
    which is also what makes freed out-of-window table entries (< 0, clamped
    to block 0 for the gather) unreachable. Returns f32, q shape.
    """
    n_blocks, bs = k_pages.shape[0], k_pages.shape[1]
    bt = jnp.clip(block_tables, 0, n_blocks - 1)
    # (B, max_blk, bs, KV, hd) -> (B, Sk, KV, hd) with Sk = max_blk * bs
    gk = k_pages[bt].reshape(bt.shape[0], -1, *k_pages.shape[2:])
    gv = v_pages[bt].reshape(bt.shape[0], -1, *v_pages.shape[2:])
    k_pos = jnp.arange(gk.shape[1], dtype=jnp.int32)
    scale = q.shape[-1] ** -0.5
    s = jnp.einsum("bskgh,btkh->bkgst", q.astype(jnp.float32),
                   gk.astype(jnp.float32)) * scale
    if softcap > 0:
        s = softcap * jnp.tanh(s / softcap)
    valid = (k_pos[None, None, :] < ctx_lens[:, None, None]) & (
        k_pos[None, None, :] <= q_pos[:, :, None]
    )  # (B, Sq, Sk)
    if window > 0:
        valid &= k_pos[None, None, :] > q_pos[:, :, None] - window
    s = jnp.where(valid[:, None, None], s, _NEG_INF)
    p = jax.nn.softmax(s, axis=-1)
    return jnp.einsum("bkgst,btkh->bskgh", p, gv.astype(jnp.float32))


def paged_attn_quant_ref(
    q: jax.Array,  # (B, Sq, KV, G, hd)
    k_idx: jax.Array,  # (n_blocks, bs, KV, hd//2) uint8, 2 int4 per byte
    k_scale: jax.Array,  # (n_blocks, bs, KV, 1) f32
    v_idx: jax.Array,
    v_scale: jax.Array,
    codebook: jax.Array,  # (16,) f32 sorted centroids
    block_tables: jax.Array,
    ctx_lens: jax.Array,
    q_pos: jax.Array,
    *,
    softcap: float = 0.0,
    window: int = 0,
) -> jax.Array:
    """int4 variant: gather PACKED blocks, dequantize only the gathered set
    (codebook lookup x per-token scale) — the dense cache never exists in HBM.
    """
    n_blocks = k_idx.shape[0]
    bt = jnp.clip(block_tables, 0, n_blocks - 1)

    def deq(idx, scale):
        lo = (idx & 0xF).astype(jnp.int32)
        hi = (idx >> 4).astype(jnp.int32)
        full = jnp.stack([lo, hi], axis=-1).reshape(*idx.shape[:-1], -1)
        return codebook[full] * scale

    gk = deq(k_idx[bt], k_scale[bt]).reshape(bt.shape[0], -1, *k_idx.shape[2:3],
                                             2 * k_idx.shape[3])
    gv = deq(v_idx[bt], v_scale[bt]).reshape(gk.shape)
    k_pos = jnp.arange(gk.shape[1], dtype=jnp.int32)
    scale = q.shape[-1] ** -0.5
    s = jnp.einsum("bskgh,btkh->bkgst", q.astype(jnp.float32), gk) * scale
    if softcap > 0:
        s = softcap * jnp.tanh(s / softcap)
    valid = (k_pos[None, None, :] < ctx_lens[:, None, None]) & (
        k_pos[None, None, :] <= q_pos[:, :, None]
    )
    if window > 0:
        valid &= k_pos[None, None, :] > q_pos[:, :, None] - window
    s = jnp.where(valid[:, None, None], s, _NEG_INF)
    p = jax.nn.softmax(s, axis=-1)
    return jnp.einsum("bkgst,btkh->bskgh", p, gv)


def topk_outlier_ref(x: jax.Array, k: int):
    """Sort-based oracle for Orizuru: (hi_vals, hi_idx, lo_vals, lo_idx).

    hi: k largest per row, descending; lo: k smallest per row, ascending.
    Tie-break on index (smaller index wins), matching the kernel's
    deterministic left-child rule.
    """
    hi_v, hi_i = jax.lax.top_k(x, k)
    lo_v, lo_i = jax.lax.top_k(-x, k)
    return hi_v, hi_i.astype(jnp.int32), -lo_v, lo_i.astype(jnp.int32)


def streaming_quantize_outlier_ref(
    x: jax.Array,  # (M, N) raw activations
    scale: jax.Array,  # (M, 1) f32 per-token scale
    boundaries: jax.Array,  # (2^n - 1,) f32
    k: int,
    *,
    mul_form: bool = False,
):
    """Oracle for the streaming quantize+detect kernel: bucketize (same two
    forms as ``fused_lut_gemm_ref``) plus the dual top-k on the raw f32
    activations. Returns (idx, hi_v, hi_i, lo_v, lo_i)."""
    xf = x.astype(jnp.float32)
    if mul_form:
        idx = jnp.sum(
            xf[..., None] >= scale[..., None] * boundaries, axis=-1
        ).astype(jnp.int32)
    else:
        idx = bucketize_ref(xf / scale, boundaries)
    return (idx, *topk_outlier_ref(xf, k))
