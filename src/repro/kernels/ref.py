"""Pure-jnp oracles for every Pallas kernel in this package.

Each ``*_ref`` matches its kernel's contract exactly (same argument layout,
same dtypes); kernel tests sweep shapes/dtypes and assert allclose against
these.
"""

from __future__ import annotations

import jax
import jax.numpy as jnp

__all__ = ["lut_gemm_ref", "bucketize_ref", "topk_outlier_ref"]


def lut_gemm_ref(
    a_idx: jax.Array,  # (M, K) int32 activation codebook indices
    w_packed: jax.Array,  # (K, N//2) uint8, two 4-bit weight indices per byte
    a_book: jax.Array,  # (2^nA,) f32
    w_book: jax.Array,  # (2^nW,) f32
) -> jax.Array:
    """Unscaled index-GEMM: Y[m,n] = sum_k aBook[aIdx[m,k]] * wBook[wIdx[k,n]]."""
    lo = (w_packed & 0xF).astype(jnp.int32)
    hi = (w_packed >> 4).astype(jnp.int32)
    w_idx = jnp.stack([lo, hi], axis=-1).reshape(w_packed.shape[0], -1)
    a = a_book[a_idx].astype(jnp.float32)
    w = w_book[w_idx].astype(jnp.float32)
    return a @ w


def bucketize_ref(x: jax.Array, boundaries: jax.Array) -> jax.Array:
    """Cluster assignment via boundaries (paper Clustering Unit): int32."""
    return jnp.searchsorted(boundaries, x, side="right").astype(jnp.int32)


def topk_outlier_ref(x: jax.Array, k: int):
    """Sort-based oracle for Orizuru: (hi_vals, hi_idx, lo_vals, lo_idx).

    hi: k largest per row, descending; lo: k smallest per row, ascending.
    Tie-break on index (smaller index wins), matching the kernel's
    deterministic left-child rule.
    """
    hi_v, hi_i = jax.lax.top_k(x, k)
    lo_v, lo_i = jax.lax.top_k(-x, k)
    return hi_v, hi_i.astype(jnp.int32), -lo_v, lo_i.astype(jnp.int32)
