"""AdamW + schedules, built directly on pytrees.

Moments are kept in fp32 regardless of (possibly bf16) param dtype; the
update is computed in fp32 and cast back — the standard mixed-precision
recipe without a separate fp32 master copy (documented trade-off: one fewer
param-sized buffer; bf16 rounding on the update is ~1e-3 relative and is
swamped by gradient noise at LLM batch sizes).
"""

from __future__ import annotations

import dataclasses
import math
from typing import Callable

import jax
import jax.numpy as jnp

__all__ = ["AdamWConfig", "adamw_init", "adamw_update", "cosine_schedule", "global_norm"]


@dataclasses.dataclass(frozen=True)
class AdamWConfig:
    lr: float = 3e-4
    b1: float = 0.9
    b2: float = 0.95
    eps: float = 1e-8
    weight_decay: float = 0.1
    clip_norm: float = 1.0  # 0 disables clipping


def cosine_schedule(base_lr: float, warmup: int, total: int, min_frac: float = 0.1) -> Callable:
    def lr(step):
        step = jnp.asarray(step, jnp.float32)
        warm = base_lr * jnp.minimum(1.0, step / max(warmup, 1))
        t = jnp.clip((step - warmup) / max(total - warmup, 1), 0.0, 1.0)
        cos = min_frac + (1 - min_frac) * 0.5 * (1 + jnp.cos(math.pi * t))
        return jnp.where(step < warmup, warm, base_lr * cos)

    return lr


def adamw_init(params) -> dict:
    zeros32 = lambda p: jnp.zeros(p.shape, jnp.float32)
    return {
        "m": jax.tree.map(zeros32, params),
        "v": jax.tree.map(zeros32, params),
        "step": jnp.zeros((), jnp.int32),
    }


def global_norm(tree) -> jax.Array:
    leaves = jax.tree.leaves(tree)
    return jnp.sqrt(sum(jnp.sum(jnp.square(l.astype(jnp.float32))) for l in leaves))


def adamw_update(grads, opt_state, params, cfg: AdamWConfig, lr: jax.Array):
    """One AdamW step. Returns (new_params, new_opt_state, metrics)."""
    gnorm = global_norm(grads)
    if cfg.clip_norm > 0:
        scale = jnp.minimum(1.0, cfg.clip_norm / jnp.maximum(gnorm, 1e-9))
        grads = jax.tree.map(lambda g: g * scale, grads)

    step = opt_state["step"] + 1
    t = step.astype(jnp.float32)
    bc1 = 1.0 - cfg.b1**t
    bc2 = 1.0 - cfg.b2**t

    def upd(p, g, m, v):
        gf = g.astype(jnp.float32)
        m_new = cfg.b1 * m + (1 - cfg.b1) * gf
        v_new = cfg.b2 * v + (1 - cfg.b2) * jnp.square(gf)
        mhat = m_new / bc1
        vhat = v_new / bc2
        delta = mhat / (jnp.sqrt(vhat) + cfg.eps)
        # decoupled weight decay on matrices only (ndim >= 2)
        if p.ndim >= 2 and cfg.weight_decay > 0:
            delta = delta + cfg.weight_decay * p.astype(jnp.float32)
        p_new = p.astype(jnp.float32) - lr * delta
        return p_new.astype(p.dtype), m_new, v_new

    flat_p, treedef = jax.tree.flatten(params)
    flat_g = treedef.flatten_up_to(grads)
    flat_m = treedef.flatten_up_to(opt_state["m"])
    flat_v = treedef.flatten_up_to(opt_state["v"])
    out = [upd(p, g, m, v) for p, g, m, v in zip(flat_p, flat_g, flat_m, flat_v)]
    new_params = jax.tree.unflatten(treedef, [o[0] for o in out])
    new_m = jax.tree.unflatten(treedef, [o[1] for o in out])
    new_v = jax.tree.unflatten(treedef, [o[2] for o in out])
    return new_params, {"m": new_m, "v": new_v, "step": step}, {"grad_norm": gnorm}
