"""Token data pipeline: deterministic, host-sharded, checkpoint-resumable.

Two sources:

* :class:`ByteCorpus` — byte-level tokenization of real text (the repo's own
  source tree by default: structured, offline-available data the example LM
  can actually learn). Vocab 256 + specials.
* :func:`synthetic_corpus` — a seeded 2nd-order Markov token stream for
  arbitrary vocab sizes (used by the big-arch smoke tests: learnable
  structure, no storage).

Determinism contract: batch ``i`` of a pipeline constructed with the same
(config, seed) is identical across runs AND across restarts —
:meth:`TokenPipeline.state` / :meth:`TokenPipeline.restore` round-trip through
the checkpointer, so training resumes mid-epoch without replaying or skipping
data. Host sharding slices the batch axis by (process_index, process_count).
"""

from __future__ import annotations

import dataclasses
import hashlib
import pathlib

import numpy as np

__all__ = ["DataConfig", "ByteCorpus", "TokenPipeline", "synthetic_corpus"]


@dataclasses.dataclass(frozen=True)
class DataConfig:
    seq_len: int
    global_batch: int
    seed: int = 0
    process_index: int = 0
    process_count: int = 1


class ByteCorpus:
    """Byte-level corpus from text files (default: this repo's sources)."""

    vocab_size = 256

    def __init__(self, root: str | None = None, suffixes=(".py", ".md")):
        root_p = pathlib.Path(root) if root else pathlib.Path(__file__).resolve().parents[3]
        parts = []
        for f in sorted(root_p.rglob("*")):
            if f.suffix in suffixes and f.is_file():
                try:
                    parts.append(f.read_bytes())
                except OSError:
                    continue
        blob = b"\n".join(parts)
        if len(blob) < 1 << 16:
            blob = blob * ((1 << 16) // max(len(blob), 1) + 1)
        self.tokens = np.frombuffer(blob, dtype=np.uint8).astype(np.int32)

    def fingerprint(self) -> str:
        return hashlib.sha256(self.tokens.tobytes()).hexdigest()[:16]


def synthetic_corpus(vocab: int, length: int, seed: int = 0) -> np.ndarray:
    """Seeded order-2 Markov stream: low-entropy enough to be learnable."""
    rng = np.random.default_rng(seed)
    # sparse transition structure: each (a, b) context prefers ~4 successors
    n_ctx = 4096
    succ = rng.integers(0, vocab, size=(n_ctx, 4))
    out = np.empty(length, dtype=np.int32)
    a = b = 0
    u = rng.integers(0, 4, size=length)
    greedy = rng.random(length) < 0.9
    for i in range(length):
        ctx = (a * 31 + b) % n_ctx
        out[i] = succ[ctx, u[i]] if greedy[i] else rng.integers(0, vocab)
        a, b = b, out[i]
    return out


class TokenPipeline:
    """Random-crop LM batches over a token array, stateful + resumable."""

    def __init__(self, tokens: np.ndarray, cfg: DataConfig):
        if cfg.global_batch % cfg.process_count:
            raise ValueError("global_batch must divide by process_count")
        self.tokens = tokens
        self.cfg = cfg
        self._step = 0

    @property
    def local_batch(self) -> int:
        return self.cfg.global_batch // self.cfg.process_count

    def _rng_for(self, step: int) -> np.random.Generator:
        # counter-based: state is just the step index
        return np.random.default_rng((self.cfg.seed, step))

    def next_batch(self) -> dict:
        """Returns {"tokens": (local_batch, seq_len + 1) int32} (input+target)."""
        cfg = self.cfg
        rng = self._rng_for(self._step)
        span = cfg.seq_len + 1
        starts = rng.integers(0, len(self.tokens) - span, size=cfg.global_batch)
        lo = cfg.process_index * self.local_batch
        sel = starts[lo : lo + self.local_batch]
        batch = np.stack([self.tokens[s : s + span] for s in sel]).astype(np.int32)
        self._step += 1
        return {"tokens": batch}

    # ---- checkpointable iterator state ------------------------------------
    def state(self) -> dict:
        return {"step": self._step, "seed": self.cfg.seed}

    def restore(self, state: dict) -> None:
        if state["seed"] != self.cfg.seed:
            raise ValueError("restoring pipeline with mismatched seed")
        self._step = int(state["step"])
