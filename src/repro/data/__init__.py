"""Deterministic, resumable, host-sharded data pipeline."""

from repro.data.pipeline import ByteCorpus, DataConfig, TokenPipeline, synthetic_corpus

__all__ = ["ByteCorpus", "DataConfig", "TokenPipeline", "synthetic_corpus"]
