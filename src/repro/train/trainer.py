"""Training substrate.

``make_train_step(model, tc)`` builds a pure jit-able step:

  * cross-entropy next-token loss (fp32 logits, optional z-loss) + MoE aux
  * gradient accumulation over ``tc.microbatches`` via ``lax.scan`` — the
    memory knob that makes 1M-token global batches compile per-device
  * AdamW update with clipping + schedule
  * optional int8 gradient compression on the DP all-reduce
    (repro.distributed.collectives; off by default, tested separately)

``Trainer`` adds the operational shell: data pipeline, checkpoint/auto-resume
(params, opt state, data-iterator state, step), straggler monitoring.
"""

from __future__ import annotations

import dataclasses
import time
from typing import Any, Callable

import jax
import jax.numpy as jnp

from repro.models.model import Model
from repro.optim.adamw import AdamWConfig, adamw_init, adamw_update, cosine_schedule

__all__ = ["TrainConfig", "make_train_step", "make_eval_step", "loss_fn", "Trainer"]


@dataclasses.dataclass(frozen=True)
class TrainConfig:
    optimizer: AdamWConfig = AdamWConfig()
    microbatches: int = 1
    z_loss: float = 1e-4
    aux_weight: float = 0.01  # MoE load-balance loss weight
    warmup_steps: int = 100
    total_steps: int = 10_000
    checkpoint_every: int = 500
    grad_compression: bool = False


_CE_CHUNK = 4096  # tokens per CE chunk (memory knob; see EXPERIMENTS §Perf C2)


def _chunked_ce(hidden, head_w, labels, z_loss: float):
    """Cross-entropy from hidden states in token chunks under remat.

    Never materializes the full (tokens, vocab) logits: the f32 logits +
    softmax backward of a 256k-vocab head cost ~8 GB/device on the 104B train
    cell before this (§Perf iteration C2). Each chunk's logits are transient
    (chunk x vocab_shard); jax.checkpoint recomputes them in backward.
    """
    d = hidden.shape[-1]
    h = hidden.reshape(-1, d)
    y = labels.reshape(-1)
    t = h.shape[0]
    chunk = min(_CE_CHUNK, t)
    pad = (-t) % chunk
    valid = jnp.pad(jnp.ones((t,), jnp.float32), (0, pad))
    if pad:
        h = jnp.pad(h, ((0, pad), (0, 0)))
        y = jnp.pad(y, (0, pad))
    n = h.shape[0] // chunk

    @jax.checkpoint
    def body(carry, xs):
        hc, yc, vc = xs
        logits = (hc.astype(jnp.float32)) @ head_w.astype(jnp.float32)
        lse = jax.nn.logsumexp(logits, axis=-1)
        ll = jnp.take_along_axis(logits, yc[:, None], axis=-1)[:, 0]
        ce_sum = jnp.sum((lse - ll) * vc)
        z_sum = jnp.sum(jnp.square(lse) * vc)
        return (carry[0] + ce_sum, carry[1] + z_sum), None

    (ce_sum, z_sum), _ = jax.lax.scan(
        body, (jnp.zeros(()), jnp.zeros(())),
        (h.reshape(n, chunk, d), y.reshape(n, chunk), valid.reshape(n, chunk)),
    )
    ce = ce_sum / t
    return ce, ce + z_loss * (z_sum / t)


def loss_fn(model: Model, params, batch: dict, tc: TrainConfig):
    """batch["tokens"]: (B, S+1). Returns (loss, metrics)."""
    from repro.models.model import head_matrix

    tokens = batch["tokens"]
    inp = {**batch, "tokens": tokens[:, :-1]}
    labels = tokens[:, 1:]
    out = model.apply(params, inp, return_hidden_only=True)
    ce, loss = _chunked_ce(out.hidden, head_matrix(model, params), labels, tc.z_loss)
    if out.aux_loss is not None:
        loss = loss + tc.aux_weight * out.aux_loss
    return loss, {"ce": ce, "aux": out.aux_loss if out.aux_loss is not None else 0.0}


def make_train_step(model: Model, tc: TrainConfig) -> Callable:
    """Returns train_step(state, batch) -> (state, metrics). state:
    {"params", "opt", "compress_err"?}. batch leaves have leading global-batch
    dim divisible by tc.microbatches."""
    lr_fn = cosine_schedule(tc.optimizer.lr, tc.warmup_steps, tc.total_steps)

    def micro_grads(params, batch):
        return jax.value_and_grad(lambda p: loss_fn(model, p, batch, tc), has_aux=True)(params)

    def train_step(state, batch):
        params = state["params"]
        n = tc.microbatches
        if n > 1:
            micro = jax.tree.map(lambda x: x.reshape(n, x.shape[0] // n, *x.shape[1:]), batch)

            def acc_body(carry, mb):
                gacc, lacc = carry
                (l, _), g = micro_grads(params, mb)
                return (jax.tree.map(jnp.add, gacc, g), lacc + l), None

            g0 = jax.tree.map(lambda p: jnp.zeros(p.shape, jnp.float32), params)
            (gsum, lsum), _ = jax.lax.scan(acc_body, (g0, jnp.zeros(())), micro)
            grads = jax.tree.map(lambda g: g / n, gsum)
            loss = lsum / n
        else:
            (loss, _), grads = micro_grads(params, batch)

        if tc.grad_compression and "compress_err" in state:
            from repro.distributed.collectives import compress_decompress_tree

            grads, new_err = compress_decompress_tree(grads, state["compress_err"])
        else:
            new_err = state.get("compress_err")

        lr = lr_fn(state["opt"]["step"] + 1)  # +1: step 0 would warm up to lr=0 (no-op step)
        new_params, new_opt, om = adamw_update(grads, state["opt"], params, tc.optimizer, lr)
        new_state = {"params": new_params, "opt": new_opt}
        if new_err is not None:
            new_state["compress_err"] = new_err
        return new_state, {"loss": loss, "lr": lr, **om}

    return train_step


def make_eval_step(model: Model, tc: TrainConfig) -> Callable:
    def eval_step(params, batch):
        loss, metrics = loss_fn(model, params, batch, tc)
        return {"loss": loss, **metrics}

    return eval_step


def init_train_state(model: Model, key, tc: TrainConfig) -> dict:
    params = model.init(key)
    state = {"params": params, "opt": adamw_init(params)}
    if tc.grad_compression:
        from repro.distributed.collectives import init_error_state

        state["compress_err"] = init_error_state(params)
    return state


class Trainer:
    """Operational training shell with fault tolerance.

    - auto-resume: restores (params, opt, pipeline state, step) from the
      latest valid checkpoint in ``ckpt_dir``
    - checkpoint cadence per TrainConfig + final checkpoint on exit
    - straggler monitor: flags steps slower than ``straggler_factor`` x the
      running median (on real clusters this triggers the elastic re-mesh path
      in repro.distributed.fault_tolerance)
    """

    def __init__(self, model: Model, tc: TrainConfig, pipeline, ckpt_dir: str | None = None,
                 seed: int = 0):
        from repro.checkpoint.checkpointer import CheckpointManager
        from repro.distributed.fault_tolerance import StepMonitor

        self.model, self.tc, self.pipeline = model, tc, pipeline
        self.train_step = jax.jit(make_train_step(model, tc))
        self.state = init_train_state(model, jax.random.PRNGKey(seed), tc)
        self.step = 0
        self.monitor = StepMonitor()
        self.ckpt = CheckpointManager(ckpt_dir) if ckpt_dir else None
        if self.ckpt is not None:
            restored = self.ckpt.restore_latest(
                {"state": self.state, "data": self.pipeline.state(), "step": 0}
            )
            if restored is not None:
                self.state = restored["state"]
                self.pipeline.restore(restored["data"])
                self.step = int(restored["step"])

    def run(self, num_steps: int, log_every: int = 10, log: Callable[[str], Any] = print):
        target = self.step + num_steps
        while self.step < target:
            batch = {k: jnp.asarray(v) for k, v in self.pipeline.next_batch().items()}
            t0 = time.perf_counter()
            self.state, metrics = self.train_step(self.state, batch)
            jax.block_until_ready(metrics["loss"])
            dt = time.perf_counter() - t0
            self.monitor.record(dt)
            self.step += 1
            if self.step % log_every == 0:
                log(
                    f"step {self.step} loss {float(metrics['loss']):.4f} "
                    f"lr {float(metrics['lr']):.2e} dt {dt*1e3:.0f}ms"
                    + (" [STRAGGLER]" if self.monitor.is_straggler(dt) else "")
                )
            if self.ckpt is not None and self.step % self.tc.checkpoint_every == 0:
                self._save()
        if self.ckpt is not None:
            self._save()
        return self.state

    def _save(self):
        self.ckpt.save(
            {"state": self.state, "data": self.pipeline.state(), "step": self.step},
            step=self.step,
        )
