"""Training loop: loss, train_step (grad-accum scan), Trainer with FT hooks."""

from repro.train.trainer import TrainConfig, Trainer, make_eval_step, make_train_step, loss_fn

__all__ = ["TrainConfig", "Trainer", "make_eval_step", "make_train_step", "loss_fn"]
