"""Render EXPERIMENTS.md §Dry-run / §Roofline tables from results/dryrun/*.json.

Usage: PYTHONPATH=src python -m repro.launch.report [--dir results/dryrun]
Prints markdown; the checked-in EXPERIMENTS.md embeds this output.
"""

from __future__ import annotations

import argparse
import json
import pathlib

from repro.configs.base import SHAPES, get_config, list_archs
from repro.launch.roofline import HW


def _fmt_s(x: float) -> str:
    if x == 0:
        return "0"
    if x < 1e-3:
        return f"{x*1e6:.0f}us"
    if x < 1:
        return f"{x*1e3:.1f}ms"
    return f"{x:.2f}s"


def model_flops(arch: str, shape_name: str) -> float:
    """6*N*D (train, dense) / 6*N_active*D (MoE) / 2*N*tokens (decode)."""
    cfg = get_config(arch)
    sh = SHAPES[shape_name]
    n = cfg.n_active_params
    if sh.kind == "train":
        return 6.0 * n * sh.global_batch * sh.seq_len
    if sh.kind == "prefill":
        return 2.0 * n * sh.global_batch * sh.seq_len
    return 2.0 * n * sh.global_batch  # decode: one token per sequence


def load(dir_: pathlib.Path, mesh: str) -> dict:
    out = {}
    for f in sorted(dir_.glob(f"*__{mesh}.json")):
        d = json.loads(f.read_text())
        out[(d.get("arch", f.stem.split("__")[0]), d.get("shape", f.stem.split("__")[1]))] = d
    return out


def render(dir_: pathlib.Path) -> str:
    hw = HW()
    chips = 256
    lines = []
    single = load(dir_, "single")
    multi = load(dir_, "multi")

    lines.append("### Dry-run matrix (status x mesh)\n")
    lines.append("| arch | shape | 16x16 | 2x16x16 | HBM/dev (single) | fits 16GB |")
    lines.append("|---|---|---|---|---|---|")
    for arch in list_archs(assigned_only=True):
        for shape in SHAPES:
            s = single.get((arch, shape))
            m = multi.get((arch, shape))
            if s is None:
                continue
            if s["status"] == "skipped":
                lines.append(f"| {arch} | {shape} | skip | skip | — | — |")
                continue
            hbm = s["memory_analysis"]["per_device_hbm_bytes"] / 1e9
            fits = "yes" if s["memory_analysis"]["fits_16GB"] else "**no**"
            ms = m["status"] if m else "?"
            lines.append(
                f"| {arch} | {shape} | {s['status']} | {ms} | {hbm:.2f} GB | {fits} |"
            )

    lines.append("\n### Roofline (single-pod 16x16, per chip)\n")
    lines.append(
        "| arch | shape | compute | memory | collective | bottleneck | "
        "MODEL_FLOPS/HLO_FLOPs | note |"
    )
    lines.append("|---|---|---|---|---|---|---|---|")
    for arch in list_archs(assigned_only=True):
        for shape in SHAPES:
            s = single.get((arch, shape))
            if not s or s["status"] != "ok":
                continue
            rr = s["roofline"]
            mf = model_flops(arch, shape) / chips
            ratio = mf / max(rr["flops"], 1.0)
            dom = rr["bottleneck"]
            note = {
                "compute": "MXU-bound: raise arithmetic efficiency (larger tiles/fusion)",
                "memory": "HBM-bound: shrink resident/streamed bytes (quantize more, shard wider)",
                "collective": "ICI-bound: cut comms (SP/FSDP schedule, fewer regathers, overlap)",
            }[dom]
            lines.append(
                f"| {arch} | {shape} | {_fmt_s(rr['compute_s'])} | {_fmt_s(rr['memory_s'])} "
                f"| {_fmt_s(rr['collective_s'])} | {dom} | {ratio:.2f} | {note} |"
            )
    return "\n".join(lines)


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--dir", default=str(
        pathlib.Path(__file__).resolve().parents[3] / "results" / "dryrun"))
    args = ap.parse_args()
    print(render(pathlib.Path(args.dir)))


if __name__ == "__main__":
    main()
