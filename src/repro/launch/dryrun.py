import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=512"

"""Multi-pod dry-run (assignment deliverable e).

Lowers + compiles every (architecture x input-shape x mesh) cell against the
production mesh — (16, 16) single pod and (2, 16, 16) two pods — records
memory_analysis / cost_analysis / multiplicity-weighted collective bytes, and
writes one JSON per cell under results/dryrun/.

The two lines above MUST stay the first statements in this module: jax locks
the device count at first init, and the dry-run (and only the dry-run) needs
512 host-platform placeholder devices.

Usage:
    PYTHONPATH=src python -m repro.launch.dryrun --arch llama3_2_1b --shape train_4k
    PYTHONPATH=src python -m repro.launch.dryrun --all            # every cell
    PYTHONPATH=src python -m repro.launch.dryrun --all --mesh multi
"""

import argparse
import json
import pathlib
import time
import traceback

import jax

from repro.configs.base import SHAPES, get_config, list_archs
from repro.distributed.param_sharding import spec_tree_to_shardings
from repro.distributed.sharding import use_rules
from repro.launch.mesh import make_production_mesh
from repro.launch.roofline import HW, analyze_hlo, roofline_terms
from repro.launch.specs import build_cell, skip_reason

RESULTS_DIR = pathlib.Path(__file__).resolve().parents[3] / "results" / "dryrun"


def run_cell(arch: str, shape: str, multi_pod: bool, out_dir: pathlib.Path,
             kv_quant: bool = False, overrides: dict | None = None,
             tag: str = "", save_hlo: bool = False) -> dict:
    mesh_name = "multi" if multi_pod else "single"
    name = f"{arch}__{shape}__{mesh_name}" + (f"__{tag}" if tag else "")
    out_path = out_dir / f"{name}.json"
    cfg = get_config(arch)
    reason = skip_reason(cfg, SHAPES[shape])
    if reason:
        rec = {"cell": name, "status": "skipped", "reason": reason}
        out_path.write_text(json.dumps(rec, indent=1))
        return rec

    t0 = time.time()
    try:
        setup = build_cell(arch, shape, multi_pod, kv_quant=kv_quant, overrides=overrides)
        mesh = make_production_mesh(multi_pod=multi_pod)
        donate = (0,) if setup.meta["kind"] == "train" else (1,)  # state / caches
        with mesh:
            with use_rules(setup.rules):
                in_shardings = tuple(
                    spec_tree_to_shardings(s, mesh) for s in setup.in_specs
                )
                jitted = jax.jit(
                    setup.step_fn, in_shardings=in_shardings, donate_argnums=donate
                )
                lowered = jitted.lower(*setup.args)
                t_lower = time.time() - t0
                compiled = lowered.compile()
                t_compile = time.time() - t0 - t_lower
                mem = compiled.memory_analysis()
                cost = compiled.cost_analysis()
                hlo = compiled.as_text()
        analysis = analyze_hlo(hlo)
        rr = roofline_terms(analysis, mem)
        hbm_used = (
            mem.argument_size_in_bytes + mem.output_size_in_bytes + mem.temp_size_in_bytes
        )
        rec = {
            "cell": name,
            "status": "ok",
            "arch": arch,
            "shape": shape,
            "mesh": [2, 16, 16] if multi_pod else [16, 16],
            "kind": setup.meta["kind"],
            "meta": setup.meta,
            "lower_s": round(t_lower, 1),
            "compile_s": round(t_compile, 1),
            "memory_analysis": {
                "argument_bytes": mem.argument_size_in_bytes,
                "output_bytes": mem.output_size_in_bytes,
                "temp_bytes": mem.temp_size_in_bytes,
                "alias_bytes": mem.alias_size_in_bytes,
                "per_device_hbm_bytes": hbm_used,
                "fits_16GB": bool(hbm_used < HW().hbm_bytes),
            },
            "cost_analysis": {
                "flops_unweighted": cost.get("flops", -1.0),
                "bytes_accessed_unweighted": cost.get("bytes accessed", -1.0),
            },
            "hlo_analysis": {
                "dot_flops_weighted": analysis["dot_flops"],
                "collective_bytes_weighted": analysis["collective_bytes"],
                "collective_breakdown": analysis["collective_breakdown"],
                "while_trip_counts": analysis["while_trip_counts"],
            },
            "roofline": rr.as_dict(),
        }
        if save_hlo:
            import gzip

            (out_dir / f"{name}.hlo.txt.gz").write_bytes(gzip.compress(hlo.encode()))
        print(
            f"[dryrun] {name}: OK compile={t_compile:.0f}s "
            f"hbm/dev={hbm_used/1e9:.2f}GB fits={rec['memory_analysis']['fits_16GB']} "
            f"bottleneck={rr.bottleneck} "
            f"(c={rr.compute_s*1e3:.2f}ms m={rr.memory_s*1e3:.2f}ms x={rr.collective_s*1e3:.2f}ms)",
            flush=True,
        )
    except Exception as e:  # noqa: BLE001 — a failing cell is a bug to record
        rec = {
            "cell": name,
            "status": "error",
            "error": f"{type(e).__name__}: {e}",
            "traceback": traceback.format_exc()[-4000:],
        }
        print(f"[dryrun] {name}: FAILED {type(e).__name__}: {str(e)[:200]}", flush=True)
    out_path.write_text(json.dumps(rec, indent=1))
    return rec


def main() -> None:
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--arch", choices=list_archs(), action="append")
    ap.add_argument("--shape", choices=list(SHAPES), action="append")
    ap.add_argument("--mesh", choices=["single", "multi", "both"], default="both")
    ap.add_argument("--all", action="store_true", help="all assigned (arch x shape) cells")
    ap.add_argument("--kv-quant", action="store_true", help="int4 K-Means KV cache variant")
    ap.add_argument("--out", default=str(RESULTS_DIR))
    ap.add_argument("--tag", default="")
    ap.add_argument("--save-hlo", action="store_true")
    ap.add_argument("--skip-done", action="store_true", help="skip cells with existing OK results")
    args = ap.parse_args()

    out_dir = pathlib.Path(args.out)
    out_dir.mkdir(parents=True, exist_ok=True)
    archs = args.arch or (list_archs(assigned_only=True) if args.all else [])
    shapes = args.shape or list(SHAPES)
    meshes = {"single": [False], "multi": [True], "both": [False, True]}[args.mesh]
    if not archs:
        ap.error("pass --arch or --all")

    n_ok = n_fail = n_skip = 0
    for arch in archs:
        for shape in shapes:
            for mp in meshes:
                name = f"{arch}__{shape}__{'multi' if mp else 'single'}"
                f = out_dir / (name + (f"__{args.tag}" if args.tag else "") + ".json")
                if args.skip_done and f.exists():
                    prev = json.loads(f.read_text())
                    if prev.get("status") in ("ok", "skipped"):
                        continue
                rec = run_cell(arch, shape, mp, out_dir, kv_quant=args.kv_quant,
                               tag=args.tag, save_hlo=args.save_hlo)
                n_ok += rec["status"] == "ok"
                n_fail += rec["status"] == "error"
                n_skip += rec["status"] == "skipped"
    print(f"[dryrun] done: {n_ok} ok, {n_fail} failed, {n_skip} skipped (documented)")


if __name__ == "__main__":
    main()
