"""Roofline analysis from compiled HLO (EXPERIMENTS.md §Roofline).

XLA's ``compiled.cost_analysis()`` counts while-loop bodies ONCE and reports
per-device numbers (verified empirically on this container). Layer stacks,
grad-accumulation and flash-attention all live in scans here, so a correct
roofline needs *execution-multiplicity weighting*: we parse the compiled HLO
text, build the computation call graph (while bodies x trip counts, fusions,
calls), recover trip counts from the integer constant in each while
condition, and weight per-computation dot-FLOPs / collective bytes by how
often each computation actually runs.

Terms (per device == per chip, since all numbers are post-SPMD):
    compute    = dot_flops_weighted / PEAK_FLOPS
    memory     = (args + outputs + 2 x temps) / HBM_BW      [memory_analysis]
    collective = collective_bytes_weighted / ICI_BW

Hardware constants: TPU v5e-class — 197 TFLOP/s bf16, 819 GB/s HBM,
~50 GB/s/link ICI (assignment-specified).
"""

from __future__ import annotations

import dataclasses
import re

__all__ = ["HW", "analyze_hlo", "roofline_terms", "RooflineResult"]


@dataclasses.dataclass(frozen=True)
class HW:
    peak_flops: float = 197e12  # bf16 per chip
    hbm_bw: float = 819e9  # B/s per chip
    ici_bw: float = 50e9  # B/s per link
    hbm_bytes: float = 16e9  # v5e capacity


_DTYPE_BYTES = {
    "f64": 8, "f32": 4, "f16": 2, "bf16": 2, "f8e4m3fn": 1, "f8e5m2": 1,
    "s64": 8, "s32": 4, "s16": 2, "s8": 1, "s4": 0.5,
    "u64": 8, "u32": 4, "u16": 2, "u8": 1, "u4": 0.5, "pred": 1,
    "c64": 8, "c128": 16,
}

_SHAPE_RE = re.compile(r"(\w+)\[([\d,]*)\]")
_COLLECTIVES = (
    "all-reduce", "all-gather", "reduce-scatter", "all-to-all", "collective-permute",
)


def _shape_bytes(dtype: str, dims: str) -> float:
    n = 1
    for d in dims.split(","):
        if d:
            n *= int(d)
    return n * _DTYPE_BYTES.get(dtype, 4)


def _parse_shapes(segment: str) -> list[tuple[str, list[int]]]:
    out = []
    for m in _SHAPE_RE.finditer(segment):
        dims = [int(d) for d in m.group(2).split(",") if d]
        out.append((m.group(1), dims))
    return out


_HDR_RE = re.compile(r"^\s*(?:ENTRY\s+)?%?([\w.\-]+)\s*\(.*\)\s*->\s*.*\{\s*$")
_INSTR_RE = re.compile(r"^\s*(?:ROOT\s+)?%([\w.\-]+)\s*=\s*(.+)$")


def _split_computations(hlo: str) -> dict[str, list[str]]:
    comps: dict[str, list[str]] = {}
    cur = None
    for line in hlo.splitlines():
        stripped = line.strip()
        if cur is None:
            m = _HDR_RE.match(stripped)
            if m and "metadata=" not in stripped.split("->")[0]:
                cur = m.group(1)
                comps[cur] = []
            continue
        if stripped == "}":
            cur = None
            continue
        if stripped:
            comps[cur].append(stripped)
    return comps


def _entry_name(hlo: str) -> str | None:
    m = re.search(r"^ENTRY\s+%?([\w.\-]+)", hlo, re.MULTILINE)
    return m.group(1) if m else None


@dataclasses.dataclass
class CompStats:
    dot_flops: float = 0.0
    coll_bytes: dict = dataclasses.field(default_factory=dict)
    children: list = dataclasses.field(default_factory=list)  # (name, is_while_body)


def _result_shapes(defn: str) -> list[tuple[str, list[int]]]:
    """Shapes of an instruction's RESULT (the text before the op name/parens)."""
    head = defn.split("(")[0] if not defn.startswith("(") else defn[: defn.index(")") + 1]
    return _parse_shapes(head if head else defn)


def _build_symtab(lines: list[str]) -> dict[str, float]:
    """name -> result bytes (tuples summed) for every instruction."""
    tab: dict[str, float] = {}
    for ln in lines:
        m = _INSTR_RE.match(ln)
        if not m:
            continue
        name, defn = m.group(1), m.group(2)
        # result type is everything before the first op token; shapes upfront
        pre_op = re.split(r"\s[a-z][\w\-]*\(", defn, maxsplit=1)[0]
        tab[name] = sum(
            _shape_bytes(dt, ",".join(map(str, dims))) for dt, dims in _parse_shapes(pre_op)
        )
    return tab


def _dims_of(lines: list[str], target: str) -> list[int] | None:
    """Result dims of instruction ``target`` (first shape in its type)."""
    for ln in lines:
        m = _INSTR_RE.match(ln)
        if m and m.group(1) == target:
            pre_op = re.split(r"\s[a-z][\w\-]*\(", m.group(2), maxsplit=1)[0]
            shapes = _parse_shapes(pre_op)
            return shapes[0][1] if shapes else None
    return None


_OPERANDS_RE = re.compile(r"\(%([\w.\-]+)(?:,\s*%([\w.\-]+))*\)")


def _operand_names(ln: str, op_token: str) -> list[str]:
    i = ln.find(op_token)
    if i < 0:
        return []
    j = ln.find("(", i)
    if j < 0:
        return []
    depth, k = 0, j
    for k in range(j, len(ln)):
        if ln[k] == "(":
            depth += 1
        elif ln[k] == ")":
            depth -= 1
            if depth == 0:
                break
    return re.findall(r"%([\w.\-]+)", ln[j : k + 1])


def _analyze_computation(lines: list[str]) -> CompStats:
    st = CompStats()
    symtab = _build_symtab(lines)
    for ln in lines:
        m = _INSTR_RE.match(ln)
        if not m:
            continue
        defn = m.group(2)
        # ---- sub-computation references (strip metadata first: op_name
        # strings contain arbitrary text) ----------------------------------
        clean = re.sub(r"metadata=\{[^}]*\}", "", defn)
        for attr, is_while in (("body", True), ("to_apply", False), ("calls", False)):
            for cm in re.finditer(rf"{attr}=%?([\w.\-]+)", clean):
                st.children.append((cm.group(1), is_while))
        # ---- dot flops ----------------------------------------------------
        dm = re.search(r"\sdot\(", clean)
        if dm:
            res = _result_shapes(defn)
            ops = _operand_names(clean, " dot(")
            if res and ops:
                lhs_dims = _dims_of(lines, ops[0]) or []
                contract = 1
                cm2 = re.search(r"lhs_contracting_dims=\{([\d,]*)\}", clean)
                if cm2:
                    for i in cm2.group(1).split(","):
                        if i and int(i) < len(lhs_dims):
                            contract *= lhs_dims[int(i)]
                res_elems = 1
                for d in res[0][1]:
                    res_elems *= d
                st.dot_flops += 2.0 * res_elems * contract
        # ---- collectives ---------------------------------------------------
        for op in _COLLECTIVES:
            token = f" {op}("
            token_start = f" {op}-start("
            use = token if token in clean else (token_start if token_start in clean else None)
            if use is None:
                continue
            operand_bytes = sum(symtab.get(o, 0.0) for o in _operand_names(clean, use))
            st.coll_bytes[op] = st.coll_bytes.get(op, 0.0) + operand_bytes
            break
    return st


def _while_trip_counts(comps: dict[str, list[str]]) -> dict[str, int]:
    """Map while-BODY computation name -> trip count, via the integer constant
    in the condition computation (jax scans compare counter < constant)."""
    trips: dict[str, int] = {}
    for lines in comps.values():
        for ln in lines:
            if " while(" not in ln:
                continue
            clean = re.sub(r"metadata=\{[^}]*\}", "", ln)
            bm = re.search(r"body=%?([\w.\-]+)", clean)
            cm = re.search(r"condition=%?([\w.\-]+)", clean)
            if not bm or not cm:
                continue
            consts = []
            for cl in comps.get(cm.group(1), []):
                consts += [int(x) for x in re.findall(r"constant\((\d+)\)", cl)]
            trips[bm.group(1)] = max(consts) if consts else 1
    return trips


def analyze_hlo(hlo: str) -> dict:
    """Multiplicity-weighted dot-FLOPs and collective bytes (per device)."""
    comps = _split_computations(hlo)
    trips = _while_trip_counts(comps)
    stats = {name: _analyze_computation(lines) for name, lines in comps.items()}

    entry = _entry_name(hlo)
    mult: dict[str, float] = {}

    def visit(name: str, m: float, depth: int = 0):
        if name not in stats or depth > 64:
            return
        mult[name] = mult.get(name, 0.0) + m
        for child, is_while_body in stats[name].children:
            trip = trips.get(child, 1) if is_while_body else 1
            visit(child, m * trip, depth + 1)

    if entry:
        visit(entry, 1.0)
    else:  # fallback: count everything once
        for name in stats:
            mult[name] = 1.0

    flops = sum(stats[n].dot_flops * m for n, m in mult.items())
    coll: dict[str, float] = {}
    for n, m in mult.items():
        for op, b in stats[n].coll_bytes.items():
            coll[op] = coll.get(op, 0.0) + b * m
    return {
        "dot_flops": flops,
        "collective_bytes": sum(coll.values()),
        "collective_breakdown": coll,
        "n_computations": len(comps),
        "while_trip_counts": trips,
    }


@dataclasses.dataclass
class RooflineResult:
    compute_s: float
    memory_s: float
    collective_s: float
    bottleneck: str
    flops: float
    mem_bytes: float
    coll_bytes: float

    def as_dict(self):
        return dataclasses.asdict(self)


def roofline_terms(hlo_analysis: dict, memory_analysis, hw: HW = HW()) -> RooflineResult:
    flops = hlo_analysis["dot_flops"]
    mem_bytes = (
        memory_analysis.argument_size_in_bytes
        + memory_analysis.output_size_in_bytes
        + 2 * memory_analysis.temp_size_in_bytes
    )
    coll_bytes = hlo_analysis["collective_bytes"]
    terms = {
        "compute": flops / hw.peak_flops,
        "memory": mem_bytes / hw.hbm_bw,
        "collective": coll_bytes / hw.ici_bw,
    }
    bottleneck = max(terms, key=terms.get)
    return RooflineResult(
        compute_s=terms["compute"],
        memory_s=terms["memory"],
        collective_s=terms["collective"],
        bottleneck=bottleneck,
        flops=flops,
        mem_bytes=mem_bytes,
        coll_bytes=coll_bytes,
    )
