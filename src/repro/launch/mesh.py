"""Production mesh definition.

A FUNCTION (not a module-level constant) so importing this module never
touches jax device state — the dry-run sets XLA_FLAGS before any jax init,
and unit tests must keep seeing 1 device.

Topology assumptions (TPU v5e-class): 256 chips/pod arranged (16, 16) as
("data", "model") — 16-way Megatron TP within a pod row, 16-way DP across.
Multi-pod adds a leading "pod" axis for cross-pod data parallelism (DCN-class
links: only DP gradient all-reduces cross it). The same code takes
(P, 16, 16) for P pods — 2 pods here per the assignment; nothing in the
sharding rules is specific to P=2.
"""

from __future__ import annotations

import jax

__all__ = ["make_production_mesh", "batch_axes_for", "MODEL_AXIS_SIZE"]

MODEL_AXIS_SIZE = 16


def make_production_mesh(*, multi_pod: bool = False):
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return jax.make_mesh(shape, axes)


def batch_axes_for(multi_pod: bool):
    """Mesh axes carrying the global batch (DP spans pods x data rows)."""
    return ("pod", "data") if multi_pod else ("data",)
