"""Per-cell setup for the multi-pod dry-run: step fn + ShapeDtypeStruct args
+ sharding specs, for every (arch x shape x mesh) combination.

``input_specs`` follows the assignment contract: weak-type-correct,
shardable ShapeDtypeStruct stand-ins for every model input — nothing is
allocated. The FULL architecture configs only ever exist through here.

Shape kinds lower different entry points (assignment spec):
  train_*   -> train_step   (fp params + AdamW state, grad-accum scan)
  prefill_* -> prefill step (QUANTIZED params: the paper's serving path)
  decode_* / long_* -> serve_step (one new token against a seq_len KV cache)
"""

from __future__ import annotations

import dataclasses
from functools import partial
from typing import Any, Callable

import jax
import jax.numpy as jnp
from jax.sharding import PartitionSpec as P

from repro.configs.base import SHAPES, ModelConfig, ShapeConfig, get_config
from repro.core.qlinear import QLinearConfig
from repro.core.quantspec import QuantSpec
from repro.distributed.param_sharding import build_cache_specs, build_param_specs
from repro.launch.mesh import MODEL_AXIS_SIZE, batch_axes_for
from repro.models.model import build, quantize_params
from repro.optim.adamw import AdamWConfig
from repro.serving.engine import ServeConfig, make_prefill_step, make_serve_step
from repro.train.trainer import TrainConfig, init_train_state, make_train_step

__all__ = ["CellSetup", "make_rules", "build_cell", "input_specs", "runnable", "skip_reason"]


@dataclasses.dataclass
class CellSetup:
    arch: str
    shape: str
    multi_pod: bool
    step_fn: Callable
    args: tuple  # ShapeDtypeStruct pytrees
    in_specs: tuple  # PartitionSpec pytrees (same structure as args)
    rules: dict
    cfg: ModelConfig
    meta: dict


def make_rules(cfg: ModelConfig, multi_pod: bool, batch_shardable: bool = True,
               seq_parallel: bool = False, seq_len: int = 0) -> dict:
    """Logical-axis -> mesh-axis map with per-arch divisibility fallbacks.

    seq_parallel=True shards the RESIDUAL-STREAM seq dim on "model" (Megatron
    SP): layernorms/residuals run on seq shards, GSPMD inserts the all-gather
    before attention/MLP and the reduce-scatter after — activation memory and
    the per-layer activation all-reduce both drop by the TP degree.
    """
    m = MODEL_AXIS_SIZE
    batch = batch_axes_for(multi_pod) if batch_shardable else None
    fits = lambda dim: (dim or 0) % m == 0 and dim
    return {
        "batch": batch,
        "seq": None,
        "seq_sp": "model" if (seq_parallel and fits(seq_len)) else None,
        "d_model": None,
        "heads_flat": "model" if fits(cfg.n_heads * cfg.head_dim) else None,
        "kv_heads": "model" if fits(cfg.n_kv_heads) else None,
        "d_ff": "model" if fits(cfg.d_ff) else None,
        "vocab": "model",  # vocab_padded is always a multiple of 128
        "experts": "model" if fits(cfg.n_experts) else None,
        "dispatch_groups": batch,  # group-local MoE dispatch follows DP
        "d_inner": "model" if fits(cfg.d_inner) else None,
        "state": None,
    }


def runnable(cfg: ModelConfig, shape: ShapeConfig) -> bool:
    return skip_reason(cfg, shape) is None


def skip_reason(cfg: ModelConfig, shape: ShapeConfig) -> str | None:
    if shape.name == "long_500k" and not cfg.supports_long_context():
        return (
            "pure full-attention arch: 500k decode requires sub-quadratic "
            "attention (DESIGN.md §5); run only for SSM/SWA/hybrid"
        )
    return None


def _batch_shards(multi_pod: bool) -> int:
    return 32 if multi_pod else 16


def input_specs(cfg: ModelConfig, shape: ShapeConfig) -> dict:
    """ShapeDtypeStructs for the model inputs of one cell (no allocation)."""
    b, s = shape.global_batch, shape.seq_len
    cdt = jnp.dtype(cfg.compute_dtype)
    if shape.kind == "train":
        out = {"tokens": jax.ShapeDtypeStruct((b, s + 1), jnp.int32)}
    elif shape.kind == "prefill":
        out = {"tokens": jax.ShapeDtypeStruct((b, s), jnp.int32)}
    else:  # decode: one new token, cache of length s
        out = {"tokens": jax.ShapeDtypeStruct((b, 1), jnp.int32)}
    if cfg.family == "vlm":
        out["image_embeds"] = jax.ShapeDtypeStruct((b, cfg.n_img_tokens, cfg.d_model), cdt)
    return out


def _batch_pspec(batch_specs: dict, batch_axes) -> dict:
    return {
        k: P(batch_axes, *([None] * (len(v.shape) - 1)))
        for k, v in batch_specs.items()
    }


def build_cell(arch: str, shape_name: str, multi_pod: bool,
               quantized_serving: bool = True, kv_quant: bool = False,
               overrides: dict | None = None) -> CellSetup:
    cfg = get_config(arch)
    if overrides:
        cfg = dataclasses.replace(cfg, **overrides)
    shape = SHAPES[shape_name]
    reason = skip_reason(cfg, shape)
    if reason:
        raise ValueError(f"cell ({arch}, {shape_name}) skipped: {reason}")
    model = build(cfg)
    shards = _batch_shards(multi_pod)
    batch_shardable = shape.global_batch % shards == 0
    # Megatron SP for wide-activation training cells (residual stream sharded
    # on "model"); see make_rules. Enabled where activations dominate HBM.
    seq_parallel = shape.kind == "train" and cfg.d_model >= 4096
    rules = make_rules(cfg, multi_pod, batch_shardable,
                       seq_parallel=seq_parallel, seq_len=shape.seq_len)
    batch_axes = rules["batch"]
    key = jax.random.PRNGKey(0)

    if shape.kind == "train":
        # Microbatch count trades activation memory against FSDP/TP gradient-
        # reduction traffic (grad collectives scale LINEARLY with microbatches
        # — §Perf iteration C1: 16 micro cost 191 s/step of ICI on the 104B).
        # With SP + double remat the activations fit at micro=2 even at 104B.
        max_micro = 4 if cfg.n_params > 50e9 else 8
        micro = max(1, min(max_micro, shape.global_batch // shards))
        tc = TrainConfig(optimizer=AdamWConfig(), microbatches=micro)
        state_shapes = jax.eval_shape(partial(init_train_state, model, key, tc))
        batch_shapes = input_specs(cfg, shape)
        # ZeRO-3/FSDP when TP-sharded (params + adam moments + grads) would
        # blow the 16 GB HBM: bf16 params + 2x f32 moments + f32 grads = 14 B/p
        fsdp = None
        if cfg.n_params * 14 / MODEL_AXIS_SIZE > 8e9 and batch_shardable:
            fsdp = batch_axes
        kw = dict(fsdp_axes=fsdp, fsdp_shards=shards if fsdp else 1)
        pspecs = build_param_specs(state_shapes["params"], MODEL_AXIS_SIZE, **kw)
        state_specs = {
            "params": pspecs,
            "opt": {
                "m": build_param_specs(state_shapes["opt"]["m"], MODEL_AXIS_SIZE, **kw),
                "v": build_param_specs(state_shapes["opt"]["v"], MODEL_AXIS_SIZE, **kw),
                "step": P(),
            },
        }
        return CellSetup(
            arch, shape_name, multi_pod,
            step_fn=make_train_step(model, tc),
            args=(state_shapes, batch_shapes),
            in_specs=(state_specs, _batch_pspec(batch_shapes, batch_axes)),
            rules=rules, cfg=cfg,
            meta={"kind": "train", "microbatches": micro, "tokens": shape.global_batch * shape.seq_len},
        )

    # ---- serving cells -----------------------------------------------------
    # decode: dynamic Orizuru detection (1-token sorts are free; Fig 3 says
    # dynamic is more accurate). prefill: OASIS-S static thresholds with
    # dense masked compensation — full sorts over 32k-token activations cost
    # ~70 GB/device of workspace (EXPERIMENTS §Perf P1).
    spec = QuantSpec(base=QLinearConfig(
        outlier_frac=0.005,
        detection="dynamic" if shape.kind == "decode" else "static_dense",
        compute_dtype=jnp.dtype(cfg.compute_dtype),
    ))
    sc = ServeConfig(cache_len=shape.seq_len, kv_quant=kv_quant,
                     quantized=quantized_serving)
    params_shapes = jax.eval_shape(partial(model.init, key))
    if quantized_serving:
        # the resolved config rides in each QLinearParams meta field, so the
        # lowered step needs no apply-time quantization plumbing
        params_shapes = jax.eval_shape(partial(quantize_params, spec=spec), params_shapes)
    cache_dt = jnp.dtype("bfloat16")
    caches_shapes = jax.eval_shape(
        partial(model.init_caches, shape.global_batch, shape.seq_len, cache_dt, kv_quant)
    )
    pspecs = build_param_specs(params_shapes, MODEL_AXIS_SIZE)
    cspecs = build_cache_specs(
        caches_shapes, batch_axes, shards, MODEL_AXIS_SIZE,
        kv_heads=cfg.n_kv_heads, ssm_state=cfg.ssm_state,
    )
    batch_shapes = input_specs(cfg, shape)

    if shape.kind == "prefill":
        return CellSetup(
            arch, shape_name, multi_pod,
            step_fn=make_prefill_step(model, sc),
            args=(params_shapes, caches_shapes, batch_shapes),
            in_specs=(pspecs, cspecs, _batch_pspec(batch_shapes, batch_axes)),
            rules=rules, cfg=cfg,
            meta={"kind": "prefill", "quantized": quantized_serving,
                  "tokens": shape.global_batch * shape.seq_len},
        )

    # decode
    tok_spec = {"tokens": batch_shapes["tokens"]}
    return CellSetup(
        arch, shape_name, multi_pod,
        step_fn=make_serve_step(model, sc),
        args=(params_shapes, caches_shapes, tok_spec["tokens"],
              jax.ShapeDtypeStruct((), jnp.int32)),
        in_specs=(pspecs, cspecs, P(batch_axes, None), P()),
        rules=rules, cfg=cfg,
        meta={"kind": "decode", "quantized": quantized_serving, "kv_quant": kv_quant,
              "tokens": shape.global_batch},
    )
