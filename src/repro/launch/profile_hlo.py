"""Collective profiler: attribute weighted collective bytes to source ops.

Parses a saved compiled-HLO dump (dryrun --save-hlo) and prints the top
collectives by execution-multiplicity-weighted bytes, with the jax op_name
metadata that names the responsible source operation — the dry-run
equivalent of reading a profiler's comm lanes.

Usage:
  PYTHONPATH=src python -m repro.launch.profile_hlo results/dryrun/<cell>.hlo.txt.gz [top_n]
"""

from __future__ import annotations

import gzip
import re
import sys

from repro.launch.roofline import (
    _analyze_computation,
    _split_computations,
    _while_trip_counts,
    _INSTR_RE,
    _build_symtab,
    _COLLECTIVES,
    _operand_names,
    _entry_name,
)


def collect(hlo: str, top_n: int = 15):
    comps = _split_computations(hlo)
    trips = _while_trip_counts(comps)
    stats = {n: _analyze_computation(lines) for n, lines in comps.items()}
    mult: dict[str, float] = {}

    def visit(name, m, depth=0):
        if name not in stats or depth > 64:
            return
        mult[name] = mult.get(name, 0.0) + m
        for child, is_wb in stats[name].children:
            visit(child, m * (trips.get(child, 1) if is_wb else 1), depth + 1)

    entry = _entry_name(hlo)
    if entry:
        visit(entry, 1.0)

    rows = []
    for cname, lines in comps.items():
        m = mult.get(cname, 0.0)
        if m == 0:
            continue
        symtab = _build_symtab(lines)
        for ln in lines:
            im = _INSTR_RE.match(ln)
            if not im:
                continue
            clean = re.sub(r"metadata=\{[^}]*\}", " ", im.group(2))
            for op in _COLLECTIVES:
                tok = f" {op}(" if f" {op}(" in clean else (
                    f" {op}-start(" if f" {op}-start(" in clean else None)
                if tok is None:
                    continue
                bytes_ = sum(symtab.get(o, 0.0) for o in _operand_names(clean, tok))
                nm = re.search(r'op_name="([^"]*)"', im.group(2))
                rows.append((bytes_ * m, bytes_, m, op, cname,
                             (nm.group(1) if nm else "?")[-110:]))
                break
    rows.sort(reverse=True)
    return rows[:top_n]


def main() -> None:
    path = sys.argv[1]
    top_n = int(sys.argv[2]) if len(sys.argv) > 2 else 15
    hlo = gzip.decompress(open(path, "rb").read()).decode() if path.endswith(".gz") else open(path).read()
    total = 0.0
    rows = collect(hlo, top_n)
    print(f"{'weighted_GB':>11} {'per_exec_MB':>11} {'mult':>6}  op              source")
    for wb, b, m, op, cname, opname in rows:
        total += wb
        print(f"{wb/1e9:>11.2f} {b/1e6:>11.1f} {m:>6.0f}  {op:<15} {opname}")
    print(f"top-{top_n} total: {total/1e9:.1f} GB weighted")


if __name__ == "__main__":
    main()
