"""Serving telemetry: typed metrics, request lifecycle timelines, step records.

One :class:`Telemetry` object per engine owns four things:

1. a **typed metrics registry** — :class:`Counter`, :class:`Gauge` (settable
   or callback-backed), and fixed-bucket :class:`Histogram` (log- or
   linear-spaced). It absorbs the scheduler's old ad-hoc ``stats`` dict: the
   counters ARE the stats now, and ``Scheduler.stats`` /
   ``ServingEngine.stats`` rebuild the legacy keys from the registry.
2. a **per-request lifecycle timeline** — enqueue → admit (with prefix-hit
   size) → prefill chunks → first token → verify rounds / rollbacks →
   preempt / finish, with wall times, so TTFT, inter-token latency, queue
   wait, and end-to-end latency percentiles come from the engine itself
   rather than a bench harness. TTFT/ITL/latency *histograms* update at the
   default ``metrics`` level; full per-request event lists are kept only
   under ``trace``.
3. a **bounded ring buffer of per-packed-step records** — budget
   utilization, rows by kind (decode / verify / prefill), blocks
   allocated / freed / copied this step, and the host-prep vs device time
   split (device time is dispatch wall time; pass ``fence=True`` to
   ``block_until_ready`` the step output so the split is exact on async
   backends).
4. **exporters** — :meth:`Telemetry.snapshot` (JSON-able dict of every
   metric plus derived percentiles) and :meth:`Telemetry.export_chrome_trace`
   (Chrome/Perfetto trace-event JSON: packed steps and draft dispatches as
   slices on an engine lane, one lane per request with queued / prefill /
   decode phases and instant events — load it at ``ui.perfetto.dev``).

Levels (``ServeConfig.telemetry``): ``"off"`` is a null object — every method
is a no-op, no per-token work, zero device dispatches, and the packed step's
jaxpr is untouched (telemetry never wraps traced code; only host-side
``jax.profiler.TraceAnnotation`` spans are emitted, and only when enabled).
``"metrics"`` (default) keeps counters, gauges, histograms, and the step
ring. ``"trace"`` additionally records per-request event timelines and named
spans for the Perfetto export. ``"quality"`` is trace plus the quantization-
numerics observability layer (``core/numerics``): the scheduler swaps in a
PROBED packed step on sampled steps (1 in ``quality_sample_every``), so —
unlike every other level — quality is allowed to retrace/recompile; the
off/metrics/trace jaxprs stay byte-identical (asserted in
tests/test_numerics.py). Quality metrics land in the same registry
(``numerics_*`` families), as Perfetto COUNTER TRACKS (pid 2) on the same
timeline as the latency lanes, and in the Prometheus text exposition
:meth:`Telemetry.expfmt`.

:class:`StreamingStats` is the one windowed streaming-stats implementation in
the repo: the step records use it for running step-time medians, and
``repro.distributed.fault_tolerance.StepMonitor`` is a thin straggler-
detection wrapper over it (re-exported there).
"""

from __future__ import annotations

import bisect
import contextlib
import dataclasses
import json
import math
import pathlib
import re
import time
from collections import deque

__all__ = [
    "Counter", "Gauge", "Histogram", "MetricsRegistry", "StreamingStats",
    "TelemetryConfig", "Telemetry", "NullTelemetry", "make_telemetry",
    "log_buckets", "linear_buckets",
]


# ---------------------------------------------------------------------------
# bucket helpers
# ---------------------------------------------------------------------------

def log_buckets(lo: float, hi: float, per_decade: int = 6) -> list[float]:
    """Geometric bucket upper bounds covering [lo, hi]."""
    if not (0 < lo < hi):
        raise ValueError(f"need 0 < lo < hi, got ({lo}, {hi})")
    n = max(1, round(per_decade * math.log10(hi / lo)))
    ratio = (hi / lo) ** (1.0 / n)
    return [lo * ratio**i for i in range(n + 1)]


def linear_buckets(lo: float, hi: float, n: int) -> list[float]:
    """n equal-width bucket upper bounds over [lo, hi]."""
    if n < 1 or hi <= lo:
        raise ValueError(f"need n >= 1 and hi > lo, got n={n}, ({lo}, {hi})")
    w = (hi - lo) / n
    return [lo + w * (i + 1) for i in range(n)]


# ---------------------------------------------------------------------------
# metric types
# ---------------------------------------------------------------------------

class Counter:
    """Monotonic counter (floats allowed: time totals are counters too)."""

    __slots__ = ("name", "help", "value")

    def __init__(self, name: str, help: str = ""):
        self.name, self.help = name, help
        self.value = 0

    def add(self, n=1) -> None:
        self.value += n

    def reset(self) -> None:
        self.value = 0


class Gauge:
    """Point-in-time value: ``set``, ``set_max`` (high-water mark), or a
    zero-arg callback evaluated lazily at snapshot time (allocator state)."""

    __slots__ = ("name", "help", "fn", "_value")

    def __init__(self, name: str, help: str = "", fn=None):
        self.name, self.help, self.fn = name, help, fn
        self._value = 0.0

    @property
    def value(self):
        return self.fn() if self.fn is not None else self._value

    def set(self, v) -> None:
        self._value = v

    def set_max(self, v) -> None:
        if v > self._value:
            self._value = v

    def reset(self) -> None:
        self._value = 0.0


class Histogram:
    """Fixed-bucket histogram: ``bounds`` are ascending upper bounds, with an
    implicit +inf overflow bucket. Percentiles are interpolated inside the
    landing bucket (exact per-sample values are never stored — observation is
    O(log buckets) and allocation-free)."""

    __slots__ = ("name", "help", "bounds", "counts", "count", "sum", "min", "max")

    def __init__(self, name: str, bounds: list[float], help: str = ""):
        if list(bounds) != sorted(bounds) or len(bounds) < 1:
            raise ValueError(f"histogram {name}: bounds must be ascending")
        self.name, self.help = name, help
        self.bounds = [float(b) for b in bounds]
        self.reset()

    def reset(self) -> None:
        self.counts = [0] * (len(self.bounds) + 1)
        self.count = 0
        self.sum = 0.0
        self.min = math.inf
        self.max = -math.inf

    def observe(self, v: float) -> None:
        self.counts[bisect.bisect_left(self.bounds, v)] += 1
        self.count += 1
        self.sum += v
        if v < self.min:
            self.min = v
        if v > self.max:
            self.max = v

    def percentile(self, q: float) -> float:
        """Approximate q-th percentile (q in [0, 100]) by linear interpolation
        within the landing bucket, clamped to the observed min/max."""
        if self.count == 0:
            return 0.0
        target = q / 100.0 * self.count
        acc = 0
        for i, c in enumerate(self.counts):
            if c == 0:
                continue
            if acc + c >= target:
                lo = self.bounds[i - 1] if i > 0 else min(self.min, self.bounds[0])
                hi = self.bounds[i] if i < len(self.bounds) else self.max
                frac = (target - acc) / c
                v = lo + (hi - lo) * max(0.0, min(1.0, frac))
                return max(self.min, min(self.max, v))
            acc += c
        return self.max

    def summary(self) -> dict:
        if self.count == 0:
            return {"count": 0}
        return {
            "count": self.count,
            "sum": self.sum,
            "mean": self.sum / self.count,
            "min": self.min,
            "max": self.max,
            "p50": self.percentile(50),
            "p95": self.percentile(95),
            "p99": self.percentile(99),
        }


class _NullMetric:
    """Shared no-op stand-in for off-level counters/gauges/histograms."""

    name = "null"
    value = 0
    count = 0
    sum = 0.0

    def add(self, n=1):
        pass

    def set(self, v):
        pass

    def set_max(self, v):
        pass

    def observe(self, v):
        pass

    def reset(self):
        pass

    def percentile(self, q):
        return 0.0

    def summary(self):
        return {"count": 0}


_NULL_METRIC = _NullMetric()


class MetricsRegistry:
    """Name -> metric table; get-or-create, so instrumentation sites never
    race over who registers first (names are global per engine)."""

    def __init__(self):
        self.counters: dict[str, Counter] = {}
        self.gauges: dict[str, Gauge] = {}
        self.histograms: dict[str, Histogram] = {}

    def counter(self, name: str, help: str = "") -> Counter:
        c = self.counters.get(name)
        if c is None:
            c = self.counters[name] = Counter(name, help)
        return c

    def gauge(self, name: str, help: str = "", fn=None) -> Gauge:
        g = self.gauges.get(name)
        if g is None:
            g = self.gauges[name] = Gauge(name, help, fn=fn)
        elif fn is not None:
            g.fn = fn
        return g

    def histogram(self, name: str, bounds: list[float] | None = None,
                  help: str = "") -> Histogram:
        h = self.histograms.get(name)
        if h is None:
            h = self.histograms[name] = Histogram(
                name, bounds if bounds is not None else log_buckets(1e-5, 100.0),
                help)
        return h

    def reset(self) -> None:
        for m in (*self.counters.values(), *self.gauges.values(),
                  *self.histograms.values()):
            m.reset()

    def snapshot(self) -> dict:
        return {
            "counters": {k: c.value for k, c in sorted(self.counters.items())},
            "gauges": {k: g.value for k, g in sorted(self.gauges.items())},
            "histograms": {k: h.summary()
                           for k, h in sorted(self.histograms.items())},
        }


# ---------------------------------------------------------------------------
# streaming stats (shared with distributed.fault_tolerance.StepMonitor)
# ---------------------------------------------------------------------------

class StreamingStats:
    """Windowed streaming statistics over a scalar series (step times).

    THE streaming-stats implementation: telemetry's step records use it for
    running medians, and ``fault_tolerance.StepMonitor`` layers straggler
    detection on top rather than keeping a parallel copy."""

    def __init__(self, window: int = 64):
        self.window = window
        self._vals: deque[float] = deque(maxlen=window)

    def record(self, v: float) -> None:
        self._vals.append(v)

    @property
    def times(self) -> list[float]:
        return list(self._vals)

    def __len__(self) -> int:
        return len(self._vals)

    def mean(self) -> float:
        return sum(self._vals) / len(self._vals) if self._vals else 0.0

    def percentile(self, q: float) -> float:
        if not self._vals:
            return 0.0
        s = sorted(self._vals)
        return s[min(len(s) - 1, int(q / 100.0 * (len(s) - 1) + 0.5))]

    def median(self) -> float:
        if not self._vals:
            return 0.0
        s = sorted(self._vals)
        n = len(s)
        return s[n // 2] if n % 2 else 0.5 * (s[n // 2 - 1] + s[n // 2])

    def summary(self) -> dict:
        if not self._vals:
            return {}
        return {"median_s": self.median(), "p95_s": self.percentile(95),
                "mean_s": self.mean(), "n": len(self._vals)}


# ---------------------------------------------------------------------------
# telemetry object
# ---------------------------------------------------------------------------

_LEVELS = ("off", "metrics", "trace", "quality")


@dataclasses.dataclass(frozen=True)
class TelemetryConfig:
    """``ServeConfig.telemetry``. ``level``: ``"off"`` (null object),
    ``"metrics"`` (default: counters/gauges/histograms + step ring),
    ``"trace"`` (adds per-request event timelines + named spans for the
    Perfetto export), or ``"quality"`` (trace + the quantization-numerics
    probes of ``core/numerics``; the only level allowed to recompile).
    ``fence=True`` blocks on the packed step's output so the host/device
    time split is exact (adds a sync, never a dispatch). ``step_ring``
    bounds the per-step record buffer; ``max_requests`` bounds completed
    request timelines kept under trace.

    Quality knobs (ignored below level quality): ``quality_sample_every``
    probes 1 in N packed steps (step 0 always probes, so short smokes
    populate every gauge); ``quality_shadow_every`` runs the shadow-
    reference forward every N packed steps; ``quality_drift_threshold`` is
    the absolute per-site drift score that raises ``numerics_drift_alarms``.
    """

    level: str = "metrics"
    fence: bool = False
    step_ring: int = 512
    max_requests: int = 2048
    quality_sample_every: int = 16
    quality_shadow_every: int = 32
    quality_drift_threshold: float = 0.5

    def __post_init__(self):
        if self.level not in _LEVELS:
            raise ValueError(
                f"telemetry level must be one of {_LEVELS}, got {self.level!r}")
        if self.step_ring < 1 or self.max_requests < 1:
            raise ValueError("step_ring and max_requests must be >= 1")
        if self.quality_sample_every < 1 or self.quality_shadow_every < 1:
            raise ValueError(
                "quality_sample_every and quality_shadow_every must be >= 1")
        if self.quality_drift_threshold <= 0:
            raise ValueError("quality_drift_threshold must be > 0")

    @classmethod
    def parse(cls, v) -> "TelemetryConfig":
        """Coerce ServeConfig.telemetry: a config, a level string, a bool
        (True -> metrics, False -> off), or None -> off."""
        if isinstance(v, cls):
            return v
        if v is None or v is False:
            return cls(level="off")
        if v is True:
            return cls(level="metrics")
        if isinstance(v, str):
            if v == "trace":
                return cls(level="trace")
            return cls(level=v)
        raise TypeError(f"cannot parse telemetry config from {v!r}")


@dataclasses.dataclass
class _RequestTrace:
    rid: int
    t_enqueue: float
    n_prompt: int = 0
    t_admit: float | None = None
    t_first_token: float | None = None
    t_finish: float | None = None
    t_last_token: float | None = None
    prefix_hit_tokens: int = 0
    n_generated: int = 0
    preemptions: int = 0
    events: list | None = None  # [(t, name, args)] under trace level


class Telemetry:
    """Live telemetry for one serving engine (see module docstring)."""

    def __init__(self, cfg: TelemetryConfig | None = None, clock=time.perf_counter):
        self.cfg = cfg or TelemetryConfig()
        if self.cfg.level == "off":
            raise ValueError("level=off is NullTelemetry; use make_telemetry()")
        self._clock = clock
        self.registry = MetricsRegistry()
        self.step_times = StreamingStats(window=min(self.cfg.step_ring, 256))
        self._t0 = clock()
        self.steps: deque[dict] = deque(maxlen=self.cfg.step_ring)
        self.spans: deque[tuple] = deque(maxlen=4 * self.cfg.step_ring)
        self._live: dict[int, _RequestTrace] = {}
        self.completed: deque[_RequestTrace] = deque(maxlen=self.cfg.max_requests)
        # (t, name, value) samples for Perfetto counter tracks (quality level)
        self.quality_series: deque[tuple] = deque(maxlen=8 * self.cfg.step_ring)
        self._mk_serving_metrics()

    # -------------------------------------------------------------- plumbing
    @property
    def enabled(self) -> bool:
        return True

    @property
    def tracing(self) -> bool:
        return self.cfg.level in ("trace", "quality")

    @property
    def quality(self) -> bool:
        return self.cfg.level == "quality"

    @property
    def fence(self) -> bool:
        return self.cfg.fence

    def now(self) -> float:
        return self._clock()

    def counter(self, name: str, help: str = "") -> Counter:
        return self.registry.counter(name, help)

    def gauge(self, name: str, help: str = "", fn=None) -> Gauge:
        return self.registry.gauge(name, help, fn=fn)

    def histogram(self, name: str, bounds=None, help: str = "") -> Histogram:
        return self.registry.histogram(name, bounds, help)

    def annotate(self, name: str):
        """Host-side ``jax.profiler.TraceAnnotation`` span (so XLA profiles
        line up with our timeline names) that ALSO lands in the span deque
        under trace level. Never wraps traced code — the jaxpr is untouched."""
        import jax.profiler

        ann = jax.profiler.TraceAnnotation(name)
        if not self.tracing:
            return ann
        return _Span(self, name, ann)

    def reset(self) -> None:
        """Zero every metric and drop buffered timelines (benchmarks call
        this after jit warmup so measurements start clean)."""
        self.registry.reset()
        self.steps.clear()
        self.spans.clear()
        self._live.clear()
        self.completed.clear()
        self.quality_series.clear()
        self.step_times = StreamingStats(window=self.step_times.window)
        self._t0 = self._clock()

    def quality_counter(self, name: str, value: float) -> None:
        """Record one sample of a quality counter track (rendered as a
        Perfetto "C" event on pid 2, sharing the timeline with the latency
        lanes). Bounded deque; call per probed step, not per site."""
        self.quality_series.append((self.now(), name, float(value)))

    def _mk_serving_metrics(self) -> None:
        """Pre-register the serving metric families so a snapshot taken
        before traffic still shows the full (zeroed) schema."""
        self.hist_ttft = self.histogram(
            "serving_ttft_s", log_buckets(1e-4, 1e3),
            "enqueue -> first sampled token, seconds")
        self.hist_itl = self.histogram(
            "serving_itl_s", log_buckets(1e-5, 1e2),
            "inter-token latency per committed decode token, seconds")
        self.hist_e2e = self.histogram(
            "serving_e2e_s", log_buckets(1e-4, 1e3),
            "enqueue -> finish, seconds")
        self.hist_queue = self.histogram(
            "serving_queue_wait_s", log_buckets(1e-5, 1e3),
            "enqueue -> admission, seconds")
        self.hist_step_host = self.histogram(
            "serving_step_host_s", log_buckets(1e-6, 1e2),
            "host-side packed-step prep per step, seconds")
        self.hist_step_device = self.histogram(
            "serving_step_device_s", log_buckets(1e-6, 1e2),
            "packed-step dispatch (device when fenced) per step, seconds")
        self.hist_step_util = self.histogram(
            "serving_step_util", linear_buckets(0.0, 1.0, 20),
            "valid cells / token budget per packed step")

    # ------------------------------------------------------ request lifecycle
    def _trace(self, rid: int) -> _RequestTrace | None:
        return self._live.get(rid)

    def request_submitted(self, rid: int, n_prompt: int) -> None:
        t = self.now()
        self.counter("serving_requests_submitted").add()
        tr = _RequestTrace(rid=rid, t_enqueue=t, n_prompt=n_prompt)
        if self.tracing:
            tr.events = [(t, "enqueue", {"prompt_tokens": n_prompt})]
        self._live[rid] = tr

    def request_admitted(self, rid: int, prefix_hit_tokens: int = 0) -> None:
        t = self.now()
        self.counter("serving_requests_admitted").add()
        tr = self._trace(rid)
        if tr is None:
            return
        if tr.t_admit is None:  # re-admission after preemption keeps the first
            tr.t_admit = t
            self.hist_queue.observe(t - tr.t_enqueue)
        tr.prefix_hit_tokens += prefix_hit_tokens
        if tr.events is not None:
            tr.events.append((t, "admit", {"prefix_hit_tokens": prefix_hit_tokens}))

    def request_event(self, rid: int, name: str, **args) -> None:
        """Trace-level timeline event (prefill_chunk, verify_round, rollback,
        cow, ...); a no-op at the metrics level."""
        if not self.tracing:
            return
        tr = self._trace(rid)
        if tr is not None and tr.events is not None:
            tr.events.append((self.now(), name, args))

    def first_token(self, rid: int) -> None:
        t = self.now()
        tr = self._trace(rid)
        if tr is None:
            return
        if tr.t_first_token is None:
            tr.t_first_token = tr.t_last_token = t
            self.hist_ttft.observe(t - tr.t_enqueue)
            if tr.events is not None:
                tr.events.append((t, "first_token", {}))
        tr.n_generated += 1

    def tokens_committed(self, rid: int, n: int) -> None:
        """n decode tokens committed for rid this step (n > 1 under
        speculation). ITL credits each token dt/n — the tokens became
        available simultaneously, so the per-token latency is the round
        time amortized over what it committed."""
        if n <= 0:
            return
        t = self.now()
        tr = self._trace(rid)
        if tr is None:
            return
        tr.n_generated += n
        if tr.t_last_token is not None:
            dt = (t - tr.t_last_token) / n
            for _ in range(n):
                self.hist_itl.observe(dt)
        tr.t_last_token = t

    def request_preempted(self, rid: int) -> None:
        self.counter("serving_preemptions").add()
        tr = self._trace(rid)
        if tr is None:
            return
        tr.preemptions += 1
        if tr.events is not None:
            tr.events.append((self.now(), "preempt", {}))

    def request_finished(self, rid: int, n_generated: int | None = None) -> None:
        t = self.now()
        self.counter("serving_requests_finished").add()
        tr = self._live.pop(rid, None)
        if tr is None:
            return
        tr.t_finish = t
        if n_generated is not None:  # authoritative count from the scheduler
            tr.n_generated = n_generated
        self.hist_e2e.observe(t - tr.t_enqueue)
        if tr.events is not None:
            tr.events.append((t, "finish", {"generated": tr.n_generated}))
        if self.tracing:
            self.completed.append(tr)

    # ------------------------------------------------------------ step records
    def step_record(self, *, host_s: float, device_s: float, cells: int,
                    budget: int, decode_rows: int = 0, verify_rows: int = 0,
                    prefill_rows: int = 0, blocks_allocated: int = 0,
                    blocks_freed: int = 0, blocks_copied: int = 0) -> None:
        """One packed step's accounting -> histograms + the bounded ring."""
        util = cells / budget if budget else 0.0
        self.hist_step_host.observe(host_s)
        self.hist_step_device.observe(device_s)
        self.hist_step_util.observe(util)
        self.step_times.record(host_s + device_s)
        self.steps.append({
            "t": self.now() - self._t0,
            "host_s": host_s, "device_s": device_s,
            "cells": cells, "budget": budget, "util": util,
            "decode_rows": decode_rows, "verify_rows": verify_rows,
            "prefill_rows": prefill_rows,
            "blocks_allocated": blocks_allocated,
            "blocks_freed": blocks_freed, "blocks_copied": blocks_copied,
        })

    # -------------------------------------------------------------- exporters
    def snapshot(self) -> dict:
        """JSON-able dump of every metric plus derived latency percentiles."""
        snap = self.registry.snapshot()
        snap["level"] = self.cfg.level
        snap["requests"] = {
            "live": len(self._live),
            "completed_traced": len(self.completed),
            "ttft_s": self.hist_ttft.summary(),
            "itl_s": self.hist_itl.summary(),
            "e2e_s": self.hist_e2e.summary(),
            "queue_wait_s": self.hist_queue.summary(),
        }
        snap["steps"] = {
            "recorded": len(self.steps),
            "step_time": self.step_times.summary(),
            "host_s": self.hist_step_host.summary(),
            "device_s": self.hist_step_device.summary(),
            "util": self.hist_step_util.summary(),
        }
        return snap

    def export_chrome_trace(self, path: str | pathlib.Path) -> pathlib.Path:
        """Write a Chrome/Perfetto trace-event JSON file and return its path.

        Lanes: pid 0 ("engine") carries packed-step slices (from the step
        ring) on tid 0 and named spans (draft scan/catch-up, trace level) on
        tid 1; pid 1 ("requests") gives every traced request its own tid with
        queued/prefill/decode phase slices and instant events; pid 2
        ("quality") renders the numerics counter tracks ("C" events — one
        track per metric, so quantization quality and latency share a
        timeline). Open the file at ui.perfetto.dev (or chrome://tracing)."""
        us = 1e6
        ev: list[dict] = [
            {"ph": "M", "pid": 0, "tid": 0, "name": "process_name",
             "args": {"name": "engine"}},
            {"ph": "M", "pid": 0, "tid": 0, "name": "thread_name",
             "args": {"name": "packed_steps"}},
            {"ph": "M", "pid": 0, "tid": 1, "name": "thread_name",
             "args": {"name": "spans"}},
            {"ph": "M", "pid": 1, "tid": 0, "name": "process_name",
             "args": {"name": "requests"}},
        ]
        if self.quality_series:
            ev.append({"ph": "M", "pid": 2, "tid": 0, "name": "process_name",
                       "args": {"name": "quality"}})
            for t, name, v in self.quality_series:
                ev.append({"ph": "C", "pid": 2, "tid": 0, "name": name,
                           "ts": (t - self._t0) * us, "args": {"value": v}})
        for s in self.steps:
            dur = (s["host_s"] + s["device_s"]) * us
            t1 = s["t"] * us  # records stamp completion time
            ev.append({"ph": "X", "pid": 0, "tid": 0, "name": "packed_step",
                       "ts": t1 - dur, "dur": dur,
                       "args": {k: v for k, v in s.items() if k != "t"}})
        for name, t_start, dur_s in self.spans:
            ev.append({"ph": "X", "pid": 0, "tid": 1, "name": name,
                       "ts": (t_start - self._t0) * us, "dur": dur_s * us})
        for tr in (*self.completed, *self._live.values()):
            tid = tr.rid
            ev.append({"ph": "M", "pid": 1, "tid": tid, "name": "thread_name",
                       "args": {"name": f"request {tr.rid}"}})

            def slice_(name, t0, t1, **args):
                if t0 is None or t1 is None or t1 < t0:
                    return
                ev.append({"ph": "X", "pid": 1, "tid": tid, "name": name,
                           "ts": (t0 - self._t0) * us,
                           "dur": (t1 - t0) * us, "args": args})

            slice_("queued", tr.t_enqueue, tr.t_admit,
                   prompt_tokens=tr.n_prompt)
            slice_("prefill", tr.t_admit, tr.t_first_token,
                   prefix_hit_tokens=tr.prefix_hit_tokens)
            slice_("decode", tr.t_first_token, tr.t_finish,
                   generated=tr.n_generated, preemptions=tr.preemptions)
            for t, name, args in tr.events or ():
                if name in ("enqueue", "admit", "first_token", "finish"):
                    continue  # already rendered as phase slices
                ev.append({"ph": "i", "pid": 1, "tid": tid, "name": name,
                           "ts": (t - self._t0) * us, "s": "t", "args": args})
        path = pathlib.Path(path)
        path.parent.mkdir(parents=True, exist_ok=True)
        path.write_text(json.dumps(
            {"traceEvents": ev, "displayTimeUnit": "ms",
             "otherData": {"level": self.cfg.level}}))
        return path

    def expfmt(self) -> str:
        """Prometheus text exposition of the registry (for external
        scrapers / file-based collection). Metric names are sanitized to the
        Prometheus charset (per-site gauges like ``numerics_sqnr_db.003.
        attn.q`` become ``numerics_sqnr_db_003_attn_q``); histograms emit
        the standard cumulative ``_bucket``/``_sum``/``_count`` triplet."""
        out: list[str] = []

        def emit(name, kind, help_, lines):
            if help_:
                out.append(f"# HELP {name} {help_}")
            out.append(f"# TYPE {name} {kind}")
            out.extend(lines)

        for k, c in sorted(self.registry.counters.items()):
            n = _promname(k)
            emit(n, "counter", c.help, [f"{n} {_promval(c.value)}"])
        for k, g in sorted(self.registry.gauges.items()):
            n = _promname(k)
            emit(n, "gauge", g.help, [f"{n} {_promval(g.value)}"])
        for k, h in sorted(self.registry.histograms.items()):
            n = _promname(k)
            lines, acc = [], 0
            for bound, cnt in zip(h.bounds, h.counts):
                acc += cnt
                lines.append(f'{n}_bucket{{le="{_promval(bound)}"}} {acc}')
            lines.append(f'{n}_bucket{{le="+Inf"}} {h.count}')
            lines.append(f"{n}_sum {_promval(h.sum)}")
            lines.append(f"{n}_count {h.count}")
            emit(n, "histogram", h.help, lines)
        return "\n".join(out) + ("\n" if out else "")


def _promname(name: str) -> str:
    return re.sub(r"[^a-zA-Z0-9_:]", "_", name)


def _promval(v) -> str:
    f = float(v)
    return repr(int(f)) if f == int(f) and abs(f) < 1e15 else repr(f)


class _Span:
    """Context manager pairing a jax TraceAnnotation with a span record."""

    __slots__ = ("tel", "name", "ann", "t0")

    def __init__(self, tel: Telemetry, name: str, ann):
        self.tel, self.name, self.ann = tel, name, ann

    def __enter__(self):
        self.ann.__enter__()
        self.t0 = self.tel.now()
        return self

    def __exit__(self, *exc):
        self.tel.spans.append((self.name, self.t0, self.tel.now() - self.t0))
        return self.ann.__exit__(*exc)


class NullTelemetry:
    """Level "off": every method is a no-op and every metric reads zero.
    No per-token allocation, no clock reads, no profiler annotations, and —
    because telemetry never wraps traced code anyway — a packed step built
    under NullTelemetry lowers to the identical jaxpr (tested)."""

    cfg = TelemetryConfig(level="off")
    enabled = False
    tracing = False
    quality = False
    fence = False
    steps: tuple = ()
    spans: tuple = ()
    completed: tuple = ()
    quality_series: tuple = ()

    def now(self) -> float:
        return 0.0

    def quality_counter(self, name, value):
        pass

    def expfmt(self) -> str:
        return ""

    def counter(self, name, help=""):
        return _NULL_METRIC

    def gauge(self, name, help="", fn=None):
        return _NULL_METRIC

    def histogram(self, name, bounds=None, help=""):
        return _NULL_METRIC

    def annotate(self, name):
        return contextlib.nullcontext()

    def reset(self):
        pass

    def request_submitted(self, rid, n_prompt):
        pass

    def request_admitted(self, rid, prefix_hit_tokens=0):
        pass

    def request_event(self, rid, name, **args):
        pass

    def first_token(self, rid):
        pass

    def tokens_committed(self, rid, n):
        pass

    def request_preempted(self, rid):
        pass

    def request_finished(self, rid, n_generated=None):
        pass

    def step_record(self, **kw):
        pass

    def snapshot(self) -> dict:
        return {"level": "off"}

    def export_chrome_trace(self, path):
        path = pathlib.Path(path)
        path.parent.mkdir(parents=True, exist_ok=True)
        path.write_text(json.dumps({"traceEvents": [],
                                    "otherData": {"level": "off"}}))
        return path


NULL_TELEMETRY = NullTelemetry()


def make_telemetry(cfg, clock=time.perf_counter):
    """``ServeConfig.telemetry`` -> a live :class:`Telemetry` or the shared
    :class:`NullTelemetry` null object (accepts a config, level string, bool,
    or None; see :meth:`TelemetryConfig.parse`)."""
    cfg = TelemetryConfig.parse(cfg)
    if cfg.level == "off":
        return NULL_TELEMETRY
    return Telemetry(cfg, clock=clock)
