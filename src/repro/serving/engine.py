"""Serving engine: the paper's end-to-end quantized inference path.

``make_serve_step``/``make_prefill_step`` build the pure functions the
multi-pod dry-run lowers (decode = one new token against a ring-buffer KV
cache of the shape-specified length). ``ServingEngine`` wraps them into a
batched request loop (greedy or temperature sampling, continuous slot reuse).

The quantization story end-to-end:
  weights    : K-Means W4 (QLinearParams tree)        — paper §III-A
  activations: K-Means A4/A3 per token + outliers     — paper §III-A/C
  KV cache   : optional K-Means int4 (beyond-paper)   — DESIGN.md §2
"""

from __future__ import annotations

import dataclasses
from typing import Callable

import jax
import jax.numpy as jnp

from repro.core.qlinear import QLinearConfig, use_apply_config
from repro.models.model import Model

__all__ = ["ServeConfig", "make_prefill_step", "make_serve_step", "ServingEngine"]


@dataclasses.dataclass(frozen=True)
class ServeConfig:
    cache_len: int = 4096
    cache_dtype: str = "bfloat16"
    kv_quant: bool = False
    temperature: float = 0.0  # 0 => greedy
    qconfig: QLinearConfig = QLinearConfig()
    quantized: bool = True  # serve QLinearParams (False = fp baseline)


def make_prefill_step(model: Model, sc: ServeConfig) -> Callable:
    """prefill(params, caches, batch) -> (first_token (B,), caches, logits)."""

    def prefill(params, caches, batch: dict):
        s = batch["tokens"].shape[1]
        positions = jnp.arange(s, dtype=jnp.int32)
        with use_apply_config(sc.qconfig):
            out = model.apply(params, batch, positions=positions, caches=caches,
                              last_only=True)
        next_tok = jnp.argmax(out.logits[:, -1, : model.cfg.vocab_size], axis=-1)
        return next_tok.astype(jnp.int32), out.caches, out.logits[:, -1]

    return prefill


def make_serve_step(model: Model, sc: ServeConfig) -> Callable:
    """serve_step(params, caches, tokens (B,1), pos ()) -> (next (B,), caches).

    This is the function the decode_32k / long_500k dry-run cells lower:
    one token in, KV cache of the assigned context length, one token out.
    """

    def serve_step(params, caches, tokens: jax.Array, pos: jax.Array):
        positions = pos[None].astype(jnp.int32)
        batch = {"tokens": tokens}
        if model.cfg.family == "vlm":
            batch["image_embeds"] = jnp.zeros(
                (tokens.shape[0], model.cfg.n_img_tokens, model.cfg.d_model),
                jnp.dtype(model.cfg.compute_dtype),
            )
        with use_apply_config(sc.qconfig):
            out = model.apply(params, batch, positions=positions, caches=caches)
        logits = out.logits[:, -1, : model.cfg.vocab_size]
        next_tok = jnp.argmax(logits, axis=-1)
        return next_tok.astype(jnp.int32), out.caches

    return serve_step


class ServingEngine:
    """Batched generation over fixed request slots.

    Requests are token prompts; the engine right-pads the batch to the slot
    count, prefill fills the caches, then greedy/temperature decode runs to
    ``max_new_tokens`` (per-request EOS masking). This is the "serve a small
    model with batched requests" driver used by examples/serve_quantized.py.
    """

    def __init__(self, model: Model, params, sc: ServeConfig, batch_slots: int = 8):
        self.model, self.sc, self.slots = model, sc, batch_slots
        self.params = params
        self._prefill = jax.jit(make_prefill_step(model, sc))
        self._step = jax.jit(make_serve_step(model, sc))

    def generate(
        self, prompts: list[list[int]], max_new_tokens: int = 32, eos_id: int | None = None,
        seed: int = 0,
    ) -> list[list[int]]:
        if len(prompts) > self.slots:
            # simple continuous batching: chunk requests through the slots
            out: list[list[int]] = []
            for i in range(0, len(prompts), self.slots):
                out += self.generate(prompts[i : i + self.slots], max_new_tokens, eos_id, seed)
            return out

        b = len(prompts)
        plen = max(len(p) for p in prompts)
        toks = jnp.array(
            [[0] * (plen - len(p)) + list(p) for p in prompts], dtype=jnp.int32
        )  # left-pad so all prompts end at the same position
        caches = self.model.init_caches(
            b, self.sc.cache_len, jnp.dtype(self.sc.cache_dtype), quantized=self.sc.kv_quant
        )
        tok, caches, logits = self._prefill(self.params, caches, {"tokens": toks,
            **self._img(b)})
        key = jax.random.PRNGKey(seed)
        done = jnp.zeros((b,), bool)
        outs = [tok]
        pos = plen
        for _ in range(max_new_tokens - 1):
            if self.sc.temperature > 0:
                key, sub = jax.random.split(key)
                tok = jax.random.categorical(sub, logits / self.sc.temperature, axis=-1)
            tok, caches = self._step(self.params, caches, tok[:, None], jnp.int32(pos))
            if eos_id is not None:
                done = done | (tok == eos_id)
                tok = jnp.where(done, eos_id, tok)
            outs.append(tok)
            pos += 1
            if eos_id is not None and bool(done.all()):
                break
        gen = jnp.stack(outs, axis=1)
        return [list(map(int, row)) for row in gen]

    def _img(self, b: int) -> dict:
        if self.model.cfg.family != "vlm":
            return {}
        return {
            "image_embeds": jnp.zeros(
                (b, self.model.cfg.n_img_tokens, self.model.cfg.d_model),
                jnp.dtype(self.model.cfg.compute_dtype),
            )
        }
