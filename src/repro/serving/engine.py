"""Serving engine: the paper's end-to-end quantized inference path.

``make_serve_step``/``make_prefill_step`` build the pure functions the
multi-pod dry-run lowers (decode = one new token against a ring-buffer KV
cache of the shape-specified length). ``ServingEngine`` wraps generation:

* families exporting per-layer **cache policies** (``model.cache_policies()``
  is not None: dense/audio/moe full attention -> ``paged_kv``, SWA archs ->
  ``windowed_paged``, ssm/hybrid -> ``recurrent`` state layers) serve through
  the **paged continuous-batching scheduler** (serving/scheduler.py) — a
  global K-Means-quantizable block pool, per-request block tables, ONE
  packed token-budget step per iteration mixing prefill and decode tokens,
  per-step slot refill, preemption-by-eviction, and refcounted
  **prefix-sharing** of content-hashed blocks with copy-on-write
  (``ServeConfig.prefix_cache``; auto-disabled unless every layer is
  ``paged_kv``). Overflow beyond ``batch_slots`` queues; it is NOT
  recursively chunked.
* families without policies (vlm/multimodal) fall back to the fixed-slot
  ring-buffer batcher, iterating slot-sized batches; left-pad tokens are
  masked out of attention via a per-row ``pad_len`` on the ring caches.

The quantization story end-to-end:
  weights    : K-Means W4/W8 per QuantSpec rule (QLinearParams tree, each
               carrying its resolved QLinearConfig)   — paper §III-A
  activations: K-Means A4/A3 per token + outliers     — paper §III-A/C
  KV cache   : optional K-Means int4 (beyond-paper)   — DESIGN.md §2,
               ring buffer AND paged block pool (serving/README.md)

Apply-time quantization behaviour lives INSIDE the params (see
repro.core.quantspec): the engine no longer carries a quantization config —
build it from a spec's KV policy with ``ServeConfig.from_spec(spec, ...)``.
"""

from __future__ import annotations

import dataclasses
from typing import Callable

import jax
import jax.numpy as jnp

from repro.core.quantspec import QuantSpec
from repro.models.model import Model
from repro.serving.speculative import SpeculativeConfig

__all__ = ["ServeConfig", "make_prefill_step", "make_serve_step", "ServingEngine"]


@dataclasses.dataclass(frozen=True)
class ServeConfig:
    cache_len: int = 4096  # max context per request (prompt + generated)
    cache_dtype: str = "bfloat16"
    kv_quant: bool = False
    temperature: float = 0.0  # 0 => greedy
    quantized: bool = True  # serve QLinearParams (False = fp baseline)
    # paged continuous-batching scheduler (attention-cache families)
    paged: bool = True  # False forces the fixed-slot ring-buffer path
    block_size: int = 16  # tokens per KV block
    n_blocks: int = 0  # pool size per layer; 0 -> slots * ceil(cache_len/block_size)
    prefill_chunk: int = 32  # prefill share of the default token budget
    token_budget: int = 0  # packed-step rows; 0 -> slots + prefill_chunk
    # prefix sharing: refcounted content-hashed blocks — admissions alias a
    # prompt's longest cached full-block prefix (prefill skipped for those
    # tokens) with copy-on-write on shared partial blocks; token-identical
    # to prefix_cache=False on greedy decode (serving/README.md)
    prefix_cache: bool = True
    # tokens packed per kernel segment row in the packed step (one
    # block-table gather per ROW, not per token); 1 = the flat layout, which
    # is also what keeps speculative greedy bit-identical to non-speculative
    # greedy (same forward shapes)
    seg_width: int = 1
    # speculative decoding: draft k tokens with a low-bit draft model, verify
    # k+1 positions per packed step (serving/speculative.py). None = off.
    # Token-identical to non-speculative greedy; greedy-only (temperature
    # configs raise until the rejection-sampling hook is implemented).
    speculative: SpeculativeConfig | None = None
    # telemetry (serving/telemetry.py): "metrics" (default — counters, gauges,
    # SLO histograms, step ring), "trace" (adds per-request timelines + spans
    # for the Perfetto export), "quality" (trace + the quantization-numerics
    # probes of core/numerics — sampled probed packed steps, drift alarms,
    # shadow-reference quality checks; the only level allowed to recompile),
    # "off" (null object: zero per-token work and an untouched packed-step
    # jaxpr), or a TelemetryConfig for fence/ring/sampling knobs
    telemetry: object = "metrics"

    @classmethod
    def from_spec(cls, spec: QuantSpec, **kw) -> "ServeConfig":
        """Serving config whose KV-cache treatment follows the spec's
        first-class kv policy (kv_bits -> int4 pool, kv_dtype -> fp pool)."""
        kw.setdefault("kv_quant", spec.kv_bits is not None)
        kw.setdefault("cache_dtype", spec.kv_dtype)
        return cls(**kw)


def make_prefill_step(model: Model, sc: ServeConfig) -> Callable:
    """prefill(params, caches, batch) -> (first_token (B,), caches, logits)."""

    def prefill(params, caches, batch: dict):
        s = batch["tokens"].shape[1]
        positions = jnp.arange(s, dtype=jnp.int32)
        out = model.apply(params, batch, positions=positions, caches=caches,
                          last_only=True)
        next_tok = jnp.argmax(out.logits[:, -1, : model.cfg.vocab_size], axis=-1)
        return next_tok.astype(jnp.int32), out.caches, out.logits[:, -1]

    return prefill


def make_serve_step(model: Model, sc: ServeConfig) -> Callable:
    """serve_step(params, caches, tokens (B,1), pos ()) -> (next (B,), caches, logits).

    This is the function the decode_32k / long_500k dry-run cells lower:
    one token in, KV cache of the assigned context length, one token out.
    ``logits`` (B, vocab) are this step's outputs, so temperature sampling
    draws from the CURRENT distribution (not stale prefill logits).
    """

    def serve_step(params, caches, tokens: jax.Array, pos: jax.Array):
        positions = pos[None].astype(jnp.int32)
        batch = {"tokens": tokens}
        if model.cfg.family == "vlm":
            batch["image_embeds"] = jnp.zeros(
                (tokens.shape[0], model.cfg.n_img_tokens, model.cfg.d_model),
                jnp.dtype(model.cfg.compute_dtype),
            )
        out = model.apply(params, batch, positions=positions, caches=caches)
        logits = out.logits[:, -1, : model.cfg.vocab_size]
        next_tok = jnp.argmax(logits, axis=-1)
        return next_tok.astype(jnp.int32), out.caches, logits

    return serve_step


def _attach_pad_lens(caches, pad_lens: jax.Array):
    """Insert a per-row ``pad_len`` into every ring attention-cache dict.

    A cache dict is recognized by its ``slot_pos`` leaf; stacked caches
    (leading scan axes) get the (B,) vector broadcast per layer. SSM/RG-LRU
    state dicts carry no ``slot_pos`` and pass through untouched (left-pad
    pollution of recurrent state is inherent to the fixed-slot batcher).
    """
    if isinstance(caches, dict):
        if "slot_pos" in caches:
            lead = caches["slot_pos"].shape[:-1]  # (), (L,) or (G, n_self)
            return caches | {
                "pad_len": jnp.broadcast_to(pad_lens, (*lead, pad_lens.shape[0]))
            }
        return {k: _attach_pad_lens(v, pad_lens) for k, v in caches.items()}
    if isinstance(caches, list):
        return [_attach_pad_lens(c, pad_lens) for c in caches]
    return caches


class ServingEngine:
    """Batched generation over ``batch_slots`` request slots.

    Paged-capable models get true continuous batching (see module docstring);
    the rest get the ring-buffer batcher with iterative (non-recursive)
    slot-sized chunking. Both paths sample each step from that step's logits.
    """

    def __init__(self, model: Model, params, sc: ServeConfig, batch_slots: int = 8,
                 draft=None, calib_stats=None, shadow_params=None):
        """``draft`` (speculative configs): a prepared draft model —
        ``(model, params)``, ``(model, params, spec)``, or the
        :class:`~repro.core.artifact.QuantizedArtifact` tuple. When omitted,
        ``sc.speculative.draft_artifact`` is loaded from disk (the
        production path: quantize the draft once, serve it everywhere).

        ``calib_stats`` / ``shadow_params`` feed the quality-observability
        layer (``telemetry="quality"``; see Scheduler): per-tap calibration
        activation stats (``core.artifact.load_calib_stats``) for drift
        scoring, and the shadow-reference parameter tree (None = serve
        params, the self-referencing probe)."""
        from repro.serving.telemetry import make_telemetry

        self.model, self.sc, self.slots = model, sc, batch_slots
        self.params = params
        self.telemetry = make_telemetry(sc.telemetry)
        policies = model.cache_policies()
        self.paged = sc.paged and policies is not None
        if self.paged and sc.speculative is not None \
                and any(p.kind == "recurrent" for p in policies):
            # recurrent layers need each verify segment (k+1 cells) in ONE
            # grid row; widen seg_width for the user instead of raising
            min_w = sc.speculative.k + 1
            if sc.seg_width < min_w:
                sc = dataclasses.replace(sc, seg_width=min_w)
                self.sc = sc
        if self.paged:
            from repro.serving.scheduler import Scheduler

            if sc.speculative is not None and draft is None:
                if sc.speculative.draft_artifact is None:
                    raise ValueError(
                        "ServeConfig.speculative needs a draft model: set "
                        "speculative.draft_artifact or pass draft=(model, "
                        "params[, spec]) to the engine"
                    )
                from repro.serving.speculative import load_draft

                draft = load_draft(sc.speculative.draft_artifact)
            self.scheduler = Scheduler(model, params, sc, slots=batch_slots,
                                       draft=draft, telemetry=self.telemetry,
                                       calib_stats=calib_stats,
                                       shadow_params=shadow_params)
        else:
            if sc.speculative is not None:
                raise ValueError(
                    "speculative decoding needs the paged scheduler "
                    "(paged=True and a paged-capable model family)"
                )
            self.scheduler = None
            self._prefill = jax.jit(make_prefill_step(model, sc))
            self._step = jax.jit(make_serve_step(model, sc))
            # fallback counters through the same registry as the paged path
            tel = self.telemetry
            self._fc = {k: tel.counter(f"serving_fallback_{k}") for k in (
                "prefills", "steps", "tokens", "prompt_tokens", "pad_tokens")}

    @property
    def stats(self) -> dict:
        """Serving counters. Paged path: the scheduler's dict (packed-step /
        preemption accounting plus prefix-cache hits, tokens of prefill
        skipped, copy-on-write copies, and cached-prefix evictions; under a
        speculative config also the draft forwards run and the acceptance
        rate — accepted / drafted tokens). The fixed-slot fallback reports
        its own batch counters (prefills, decode steps, tokens served, and
        the pad-row fraction of prefill cells) from the same registry."""
        if self.scheduler is None:
            d = {k: c.value for k, c in self._fc.items()}
            d["pad_fraction"] = d["pad_tokens"] / max(1, d["prompt_tokens"])
            return d
        d = dict(self.scheduler.stats,
                 prefix_evictions=self.scheduler.allocator.evictions,
                 prefix_blocks_cached=self.scheduler.allocator.n_cached)
        if self.scheduler.draft is not None:
            d["draft_steps"] = self.scheduler.draft.steps
            d["acceptance_rate"] = (d["accepted_tokens"]
                                    / max(1, d["drafted_tokens"]))
        return d

    def snapshot(self) -> dict:
        """JSON-able dump of every telemetry metric (see Telemetry.snapshot)."""
        return self.telemetry.snapshot()

    def export_chrome_trace(self, path):
        """Write a Chrome/Perfetto trace-event JSON file; open at
        ui.perfetto.dev. Richest under ``telemetry="trace"``."""
        return self.telemetry.export_chrome_trace(path)

    def generate(
        self, prompts: list[list[int]], max_new_tokens: int | list[int] = 32,
        eos_id: int | None = None, seed: int = 0,
    ) -> list[list[int]]:
        """Generate for every prompt; returns per-prompt token lists of
        exactly its max_new_tokens (eos-padded after early stop).
        ``max_new_tokens`` may be per-request (paged scheduler path only)."""
        budgets = (max_new_tokens if isinstance(max_new_tokens, list)
                   else [max_new_tokens] * len(prompts))
        if len(budgets) != len(prompts):
            raise ValueError("per-request max_new_tokens must match prompts")
        if self.paged:
            rids = [self.scheduler.submit(p, n, eos_id, seed, salt=i)
                    for i, (p, n) in enumerate(zip(prompts, budgets))]
            results = self.scheduler.run()
            return [results[r] for r in rids]
        if isinstance(max_new_tokens, list):
            raise ValueError("per-request budgets need the paged scheduler")
        out: list[list[int]] = []
        for i in range(0, len(prompts), self.slots):  # iterative, not recursive
            out += self._generate_batch(prompts[i : i + self.slots],
                                        max_new_tokens, eos_id, seed)
        return out

    def _generate_batch(
        self, prompts: list[list[int]], max_new_tokens: int, eos_id: int | None,
        seed: int,
    ) -> list[list[int]]:
        b = len(prompts)
        plen = max(len(p) for p in prompts)
        toks = jnp.array(
            [[0] * (plen - len(p)) + list(p) for p in prompts], dtype=jnp.int32
        )  # left-pad so all prompts end at the same position
        caches = self.model.init_caches(
            b, self.sc.cache_len, jnp.dtype(self.sc.cache_dtype), quantized=self.sc.kv_quant
        )
        # pad tokens land in the KV cache at positions [0, pad_len) — attach
        # the per-row pad length so attention masks them (they used to be
        # attended as real context, skewing short prompts in mixed batches)
        pads = jnp.array([plen - len(p) for p in prompts], jnp.int32)
        caches = _attach_pad_lens(caches, pads)
        self._fc["prefills"].add()
        self._fc["prompt_tokens"].add(b * plen)
        self._fc["pad_tokens"].add(sum(plen - len(p) for p in prompts))
        with self.telemetry.annotate("fallback_prefill"):
            tok, caches, logits = self._prefill(self.params, caches,
                                                {"tokens": toks, **self._img(b)})
        key = jax.random.PRNGKey(seed)
        done = jnp.zeros((b,), bool)
        if self.sc.temperature > 0:
            key, sub = jax.random.split(key)
            tok = jax.random.categorical(
                sub, logits[:, : self.model.cfg.vocab_size] / self.sc.temperature, axis=-1
            ).astype(jnp.int32)
        outs = [tok]
        pos = plen
        for _ in range(max_new_tokens - 1):
            with self.telemetry.annotate("fallback_step"):
                tok, caches, logits = self._step(self.params, caches,
                                                 tok[:, None], jnp.int32(pos))
            self._fc["steps"].add()
            if self.sc.temperature > 0:
                key, sub = jax.random.split(key)
                tok = jax.random.categorical(
                    sub, logits / self.sc.temperature, axis=-1
                ).astype(jnp.int32)
            if eos_id is not None:
                done = done | (tok == eos_id)
                tok = jnp.where(done, eos_id, tok)
            outs.append(tok)
            pos += 1
            if eos_id is not None and bool(done.all()):
                break
        gen = jnp.stack(outs, axis=1)
        self._fc["tokens"].add(b * len(outs))
        rows = [list(map(int, row)) for row in gen]
        pad = eos_id if eos_id is not None else 0
        return [row + [pad] * (max_new_tokens - len(row)) for row in rows]

    def _img(self, b: int) -> dict:
        if self.model.cfg.family != "vlm":
            return {}
        return {
            "image_embeds": jnp.zeros(
                (b, self.model.cfg.n_img_tokens, self.model.cfg.d_model),
                jnp.dtype(self.model.cfg.compute_dtype),
            )
        }
