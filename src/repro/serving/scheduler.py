"""Continuous-batching scheduler over the paged K-Means KV cache.

Request lifecycle::

    QUEUED --admit (FCFS, free-block budget)--> RUNNING (prefilling)
    RUNNING --prompt fully written--> RUNNING (decoding)
    RUNNING --EOS / max-tokens--> FINISHED      (slot + blocks freed,
    RUNNING --pool exhausted--> PREEMPTED        refilled next step)
    PREEMPTED --requeued at the front--> QUEUED  (recompute on re-admission)

The hot loop is ONE jitted *packed* step of fixed shape: a grid of
``rows x seg_width`` token cells (``token_budget = rows * seg_width``). Every
scheduler iteration fills the grid with **segments** — each grid row is a
contiguous run of ONE request's tokens, carrying that request's slot id and
per-cell absolute positions (-1 = padded cell). Decoding requests get their
row reserved FIRST (admissions can never starve decode), prefill segments
fill the remaining rows FCFS (``seg_width`` tokens per row, so a chunk of
``n`` prompt tokens costs ``ceil(n / seg_width)`` block-table gathers instead
of ``n`` — the kernel attends a whole query segment per row). Each cell
writes its token's KV into the row's slot blocks and attends causally through
that slot's block table; rows and cells of the same request are causally
ordered by position within the same forward (write-then-attend).

**Speculative decoding** (``ServeConfig.speculative``): each decoding
request's reservation becomes a *verify segment* ``[next_token, d_1 .. d_k]``
— ``k`` greedy tokens proposed by a low-bit draft model
(``serving/speculative.py``: one scanned draft dispatch + private per-slot
paged pool) before the target step. The verify segment occupies
``ceil((k+1)/seg_width)`` consecutive grid rows — at the default
``seg_width=1`` that is k+1 flat rows, the SAME forward shape as
non-speculative serving, so per-row results are bit-identical and greedy
verification commits exactly the tokens plain greedy decoding would have
produced (the target's per-position argmaxes, applied via
``greedy_verify``). Rejected positions **roll back**:
their cache rows sit above the request's new context horizon (never attended,
rewritten by the next round's writes), and blocks holding only rejected
tokens are freed (``BlockAllocator.truncate`` — a shared tail block is only
decref'd). The draft's own state rewinds via a host-side counter.

**Prefix sharing** (``ServeConfig.prefix_cache``): as prefill fills a block
completely, the scheduler registers it with the allocator under the chain
hash of (pool identity, every token up to the block's end). Admission then
matches an incoming prompt's longest cached full-block prefix, increfs and
aliases those physical blocks into the new request's table, and sets
``prefilled`` past the shared tokens — their prefill compute is skipped
entirely; only the tail gets fresh blocks. Writes into a block whose
refcount exceeds 1 (aliased-last-block, or a verify segment reaching into a
shared block) are **copy-on-write**: the block's pool rows are copied
device-side into a fresh block and the table entry swapped before the packed
step, so ``attention_apply`` and the Pallas kernel never see sharing.
Deterministic K-Means assignment makes shared KV bit-identical to recomputed
KV, so sharing never changes sampled tokens.

Preemption is by eviction: when a decoding sequence cannot get a block, the
most recently admitted *other* request is evicted (blocks decref'd, requeued
front, prefill progress reset) and recomputed later — a decref only recycles
a block nobody else holds, and a re-admitted victim usually re-matches its
own just-registered prefix blocks, making recovery cheap. Cached refcount-0
prefix blocks are reclaimed (LRU) by the allocator before any preemption.

Sampling happens host-side from the logits the packed step returns (greedy
or per-request-keyed temperature): a decoding request samples from its
row's cells; a request whose LAST prompt token was written this step samples
its first token from that cell — per-request keys make sampled outputs
independent of how steps were packed. Speculative configs are greedy-only
(the rejection-sampling hook in speculative.py documents the temperature
contract and raises until implemented).
"""

from __future__ import annotations

import dataclasses
import enum
from collections import deque

import jax
import jax.numpy as jnp
import numpy as np

from repro.serving.paged_cache import (
    BlockAllocator,
    PagedCacheConfig,
    blocks_needed,
    chain_hash,
    copy_blocks,
    prefix_seed,
    release_horizon,
    zero_state_slot,
)
from repro.serving.speculative import (
    DraftRunner,
    greedy_verify,
    make_packed_fn,
    make_probed_packed_fn,
    rejection_sample,
)
from repro.serving.telemetry import linear_buckets, log_buckets, make_telemetry

__all__ = ["RequestState", "Request", "Scheduler"]


def _softmax(x: np.ndarray) -> np.ndarray:
    x = x.astype(np.float64) - x.max()
    e = np.exp(x)
    return e / e.sum()


class RequestState(enum.Enum):
    QUEUED = "queued"
    RUNNING = "running"
    PREEMPTED = "preempted"
    FINISHED = "finished"


@dataclasses.dataclass
class Request:
    rid: int
    prompt: list[int]
    max_new_tokens: int
    eos_id: int | None
    key: jax.Array  # per-request sampling key (temperature > 0)
    state: RequestState = RequestState.QUEUED
    context: list[int] = dataclasses.field(default_factory=list)  # tokens fed
    generated: list[int] = dataclasses.field(default_factory=list)
    prefilled: int = 0  # context tokens written to the cache so far
    next_token: int | None = None  # sampled, not yet fed to the model
    blocks: list[int] = dataclasses.field(default_factory=list)
    block_hashes: list[bytes] = dataclasses.field(default_factory=list)  # chain
    slot: int = -1

    @property
    def decoding(self) -> bool:
        """Context fully written: the next packed step feeds next_token."""
        return self.prefilled >= len(self.context)

    @property
    def done(self) -> bool:
        if len(self.generated) >= self.max_new_tokens:
            return True
        return self.eos_id is not None and bool(self.generated) and \
            self.generated[-1] == self.eos_id

    def output(self) -> list[int]:
        """Exactly max_new_tokens tokens (eos-padded after early stop)."""
        out = list(self.generated[: self.max_new_tokens])
        pad = self.eos_id if self.eos_id is not None else 0
        return out + [pad] * (self.max_new_tokens - len(out))


class Scheduler:
    """Owns the block pool, the allocator, and the single jitted packed step.

    ``sc`` is a :class:`repro.serving.engine.ServeConfig`; its ``cache_len``
    bounds per-request context (prompt + generated), ``block_size`` /
    ``n_blocks`` size the pool (n_blocks=0 -> slots * blocks-per-request, a
    no-preemption default; pass a smaller pool to exercise preemption),
    ``token_budget`` sizes the packed grid (0 -> slots + prefill_chunk,
    rounded up to ``rows * seg_width`` cells with room for every slot's
    decode/verify segment), ``seg_width`` packs that many tokens per kernel
    segment row (default 1, the flat layout — under a speculative config a
    verify segment then spans k+1 flat rows, keeping forward shapes
    bit-identical to non-speculative serving), and ``prefix_cache`` enables
    refcounted prefix-block sharing.

    ``draft`` (speculative configs): ``(model, params)`` or
    ``(model, params, spec)`` — e.g. a ``load_quantized`` artifact tuple.

    Quality observability (``telemetry="quality"``): ``calib_stats`` is the
    per-tap calibration-time activation-stats dict persisted by
    ``core/artifact.save_quantized`` (``load_calib_stats``) — live probe
    stats drift-score against it (absent stats, the first probed step seeds
    a self-baseline); ``shadow_params`` is the reference parameter tree for
    the shadow quality probe (dense fp or a higher-precision spec) — None
    uses the serving params themselves (the self-referencing spec: KL ~ 0,
    agreement == 1 gates the probe machinery itself).
    """

    def __init__(self, model, params, sc, slots: int = 8, draft=None,
                 telemetry=None, calib_stats=None, shadow_params=None):
        policies = model.cache_policies()
        if policies is None:
            raise ValueError(
                f"family {model.cfg.family} exports no cache policies "
                "(cannot use the paged scheduler)"
            )
        self.policies = policies
        # per-policy resource model: paged layers cost blocks, recurrent
        # layers cost zero blocks but pin their slot's state; windowed layers
        # additionally allow freeing out-of-window blocks (release_horizon
        # is 0 whenever any full-attention layer still needs every block)
        self._has_paged = any(
            p.kind in ("paged_kv", "windowed_paged") for p in policies)
        self._rec = any(p.kind == "recurrent" for p in policies)
        self.release_window = release_horizon(policies)
        self.model, self.params, self.sc, self.slots = model, params, sc, slots
        self.telemetry = telemetry if telemetry is not None \
            else make_telemetry(getattr(sc, "telemetry", "metrics"))
        self.spec = sc.speculative
        self.draft: DraftRunner | None = None
        if self.spec is not None:
            if sc.temperature > 0:
                rejection_sample()  # greedy-only: raises NotImplementedError
            if draft is None:
                raise ValueError(
                    "speculative serving needs a draft model: pass "
                    "draft=(model, params[, spec]) or set "
                    "speculative.draft_artifact on the engine"
                )
            dm, dp, dspec = (tuple(draft) + (None,))[:3]
            if dm.cfg.vocab_size != model.cfg.vocab_size:
                raise ValueError(
                    f"draft vocab {dm.cfg.vocab_size} != target vocab "
                    f"{model.cfg.vocab_size}: verification compares argmaxes"
                )
            self.draft = DraftRunner(
                dm, dp, slots=slots, cache_len=sc.cache_len, k=self.spec.k,
                block_size=sc.block_size,
                cache_dtype=jnp.dtype(dspec.kv_dtype if dspec else sc.cache_dtype),
                kv_quant=(dspec.kv_bits is not None) if dspec else sc.kv_quant,
                token_budget=self.spec.draft_token_budget,
                telemetry=self.telemetry,
            )
        # grid geometry: rows x seg_width cells. Decode reservation needs
        # every slot's verify segment (k+1 tokens under speculation, 1
        # otherwise) to fit simultaneously. seg_width only changes how many
        # cells share one kernel segment row (one block-table gather per
        # row); it never changes which tokens run — a seg_width=1 grid with
        # the same cell budget is bit-identical, which is what keeps
        # speculative greedy exactly equal to non-speculative greedy.
        self.seg_width = max(1, sc.seg_width)
        seg_len = (self.spec.k + 1) if self.spec else 1
        self._dec_rows = -(-seg_len // self.seg_width)  # rows per decode seg
        base = sc.token_budget or (slots + sc.prefill_chunk)
        rows = -(-base // self.seg_width)
        if sc.token_budget == 0:
            rows = max(rows, slots * self._dec_rows)
        if rows < slots * self._dec_rows:
            raise ValueError(
                f"token_budget {base} gives {rows} segment rows of width "
                f"{self.seg_width} but decode reservation needs "
                f"{slots * self._dec_rows} (slots x ceil((k+1)/seg_width))"
            )
        if self._rec and self._dec_rows > 1:
            raise ValueError(
                "recurrent layers gather/scatter state by slot, so a verify "
                f"segment must fit in ONE grid row: raise seg_width to >= "
                f"k+1 = {seg_len} (got {self.seg_width})"
            )
        self.rows = rows
        self.token_budget = rows * self.seg_width
        max_blk = blocks_needed(sc.cache_len, sc.block_size)
        n_blocks = sc.n_blocks or slots * max_blk
        self.pcfg = PagedCacheConfig(block_size=sc.block_size, n_blocks=n_blocks,
                                     max_blocks_per_seq=max_blk)
        self.pools = model.init_caches(
            slots, sc.cache_len, jnp.dtype(sc.cache_dtype), quantized=sc.kv_quant,
            layout="paged", block_size=sc.block_size, n_blocks=n_blocks,
        )
        # prefix sharing aliases physical blocks across requests, which only
        # composes with layers that keep every block forever: windowed layers
        # free out-of-window blocks (an alias would free a shared block) and
        # recurrent layers have no blocks to share
        prefix_on = sc.prefix_cache and bool(policies) and \
            all(p.kind == "paged_kv" for p in policies)
        self.allocator = BlockAllocator(n_blocks, prefix_cache=prefix_on,
                                        telemetry=self.telemetry)
        # chain-hash root: blocks are only shareable within one (layer-set,
        # quant-policy, geometry) identity — a pool restarted with a different
        # KV treatment can never alias stale hashes
        self._hash_seed = prefix_seed(
            family=model.cfg.family, n_layers=model.cfg.n_layers,
            n_kv_heads=model.cfg.n_kv_heads, head_dim=model.cfg.head_dim,
            kv_quant=sc.kv_quant, cache_dtype=str(sc.cache_dtype),
            block_size=sc.block_size,
        )
        self._queue: deque[Request] = deque()
        self._running: list[Request] = []
        self._slot_free = list(range(slots - 1, -1, -1))
        self._next_rid = 0
        # serving counters live in the telemetry registry (Scheduler.stats
        # rebuilds the legacy dict from them); cached as attributes so the
        # hot loop pays one method call, and all of them no-op at level=off
        tel = self.telemetry
        self._c = {k: tel.counter(f"serving_{k}") for k in (
            "packed_steps", "decode_steps", "prefill_chunks", "mixed_steps",
            "decode_slot_tokens", "prefill_tokens", "packed_tokens",
            "prefix_hits", "prefix_hit_tokens", "prefill_skipped",
            "cow_copies", "spec_rounds", "drafted_tokens", "accepted_tokens",
            "rolled_back_tokens")}
        self._c["preemptions"] = tel.counter("serving_preemptions")
        self._g_peak = tel.gauge("serving_pool_occupancy_peak",
                                 "high-water live-block fraction")
        tel.gauge("serving_queue_depth", fn=lambda: len(self._queue))
        tel.gauge("serving_running_requests", fn=lambda: len(self._running))
        # LUT-GEMM route dispatch (core/kernel_routing): trace-time counts of
        # which GEMM path each projection compiled into — pallas fused kernel
        # vs jnp factorized vs explicit fallback. Lazy gauges over the
        # process-global registry, so "which GEMM path actually ran" is
        # answerable from any telemetry snapshot.
        from repro.core import kernel_routing as _kr

        self._g_lut = {
            "lut_kernel_calls": tel.gauge(
                "serving_lut_kernel_calls", fn=_kr.kernel_calls,
                help="projections routed to the fused Pallas LUT-GEMM"),
            "lut_jnp_calls": tel.gauge(
                "serving_lut_jnp_calls", fn=_kr.jnp_calls,
                help="projections routed to the jnp factorized LUT-GEMM"),
            "lut_fallbacks": tel.gauge(
                "serving_lut_fallbacks", fn=_kr.fallback_count,
                help="explicit pallas->jnp tier fallbacks"),
        }
        # Orizuru outlier-engine dispatch: which detection path each dual-
        # branch projection compiled into, plus the compensation route
        # (gather vs scatter) its comp_mode resolved to. Same lazy-gauge
        # pattern as the LUT-GEMM counters above.
        self._g_outlier = {
            "outlier_detect_calls": tel.gauge(
                "serving_outlier_detect_calls", fn=_kr.detect_calls,
                help="outlier-branch detection resolutions (any route)"),
            "outlier_kernel_calls": tel.gauge(
                "serving_outlier_kernel_calls", fn=_kr.detect_kernel_calls,
                help="detections routed to the Pallas Orizuru kernel"),
            "outlier_jnp_calls": tel.gauge(
                "serving_outlier_jnp_calls", fn=_kr.detect_jnp_calls,
                help="detections routed to lax.top_k / threshold scoring"),
            "outlier_fallbacks": tel.gauge(
                "serving_outlier_fallbacks", fn=_kr.detect_fallback_count,
                help="explicit detection pallas->jnp demotions"),
            "outlier_comp_gather": tel.gauge(
                "serving_outlier_comp_gather",
                fn=lambda: _kr.comp_route_counts().get("gather", 0),
                help="compensations resolved to the row-gather route"),
            "outlier_comp_scatter": tel.gauge(
                "serving_outlier_comp_scatter",
                fn=lambda: _kr.comp_route_counts().get("scatter", 0),
                help="compensations resolved to the scatter+dense route"),
        }
        self._h_accept = tel.histogram(
            "serving_spec_accepted_per_round",
            linear_buckets(0.0, float(self.spec.k + 1) if self.spec else 1.0,
                           (self.spec.k + 1) if self.spec else 1),
            "accepted draft tokens per verify round")
        self._h_draft_round = tel.histogram(
            "serving_draft_round_s", log_buckets(1e-6, 1e2),
            "draft propose (catch-up + scan) per round, seconds")
        self._c_draft_time = tel.counter(
            "serving_draft_time_s", "total seconds in draft proposal")
        self._c_target_time = tel.counter(
            "serving_target_time_s", "total seconds in target packed steps")
        self._g_live_peak = tel.gauge(
            "serving_peak_live_blocks_per_seq",
            help="high-water LIVE (non-freed) blocks held by any one request "
                 "— bounded by ceil(window/block_size)+1 under windowed_paged")
        self._packed_fn = jax.jit(make_packed_fn(model))
        self._copy_fn = jax.jit(copy_blocks)
        if self._rec:
            self._zero_fn = jax.jit(zero_state_slot)
            self._commit_fn = jax.jit(self._make_commit_fn())
        # ---- quality level: quantization-numerics observability ----------
        # Every other level keeps self._packed_fn untouched (its jaxpr is
        # asserted identical to a probe-free build); quality swaps in the
        # PROBED packed step on sampled steps and pays its recompile.
        self._quality = None
        self._probe_fn = None
        self._step_i = 0
        if getattr(tel, "quality", False):
            from repro.core import numerics as _nx

            self._probe_fn = jax.jit(make_probed_packed_fn(model))
            self._quality = _nx.QualityMonitor(
                tel, calib_stats=calib_stats,
                drift_threshold=tel.cfg.quality_drift_threshold)
            self._shadow_params = (shadow_params if shadow_params is not None
                                   else params)
            self._shadow_len = sc.cache_len
            self._shadow_fn = jax.jit(self._make_shadow_fn())
            self._h_shadow_kl = tel.histogram(
                "numerics_shadow_logit_kl", log_buckets(1e-9, 1e3),
                "KL(serving || shadow reference) at the probed decode "
                "position, nats")
            self._g_shadow_top1 = tel.gauge(
                "numerics_shadow_top1_agreement",
                "serving vs shadow argmax agreement at the probed position")
            self._g_shadow_agree = tel.gauge(
                "numerics_shadow_token_agreement",
                "teacher-forced shadow greedy agreement over the committed "
                "decode window")
            self._c_shadow = tel.counter(
                "numerics_shadow_probes", "shadow-reference forwards run")
            if self.spec is not None:
                self._h_first_reject = tel.histogram(
                    "numerics_spec_first_reject_pos",
                    linear_buckets(0.0, float(self.spec.k + 1),
                                   self.spec.k + 1),
                    "draft position of the first greedy rejection "
                    "(acceptance attribution; full accepts not observed)")

    @property
    def stats(self) -> dict:
        """Legacy counter dict, rebuilt from the telemetry registry (all
        zeros under ``telemetry="off"``). Read-only: mutate via telemetry."""
        d = {k: c.value for k, c in self._c.items()}
        d["peak_occupancy"] = self._g_peak.value
        d["peak_live_blocks_per_seq"] = self._g_live_peak.value
        for k, g in self._g_lut.items():  # trace-time LUT route dispatch
            d[k] = g.value
        for k, g in self._g_outlier.items():  # Orizuru detect + comp routes
            d[k] = g.value
        return d

    # ----------------------------------------------------------------- host
    def submit(self, prompt: list[int], max_new_tokens: int,
               eos_id: int | None = None, seed: int = 0,
               salt: int | None = None) -> int:
        """``salt`` individualizes the sampling key within one batch of
        submissions (the engine passes the request's index) so a given
        (seed, request set) resamples identically across generate calls."""
        if not prompt:
            raise ValueError("empty prompt (nothing to prefill)")
        if len(prompt) + max_new_tokens > self.pcfg.max_context:
            raise ValueError(
                f"prompt({len(prompt)}) + max_new({max_new_tokens}) exceeds "
                f"cache_len {self.pcfg.max_context}"
            )
        rid = self._next_rid
        self._next_rid += 1
        r = Request(rid=rid, prompt=list(prompt), max_new_tokens=max_new_tokens,
                    eos_id=eos_id,
                    key=jax.random.PRNGKey(seed * 100_003 + (rid if salt is None else salt)),
                    context=list(prompt))
        self._queue.append(r)
        self.telemetry.request_submitted(rid, len(prompt))
        return rid

    def run(self) -> dict[int, list[int]]:
        """Drain queue + running set; returns {rid: generated tokens}."""
        results: dict[int, list[int]] = {}
        while self.step(results):
            pass
        return results

    def step(self, results: dict[int, list[int]]) -> bool:
        """One scheduler iteration: refill slots from the queue, retire
        finished requests, run one packed token-budget forward over all
        running slots. Finished outputs are added to ``results``. Returns
        True while work remains — online drivers (bench_serving) interleave
        ``submit`` between steps.
        """
        admitted = self._refill_slots()
        for r in [r for r in self._running if r.done]:
            self._finish(r, results)
        if self._running:
            self._packed_once(results)
            return True
        if self._queue and not admitted:  # head can never fit: pool all idle
            r = self._queue[0]
            need = self._blocks_for(len(r.context) + 1)
            raise RuntimeError(
                f"request {r.rid} needs {need} blocks (context + first decode);"
                f" pool has {self.allocator.n_free}/{self.pcfg.n_blocks} free"
            )
        return bool(self._queue)

    # ------------------------------------------------------------- admission
    def _refill_slots(self) -> int:
        """FCFS admission: head of queue enters iff a slot is free and the
        pool can hold its full current context PLUS the first decode token
        (reserving ``blocks_needed(len + 1)`` up front — admitting on an
        exact fit used to let a block_size-multiple prompt be preempted by
        its own first ``_grow``). Returns #admitted. Admission only binds a
        slot + blocks; the prompt is written by the packed steps (alongside
        everyone else's decode tokens), never serially.

        With the prefix cache on, the longest chain of cached full blocks is
        aliased (incref) instead of allocated, and ``prefilled`` starts past
        the shared tokens — capped at ``len(context) - 1`` so at least one
        prompt token is always computed (its logits seed sampling). The draft
        runner never shares that skip: its slot state resets to 0 and the
        whole prompt replays through the draft on the first proposal."""
        admitted = 0
        bs = self.pcfg.block_size
        while self._queue and self._slot_free:
            r = self._queue[0]
            need = self._blocks_for(len(r.context) + 1)
            shared, hashes = self._match_prefix(r)  # increfs on hit
            fresh = self.allocator.alloc(need - len(shared))
            if fresh is None:
                if shared:  # roll the aliases back: blocks return to cached
                    self.allocator.free(list(reversed(shared)))
                break
            self._queue.popleft()
            r.blocks, r.block_hashes = shared + fresh, hashes
            r.slot, r.state = self._slot_free.pop(), RequestState.RUNNING
            r.prefilled = min(len(shared) * bs, len(r.context) - 1)
            if self._rec:
                # fresh occupant: the slot's recurrent state must not leak
                # from the previous request (KV blocks are freshly allocated,
                # state slots are reused in place)
                self.pools = self._zero_fn(self.pools, r.slot)
            if self.draft is not None:
                self.draft.reset(r.slot)
            if shared:
                self._c["prefix_hits"].add()
                self._c["prefix_hit_tokens"].add(len(shared) * bs)
                self._c["prefill_skipped"].add(r.prefilled)
            self.telemetry.request_admitted(r.rid,
                                            prefix_hit_tokens=len(shared) * bs)
            self._running.append(r)
            admitted += 1
        self._g_peak.set_max(self.allocator.occupancy)
        return admitted

    def _match_prefix(self, r: Request) -> tuple[list[int], list[bytes]]:
        """Longest cached full-block prefix of r.context: walks the chain
        hash block by block, increfs every hit (reviving cached refcount-0
        blocks), stops at the first miss. Returns (block ids, chain hashes)."""
        if not self.allocator.prefix_cache:
            return [], []
        bs = self.pcfg.block_size
        ids: list[int] = []
        hashes: list[bytes] = []
        h = self._hash_seed
        for j in range(len(r.context) // bs):
            h = chain_hash(h, r.context[j * bs : (j + 1) * bs])
            bid = self.allocator.lookup(h)
            if bid is None:
                break
            self.allocator.incref(bid)
            ids.append(bid)
            hashes.append(h)
        return ids, hashes

    # ------------------------------------------------------------ packed step
    def _k_for(self, r: Request) -> int:
        """Draft tokens to propose for ``r`` this round: the configured k,
        clipped so verification can never commit past the request's remaining
        generation budget (which also bounds every write below cache_len —
        ``submit`` checked prompt + max_new against the pool geometry)."""
        if self.spec is None:
            return 0
        return max(0, min(self.spec.k,
                          r.max_new_tokens - len(r.generated) - 1))

    def _packed_once(self, results: dict) -> None:
        """Assemble and run one token-budget grid forward.

        Budget policy: decode/verify segments FIRST (``ceil((k+1)/seg_width)``
        rows per decoding slot — a step can never stall decode to admit),
        then prefill segments FCFS over the remaining rows (a request's next
        unwritten context tokens, packed ``seg_width`` per row, clipped to
        the rows that fit; large prompts span several steps).
        """
        S = self.seg_width
        tel = self.telemetry
        t_host0 = tel.now()
        blocks_alloc0 = self.allocator.blocks_allocated
        blocks_freed0 = self.allocator.blocks_freed
        cow0 = self._c["cow_copies"].value
        while True:
            # decode reservation: guarantee blocks for every incoming token
            # (may preempt — victims leave self._running, incl. prefilling)
            for r in list(self._running):
                if r.state is RequestState.RUNNING and r.decoding:
                    self._grow(r, self._k_for(r) + 1)
            if not self._running:
                return
            decoders = [r for r in self._running if r.decoding]
            segments: list[tuple[Request, int, int]] = []  # (request, start, n)
            rows_left = self.rows - len(decoders) * self._dec_rows
            for r in self._running:
                if rows_left <= 0:
                    break
                if not r.decoding:
                    # recurrent state is gathered/scattered once per row, so
                    # a slot gets at most ONE row per step: cap its prefill
                    # chunk at seg_width tokens (pure-KV stacks may span rows)
                    cap = S if self._rec else rows_left * S
                    n = min(cap, len(r.context) - r.prefilled)
                    segments.append((r, r.prefilled, n))
                    rows_left -= -(-n // S)
            if not self._has_paged:
                break  # no blocks -> nothing to copy-on-write
            if self._cow_pass(decoders, segments):
                break  # no preemption mid-pass: the plan above is still live

        # draft proposal AFTER the plan is stable (growth/COW preemptions are
        # done, so no proposal is wasted on an evicted request); the draft
        # pool is private, so proposing cannot invalidate the plan
        drafts: dict[int, list[int]] = {}
        draft_dt = 0.0
        if self.draft is not None and decoders:
            t_d0 = tel.now()
            drafts = self.draft.propose(
                [(r.rid, r.slot, r.context, r.next_token, self._k_for(r))
                 for r in decoders])
            draft_dt = tel.now() - t_d0
            self._h_draft_round.observe(draft_dt)
            self._c_draft_time.add(draft_dt)

        max_blk = self.pcfg.max_blocks_per_seq
        bt = np.full((self.slots, max_blk), -1, np.int32)
        slot_ids = np.zeros((self.rows,), np.int32)
        pos = np.full((self.rows, S), -1, np.int32)
        tok = np.zeros((self.rows, S), np.int32)
        for r in self._running:
            bt[r.slot] = self._bt_row(r)
        row = 0

        def fill(seq, start_pos, slot):
            """Pack one request's token run into consecutive grid cells
            starting on a fresh row; returns the cell coordinates."""
            nonlocal row
            cells = []
            for j, t in enumerate(seq):
                rr, cc = row + j // S, j % S
                slot_ids[rr] = slot
                pos[rr, cc] = start_pos + j
                tok[rr, cc] = t
                cells.append((rr, cc))
            row += -(-len(seq) // S)
            return cells

        # decode/verify segments first (the reservation above sized them in),
        # then prefill segments over the remaining rows
        verify_cells: dict[int, list] = {}
        for r in decoders:
            verify_cells[r.rid] = fill([r.next_token] + drafts.get(r.rid, []),
                                       len(r.context), r.slot)
        last_cell: dict[int, tuple[int, int]] = {}
        n_prefill = 0
        for r, start, n in segments:
            last_cell[r.rid] = fill(r.context[start : start + n], start,
                                    r.slot)[-1]
            n_prefill += n
        ctx = pos.max(axis=1) + 1  # per-row horizon (all-pad rows stay 0)

        # quality level: 1 in quality_sample_every steps runs the PROBED
        # packed fn (step 0 included, so short smokes populate every gauge);
        # all other steps — and every other level — dispatch the untouched
        # packed step
        probe_now = (self._probe_fn is not None and
                     self._step_i % tel.cfg.quality_sample_every == 0)
        probes = None
        t_dispatch = tel.now()
        with tel.annotate("packed_step"):
            args = (self.params, self.pools, jnp.asarray(bt),
                    jnp.asarray(slot_ids), jnp.asarray(pos), jnp.asarray(ctx),
                    jnp.asarray(tok))
            if probe_now:
                self.pools, logits, extras, probes = self._probe_fn(*args)
            else:
                self.pools, logits, extras = self._packed_fn(*args)
            if tel.fence:  # exact host/device split on async backends
                jax.block_until_ready(logits)
        t_done = tel.now()
        self._c_target_time.add(t_done - t_dispatch)

        st = self._c
        n_cells = int((pos >= 0).sum())
        st["packed_steps"].add()
        st["packed_tokens"].add(n_cells)
        st["prefill_tokens"].add(n_prefill)
        st["prefill_chunks"].add(len(segments))
        if decoders:
            st["decode_steps"].add()
        if decoders and segments:
            st["mixed_steps"].add()

        if self.spec is not None and decoders:
            # one device->host transfer of every verify argmax
            am = np.asarray(jnp.argmax(logits, axis=-1))
        shadow_pick = None
        shadow_args = None
        if (self._quality is not None and decoders and
                self._step_i % tel.cfg.quality_shadow_every == 0):
            # deepest committed context = most decode positions to audit
            shadow_pick = max(decoders, key=lambda q: len(q.context))
        for r in decoders:
            cells = verify_cells[r.rid]
            if r is shadow_pick:
                # first verify cell's logits condition on context +
                # [next_token] — the prefix the shadow forward replays
                rw0, cc0 = cells[0]
                shadow_args = (r, np.asarray(logits[rw0, cc0], np.float32),
                               len(r.context) + 1)
            r.context.append(r.next_token)
            r.prefilled += 1  # the segment's first cell wrote it to the cache
            if self.spec is None:
                rw, cc = cells[0]
                r.next_token = self._sample(logits[rw, cc], r)
                r.generated.append(r.next_token)
                st["decode_slot_tokens"].add()
                tel.tokens_committed(r.rid, 1)
                continue
            d = drafts.get(r.rid, [])
            committed = greedy_verify([int(am[rr, cc]) for rr, cc in cells], d,
                                      r.eos_id)
            # all committed-but-last tokens have valid KV already in the
            # cache (their cells matched the drafts written this step); the
            # last one is the new pending next_token
            r.context.extend(committed[:-1])
            r.prefilled += len(committed) - 1
            r.next_token = committed[-1]
            r.generated.extend(committed)
            # acceptance accounting: every committed-but-last token matched
            # its draft by construction; the last counts too when it is an
            # EOS that agreed with its draft (committed, just absorbing)
            n_acc = len(committed) - 1
            if n_acc < len(d) and committed[-1] == d[n_acc]:
                n_acc += 1
            st["spec_rounds"].add()
            st["drafted_tokens"].add(len(d))
            st["accepted_tokens"].add(n_acc)
            st["rolled_back_tokens"].add(len(d) - n_acc)
            st["decode_slot_tokens"].add(len(committed))
            self._h_accept.observe(n_acc)
            if self._quality is not None and n_acc < len(d):
                # acceptance attribution: which draft position broke first
                self._h_first_reject.observe(float(n_acc))
            tel.tokens_committed(r.rid, len(committed))
            tel.request_event(r.rid, "verify_round", drafted=len(d),
                              accepted=n_acc, committed=len(committed))
            self._rollback(r)
            if self._rec and len(committed) < len(cells):
                # the packed step scattered recurrent state at the row's last
                # cell; rewind it to the last CONSUMED cell (next_token +
                # committed[:-1] = cells 0..len(committed)-1)
                self.pools = self._commit_fn(
                    self.pools, extras, cells[0][0], r.slot,
                    len(committed) - 1)
            self.draft.sync(r.slot, len(r.context))
        for r, start, n in segments:
            r.prefilled = start + n
            tel.request_event(r.rid, "prefill_chunk", start=start, n=n)
            if r.decoding and r.next_token is None:
                # the prompt's real last token was in this step: its logits
                # cell is the first sampled token (a re-admitted preemption
                # keeps its already-decided next_token instead)
                rw, col = last_cell[r.rid]
                r.next_token = self._sample(logits[rw, col], r)
                r.generated.append(r.next_token)
                tel.first_token(r.rid)
        for r in self._running:
            self._register_full_blocks(r)  # publish before anyone finishes
        if self._has_paged:
            for r in self._running:
                if self.release_window:
                    self._release_windowed(r)
                self._g_live_peak.set_max(
                    sum(1 for b in r.blocks if b >= 0))
        for r in [r for r in self._running if r.done]:
            self._finish(r, results)
        if probes is not None:
            # one transfer of the whole probe dict -> gauges + drift/alarms
            self._quality.ingest(jax.device_get(probes))
        if shadow_args is not None:
            self._shadow_probe(*shadow_args)
        self._step_i += 1
        if tel.enabled:
            dec_rows = len(decoders) * self._dec_rows
            tel.step_record(
                host_s=(t_dispatch - t_host0 - draft_dt) + (tel.now() - t_done),
                device_s=t_done - t_dispatch,
                cells=n_cells, budget=self.token_budget,
                decode_rows=0 if self.spec else dec_rows,
                verify_rows=dec_rows if self.spec else 0,
                prefill_rows=sum(-(-n // S) for _, _, n in segments),
                blocks_allocated=self.allocator.blocks_allocated - blocks_alloc0,
                blocks_freed=self.allocator.blocks_freed - blocks_freed0,
                blocks_copied=self._c["cow_copies"].value - cow0,
            )

    def _blocks_for(self, n_tokens: int) -> int:
        """Blocks to reserve for an ``n_tokens`` context: zero when no layer
        is paged — recurrent state is slot-major, pinned by the slot."""
        if not self._has_paged:
            return 0
        return blocks_needed(n_tokens, self.pcfg.block_size)

    def _make_shadow_fn(self):
        """Jitted shadow-reference forward: a plain cache-free teacher-forced
        run over one request's committed context, zero-padded to a fixed
        length (``cache_len``) so it compiles once. Causality makes the
        padding inert for every position actually read."""
        model = self.model

        def shadow(params, tokens):  # (1, L) int32
            out = model.apply(params, {"tokens": tokens})
            return out.logits[0, :, : model.cfg.vocab_size]

        return shadow

    def _shadow_probe(self, r: Request, served: np.ndarray,
                      prefix_len: int) -> None:
        """Off-hot-path shadow quality probe (quality level, sampled): re-run
        ``r``'s committed context through the reference forward; record the
        logit KL + top-1 agreement at the probed decode position (vs the
        serving step's own logits for the same prefix) and the teacher-forced
        greedy-token agreement over the whole committed decode window."""
        toks = r.context[: self._shadow_len]
        m = len(toks)
        if m < 2:
            return
        padded = np.zeros((1, self._shadow_len), np.int32)
        padded[0, :m] = toks
        sl = np.asarray(jax.device_get(
            self._shadow_fn(self._shadow_params, jnp.asarray(padded))),
            np.float32)[:m]
        i = prefix_len - 1
        if 0 <= i < m:
            p, q = _softmax(served), _softmax(sl[i])
            kl = max(float(np.sum(p * (np.log(p + 1e-12)
                                       - np.log(q + 1e-12)))), 0.0)
            self._h_shadow_kl.observe(kl)
            self._g_shadow_top1.set(
                float(int(np.argmax(served)) == int(np.argmax(sl[i]))))
            self.telemetry.quality_counter("numerics_shadow_logit_kl", kl)
        start = max(len(r.prompt) - 1, 0)
        if m - 1 > start:
            pred = np.argmax(sl[start: m - 1], axis=-1)
            ref = np.asarray(toks[start + 1: m])
            self._g_shadow_agree.set(float((pred == ref).mean()))
        self._c_shadow.add()

    def _make_commit_fn(self):
        """Jitted corrective commit for recurrent layers: a verify row's
        packed step scattered the state at the row's LAST cell, but greedy
        verification may consume only cells 0..m-1 — rewrite each state pool
        from the per-cell "*_steps" transients at the last consumed cell.
        Generic over the extras keys, so fp ("h_steps"/"conv_steps") and
        quantized ("h_idx_steps"/"h_scale_steps"/"conv_steps") layers both
        rewind; KV layers have empty extras and pass through."""

        def fix_layer(pool, extras, row, slot, step, scanned):
            out = dict(pool)
            for key, steps in extras.items():
                base = key[: -len("_steps")]
                if scanned:  # leading (L, ...) layer dim rides the arrays
                    out[base] = out[base].at[:, slot].set(steps[:, row, step])
                else:
                    out[base] = out[base].at[slot].set(steps[row, step])
            return out

        def commit(pools, extras, row, slot, step):
            if isinstance(pools, dict):  # scan-stacked homogeneous family
                return fix_layer(pools, extras, row, slot, step, True)
            return [fix_layer(lp, le, row, slot, step, False)
                    for lp, le in zip(pools, extras)]

        return commit

    def _rollback(self, r: Request) -> None:
        """Free the blocks a verify segment grew that now hold only rejected
        draft tokens: everything past ``blocks_needed(len(context) + 1)``
        (context plus the pending next_token write — the admission-time
        reservation invariant). Rejected writes *inside* a kept block need no
        cleanup: they sit above the context horizon, are masked out of every
        read, and are overwritten by the next round's writes. Freed tail
        blocks are never registered (registration stops at ``prefilled``) and
        never shared (aliasing only covers prompt blocks), so the truncate is
        a plain decref to the free list. (Windowed -1 holes only ever sit in
        the LEADING region below the write horizon, never in this tail.)"""
        keep = self._blocks_for(len(r.context) + 1)
        if len(r.blocks) > keep:
            r.blocks = self.allocator.truncate(r.blocks, keep)

    def _release_windowed(self, r: Request) -> None:
        """Free blocks no future query of ``r`` can attend. With window W,
        a query at position q attends keys > q - W; every future query sits
        at >= r.prefilled, so block j (tokens [j*bs, (j+1)*bs)) is dead once
        (j+1)*bs <= prefilled - W + 1. A freed entry leaves a -1 hole in the
        LOGICAL table (position p stays at table[p // bs]); the attention
        kernels clamp -1 to block 0 and the window mask makes those keys
        unreachable. Steady-state live blocks per request are thus capped at
        ceil(W / bs) + 1 (paged_cache.windowed_block_cap)."""
        bs = self.pcfg.block_size
        drop = max(0, r.prefilled - self.release_window + 1) // bs
        for j in range(min(drop, len(r.blocks))):
            if r.blocks[j] >= 0:
                self.allocator.free([r.blocks[j]])
                r.blocks[j] = -1

    def _cow_pass(self, decoders, segments) -> bool:
        """Copy-on-write: any block this step will write into whose refcount
        exceeds 1 (a shared prefix block — the aliased-last-block case, or a
        verify segment reaching into one) is replaced by a private
        device-side copy before the packed step runs, so the write can never
        leak into another request's context. Returns False if making room for
        a copy preempted somebody — the caller's decode/segment plan is stale
        and must be recomputed (the swaps done so far remain valid: the
        blocks are now private)."""
        writes: list[tuple[Request, int, int]] = []  # (request, lo blk, hi blk)
        bs = self.pcfg.block_size
        for r in decoders:
            n0 = len(r.context)
            writes.append((r, n0 // bs, (n0 + self._k_for(r)) // bs))
        for r, start, n in segments:
            writes.append((r, start // bs, (start + n - 1) // bs))
        copies: list[tuple[Request, int, int]] = []  # (request, src, dst)
        plan_live = True
        for r, lo, hi in writes:
            if r.state is not RequestState.RUNNING:
                continue  # preempted by an earlier copy's allocation
            for j in range(lo, hi + 1):
                bid = r.blocks[j]
                if self.allocator.refcount(bid) <= 1:
                    continue
                new, preempted = self._alloc_one(r)  # never preempts r itself
                plan_live &= not preempted
                copies.append((r, bid, new))
                r.blocks[j] = new
                self.allocator.free([bid])  # drop r's alias on the original
        # a later allocation may have preempted an earlier copy's owner and
        # recycled its destination block — drop stale pairs so no two copies
        # scatter into the same destination (scatter order is unspecified)
        copies = [(r, s, d) for r, s, d in copies
                  if r.state is RequestState.RUNNING]
        self._c["cow_copies"].add(len(copies))
        if copies:
            # pad (src, dst) to a power-of-two bucket by REPEATING the first
            # pair (duplicate scatters of the same value are idempotent, and
            # no pad row can race a real destination): the jitted copy then
            # compiles per bucket, not per distinct copy count (an
            # unbounded-recompile serving stall)
            cap = 1
            while cap < len(copies):
                cap *= 2
            pad = cap - len(copies)
            src = [s for _, s, _ in copies] + [copies[0][1]] * pad
            dst = [d for _, _, d in copies] + [copies[0][2]] * pad
            self.pools = self._copy_fn(self.pools, np.asarray(src, np.int32),
                                       np.asarray(dst, np.int32))
        return plan_live

    def _grow(self, r: Request, n_tokens: int = 1) -> None:
        """Guarantee blocks for positions ``len(context) .. len(context) +
        n_tokens - 1`` (the cells about to be written — one decode token, or
        a whole verify segment), evicting the youngest other request if the
        pool is dry."""
        while self._blocks_for(len(r.context) + n_tokens) > len(r.blocks):
            got, _ = self._alloc_one(r)
            r.blocks.append(got)

    def _alloc_one(self, r: Request) -> tuple[int, bool]:
        """One block for ``r``, preempting the youngest *other* request until
        the allocator (free list, then cached-prefix LRU) can serve it.
        Returns (block id, whether anything was preempted)."""
        preempted = False
        while True:
            got = self.allocator.alloc(1)
            if got is not None:
                self._g_peak.set_max(self.allocator.occupancy)
                return got[0], preempted
            victims = [v for v in self._running if v is not r]
            if not victims:
                raise RuntimeError(
                    f"request {r.rid} cannot grow: pool of {self.pcfg.n_blocks} "
                    "blocks is exhausted and there is nothing left to preempt"
                )
            self._preempt(victims[-1])
            preempted = True

    def _register_full_blocks(self, r: Request) -> None:
        """Publish every newly-FULL block of ``r`` under its chain hash so
        later admissions can alias it (first writer wins; blocks aliased at
        admission arrive pre-hashed in r.block_hashes and are skipped).
        ``prefilled`` only ever counts verified/committed tokens, so a block
        is published iff every one of its rows holds accepted context — a
        rejected speculative write can never leak into the prefix cache."""
        if not self.allocator.prefix_cache:
            return
        bs = self.pcfg.block_size
        full = r.prefilled // bs  # only blocks whose every token is written
        h = r.block_hashes[-1] if r.block_hashes else self._hash_seed
        while len(r.block_hashes) < full:
            j = len(r.block_hashes)
            h = chain_hash(h, r.context[j * bs : (j + 1) * bs])
            r.block_hashes.append(h)
            self.allocator.register(h, r.blocks[j])

    def _preempt(self, r: Request) -> None:
        # decref tail-first so a whole cached chain ages out leaf-before-root
        # (evicting a root block would orphan its still-cached descendants);
        # windowed -1 holes were already freed at release time
        self.allocator.free([b for b in reversed(r.blocks) if b >= 0])
        r.blocks, r.block_hashes = [], []
        self._slot_free.append(r.slot)
        r.slot = -1
        r.prefilled = 0  # re-admission rewrites (or re-matches) the context
        r.state = RequestState.PREEMPTED
        self._running.remove(r)
        self._queue.appendleft(r)  # front: preserves FCFS completion order
        self._c["preemptions"].add()
        self.telemetry.request_preempted(r.rid)

    def _finish(self, r: Request, results: dict) -> None:
        self.allocator.free([b for b in reversed(r.blocks) if b >= 0])
        r.blocks, r.block_hashes = [], []
        self._slot_free.append(r.slot)
        r.slot = -1
        r.state = RequestState.FINISHED
        self._running.remove(r)
        results[r.rid] = r.output()
        self.telemetry.request_finished(r.rid, n_generated=len(r.generated))

    # ----------------------------------------------------------------- misc
    def _bt_row(self, r: Request) -> np.ndarray:
        row = np.full((self.pcfg.max_blocks_per_seq,), -1, np.int32)
        row[: len(r.blocks)] = r.blocks
        return row

    def _sample(self, logits: jax.Array, r: Request) -> int:
        if self.sc.temperature > 0:
            r.key, sub = jax.random.split(r.key)
            return int(jax.random.categorical(sub, logits / self.sc.temperature))
        return int(jnp.argmax(logits))
