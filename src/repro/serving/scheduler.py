"""Continuous-batching scheduler over the paged K-Means KV cache.

Request lifecycle::

    QUEUED --admit (FCFS, free-block budget)--> RUNNING (prefilling)
    RUNNING --prompt fully written--> RUNNING (decoding)
    RUNNING --EOS / max-tokens--> FINISHED      (slot + blocks freed,
    RUNNING --pool exhausted--> PREEMPTED        refilled next step)
    PREEMPTED --requeued at the front--> QUEUED  (recompute on re-admission)

The hot loop is ONE jitted *packed* step of fixed shape: every scheduler
iteration assembles a flat batch of exactly ``token_budget`` token rows —
one decode token for every decoding slot (reserved FIRST, so admissions can
never starve running requests) plus as many prefill tokens from admitting
requests as fit in the remaining budget — with per-token (slot, position)
vectors. Each row writes its token's KV into the slot's blocks and attends
through the slot's block table; rows of the same request are causally
ordered by position within the same forward (write-then-attend), so a
prefill segment and the step's decode tokens ride in one ``model.apply``.
Unused rows carry position -1 and are masked out of both the scatter and the
attention. There is no separate prefill function and no batch=1 serial
admission phase: prefill/decode interference is gone by construction, and a
step's cost is always exactly ``token_budget`` tokens.

**Prefix sharing** (``ServeConfig.prefix_cache``): as prefill fills a block
completely, the scheduler registers it with the allocator under the chain
hash of (pool identity, every token up to the block's end). Admission then
matches an incoming prompt's longest cached full-block prefix, increfs and
aliases those physical blocks into the new request's table, and sets
``prefilled`` past the shared tokens — their prefill compute is skipped
entirely; only the tail gets fresh blocks. Writes into a block whose
refcount exceeds 1 (the aliased-last-block case when a prompt is an exact
multiple of block_size) are **copy-on-write**: the block's pool rows are
copied device-side across all layers into a fresh block and the table entry
swapped before the packed step, so ``attention_apply`` and the Pallas
kernel never see sharing. Deterministic K-Means assignment makes shared KV
bit-identical to recomputed KV, so sharing never changes sampled tokens.

Preemption is by eviction: when a decoding sequence cannot get a block, the
most recently admitted *other* request is evicted (blocks decref'd, requeued
front, prefill progress reset) and recomputed later — a decref only recycles
a block nobody else holds, and a re-admitted victim usually re-matches its
own just-registered prefix blocks, making recovery cheap. Cached refcount-0
prefix blocks are reclaimed (LRU) by the allocator before any preemption.

Sampling happens host-side from the logits the packed step returns (greedy
or per-request-keyed temperature): a decoding request samples from its
decode row; a request whose LAST prompt token was written this step samples
its first token from that row — per-request keys make sampled outputs
independent of how steps were packed.
"""

from __future__ import annotations

import dataclasses
import enum
from collections import deque

import jax
import jax.numpy as jnp
import numpy as np

from repro.serving.paged_cache import (
    BlockAllocator,
    PagedCacheConfig,
    attach_tables,
    blocks_needed,
    chain_hash,
    copy_blocks,
    detach_tables,
    prefix_seed,
)

__all__ = ["RequestState", "Request", "Scheduler"]


class RequestState(enum.Enum):
    QUEUED = "queued"
    RUNNING = "running"
    PREEMPTED = "preempted"
    FINISHED = "finished"


@dataclasses.dataclass
class Request:
    rid: int
    prompt: list[int]
    max_new_tokens: int
    eos_id: int | None
    key: jax.Array  # per-request sampling key (temperature > 0)
    state: RequestState = RequestState.QUEUED
    context: list[int] = dataclasses.field(default_factory=list)  # tokens fed
    generated: list[int] = dataclasses.field(default_factory=list)
    prefilled: int = 0  # context tokens written to the cache so far
    next_token: int | None = None  # sampled, not yet fed to the model
    blocks: list[int] = dataclasses.field(default_factory=list)
    block_hashes: list[bytes] = dataclasses.field(default_factory=list)  # chain
    slot: int = -1

    @property
    def decoding(self) -> bool:
        """Context fully written: the next packed step feeds next_token."""
        return self.prefilled >= len(self.context)

    @property
    def done(self) -> bool:
        if len(self.generated) >= self.max_new_tokens:
            return True
        return self.eos_id is not None and bool(self.generated) and \
            self.generated[-1] == self.eos_id

    def output(self) -> list[int]:
        """Exactly max_new_tokens tokens (eos-padded after early stop)."""
        out = list(self.generated[: self.max_new_tokens])
        pad = self.eos_id if self.eos_id is not None else 0
        return out + [pad] * (self.max_new_tokens - len(out))


class Scheduler:
    """Owns the block pool, the allocator, and the single jitted packed step.

    ``sc`` is a :class:`repro.serving.engine.ServeConfig`; its ``cache_len``
    bounds per-request context (prompt + generated), ``block_size`` /
    ``n_blocks`` size the pool (n_blocks=0 -> slots * blocks-per-request, a
    no-preemption default; pass a smaller pool to exercise preemption),
    ``token_budget`` fixes the packed step's row count (0 -> slots +
    prefill_chunk; must be >= slots so every decoding slot always fits), and
    ``prefix_cache`` enables refcounted prefix-block sharing.
    """

    def __init__(self, model, params, sc, slots: int = 8):
        if not model.supports_paged_cache():
            raise ValueError(f"family {model.cfg.family} cannot use the paged scheduler")
        self.model, self.params, self.sc, self.slots = model, params, sc, slots
        self.token_budget = sc.token_budget or (slots + sc.prefill_chunk)
        if self.token_budget < slots:
            raise ValueError(
                f"token_budget {self.token_budget} < slots {slots}: decode "
                "reservation needs one row per slot"
            )
        max_blk = blocks_needed(sc.cache_len, sc.block_size)
        n_blocks = sc.n_blocks or slots * max_blk
        self.pcfg = PagedCacheConfig(block_size=sc.block_size, n_blocks=n_blocks,
                                     max_blocks_per_seq=max_blk)
        self.pools = model.init_caches(
            slots, sc.cache_len, jnp.dtype(sc.cache_dtype), quantized=sc.kv_quant,
            layout="paged", block_size=sc.block_size, n_blocks=n_blocks,
        )
        self.allocator = BlockAllocator(n_blocks, prefix_cache=sc.prefix_cache)
        # chain-hash root: blocks are only shareable within one (layer-set,
        # quant-policy, geometry) identity — a pool restarted with a different
        # KV treatment can never alias stale hashes
        self._hash_seed = prefix_seed(
            family=model.cfg.family, n_layers=model.cfg.n_layers,
            n_kv_heads=model.cfg.n_kv_heads, head_dim=model.cfg.head_dim,
            kv_quant=sc.kv_quant, cache_dtype=str(sc.cache_dtype),
            block_size=sc.block_size,
        )
        self._queue: deque[Request] = deque()
        self._running: list[Request] = []
        self._slot_free = list(range(slots - 1, -1, -1))
        self._next_rid = 0
        self.stats = {"packed_steps": 0, "decode_steps": 0, "prefill_chunks": 0,
                      "mixed_steps": 0, "preemptions": 0, "peak_occupancy": 0.0,
                      "decode_slot_tokens": 0, "prefill_tokens": 0,
                      "packed_tokens": 0, "prefix_hits": 0,
                      "prefix_hit_tokens": 0, "prefill_skipped": 0,
                      "cow_copies": 0}
        self._packed_fn = jax.jit(self._make_packed_step())
        self._copy_fn = jax.jit(copy_blocks)

    # ------------------------------------------------------------------ jit
    def _make_packed_step(self):
        model = self.model

        def packed_step(params, pools, bt, slot_ids, positions, ctx, tokens):
            """The unified token-budget forward: tokens/positions/ctx/slot_ids
            are flat (T,) vectors (position -1 = unused row), bt is the
            per-SLOT (slots, max_blk) block-table matrix. Row t writes
            tokens[t] at positions[t] into slot_ids[t]'s blocks and attends
            to that slot's context up to positions[t]; returns per-row
            next-token logits (T, vocab)."""
            caches = attach_tables(pools, bt, ctx, model.cfg.n_layers,
                                   model.cfg.scan_layers, token_slots=slot_ids)
            out = model.apply(params, {"tokens": tokens[:, None]},
                              positions=positions[:, None], caches=caches)
            return detach_tables(out.caches), out.logits[:, 0, : model.cfg.vocab_size]

        return packed_step

    # ----------------------------------------------------------------- host
    def submit(self, prompt: list[int], max_new_tokens: int,
               eos_id: int | None = None, seed: int = 0,
               salt: int | None = None) -> int:
        """``salt`` individualizes the sampling key within one batch of
        submissions (the engine passes the request's index) so a given
        (seed, request set) resamples identically across generate calls."""
        if not prompt:
            raise ValueError("empty prompt (nothing to prefill)")
        if len(prompt) + max_new_tokens > self.pcfg.max_context:
            raise ValueError(
                f"prompt({len(prompt)}) + max_new({max_new_tokens}) exceeds "
                f"cache_len {self.pcfg.max_context}"
            )
        rid = self._next_rid
        self._next_rid += 1
        r = Request(rid=rid, prompt=list(prompt), max_new_tokens=max_new_tokens,
                    eos_id=eos_id,
                    key=jax.random.PRNGKey(seed * 100_003 + (rid if salt is None else salt)),
                    context=list(prompt))
        self._queue.append(r)
        return rid

    def run(self) -> dict[int, list[int]]:
        """Drain queue + running set; returns {rid: generated tokens}."""
        results: dict[int, list[int]] = {}
        while self.step(results):
            pass
        return results

    def step(self, results: dict[int, list[int]]) -> bool:
        """One scheduler iteration: refill slots from the queue, retire
        finished requests, run one packed token-budget forward over all
        running slots. Finished outputs are added to ``results``. Returns
        True while work remains — online drivers (bench_serving) interleave
        ``submit`` between steps.
        """
        admitted = self._refill_slots()
        for r in [r for r in self._running if r.done]:
            self._finish(r, results)
        if self._running:
            self._packed_once(results)
            return True
        if self._queue and not admitted:  # head can never fit: pool all idle
            r = self._queue[0]
            need = blocks_needed(len(r.context) + 1, self.pcfg.block_size)
            raise RuntimeError(
                f"request {r.rid} needs {need} blocks (context + first decode);"
                f" pool has {self.allocator.n_free}/{self.pcfg.n_blocks} free"
            )
        return bool(self._queue)

    # ------------------------------------------------------------- admission
    def _refill_slots(self) -> int:
        """FCFS admission: head of queue enters iff a slot is free and the
        pool can hold its full current context PLUS the first decode token
        (reserving ``blocks_needed(len + 1)`` up front — admitting on an
        exact fit used to let a block_size-multiple prompt be preempted by
        its own first ``_grow``). Returns #admitted. Admission only binds a
        slot + blocks; the prompt is written by the packed steps (alongside
        everyone else's decode tokens), never serially.

        With the prefix cache on, the longest chain of cached full blocks is
        aliased (incref) instead of allocated, and ``prefilled`` starts past
        the shared tokens — capped at ``len(context) - 1`` so at least one
        prompt token is always computed (its logits seed sampling)."""
        admitted = 0
        bs = self.pcfg.block_size
        while self._queue and self._slot_free:
            r = self._queue[0]
            need = blocks_needed(len(r.context) + 1, bs)
            shared, hashes = self._match_prefix(r)  # increfs on hit
            fresh = self.allocator.alloc(need - len(shared))
            if fresh is None:
                if shared:  # roll the aliases back: blocks return to cached
                    self.allocator.free(list(reversed(shared)))
                break
            self._queue.popleft()
            r.blocks, r.block_hashes = shared + fresh, hashes
            r.slot, r.state = self._slot_free.pop(), RequestState.RUNNING
            r.prefilled = min(len(shared) * bs, len(r.context) - 1)
            if shared:
                self.stats["prefix_hits"] += 1
                self.stats["prefix_hit_tokens"] += len(shared) * bs
                self.stats["prefill_skipped"] += r.prefilled
            self._running.append(r)
            admitted += 1
        self.stats["peak_occupancy"] = max(self.stats["peak_occupancy"],
                                           self.allocator.occupancy)
        return admitted

    def _match_prefix(self, r: Request) -> tuple[list[int], list[bytes]]:
        """Longest cached full-block prefix of r.context: walks the chain
        hash block by block, increfs every hit (reviving cached refcount-0
        blocks), stops at the first miss. Returns (block ids, chain hashes)."""
        if not self.allocator.prefix_cache:
            return [], []
        bs = self.pcfg.block_size
        ids: list[int] = []
        hashes: list[bytes] = []
        h = self._hash_seed
        for j in range(len(r.context) // bs):
            h = chain_hash(h, r.context[j * bs : (j + 1) * bs])
            bid = self.allocator.lookup(h)
            if bid is None:
                break
            self.allocator.incref(bid)
            ids.append(bid)
            hashes.append(h)
        return ids, hashes

    # ------------------------------------------------------------ packed step
    def _packed_once(self, results: dict) -> None:
        """Assemble and run one token-budget forward.

        Budget policy: decode rows FIRST (one per decoding slot — a step can
        never stall decode to admit), then prefill segments FCFS over the
        remaining budget (a request's segment is its next unwritten context
        tokens, clipped to what fits; large prompts span several steps).
        """
        t_budget = self.token_budget
        while True:
            # decode reservation: guarantee a block for each incoming token
            # (may preempt — victims leave self._running, incl. prefilling)
            for r in list(self._running):
                if r.state is RequestState.RUNNING and r.decoding:
                    self._grow(r)
            if not self._running:
                return
            decoders = [r for r in self._running if r.decoding]
            segments: list[tuple[Request, int, int]] = []  # (request, start, n)
            budget = t_budget - len(decoders)
            for r in self._running:
                if budget <= 0:
                    break
                if not r.decoding:
                    n = min(budget, len(r.context) - r.prefilled)
                    segments.append((r, r.prefilled, n))
                    budget -= n
            if self._cow_pass(decoders, segments):
                break  # no preemption mid-pass: the plan above is still live

        max_blk = self.pcfg.max_blocks_per_seq
        bt = np.full((self.slots, max_blk), -1, np.int32)
        slot_ids = np.zeros((t_budget,), np.int32)
        pos = np.full((t_budget,), -1, np.int32)
        tok = np.zeros((t_budget,), np.int32)
        for r in self._running:
            bt[r.slot] = self._bt_row(r)
        row = 0
        decode_row: dict[int, int] = {}
        for r in decoders:
            slot_ids[row], pos[row], tok[row] = r.slot, len(r.context), r.next_token
            decode_row[r.rid] = row
            row += 1
        last_row: dict[int, int] = {}
        for r, start, n in segments:
            sl = slice(row, row + n)
            slot_ids[sl] = r.slot
            pos[sl] = np.arange(start, start + n)
            tok[sl] = r.context[start : start + n]
            last_row[r.rid] = row + n - 1
            row += n
        ctx = pos + 1  # write/attend horizon per row (-1 rows stay invalid)

        self.pools, logits = self._packed_fn(
            self.params, self.pools, jnp.asarray(bt), jnp.asarray(slot_ids),
            jnp.asarray(pos), jnp.asarray(ctx), jnp.asarray(tok),
        )

        st = self.stats
        st["packed_steps"] += 1
        st["packed_tokens"] += row
        st["decode_slot_tokens"] += len(decoders)
        st["prefill_tokens"] += sum(n for _, _, n in segments)
        st["prefill_chunks"] += len(segments)
        if decoders:
            st["decode_steps"] += 1
        if decoders and segments:
            st["mixed_steps"] += 1

        for r in decoders:
            r.context.append(r.next_token)
            r.prefilled += 1  # the decode row wrote it to the cache
            r.next_token = self._sample(logits[decode_row[r.rid]], r)
            r.generated.append(r.next_token)
        for r, start, n in segments:
            r.prefilled = start + n
            if r.decoding and r.next_token is None:
                # the prompt's real last token was in this step: its logits
                # row is the first sampled token (a re-admitted preemption
                # keeps its already-decided next_token instead)
                r.next_token = self._sample(logits[last_row[r.rid]], r)
                r.generated.append(r.next_token)
        for r in self._running:
            self._register_full_blocks(r)  # publish before anyone finishes
        for r in [r for r in self._running if r.done]:
            self._finish(r, results)

    def _cow_pass(self, decoders, segments) -> bool:
        """Copy-on-write: any block this step will write into whose refcount
        exceeds 1 (a shared prefix block — the aliased-last-block case) is
        replaced by a private device-side copy before the packed step runs,
        so the write can never leak into another request's context. Returns
        False if making room for a copy preempted somebody — the caller's
        decode/segment plan is stale and must be recomputed (the swaps done
        so far remain valid: the blocks are now private)."""
        writes: list[tuple[Request, int, int]] = []  # (request, lo blk, hi blk)
        bs = self.pcfg.block_size
        for r in decoders:
            j = len(r.context) // bs
            writes.append((r, j, j))
        for r, start, n in segments:
            writes.append((r, start // bs, (start + n - 1) // bs))
        copies: list[tuple[Request, int, int]] = []  # (request, src, dst)
        plan_live = True
        for r, lo, hi in writes:
            if r.state is not RequestState.RUNNING:
                continue  # preempted by an earlier copy's allocation
            for j in range(lo, hi + 1):
                bid = r.blocks[j]
                if self.allocator.refcount(bid) <= 1:
                    continue
                new, preempted = self._alloc_one(r)  # never preempts r itself
                plan_live &= not preempted
                copies.append((r, bid, new))
                r.blocks[j] = new
                self.allocator.free([bid])  # drop r's alias on the original
        # a later allocation may have preempted an earlier copy's owner and
        # recycled its destination block — drop stale pairs so no two copies
        # scatter into the same destination (scatter order is unspecified)
        copies = [(r, s, d) for r, s, d in copies
                  if r.state is RequestState.RUNNING]
        self.stats["cow_copies"] += len(copies)
        if copies:
            # pad (src, dst) to a power-of-two bucket by REPEATING the first
            # pair (duplicate scatters of the same value are idempotent, and
            # no pad row can race a real destination): the jitted copy then
            # compiles per bucket, not per distinct copy count (an
            # unbounded-recompile serving stall)
            cap = 1
            while cap < len(copies):
                cap *= 2
            pad = cap - len(copies)
            src = [s for _, s, _ in copies] + [copies[0][1]] * pad
            dst = [d for _, _, d in copies] + [copies[0][2]] * pad
            self.pools = self._copy_fn(self.pools, np.asarray(src, np.int32),
                                       np.asarray(dst, np.int32))
        return plan_live

    def _grow(self, r: Request) -> None:
        """Guarantee a block for position len(r.context) (the token about to
        be written), evicting the youngest other request if the pool is dry."""
        if blocks_needed(len(r.context) + 1, self.pcfg.block_size) <= len(r.blocks):
            return
        got, _ = self._alloc_one(r)
        r.blocks.append(got)

    def _alloc_one(self, r: Request) -> tuple[int, bool]:
        """One block for ``r``, preempting the youngest *other* request until
        the allocator (free list, then cached-prefix LRU) can serve it.
        Returns (block id, whether anything was preempted)."""
        preempted = False
        while True:
            got = self.allocator.alloc(1)
            if got is not None:
                self.stats["peak_occupancy"] = max(self.stats["peak_occupancy"],
                                                   self.allocator.occupancy)
                return got[0], preempted
            victims = [v for v in self._running if v is not r]
            if not victims:
                raise RuntimeError(
                    f"request {r.rid} cannot grow: pool of {self.pcfg.n_blocks} "
                    "blocks is exhausted and there is nothing left to preempt"
                )
            self._preempt(victims[-1])
            preempted = True

    def _register_full_blocks(self, r: Request) -> None:
        """Publish every newly-FULL block of ``r`` under its chain hash so
        later admissions can alias it (first writer wins; blocks aliased at
        admission arrive pre-hashed in r.block_hashes and are skipped)."""
        if not self.allocator.prefix_cache:
            return
        bs = self.pcfg.block_size
        full = r.prefilled // bs  # only blocks whose every token is written
        h = r.block_hashes[-1] if r.block_hashes else self._hash_seed
        while len(r.block_hashes) < full:
            j = len(r.block_hashes)
            h = chain_hash(h, r.context[j * bs : (j + 1) * bs])
            r.block_hashes.append(h)
            self.allocator.register(h, r.blocks[j])

    def _preempt(self, r: Request) -> None:
        # decref tail-first so a whole cached chain ages out leaf-before-root
        # (evicting a root block would orphan its still-cached descendants)
        self.allocator.free(list(reversed(r.blocks)))
        r.blocks, r.block_hashes = [], []
        self._slot_free.append(r.slot)
        r.slot = -1
        r.prefilled = 0  # re-admission rewrites (or re-matches) the context
        r.state = RequestState.PREEMPTED
        self._running.remove(r)
        self._queue.appendleft(r)  # front: preserves FCFS completion order
        self.stats["preemptions"] += 1

    def _finish(self, r: Request, results: dict) -> None:
        self.allocator.free(list(reversed(r.blocks)))
        r.blocks, r.block_hashes = [], []
        self._slot_free.append(r.slot)
        r.slot = -1
        r.state = RequestState.FINISHED
        self._running.remove(r)
        results[r.rid] = r.output()

    # ----------------------------------------------------------------- misc
    def _bt_row(self, r: Request) -> np.ndarray:
        row = np.full((self.pcfg.max_blocks_per_seq,), -1, np.int32)
        row[: len(r.blocks)] = r.blocks
        return row

    def _sample(self, logits: jax.Array, r: Request) -> int:
        if self.sc.temperature > 0:
            r.key, sub = jax.random.split(r.key)
            return int(jax.random.categorical(sub, logits / self.sc.temperature))
        return int(jnp.argmax(logits))
