"""Continuous-batching scheduler over the paged K-Means KV cache.

Request lifecycle::

    QUEUED --admit (FCFS, free-block budget)--> RUNNING
    RUNNING --EOS / max-tokens--> FINISHED      (slot + blocks freed,
    RUNNING --pool exhausted--> PREEMPTED        refilled next step)
    PREEMPTED --requeued at the front--> QUEUED  (recompute on re-admission)

The decode hot loop is ONE jitted function of fixed shape (``slots`` rows,
``max_blocks_per_seq`` table columns): every step all slots decode one token
against their own block tables; finished slots are refilled from the queue
between steps, so throughput under mixed-length traffic no longer degrades
to the slowest request of a chunk. Prefill runs per request in fixed-size
token chunks (``prefill_chunk``) through a second jitted function — a new
request only ever costs its own prompt length, not the batch-wide pad.

Preemption is by eviction: when a growing sequence cannot get a block, the
most recently admitted *other* request is evicted (blocks freed, requeued
front) and recomputed later — deterministic K-Means assignment makes the
recomputed KV bit-identical, so preemption never changes tokens.

Sampling happens host-side from logits the step functions return (greedy or
per-request-keyed temperature) — decode logits, not stale prefill logits.
"""

from __future__ import annotations

import dataclasses
import enum
from collections import deque

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.qlinear import use_apply_config
from repro.serving.paged_cache import (
    BlockAllocator,
    PagedCacheConfig,
    attach_tables,
    blocks_needed,
    detach_tables,
)

__all__ = ["RequestState", "Request", "Scheduler"]


class RequestState(enum.Enum):
    QUEUED = "queued"
    RUNNING = "running"
    PREEMPTED = "preempted"
    FINISHED = "finished"


@dataclasses.dataclass
class Request:
    rid: int
    prompt: list[int]
    max_new_tokens: int
    eos_id: int | None
    key: jax.Array  # per-request sampling key (temperature > 0)
    state: RequestState = RequestState.QUEUED
    context: list[int] = dataclasses.field(default_factory=list)  # tokens fed
    generated: list[int] = dataclasses.field(default_factory=list)
    next_token: int | None = None  # sampled, not yet fed to the model
    blocks: list[int] = dataclasses.field(default_factory=list)
    slot: int = -1

    @property
    def done(self) -> bool:
        if len(self.generated) >= self.max_new_tokens:
            return True
        return self.eos_id is not None and bool(self.generated) and \
            self.generated[-1] == self.eos_id

    def output(self) -> list[int]:
        """Exactly max_new_tokens tokens (eos-padded after early stop)."""
        out = list(self.generated[: self.max_new_tokens])
        pad = self.eos_id if self.eos_id is not None else 0
        return out + [pad] * (self.max_new_tokens - len(out))


class Scheduler:
    """Owns the block pool, the allocator, and the two jitted step functions.

    ``sc`` is a :class:`repro.serving.engine.ServeConfig`; its ``cache_len``
    bounds per-request context (prompt + generated), ``block_size`` /
    ``n_blocks`` size the pool (n_blocks=0 -> slots * blocks-per-request, a
    no-preemption default; pass a smaller pool to exercise preemption).
    """

    def __init__(self, model, params, sc, slots: int = 8):
        if not model.supports_paged_cache():
            raise ValueError(f"family {model.cfg.family} cannot use the paged scheduler")
        self.model, self.params, self.sc, self.slots = model, params, sc, slots
        max_blk = blocks_needed(sc.cache_len, sc.block_size)
        n_blocks = sc.n_blocks or slots * max_blk
        self.pcfg = PagedCacheConfig(block_size=sc.block_size, n_blocks=n_blocks,
                                     max_blocks_per_seq=max_blk)
        self.pools = model.init_caches(
            slots, sc.cache_len, jnp.dtype(sc.cache_dtype), quantized=sc.kv_quant,
            layout="paged", block_size=sc.block_size, n_blocks=n_blocks,
        )
        self.allocator = BlockAllocator(n_blocks)
        self._queue: deque[Request] = deque()
        self._running: list[Request] = []
        self._slot_free = list(range(slots - 1, -1, -1))
        self._next_rid = 0
        self.stats = {"decode_steps": 0, "prefill_chunks": 0, "preemptions": 0,
                      "peak_occupancy": 0.0, "decode_slot_tokens": 0}
        self._prefill_fn = jax.jit(self._make_prefill_chunk())
        self._decode_fn = jax.jit(self._make_decode_step())

    # ------------------------------------------------------------------ jit
    def _attach(self, bt, cl):
        return attach_tables(self.pools, bt, cl, self.model.cfg.n_layers,
                             self.model.cfg.scan_layers)

    def _make_prefill_chunk(self):
        model, sc, chunk = self.model, self.sc, self.sc.prefill_chunk

        def prefill_chunk(params, pools, bt, tokens, start, plen):
            """tokens (1, chunk) zero-padded; writes positions
            [start, min(start+chunk, plen)); returns logits at row plen-1
            (garbage unless this chunk contains it)."""
            positions = start + jnp.arange(chunk, dtype=jnp.int32)
            ctx = jnp.minimum(start + chunk, plen)[None]
            caches = attach_tables(pools, bt, ctx, model.cfg.n_layers,
                                   model.cfg.scan_layers)
            with use_apply_config(sc.qconfig):
                out = model.apply(params, {"tokens": tokens},
                                  positions=positions, caches=caches)
            logits = out.logits[0, jnp.clip(plen - 1 - start, 0, chunk - 1)]
            return detach_tables(out.caches), logits[: model.cfg.vocab_size]

        return prefill_chunk

    def _make_decode_step(self):
        model, sc = self.model, self.sc

        def decode_step(params, pools, bt, ctx_lens, tokens):
            """One token for every slot. ctx_lens counts the incoming token
            (0 = idle slot: nothing is written or read for that row)."""
            positions = (ctx_lens - 1)[:, None]
            caches = attach_tables(pools, bt, ctx_lens, model.cfg.n_layers,
                                   model.cfg.scan_layers)
            with use_apply_config(sc.qconfig):
                out = model.apply(params, {"tokens": tokens},
                                  positions=positions, caches=caches)
            return detach_tables(out.caches), out.logits[:, -1, : model.cfg.vocab_size]

        return decode_step

    # ----------------------------------------------------------------- host
    def submit(self, prompt: list[int], max_new_tokens: int,
               eos_id: int | None = None, seed: int = 0,
               salt: int | None = None) -> int:
        """``salt`` individualizes the sampling key within one batch of
        submissions (the engine passes the request's index) so a given
        (seed, request set) resamples identically across generate calls."""
        if not prompt:
            raise ValueError("empty prompt (nothing to prefill)")
        if len(prompt) + max_new_tokens > self.pcfg.max_context:
            raise ValueError(
                f"prompt({len(prompt)}) + max_new({max_new_tokens}) exceeds "
                f"cache_len {self.pcfg.max_context}"
            )
        rid = self._next_rid
        self._next_rid += 1
        r = Request(rid=rid, prompt=list(prompt), max_new_tokens=max_new_tokens,
                    eos_id=eos_id,
                    key=jax.random.PRNGKey(seed * 100_003 + (rid if salt is None else salt)),
                    context=list(prompt))
        self._queue.append(r)
        return rid

    def run(self) -> dict[int, list[int]]:
        """Drain queue + running set; returns {rid: generated tokens}."""
        results: dict[int, list[int]] = {}
        while self.step(results):
            pass
        return results

    def step(self, results: dict[int, list[int]]) -> bool:
        """One scheduler iteration: refill slots from the queue, retire
        finished requests, decode one token for every running slot. Finished
        outputs are added to ``results``. Returns True while work remains —
        online drivers (bench_serving) interleave ``submit`` between steps.
        """
        admitted = self._refill_slots()
        for r in [r for r in self._running if r.done]:
            self._finish(r, results)
        if self._running:
            self._decode_once(results)
            return True
        if self._queue and not admitted:  # head can never fit: whole pool is free
            r = self._queue[0]
            raise RuntimeError(
                f"request {r.rid} needs {blocks_needed(len(r.context), self.pcfg.block_size)}"
                f" blocks; pool has {self.allocator.n_free}/{self.pcfg.n_blocks} free"
            )
        return bool(self._queue)

    # ------------------------------------------------------- admission/prefill
    def _refill_slots(self) -> int:
        """FCFS admission: head of queue enters iff a slot is free and the
        pool can hold its full current context. Returns #admitted."""
        admitted = 0
        while self._queue and self._slot_free:
            r = self._queue[0]
            blocks = self.allocator.alloc(blocks_needed(len(r.context),
                                                        self.pcfg.block_size))
            if blocks is None:
                break
            self._queue.popleft()
            r.blocks, r.slot, r.state = blocks, self._slot_free.pop(), RequestState.RUNNING
            self._running.append(r)
            self._prefill(r)
            admitted += 1
        self.stats["peak_occupancy"] = max(self.stats["peak_occupancy"],
                                           self.allocator.occupancy)
        return admitted

    def _prefill(self, r: Request) -> None:
        """Chunked prefill of r.context into r.blocks; samples the first
        token from the REAL last-position logits unless the request is a
        re-admitted preemption (its next_token is already decided)."""
        chunk = self.sc.prefill_chunk
        plen = len(r.context)
        toks = np.zeros((1, -(-plen // chunk) * chunk), np.int32)
        toks[0, :plen] = r.context
        bt = self._bt_row(r)[None]
        logits = None
        for start in range(0, plen, chunk):
            self.pools, logits = self._prefill_fn(
                self.params, self.pools, bt, jnp.asarray(toks[:, start:start + chunk]),
                jnp.int32(start), jnp.int32(plen),
            )
            self.stats["prefill_chunks"] += 1
        if r.next_token is None:
            r.next_token = self._sample(logits, r)
            r.generated.append(r.next_token)

    # ---------------------------------------------------------------- decode
    def _decode_once(self, results: dict) -> None:
        for r in list(self._running):
            if r.state is RequestState.RUNNING:  # not preempted by an earlier _grow
                self._grow(r)
        if not self._running:
            return
        bt = np.full((self.slots, self.pcfg.max_blocks_per_seq), -1, np.int32)
        cl = np.zeros((self.slots,), np.int32)
        tk = np.zeros((self.slots, 1), np.int32)
        for r in self._running:
            bt[r.slot] = self._bt_row(r)
            cl[r.slot] = len(r.context) + 1  # incoming token included
            tk[r.slot, 0] = r.next_token
        self.pools, logits = self._decode_fn(
            self.params, self.pools, jnp.asarray(bt), jnp.asarray(cl), jnp.asarray(tk)
        )
        self.stats["decode_steps"] += 1
        self.stats["decode_slot_tokens"] += len(self._running)
        for r in self._running:
            r.context.append(r.next_token)
            r.next_token = self._sample(logits[r.slot], r)
            r.generated.append(r.next_token)
        for r in [r for r in self._running if r.done]:
            self._finish(r, results)

    def _grow(self, r: Request) -> None:
        """Guarantee a block for position len(r.context) (the token about to
        be written), evicting the youngest other request if the pool is dry."""
        if blocks_needed(len(r.context) + 1, self.pcfg.block_size) <= len(r.blocks):
            return
        while True:
            got = self.allocator.alloc(1)
            if got is not None:
                r.blocks.extend(got)
                self.stats["peak_occupancy"] = max(self.stats["peak_occupancy"],
                                                   self.allocator.occupancy)
                return
            victims = [v for v in self._running if v is not r]
            if not victims:
                raise RuntimeError(
                    f"request {r.rid} cannot grow: pool of {self.pcfg.n_blocks} "
                    "blocks is exhausted and there is nothing left to preempt"
                )
            self._preempt(victims[-1])

    def _preempt(self, r: Request) -> None:
        self.allocator.free(r.blocks)
        r.blocks = []
        self._slot_free.append(r.slot)
        r.slot = -1
        r.state = RequestState.PREEMPTED
        self._running.remove(r)
        self._queue.appendleft(r)  # front: preserves FCFS completion order
        self.stats["preemptions"] += 1

    def _finish(self, r: Request, results: dict) -> None:
        self.allocator.free(r.blocks)
        r.blocks = []
        self._slot_free.append(r.slot)
        r.slot = -1
        r.state = RequestState.FINISHED
        self._running.remove(r)
        results[r.rid] = r.output()

    # ----------------------------------------------------------------- misc
    def _bt_row(self, r: Request) -> np.ndarray:
        row = np.full((self.pcfg.max_blocks_per_seq,), -1, np.int32)
        row[: len(r.blocks)] = r.blocks
        return row

    def _sample(self, logits: jax.Array, r: Request) -> int:
        if self.sc.temperature > 0:
            r.key, sub = jax.random.split(r.key)
            return int(jax.random.categorical(sub, logits / self.sc.temperature))
        return int(jnp.argmax(logits))
