"""Continuous-batching scheduler over the paged K-Means KV cache.

Request lifecycle::

    QUEUED --admit (FCFS, free-block budget)--> RUNNING (prefilling)
    RUNNING --prompt fully written--> RUNNING (decoding)
    RUNNING --EOS / max-tokens--> FINISHED      (slot + blocks freed,
    RUNNING --pool exhausted--> PREEMPTED        refilled next step)
    PREEMPTED --requeued at the front--> QUEUED  (recompute on re-admission)

The hot loop is ONE jitted *packed* step of fixed shape: every scheduler
iteration assembles a flat batch of exactly ``token_budget`` token rows —
one decode token for every decoding slot (reserved FIRST, so admissions can
never starve running requests) plus as many prefill tokens from admitting
requests as fit in the remaining budget — with per-token (slot, position)
vectors. Each row writes its token's KV into the slot's blocks and attends
through the slot's block table; rows of the same request are causally
ordered by position within the same forward (write-then-attend), so a
prefill segment and the step's decode tokens ride in one ``model.apply``.
Unused rows carry position -1 and are masked out of both the scatter and the
attention. There is no separate prefill function and no batch=1 serial
admission phase: prefill/decode interference is gone by construction, and a
step's cost is always exactly ``token_budget`` tokens.

Preemption is by eviction: when a decoding sequence cannot get a block, the
most recently admitted *other* request is evicted (blocks freed, requeued
front, prefill progress reset) and recomputed later — deterministic K-Means
assignment makes the recomputed KV bit-identical, so preemption never
changes tokens.

Sampling happens host-side from the logits the packed step returns (greedy
or per-request-keyed temperature): a decoding request samples from its
decode row; a request whose LAST prompt token was written this step samples
its first token from that row — per-request keys make sampled outputs
independent of how steps were packed.
"""

from __future__ import annotations

import dataclasses
import enum
from collections import deque

import jax
import jax.numpy as jnp
import numpy as np

from repro.serving.paged_cache import (
    BlockAllocator,
    PagedCacheConfig,
    attach_tables,
    blocks_needed,
    detach_tables,
)

__all__ = ["RequestState", "Request", "Scheduler"]


class RequestState(enum.Enum):
    QUEUED = "queued"
    RUNNING = "running"
    PREEMPTED = "preempted"
    FINISHED = "finished"


@dataclasses.dataclass
class Request:
    rid: int
    prompt: list[int]
    max_new_tokens: int
    eos_id: int | None
    key: jax.Array  # per-request sampling key (temperature > 0)
    state: RequestState = RequestState.QUEUED
    context: list[int] = dataclasses.field(default_factory=list)  # tokens fed
    generated: list[int] = dataclasses.field(default_factory=list)
    prefilled: int = 0  # context tokens written to the cache so far
    next_token: int | None = None  # sampled, not yet fed to the model
    blocks: list[int] = dataclasses.field(default_factory=list)
    slot: int = -1

    @property
    def decoding(self) -> bool:
        """Context fully written: the next packed step feeds next_token."""
        return self.prefilled >= len(self.context)

    @property
    def done(self) -> bool:
        if len(self.generated) >= self.max_new_tokens:
            return True
        return self.eos_id is not None and bool(self.generated) and \
            self.generated[-1] == self.eos_id

    def output(self) -> list[int]:
        """Exactly max_new_tokens tokens (eos-padded after early stop)."""
        out = list(self.generated[: self.max_new_tokens])
        pad = self.eos_id if self.eos_id is not None else 0
        return out + [pad] * (self.max_new_tokens - len(out))


class Scheduler:
    """Owns the block pool, the allocator, and the single jitted packed step.

    ``sc`` is a :class:`repro.serving.engine.ServeConfig`; its ``cache_len``
    bounds per-request context (prompt + generated), ``block_size`` /
    ``n_blocks`` size the pool (n_blocks=0 -> slots * blocks-per-request, a
    no-preemption default; pass a smaller pool to exercise preemption), and
    ``token_budget`` fixes the packed step's row count (0 -> slots +
    prefill_chunk; must be >= slots so every decoding slot always fits).
    """

    def __init__(self, model, params, sc, slots: int = 8):
        if not model.supports_paged_cache():
            raise ValueError(f"family {model.cfg.family} cannot use the paged scheduler")
        self.model, self.params, self.sc, self.slots = model, params, sc, slots
        self.token_budget = sc.token_budget or (slots + sc.prefill_chunk)
        if self.token_budget < slots:
            raise ValueError(
                f"token_budget {self.token_budget} < slots {slots}: decode "
                "reservation needs one row per slot"
            )
        max_blk = blocks_needed(sc.cache_len, sc.block_size)
        n_blocks = sc.n_blocks or slots * max_blk
        self.pcfg = PagedCacheConfig(block_size=sc.block_size, n_blocks=n_blocks,
                                     max_blocks_per_seq=max_blk)
        self.pools = model.init_caches(
            slots, sc.cache_len, jnp.dtype(sc.cache_dtype), quantized=sc.kv_quant,
            layout="paged", block_size=sc.block_size, n_blocks=n_blocks,
        )
        self.allocator = BlockAllocator(n_blocks)
        self._queue: deque[Request] = deque()
        self._running: list[Request] = []
        self._slot_free = list(range(slots - 1, -1, -1))
        self._next_rid = 0
        self.stats = {"packed_steps": 0, "decode_steps": 0, "prefill_chunks": 0,
                      "mixed_steps": 0, "preemptions": 0, "peak_occupancy": 0.0,
                      "decode_slot_tokens": 0, "prefill_tokens": 0,
                      "packed_tokens": 0}
        self._packed_fn = jax.jit(self._make_packed_step())

    # ------------------------------------------------------------------ jit
    def _make_packed_step(self):
        model = self.model

        def packed_step(params, pools, bt, slot_ids, positions, ctx, tokens):
            """The unified token-budget forward: tokens/positions/ctx/slot_ids
            are flat (T,) vectors (position -1 = unused row), bt is the
            per-SLOT (slots, max_blk) block-table matrix. Row t writes
            tokens[t] at positions[t] into slot_ids[t]'s blocks and attends
            to that slot's context up to positions[t]; returns per-row
            next-token logits (T, vocab)."""
            caches = attach_tables(pools, bt, ctx, model.cfg.n_layers,
                                   model.cfg.scan_layers, token_slots=slot_ids)
            out = model.apply(params, {"tokens": tokens[:, None]},
                              positions=positions[:, None], caches=caches)
            return detach_tables(out.caches), out.logits[:, 0, : model.cfg.vocab_size]

        return packed_step

    # ----------------------------------------------------------------- host
    def submit(self, prompt: list[int], max_new_tokens: int,
               eos_id: int | None = None, seed: int = 0,
               salt: int | None = None) -> int:
        """``salt`` individualizes the sampling key within one batch of
        submissions (the engine passes the request's index) so a given
        (seed, request set) resamples identically across generate calls."""
        if not prompt:
            raise ValueError("empty prompt (nothing to prefill)")
        if len(prompt) + max_new_tokens > self.pcfg.max_context:
            raise ValueError(
                f"prompt({len(prompt)}) + max_new({max_new_tokens}) exceeds "
                f"cache_len {self.pcfg.max_context}"
            )
        rid = self._next_rid
        self._next_rid += 1
        r = Request(rid=rid, prompt=list(prompt), max_new_tokens=max_new_tokens,
                    eos_id=eos_id,
                    key=jax.random.PRNGKey(seed * 100_003 + (rid if salt is None else salt)),
                    context=list(prompt))
        self._queue.append(r)
        return rid

    def run(self) -> dict[int, list[int]]:
        """Drain queue + running set; returns {rid: generated tokens}."""
        results: dict[int, list[int]] = {}
        while self.step(results):
            pass
        return results

    def step(self, results: dict[int, list[int]]) -> bool:
        """One scheduler iteration: refill slots from the queue, retire
        finished requests, run one packed token-budget forward over all
        running slots. Finished outputs are added to ``results``. Returns
        True while work remains — online drivers (bench_serving) interleave
        ``submit`` between steps.
        """
        admitted = self._refill_slots()
        for r in [r for r in self._running if r.done]:
            self._finish(r, results)
        if self._running:
            self._packed_once(results)
            return True
        if self._queue and not admitted:  # head can never fit: whole pool is free
            r = self._queue[0]
            raise RuntimeError(
                f"request {r.rid} needs {blocks_needed(len(r.context), self.pcfg.block_size)}"
                f" blocks; pool has {self.allocator.n_free}/{self.pcfg.n_blocks} free"
            )
        return bool(self._queue)

    # ------------------------------------------------------------- admission
    def _refill_slots(self) -> int:
        """FCFS admission: head of queue enters iff a slot is free and the
        pool can hold its full current context. Returns #admitted. Admission
        only binds a slot + blocks; the prompt is written by the packed steps
        (alongside everyone else's decode tokens), never serially."""
        admitted = 0
        while self._queue and self._slot_free:
            r = self._queue[0]
            blocks = self.allocator.alloc(blocks_needed(len(r.context),
                                                        self.pcfg.block_size))
            if blocks is None:
                break
            self._queue.popleft()
            r.blocks, r.slot, r.state = blocks, self._slot_free.pop(), RequestState.RUNNING
            r.prefilled = 0
            self._running.append(r)
            admitted += 1
        self.stats["peak_occupancy"] = max(self.stats["peak_occupancy"],
                                           self.allocator.occupancy)
        return admitted

    # ------------------------------------------------------------ packed step
    def _packed_once(self, results: dict) -> None:
        """Assemble and run one token-budget forward.

        Budget policy: decode rows FIRST (one per decoding slot — a step can
        never stall decode to admit), then prefill segments FCFS over the
        remaining budget (a request's segment is its next unwritten context
        tokens, clipped to what fits; large prompts span several steps).
        """
        t_budget = self.token_budget
        # decode reservation: guarantee a block for each incoming token (may
        # preempt — victims leave self._running, including prefilling ones)
        for r in list(self._running):
            if r.state is RequestState.RUNNING and r.decoding:
                self._grow(r)
        if not self._running:
            return
        decoders = [r for r in self._running if r.decoding]
        segments: list[tuple[Request, int, int]] = []  # (request, start, n)
        budget = t_budget - len(decoders)
        for r in self._running:
            if budget <= 0:
                break
            if not r.decoding:
                n = min(budget, len(r.context) - r.prefilled)
                segments.append((r, r.prefilled, n))
                budget -= n

        max_blk = self.pcfg.max_blocks_per_seq
        bt = np.full((self.slots, max_blk), -1, np.int32)
        slot_ids = np.zeros((t_budget,), np.int32)
        pos = np.full((t_budget,), -1, np.int32)
        tok = np.zeros((t_budget,), np.int32)
        for r in self._running:
            bt[r.slot] = self._bt_row(r)
        row = 0
        decode_row: dict[int, int] = {}
        for r in decoders:
            slot_ids[row], pos[row], tok[row] = r.slot, len(r.context), r.next_token
            decode_row[r.rid] = row
            row += 1
        last_row: dict[int, int] = {}
        for r, start, n in segments:
            sl = slice(row, row + n)
            slot_ids[sl] = r.slot
            pos[sl] = np.arange(start, start + n)
            tok[sl] = r.context[start : start + n]
            last_row[r.rid] = row + n - 1
            row += n
        ctx = pos + 1  # write/attend horizon per row (-1 rows stay invalid)

        self.pools, logits = self._packed_fn(
            self.params, self.pools, jnp.asarray(bt), jnp.asarray(slot_ids),
            jnp.asarray(pos), jnp.asarray(ctx), jnp.asarray(tok),
        )

        st = self.stats
        st["packed_steps"] += 1
        st["packed_tokens"] += row
        st["decode_slot_tokens"] += len(decoders)
        st["prefill_tokens"] += sum(n for _, _, n in segments)
        st["prefill_chunks"] += len(segments)
        if decoders:
            st["decode_steps"] += 1
        if decoders and segments:
            st["mixed_steps"] += 1

        for r in decoders:
            r.context.append(r.next_token)
            r.prefilled += 1  # the decode row wrote it to the cache
            r.next_token = self._sample(logits[decode_row[r.rid]], r)
            r.generated.append(r.next_token)
        for r, start, n in segments:
            r.prefilled = start + n
            if r.decoding and r.next_token is None:
                # the prompt's real last token was in this step: its logits
                # row is the first sampled token (a re-admitted preemption
                # keeps its already-decided next_token instead)
                r.next_token = self._sample(logits[last_row[r.rid]], r)
                r.generated.append(r.next_token)
        for r in [r for r in self._running if r.done]:
            self._finish(r, results)

    def _grow(self, r: Request) -> None:
        """Guarantee a block for position len(r.context) (the token about to
        be written), evicting the youngest other request if the pool is dry."""
        if blocks_needed(len(r.context) + 1, self.pcfg.block_size) <= len(r.blocks):
            return
        while True:
            got = self.allocator.alloc(1)
            if got is not None:
                r.blocks.extend(got)
                self.stats["peak_occupancy"] = max(self.stats["peak_occupancy"],
                                                   self.allocator.occupancy)
                return
            victims = [v for v in self._running if v is not r]
            if not victims:
                raise RuntimeError(
                    f"request {r.rid} cannot grow: pool of {self.pcfg.n_blocks} "
                    "blocks is exhausted and there is nothing left to preempt"
                )
            self._preempt(victims[-1])

    def _preempt(self, r: Request) -> None:
        self.allocator.free(r.blocks)
        r.blocks = []
        self._slot_free.append(r.slot)
        r.slot = -1
        r.prefilled = 0  # re-admission rewrites the whole context
        r.state = RequestState.PREEMPTED
        self._running.remove(r)
        self._queue.appendleft(r)  # front: preserves FCFS completion order
        self.stats["preemptions"] += 1

    def _finish(self, r: Request, results: dict) -> None:
        self.allocator.free(r.blocks)
        r.blocks = []
        self._slot_free.append(r.slot)
        r.slot = -1
        r.state = RequestState.FINISHED
        self._running.remove(r)
        results[r.rid] = r.output()

    # ----------------------------------------------------------------- misc
    def _bt_row(self, r: Request) -> np.ndarray:
        row = np.full((self.pcfg.max_blocks_per_seq,), -1, np.int32)
        row[: len(r.blocks)] = r.blocks
        return row

    def _sample(self, logits: jax.Array, r: Request) -> int:
        if self.sc.temperature > 0:
            r.key, sub = jax.random.split(r.key)
            return int(jax.random.categorical(sub, logits / self.sc.temperature))
        return int(jnp.argmax(logits))
