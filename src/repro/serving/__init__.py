"""Serving subsystem: paged K-Means KV cache + continuous-batching scheduler
with prefix sharing and speculative decoding.

See serving/README.md for the block layout, scheduler states, int4 format,
and the draft-propose / target-verify loop.
"""

from repro.serving.engine import ServeConfig, ServingEngine, make_prefill_step, make_serve_step
from repro.serving.paged_cache import BlockAllocator, PagedCacheConfig
from repro.serving.scheduler import Request, RequestState, Scheduler
from repro.serving.speculative import (
    DEFAULT_DRAFT_SPEC,
    DraftRunner,
    SpeculativeConfig,
    greedy_verify,
)

__all__ = [
    "ServeConfig",
    "ServingEngine",
    "make_prefill_step",
    "make_serve_step",
    "BlockAllocator",
    "PagedCacheConfig",
    "Request",
    "RequestState",
    "Scheduler",
    "SpeculativeConfig",
    "DraftRunner",
    "greedy_verify",
    "DEFAULT_DRAFT_SPEC",
]
