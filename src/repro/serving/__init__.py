"""Quantized serving engine: prefill/decode with batched requests."""

from repro.serving.engine import ServeConfig, ServingEngine, make_prefill_step, make_serve_step

__all__ = ["ServeConfig", "ServingEngine", "make_prefill_step", "make_serve_step"]
