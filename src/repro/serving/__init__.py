"""Serving subsystem: paged K-Means KV cache + continuous-batching scheduler.

See serving/README.md for the block layout, scheduler states and int4 format.
"""

from repro.serving.engine import ServeConfig, ServingEngine, make_prefill_step, make_serve_step
from repro.serving.paged_cache import BlockAllocator, PagedCacheConfig
from repro.serving.scheduler import Request, RequestState, Scheduler

__all__ = [
    "ServeConfig",
    "ServingEngine",
    "make_prefill_step",
    "make_serve_step",
    "BlockAllocator",
    "PagedCacheConfig",
    "Request",
    "RequestState",
    "Scheduler",
]
