"""Serving subsystem: paged K-Means KV cache + continuous-batching scheduler
with prefix sharing, speculative decoding, and first-class telemetry.

See serving/README.md for the block layout, scheduler states, int4 format,
the draft-propose / target-verify loop, and the observability metric names.
"""

from repro.serving.engine import ServeConfig, ServingEngine, make_prefill_step, make_serve_step
from repro.serving.paged_cache import BlockAllocator, PagedCacheConfig
from repro.serving.scheduler import Request, RequestState, Scheduler
from repro.serving.speculative import (
    DEFAULT_DRAFT_SPEC,
    DraftRunner,
    SpeculativeConfig,
    greedy_verify,
)
from repro.serving.telemetry import (
    Counter,
    Gauge,
    Histogram,
    MetricsRegistry,
    NullTelemetry,
    StreamingStats,
    Telemetry,
    TelemetryConfig,
    linear_buckets,
    log_buckets,
    make_telemetry,
)

__all__ = [
    "ServeConfig",
    "ServingEngine",
    "make_prefill_step",
    "make_serve_step",
    "BlockAllocator",
    "PagedCacheConfig",
    "Request",
    "RequestState",
    "Scheduler",
    "SpeculativeConfig",
    "DraftRunner",
    "greedy_verify",
    "DEFAULT_DRAFT_SPEC",
    "Counter",
    "Gauge",
    "Histogram",
    "MetricsRegistry",
    "StreamingStats",
    "Telemetry",
    "TelemetryConfig",
    "NullTelemetry",
    "make_telemetry",
    "log_buckets",
    "linear_buckets",
]
