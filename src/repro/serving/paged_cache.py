"""Paged K-Means KV cache: global block pool + host-side block allocator.

Layout (per attention layer, stacked over L by ``Model.init_caches``):

  bf16 pool : pages_k / pages_v           (n_blocks, block_size, KV, hd)
  int4 pool : pages_k_idx / pages_v_idx   (n_blocks, block_size, KV, hd//2) u8
              pages_k_scale / pages_v_scale (n_blocks, block_size, KV, 1) f32
              kv_codebook                 (16,) f32 sorted K-Means centroids

Token position ``p`` of a request lives at pool slot
``(block_table[p // block_size], p % block_size)``. Block tables and valid
context lengths are *per-call* arguments, attached to the pool tree right
before ``model.apply`` (``attach_tables``) and stripped from the returned
caches (``detach_tables``) — the pool is the only persistent device state,
so prefill (batch=1) and batched decode share it functionally.

The allocator is deliberately host-side Python (vLLM-style): block churn is
a few ints per step and per-request bookkeeping (alloc on growth, free on
finish/preemption) is control flow the scheduler owns anyway.

Blocks are **refcounted** so one physical block can back the same token
prefix in many requests (prefix sharing): ``alloc`` hands out blocks at
refcount 1, ``incref`` adds an alias, ``free`` decrements and only recycles
at refcount 0. A *full* block whose content hash has been ``register``-ed
is not recycled immediately when its refcount drops to 0 — it parks in an
LRU of cached prefix blocks, stays matchable via ``lookup``, and is only
evicted (hash unregistered, returned to the free list) when ``alloc`` runs
out of truly-free blocks. Content identity is the **chain hash** of
(pool/layer-set/quant-policy seed, token ids of every block up to and
including this one) — see ``prefix_seed`` / ``chain_hash``; identical chain
hash implies an identical token prefix, and deterministic K-Means writes
make the stored KV bit-identical, so aliasing is exact, not approximate.
"""

from __future__ import annotations

import dataclasses
import hashlib
from collections import OrderedDict

import jax
import jax.numpy as jnp
import numpy as np

__all__ = ["PagedCacheConfig", "BlockAllocator", "CachePolicy", "attach_tables",
           "detach_tables", "blocks_needed", "chain_hash", "prefix_seed",
           "copy_blocks", "release_horizon", "windowed_block_cap",
           "recurrent_state_keys", "zero_state_slot", "restore_state_slot",
           "split_step_extras"]

_TABLE_KEYS = ("block_tables", "ctx_lens", "token_slots")

# per-slot recurrent state arrays (mamba / RG-LRU): everything the scheduler
# must zero on (re-)admission and the draft must snapshot/restore on rollback.
# "state_codebook" is static (shared centroids) and deliberately absent.
_STATE_KEYS = ("h", "conv", "h_idx", "h_scale")


@dataclasses.dataclass(frozen=True)
class CachePolicy:
    """Per-layer cache descriptor. One of three kinds:

    * ``paged_kv`` — full-attention KV in the shared refcounted block pool
      (today's path). Cost: ``blocks_needed(context)`` blocks. Prefix sharing
      and speculation both compose.
    * ``windowed_paged`` — paged KV for a sliding-window layer: blocks whose
      every token has fallen out of ``window`` are freed back to the pool, so
      steady-state cost per request is capped at
      ``ceil(window / block_size) + 1`` blocks. Speculation composes; prefix
      sharing is disabled (an aliased mid-context block may already be freed
      by one holder while another still needs it... the registry is simply
      gated off when any windowed layer is present).
    * ``recurrent`` — constant-size Mamba / RG-LRU state: zero blocks, one
      pinned state slot per request. Speculative verify composes via per-step
      state trajectories (the packed step returns them as extras) plus a
      corrective commit on partial acceptance; preemption zeroes the slot and
      replays. Prefix sharing does not apply (no blocks to alias).
    """

    kind: str  # "paged_kv" | "windowed_paged" | "recurrent"
    window: int = 0  # windowed_paged only: the layer's sliding window

    def __post_init__(self):
        if self.kind not in ("paged_kv", "windowed_paged", "recurrent"):
            raise ValueError(f"unknown cache policy kind {self.kind!r}")
        if self.kind == "windowed_paged" and self.window <= 0:
            raise ValueError("windowed_paged policy needs window > 0")


def release_horizon(policies) -> int:
    """The window W such that blocks wholly below ``prefilled - W + 1`` can
    be freed, or 0 when nothing may ever be freed. Block ids are shared by
    every layer's pool, so one full-attention (``paged_kv``) layer pins the
    whole table; otherwise the *minimum* window over windowed layers is
    conservative for all of them. Pure-recurrent stacks hold no blocks at
    all (also 0: there is nothing to release)."""
    if any(p.kind == "paged_kv" for p in policies):
        return 0
    windows = [p.window for p in policies if p.kind == "windowed_paged"]
    return min(windows) if windows else 0


def windowed_block_cap(window: int, block_size: int) -> int:
    """Steady-state live blocks per request for a windowed layer: the window
    can straddle ``ceil(window / block_size)`` blocks plus the partially
    written block the decode head is growing into."""
    return blocks_needed(window, block_size) + 1


def recurrent_state_keys(layer: dict) -> list[str]:
    """The per-slot state arrays of one (possibly scanned) cache layer dict —
    empty for paged KV layers, which makes every helper below a no-op on
    them."""
    return [k for k in _STATE_KEYS if k in layer]


def zero_state_slot(pools, slot):
    """Zero one scheduler slot's recurrent state across every layer (new or
    re-admitted occupant: prefill replays from position 0). jit-able; paged
    pool arrays pass through untouched."""

    def z(layer, scanned):
        out = dict(layer)
        for k in recurrent_state_keys(layer):
            v = layer[k]
            out[k] = v.at[:, slot].set(0) if scanned else v.at[slot].set(0)
        return out

    if isinstance(pools, dict):
        return z(pools, True)
    return [z(layer, False) for layer in pools]


def restore_state_slot(pools, snapshot, slot):
    """Copy one slot's recurrent state from ``snapshot`` (an earlier pools
    tree) back into ``pools`` — the draft runner's speculative rollback for
    recurrent layers. jit-able."""

    def r(layer, snap, scanned):
        out = dict(layer)
        for k in recurrent_state_keys(layer):
            if scanned:
                out[k] = layer[k].at[:, slot].set(snap[k][:, slot])
            else:
                out[k] = layer[k].at[slot].set(snap[k][slot])
        return out

    if isinstance(pools, dict):
        return r(pools, snapshot, True)
    return [r(layer, snap, False) for layer, snap in zip(pools, snapshot)]


def split_step_extras(caches):
    """Split a packed step's returned caches into (persistent pools, per-step
    extras). Recurrent layers in the packed layout emit transient ``*_steps``
    trajectories (state after each grid cell) alongside the optimistically
    scattered pool state; the scheduler needs them only for the corrective
    commit on partial speculative acceptance, so they never persist."""

    def split(layer):
        pool = {k: v for k, v in layer.items() if not k.endswith("_steps")}
        steps = {k: v for k, v in layer.items() if k.endswith("_steps")}
        return pool, steps

    if isinstance(caches, dict):
        return split(caches)
    pairs = [split(layer) for layer in caches]
    return [p for p, _ in pairs], [s for _, s in pairs]


@dataclasses.dataclass(frozen=True)
class PagedCacheConfig:
    """Pool geometry. max context per request = block_size * max_blocks_per_seq."""

    block_size: int = 16
    n_blocks: int = 256  # per-layer pool size (shared by all requests)
    max_blocks_per_seq: int = 16

    @property
    def max_context(self) -> int:
        return self.block_size * self.max_blocks_per_seq


def blocks_needed(n_tokens: int, block_size: int) -> int:
    return -(-n_tokens // block_size)


def prefix_seed(**pool_identity) -> bytes:
    """Root of the chain hash: two pools share prefix blocks only if their
    layer-set and quantization policy agree (the scheduler seeds with model
    family / layer count / KV geometry / kv_quant / cache dtype / block
    size), so a hash can never alias blocks with incompatible contents."""
    rep = repr(sorted(pool_identity.items())).encode()
    return hashlib.blake2b(rep, digest_size=16).digest()


def chain_hash(parent: bytes, tokens) -> bytes:
    """Hash of one full block's identity: parent chain hash (covering every
    earlier token) + this block's token ids. KV at position p depends on ALL
    tokens <= p, which is exactly what the chain covers."""
    h = hashlib.blake2b(digest_size=16)
    h.update(parent)
    h.update(np.asarray(list(tokens), np.int64).tobytes())
    return h.digest()


class BlockAllocator:
    """Refcounted free-list allocator over the pool's block ids (all layers
    share ids: logical block b maps to pool slot b in every layer's pool).

    A block is in exactly one of three states:

      free    refcount 0, on the free list, contents meaningless
      live    refcount >= 1 (one ref per holding request)
      cached  refcount 0 but ``register``-ed under a prefix hash: parked in
              an LRU, still returned by ``lookup`` (revive via ``incref``),
              evicted oldest-first when ``alloc`` needs the space

    ``n_free`` counts *allocatable* blocks (free + cached): admission
    decisions must see cached prefixes as reclaimable, or a warm cache would
    refuse traffic it can serve.
    """

    def __init__(self, n_blocks: int, prefix_cache: bool = False,
                 telemetry=None):
        self.n_blocks = n_blocks
        self.prefix_cache = prefix_cache
        self.evictions = 0  # cached prefix blocks reclaimed under pressure
        self.blocks_allocated = 0  # running total, blocks handed out by alloc
        self.blocks_freed = 0  # running total, refs recycled to free/cached
        self._free = list(range(n_blocks - 1, -1, -1))
        self._ref = [0] * n_blocks
        self._hash_to_block: dict[bytes, int] = {}
        self._block_hash: dict[int, bytes] = {}
        self._lru: OrderedDict[int, None] = OrderedDict()  # oldest first
        if telemetry is not None and telemetry.enabled:
            # allocator state gauges read lazily at snapshot time; eviction
            # causes split into pressure (alloc ran dry) vs never (register
            # collisions stay private and free normally, so only pressure
            # evictions exist today — the counter names the cause explicitly)
            telemetry.gauge("serving_blocks_free", "truly-free blocks",
                            fn=lambda: len(self._free))
            telemetry.gauge("serving_blocks_cached", "cached prefix blocks (LRU)",
                            fn=lambda: len(self._lru))
            telemetry.gauge("serving_blocks_live",
                            "blocks held live (refcount >= 1)",
                            fn=lambda: self.n_blocks - self.n_free)
            self._c_evict = telemetry.counter(
                "serving_block_evictions_pressure",
                "cached prefix blocks reclaimed because alloc ran dry")
        else:
            self._c_evict = None

    @property
    def n_free(self) -> int:
        """Allocatable blocks: truly free + cached (evictable) prefix blocks."""
        return len(self._free) + len(self._lru)

    @property
    def n_cached(self) -> int:
        return len(self._lru)

    @property
    def occupancy(self) -> float:
        """Fraction of blocks held live (cached prefixes are reclaimable)."""
        return 1.0 - self.n_free / self.n_blocks

    def refcount(self, block_id: int) -> int:
        return self._ref[block_id]

    def alloc(self, n: int) -> list[int] | None:
        """n block ids at refcount 1, or None (allocation is all-or-nothing).
        Evicts cached prefix blocks (oldest first) only when the free list
        alone cannot cover the request."""
        if n <= 0:  # n=0 must NOT slice the whole free list ([-0:] == [:])
            return []
        if n > self.n_free:
            return None
        while len(self._free) < n:
            self._evict_one()
        got = self._free[-n:][::-1]
        del self._free[len(self._free) - n:]
        for b in got:
            self._ref[b] = 1
        self.blocks_allocated += n
        return got

    def free(self, ids: list[int]) -> None:
        """Drop one reference per id. The whole list is validated BEFORE any
        mutation — an out-of-range, already-free, or over-duplicated id
        raises and leaves the pool untouched (a silent double-free later
        hands one block to two requests; a partial decref on error would let
        a retry of the same list do the same)."""
        counts: dict[int, int] = {}
        for b in ids:
            if not isinstance(b, (int, np.integer)) or not 0 <= b < self.n_blocks:
                raise ValueError(
                    f"free of block {b!r}: out of range for pool of {self.n_blocks}"
                )
            counts[b] = counts.get(b, 0) + 1
        for b, c in counts.items():
            if self._ref[b] < c:
                raise ValueError(
                    f"free of block {b}: {c} frees but {self._ref[b]} refs "
                    "held (double free?)"
                )
        for b in ids:
            self._ref[b] -= 1
            if self._ref[b] == 0:
                self.blocks_freed += 1
                if b in self._block_hash:  # registered prefix: park, matchable
                    self._lru[b] = None
                    self._lru.move_to_end(b)
                else:
                    self._free.append(b)

    def truncate(self, ids: list[int], keep: int) -> list[int]:
        """Rollback: drop one reference from every block past the first
        ``keep`` (tail-first, so cached chains age leaf-before-root) and
        return the kept prefix. The speculative-decoding path uses this to
        free blocks that held only rejected draft tokens; the freed suffix is
        validated exactly like ``free`` (a shared tail block is merely
        decref'd — the other holders keep it)."""
        if keep < 0:
            raise ValueError(f"truncate keep must be >= 0, got {keep}")
        self.free(list(reversed(ids[keep:])))
        return list(ids[:keep])

    def incref(self, block_id: int) -> None:
        """Add an alias to a live or cached block (never to a free one)."""
        if self._ref[block_id] == 0:
            if block_id not in self._lru:
                raise ValueError(f"incref of free block {block_id}")
            del self._lru[block_id]  # revive from the cached LRU
        self._ref[block_id] += 1

    def register(self, prefix_hash: bytes, block_id: int) -> bool:
        """Publish a live full block under its chain hash (first writer wins:
        a concurrent duplicate simply stays private and frees normally)."""
        if not self.prefix_cache:
            return False
        if self._ref[block_id] <= 0:
            raise ValueError(f"register of non-live block {block_id}")
        if prefix_hash in self._hash_to_block:
            return False
        if block_id in self._block_hash:
            raise ValueError(f"block {block_id} already registered")
        self._hash_to_block[prefix_hash] = block_id
        self._block_hash[block_id] = prefix_hash
        return True

    def lookup(self, prefix_hash: bytes) -> int | None:
        return self._hash_to_block.get(prefix_hash)

    def _evict_one(self) -> None:
        bid, _ = self._lru.popitem(last=False)  # oldest cached prefix block
        del self._hash_to_block[self._block_hash.pop(bid)]
        self._free.append(bid)
        self.evictions += 1
        if self._c_evict is not None:
            self._c_evict.add()


def copy_blocks(pools, src: jax.Array, dst: jax.Array):
    """Device-side block copy across every layer's pool arrays (the
    copy-on-write primitive): pool rows ``src[i]`` overwrite rows ``dst[i]``
    in every ``pages_*`` leaf. Scanned pools are a dict with a leading L
    axis (blocks on axis 1); unscanned pools are a list of per-layer dicts
    (blocks on axis 0). Returns the updated pool tree."""
    src = jnp.asarray(src, jnp.int32)
    dst = jnp.asarray(dst, jnp.int32)

    def cp(layer, blocks_axis):
        out = {}
        for k, v in layer.items():
            if k.startswith("pages_"):
                v = (v.at[:, dst].set(v[:, src]) if blocks_axis == 1
                     else v.at[dst].set(v[src]))
            out[k] = v
        return out

    if isinstance(pools, dict):
        return cp(pools, 1)
    return [cp(layer, 0) for layer in pools]


def attach_tables(pools, block_tables: jax.Array, ctx_lens: jax.Array,
                  n_layers: int, scan_layers: bool, token_slots=None):
    """Pool tree + per-call (B, max_blk)/(B,) tables -> apply-ready caches.

    Two layouts share this interface:

    * per-sequence (token_slots=None): batch row ``b`` is one sequence —
      ``block_tables[b]`` is its table, ``ctx_lens[b]`` its valid context.
      This is the prefill / classic decode layout.
    * packed (token_slots (G,)): batch row ``g`` is ONE SEGMENT — S
      contiguous tokens (S = 1: one token per row) — of scheduler slot
      ``token_slots[g]``; ``block_tables`` stays per *slot*
      (slots, max_blk) and ``ctx_lens`` is per segment row (G,). The
      per-row table gather (``block_tables[token_slots]``) happens
      device-side inside ``attention_apply``, once per segment — the
      token-budget mixed prefill+decode(+verify) step.

    Under ``scan_layers`` caches are scanned over a leading L axis, so the
    (identical) tables are broadcast per layer; unscanned models get the same
    arrays aliased into each layer dict.
    """
    bt = block_tables.astype(jnp.int32)
    cl = ctx_lens.astype(jnp.int32)
    extra = {"block_tables": bt, "ctx_lens": cl}
    if token_slots is not None:
        extra["token_slots"] = token_slots.astype(jnp.int32)
    if scan_layers:
        extra = {k: jnp.broadcast_to(v, (n_layers, *v.shape))
                 for k, v in extra.items()}
        return pools | extra
    return [layer | extra for layer in pools]


def detach_tables(caches):
    """Inverse of attach_tables: keep only the persistent pool arrays."""
    if isinstance(caches, list):
        return [{k: v for k, v in layer.items() if k not in _TABLE_KEYS}
                for layer in caches]
    return {k: v for k, v in caches.items() if k not in _TABLE_KEYS}
