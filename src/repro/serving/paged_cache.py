"""Paged K-Means KV cache: global block pool + host-side block allocator.

Layout (per attention layer, stacked over L by ``Model.init_caches``):

  bf16 pool : pages_k / pages_v           (n_blocks, block_size, KV, hd)
  int4 pool : pages_k_idx / pages_v_idx   (n_blocks, block_size, KV, hd//2) u8
              pages_k_scale / pages_v_scale (n_blocks, block_size, KV, 1) f32
              kv_codebook                 (16,) f32 sorted K-Means centroids

Token position ``p`` of a request lives at pool slot
``(block_table[p // block_size], p % block_size)``. Block tables and valid
context lengths are *per-call* arguments, attached to the pool tree right
before ``model.apply`` (``attach_tables``) and stripped from the returned
caches (``detach_tables``) — the pool is the only persistent device state,
so prefill (batch=1) and batched decode share it functionally.

The allocator is deliberately host-side Python (vLLM-style): block churn is
a few ints per step and per-request bookkeeping (alloc on growth, free on
finish/preemption) is control flow the scheduler owns anyway.
"""

from __future__ import annotations

import dataclasses

import jax
import jax.numpy as jnp

__all__ = ["PagedCacheConfig", "BlockAllocator", "attach_tables", "detach_tables",
           "blocks_needed"]

_TABLE_KEYS = ("block_tables", "ctx_lens", "token_slots")


@dataclasses.dataclass(frozen=True)
class PagedCacheConfig:
    """Pool geometry. max context per request = block_size * max_blocks_per_seq."""

    block_size: int = 16
    n_blocks: int = 256  # per-layer pool size (shared by all requests)
    max_blocks_per_seq: int = 16

    @property
    def max_context(self) -> int:
        return self.block_size * self.max_blocks_per_seq


def blocks_needed(n_tokens: int, block_size: int) -> int:
    return -(-n_tokens // block_size)


class BlockAllocator:
    """Free-list allocator over the pool's block ids (all layers share ids:
    logical block b maps to pool slot b in every layer's pool)."""

    def __init__(self, n_blocks: int):
        self.n_blocks = n_blocks
        self._free = list(range(n_blocks - 1, -1, -1))

    @property
    def n_free(self) -> int:
        return len(self._free)

    @property
    def occupancy(self) -> float:
        return 1.0 - len(self._free) / self.n_blocks

    def alloc(self, n: int) -> list[int] | None:
        """n block ids, or None (allocation is all-or-nothing)."""
        if n <= 0:  # n=0 must NOT slice the whole free list ([-0:] == [:])
            return []
        if n > len(self._free):
            return None
        got = self._free[-n:][::-1]
        del self._free[len(self._free) - n:]
        return got

    def free(self, ids: list[int]) -> None:
        self._free.extend(reversed(ids))


def attach_tables(pools, block_tables: jax.Array, ctx_lens: jax.Array,
                  n_layers: int, scan_layers: bool, token_slots=None):
    """Pool tree + per-call (B, max_blk)/(B,) tables -> apply-ready caches.

    Two layouts share this interface:

    * per-sequence (token_slots=None): batch row ``b`` is one sequence —
      ``block_tables[b]`` is its table, ``ctx_lens[b]`` its valid context.
      This is the prefill / classic decode layout.
    * packed (token_slots (T,)): batch row ``t`` is ONE TOKEN of scheduler
      slot ``token_slots[t]``; ``block_tables`` stays per *slot*
      (slots, max_blk) and ``ctx_lens`` is per token (T,). The per-row table
      gather (``block_tables[token_slots]``) happens device-side inside
      ``attention_apply`` — the token-budget mixed prefill+decode step.

    Under ``scan_layers`` caches are scanned over a leading L axis, so the
    (identical) tables are broadcast per layer; unscanned models get the same
    arrays aliased into each layer dict.
    """
    bt = block_tables.astype(jnp.int32)
    cl = ctx_lens.astype(jnp.int32)
    extra = {"block_tables": bt, "ctx_lens": cl}
    if token_slots is not None:
        extra["token_slots"] = token_slots.astype(jnp.int32)
    if scan_layers:
        extra = {k: jnp.broadcast_to(v, (n_layers, *v.shape))
                 for k, v in extra.items()}
        return pools | extra
    return [layer | extra for layer in pools]


def detach_tables(caches):
    """Inverse of attach_tables: keep only the persistent pool arrays."""
    if isinstance(caches, list):
        return [{k: v for k, v in layer.items() if k not in _TABLE_KEYS}
                for layer in caches]
    return {k: v for k, v in caches.items() if k not in _TABLE_KEYS}
