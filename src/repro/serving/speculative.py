"""Speculative decoding: low-bit K-Means draft model + multi-token verification.

KLLM's accuracy headroom at very low bit-widths makes a W3/A4 artifact of the
*same* model (one QuantSpec away) a nearly-free **draft model**: per serving
round, each decoding request drafts ``k`` greedy tokens with the cheap draft,
then the target model verifies all ``k + 1`` positions as ONE multi-token
segment through the scheduler's packed token-budget step — committing between
1 and ``k + 1`` tokens per target forward instead of exactly 1.

Division of labour:

* :class:`DraftRunner` (here) owns the draft model's state: a private paged
  KV pool with **static per-slot block tables** (slot ``s`` owns blocks
  ``[s*max_blk, (s+1)*max_blk)`` — no allocator, no sharing, rollback is a
  host-side counter rewind). ``propose`` catches the draft cache up on every
  context token it has not seen (a new admission replays its whole prompt;
  the draft never aliases the target's prefix cache), then drafts ``k``
  tokens autoregressively, one packed step per token.
* The **scheduler** (scheduler.py) builds each decoder's verify segment
  ``[next_token, d_1 .. d_k]`` at positions ``n .. n+k``, runs it through the
  same packed forward as everything else (consecutive grid cells: flat rows
  at ``seg_width=1`` — bit-identical shapes to non-speculative serving — or
  the S>1 paged-attention layout),
  and applies :func:`greedy_verify` to the per-position argmaxes. Rejected
  positions are **rolled back**: the cache rows they wrote are overwritten by
  the next (correct) writes before they can ever be attended (reads are
  gated by ``ctx_lens`` and per-token causal masks), and blocks holding only
  rejected tokens are freed (``BlockAllocator.truncate``).

Greedy verification is **exact**: token ``g_i = argmax`` of the target's
logits after consuming position ``i`` is, by construction, precisely the
token non-speculative greedy decoding would have produced given the same
prefix — accepted drafts merely reveal several such argmaxes per forward.
Speculative greedy output is therefore token-identical to ``speculative=None``
(asserted in tests/test_speculative.py and bench_serving --smoke), no matter
how bad the draft is; draft quality only moves the acceptance rate.

Temperature sampling needs the rejection-sampling acceptance rule; the
:func:`rejection_sample` hook documents the contract and raises until it is
implemented — the scheduler refuses ``temperature > 0`` up front.
"""

from __future__ import annotations

import dataclasses

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.qlinear import QLinearConfig
from repro.core.quantspec import QuantSpec
from repro.serving.paged_cache import (
    attach_tables,
    blocks_needed,
    detach_tables,
    restore_state_slot,
    split_step_extras,
    zero_state_slot,
)

__all__ = ["SpeculativeConfig", "DraftRunner", "greedy_verify", "rejection_sample",
           "make_packed_fn", "make_probed_packed_fn", "load_draft",
           "DEFAULT_DRAFT_SPEC"]


# Default draft policy: W3 K-Means weights everywhere except a W4 guard on
# the most CE-sensitive projection — benchmarks/bench_sensitivity.py ranks
# the projection classes by held-out CE impact under bit-width stress, and
# mlp/wi tops it (the guarded W3 draft beat both the unguarded and the
# down-proj-guarded variants there) — plus A4 activations and int4 K-Means
# draft KV (cheap draft cache state). ~25% smaller weight bytes than W4.
# Online Orizuru outlier detection is ON (the serving default since the
# outlier engine landed): better draft CE means higher acceptance, and the
# streaming/detection kernel keeps it one pass; greedy verification keeps
# serving token-identical regardless of draft quality.
DEFAULT_DRAFT_SPEC = QuantSpec(
    base=QLinearConfig(w_bits=3, a_bits=4, detection="dynamic",
                       outlier_frac=0.005),
    rules=[("mlp/wi", {"w_bits": 4})],
    kv_bits=4, kv_dtype="float32",
)


@dataclasses.dataclass(frozen=True)
class SpeculativeConfig:
    """``ServeConfig.speculative``: None = off, an instance = on.

    ``k``: draft tokens proposed per decoding request per round (the target
    verifies ``k + 1`` positions; commits 1..k+1 tokens). Each decoding
    request's packed-step reservation grows from 1 cell to ``k + 1`` cells.
    ``draft_artifact``: directory for ``repro.core.artifact.load_quantized``
    (the production path). Tests/benchmarks may instead hand the engine a
    built draft via ``ServingEngine(..., draft=(model, params))``.
    ``draft_token_budget``: rows of the draft's packed step (catch-up prefill
    throughput); 0 -> ``slots + 32``.
    """

    k: int = 3
    draft_artifact: str | None = None
    draft_token_budget: int = 0

    def __post_init__(self):
        if self.k < 1:
            raise ValueError(f"speculative k must be >= 1, got {self.k}")


def make_packed_fn(model):
    """The packed segment forward shared by the target scheduler and the
    draft runner. All arguments are fixed-shape per engine:

      slot_ids  (G,)    scheduler slot of each segment row
      positions (G, S)  absolute token positions (-1 = padded cell)
      ctx       (G,)    write/attend horizon per row (last valid pos + 1)
      tokens    (G, S)  token ids (garbage in padded cells)

    Row ``g`` writes its valid tokens' KV into ``slot_ids[g]``'s blocks and
    attends each token causally through that slot's block table (the S>1
    paged-attention layout: per-row block-table gather happens device-side in
    ``attention_apply``, one gather per *segment* rather than per token).
    Recurrent layers instead gather/scatter slot-major state by
    ``slot_ids`` — for them a slot must appear in at most one row with valid
    cells per call, and a row's valid cells must be a contiguous prefix.
    Returns (pools, logits (G, S, vocab), extras) where ``extras`` holds the
    recurrent layers' per-cell "*_steps" transients (empty dicts for pure
    KV stacks) — the scheduler uses them to rewind partially-accepted
    speculative rows (paged_cache.split_step_extras)."""

    def packed_step(params, pools, bt, slot_ids, positions, ctx, tokens):
        caches = attach_tables(pools, bt, ctx, model.cfg.n_layers,
                               model.cfg.scan_layers, token_slots=slot_ids)
        out = model.apply(params, {"tokens": tokens}, positions=positions,
                          caches=caches)
        pools, extras = split_step_extras(detach_tables(out.caches))
        return pools, out.logits[..., : model.cfg.vocab_size], extras

    return packed_step


def make_probed_packed_fn(model):
    """Quality-level packed forward: :func:`make_packed_fn`'s exact contract
    plus a 4th output — the flat ``{site/stat: value}`` dict of quant-health
    probes from ``core/numerics`` (one site per quantized projection per
    layer, in forward order).

    Pools, logits, and extras come from the UNTOUCHED scanned packed step —
    the same ops :func:`make_packed_fn` traces — so serving state and greedy
    tokens at the ``quality`` level are bit-identical to every other level
    by construction (asserted in tests/test_numerics.py). The probes ride a
    SECOND, probe-only forward whose outputs are discarded: a ``lax.scan``
    body cannot return per-iteration aux stats, so a scan-stacked model is
    unrolled for it (stacked ``params["blocks"]`` / layer pools unstacked
    per layer, exactly like ``model.unstack_for_capture``) and runs with
    ``scan_layers=False`` under an active probe collector, masked on
    ``positions >= 0`` so padded grid cells contribute zero to every stat.
    The duplicated forward is the sampled probe step's price (one extra
    forward every ``quality_sample_every`` steps); scan-stacked families
    with no unrolled variant (vlm) serve unprobed (empty dict). Only the
    ``quality`` telemetry level traces this function.
    """
    from repro.core import numerics as nx
    from repro.models.model import build

    cfg = model.cfg
    unroll = cfg.scan_layers and cfg.family != "vlm"
    umodel = build(dataclasses.replace(cfg, scan_layers=False)) if unroll else model
    n_layers = cfg.n_layers
    packed_step = make_packed_fn(model)

    def probed_step(params, pools, bt, slot_ids, positions, ctx, tokens):
        # authoritative outputs: the exact scanned packed step
        new_pools, logits, extras = packed_step(
            params, pools, bt, slot_ids, positions, ctx, tokens)
        if cfg.scan_layers and not unroll:
            # probes inside a scan body would leak tracers — serve unprobed
            return new_pools, logits, extras, {}
        if unroll:
            blocks = params["blocks"]
            params_u = {**params, "blocks": [
                jax.tree.map(lambda a, i=i: a[i], blocks)
                for i in range(n_layers)]}
            pools_u = [jax.tree.map(lambda a, i=i: a[i], pools)
                       for i in range(n_layers)]
        else:
            params_u, pools_u = params, pools
        caches = attach_tables(pools_u, bt, ctx, n_layers, False,
                               token_slots=slot_ids)
        mask = (positions >= 0).astype(jnp.float32)
        with nx.collect(mask=mask) as col:
            umodel.apply(params_u, {"tokens": tokens}, positions=positions,
                         caches=caches)
        return new_pools, logits, extras, col.out

    return probed_step


def greedy_verify(targets: list[int], drafts: list[int],
                  eos_id: int | None = None) -> list[int]:
    """Greedy acceptance rule. ``targets[i]`` is the target model's argmax
    after consuming verify position ``i`` (position 0 carries the committed
    ``next_token``, positions 1..k the drafts); ``len(targets) == k + 1``.

    Returns the **committed** tokens, in order: every leading target token
    that agrees with its draft (their KV writes are already valid), plus one
    final token — the first disagreement (the "correction"), the bonus token
    after a full match, or an EOS (absorbing: nothing is committed past it).
    Always commits at least one token; the last committed token is the
    request's new ``next_token`` (fed to the cache next round), the rest
    extend its context directly.
    """
    committed: list[int] = []
    for i, g in enumerate(targets):
        committed.append(int(g))
        if eos_id is not None and g == eos_id:
            break  # absorbing: later matches would decode past EOS
        if i >= len(drafts) or g != drafts[i]:
            break  # correction (or the bonus token after k acceptances)
    return committed


def rejection_sample(*_args, **_kw):
    """Temperature-sampling acceptance hook (NOT yet implemented).

    Contract (Leviathan-style speculative sampling): accept draft ``d_i``
    with probability ``min(1, p_target(d_i) / p_draft(d_i))``; on rejection
    sample the correction from the residual ``max(0, p_target - p_draft)``
    renormalized, which keeps the committed stream distributed exactly as
    target-only sampling. Requires the draft's per-position probabilities to
    ride along with the proposed tokens. Until then the scheduler only
    accepts ``temperature == 0`` speculative configs.
    """
    raise NotImplementedError(
        "speculative decoding with temperature > 0 needs the "
        "rejection-sampling acceptance rule (accept d_i w.p. "
        "min(1, p_target/p_draft), resample the correction from the "
        "residual); only greedy verification is implemented — serve with "
        "temperature=0 or speculative=None"
    )


def load_draft(directory: str):
    """Load a draft artifact -> (model, params, spec) for the scheduler."""
    from repro.core.artifact import load_quantized  # lazy: keep import light

    art = load_quantized(directory)
    return art.model, art.params, art.spec


class DraftRunner:
    """The draft model's serving state, mirrored onto the target scheduler's
    slots. Two jitted forwards — a packed catch-up step (``budget`` S=1 rows)
    and a **scanned draft loop** (one dispatch running all ``k + 1``
    autoregressive single-token forwards inside ``lax.scan``) — over a
    private paged pool, plus a host-side per-slot ``pos`` counter: the number
    of leading cache positions whose contents agree with the request's
    current context.

    The scanned loop is what makes drafting pay for itself: per verify round
    the draft costs ONE device dispatch (k+1 tiny forwards fused), so a round
    is 2 dispatches (draft + target) for 1..k+1 committed tokens per decoder,
    versus one full packed step per token without speculation — the win
    survives even dispatch-overhead-dominated CPU shapes.

    Rollback is the counter: after verification the scheduler calls
    ``sync(slot, len(context))``; rejected draft rows above the new context
    are simply rewritten by the next round's catch-up/drafting writes before
    anything can attend to them (paged reads are gated by ``ctx_lens`` and
    the per-token causal mask, so a stale row above the horizon is
    invisible). ``reset`` (new admission to the slot) rewinds to 0 — the
    draft replays the whole prompt; it never aliases the target's prefix
    cache, whose pool it does not share.
    """

    def __init__(self, model, params, *, slots: int, cache_len: int, k: int,
                 block_size: int = 16, cache_dtype=jnp.float32,
                 kv_quant: bool = False, token_budget: int = 0,
                 telemetry=None):
        policies = model.cache_policies()
        if policies is None:
            raise ValueError(
                f"draft family {model.cfg.family} exports no cache policies "
                "(cannot back a draft pool)"
            )
        self._rec = any(p.kind == "recurrent" for p in policies)
        self.model, self.params, self.k = model, params, k
        self.slots = slots
        # headroom: the scanned loop writes up to position n + k for a row
        # whose own horizon stops earlier (k_r < k near a budget end) — those
        # cells must land in real blocks, never clip into a neighbour
        draft_len = cache_len + k + 1
        self.max_blk = blocks_needed(draft_len, block_size)
        n_blocks = slots * self.max_blk
        self.pools = model.init_caches(
            slots, draft_len, jnp.dtype(cache_dtype), quantized=kv_quant,
            layout="paged", block_size=block_size, n_blocks=n_blocks,
        )
        # static ownership: slot s owns blocks [s*max_blk, (s+1)*max_blk) —
        # the table never changes, so there is no allocator to keep safe
        self._bt = jnp.asarray(
            np.arange(n_blocks, dtype=np.int32).reshape(slots, self.max_blk))
        # catch-up rows per dispatch; the scanned draft loop itself always
        # runs a fixed `slots`-row shape, so any positive budget is valid
        # (smaller = less memory, more catch-up dispatches per long prompt)
        self.budget = token_budget or (slots + 32)
        if self.budget < 1:
            raise ValueError(
                f"draft_token_budget must be >= 1, got {self.budget}"
            )
        self.pos = [0] * slots  # valid draft-cache positions per slot
        self._catch_fn = jax.jit(make_packed_fn(model))
        self._draft_fn = jax.jit(self._make_draft_loop())
        if self._rec:
            # recurrent state rollback is a host-side snapshot (the pools
            # BEFORE the scan loop) restored per rejected slot, plus the
            # zero-fill on admission; KV layers keep the counter mechanism
            self._zero_fn = jax.jit(zero_state_slot)
            self._restore_fn = jax.jit(restore_state_slot)
            self._snap_pools = None
            self._snap_base: dict[int, int] = {}
            # recurrent catch-up runs one MULTI-TOKEN row per slot (state is
            # gathered/scattered by slot, so a slot cannot span rows); this
            # is the per-row segment length per dispatch
            self._catch_S = 32
        self.steps = 0  # draft device dispatches (engine stats)
        from repro.serving.telemetry import NULL_TELEMETRY

        self.telemetry = telemetry if telemetry is not None else NULL_TELEMETRY
        self._c_steps = self.telemetry.counter(
            "serving_draft_steps", "draft device dispatches (catch-up + scan)")

    def _make_draft_loop(self):
        """One dispatch = k+1 scanned single-token forwards over all slots.

        Iteration j feeds each row's current token at position ``pos`` and
        proposes the next via argmax: starting from (next_token, n) this
        yields d_1 .. d_{k+1} while writing next_token, d_1 .. d_k to the
        draft cache — the extra (k+1)-th iteration's write is what keeps a
        fully-accepted request's draft cache caught up without a separate
        catch-up dispatch next round (its proposal is discarded). Padded rows
        carry pos = -1: their writes are dropped and their argmaxes ignored.
        """
        packed = make_packed_fn(self.model)
        k = self.k

        def draft_loop(params, pools, bt, slot_ids, tok0, pos0):
            def body(carry, _):
                pools, tok, pos = carry
                valid = pos >= 0
                ctx = jnp.where(valid, pos + 1, 0)
                pools, logits, _ = packed(params, pools, bt, slot_ids,
                                          pos[:, None], ctx, tok[:, None])
                nxt = jnp.argmax(logits[:, 0], axis=-1).astype(jnp.int32)
                return (pools, nxt, jnp.where(valid, pos + 1, -1)), nxt

            (pools, _, _), drafts = jax.lax.scan(
                body, (pools, tok0, pos0), None, length=k + 1)
            return pools, drafts  # (k+1, R); row k is the discarded lookahead

        return draft_loop

    # ------------------------------------------------------------- lifecycle
    def reset(self, slot: int) -> None:
        """New occupant for ``slot``: nothing in the draft cache is valid."""
        self.pos[slot] = 0
        if self._rec:
            self.pools = self._zero_fn(self.pools, slot)

    def sync(self, slot: int, n_valid: int) -> None:
        """Post-verification rollback: positions >= n_valid were rejected
        drafts (or never written) — rewind so catch-up rewrites them.

        KV layers need only the counter (stale rows above the horizon are
        invisible until overwritten); recurrent layers hold ONE state that
        the scan loop advanced past the rejection point, so it is restored
        from the pre-scan snapshot (state at the old context length) and
        catch-up replays the accepted tokens next round. Full acceptance
        keeps the advanced state — the consumed tokens ARE the new context.
        """
        if self._rec and n_valid < self.pos[slot]:
            if self._snap_pools is None:  # no snapshot (never proposed)
                self.reset(slot)
                return
            self.pools = self._restore_fn(self.pools, self._snap_pools, slot)
            self.pos[slot] = min(self._snap_base.get(slot, 0), n_valid)
        else:
            self.pos[slot] = min(self.pos[slot], n_valid)

    # -------------------------------------------------------------- proposal
    def propose(self, reqs: list[tuple[int, int, list[int], int, int]],
                ) -> dict[int, list[int]]:
        """Draft up to ``k`` greedy tokens per request.

        ``reqs``: (rid, slot, context, next_token, k_r) per decoding
        request — ``context`` is every token already committed to the target
        cache and ``next_token`` the sampled-but-unwritten token the verify
        segment will start with. Returns {rid: [d_1 .. d_{k_r}]} (k_r = 0
        entries omitted; such rows still ride the loop so their
        ``next_token`` write keeps the draft cache warm).

        Catch-up first: context tokens the draft cache has not seen are
        packed FCFS into ``budget``-row steps (a fresh admission replays its
        whole prompt here; steady state needs none). Then ONE scanned
        dispatch drafts autoregressively for every decoding row at once.
        Draft sampling is argmax — greedy verification's acceptance test is
        an argmax comparison, so a sampled draft would only lower the
        acceptance rate.
        """
        if not reqs:
            return {}
        T = self.budget

        # catch-up: feed unseen context tokens (logits unused — the scanned
        # loop below starts from next_token, which is never behind)
        pending = []
        for _rid, slot, context, _nt, _k in reqs:
            if self.pos[slot] < len(context):
                pending.append([slot, list(context[self.pos[slot]:]),
                                self.pos[slot]])
        if self._rec:
            # one multi-token row per slot per dispatch: recurrent state is
            # gathered/scattered by slot, so the S=1 multi-row packing below
            # (several rows of the SAME slot) would gather a stale h for
            # every row after the first
            Sc = self._catch_S
            while pending:
                slot_ids = np.zeros((self.slots,), np.int32)
                pos = np.full((self.slots, Sc), -1, np.int32)
                tok = np.zeros((self.slots, Sc), np.int32)
                leftover = list(pending[self.slots:])
                for row, (slot, toks, start) in enumerate(pending[: self.slots]):
                    n = min(Sc, len(toks))
                    slot_ids[row] = slot
                    pos[row, :n] = np.arange(start, start + n)
                    tok[row, :n] = toks[:n]
                    if n < len(toks):
                        leftover.append([slot, toks[n:], start + n])
                with self.telemetry.annotate("draft_catchup"):
                    self.pools, _, _ = self._catch_fn(
                        self.params, self.pools, self._bt,
                        jnp.asarray(slot_ids), jnp.asarray(pos),
                        jnp.asarray(pos.max(axis=1) + 1), jnp.asarray(tok),
                    )
                self.steps += 1
                self._c_steps.add()
                pending = leftover
            # snapshot for post-verification rollback: state at exactly
            # len(context) consumed tokens per slot (see sync)
            self._snap_pools = self.pools
            self._snap_base = {slot: len(ctx) for _r, slot, ctx, _nt, _k in reqs}
        while pending:
            slot_ids = np.zeros((T,), np.int32)
            pos = np.full((T, 1), -1, np.int32)
            tok = np.zeros((T, 1), np.int32)
            row, leftover = 0, []
            for item in pending:
                slot, toks, start = item
                if row >= T:
                    leftover.append(item)
                    continue
                n = min(T - row, len(toks))
                sl = slice(row, row + n)
                slot_ids[sl] = slot
                pos[sl, 0] = np.arange(start, start + n)
                tok[sl, 0] = toks[:n]
                if n < len(toks):
                    leftover.append([slot, toks[n:], start + n])
                row += n
            with self.telemetry.annotate("draft_catchup"):
                self.pools, _, _ = self._catch_fn(
                    self.params, self.pools, self._bt, jnp.asarray(slot_ids),
                    jnp.asarray(pos), jnp.asarray(pos[:, 0] + 1),
                    jnp.asarray(tok),
                )
            self.steps += 1
            self._c_steps.add()
            pending = leftover

        # one scanned dispatch: k+1 fused AR steps across all decoding rows
        slot_ids = np.zeros((self.slots,), np.int32)
        tok0 = np.zeros((self.slots,), np.int32)
        pos0 = np.full((self.slots,), -1, np.int32)
        for row, (_rid, slot, context, next_token, _k) in enumerate(reqs):
            slot_ids[row], tok0[row], pos0[row] = slot, next_token, len(context)
        with self.telemetry.annotate("draft_scan"):
            self.pools, dr = self._draft_fn(
                self.params, self.pools, self._bt, jnp.asarray(slot_ids),
                jnp.asarray(tok0), jnp.asarray(pos0),
            )
        self.steps += 1
        self._c_steps.add()
        dr = np.asarray(dr)  # (k+1, slots)
        drafts: dict[int, list[int]] = {}
        for row, (rid, slot, context, _nt, k_r) in enumerate(reqs):
            if k_r > 0:
                drafts[rid] = [int(dr[j, row]) for j in range(k_r)]
            # cache holds context + next_token + d_1..d_k for this row
            self.pos[slot] = len(context) + self.k + 1
        return drafts
