"""Sharded atomic checkpointing (msgpack + zstd), no external deps."""

from repro.checkpoint.checkpointer import CheckpointManager, load_checkpoint, save_checkpoint

__all__ = ["CheckpointManager", "load_checkpoint", "save_checkpoint"]
