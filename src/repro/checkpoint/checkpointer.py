"""Fault-tolerant checkpointing: atomic, integrity-checked, resumable.

Layout per step::

    <dir>/step_<N>/shard_<host>.msgpack.zst   # {keystr: {dtype, shape, raw}}
    <dir>/step_<N>/MANIFEST.json              # step, host count, per-leaf sha256
    <dir>/step_<N>/COMMIT                     # written LAST -> crash-atomic

Restore is template-based: leaves are matched by ``jax.tree_util.keystr``
path, so any registered-dataclass pytree (QuantizedWeight etc.) round-trips.
A checkpoint without COMMIT (crash mid-write) is ignored by
``restore_latest`` — that plus the data-pipeline state being checkpointed is
the restart story: kill -9 at any point resumes from the last durable step
with no data replay/skip.

Multi-host posture: each process writes only its addressable shard file
(shard_<process_index>); process 0 writes the manifest after a barrier. On
this single-host container that degenerates to one shard, but the layout and
code paths are the multi-host ones.
"""

from __future__ import annotations

import hashlib
import json
import pathlib
import re
import shutil
import threading

import jax
import msgpack
import numpy as np

try:  # zstd is an optional dep: fall back to raw (uncompressed) shards
    import zstandard as _zstd
except ImportError:  # pragma: no cover - depends on container
    _zstd = None

__all__ = ["save_checkpoint", "load_checkpoint", "CheckpointManager"]

_ZSTD_MAGIC = b"\x28\xb5\x2f\xfd"


def _compress(raw: bytes) -> bytes:
    return _zstd.ZstdCompressor(level=3).compress(raw) if _zstd else raw


def _decompress(blob: bytes, compressed: bool | None = None) -> bytes:
    """Inverse of _compress. ``compressed`` is the shard's explicit per-leaf
    flag; legacy shards without it fall back to zstd frame sniffing. Either
    way raw-stored shards (written where zstd was unavailable) load fine in
    an env that has it, and vice versa."""
    if compressed is None:
        compressed = blob[:4] == _ZSTD_MAGIC
    if compressed:
        if _zstd is None:
            raise ImportError("checkpoint shard is zstd-compressed but the "
                              "'zstandard' package is not installed")
        return _zstd.ZstdDecompressor().decompress(blob)
    return bytes(blob)


def _flatten(tree) -> dict[str, np.ndarray]:
    flat = jax.tree_util.tree_flatten_with_path(tree)[0]
    out = {}
    for path, leaf in flat:
        out[jax.tree_util.keystr(path)] = np.asarray(jax.device_get(leaf))
    return out


def save_checkpoint(directory: str, step: int, tree, process_index: int = 0,
                    process_count: int = 1) -> pathlib.Path:
    d = pathlib.Path(directory) / f"step_{step:08d}"
    tmp = d.parent / f".tmp_step_{step:08d}_{process_index}"
    tmp.mkdir(parents=True, exist_ok=True)
    flat = _flatten(tree)
    payload = {
        k: {"dtype": str(v.dtype), "shape": list(v.shape), "raw": _compress(v.tobytes()),
            "z": _zstd is not None}
        for k, v in flat.items()
    }
    shard = tmp / f"shard_{process_index}.msgpack.zst"
    shard.write_bytes(msgpack.packb(payload, use_bin_type=True))
    if process_index == 0:
        manifest = {
            "step": step,
            "process_count": process_count,
            "leaves": {k: hashlib.sha256(v.tobytes()).hexdigest()[:16] for k, v in flat.items()},
        }
        (tmp / "MANIFEST.json").write_text(json.dumps(manifest, indent=1))
    d.mkdir(parents=True, exist_ok=True)
    for f in tmp.iterdir():
        f.replace(d / f.name)
    tmp.rmdir()
    (d / "COMMIT").write_text("ok")  # commit marker LAST
    return d


def load_checkpoint(directory: str, step: int, template, verify: bool = True):
    d = pathlib.Path(directory) / f"step_{step:08d}"
    if not (d / "COMMIT").exists():
        raise FileNotFoundError(f"checkpoint {d} has no COMMIT marker (incomplete)")
    payload: dict = {}
    for shard in sorted(d.glob("shard_*.msgpack.zst")):
        payload.update(msgpack.unpackb(shard.read_bytes(), raw=False))
    if verify and (d / "MANIFEST.json").exists():
        manifest = json.loads((d / "MANIFEST.json").read_text())
        for k, h in manifest["leaves"].items():
            raw = _decompress(payload[k]["raw"], payload[k].get("z"))
            if hashlib.sha256(raw).hexdigest()[:16] != h:
                raise IOError(f"checkpoint corruption detected at leaf {k}")

    paths, treedef = jax.tree_util.tree_flatten_with_path(template)
    leaves = []
    for path, tmpl_leaf in paths:
        k = jax.tree_util.keystr(path)
        if k not in payload:
            raise KeyError(f"checkpoint missing leaf {k}")
        ent = payload[k]
        arr = np.frombuffer(_decompress(ent["raw"], ent.get("z")), dtype=np.dtype(ent["dtype"]))
        leaves.append(arr.reshape(ent["shape"]))
    return jax.tree_util.tree_unflatten(treedef, leaves)


class CheckpointManager:
    """Cadenced saves, retention, latest-valid discovery, optional async."""

    def __init__(self, directory: str, keep: int = 3, async_save: bool = False):
        self.dir = pathlib.Path(directory)
        self.dir.mkdir(parents=True, exist_ok=True)
        self.keep = keep
        self.async_save = async_save
        self._thread: threading.Thread | None = None

    def steps(self) -> list[int]:
        out = []
        for d in self.dir.glob("step_*"):
            if (d / "COMMIT").exists():
                m = re.fullmatch(r"step_(\d+)", d.name)
                if m:
                    out.append(int(m.group(1)))
        return sorted(out)

    def save(self, tree, step: int):
        self.wait()  # one async save in flight at a time
        host_tree = jax.tree.map(jax.device_get, tree)  # snapshot before async

        def _do():
            save_checkpoint(str(self.dir), step, host_tree)
            self._gc()

        if self.async_save:
            self._thread = threading.Thread(target=_do, daemon=True)
            self._thread.start()
        else:
            _do()

    def wait(self):
        if self._thread is not None:
            self._thread.join()
            self._thread = None

    def restore(self, step: int, template):
        return load_checkpoint(str(self.dir), step, template)

    def restore_latest(self, template):
        steps = self.steps()
        return self.restore(steps[-1], template) if steps else None

    def _gc(self):
        steps = self.steps()
        for s in steps[: -self.keep] if self.keep > 0 else []:
            shutil.rmtree(self.dir / f"step_{s:08d}", ignore_errors=True)
