"""llama3.2-1b [dense] — 16L d=2048 32H (GQA kv=8) d_ff=8192 vocab=128256.
[hf:meta-llama/Llama-3.2-1B; unverified]
"""

import dataclasses

from repro.configs.base import ModelConfig

CONFIG = ModelConfig(
    arch_id="llama3_2_1b",
    family="dense",
    n_layers=16,
    d_model=2048,
    n_heads=32,
    n_kv_heads=8,
    d_ff=8192,
    vocab_size=128_256,
    head_dim=64,
    act_fn="silu",
    norm="rms",
    rope_theta=500_000.0,
    tie_embeddings=True,
    param_dtype="bfloat16",
    compute_dtype="bfloat16",
    remat="block",
    attn_chunk=2048,
)


def smoke_config() -> ModelConfig:
    return dataclasses.replace(
        CONFIG,
        n_layers=2,
        d_model=64,
        n_heads=4,
        n_kv_heads=2,
        head_dim=16,
        d_ff=128,
        vocab_size=256,
        param_dtype="float32",
        compute_dtype="float32",
        remat="none",
        attn_chunk=0,
    )
