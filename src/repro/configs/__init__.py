"""Architecture configs: the 10 assigned archs + the paper's own eval model.

Use ``repro.configs.base.get_config(arch_id)`` / ``get_smoke_config(arch_id)``.
"""

from repro.configs.base import (
    SHAPES,
    ModelConfig,
    ShapeConfig,
    get_config,
    get_smoke_config,
    list_archs,
)

__all__ = [
    "SHAPES",
    "ModelConfig",
    "ShapeConfig",
    "get_config",
    "get_smoke_config",
    "list_archs",
]
