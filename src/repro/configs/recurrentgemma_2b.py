"""recurrentgemma-2b [hybrid] — 26L d=2560 10H (MQA kv=1, head_dim 256)
d_ff=7680 vocab=256000; RG-LRU recurrent blocks + local attention, pattern
(rec, rec, attn), window 2048. [arXiv:2402.19427; hf]

Bounded local-attn KV + O(1) LRU state -> runs the long_500k cell.
"""

import dataclasses

from repro.configs.base import ModelConfig

CONFIG = ModelConfig(
    arch_id="recurrentgemma_2b",
    family="hybrid",
    n_layers=26,
    d_model=2560,
    n_heads=10,
    n_kv_heads=1,
    d_ff=7680,
    vocab_size=256_000,
    head_dim=256,
    d_inner=2560,  # LRU width
    ssm_conv=4,
    block_pattern=("rec", "rec", "attn"),
    sliding_window=2048,
    act_fn="gelu",
    norm="rms",
    rope_theta=10_000.0,
    tie_embeddings=True,
    param_dtype="bfloat16",
    compute_dtype="bfloat16",
    remat="block",
    attn_chunk=2048,
    scan_layers=False,  # heterogeneous stack is unrolled (26 blocks)
)


def smoke_config() -> ModelConfig:
    return dataclasses.replace(
        CONFIG,
        n_layers=3,
        d_model=64,
        n_heads=4,
        n_kv_heads=1,
        head_dim=16,
        d_ff=128,
        vocab_size=256,
        d_inner=64,
        sliding_window=16,
        param_dtype="float32",
        compute_dtype="float32",
        remat="none",
        attn_chunk=0,
    )
