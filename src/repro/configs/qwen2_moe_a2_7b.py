"""qwen2-moe-a2.7b [moe] — 24L d=2048 16H (GQA kv=16) d_ff=1408/expert,
vocab=151936, 60 routed experts top-4 + 4 shared experts.
[hf:Qwen/Qwen1.5-MoE-A2.7B; hf]
"""

import dataclasses

from repro.configs.base import ModelConfig

CONFIG = ModelConfig(
    arch_id="qwen2_moe_a2_7b",
    family="moe",
    n_layers=24,
    d_model=2048,
    n_heads=16,
    n_kv_heads=16,
    d_ff=1408,
    vocab_size=151_936,
    n_experts=60,
    experts_per_token=4,
    n_shared_experts=4,
    shared_expert_d_ff=1408,
    act_fn="silu",
    norm="rms",
    rope_theta=1_000_000.0,
    param_dtype="bfloat16",
    compute_dtype="bfloat16",
    remat="block",
    attn_chunk=2048,
)


def smoke_config() -> ModelConfig:
    return dataclasses.replace(
        CONFIG,
        n_layers=2,
        d_model=64,
        n_heads=4,
        n_kv_heads=4,
        head_dim=16,
        d_ff=64,
        vocab_size=256,
        n_experts=8,
        experts_per_token=2,
        n_shared_experts=2,
        shared_expert_d_ff=64,
        capacity_factor=2.0,
        param_dtype="float32",
        compute_dtype="float32",
        remat="none",
        attn_chunk=0,
    )
