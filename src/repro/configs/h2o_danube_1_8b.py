"""h2o-danube-1.8b [dense] — 24L d=2560 32H (GQA kv=8) d_ff=6912 vocab=32000,
llama+mistral mix with sliding-window attention. [arXiv:2401.16818; hf]

SWA window 4096 bounds decode KV memory -> this arch RUNS the long_500k cell.
"""

import dataclasses

from repro.configs.base import ModelConfig

CONFIG = ModelConfig(
    arch_id="h2o_danube_1_8b",
    family="dense",
    n_layers=24,
    d_model=2560,
    n_heads=32,
    n_kv_heads=8,
    d_ff=6912,
    vocab_size=32_000,
    sliding_window=4096,
    act_fn="silu",
    norm="rms",
    rope_theta=10_000.0,
    param_dtype="bfloat16",
    compute_dtype="bfloat16",
    remat="block",
    attn_chunk=2048,
)


def smoke_config() -> ModelConfig:
    return dataclasses.replace(
        CONFIG,
        n_layers=2,
        d_model=64,
        n_heads=4,
        n_kv_heads=2,
        head_dim=16,
        d_ff=128,
        vocab_size=256,
        sliding_window=16,
        param_dtype="float32",
        compute_dtype="float32",
        remat="none",
        attn_chunk=0,
    )
