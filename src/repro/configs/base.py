"""Model/shape configuration system + architecture registry.

Every assigned architecture provides one module in ``repro/configs/`` exposing
``CONFIG`` (the exact published configuration) and ``smoke_config()`` (a
reduced same-family config for CPU smoke tests). ``get_config(arch_id)`` /
``list_archs()`` are the registry entry points used by the launcher, dry-run
and tests (``--arch <id>``).
"""

from __future__ import annotations

import dataclasses
import importlib
from typing import Literal

__all__ = ["ModelConfig", "ShapeConfig", "SHAPES", "get_config", "get_smoke_config", "list_archs"]

Family = Literal["dense", "moe", "ssm", "hybrid", "vlm", "audio"]


@dataclasses.dataclass(frozen=True)
class ModelConfig:
    """Static architecture description (hashable; safe as a jit static arg)."""

    arch_id: str
    family: Family
    n_layers: int
    d_model: int
    n_heads: int
    n_kv_heads: int
    d_ff: int
    vocab_size: int
    head_dim: int = 0  # 0 -> d_model // n_heads

    # block structure / numerics
    act_fn: str = "silu"  # silu | gelu | relu2
    norm: str = "rms"  # rms | layer
    parallel_blocks: bool = False  # command-r: x + attn(n(x)) + mlp(n(x))
    rope_theta: float = 10_000.0
    pos_embed: str = "rope"  # rope | sinusoidal | none
    tie_embeddings: bool = False
    logit_softcap: float = 0.0
    sliding_window: int = 0  # 0 = full attention; >0 = SWA / local-attn window

    # MoE
    n_experts: int = 0
    experts_per_token: int = 0
    n_shared_experts: int = 0
    shared_expert_d_ff: int = 0
    capacity_factor: float = 1.25
    moe_dispatch_groups: int = 32  # DP-aligned group-local dispatch (see moe.py)

    # SSM (mamba-1) / RG-LRU
    d_inner: int = 0
    ssm_state: int = 16
    ssm_conv: int = 4
    dt_rank: int = 0
    block_pattern: tuple[str, ...] = ()  # hybrid: e.g. ("rec", "rec", "attn")

    # VLM cross-attention
    cross_attn_every: int = 0  # every k-th layer is a cross-attn block
    n_img_tokens: int = 0

    input_mode: str = "tokens"  # tokens | tokens+image
    param_dtype: str = "float32"
    compute_dtype: str = "float32"

    # compile-scalability / memory knobs
    scan_layers: bool = True
    remat: str = "none"  # none | block  (activation checkpointing per block)
    attn_chunk: int = 0  # 0 = dense attention; >0 = flash-style chunk size

    def __post_init__(self):
        if self.head_dim == 0 and self.n_heads:
            object.__setattr__(self, "head_dim", self.d_model // self.n_heads)

    @property
    def vocab_padded(self) -> int:
        """Vocab padded to a multiple of 128 (TP-shardable, MXU-aligned)."""
        return (self.vocab_size + 127) // 128 * 128

    @property
    def n_params(self) -> int:
        """Approximate parameter count (embeddings + blocks), for roofline."""
        d, v = self.d_model, self.vocab_padded
        emb = v * d * (1 if self.tie_embeddings else 2)
        per_layer = 0
        hd = self.head_dim
        attn = d * self.n_heads * hd + 2 * d * self.n_kv_heads * hd + self.n_heads * hd * d
        gate_mult = 3 if self.act_fn in ("silu", "gelu") else 2
        mlp = gate_mult * d * self.d_ff
        if self.family == "moe":
            mlp = self.n_experts * gate_mult * d * self.d_ff + d * self.n_experts
            mlp += self.n_shared_experts * gate_mult * d * self.shared_expert_d_ff
        if self.family == "ssm":
            di, n, r = self.d_inner, self.ssm_state, self.dt_rank
            per_layer = d * 2 * di + di * (r + 2 * n) + r * di + di * d + di * self.ssm_conv + di * n
            return emb + self.n_layers * per_layer
        per_layer = attn + mlp
        if self.family == "hybrid":
            # mix of recurrent and attention blocks; approximate with average
            di = self.d_inner or d
            rec = 2 * d * di + di * d + 3 * di * self.ssm_conv + 2 * di
            n_rec = sum(1 for b in self._pattern_expanded() if b == "rec")
            n_att = self.n_layers - n_rec
            return emb + n_att * (attn + mlp) + n_rec * (rec + mlp)
        if self.family == "vlm" and self.cross_attn_every:
            n_cross = self.n_layers // self.cross_attn_every
            per_layer_cross = attn + mlp + 2 * d  # gates
            return emb + (self.n_layers - n_cross) * per_layer + n_cross * per_layer_cross
        return emb + self.n_layers * per_layer

    @property
    def n_active_params(self) -> int:
        """Active params per token (== n_params for dense; routed subset for MoE)."""
        if self.family != "moe":
            return self.n_params
        d = self.d_model
        gate_mult = 3 if self.act_fn in ("silu", "gelu") else 2
        dense_side = self.n_params - self.n_layers * self.n_experts * gate_mult * d * self.d_ff
        active_moe = self.n_layers * self.experts_per_token * gate_mult * d * self.d_ff
        return dense_side + active_moe

    def supports_long_context(self) -> bool:
        """True iff attention cost/memory is bounded (SSM, window, hybrid)."""
        if self.family in ("ssm", "hybrid"):
            return True
        return self.sliding_window > 0

    def _pattern_expanded(self) -> tuple[str, ...]:
        if not self.block_pattern:
            return ()
        reps = -(-self.n_layers // len(self.block_pattern))
        return (self.block_pattern * reps)[: self.n_layers]


@dataclasses.dataclass(frozen=True)
class ShapeConfig:
    """One assigned input-shape cell."""

    name: str
    seq_len: int
    global_batch: int
    kind: Literal["train", "prefill", "decode"]


SHAPES: dict[str, ShapeConfig] = {
    "train_4k": ShapeConfig("train_4k", 4_096, 256, "train"),
    "prefill_32k": ShapeConfig("prefill_32k", 32_768, 32, "prefill"),
    "decode_32k": ShapeConfig("decode_32k", 32_768, 128, "decode"),
    "long_500k": ShapeConfig("long_500k", 524_288, 1, "decode"),
}

_ARCHS = [
    "granite_moe_3b_a800m",
    "qwen2_moe_a2_7b",
    "h2o_danube_1_8b",
    "llama3_2_1b",
    "command_r_plus_104b",
    "nemotron_4_15b",
    "llama3_2_vision_11b",
    "falcon_mamba_7b",
    "musicgen_large",
    "recurrentgemma_2b",
    "oasis_7b",  # the paper's own LLaMA-7B-class evaluation model
]


def list_archs(assigned_only: bool = False) -> list[str]:
    return _ARCHS[:-1] if assigned_only else list(_ARCHS)


def _module(arch_id: str):
    if arch_id not in _ARCHS:
        raise KeyError(f"unknown arch '{arch_id}'; known: {_ARCHS}")
    return importlib.import_module(f"repro.configs.{arch_id}")


def get_config(arch_id: str) -> ModelConfig:
    return _module(arch_id).CONFIG


def get_smoke_config(arch_id: str) -> ModelConfig:
    return _module(arch_id).smoke_config()
