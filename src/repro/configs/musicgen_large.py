"""musicgen-large [audio] — 48L d=2048 32H (kv=32, MHA) d_ff=8192 vocab=2048,
decoder-only over EnCodec tokens, sinusoidal positions, plain-GeLU FFN.
[arXiv:2306.05284; hf]

Backbone only: the EnCodec frontend is a STUB — the model consumes discrete
codec tokens (vocab 2048) directly, per the assignment.
"""

import dataclasses

from repro.configs.base import ModelConfig

CONFIG = ModelConfig(
    arch_id="musicgen_large",
    family="audio",
    n_layers=48,
    d_model=2048,
    n_heads=32,
    n_kv_heads=32,
    d_ff=8192,
    vocab_size=2048,
    act_fn="gelu_plain",
    norm="layer",
    pos_embed="sinusoidal",
    param_dtype="bfloat16",
    compute_dtype="bfloat16",
    remat="block",
    attn_chunk=2048,
)


def smoke_config() -> ModelConfig:
    return dataclasses.replace(
        CONFIG,
        n_layers=2,
        d_model=64,
        n_heads=4,
        n_kv_heads=4,
        head_dim=16,
        d_ff=128,
        vocab_size=256,
        param_dtype="float32",
        compute_dtype="float32",
        remat="none",
        attn_chunk=0,
    )
