"""command-r-plus-104b [dense] — 64L d=12288 96H (GQA kv=8) d_ff=33792
vocab=256000. Parallel attention+FFN blocks, LayerNorm, no biases.
[hf:CohereForAI/c4ai-command-r-v01; unverified]
"""

import dataclasses

from repro.configs.base import ModelConfig

CONFIG = ModelConfig(
    arch_id="command_r_plus_104b",
    family="dense",
    n_layers=64,
    d_model=12_288,
    n_heads=96,
    n_kv_heads=8,
    d_ff=33_792,
    vocab_size=256_000,
    head_dim=128,
    parallel_blocks=True,
    act_fn="silu",
    norm="layer",
    rope_theta=75_000.0,
    tie_embeddings=True,
    param_dtype="bfloat16",
    compute_dtype="bfloat16",
    remat="double",
    attn_chunk=2048,
)


def smoke_config() -> ModelConfig:
    return dataclasses.replace(
        CONFIG,
        n_layers=2,
        d_model=64,
        n_heads=4,
        n_kv_heads=2,
        head_dim=16,
        d_ff=128,
        vocab_size=256,
        param_dtype="float32",
        compute_dtype="float32",
        remat="none",
        attn_chunk=0,
    )
