"""nemotron-4-15b [dense] — 32L d=6144 48H (GQA kv=8) d_ff=24576 vocab=256000,
squared-ReLU MLP (no gating), LayerNorm. [arXiv:2402.16819; unverified]

Squared-ReLU activations are one-sided heavy-tailed — the outlier-compensation
branch of the paper's technique is especially relevant here (DESIGN.md §5).
"""

import dataclasses

from repro.configs.base import ModelConfig

CONFIG = ModelConfig(
    arch_id="nemotron_4_15b",
    family="dense",
    n_layers=32,
    d_model=6144,
    n_heads=48,
    n_kv_heads=8,
    d_ff=24_576,
    vocab_size=256_000,
    head_dim=128,
    act_fn="relu2",
    norm="layer",
    rope_theta=10_000.0,
    param_dtype="bfloat16",
    compute_dtype="bfloat16",
    remat="block",
    attn_chunk=2048,
)


def smoke_config() -> ModelConfig:
    return dataclasses.replace(
        CONFIG,
        n_layers=2,
        d_model=64,
        n_heads=4,
        n_kv_heads=2,
        head_dim=16,
        d_ff=128,
        vocab_size=256,
        param_dtype="float32",
        compute_dtype="float32",
        remat="none",
        attn_chunk=0,
    )
