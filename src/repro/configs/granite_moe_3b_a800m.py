"""granite-moe-3b-a800m [moe] — 32L d=1536 24H (GQA kv=8) d_ff=512/expert,
vocab=49155, MoE top-8. [hf:ibm-granite/granite-3.0-1b-a400m-base; hf]

Assignment note: the spec line reads "MoE 40e top-8 — 32 experts top-8"; we
follow the leading spec (40 experts, top-8) and record the discrepancy here.
"""

import dataclasses

from repro.configs.base import ModelConfig

CONFIG = ModelConfig(
    arch_id="granite_moe_3b_a800m",
    family="moe",
    n_layers=32,
    d_model=1536,
    n_heads=24,
    n_kv_heads=8,
    d_ff=512,
    vocab_size=49_155,
    n_experts=40,
    experts_per_token=8,
    act_fn="silu",
    norm="rms",
    tie_embeddings=True,
    rope_theta=10_000.0,
    param_dtype="bfloat16",
    compute_dtype="bfloat16",
    remat="block",
    attn_chunk=2048,
)


def smoke_config() -> ModelConfig:
    return dataclasses.replace(
        CONFIG,
        n_layers=2,
        d_model=64,
        n_heads=4,
        n_kv_heads=2,
        head_dim=16,
        d_ff=64,
        vocab_size=256,
        n_experts=8,
        experts_per_token=2,
        capacity_factor=2.0,
        param_dtype="float32",
        compute_dtype="float32",
        remat="none",
        attn_chunk=0,
    )
