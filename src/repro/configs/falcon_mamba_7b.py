"""falcon-mamba-7b [ssm] — 64L d=4096 attention-free Mamba-1, ssm_state=16,
vocab=65024. [arXiv:2410.05355; unverified]

O(1) decode state -> runs the long_500k cell.
"""

import dataclasses

from repro.configs.base import ModelConfig

CONFIG = ModelConfig(
    arch_id="falcon_mamba_7b",
    family="ssm",
    n_layers=64,
    d_model=4096,
    n_heads=1,  # unused (attention-free)
    n_kv_heads=1,
    d_ff=0,
    vocab_size=65_024,
    d_inner=8192,
    ssm_state=16,
    ssm_conv=4,
    dt_rank=256,
    norm="rms",
    pos_embed="none",
    param_dtype="bfloat16",
    compute_dtype="bfloat16",
    remat="block",
)


def smoke_config() -> ModelConfig:
    return dataclasses.replace(
        CONFIG,
        n_layers=2,
        d_model=64,
        vocab_size=256,
        d_inner=128,
        ssm_state=4,
        ssm_conv=4,
        dt_rank=8,
        param_dtype="float32",
        compute_dtype="float32",
        remat="none",
    )
