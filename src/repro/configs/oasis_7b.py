"""oasis-7b — the paper's own evaluation model class (LLaMA-7B: 32L d=4096
32H MHA d_ff=11008 vocab=32000). Used for the paper-faithful benchmarks
(Table I/III analogs, Fig. 14/16) and as the K=4096, N=4096 GEMM reference.
"""

import dataclasses

from repro.configs.base import ModelConfig

CONFIG = ModelConfig(
    arch_id="oasis_7b",
    family="dense",
    n_layers=32,
    d_model=4096,
    n_heads=32,
    n_kv_heads=32,
    d_ff=11_008,
    vocab_size=32_000,
    act_fn="silu",
    norm="rms",
    rope_theta=10_000.0,
    param_dtype="bfloat16",
    compute_dtype="bfloat16",
    remat="block",
    attn_chunk=2048,
)


def smoke_config() -> ModelConfig:
    return dataclasses.replace(
        CONFIG,
        n_layers=2,
        d_model=64,
        n_heads=4,
        n_kv_heads=4,
        head_dim=16,
        d_ff=128,
        vocab_size=256,
        param_dtype="float32",
        compute_dtype="float32",
        remat="none",
        attn_chunk=0,
    )
