"""llama-3.2-vision-11b [vlm] — 40L d=4096 32H (GQA kv=8) d_ff=14336
vocab=128256; every 5th block is a gated cross-attention block over image
patch embeddings. [hf:meta-llama/Llama-3.2-11B-Vision; unverified]

The vision frontend (ViT + projector) is a STUB per the assignment:
input_specs() provides precomputed (B, 1601, d_model) patch embeddings.
"""

import dataclasses

from repro.configs.base import ModelConfig

CONFIG = ModelConfig(
    arch_id="llama3_2_vision_11b",
    family="vlm",
    n_layers=40,
    d_model=4096,
    n_heads=32,
    n_kv_heads=8,
    d_ff=14_336,
    vocab_size=128_256,
    head_dim=128,
    cross_attn_every=5,
    n_img_tokens=1601,
    input_mode="tokens+image",
    act_fn="silu",
    norm="rms",
    rope_theta=500_000.0,
    param_dtype="bfloat16",
    compute_dtype="bfloat16",
    remat="block",
    attn_chunk=2048,
)


def smoke_config() -> ModelConfig:
    return dataclasses.replace(
        CONFIG,
        n_layers=4,
        d_model=64,
        n_heads=4,
        n_kv_heads=2,
        head_dim=16,
        d_ff=128,
        vocab_size=256,
        cross_attn_every=2,
        n_img_tokens=16,
        param_dtype="float32",
        compute_dtype="float32",
        remat="none",
        attn_chunk=0,
    )
