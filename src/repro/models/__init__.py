"""Model substrate: layers + the 10 assigned architecture families."""
