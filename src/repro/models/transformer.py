"""Dense decoder-only transformer LM.

Covers llama3.2-1b, h2o-danube (SWA), command-r-plus (parallel blocks,
LayerNorm, no bias), nemotron-4 (squared-ReLU, LayerNorm) and the musicgen
backbone (sinusoidal positions, EnCodec-token vocab). Layer stack runs under
``lax.scan`` over stacked params so HLO size is depth-independent; optional
per-block remat.
"""

from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig
from repro.distributed.sharding import constrain
from repro.models import layers as L

__all__ = ["init", "apply", "init_caches", "cache_policies"]


def _init_block(key, cfg: ModelConfig, dtype):
    k1, k2 = jax.random.split(key)
    p = {
        "norm1": L.norm_init(cfg.d_model, cfg.norm, dtype),
        "attn": L.attention_init(k1, cfg, dtype),
        "mlp": L.mlp_init(k2, cfg.d_model, cfg.d_ff, cfg.act_fn, dtype),
    }
    if not cfg.parallel_blocks:
        p["norm2"] = L.norm_init(cfg.d_model, cfg.norm, dtype)
    return p


def init(key, cfg: ModelConfig):
    dtype = jnp.dtype(cfg.param_dtype)
    k_emb, k_blocks, k_head = jax.random.split(key, 3)
    keys = jax.random.split(k_blocks, cfg.n_layers)
    if cfg.scan_layers:
        blocks = jax.vmap(lambda k: _init_block(k, cfg, dtype))(keys)
    else:
        blocks = [_init_block(k, cfg, dtype) for k in keys]
    params = {
        "embed": L.embed_init(k_emb, cfg.vocab_padded, cfg.d_model, dtype),
        "blocks": blocks,
        "norm_f": L.norm_init(cfg.d_model, cfg.norm, dtype),
    }
    if not cfg.tie_embeddings:
        params["head"] = L.dense_init(k_head, cfg.d_model, cfg.vocab_padded, dtype)
    return params


def init_caches(cfg: ModelConfig, batch: int, cache_len: int, dtype=jnp.bfloat16,
                quantized: bool = False, layout: str = "ring",
                block_size: int = 16, n_blocks: int = 0):
    """Stacked (L, ...) KV caches.

    layout="ring" (default): dense ring buffer per request slot; cache_len
    should be the window for SWA archs (bounded memory at 500k) and max_seq
    otherwise. quantized=True -> K-Means int4 KV storage (see
    layers.init_kv_cache).

    layout="paged": a global pool of ``n_blocks`` blocks of ``block_size``
    tokens per layer (layers.init_paged_kv_cache); ``batch``/``cache_len``
    only size the default pool (``batch * ceil(cache_len / block_size)``
    blocks when n_blocks=0). The returned tree holds pools ONLY — the
    serving scheduler attaches per-call ``block_tables``/``ctx_lens``
    (repro.serving.paged_cache.attach_tables) before model.apply. SWA
    configs use the same pool with LOGICAL (unclamped) tables: position p
    always lives at table[p // block_size], and the scheduler frees table
    entries that fall wholly out of the window (windowed_paged policy) —
    only the ring layout clamps cache_len to the window.
    """
    if layout == "paged":
        if n_blocks <= 0:
            n_blocks = batch * -(-cache_len // block_size)
        one = lambda: L.init_paged_kv_cache(cfg, n_blocks, block_size, dtype, quantized)
    else:
        if cfg.sliding_window:
            cache_len = min(cache_len, cfg.sliding_window)
        one = lambda: L.init_kv_cache(cfg, batch, cache_len, dtype, quantized)
    if cfg.scan_layers:
        return jax.tree.map(lambda *xs: jnp.stack(xs), *[one() for _ in range(cfg.n_layers)])
    return [one() for _ in range(cfg.n_layers)]


def cache_policies(cfg: ModelConfig):
    """Per-layer cache policy for the serving scheduler: every dense block is
    paged KV; SWA configs get the windowed variant (out-of-window blocks are
    freed, capping steady-state blocks at ceil(window / block_size) + 1)."""
    from repro.serving.paged_cache import CachePolicy

    if cfg.sliding_window:
        pol = CachePolicy("windowed_paged", window=cfg.sliding_window)
    else:
        pol = CachePolicy("paged_kv")
    return [pol] * cfg.n_layers


def _block_apply(p, x, cfg: ModelConfig, positions, cache):
    window = cfg.sliding_window
    if cfg.parallel_blocks:
        n = L.norm_apply(p["norm1"], x, cfg.norm)
        a, new_cache = L.attention_apply(
            p["attn"], n, cfg, positions=positions, cache=cache, window=window
        )
        m = L.mlp_apply(p["mlp"], n, cfg.act_fn)
        x = x + a + m
    else:
        a, new_cache = L.attention_apply(
            p["attn"], L.norm_apply(p["norm1"], x, cfg.norm), cfg,
            positions=positions, cache=cache, window=window,
        )
        x = x + a
        x = x + L.mlp_apply(p["mlp"], L.norm_apply(p["norm2"], x, cfg.norm), cfg.act_fn)
    return constrain(x, "batch", "seq_sp", "d_model"), new_cache


def _embed_in(params, cfg: ModelConfig, tokens, positions):
    x = params["embed"]["table"][tokens].astype(jnp.dtype(cfg.compute_dtype))
    if cfg.pos_embed == "sinusoidal":
        pe = L.sinusoidal_positions(positions, cfg.d_model).astype(x.dtype)
        x = x + (pe if positions.ndim == 2 else pe[None])  # (B,S,d) | (1,S,d)
    return constrain(x, "batch", "seq_sp", "d_model")


def _logits_out(params, cfg: ModelConfig, x):
    x = L.norm_apply(params["norm_f"], x, cfg.norm)
    if cfg.tie_embeddings:
        logits = x @ params["embed"]["table"].astype(x.dtype).T
    else:
        logits = L.dense_apply(params["head"], x)
    return constrain(logits.astype(jnp.float32), "batch", "seq", "vocab")


def apply(params, cfg: ModelConfig, tokens: jax.Array, *, positions=None, caches=None, last_only: bool = False, return_hidden_only: bool = False):
    """Forward pass. tokens: (B, S) int32.

    positions: (S,) absolute positions shared across the batch (defaults to
    arange — training/prefill), or (B, S) per-row (continuous-batching:
    ring decode at per-request depths, and the serving scheduler's packed
    token-budget step, where each row is ONE token of some request and
    position -1 marks an unused row). caches: stacked KV caches for
    decode/prefill, returned updated; paged caches may carry per-call
    ``block_tables``/``ctx_lens``/``token_slots`` (see
    repro.serving.paged_cache.attach_tables).
    Returns (logits f32 (B, S, vocab_padded), new_caches).
    """
    b, s = tokens.shape
    if positions is None:
        positions = jnp.arange(s, dtype=jnp.int32)
    x = _embed_in(params, cfg, tokens, positions)

    if cfg.scan_layers:
        def body(carry, xs):
            if caches is None:
                p = xs
                y, _ = _block_apply(p, carry, cfg, positions, None)
                return y, None
            p, c = xs
            y, nc = _block_apply(p, carry, cfg, positions, c)
            return y, nc

        if cfg.remat in ("block", "double"):
            body = jax.checkpoint(body)
        if cfg.remat == "double" and caches is None:
            # sqrt(L) checkpointing: nested checkpointed scans -> only O(sqrt L)
            # residual-stream carries live at once instead of O(L). This is
            # what brings the 104B train cell under HBM (EXPERIMENTS §Perf).
            l = cfg.n_layers
            g1 = max(d for d in range(1, int(l**0.5) + 1) if l % d == 0)

            @jax.checkpoint
            def group_body(carry, xs_group):
                y, _ = jax.lax.scan(body, carry, xs_group)
                return y, None

            grouped = jax.tree.map(
                lambda a: a.reshape(g1, l // g1, *a.shape[1:]), params["blocks"]
            )
            x, _ = jax.lax.scan(group_body, x, grouped)
            new_caches = None
        else:
            xs = params["blocks"] if caches is None else (params["blocks"], caches)
            x, new_caches = jax.lax.scan(body, x, xs)
    else:
        new_caches = []
        for i, p in enumerate(params["blocks"]):
            c = None if caches is None else caches[i]
            x, nc = _block_apply(p, x, cfg, positions, c)
            new_caches.append(nc)
        if caches is None:
            new_caches = None

    if last_only:
        x = x[:, -1:]
    if return_hidden_only:
        from repro.models.layers import norm_apply
        return norm_apply(params["norm_f"], x, cfg.norm), new_caches
    return _logits_out(params, cfg, x), new_caches
