"""Cross-attention VLM decoder (llama3.2-vision backbone).

40 transformer blocks = 8 groups of (4 self-attention blocks + 1 gated
cross-attention block). The vision frontend is a STUB per the assignment:
``image_embeds`` arrive as precomputed (B, n_img_tokens, d_model) patch
embeddings (in real deployment the ViT + projector produce these).

Compile scalability: one outer ``lax.scan`` over the 8 groups; inside each
group an inner scan over its 4 stacked self blocks, then the group's cross
block — HLO is O(1) in depth on both levels.
"""

from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig
from repro.distributed.sharding import constrain
from repro.models import layers as L
from repro.models.transformer import _block_apply, _embed_in, _init_block, _logits_out

__all__ = ["init", "apply", "init_caches"]


def _init_cross_block(key, cfg: ModelConfig, dtype):
    k1, k2 = jax.random.split(key)
    return {
        "norm1": L.norm_init(cfg.d_model, cfg.norm, dtype),
        "attn": L.attention_init(k1, cfg, dtype, cross=True),
        "norm2": L.norm_init(cfg.d_model, cfg.norm, dtype),
        "mlp": L.mlp_init(k2, cfg.d_model, cfg.d_ff, cfg.act_fn, dtype),
        "mlp_gate": jnp.zeros((), dtype),  # tanh-gated ffn (zero-init: identity at t=0)
    }


def _groups(cfg: ModelConfig) -> tuple[int, int]:
    per = cfg.cross_attn_every  # group = (per-1) self + 1 cross
    assert cfg.n_layers % per == 0, "n_layers must divide into (self*k + cross) groups"
    return cfg.n_layers // per, per - 1


def init(key, cfg: ModelConfig):
    dtype = jnp.dtype(cfg.param_dtype)
    n_groups, n_self = _groups(cfg)
    k_emb, k_self, k_cross, k_head = jax.random.split(key, 4)
    self_keys = jax.random.split(k_self, n_groups * n_self).reshape(n_groups, n_self, 2)
    cross_keys = jax.random.split(k_cross, n_groups)
    self_blocks = jax.vmap(jax.vmap(lambda k: _init_block(k, cfg, dtype)))(self_keys)
    cross_blocks = jax.vmap(lambda k: _init_cross_block(k, cfg, dtype))(cross_keys)
    params = {
        "embed": L.embed_init(k_emb, cfg.vocab_padded, cfg.d_model, dtype),
        "self_blocks": self_blocks,  # (G, n_self, ...)
        "cross_blocks": cross_blocks,  # (G, ...)
        "norm_f": L.norm_init(cfg.d_model, cfg.norm, dtype),
        "head": L.dense_init(k_head, cfg.d_model, cfg.vocab_padded, dtype),
    }
    return params


def init_caches(cfg: ModelConfig, batch: int, cache_len: int, dtype=jnp.bfloat16,
                quantized: bool = False):
    """Self-attn KV ring caches stacked (G, n_self, ...) + per-group cross-KV
    caches (populated at prefill, reused every decode step — recomputing
    cross K/V from 1601 image tokens per token was the vision decode cell's
    dominant compute, EXPERIMENTS §Perf V1)."""
    n_groups, n_self = _groups(cfg)
    one = lambda: L.init_kv_cache(cfg, batch, cache_len, dtype, quantized)
    stack = lambda xs: jax.tree.map(lambda *ys: jnp.stack(ys), *xs)
    self_caches = stack([stack([one() for _ in range(n_self)]) for _ in range(n_groups)])
    kv, hd = cfg.n_kv_heads, cfg.head_dim
    cross = {
        "ck": jnp.zeros((n_groups, batch, cfg.n_img_tokens, kv, hd), jnp.bfloat16),
        "cv": jnp.zeros((n_groups, batch, cfg.n_img_tokens, kv, hd), jnp.bfloat16),
    }
    return {"self": self_caches, "cross": cross}


def _cross_block_apply(p, x, cfg: ModelConfig, positions, memory, cache=None):
    a, new_cache = L.attention_apply(
        p["attn"], L.norm_apply(p["norm1"], x, cfg.norm), cfg,
        positions=positions, memory=memory, cache=cache, layer_tag="cross",
    )
    x = x + a  # attention_apply already applies the tanh attn gate
    m = L.mlp_apply(p["mlp"], L.norm_apply(p["norm2"], x, cfg.norm), cfg.act_fn)
    x = x + jnp.tanh(p["mlp_gate"].astype(m.dtype)) * m
    return constrain(x, "batch", "seq", "d_model"), new_cache


def apply(
    params,
    cfg: ModelConfig,
    tokens: jax.Array,
    *,
    image_embeds: jax.Array,  # (B, n_img_tokens, d_model) — stub frontend output
    positions=None,
    caches=None,
    last_only: bool = False,
    return_hidden_only: bool = False,
):
    b, s = tokens.shape
    if positions is None:
        positions = jnp.arange(s, dtype=jnp.int32)
    x = _embed_in(params, cfg, tokens, positions)
    memory = constrain(image_embeds.astype(x.dtype), "batch", None, "d_model")

    def group_body(carry, xs):
        h = carry
        if caches is None:
            self_ps, cross_p = xs
            def inner(hh, p):
                y, _ = _block_apply(p, hh, cfg, positions, None)
                return y, None
            h, _ = jax.lax.scan(inner, h, self_ps)
            h, _ = _cross_block_apply(cross_p, h, cfg, positions, memory)
            return h, None
        self_ps, cross_p, cs, cross_c = xs
        def inner_c(hh, pc):
            p, c = pc
            y, nc = _block_apply(p, hh, cfg, positions, c)
            return y, nc
        h, ncs = jax.lax.scan(inner_c, h, (self_ps, cs))
        h, new_cross = _cross_block_apply(cross_p, h, cfg, positions, memory, cross_c)
        return h, (ncs, new_cross)

    if cfg.remat == "block":
        group_body = jax.checkpoint(group_body)
    xs = (
        (params["self_blocks"], params["cross_blocks"])
        if caches is None
        else (params["self_blocks"], params["cross_blocks"], caches["self"],
              caches["cross"])
    )
    x, scanned = jax.lax.scan(group_body, x, xs)
    if caches is None:
        new_caches = None
    else:
        new_caches = {"self": scanned[0], "cross": scanned[1]}
    if last_only:
        x = x[:, -1:]
    if return_hidden_only:
        from repro.models.layers import norm_apply
        return norm_apply(params["norm_f"], x, cfg.norm), new_caches
    return _logits_out(params, cfg, x), new_caches
