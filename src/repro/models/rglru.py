"""RecurrentGemma / Griffin hybrid: RG-LRU recurrent blocks + local MQA attention.

Block pattern (1 attention : 2 recurrent), e.g. 26 layers =
8 x (rec, rec, attn) + (rec, rec). The stack is heterogeneous, so layers are
laid out as an unrolled loop over the expanded pattern (26 small blocks keeps
HLO manageable; the homogeneous families use scan).

RG-LRU recurrence (Griffin eqs. 1-4), elementwise over the LRU width:
    r_t = sigmoid(W_a u_t + b_a)          recurrence gate
    i_t = sigmoid(W_x u_t + b_x)          input gate
    a_t = exp(c * r_t * log(sigmoid(L)))  with c = 8
    h_t = a_t h_{t-1} + sqrt(1 - a_t^2) * (i_t * u_t)

The recurrence is elementwise -> the associative-scan helper from the mamba
module is reused with state size 1. Local attention uses the shared ring-
buffer KV cache with window = cfg.sliding_window, so long_500k decode holds
O(window) keys — this arch runs the 500k cell.
"""

from __future__ import annotations

import math

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig
from repro.distributed.sharding import constrain
from repro.models import layers as L
from repro.models.mamba import (
    _conv_causal,
    _packed_conv_tails,
    _ssm_scan,
    _ssm_scan_q,
    _take_final,
)

__all__ = ["init", "apply", "init_caches", "cache_policies", "expanded_pattern"]

_C_RGLRU = 8.0


def expanded_pattern(cfg: ModelConfig) -> tuple[str, ...]:
    reps = -(-cfg.n_layers // len(cfg.block_pattern))
    return (cfg.block_pattern * reps)[: cfg.n_layers]


def _init_rec_block(key, cfg: ModelConfig, dtype):
    d, di = cfg.d_model, cfg.d_inner or cfg.d_model
    ks = jax.random.split(key, 6)
    return {
        "norm1": L.norm_init(d, cfg.norm, dtype),
        "lin_y": L.dense_init(ks[0], d, di, dtype),
        "lin_x": L.dense_init(ks[1], d, di, dtype),
        "conv_w": (jax.random.normal(ks[2], (cfg.ssm_conv, di)) / math.sqrt(cfg.ssm_conv)).astype(dtype),
        "conv_b": jnp.zeros((di,), dtype),
        "w_a": L.dense_init(ks[3], di, di, dtype, bias=True),
        "w_x": L.dense_init(ks[4], di, di, dtype, bias=True),
        "lambda": jnp.full((di,), 2.0, jnp.float32),  # sigmoid -> a ~ 0.88
        "lin_out": L.dense_init(ks[5], di, d, dtype),
        "norm2": L.norm_init(d, cfg.norm, dtype),
        "mlp": L.mlp_init(jax.random.fold_in(key, 7), d, cfg.d_ff, cfg.act_fn, dtype),
    }


def _init_attn_block(key, cfg: ModelConfig, dtype):
    k1, k2 = jax.random.split(key)
    return {
        "norm1": L.norm_init(cfg.d_model, cfg.norm, dtype),
        "attn": L.attention_init(k1, cfg, dtype),
        "norm2": L.norm_init(cfg.d_model, cfg.norm, dtype),
        "mlp": L.mlp_init(k2, cfg.d_model, cfg.d_ff, cfg.act_fn, dtype),
    }


def init(key, cfg: ModelConfig):
    dtype = jnp.dtype(cfg.param_dtype)
    k_emb, k_blocks, k_head = jax.random.split(key, 3)
    keys = jax.random.split(k_blocks, cfg.n_layers)
    blocks = []
    for k, kind in zip(keys, expanded_pattern(cfg)):
        blocks.append(
            _init_rec_block(k, cfg, dtype) if kind == "rec" else _init_attn_block(k, cfg, dtype)
        )
    params = {
        "embed": L.embed_init(k_emb, cfg.vocab_padded, cfg.d_model, dtype),
        "blocks": blocks,
        "norm_f": L.norm_init(cfg.d_model, cfg.norm, dtype),
    }
    if not cfg.tie_embeddings:
        params["head"] = L.dense_init(k_head, cfg.d_model, cfg.vocab_padded, dtype)
    return params


def _rec_state(batch: int, di: int, cw: int, dtype, quantized: bool):
    """One RG-LRU layer's state: LRU h + conv tail. quantized=True stores h
    as K-Means int4 (layers.state_quantize over the width dim); the conv
    tail (cw-1 tokens) stays fp."""
    conv = jnp.zeros((batch, cw - 1, di), dtype)
    if not quantized:
        return {"h": jnp.zeros((batch, di), jnp.float32), "conv": conv}
    from repro.models.model import _default_codebook  # structural codebook

    return {
        "h_idx": jnp.zeros((batch, di // 2), jnp.uint8),
        "h_scale": jnp.zeros((batch, 1), jnp.float32),
        "conv": conv,
        "state_codebook": _default_codebook(4),
    }


def init_caches(cfg: ModelConfig, batch: int, cache_len: int, dtype=jnp.bfloat16,
                quantized: bool = False, layout: str = "ring",
                block_size: int = 16, n_blocks: int = 0):
    """Heterogeneous cache list: recurrent layers get slot-major state in
    EVERY layout (the recurrent policy costs zero blocks); attention layers
    get a ring buffer clamped to the window (layout="ring") or a share of
    the global paged pool with logical unclamped tables (layout="paged" —
    the scheduler's windowed_paged policy frees out-of-window blocks)."""
    di = cfg.d_inner or cfg.d_model
    if layout == "paged":
        if n_blocks <= 0:
            n_blocks = batch * -(-cache_len // block_size)
        attn_one = lambda: L.init_paged_kv_cache(cfg, n_blocks, block_size, dtype, quantized)
    else:
        kv_len = min(cache_len, cfg.sliding_window) if cfg.sliding_window else cache_len
        attn_one = lambda: L.init_kv_cache(cfg, batch, kv_len, dtype, quantized)
    caches = []
    for kind in expanded_pattern(cfg):
        if kind == "rec":
            caches.append(_rec_state(batch, di, cfg.ssm_conv, dtype, quantized))
        else:
            caches.append(attn_one())
    return caches


def cache_policies(cfg: ModelConfig):
    """Per-layer policies following the block pattern: rec -> recurrent
    (zero blocks, one pinned state slot), attn -> windowed paged KV (local
    attention always has a window in this family; fall back to full paged
    KV if a config clears it)."""
    from repro.serving.paged_cache import CachePolicy

    if cfg.sliding_window:
        attn = CachePolicy("windowed_paged", window=cfg.sliding_window)
    else:
        attn = CachePolicy("paged_kv")
    rec = CachePolicy("recurrent")
    return [rec if kind == "rec" else attn for kind in expanded_pattern(cfg)]


def _rglru_gates(p, u: jax.Array):
    """u: (B, S, di) post-conv. Returns (a_t, gated input), both f32."""
    uf = u.astype(jnp.float32)
    r = jax.nn.sigmoid(L.dense_apply(p["w_a"], u, "rglru.wa").astype(jnp.float32))
    i = jax.nn.sigmoid(L.dense_apply(p["w_x"], u, "rglru.wx").astype(jnp.float32))
    log_a = jax.nn.log_sigmoid(p["lambda"])  # (di,) < 0
    a = jnp.exp(_C_RGLRU * r * log_a)  # (B, S, di)
    gated = jnp.sqrt(jnp.maximum(1.0 - jnp.square(a), 1e-9)) * (i * uf)
    return a, gated


def _rec_block_apply(p, x, cfg: ModelConfig, cache, positions=None):
    """One RG-LRU block. Cache layouts mirror mamba._block_apply: ring
    {"h"|"h_idx"+"h_scale"+"state_codebook", "conv"}, or the packed serving
    layout (slot-major pools + "token_slots" + (G, S) positions with -1
    pads; one row per slot, valid cells a contiguous prefix) which emits
    per-cell "*_steps" transients for speculative rewind."""
    packed = cache is not None and "token_slots" in cache
    quantized = cache is not None and "h_idx" in cache
    residual = x
    n = L.norm_apply(p["norm1"], x, cfg.norm)
    y = jax.nn.gelu(L.dense_apply(p["lin_y"], n, "rec.lin_y"))
    u = L.dense_apply(p["lin_x"], n, "rec.lin_x")
    u = constrain(u, "batch", "seq", "d_inner")
    if packed:
        slots = cache["token_slots"]  # (G,)
        n_slots = cache["conv"].shape[0]
        n_valid = (positions >= 0).sum(axis=1)  # (G,)
        tail0 = cache["conv"][slots]
        tails = _packed_conv_tails(tail0, u, cfg.ssm_conv).astype(cache["conv"].dtype)
    else:
        tail0 = cache["conv"] if cache is not None else None
    u, new_tail = _conv_causal(u, p["conv_w"], p["conv_b"], tail0)

    if cache is None:
        h0 = jnp.zeros((x.shape[0], u.shape[-1]), jnp.float32)
    elif quantized:
        book = cache["state_codebook"]
        h0 = L.state_dequantize(
            cache["h_idx"][slots] if packed else cache["h_idx"],
            cache["h_scale"][slots] if packed else cache["h_scale"],
            book,
        )
    else:
        h0 = cache["h"][slots] if packed else cache["h"]

    a, gated = _rglru_gates(p, u)
    if quantized:
        hs, h_idx_steps, h_sc_steps = _ssm_scan_q(a, gated, h0, book)
        h_final = None
    else:
        ys, hf = _ssm_scan(a[..., None], gated[..., None], h0[..., None])
        hs, h_final = ys[..., 0], hf[..., 0]
    u = hs.astype(u.dtype)
    out = L.dense_apply(p["lin_out"], y * u, "rec.lin_out")
    x = residual + out
    x = x + L.mlp_apply(p["mlp"], L.norm_apply(p["norm2"], x, cfg.norm), cfg.act_fn)

    if cache is None:
        new_cache = None
    elif packed:
        sc_idx = jnp.where(n_valid > 0, slots, n_slots)
        if quantized:
            new_cache = dict(
                cache,
                h_idx=cache["h_idx"].at[sc_idx].set(
                    _take_final(h_idx_steps, n_valid), mode="drop"),
                h_scale=cache["h_scale"].at[sc_idx].set(
                    _take_final(h_sc_steps, n_valid), mode="drop"),
                conv=cache["conv"].at[sc_idx].set(
                    _take_final(tails, n_valid), mode="drop"),
                h_idx_steps=h_idx_steps,
                h_scale_steps=h_sc_steps,
                conv_steps=tails,
            )
        else:
            new_cache = dict(
                cache,
                h=cache["h"].at[sc_idx].set(_take_final(hs, n_valid), mode="drop"),
                conv=cache["conv"].at[sc_idx].set(
                    _take_final(tails, n_valid), mode="drop"),
                h_steps=hs,
                conv_steps=tails,
            )
    elif quantized:
        new_cache = {
            "h_idx": h_idx_steps[:, -1],
            "h_scale": h_sc_steps[:, -1],
            "conv": new_tail,
            "state_codebook": book,
        }
    else:
        new_cache = {"h": h_final, "conv": new_tail}
    return constrain(x, "batch", "seq_sp", "d_model"), new_cache


def _attn_block_apply(p, x, cfg: ModelConfig, positions, cache):
    a, new_cache = L.attention_apply(
        p["attn"], L.norm_apply(p["norm1"], x, cfg.norm), cfg,
        positions=positions, cache=cache, window=cfg.sliding_window,
    )
    x = x + a
    x = x + L.mlp_apply(p["mlp"], L.norm_apply(p["norm2"], x, cfg.norm), cfg.act_fn)
    return constrain(x, "batch", "seq_sp", "d_model"), new_cache


def apply(params, cfg: ModelConfig, tokens: jax.Array, *, positions=None, caches=None, last_only: bool = False, return_hidden_only: bool = False):
    from repro.models.transformer import _embed_in, _logits_out

    b, s = tokens.shape
    if positions is None:
        positions = jnp.arange(s, dtype=jnp.int32)
    x = _embed_in(params, cfg, tokens, positions)

    rec_fn, attn_fn = _rec_block_apply, _attn_block_apply
    if cfg.remat != "none" and caches is None:
        # the heterogeneous stack is unrolled, so remat must wrap each block
        # explicitly (the scan families checkpoint their scan body instead)
        rec_fn = jax.checkpoint(_rec_block_apply, static_argnums=(2,))
        attn_fn = jax.checkpoint(_attn_block_apply, static_argnums=(2,))

    new_caches = []
    for i, (p, kind) in enumerate(zip(params["blocks"], expanded_pattern(cfg))):
        c = None if caches is None else caches[i]
        if kind == "rec":
            x, nc = rec_fn(p, x, cfg, c, positions)
        else:
            x, nc = attn_fn(p, x, cfg, positions, c)
        new_caches.append(nc)
    if caches is None:
        new_caches = None
    if last_only:
        x = x[:, -1:]
    if return_hidden_only:
        from repro.models.layers import norm_apply
        return norm_apply(params["norm_f"], x, cfg.norm), new_caches
    return _logits_out(params, cfg, x), new_caches
