"""Unified model API: family dispatch + quantized-inference transformation.

``build(cfg)`` returns a :class:`Model` with a family-independent contract:

    params               = model.init(key)
    out                  = model.apply(params, batch)                 # train/prefill
    out                  = model.apply(params, batch, caches=...)     # decode
    caches               = model.init_caches(batch_size, cache_len)
    qparams              = quantize_model(model, params, spec, calib) # PTQ -> QLinearParams tree

``out`` is a :class:`ModelOutput` (logits, caches, aux_loss). ``batch`` is a
dict with "tokens" (B, S) and, for the VLM family, "image_embeds".

Quantization is policy-driven: ``quantize_model`` resolves a declarative
:class:`~repro.core.quantspec.QuantSpec` (ordered path-glob rules) to a
concrete per-projection :class:`QLinearConfig`, which is stored INSIDE each
produced :class:`QLinearParams` — apply-time behaviour travels with the
params, there is no ambient/global apply config.
"""

from __future__ import annotations

import dataclasses
from typing import Any

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig
from repro.core.qlinear import QLinearConfig, QLinearParams
from repro.core.quantize import fit_activation_codebook, quantize_weight
from repro.core.quantspec import QuantSpec
from repro.models import mamba, moe, multimodal, rglru, transformer

__all__ = ["Model", "ModelOutput", "build", "quantize_model", "quantize_params",
           "unstack_for_capture", "head_matrix"]

_FAMILY_MODULES = {
    "dense": transformer,
    "audio": transformer,  # musicgen backbone == decoder-only LM over codec tokens
    "moe": moe,
    "ssm": mamba,
    "hybrid": rglru,
    "vlm": multimodal,
}


@dataclasses.dataclass
class ModelOutput:
    logits: jax.Array | None  # (B, S, vocab_padded) f32 (None if hidden-only)
    caches: Any = None
    aux_loss: jax.Array | None = None
    hidden: jax.Array | None = None  # final-norm hidden states (B, S, d)


@dataclasses.dataclass(frozen=True)
class Model:
    cfg: ModelConfig

    @property
    def _mod(self):
        return _FAMILY_MODULES[self.cfg.family]

    def init(self, key) -> dict:
        return self._mod.init(key, self.cfg)

    def init_caches(self, batch: int, cache_len: int, dtype=jnp.bfloat16,
                    quantized: bool = False, layout: str = "ring",
                    block_size: int = 16, n_blocks: int = 0):
        """layout="ring" (every family) or "paged" (families exporting cache
        policies: dense/audio/moe/ssm/hybrid) — per-layer pools for the
        continuous-batching scheduler: a global block pool for (windowed)
        paged-KV layers, slot-indexed constant-size state for recurrent
        layers; see repro.serving.paged_cache."""
        if layout == "paged":
            if self.cache_policies() is None:
                raise ValueError(
                    f"family {self.cfg.family} exports no cache policies "
                    "(no paged serving layout)"
                )
            return self._mod.init_caches(self.cfg, batch, cache_len, dtype, quantized,
                                         layout="paged", block_size=block_size,
                                         n_blocks=n_blocks)
        return self._mod.init_caches(self.cfg, batch, cache_len, dtype, quantized)

    def cache_policies(self):
        """Per-layer :class:`~repro.serving.paged_cache.CachePolicy` list for
        the serving scheduler, or None when the family cannot serve through
        the packed paged step (vlm — the engine falls back to the fixed-slot
        ring path)."""
        fn = getattr(self._mod, "cache_policies", None)
        return None if fn is None else fn(self.cfg)

    def apply(self, params, batch: dict, *, positions=None, caches=None,
              last_only: bool = False, return_hidden_only: bool = False) -> ModelOutput:
        """``positions`` may be (S,) shared or (B, S) per-row — the latter is
        the serving scheduler's layout (per-request decode depths / the
        packed token-budget step, position -1 = unused row)."""
        kwargs = dict(positions=positions, caches=caches, last_only=last_only,
                      return_hidden_only=return_hidden_only)
        if self.cfg.family == "vlm":
            kwargs["image_embeds"] = batch["image_embeds"]
        out = self._mod.apply(params, self.cfg, batch["tokens"], **kwargs)
        if self.cfg.family == "moe":
            val, caches_out, aux = out
        else:
            (val, caches_out), aux = out, None
        if return_hidden_only:
            return ModelOutput(None, caches_out, aux, hidden=val)
        return ModelOutput(val, caches_out, aux)


def build(cfg: ModelConfig) -> Model:
    if cfg.family not in _FAMILY_MODULES:
        raise ValueError(f"unknown family {cfg.family}")
    return Model(cfg)


def head_matrix(model: Model, params) -> jax.Array:
    """(d, vocab_padded) unembedding matrix (transposed table when tied)."""
    if model.cfg.tie_embeddings:
        return params["embed"]["table"].T
    return params["head"]["w"]


def unstack_for_capture(model: Model, params):
    """(model, scan-stacked params) -> (unscanned model, per-layer param list).

    Calibration taps only fire in plain-Python forwards; scan bodies are
    traced, so capture requires the unrolled (scan_layers=False) variant.
    Supported for the single-stack families (dense/audio/moe/ssm)."""
    cfg = model.cfg
    if not cfg.scan_layers or cfg.family == "vlm":
        return model, params
    blocks = params["blocks"]
    n = jax.tree.leaves(blocks)[0].shape[0]
    blocks_list = [jax.tree.map(lambda a: a[i], blocks) for i in range(n)]
    cfg2 = dataclasses.replace(cfg, scan_layers=False)
    return build(cfg2), {**params, "blocks": blocks_list}


# ---------------------------------------------------------------------------
# PTQ parameter transformation
# ---------------------------------------------------------------------------

# Keys whose 'w' leaves are the paper-quantizable projections. Router weights,
# norms, embeddings and the lm head stay fp REGARDLESS of the spec (paper:
# norms/softmax fp16; router is tiny and accuracy-critical) — the spec decides
# which of the eligible projections are quantized and how.
_QUANT_KEYS = {
    "wq", "wk", "wv", "wo", "wi", "wd",
    "in_proj", "x_proj", "dt_proj", "out_proj",
    "lin_y", "lin_x", "lin_out", "w_a", "w_x",
}
_SKIP_KEYS = {"router", "head", "embed", "shared_gate"}

# param leaf key -> calibration tap name(s) it feeds (see dense_apply's
# tap_name plumbing in models/*.py). Cross-attention q/o taps are "cross.*";
# the path carries "cross" for those blocks, handled in _tap_candidates.
_TAP_OF = {
    "wq": ("attn.q",), "wk": ("attn.k",), "wv": ("attn.v",), "wo": ("attn.o",),
    "wi": ("mlp.wi",), "wd": ("mlp.wd",),
    "in_proj": ("mamba.in_proj",), "x_proj": ("mamba.x_proj",),
    "dt_proj": ("mamba.dt_proj",), "out_proj": ("mamba.out_proj",),
    "lin_y": ("rec.lin_y",), "lin_x": ("rec.lin_x",), "lin_out": ("rec.lin_out",),
    "w_a": ("rglru.wa",), "w_x": ("rglru.wx",),
}


def _default_codebook(nbits: int, method: str = "kmeans") -> jax.Array:
    """Structural activation codebook (gaussian quantiles) for when no
    calibration activations are available (dry-run / structural quantization).
    Real deployments calibrate via repro.core.calibration."""
    if method == "uniform":
        return jnp.linspace(-2.5, 2.5, 2**nbits)
    from jax.scipy.stats import norm as _norm

    qs = (jnp.arange(2**nbits, dtype=jnp.float32) + 0.5) / (2**nbits)
    return _norm.ppf(qs).astype(jnp.float32)


def quantize_model(model: Model, params, spec: QuantSpec,
                   calib: dict | None = None) -> dict:
    """PTQ a whole model under a declarative per-layer policy.

    ``spec`` is a :class:`~repro.core.quantspec.QuantSpec`: ordered
    ``(path-glob -> QLinearConfig overrides | skip)`` rules resolved against
    each quantizable projection's parameter path (e.g. ``blocks/attn/wq``).
    The resolved config is stored inside each produced
    :class:`QLinearParams`, so the returned tree is self-describing — serve
    it directly, or persist it with ``repro.core.artifact.save_quantized``.

    ``calib``: optional {tap_name: (tokens, K) activations} from
    ``core.calibration.capture`` — when provided, activation codebooks (and
    OASIS-S static thresholds) are learned per projection; otherwise the
    structural gaussian codebook is used.
    """
    # the param tree itself carries the structure the rules match against;
    # the model is used to catch params/model mix-ups before a shape error
    # surfaces deep inside apply
    expect = {"embed"}
    expect |= {"self_blocks", "cross_blocks"} if model.cfg.family == "vlm" else {"blocks"}
    if not model.cfg.tie_embeddings:
        expect |= {"head"}
    missing = expect - set(params)
    if missing:
        raise ValueError(
            f"params are missing {sorted(missing)} — not a parameter tree of "
            f"{model.cfg.arch_id} (family {model.cfg.family})"
        )
    return quantize_params(params, spec, calib)


def quantize_params(params, spec, calib: dict | None = None, path: str = ""):
    """Recursively replace quantizable fp linears with QLinearParams.

    ``spec`` may be a :class:`QuantSpec` or (backward compat) a bare
    :class:`QLinearConfig`, which behaves as a rule-free spec. Projections a
    rule resolves to ``skip`` keep their fp weight dict. Works on stacked
    (scan) params via vmap — note stacked projections share one path
    (``blocks/attn/wq``), so per-layer-index rules need scan_layers=False.
    """
    if isinstance(spec, QLinearConfig):
        spec = QuantSpec(base=spec)
    if isinstance(params, list):
        return [quantize_params(p, spec, calib, f"{path}/{i}" if path else str(i))
                for i, p in enumerate(params)]
    if not isinstance(params, dict):
        return params
    out = {}
    for k, v in params.items():
        sub = f"{path}/{k}" if path else k
        if k in _SKIP_KEYS:
            out[k] = v
        elif k in _QUANT_KEYS and isinstance(v, dict) and "w" in v:
            cfg = spec.resolve(sub)
            out[k] = v if cfg is None else _quantize_one(v, cfg, calib, sub)
        elif isinstance(v, (dict, list)):
            out[k] = quantize_params(v, spec, calib, sub)
        else:
            out[k] = v
    return out


def _quantize_one(p: dict, cfg: QLinearConfig, calib: dict | None, path: str):
    """Quantize one projection under its RESOLVED config (stored in the
    result's ``cfg`` meta field, so apply needs no outside configuration)."""
    w = p["w"]
    bias = p.get("b")

    def one(w2d, b1d):
        qw = quantize_weight(w2d.astype(jnp.float32), nbits=cfg.w_bits, method=cfg.method)
        book = _codebook_for(path, cfg, calib)
        thr_lo = thr_hi = None
        if cfg.detection in ("static", "static_dense"):
            acts = _calib_for(path, calib)
            if acts is not None:
                from repro.core.outlier import static_thresholds

                thr_lo, thr_hi = static_thresholds(acts, cfg.outlier_frac)
            else:
                thr_lo, thr_hi = jnp.float32(-3.0), jnp.float32(3.0)
        return QLinearParams(qw=qw, act_codebook=book, bias=b1d, thr_lo=thr_lo,
                             thr_hi=thr_hi, cfg=cfg)

    if w.ndim < 2:
        raise ValueError(f"unexpected weight rank {w.ndim} at {path}")
    # vmap over stacked scan axes (layers, or vlm's groups x layers)
    if bias is None:
        fn = lambda wi: one(wi, None)
        for _ in range(w.ndim - 2):
            fn = jax.vmap(fn)
        return fn(w)
    fn = one
    for _ in range(w.ndim - 2):
        fn = jax.vmap(fn)
    return fn(w, bias)


def _tap_candidates(path: str) -> tuple[str, ...]:
    """Calibration tap names that feed the projection at ``path``."""
    leaf = path.rsplit("/", 1)[-1]
    taps = _TAP_OF.get(leaf, (leaf,))
    if "cross" in path:  # vlm cross-attn blocks tap under layer_tag="cross"
        taps = tuple(t.replace("attn.", "cross.") for t in taps) + taps
    return taps


def _calib_for(path: str, calib: dict | None):
    """Captured activations for the projection at ``path``, or None.

    Tap names are projection-scoped ("attn.q", "mlp.wd", ...), not
    path-scoped: scanned stacks capture one pooled tensor per projection.
    Exact tap-name match first, then suffix match (unrolled captures may
    prefix names).
    """
    if not calib:
        return None
    for tap in _tap_candidates(path):
        if tap in calib:
            return calib[tap]
    for tap in _tap_candidates(path):
        for name, acts in calib.items():
            if name.endswith(tap):
                return acts
    return None


def _codebook_for(path: str, cfg: QLinearConfig, calib: dict | None):
    acts = _calib_for(path, calib)
    if acts is not None:
        return fit_activation_codebook(acts, nbits=cfg.a_bits,
                                       scale_mode=cfg.scale_mode, method=cfg.method)
    return _default_codebook(cfg.a_bits, cfg.method)
