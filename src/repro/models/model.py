"""Unified model API: family dispatch + quantized-inference transformation.

``build(cfg)`` returns a :class:`Model` with a family-independent contract:

    params               = model.init(key)
    out                  = model.apply(params, batch)                 # train/prefill
    out                  = model.apply(params, batch, caches=...)     # decode
    caches               = model.init_caches(batch_size, cache_len)
    qparams              = model.quantize(params, calib, qcfg)        # PTQ -> QLinearParams tree

``out`` is a :class:`ModelOutput` (logits, caches, aux_loss). ``batch`` is a
dict with "tokens" (B, S) and, for the VLM family, "image_embeds".
"""

from __future__ import annotations

import dataclasses
from typing import Any

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig
from repro.core.qlinear import QLinearConfig, QLinearParams
from repro.core.quantize import fit_activation_codebook, quantize_weight
from repro.models import mamba, moe, multimodal, rglru, transformer

__all__ = ["Model", "ModelOutput", "build", "quantize_params", "unstack_for_capture",
           "head_matrix"]

_FAMILY_MODULES = {
    "dense": transformer,
    "audio": transformer,  # musicgen backbone == decoder-only LM over codec tokens
    "moe": moe,
    "ssm": mamba,
    "hybrid": rglru,
    "vlm": multimodal,
}


@dataclasses.dataclass
class ModelOutput:
    logits: jax.Array | None  # (B, S, vocab_padded) f32 (None if hidden-only)
    caches: Any = None
    aux_loss: jax.Array | None = None
    hidden: jax.Array | None = None  # final-norm hidden states (B, S, d)


@dataclasses.dataclass(frozen=True)
class Model:
    cfg: ModelConfig

    @property
    def _mod(self):
        return _FAMILY_MODULES[self.cfg.family]

    def init(self, key) -> dict:
        return self._mod.init(key, self.cfg)

    def init_caches(self, batch: int, cache_len: int, dtype=jnp.bfloat16,
                    quantized: bool = False, layout: str = "ring",
                    block_size: int = 16, n_blocks: int = 0):
        """layout="ring" (every family) or "paged" (attention-cache families:
        dense/audio/moe) — a global block pool for the continuous-batching
        scheduler; see repro.serving.paged_cache."""
        if layout == "paged":
            if not self.supports_paged_cache():
                raise ValueError(f"family {self.cfg.family} has no paged KV cache")
            return self._mod.init_caches(self.cfg, batch, cache_len, dtype, quantized,
                                         layout="paged", block_size=block_size,
                                         n_blocks=n_blocks)
        return self._mod.init_caches(self.cfg, batch, cache_len, dtype, quantized)

    def supports_paged_cache(self) -> bool:
        return self.cfg.family in ("dense", "audio", "moe") and not self.cfg.sliding_window

    def apply(self, params, batch: dict, *, positions=None, caches=None,
              last_only: bool = False, return_hidden_only: bool = False) -> ModelOutput:
        """``positions`` may be (S,) shared or (B, S) per-row — the latter is
        the serving scheduler's layout (per-request decode depths / the
        packed token-budget step, position -1 = unused row)."""
        kwargs = dict(positions=positions, caches=caches, last_only=last_only,
                      return_hidden_only=return_hidden_only)
        if self.cfg.family == "vlm":
            kwargs["image_embeds"] = batch["image_embeds"]
        out = self._mod.apply(params, self.cfg, batch["tokens"], **kwargs)
        if self.cfg.family == "moe":
            val, caches_out, aux = out
        else:
            (val, caches_out), aux = out, None
        if return_hidden_only:
            return ModelOutput(None, caches_out, aux, hidden=val)
        return ModelOutput(val, caches_out, aux)

    def quantize(self, params, qcfg: QLinearConfig, calib: dict | None = None) -> dict:
        return quantize_params(params, qcfg, calib)


def build(cfg: ModelConfig) -> Model:
    if cfg.family not in _FAMILY_MODULES:
        raise ValueError(f"unknown family {cfg.family}")
    return Model(cfg)


def head_matrix(model: Model, params) -> jax.Array:
    """(d, vocab_padded) unembedding matrix (transposed table when tied)."""
    if model.cfg.tie_embeddings:
        return params["embed"]["table"].T
    return params["head"]["w"]


def unstack_for_capture(model: Model, params):
    """(model, scan-stacked params) -> (unscanned model, per-layer param list).

    Calibration taps only fire in plain-Python forwards; scan bodies are
    traced, so capture requires the unrolled (scan_layers=False) variant.
    Supported for the single-stack families (dense/audio/moe/ssm)."""
    cfg = model.cfg
    if not cfg.scan_layers or cfg.family == "vlm":
        return model, params
    blocks = params["blocks"]
    n = jax.tree.leaves(blocks)[0].shape[0]
    blocks_list = [jax.tree.map(lambda a: a[i], blocks) for i in range(n)]
    cfg2 = dataclasses.replace(cfg, scan_layers=False)
    return build(cfg2), {**params, "blocks": blocks_list}


# ---------------------------------------------------------------------------
# PTQ parameter transformation
# ---------------------------------------------------------------------------

# Keys whose 'w' leaves are the paper-quantizable projections. Router weights,
# norms, embeddings and the lm head stay fp (paper: norms/softmax fp16;
# router is tiny and accuracy-critical).
_QUANT_KEYS = {
    "wq", "wk", "wv", "wo", "wi", "wd",
    "in_proj", "x_proj", "dt_proj", "out_proj",
    "lin_y", "lin_x", "lin_out", "w_a", "w_x",
}
_SKIP_KEYS = {"router", "head", "embed", "shared_gate"}


def _default_codebook(nbits: int, method: str = "kmeans") -> jax.Array:
    """Structural activation codebook (gaussian quantiles) for when no
    calibration activations are available (dry-run / structural quantization).
    Real deployments calibrate via repro.core.calibration."""
    if method == "uniform":
        return jnp.linspace(-2.5, 2.5, 2**nbits)
    from jax.scipy.stats import norm as _norm

    qs = (jnp.arange(2**nbits, dtype=jnp.float32) + 0.5) / (2**nbits)
    return _norm.ppf(qs).astype(jnp.float32)


def quantize_params(params, qcfg: QLinearConfig, calib: dict | None = None, path: str = ""):
    """Recursively replace quantizable fp linears with QLinearParams.

    ``calib``: optional {tap_name: (tokens, K) activations} from
    ``core.calibration.capture`` — when provided, activation codebooks are
    learned per layer; otherwise the structural gaussian codebook is used.
    Works on stacked (scan) params via vmap.
    """
    if isinstance(params, list):
        return [quantize_params(p, qcfg, calib, f"{path}[{i}]") for i, p in enumerate(params)]
    if not isinstance(params, dict):
        return params
    out = {}
    for k, v in params.items():
        sub = f"{path}.{k}" if path else k
        if k in _SKIP_KEYS:
            out[k] = v
        elif k in _QUANT_KEYS and isinstance(v, dict) and "w" in v:
            out[k] = _quantize_one(v, qcfg, calib, sub)
        elif isinstance(v, (dict, list)):
            out[k] = quantize_params(v, qcfg, calib, sub)
        else:
            out[k] = v
    return out


def _quantize_one(p: dict, qcfg: QLinearConfig, calib: dict | None, path: str):
    w = p["w"]
    bias = p.get("b")

    def one(w2d, b1d):
        qw = quantize_weight(w2d.astype(jnp.float32), nbits=qcfg.w_bits, method=qcfg.method)
        book = _codebook_for(path, w2d.shape[0], qcfg, calib)
        thr_lo = thr_hi = None
        if qcfg.detection in ("static", "static_dense"):
            acts = _calib_for(path, calib)
            if acts is not None:
                from repro.core.outlier import static_thresholds

                thr_lo, thr_hi = static_thresholds(acts, qcfg.outlier_frac)
            else:
                thr_lo, thr_hi = jnp.float32(-3.0), jnp.float32(3.0)
        return QLinearParams(qw=qw, act_codebook=book, bias=b1d, thr_lo=thr_lo, thr_hi=thr_hi)

    if w.ndim < 2:
        raise ValueError(f"unexpected weight rank {w.ndim} at {path}")
    # vmap over stacked scan axes (layers, or vlm's groups x layers)
    if bias is None:
        fn = lambda wi: one(wi, None)
        for _ in range(w.ndim - 2):
            fn = jax.vmap(fn)
        return fn(w)
    fn = one
    for _ in range(w.ndim - 2):
        fn = jax.vmap(fn)
    return fn(w, bias)


def _calib_for(path: str, calib: dict | None):
    if not calib:
        return None
    leaf = path.split(".")[-1].split("[")[0]
    for name, acts in calib.items():
        if name.endswith(leaf) or leaf in name:
            return acts
    return None


def _codebook_for(path: str, k_dim: int, qcfg: QLinearConfig, calib: dict | None):
    acts = _calib_for(path, calib)
    if acts is not None:
        return fit_activation_codebook(acts, nbits=qcfg.a_bits,
                                       scale_mode=qcfg.scale_mode, method=qcfg.method)
    return _default_codebook(qcfg.a_bits, qcfg.method)
