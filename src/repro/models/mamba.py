"""Mamba-1 selective SSM decoder (falcon-mamba-7b).

Attention-free: each block is in_proj -> causal depthwise conv -> selective
SSM -> gated out_proj. Training uses a *chunked* associative scan (parallel
within a chunk, sequential across chunks) so the (B, T, d_inner, N) discretized
operands never materialize for the full sequence — the memory/throughput
trade-off is the chunk size. Decode carries an O(B * d_inner * N) state and a
(conv_w-1)-deep conv tail: long_500k decodes with **constant** memory, which
is why this arch runs the 500k cell.

Quantization applicability (DESIGN.md §5): in/x/dt/out projections are
QLinear-able GEMMs (the bulk of FLOPs/bytes); the recurrence itself is
elementwise and stays fp.
"""

from __future__ import annotations

import math

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig
from repro.distributed.sharding import constrain
from repro.models import layers as L

__all__ = ["init", "apply", "init_caches", "cache_policies"]

_CHUNK = 128  # associative-scan chunk (memory knob; halving it was measured at <1% HBM — the (B,S,di,N) scan output dominates, not the chunk workspace)


def _init_block(key, cfg: ModelConfig, dtype):
    d, di, n, r = cfg.d_model, cfg.d_inner, cfg.ssm_state, cfg.dt_rank
    ks = jax.random.split(key, 6)
    # S4D-real initialization for A; dt bias init for softplus in [1e-3, 1e-1]
    a_init = jnp.tile(jnp.arange(1, n + 1, dtype=jnp.float32)[None, :], (di, 1))
    dt = jnp.exp(
        jax.random.uniform(ks[0], (di,)) * (math.log(0.1) - math.log(1e-3)) + math.log(1e-3)
    )
    return {
        "norm": L.norm_init(d, cfg.norm, dtype),
        "in_proj": L.dense_init(ks[1], d, 2 * di, dtype),
        "conv_w": (jax.random.normal(ks[2], (cfg.ssm_conv, di)) / math.sqrt(cfg.ssm_conv)).astype(dtype),
        "conv_b": jnp.zeros((di,), dtype),
        "x_proj": L.dense_init(ks[3], di, r + 2 * n, dtype),
        "dt_proj": L.dense_init(ks[4], r, di, dtype, bias=True),
        "dt_bias": jnp.log(jnp.expm1(dt)).astype(jnp.float32),  # inverse softplus
        "A_log": jnp.log(a_init),
        "D": jnp.ones((di,), jnp.float32),
        "out_proj": L.dense_init(ks[5], di, d, dtype),
    }


def init(key, cfg: ModelConfig):
    dtype = jnp.dtype(cfg.param_dtype)
    k_emb, k_blocks, k_head = jax.random.split(key, 3)
    keys = jax.random.split(k_blocks, cfg.n_layers)
    blocks = (
        jax.vmap(lambda k: _init_block(k, cfg, dtype))(keys)
        if cfg.scan_layers
        else [_init_block(k, cfg, dtype) for k in keys]
    )
    params = {
        "embed": L.embed_init(k_emb, cfg.vocab_padded, cfg.d_model, dtype),
        "blocks": blocks,
        "norm_f": L.norm_init(cfg.d_model, cfg.norm, dtype),
    }
    if not cfg.tie_embeddings:
        params["head"] = L.dense_init(k_head, cfg.d_model, cfg.vocab_padded, dtype)
    return params


def _state_cache(batch: int, di: int, n: int, cw: int, dtype, quantized: bool):
    """One layer's recurrent state: SSM state h + conv tail.

    quantized=True stores h as K-Means int4 (layers.state_quantize format:
    packed indices + per-row RMS scale + shared codebook); the conv tail stays
    fp — it is cw-1 tokens, not O(context), so there is nothing to save.
    """
    conv = jnp.zeros((batch, cw - 1, di), dtype)
    if not quantized:
        return {"h": jnp.zeros((batch, di, n), jnp.float32), "conv": conv}
    from repro.models.model import _default_codebook  # structural codebook

    return {
        "h_idx": jnp.zeros((batch, di, n // 2), jnp.uint8),
        "h_scale": jnp.zeros((batch, di, 1), jnp.float32),
        "conv": conv,
        "state_codebook": _default_codebook(4),
    }


def init_caches(cfg: ModelConfig, batch: int, cache_len: int = 0, dtype=jnp.float32,
                quantized: bool = False, layout: str = "ring",
                block_size: int = 16, n_blocks: int = 0):
    """SSM state + conv tail per layer (cache_len unused: state is O(1)).

    ``layout`` exists for interface parity with the attention families: the
    paged serving path indexes the SAME slot-major state arrays by scheduler
    slot (the ``recurrent`` cache policy costs zero KV blocks), so both
    layouts return identical trees. quantized=True -> int4 K-Means state
    (see _state_cache)."""
    del layout, block_size, n_blocks  # state is slot-major in every layout
    di, n, cw = cfg.d_inner, cfg.ssm_state, cfg.ssm_conv
    one = lambda: _state_cache(batch, di, n, cw, dtype, quantized)
    if cfg.scan_layers:
        return jax.tree.map(lambda *xs: jnp.stack(xs), *[one() for _ in range(cfg.n_layers)])
    return [one() for _ in range(cfg.n_layers)]


def cache_policies(cfg: ModelConfig):
    """Every Mamba block carries O(1) recurrent state: zero KV blocks, one
    pinned state slot per request (snapshot/rollback handled host-side by the
    scheduler + draft runner)."""
    from repro.serving.paged_cache import CachePolicy

    return [CachePolicy("recurrent")] * cfg.n_layers


def _conv_causal(x, w, b, tail=None):
    """Depthwise causal conv. x: (B, S, di); w: (cw, di). tail: (B, cw-1, di)."""
    cw = w.shape[0]
    pad = tail if tail is not None else jnp.zeros((x.shape[0], cw - 1, x.shape[-1]), x.dtype)
    xp = jnp.concatenate([pad, x], axis=1)
    y = sum(xp[:, i : i + x.shape[1]] * w[i].astype(x.dtype) for i in range(cw))
    new_tail = xp[:, -(cw - 1) :] if cw > 1 else None
    return y + b.astype(x.dtype), new_tail


def _ssm_scan(a, bx, h0):
    """Chunked linear recurrence h_t = a_t*h_{t-1} + bx_t.

    a, bx: (B, S, di, N) f32; h0: (B, di, N). Returns (ys (B,S,di,N), h_S).
    """
    bsz, s, di, n = a.shape
    chunk = min(_CHUNK, s)
    if s % chunk:
        raise ValueError(f"seq {s} must be divisible by scan chunk {chunk}")
    nc = s // chunk
    a_c = a.reshape(bsz, nc, chunk, di, n).swapaxes(0, 1)
    b_c = bx.reshape(bsz, nc, chunk, di, n).swapaxes(0, 1)

    def combine(l, r):
        al, bl = l
        ar, br = r
        return al * ar, bl * ar + br

    def chunk_step(h, xs):
        ac, bc = xs  # (B, chunk, di, N)
        a_cum, b_cum = jax.lax.associative_scan(combine, (ac, bc), axis=1)
        hs = a_cum * h[:, None] + b_cum
        return hs[:, -1], hs

    h_final, ys = jax.lax.scan(chunk_step, h0, (a_c, b_c))
    ys = ys.swapaxes(0, 1).reshape(bsz, s, di, n)
    return ys, h_final


def _ssm_scan_q(a, bx, h0, codebook):
    """Sequential recurrence with PER-TOKEN state requantization.

    The carry entering step t is always deq(quant(h_{t-1})): position t's
    state depends only on the token stream, never on how a sequence was
    chunked, so ring decode (one token per step) and the packed serving
    layout (multi-token rows) produce bit-identical states. a, bx:
    (B, S, ...) f32; h0 fp (already dequantized). Returns (hs fp per step,
    h_idx per step, h_scale per step) — y uses the fp pre-quantization hs.
    """

    def step(h, ab):
        at, bt = ab
        hn = at * h + bt
        idx, sc = L.state_quantize(hn, codebook)
        return L.state_dequantize(idx, sc, codebook), (hn, idx, sc)

    _, (hs, idxs, scs) = jax.lax.scan(step, h0, (a.swapaxes(0, 1), bx.swapaxes(0, 1)))
    return hs.swapaxes(0, 1), idxs.swapaxes(0, 1), scs.swapaxes(0, 1)


def _packed_conv_tails(tail0, xs, cw):
    """Per-cell conv tails for the packed layout. tail0: (G, cw-1, di) tail
    gathered by slot; xs: (G, S, di) raw pre-conv inputs. Returns
    (G, S, cw-1, di): cell i holds the tail AFTER consuming tokens 0..i."""
    z = jnp.concatenate([tail0.astype(xs.dtype), xs], axis=1)
    idx = jnp.arange(xs.shape[1])[:, None] + jnp.arange(1, cw)[None, :]
    return z[:, idx]


def _take_final(steps, n_valid):
    """steps: (G, S, ...) per-cell values; pick index n_valid-1 per row
    (clamped to 0 for all-pad rows, whose scatter is dropped anyway)."""
    g = steps.shape[0]
    i = jnp.clip(n_valid - 1, 0).astype(jnp.int32)
    i = i.reshape((g,) + (1,) * (steps.ndim - 1))
    return jnp.take_along_axis(steps, i, axis=1)[:, 0]


def _block_apply(p, x, cfg: ModelConfig, cache, positions=None):
    """One Mamba block. x: (B, S, d).

    Cache layouts:
      * ring (training=None / decode): {"h", "conv"}, or {"h_idx", "h_scale",
        "conv", "state_codebook"} when the state is int4 K-Means quantized.
      * packed serving: the same slot-major pools plus a "token_slots" (G,)
        row->slot map and (G, S) positions with -1 marking pad cells. Each
        scheduler slot appears in AT MOST ONE row per dispatch and a row's
        valid cells are a contiguous prefix (the scheduler/draft runner
        enforce both). The block gathers state by slot, runs the row, and
        scatters back the state at the LAST VALID cell (all-pad rows are
        dropped). It also emits per-cell "*_steps" transients so the
        scheduler can rewind a speculative row to its last accepted token
        (see paged_cache.split_step_extras).
    """
    di, n, r = cfg.d_inner, cfg.ssm_state, cfg.dt_rank
    packed = cache is not None and "token_slots" in cache
    quantized = cache is not None and "h_idx" in cache
    residual = x
    x = L.norm_apply(p["norm"], x, cfg.norm)
    xz = L.dense_apply(p["in_proj"], x, "mamba.in_proj")
    xs, z = jnp.split(xz, 2, axis=-1)
    xs = constrain(xs, "batch", "seq", "d_inner")

    if packed:
        slots = cache["token_slots"]  # (G,)
        n_slots = cache["conv"].shape[0]
        n_valid = (positions >= 0).sum(axis=1)  # (G,)
        tail0 = cache["conv"][slots]
        tails = _packed_conv_tails(tail0, xs, cfg.ssm_conv).astype(cache["conv"].dtype)
    else:
        tail0 = cache["conv"] if cache is not None else None
    xs, new_tail = _conv_causal(xs, p["conv_w"], p["conv_b"], tail0)
    xs = jax.nn.silu(xs)

    proj = L.dense_apply(p["x_proj"], xs, "mamba.x_proj").astype(jnp.float32)
    dt, bmat, cmat = jnp.split(proj, [r, r + n], axis=-1)
    dt = jax.nn.softplus(
        L.dense_apply(p["dt_proj"], dt.astype(xs.dtype), "mamba.dt_proj").astype(jnp.float32)
        + p["dt_bias"]
    )  # (B, S, di)
    a = -jnp.exp(p["A_log"])  # (di, N)
    xf = xs.astype(jnp.float32)

    a_bar = jnp.exp(dt[..., None] * a)  # (B, S, di, N)
    bx = (dt * xf)[..., None] * bmat[..., None, :]  # (B, S, di, N)

    if cache is None:
        h0 = jnp.zeros((x.shape[0], di, n), jnp.float32)
    elif quantized:
        book = cache["state_codebook"]
        h0 = L.state_dequantize(
            cache["h_idx"][slots] if packed else cache["h_idx"],
            cache["h_scale"][slots] if packed else cache["h_scale"],
            book,
        )
    else:
        h0 = cache["h"][slots] if packed else cache["h"]

    if quantized:
        hs, h_idx_steps, h_sc_steps = _ssm_scan_q(a_bar, bx, h0, book)
    else:
        hs, h_final = _ssm_scan(a_bar, bx, h0)
    y = jnp.einsum("bsdn,bsn->bsd", hs, cmat) + p["D"] * xf  # (B, S, di)
    y = (y.astype(xs.dtype)) * jax.nn.silu(z)
    y = constrain(y, "batch", "seq", "d_inner")
    out = L.dense_apply(p["out_proj"], y, "mamba.out_proj")

    if cache is None:
        new_cache = None
    elif packed:
        # Pad cells are TRAILING, so the conv/scan values at valid cells are
        # untouched by garbage pad tokens; the state at cell n_valid-1 is the
        # row's true final state. Rows with zero valid cells scatter
        # out-of-bounds and are dropped.
        sc_idx = jnp.where(n_valid > 0, slots, n_slots)
        if quantized:
            new_cache = dict(
                cache,
                h_idx=cache["h_idx"].at[sc_idx].set(
                    _take_final(h_idx_steps, n_valid), mode="drop"),
                h_scale=cache["h_scale"].at[sc_idx].set(
                    _take_final(h_sc_steps, n_valid), mode="drop"),
                conv=cache["conv"].at[sc_idx].set(
                    _take_final(tails, n_valid), mode="drop"),
                h_idx_steps=h_idx_steps,
                h_scale_steps=h_sc_steps,
                conv_steps=tails,
            )
        else:
            new_cache = dict(
                cache,
                h=cache["h"].at[sc_idx].set(_take_final(hs, n_valid), mode="drop"),
                conv=cache["conv"].at[sc_idx].set(
                    _take_final(tails, n_valid), mode="drop"),
                h_steps=hs,
                conv_steps=tails,
            )
    elif quantized:
        new_cache = {
            "h_idx": h_idx_steps[:, -1],
            "h_scale": h_sc_steps[:, -1],
            "conv": new_tail,
            "state_codebook": book,
        }
    else:
        new_cache = {"h": h_final, "conv": new_tail}
    return residual + out, new_cache


def apply(params, cfg: ModelConfig, tokens: jax.Array, *, positions=None, caches=None, last_only: bool = False, return_hidden_only: bool = False):
    from repro.models.transformer import _embed_in, _logits_out

    b, s = tokens.shape
    if positions is None:
        positions = jnp.arange(s, dtype=jnp.int32)
    x = _embed_in(params, cfg, tokens, positions)

    if cfg.scan_layers:
        def body(carry, xs):
            if caches is None:
                y, _ = _block_apply(xs, carry, cfg, None)
                return y, None
            p, c = xs
            y, nc = _block_apply(p, carry, cfg, c, positions)
            return y, nc

        if cfg.remat == "block":
            body = jax.checkpoint(body)
        xs = params["blocks"] if caches is None else (params["blocks"], caches)
        x, new_caches = jax.lax.scan(body, x, xs)
    else:
        new_caches = []
        for i, p in enumerate(params["blocks"]):
            c = None if caches is None else caches[i]
            x, nc = _block_apply(p, x, cfg, c, positions)
            new_caches.append(nc)
        if caches is None:
            new_caches = None
    if last_only:
        x = x[:, -1:]
    if return_hidden_only:
        from repro.models.layers import norm_apply
        return norm_apply(params["norm_f"], x, cfg.norm), new_caches
    return _logits_out(params, cfg, x), new_caches
