"""Mamba-1 selective SSM decoder (falcon-mamba-7b).

Attention-free: each block is in_proj -> causal depthwise conv -> selective
SSM -> gated out_proj. Training uses a *chunked* associative scan (parallel
within a chunk, sequential across chunks) so the (B, T, d_inner, N) discretized
operands never materialize for the full sequence — the memory/throughput
trade-off is the chunk size. Decode carries an O(B * d_inner * N) state and a
(conv_w-1)-deep conv tail: long_500k decodes with **constant** memory, which
is why this arch runs the 500k cell.

Quantization applicability (DESIGN.md §5): in/x/dt/out projections are
QLinear-able GEMMs (the bulk of FLOPs/bytes); the recurrence itself is
elementwise and stays fp.
"""

from __future__ import annotations

import math

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig
from repro.distributed.sharding import constrain
from repro.models import layers as L

__all__ = ["init", "apply", "init_caches"]

_CHUNK = 128  # associative-scan chunk (memory knob; halving it was measured at <1% HBM — the (B,S,di,N) scan output dominates, not the chunk workspace)


def _init_block(key, cfg: ModelConfig, dtype):
    d, di, n, r = cfg.d_model, cfg.d_inner, cfg.ssm_state, cfg.dt_rank
    ks = jax.random.split(key, 6)
    # S4D-real initialization for A; dt bias init for softplus in [1e-3, 1e-1]
    a_init = jnp.tile(jnp.arange(1, n + 1, dtype=jnp.float32)[None, :], (di, 1))
    dt = jnp.exp(
        jax.random.uniform(ks[0], (di,)) * (math.log(0.1) - math.log(1e-3)) + math.log(1e-3)
    )
    return {
        "norm": L.norm_init(d, cfg.norm, dtype),
        "in_proj": L.dense_init(ks[1], d, 2 * di, dtype),
        "conv_w": (jax.random.normal(ks[2], (cfg.ssm_conv, di)) / math.sqrt(cfg.ssm_conv)).astype(dtype),
        "conv_b": jnp.zeros((di,), dtype),
        "x_proj": L.dense_init(ks[3], di, r + 2 * n, dtype),
        "dt_proj": L.dense_init(ks[4], r, di, dtype, bias=True),
        "dt_bias": jnp.log(jnp.expm1(dt)).astype(jnp.float32),  # inverse softplus
        "A_log": jnp.log(a_init),
        "D": jnp.ones((di,), jnp.float32),
        "out_proj": L.dense_init(ks[5], di, d, dtype),
    }


def init(key, cfg: ModelConfig):
    dtype = jnp.dtype(cfg.param_dtype)
    k_emb, k_blocks, k_head = jax.random.split(key, 3)
    keys = jax.random.split(k_blocks, cfg.n_layers)
    blocks = (
        jax.vmap(lambda k: _init_block(k, cfg, dtype))(keys)
        if cfg.scan_layers
        else [_init_block(k, cfg, dtype) for k in keys]
    )
    params = {
        "embed": L.embed_init(k_emb, cfg.vocab_padded, cfg.d_model, dtype),
        "blocks": blocks,
        "norm_f": L.norm_init(cfg.d_model, cfg.norm, dtype),
    }
    if not cfg.tie_embeddings:
        params["head"] = L.dense_init(k_head, cfg.d_model, cfg.vocab_padded, dtype)
    return params


def init_caches(cfg: ModelConfig, batch: int, cache_len: int = 0, dtype=jnp.float32,
                quantized: bool = False):
    """SSM state + conv tail per layer (cache_len unused: state is O(1);
    quantized is a no-op — there is no KV cache to quantize)."""
    di, n, cw = cfg.d_inner, cfg.ssm_state, cfg.ssm_conv
    one = lambda: {
        "h": jnp.zeros((batch, di, n), jnp.float32),
        "conv": jnp.zeros((batch, cw - 1, di), dtype),
    }
    if cfg.scan_layers:
        return jax.tree.map(lambda *xs: jnp.stack(xs), *[one() for _ in range(cfg.n_layers)])
    return [one() for _ in range(cfg.n_layers)]


def _conv_causal(x, w, b, tail=None):
    """Depthwise causal conv. x: (B, S, di); w: (cw, di). tail: (B, cw-1, di)."""
    cw = w.shape[0]
    pad = tail if tail is not None else jnp.zeros((x.shape[0], cw - 1, x.shape[-1]), x.dtype)
    xp = jnp.concatenate([pad, x], axis=1)
    y = sum(xp[:, i : i + x.shape[1]] * w[i].astype(x.dtype) for i in range(cw))
    new_tail = xp[:, -(cw - 1) :] if cw > 1 else None
    return y + b.astype(x.dtype), new_tail


def _ssm_scan(a, bx, h0):
    """Chunked linear recurrence h_t = a_t*h_{t-1} + bx_t.

    a, bx: (B, S, di, N) f32; h0: (B, di, N). Returns (ys (B,S,di,N), h_S).
    """
    bsz, s, di, n = a.shape
    chunk = min(_CHUNK, s)
    if s % chunk:
        raise ValueError(f"seq {s} must be divisible by scan chunk {chunk}")
    nc = s // chunk
    a_c = a.reshape(bsz, nc, chunk, di, n).swapaxes(0, 1)
    b_c = bx.reshape(bsz, nc, chunk, di, n).swapaxes(0, 1)

    def combine(l, r):
        al, bl = l
        ar, br = r
        return al * ar, bl * ar + br

    def chunk_step(h, xs):
        ac, bc = xs  # (B, chunk, di, N)
        a_cum, b_cum = jax.lax.associative_scan(combine, (ac, bc), axis=1)
        hs = a_cum * h[:, None] + b_cum
        return hs[:, -1], hs

    h_final, ys = jax.lax.scan(chunk_step, h0, (a_c, b_c))
    ys = ys.swapaxes(0, 1).reshape(bsz, s, di, n)
    return ys, h_final


def _block_apply(p, x, cfg: ModelConfig, cache):
    """One Mamba block. x: (B, S, d)."""
    di, n, r = cfg.d_inner, cfg.ssm_state, cfg.dt_rank
    residual = x
    x = L.norm_apply(p["norm"], x, cfg.norm)
    xz = L.dense_apply(p["in_proj"], x, "mamba.in_proj")
    xs, z = jnp.split(xz, 2, axis=-1)
    xs = constrain(xs, "batch", "seq", "d_inner")

    tail = cache["conv"] if cache is not None else None
    xs, new_tail = _conv_causal(xs, p["conv_w"], p["conv_b"], tail)
    xs = jax.nn.silu(xs)

    proj = L.dense_apply(p["x_proj"], xs, "mamba.x_proj").astype(jnp.float32)
    dt, bmat, cmat = jnp.split(proj, [r, r + n], axis=-1)
    dt = jax.nn.softplus(
        L.dense_apply(p["dt_proj"], dt.astype(xs.dtype), "mamba.dt_proj").astype(jnp.float32)
        + p["dt_bias"]
    )  # (B, S, di)
    a = -jnp.exp(p["A_log"])  # (di, N)
    xf = xs.astype(jnp.float32)

    a_bar = jnp.exp(dt[..., None] * a)  # (B, S, di, N)
    bx = (dt * xf)[..., None] * bmat[..., None, :]  # (B, S, di, N)

    h0 = (
        cache["h"]
        if cache is not None
        else jnp.zeros((x.shape[0], di, n), jnp.float32)
    )
    hs, h_final = _ssm_scan(a_bar, bx, h0)
    y = jnp.einsum("bsdn,bsn->bsd", hs, cmat) + p["D"] * xf  # (B, S, di)
    y = (y.astype(xs.dtype)) * jax.nn.silu(z)
    y = constrain(y, "batch", "seq", "d_inner")
    out = L.dense_apply(p["out_proj"], y, "mamba.out_proj")
    new_cache = None if cache is None else {"h": h_final, "conv": new_tail}
    return residual + out, new_cache


def apply(params, cfg: ModelConfig, tokens: jax.Array, *, positions=None, caches=None, last_only: bool = False, return_hidden_only: bool = False):
    from repro.models.transformer import _embed_in, _logits_out

    b, s = tokens.shape
    if positions is None:
        positions = jnp.arange(s, dtype=jnp.int32)
    x = _embed_in(params, cfg, tokens, positions)

    if cfg.scan_layers:
        def body(carry, xs):
            if caches is None:
                y, _ = _block_apply(xs, carry, cfg, None)
                return y, None
            p, c = xs
            y, nc = _block_apply(p, carry, cfg, c)
            return y, nc

        if cfg.remat == "block":
            body = jax.checkpoint(body)
        xs = params["blocks"] if caches is None else (params["blocks"], caches)
        x, new_caches = jax.lax.scan(body, x, xs)
    else:
        new_caches = []
        for i, p in enumerate(params["blocks"]):
            c = None if caches is None else caches[i]
            x, nc = _block_apply(p, x, cfg, c)
            new_caches.append(nc)
        if caches is None:
            new_caches = None
    if last_only:
        x = x[:, -1:]
    if return_hidden_only:
        from repro.models.layers import norm_apply
        return norm_apply(params["norm_f"], x, cfg.norm), new_caches
    return _logits_out(params, cfg, x), new_caches
