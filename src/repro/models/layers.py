"""Shared building blocks for the model zoo.

Conventions
-----------
* Params are nested dicts of arrays (no framework). A stacked layer axis
  (leading L) is used with ``lax.scan`` so HLO size is O(1) in depth.
* Every projection goes through :func:`dense_apply`, which dispatches between
  a plain fp weight dict and :class:`~repro.core.qlinear.QLinearParams` —
  quantized inference is a drop-in parameter transformation, not a separate
  model definition.
* Tensors are annotated with *logical* axis names via
  ``repro.distributed.sharding.constrain``; the active rule set decides the
  mesh mapping (DP/TP/SP) — model code is mesh-agnostic.
* Attention is memory-efficient when ``chunk > 0``: nested scans over query /
  key chunks with an online-softmax accumulator (flash-style), which is what
  makes the 32k prefill shapes compile within HBM.
"""

from __future__ import annotations

import math
import os

import jax
import jax.numpy as jnp

from repro.core import calibration, numerics
from repro.core.qlinear import QLinearParams, qlinear_apply
from repro.distributed.sharding import constrain

__all__ = [
    "dense_init",
    "dense_apply",
    "norm_init",
    "norm_apply",
    "embed_init",
    "rope_apply",
    "sinusoidal_positions",
    "attention_init",
    "attention_apply",
    "init_kv_cache",
    "init_paged_kv_cache",
    "state_quantize",
    "state_dequantize",
    "mlp_init",
    "mlp_apply",
]

_NEG_INF = float(jnp.finfo(jnp.float32).min)


# ---------------------------------------------------------------------------
# dense / norm / embed primitives
# ---------------------------------------------------------------------------

def dense_init(key, d_in: int, d_out: int, dtype, bias: bool = False, scale: float | None = None):
    s = scale if scale is not None else 1.0 / math.sqrt(d_in)
    p = {"w": (jax.random.normal(key, (d_in, d_out)) * s).astype(dtype)}
    if bias:
        p["b"] = jnp.zeros((d_out,), dtype)
    return p


def dense_apply(p, x: jax.Array, tap_name: str | None = None) -> jax.Array:
    """fp or quantized projection; taps activations during calibration.

    QLinearParams carry their own resolved apply config (``p.cfg``, set by
    the QuantSpec at quantize time) — no ambient configuration is consulted.
    """
    if tap_name is not None and not isinstance(x, jax.core.Tracer):
        x = calibration.tap(tap_name, x)
    if isinstance(p, QLinearParams):
        # names the next quant-health probe site (works on tracers, unlike
        # calibration.tap); no-op unless a numerics collector is active
        numerics.announce(tap_name)
        return qlinear_apply(p, x)
    y = x @ p["w"].astype(x.dtype)
    if "b" in p:
        y = y + p["b"].astype(x.dtype)
    return y


def norm_init(d: int, kind: str, dtype):
    p = {"scale": jnp.ones((d,), dtype)}
    if kind == "layer":
        p["bias"] = jnp.zeros((d,), dtype)
    return p


def norm_apply(p, x: jax.Array, kind: str, eps: float = 1e-6) -> jax.Array:
    xf = x.astype(jnp.float32)
    if kind == "rms":
        y = xf * jax.lax.rsqrt(jnp.mean(jnp.square(xf), axis=-1, keepdims=True) + eps)
    elif kind == "layer":
        mu = jnp.mean(xf, axis=-1, keepdims=True)
        var = jnp.mean(jnp.square(xf - mu), axis=-1, keepdims=True)
        y = (xf - mu) * jax.lax.rsqrt(var + eps)
    else:
        raise ValueError(kind)
    y = y * p["scale"].astype(jnp.float32)
    if kind == "layer":
        y = y + p["bias"].astype(jnp.float32)
    return y.astype(x.dtype)


def embed_init(key, vocab: int, d: int, dtype):
    return {"table": (jax.random.normal(key, (vocab, d)) * 0.02).astype(dtype)}


def sinusoidal_positions(positions: jax.Array, d: int) -> jax.Array:
    """Classic transformer sinusoidal embedding, (..., d)."""
    half = d // 2
    freq = jnp.exp(-math.log(10_000.0) * jnp.arange(half, dtype=jnp.float32) / half)
    ang = positions.astype(jnp.float32)[..., None] * freq
    return jnp.concatenate([jnp.sin(ang), jnp.cos(ang)], axis=-1)


# ---------------------------------------------------------------------------
# RoPE
# ---------------------------------------------------------------------------

def rope_apply(x: jax.Array, positions: jax.Array, theta: float) -> jax.Array:
    """Rotary embedding. x: (B, S, ..., hd); positions: (S,) or (B, S).

    2-D positions carry a per-request absolute position — the continuous-
    batching decode path, where every batch row sits at a different point in
    its own sequence.
    """
    hd = x.shape[-1]
    half = hd // 2
    freq = theta ** (-jnp.arange(half, dtype=jnp.float32) / half)
    ang = positions.astype(jnp.float32)[..., None] * freq  # (..., half)
    # broadcast ((B,) S, 1..., half) against x's (B, S, ..., half)
    ang = ang.reshape(*positions.shape, *([1] * (x.ndim - 3)), half)
    sin, cos = jnp.sin(ang), jnp.cos(ang)
    x1, x2 = x[..., :half], x[..., half:]
    xf1, xf2 = x1.astype(jnp.float32), x2.astype(jnp.float32)
    return jnp.concatenate([xf1 * cos - xf2 * sin, xf2 * cos + xf1 * sin], axis=-1).astype(x.dtype)


# ---------------------------------------------------------------------------
# attention
# ---------------------------------------------------------------------------

def attention_init(key, cfg, dtype, cross: bool = False):
    d, h, kv, hd = cfg.d_model, cfg.n_heads, cfg.n_kv_heads, cfg.head_dim
    ks = jax.random.split(key, 4)
    p = {
        "wq": dense_init(ks[0], d, h * hd, dtype),
        "wk": dense_init(ks[1], d, kv * hd, dtype),
        "wv": dense_init(ks[2], d, kv * hd, dtype),
        "wo": dense_init(ks[3], h * hd, d, dtype, scale=1.0 / math.sqrt(h * hd)),
    }
    if cross:
        p["gate"] = jnp.zeros((), dtype)  # llama3.2-vision tanh gate
    return p


def _mask(q_pos, k_pos, window: int, causal: bool):
    """(Sq, Sk) bool validity mask; k_pos == -1 marks empty cache slots."""
    valid = (k_pos >= 0)[None, :]
    if causal:
        valid &= k_pos[None, :] <= q_pos[:, None]
        if window > 0:
            valid &= k_pos[None, :] > q_pos[:, None] - window
    return valid


def _mask_scores(s, msk, k_pos, k_min):
    """Apply an (Sq, Sk) mask to scores s (B, KV, G, Sq, Sk).

    ``k_min`` (B,) optionally also masks keys at positions < k_min[b] per
    batch row — the left-pad exclusion for the fixed-slot fallback engine,
    where a short prompt's pad tokens occupy cache positions [0, pad_len).
    """
    if k_min is not None:
        mb = msk[None] & (k_pos[None, None, :] >= k_min[:, None, None])  # (B,Sq,Sk)
        return jnp.where(mb[:, None, None], s, _NEG_INF)
    return jnp.where(msk[None, None, None], s, _NEG_INF)


def _sdpa_dense(q, k, v, q_pos, k_pos, window, causal, softcap, k_min=None):
    """q: (B,Sq,KV,G,hd); k,v: (B,Sk,KV,hd) -> (B,Sq,KV,G,hd)."""
    scale = q.shape[-1] ** -0.5
    s = jnp.einsum("bskgh,btkh->bkgst", q.astype(jnp.float32), k.astype(jnp.float32)) * scale
    if softcap > 0:
        s = softcap * jnp.tanh(s / softcap)
    s = _mask_scores(s, _mask(q_pos, k_pos, window, causal), k_pos, k_min)
    p = jax.nn.softmax(s, axis=-1)
    o = jnp.einsum("bkgst,btkh->bskgh", p, v.astype(jnp.float32))
    return o.astype(q.dtype)


def _sdpa_flash(q, k, v, q_pos, k_pos, window, causal, softcap, q_chunk, k_chunk,
                k_min=None):
    """Flash-style online-softmax attention: nested scan over q/k chunks.

    Peak scores buffer is (B, KV, G, q_chunk, k_chunk) instead of (.., Sq, Sk)
    — this is the difference between 32k-prefill fitting in HBM or not.
    """
    b, sq, kvh, g, hd = q.shape
    sk = k.shape[1]
    q_chunk = min(q_chunk, sq)
    k_chunk = min(k_chunk, sk)
    # pad to chunk multiples (padded q rows discarded; padded k masked via pos=-1)
    pq, pk = (-sq) % q_chunk, (-sk) % k_chunk
    if pq:
        q = jnp.pad(q, ((0, 0), (0, pq), (0, 0), (0, 0), (0, 0)))
        q_pos = jnp.pad(q_pos, (0, pq), constant_values=0)
    if pk:
        k = jnp.pad(k, ((0, 0), (0, pk), (0, 0), (0, 0)))
        v = jnp.pad(v, ((0, 0), (0, pk), (0, 0), (0, 0)))
        k_pos = jnp.pad(k_pos, (0, pk), constant_values=-1)
    nq, nk = (sq + pq) // q_chunk, (sk + pk) // k_chunk
    scale = hd**-0.5

    k_ch = k.reshape(b, nk, k_chunk, kvh, hd).swapaxes(0, 1)
    v_ch = v.reshape(b, nk, k_chunk, kvh, hd).swapaxes(0, 1)
    kp_ch = k_pos.reshape(nk, k_chunk)

    def one_q_chunk(args):
        qc, qp = args  # (B, Cq, KV, G, hd), (Cq,)
        qf = qc.astype(jnp.float32)

        def kv_step(carry, xs):
            m, l, acc = carry
            kc, vc, kp = xs
            s = jnp.einsum("bskgh,btkh->bkgst", qf, kc.astype(jnp.float32)) * scale
            if softcap > 0:
                s = softcap * jnp.tanh(s / softcap)
            s = _mask_scores(s, _mask(qp, kp, window, causal), kp, k_min)
            m_new = jnp.maximum(m, jnp.max(s, axis=-1))
            p = jnp.exp(s - m_new[..., None])
            alpha = jnp.exp(m - m_new)
            l = l * alpha + jnp.sum(p, axis=-1)
            acc = acc * alpha[..., None] + jnp.einsum(
                "bkgst,btkh->bkgsh", p, vc.astype(jnp.float32)
            )
            return (m_new, l, acc), None

        m0 = jnp.full((b, kvh, g, q_chunk), _NEG_INF, jnp.float32)
        l0 = jnp.zeros((b, kvh, g, q_chunk), jnp.float32)
        a0 = jnp.zeros((b, kvh, g, q_chunk, hd), jnp.float32)
        (m, l, acc), _ = jax.lax.scan(kv_step, (m0, l0, a0), (k_ch, v_ch, kp_ch))
        o = acc / jnp.maximum(l, 1e-30)[..., None]  # (B,KV,G,Cq,hd)
        return o.transpose(0, 3, 1, 2, 4).astype(q.dtype)  # (B,Cq,KV,G,hd)

    q_ch = q.reshape(b, nq, q_chunk, kvh, g, hd).swapaxes(0, 1)
    qp_ch = q_pos.reshape(nq, q_chunk)
    o = jax.lax.map(one_q_chunk, (q_ch, qp_ch))  # (nq, B, Cq, KV, G, hd)
    o = o.swapaxes(0, 1).reshape(b, sq + pq, kvh, g, hd)
    return o[:, :sq]


def init_kv_cache(cfg, batch: int, cache_len: int, dtype, quantized: bool = False) -> dict:
    """Ring-buffer KV cache for one attention layer.

    slot_pos[j] holds the absolute position stored in slot j (-1 = empty).
    For windowed attention cache_len == window; decode is then O(window)
    compute and memory — this is what makes long_500k decodable for the
    SWA/hybrid archs.

    quantized=True stores K/V as K-Means int4 (two indices per uint8) with a
    per-(token, head) scale — the paper's activation quantization applied to
    the KV cache (beyond-paper, KVQuant-style): 4x less HBM traffic on the
    decode-dominating cache reads.
    """
    kv, hd = cfg.n_kv_heads, cfg.head_dim
    base = {"slot_pos": jnp.full((cache_len,), -1, jnp.int32)}
    if not quantized:
        return base | {
            "k": jnp.zeros((batch, cache_len, kv, hd), dtype),
            "v": jnp.zeros((batch, cache_len, kv, hd), dtype),
        }
    from repro.models.model import _default_codebook  # structural codebook

    return base | {
        "k_idx": jnp.zeros((batch, cache_len, kv, hd // 2), jnp.uint8),
        "v_idx": jnp.zeros((batch, cache_len, kv, hd // 2), jnp.uint8),
        "k_scale": jnp.zeros((batch, cache_len, kv, 1), jnp.float32),
        "v_scale": jnp.zeros((batch, cache_len, kv, 1), jnp.float32),
        "kv_codebook": _default_codebook(4),
    }


def _kv_quantize(x: jax.Array, codebook: jax.Array):
    """x: (B, T, KV, hd) -> (packed idx, per-(token, head) scale)."""
    from repro.core.codebook import assign_via_boundaries
    from repro.core.quantize import pack_int4

    s = jnp.maximum(
        jnp.sqrt(jnp.mean(jnp.square(x.astype(jnp.float32)), axis=-1, keepdims=True)), 1e-12
    )
    idx = assign_via_boundaries((x / s).astype(jnp.float32), codebook)
    return pack_int4(idx), s


def _kv_dequantize(packed: jax.Array, scale: jax.Array, codebook: jax.Array, dtype):
    from repro.core.quantize import unpack_int4

    return (codebook[unpack_int4(packed)] * scale).astype(dtype)


def state_quantize(x: jax.Array, codebook: jax.Array):
    """Recurrent-state int4 quantization (Mamba ``h`` (B, di, N) / RG-LRU
    ``h`` (B, di)): per-vector RMS scale over the LAST dim + K-Means boundary
    assignment, the exact KV-pool format reused for SSM state under the
    ``recurrent`` cache policy. Returns (packed idx uint8, scale f32); the
    last dim must be even (two int4 indices per byte)."""
    return _kv_quantize(x, codebook)


def state_dequantize(packed: jax.Array, scale: jax.Array, codebook: jax.Array):
    """Inverse of :func:`state_quantize`; the recurrence runs in f32."""
    return _kv_dequantize(packed, scale, codebook, jnp.float32)


def _cache_write(cache: dict, k, v, positions):
    """Write the last min(S, C) tokens into ring slots; returns new cache.

    Writes use dynamic_update_slice / roll instead of scatter: XLA reliably
    performs DUS in-place on donated buffers, whereas a dynamic-index scatter
    was observed to materialize a full cache copy (+13 GB/device on the
    musicgen decode_32k cell). Contract: ``positions`` are contiguous
    ascending, and multi-token writes start ring-aligned (true for prefill
    from position 0 with C | S or S <= C — the launcher's cases).
    """
    c = cache["slot_pos"].shape[0]
    n_w = min(k.shape[1], c)
    k_w, v_w = k[:, -n_w:], v[:, -n_w:]
    pos_w = positions[-n_w:]
    start = jnp.mod(pos_w[0], c)

    if n_w == c:
        # full overwrite: position p+i lands in slot (p+i) % c == roll by start
        write = lambda _, val: jnp.roll(val, start, axis=1)
        sp = jnp.roll(pos_w, start)
    else:
        write = lambda buf, val: jax.lax.dynamic_update_slice(
            buf, val, (0, start) + (0,) * (buf.ndim - 2)
        )
        sp = jax.lax.dynamic_update_slice(cache["slot_pos"], pos_w, (start,))

    if "k_idx" in cache:
        ki, ks = _kv_quantize(k_w, cache["kv_codebook"])
        vi, vs = _kv_quantize(v_w, cache["kv_codebook"])
        return cache | {
            "k_idx": write(cache["k_idx"], ki),
            "v_idx": write(cache["v_idx"], vi),
            "k_scale": write(cache["k_scale"], ks),
            "v_scale": write(cache["v_scale"], vs),
            "slot_pos": sp,
        }
    return cache | {
        "k": write(cache["k"], k_w.astype(cache["k"].dtype)),
        "v": write(cache["v"], v_w.astype(cache["v"].dtype)),
        "slot_pos": sp,
    }


def _cache_read(cache: dict, dtype):
    if "k_idx" in cache:
        book = cache["kv_codebook"]
        k = _kv_dequantize(cache["k_idx"], cache["k_scale"], book, dtype)
        v = _kv_dequantize(cache["v_idx"], cache["v_scale"], book, dtype)
        return k, v
    return cache["k"], cache["v"]


# ---------------------------------------------------------------------------
# paged KV cache (block pool + per-request block tables)
# ---------------------------------------------------------------------------

def _paged_kernel_default() -> bool:
    """REPRO_PAGED_KERNEL routing: opt-OUT on TPU, opt-in elsewhere.

    unset / "auto" -> kernel on TPU backends, jnp gather everywhere else
    (interpret-mode Pallas is far slower than XLA's fused gather on CPU);
    "0"/"off"/"false" -> always jnp; anything else -> always kernel.
    """
    env = os.environ.get("REPRO_PAGED_KERNEL", "auto").strip().lower()
    if env in ("", "auto"):
        return jax.default_backend() == "tpu"
    return env not in ("0", "off", "false")


# resolved on first paged-attention call, NOT at import: jax.default_backend()
# initializes the backend, which would break jax.distributed.initialize() /
# platform overrides in any program that merely imports the model stack.
# Tests monkeypatch this to force a route.
_USE_PAGED_KERNEL: bool | None = None


def _paged_kernel_enabled() -> bool:
    global _USE_PAGED_KERNEL
    if _USE_PAGED_KERNEL is None:
        _USE_PAGED_KERNEL = _paged_kernel_default()
    return _USE_PAGED_KERNEL


def init_paged_kv_cache(cfg, n_blocks: int, block_size: int, dtype,
                        quantized: bool = False) -> dict:
    """One attention layer's slice of the global paged block pool.

    Unlike the ring buffer, storage is a pool of ``n_blocks`` fixed-size
    token blocks shared by all requests; a per-request *block table*
    (attached per call by the serving scheduler) maps logical block
    ``pos // block_size`` to a pool slot. Token position ``p`` lives at
    ``(table[p // block_size], p % block_size)`` — no wraparound, blocks are
    allocated/freed as sequences grow/finish.

    quantized=True stores K/V as K-Means int4 indices (two per uint8) with a
    per-(token, head) fp32 scale — same format as the ring cache, kept
    packed in HBM and only expanded for the blocks a request actually reads.
    """
    kv, hd = cfg.n_kv_heads, cfg.head_dim
    if not quantized:
        return {
            "pages_k": jnp.zeros((n_blocks, block_size, kv, hd), dtype),
            "pages_v": jnp.zeros((n_blocks, block_size, kv, hd), dtype),
        }
    from repro.models.model import _default_codebook  # structural codebook

    return {
        "pages_k_idx": jnp.zeros((n_blocks, block_size, kv, hd // 2), jnp.uint8),
        "pages_v_idx": jnp.zeros((n_blocks, block_size, kv, hd // 2), jnp.uint8),
        "pages_k_scale": jnp.zeros((n_blocks, block_size, kv, 1), jnp.float32),
        "pages_v_scale": jnp.zeros((n_blocks, block_size, kv, 1), jnp.float32),
        "kv_codebook": _default_codebook(4),
    }


def _paged_write(cache: dict, k, v, positions, ctx_lens):
    """Scatter this call's tokens into their block slots; returns new cache.

    positions: (B, S) absolute token positions; a token is written iff
    ``0 <= positions[b, s] < ctx_lens[b]`` and its block-table entry is
    allocated — padded rows (chunked-prefill tail, idle decode slots) carry
    positions outside that range and are dropped via an out-of-bounds
    scatter index, so an idle slot can never corrupt another request's block.
    """
    pages = cache["pages_k"] if "pages_k" in cache else cache["pages_k_idx"]
    n_blocks, bs = pages.shape[0], pages.shape[1]
    bt = cache["block_tables"]  # (B, max_blocks_per_seq)
    b, s = positions.shape
    blk = jnp.clip(positions // bs, 0, bt.shape[1] - 1)
    block_id = jnp.take_along_axis(bt, blk, axis=1)  # (B, S)
    valid = (positions >= 0) & (positions < ctx_lens[:, None]) & (block_id >= 0)
    dest = jnp.where(valid, block_id * bs + positions % bs, n_blocks * bs)

    def scatter(pool, vals):
        flat = pool.reshape(n_blocks * bs, *pool.shape[2:])
        flat = flat.at[dest.reshape(-1)].set(
            vals.reshape(b * s, *vals.shape[2:]), mode="drop"
        )
        return flat.reshape(pool.shape)

    if "pages_k_idx" in cache:
        ki, ks = _kv_quantize(k, cache["kv_codebook"])
        vi, vs = _kv_quantize(v, cache["kv_codebook"])
        return cache | {
            "pages_k_idx": scatter(cache["pages_k_idx"], ki),
            "pages_v_idx": scatter(cache["pages_v_idx"], vi),
            "pages_k_scale": scatter(cache["pages_k_scale"], ks),
            "pages_v_scale": scatter(cache["pages_v_scale"], vs),
        }
    return cache | {
        "pages_k": scatter(cache["pages_k"], k.astype(pages.dtype)),
        "pages_v": scatter(cache["pages_v"], v.astype(pages.dtype)),
    }


def _paged_attend(cache: dict, q, q_pos, softcap, window: int = 0):
    """Attention against the block pool through the block table.

    q: (B, S, KV, G, hd); q_pos: (B, S). Every batch row is a query *segment*
    of one sequence (decode: S == 1; chunked prefill: S == chunk; the packed
    token-budget step: B == n_tokens rows of S == 1). On TPU backends the
    Pallas gather kernel is the default route (REPRO_PAGED_KERNEL=0 opts
    out); elsewhere the jnp reference is used, which XLA fuses well and
    which lowers on any backend. ``window > 0`` masks keys at positions
    ``<= q_pos - window`` (sliding-window layers under the windowed_paged
    cache policy) — freed out-of-window table entries are < 0 and therefore
    never reachable through the surviving mask.
    """
    from repro.kernels import ref as kref

    bt, cl = cache["block_tables"], cache["ctx_lens"]
    quantized = "pages_k_idx" in cache
    # named unconditionally (telemetry-independent) so XLA profiles line up
    # with the serving timeline names in every mode — and the jaxpr is the
    # same whether telemetry is on or off
    with jax.named_scope("paged_attention"):
        if _paged_kernel_enabled():
            from repro.kernels.ops import should_interpret
            from repro.kernels.paged_attn import paged_attn_kernel_call

            if quantized:
                args = (cache["pages_k_idx"], cache["pages_k_scale"],
                        cache["pages_v_idx"], cache["pages_v_scale"],
                        cache["kv_codebook"])
            else:
                args = (cache["pages_k"], cache["pages_v"])
            o = paged_attn_kernel_call(
                q, *args, block_tables=bt, ctx_lens=cl, q_pos=q_pos,
                softcap=softcap, window=window, interpret=should_interpret(),
            )
            return o.astype(q.dtype)
        if quantized:
            return kref.paged_attn_quant_ref(
                q, cache["pages_k_idx"], cache["pages_k_scale"],
                cache["pages_v_idx"], cache["pages_v_scale"],
                cache["kv_codebook"], bt, cl, q_pos, softcap=softcap,
                window=window,
            ).astype(q.dtype)
        return kref.paged_attn_ref(
            q, cache["pages_k"], cache["pages_v"], bt, cl, q_pos,
            softcap=softcap, window=window,
        ).astype(q.dtype)


def attention_apply(
    p,
    x: jax.Array,
    cfg,
    *,
    positions: jax.Array,  # (S,) absolute positions of x's tokens
    cache: dict | None = None,  # ring-buffer cache (updated + returned)
    memory: jax.Array | None = None,  # cross-attention memory (B, M, d)
    window: int = 0,
    layer_tag: str = "attn",
):
    """GQA attention, all phases (train / prefill / decode / cross).

    Returns (out, new_cache). ``positions`` must be contiguous ascending per
    batch row: shape (S,) shared across the batch (train / prefill / ring
    decode), or (B, S) per-request (paged continuous-batching, where every
    row is at a different depth in its own sequence; position -1 marks a
    padded row that is neither written nor attended).

    Paged caches may carry ``token_slots`` (B,) — the packed token-budget
    layout, where ``block_tables``/ ``ctx_lens`` are per *slot* and each
    batch row is one SEGMENT (S contiguous tokens, possibly padded with
    position -1; S = 1 is the flat one-token-per-row case) of slot
    ``token_slots[b]``; the per-row table is gathered device-side, once per
    segment rather than once per token. Verify segments of the speculative
    decoder ride this same layout. Ring caches may carry ``pad_len`` (B,) —
    keys at positions < pad_len[b] (a left-padded prompt's pad tokens) are
    masked.
    """
    b, s, d = x.shape
    h, kv, hd = cfg.n_heads, cfg.n_kv_heads, cfg.head_dim
    g = h // kv
    softcap = cfg.logit_softcap
    paged = cache is not None and "block_tables" in cache

    q = constrain(dense_apply(p["wq"], x, f"{layer_tag}.q"), "batch", "seq", "heads_flat")
    q = q.reshape(b, s, kv, g, hd)
    kv_src = memory if memory is not None else x
    cross_cached = memory is not None and cache is not None and "ck" in cache
    if cross_cached:
        # decode: reuse the cross K/V computed once at prefill (recomputing
        # them per token cost 2 x M x d x kv x hd FLOPs PER LAYER PER TOKEN —
        # the vision decode cell's MODEL_FLOPS ratio was 0.04 before this)
        k, v = cache["ck"], cache["cv"]
    else:
        k = dense_apply(p["wk"], kv_src, f"{layer_tag}.k").reshape(b, -1, kv, hd)
        v = dense_apply(p["wv"], kv_src, f"{layer_tag}.v").reshape(b, -1, kv, hd)

    cross = memory is not None
    if not cross and cfg.pos_embed == "rope":
        q = rope_apply(q, positions, cfg.rope_theta)
        k = rope_apply(k, positions, cfg.rope_theta)
    q = constrain(q, "batch", "seq", "kv_heads", None, None)
    k = constrain(k, "batch", "seq" if not cross else None, "kv_heads", None)
    v = constrain(v, "batch", "seq" if not cross else None, "kv_heads", None)

    new_cache = cache
    if cross:
        if cache is not None and not cross_cached:
            # prefill populates the cross-KV cache for decode reuse
            new_cache = {"ck": k.astype(jnp.bfloat16), "cv": v.astype(jnp.bfloat16)}
        k_pos = jnp.zeros((k.shape[1],), jnp.int32)
        o = _attn_dispatch(q, k.astype(q.dtype), v.astype(q.dtype), positions, k_pos,
                           0, False, softcap, cfg)
    elif paged:
        if "token_slots" in cache:
            # packed layout: per-slot tables, one token per row — gather the
            # per-row table on device (host ships slots*max_blk ints, not T*)
            cache = cache | {
                "block_tables": jnp.take(cache["block_tables"],
                                         cache["token_slots"], axis=0)
            }
        q_pos = positions if positions.ndim == 2 else jnp.broadcast_to(positions, (b, s))
        new_cache = _paged_write(cache, k, v, q_pos, cache["ctx_lens"])
        o = _paged_attend(new_cache, q, q_pos, softcap, window)
    elif cache is not None:
        new_cache = _cache_write(cache, k, v, positions)
        ck, cv = _cache_read(new_cache, x.dtype)
        o = _attn_dispatch(
            q, ck, cv, positions, new_cache["slot_pos"], window, True, softcap, cfg,
            k_min=cache.get("pad_len"),
        )
    else:
        k_pos = positions
        o = _attn_dispatch(q, k, v, positions, k_pos, window, True, softcap, cfg)

    o = constrain(o.reshape(b, s, h * hd), "batch", "seq", "heads_flat")
    out = dense_apply(p["wo"], o, f"{layer_tag}.o")
    if "gate" in p:  # gated cross-attention (llama3.2-vision)
        out = jnp.tanh(p["gate"].astype(out.dtype)) * out
    return out, new_cache


def _attn_dispatch(q, k, v, q_pos, k_pos, window, causal, softcap, cfg, k_min=None):
    big = q.shape[1] * k.shape[1] > 4_194_304  # 2048^2
    if cfg.attn_chunk > 0 and big:
        return _sdpa_flash(
            q, k, v, q_pos, k_pos, window, causal, softcap,
            q_chunk=cfg.attn_chunk, k_chunk=cfg.attn_chunk, k_min=k_min,
        )
    return _sdpa_dense(q, k, v, q_pos, k_pos, window, causal, softcap, k_min=k_min)


# ---------------------------------------------------------------------------
# MLP
# ---------------------------------------------------------------------------

def mlp_init(key, d: int, d_ff: int, act_fn: str, dtype):
    k1, k2 = jax.random.split(key)
    mult = 2 if act_fn in ("silu", "gelu") else 1  # fused [gate; up]
    return {
        "wi": dense_init(k1, d, mult * d_ff, dtype),
        "wd": dense_init(k2, d_ff, d, dtype, scale=1.0 / math.sqrt(d_ff)),
    }


def mlp_apply(p, x: jax.Array, act_fn: str, layer_tag: str = "mlp") -> jax.Array:
    hidden = dense_apply(p["wi"], x, f"{layer_tag}.wi")
    if act_fn in ("silu", "gelu"):
        gate, up = jnp.split(hidden, 2, axis=-1)
        act = jax.nn.silu(gate) if act_fn == "silu" else jax.nn.gelu(gate)
        hidden = act * up
    elif act_fn == "relu2":
        hidden = jnp.square(jax.nn.relu(hidden))
    elif act_fn == "gelu_plain":
        hidden = jax.nn.gelu(hidden)
    else:
        raise ValueError(act_fn)
    hidden = constrain(hidden, "batch", "seq", "d_ff")
    return dense_apply(p["wd"], hidden, f"{layer_tag}.wd")
