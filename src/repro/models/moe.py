"""Mixture-of-Experts decoder LM (granite-moe, qwen2-moe).

Token-choice top-k routing with a *sort-based* dispatch (argsort by expert id,
capacity-bounded slots) rather than the (T, E, C) one-hot dispatch tensor —
the one-hot form is O(T·E·C) memory and does not survive 1M-token batches;
the sort form is O(T·k) and shards cleanly (capacity dim constrained onto the
"data" axis, expert hidden dim onto "model").

Includes qwen2-style shared experts (a wide dense MLP with a sigmoid gate —
the sum of N parallel gated MLPs is algebraically one N×-wide gated MLP) and
a load-balancing auxiliary loss (Switch-style), returned to the trainer.
"""

from __future__ import annotations

import math

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig
from repro.distributed.sharding import constrain, replicate
from repro.models import layers as L

__all__ = ["init", "apply", "init_caches", "cache_policies", "moe_capacity"]


def moe_capacity(n_tokens: int, cfg: ModelConfig) -> int:
    """Per-expert capacity for a dispatch group, rounded to 8."""
    c = n_tokens * cfg.experts_per_token * cfg.capacity_factor / cfg.n_experts
    return max(8, int(math.ceil(c / 8.0)) * 8)


def _moe_init(key, cfg: ModelConfig, dtype):
    d, f, e = cfg.d_model, cfg.d_ff, cfg.n_experts
    mult = 2 if cfg.act_fn in ("silu", "gelu") else 1
    kr, k1, k2, ks, kg = jax.random.split(key, 5)
    p = {
        "router": L.dense_init(kr, d, e, jnp.float32),  # router kept fp32 (accuracy-critical)
        "wi": (jax.random.normal(k1, (e, d, mult * f)) / math.sqrt(d)).astype(dtype),
        "wd": (jax.random.normal(k2, (e, f, d)) / math.sqrt(f)).astype(dtype),
    }
    if cfg.n_shared_experts:
        f_sh = cfg.n_shared_experts * cfg.shared_expert_d_ff
        p["shared"] = L.mlp_init(ks, d, f_sh, cfg.act_fn, dtype)
        p["shared_gate"] = L.dense_init(kg, d, 1, dtype)
    return p


def _dispatch_group(xg, topi_g, topw_g, e: int, cap: int, dtype):
    """Sort-based dispatch for ONE token group (vmapped over groups).

    xg: (Tg, d), topi_g/topw_g: (Tg, k). Returns
    (expert_in (E, cap, d), slot (F,), st (F,), keep (F,), sw (F,)).
    """
    tg, k = topi_g.shape
    f = tg * k
    e_flat = topi_g.reshape(f)
    w_flat = topw_g.reshape(f)
    t_flat = jnp.arange(f, dtype=jnp.int32) // k
    order = jnp.argsort(e_flat, stable=True)
    se, st, sw = e_flat[order], t_flat[order], w_flat[order]
    counts = jnp.zeros((e,), jnp.int32).at[se].add(1)
    start = jnp.concatenate([jnp.zeros((1,), jnp.int32), jnp.cumsum(counts)[:-1]])
    pos_in_e = jnp.arange(f, dtype=jnp.int32) - start[se]
    keep = pos_in_e < cap
    # overflow tokens: clamp into the last slot of their expert with a ZEROED
    # value — .add of zeros never corrupts, and every dim stays shardable.
    slot = jnp.where(keep, se * cap + pos_in_e, se * cap + cap - 1)
    values = (xg[st] * keep[:, None]).astype(dtype)
    expert_in = jnp.zeros((e * cap, xg.shape[-1]), dtype).at[slot].add(values)
    return expert_in.reshape(e, cap, -1), slot, st, keep, sw


def _combine_group(eo_g, slot, st, keep, sw, tg: int, dtype):
    """eo_g: (E*cap, d) -> (Tg, d) weighted combine for one group."""
    gathered = eo_g[jnp.where(keep, slot, 0)] * (sw * keep)[:, None].astype(dtype)
    return jnp.zeros((tg, eo_g.shape[-1]), dtype).at[st].add(gathered)


def _moe_apply(p, x: jax.Array, cfg: ModelConfig):
    """x: (B, S, d) -> (out, aux_loss).

    GROUP-LOCAL dispatch: tokens are split into ``cfg.moe_dispatch_groups``
    contiguous groups aligned with the DP sharding; each group sorts only its
    own tokens into per-group expert capacity (the per-device-capacity
    pattern of real EP systems). All scatters/gathers keep the sharded group
    dim -> zero cross-shard token movement; the only collective left in the
    MoE layer is the Megatron-style psum of the down-projection (expert FFN
    hidden dim sharded on "model"). A global sort instead forces GSPMD into
    replicated scatter fallbacks (observed 61 GB/device + TB-scale
    all-reduces on granite train_4k; see EXPERIMENTS.md §Perf).
    """
    b, s, d = x.shape
    t = b * s
    k = cfg.experts_per_token
    e = cfg.n_experts
    g = max(1, min(cfg.moe_dispatch_groups, t))
    while t % g:
        g -= 1
    tg = t // g
    cap = moe_capacity(tg, cfg)
    xg = x.reshape(g, tg, d)

    gates = jax.nn.softmax(xg.astype(jnp.float32) @ p["router"]["w"], axis=-1)  # (G, Tg, E)
    topw, topi = jax.lax.top_k(gates, k)
    topw = topw / jnp.maximum(topw.sum(-1, keepdims=True), 1e-9)

    # ---- load-balancing aux loss (Switch): E * <frac_tokens_e> . <mean_gate_e>
    frac = jnp.mean(
        jnp.sum(jax.nn.one_hot(topi, e, dtype=jnp.float32), axis=-2), axis=(0, 1)
    )
    aux = e * jnp.sum(frac * jnp.mean(gates, axis=(0, 1))) / k

    expert_in, slot, st, keep, sw = jax.vmap(
        lambda xx, ii, ww: _dispatch_group(xx, ii, ww, e, cap, x.dtype)
    )(xg, topi, topw)
    expert_in = constrain(expert_in, "dispatch_groups", "experts", None, None)

    # ---- expert computation ---------------------------------------------
    # §Perf G3: expert weights are STORED "model"-sharded (param specs) but
    # GATHERED at use (ZeRO-3 over the model axis). With f-sharded compute the
    # up-projection's BACKWARD psums the 10x-expanded (G,E,cap,d) activation
    # gradient (col-parallel transpose) — gathering the weights instead turns
    # that into a reduce-scatter of the 12x-smaller WEIGHT gradient.
    h = jnp.einsum("gecd,edf->gecf", expert_in, replicate(p["wi"].astype(x.dtype)))
    if cfg.act_fn in ("silu", "gelu"):
        gate, up = jnp.split(h, 2, axis=-1)
        act = jax.nn.silu(gate) if cfg.act_fn == "silu" else jax.nn.gelu(gate)
        h = act * up
    else:
        h = jnp.square(jax.nn.relu(h))
    # §Perf G2: the down-projection contracts the "model"-sharded expert
    # hidden dim; a psum of the 10x-EXPANDED (G,E,cap,d) partial output cost
    # 1 GB/exec (fwd) + 2x (bwd) on granite train_4k, and GSPMD would not
    # defer it past the combine (G1, refuted). Instead we re-shard BEFORE the
    # contraction: all-gather h to full expert-hidden (84 MB/exec) and the
    # expert down-weights (63 MB/layer), then contract locally — 12x fewer
    # collective bytes for this layer at f=512.
    h = constrain(h, "dispatch_groups", "experts", None, None)
    eo = jnp.einsum("gecf,efd->gecd", h, replicate(p["wd"].astype(x.dtype)))

    out = jax.vmap(
        lambda ee, sl, tt, kk, ww: _combine_group(ee.reshape(e * cap, d), sl, tt, kk, ww, tg, x.dtype)
    )(eo, slot, st, keep, sw)
    out = constrain(out, "dispatch_groups", None, None).reshape(b, s, d)

    if cfg.n_shared_experts:
        # shared expert operates on the 3D (B, S, d) stream so the standard
        # ("batch", "seq", "d_ff") activation constraints apply
        sg = jax.nn.sigmoid(L.dense_apply(p["shared_gate"], x).astype(jnp.float32))
        out = out + (sg.astype(x.dtype) * L.mlp_apply(p["shared"], x, cfg.act_fn, "shared_mlp"))

    return out, aux


def _init_block(key, cfg: ModelConfig, dtype):
    k1, k2 = jax.random.split(key)
    return {
        "norm1": L.norm_init(cfg.d_model, cfg.norm, dtype),
        "attn": L.attention_init(k1, cfg, dtype),
        "norm2": L.norm_init(cfg.d_model, cfg.norm, dtype),
        "moe": _moe_init(k2, cfg, dtype),
    }


def init(key, cfg: ModelConfig):
    dtype = jnp.dtype(cfg.param_dtype)
    k_emb, k_blocks, k_head = jax.random.split(key, 3)
    keys = jax.random.split(k_blocks, cfg.n_layers)
    if cfg.scan_layers:
        blocks = jax.vmap(lambda k: _init_block(k, cfg, dtype))(keys)
    else:
        blocks = [_init_block(k, cfg, dtype) for k in keys]
    params = {
        "embed": L.embed_init(k_emb, cfg.vocab_padded, cfg.d_model, dtype),
        "blocks": blocks,
        "norm_f": L.norm_init(cfg.d_model, cfg.norm, dtype),
    }
    if not cfg.tie_embeddings:
        params["head"] = L.dense_init(k_head, cfg.d_model, cfg.vocab_padded, dtype)
    return params


from repro.models.transformer import (  # noqa: E402
    _embed_in,
    _logits_out,
    cache_policies as _tf_cache_policies,
    init_caches as _tf_init_caches,
)

init_caches = _tf_init_caches
cache_policies = _tf_cache_policies  # same attention stack -> same policies


def _block_apply(p, x, cfg: ModelConfig, positions, cache):
    a, new_cache = L.attention_apply(
        p["attn"], L.norm_apply(p["norm1"], x, cfg.norm), cfg,
        positions=positions, cache=cache, window=cfg.sliding_window,
    )
    x = x + a
    m, aux = _moe_apply(p["moe"], L.norm_apply(p["norm2"], x, cfg.norm), cfg)
    x = x + m
    return constrain(x, "batch", "seq_sp", "d_model"), new_cache, aux


def apply(params, cfg: ModelConfig, tokens: jax.Array, *, positions=None, caches=None, last_only: bool = False, return_hidden_only: bool = False):
    """Returns (logits, new_caches, aux_loss)."""
    b, s = tokens.shape
    if positions is None:
        positions = jnp.arange(s, dtype=jnp.int32)
    x = _embed_in(params, cfg, tokens, positions)

    if cfg.scan_layers:
        def body(carry, xs):
            h, aux_sum = carry
            if caches is None:
                y, _, aux = _block_apply(xs, h, cfg, positions, None)
                return (y, aux_sum + aux), None
            p, c = xs
            y, nc, aux = _block_apply(p, h, cfg, positions, c)
            return (y, aux_sum + aux), nc

        if cfg.remat == "block":
            body = jax.checkpoint(body)
        xs = params["blocks"] if caches is None else (params["blocks"], caches)
        (x, aux_total), new_caches = jax.lax.scan(body, (x, jnp.zeros((), jnp.float32)), xs)
    else:
        aux_total = jnp.zeros((), jnp.float32)
        new_caches = []
        for i, p in enumerate(params["blocks"]):
            c = None if caches is None else caches[i]
            x, nc, aux = _block_apply(p, x, cfg, positions, c)
            aux_total = aux_total + aux
            new_caches.append(nc)
        if caches is None:
            new_caches = None

    if last_only:
        x = x[:, -1:]
    if return_hidden_only:
        from repro.models.layers import norm_apply
        return norm_apply(params["norm_f"], x, cfg.norm), new_caches, aux_total / cfg.n_layers
    return _logits_out(params, cfg, x), new_caches, aux_total / cfg.n_layers
