"""Fault-tolerance machinery for 1000+-node posture.

Components (each unit-testable on one host):

* :class:`StepMonitor` — running step-time stats + straggler detection
  (step > factor x running median). On a real cluster the detection feeds
  either collective-timeout tuning or the elastic path below. The windowed
  stats themselves are :class:`repro.serving.telemetry.StreamingStats`
  (re-exported here) — the one streaming-stats implementation in the repo,
  shared with the serving telemetry's per-step timing records.
* :func:`elastic_plan` — given surviving pod/host counts, produce the largest
  valid (pod, data, model) mesh that preserves TP degree (re-sharding TP
  requires weight reshuffling; dropping DP replicas does not), plus the batch
  re-split. The driver recompiles on the planned mesh and restores the latest
  checkpoint — params are saved unsharded-logical so any mesh can load them.
* :class:`Heartbeat` — liveness file per host; stale heartbeat == dead host
  (the launcher-side detector on clusters without a control plane).
* :func:`find_resumable_step` — newest COMMIT-marked checkpoint.
"""

from __future__ import annotations

import dataclasses
import json
import pathlib
import time

from repro.serving.telemetry import StreamingStats

__all__ = ["StepMonitor", "StreamingStats", "Heartbeat", "elastic_plan",
           "find_resumable_step"]


class StepMonitor:
    """Straggler detection (step > factor x running median) over a
    :class:`StreamingStats` window — the same implementation telemetry's
    per-step records use, not a parallel copy."""

    def __init__(self, window: int = 64, straggler_factor: float = 2.0):
        self.stats = StreamingStats(window=window)
        self.factor = straggler_factor
        self.straggler_count = 0

    @property
    def window(self) -> int:
        return self.stats.window

    @property
    def times(self) -> list[float]:
        return self.stats.times

    def record(self, dt: float) -> None:
        self.stats.record(dt)
        if self.is_straggler(dt):
            self.straggler_count += 1

    def median(self) -> float:
        return self.stats.median()

    def is_straggler(self, dt: float) -> bool:
        return len(self.stats) >= 8 and dt > self.factor * self.median()

    def summary(self) -> dict:
        if not len(self.stats):
            return {}
        return {
            "median_s": self.median(),
            "p95_s": self.stats.percentile(95),
            "stragglers": self.straggler_count,
        }


class Heartbeat:
    """Per-host liveness file; launcher declares a host dead when stale."""

    def __init__(self, directory: str, host_id: int, stale_after_s: float = 60.0):
        self.path = pathlib.Path(directory) / f"heartbeat_{host_id}.json"
        self.path.parent.mkdir(parents=True, exist_ok=True)
        self.stale_after = stale_after_s
        self.host_id = host_id

    def beat(self, step: int = -1) -> None:
        self.path.write_text(json.dumps({"t": time.time(), "step": step, "host": self.host_id}))

    @staticmethod
    def live_hosts(directory: str, stale_after_s: float = 60.0) -> list[int]:
        now = time.time()
        out = []
        for f in pathlib.Path(directory).glob("heartbeat_*.json"):
            try:
                d = json.loads(f.read_text())
            except (json.JSONDecodeError, OSError):
                continue
            if now - d["t"] < stale_after_s:
                out.append(int(d["host"]))
        return sorted(out)


@dataclasses.dataclass(frozen=True)
class ElasticPlan:
    mesh_shape: tuple[int, ...]
    mesh_axes: tuple[str, ...]
    global_batch: int
    note: str


def elastic_plan(
    surviving_chips: int,
    model_parallel: int,
    old_global_batch: int,
    old_chips: int,
    chips_per_pod: int = 256,
) -> ElasticPlan:
    """Largest valid mesh after failures, preserving the TP degree.

    Policy: TP degree is sacred (changing it reshards weights); we shrink the
    DP extent to the largest multiple that fits, and scale global batch
    proportionally (keeping per-replica batch constant — the loss-scale-stable
    choice; the LR schedule is stepped on tokens, not steps, so training
    dynamics survive).
    """
    if surviving_chips < model_parallel:
        raise ValueError("fewer chips than one TP group — cannot continue")
    dp = surviving_chips // model_parallel
    chips = dp * model_parallel
    pods = max(1, chips // chips_per_pod)
    new_batch = max(1, old_global_batch * chips // old_chips)
    if pods > 1 and chips % chips_per_pod == 0:
        shape = (pods, chips_per_pod // model_parallel, model_parallel)
        axes = ("pod", "data", "model")
    else:
        shape = (dp, model_parallel)
        axes = ("data", "model")
    return ElasticPlan(
        mesh_shape=shape,
        mesh_axes=axes,
        global_batch=new_batch,
        note=f"dropped {old_chips - chips} chips; DP {old_chips // model_parallel} -> {dp}",
    )


def find_resumable_step(ckpt_dir: str) -> int | None:
    """Newest COMMIT-marked checkpoint step (None if none exist)."""
    best = None
    for d in pathlib.Path(ckpt_dir).glob("step_*"):
        if (d / "COMMIT").exists():
            s = int(d.name.split("_")[1])
            best = s if best is None else max(best, s)
    return best
