"""Logical-axis sharding rules (t5x/MaxText-style) for the model zoo.

Model code annotates tensors with *logical* axis names ("batch", "seq",
"d_model", "heads", "d_ff", "vocab", "experts", ...). A rule set maps logical
axes -> mesh axes; `constrain` applies with_sharding_constraint only when a
rule set is active (CPU unit tests run with no rules and zero overhead).

Rule sets are data, so the dry-run can sweep sharding strategies (this is the
knob §Perf hillclimbs — e.g. moving "seq" between None and "model" toggles
sequence parallelism without touching model code).
"""

from __future__ import annotations

import contextlib
import contextvars

import jax
from jax.sharding import PartitionSpec as P

__all__ = [
    "DEFAULT_RULES",
    "MULTI_POD_RULES",
    "active_rules",
    "constrain",
    "replicate",
    "spec_for",
    "use_rules",
]

# Single-pod mesh ("data", "model"). Megatron-style TP over "model", DP over
# "data". "seq" unsharded by default; SP rules override per-shape.
DEFAULT_RULES: dict[str, object] = {
    "batch": "data",
    "seq": None,
    "seq_sp": None,  # residual-stream seq dim; "model" enables Megatron SP
    "d_model": None,
    "heads_flat": "model",  # flattened H*head_dim projection outputs
    "kv_heads": "model",
    "d_ff": "model",
    "vocab": "model",
    "experts": "model",
    "dispatch_groups": "data",
    "d_inner": "model",  # SSM/LRU inner channels
    "state": None,
}

# Multi-pod mesh ("pod", "data", "model"): DP spans pod x data.
MULTI_POD_RULES: dict[str, object] = {**DEFAULT_RULES, "batch": ("pod", "data")}

_RULES: contextvars.ContextVar[dict | None] = contextvars.ContextVar(
    "repro_sharding_rules", default=None
)


def active_rules() -> dict | None:
    return _RULES.get()


@contextlib.contextmanager
def use_rules(rules: dict | None):
    token = _RULES.set(rules)
    try:
        yield
    finally:
        _RULES.reset(token)


def spec_for(*logical_axes: str | None, rules: dict | None = None) -> P:
    """PartitionSpec for a tensor whose dims carry these logical names."""
    r = rules if rules is not None else (_RULES.get() or {})
    return P(*[r.get(a) if a is not None else None for a in logical_axes])


def replicate(x: jax.Array) -> jax.Array:
    """FORCE full replication (explicit all-gather of a sharded operand).

    Unlike :func:`constrain` (which skips all-None specs to leave propagation
    free), this is deliberate: used where gathering a small operand is cheaper
    than reducing a large partial result (e.g. MoE down-projection, §Perf G2).
    """
    rules = _RULES.get()
    if rules is None:
        return x
    return jax.lax.with_sharding_constraint(x, P(*([None] * x.ndim)))


def constrain(x: jax.Array, *logical_axes: str | None) -> jax.Array:
    """with_sharding_constraint iff a rule set is active AND at least one axis
    resolves to a mesh axis. An all-None spec would FORCE replication — when
    we have no opinion we must leave GSPMD propagation free instead."""
    rules = _RULES.get()
    if rules is None:
        return x
    spec = spec_for(*logical_axes, rules=rules)
    if all(a is None for a in spec):
        return x
    return jax.lax.with_sharding_constraint(x, spec)
