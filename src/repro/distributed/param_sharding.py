"""Parameter sharding specs (Megatron-style TP) derived from param-tree paths.

Column-parallel projections shard their OUTPUT dim on "model"; row-parallel
(the projection back to d_model) shard their INPUT (contraction) dim, so the
TP pattern per block is the classic col->row pair with one all-reduce.
Divisibility against the model-axis size is checked per actual dim — a dim
that does not divide falls back to replicated (this is how 24-head /
10-head archs stay valid on the fixed 16-way mesh; DESIGN.md §4).

Works transparently for quantized trees: QuantizedWeight.packed/scale follow
their parent projection's rule; codebooks replicate.
"""

from __future__ import annotations

import jax
from jax.sharding import NamedSharding
from jax.sharding import PartitionSpec as P

__all__ = ["build_param_specs", "build_cache_specs", "spec_tree_to_shardings"]

# parent linear name -> "col" (shard last dim) | "row" (shard first matrix dim)
_COL = {
    "wq", "wk", "wv", "wi", "in_proj", "dt_proj", "lin_y", "lin_x",
    "w_a", "w_x", "head",
}
_ROW = {"wo", "wd", "out_proj", "lin_out", "x_proj"}
_REPLICATED = {"router", "shared_gate", "norm1", "norm2", "norm", "norm_f"}

# vector params sharded on "model" when divisible (all live on d_inner)
_VEC_MODEL = {"conv_b", "dt_bias", "D", "lambda"}


def _names_of(path) -> list[str]:
    names = []
    for k in path:
        if isinstance(k, jax.tree_util.DictKey):
            names.append(str(k.key))
        elif isinstance(k, jax.tree_util.GetAttrKey):
            names.append(k.name)
        elif isinstance(k, jax.tree_util.SequenceKey):
            names.append(f"[{k.idx}]")
    return names


def _div(dim: int, mesh_axis_size: int):
    return dim % mesh_axis_size == 0


def _leaf_spec(path, leaf, model_size: int) -> P:
    names = _names_of(path)
    shape = leaf.shape
    ndim = len(shape)
    axes: list = [None] * ndim

    parent = None
    for n in reversed(names):
        if n in _COL or n in _ROW or n in _REPLICATED:
            parent = n
            break

    last = names[-1] if names else ""

    def set_axis(i: int):
        if _div(shape[i], model_size):
            axes[i] = "model"

    if last == "table" and ndim >= 2:  # embedding (V, d): shard vocab
        set_axis(ndim - 2)
    elif parent in _REPLICATED:
        pass
    elif last in ("w",):
        if parent in _COL and ndim >= 1:
            set_axis(ndim - 1)
        elif parent in _ROW and ndim >= 2:
            set_axis(ndim - 2)
    elif last == "b":
        if parent in _COL and ndim >= 1:
            set_axis(ndim - 1)
    elif last == "packed":  # QuantizedWeight indices (K, N//2)
        if parent in _COL:
            set_axis(ndim - 1)
        elif parent in _ROW and ndim >= 2:
            set_axis(ndim - 2)
    elif last == "scale" and parent is not None:  # per-out-channel scales (N,)
        if parent in _COL:
            set_axis(ndim - 1)
    elif last in ("codebook", "act_codebook", "thr_lo", "thr_hi"):
        pass
    elif last == "conv_w" and ndim >= 2:  # (cw, di)
        set_axis(ndim - 1)
    elif last == "A_log" and ndim >= 2:  # (di, N)
        set_axis(ndim - 2)
    elif last in _VEC_MODEL and ndim >= 1:
        set_axis(ndim - 1)
    # MoE expert tensors: wi (E, d, 2f) / wd (E, f, d) handled by parent rule
    # above via "w"? They are raw arrays named wi/wd directly:
    elif last == "wi" and ndim >= 3:  # (E, d, 2f)
        set_axis(ndim - 1)
    elif last == "wd" and ndim >= 3:  # (E, f, d)
        set_axis(ndim - 2)

    return P(*axes)


def build_param_specs(params_shapes, model_size: int = 16, fsdp_axes=None,
                      fsdp_shards: int = 1):
    """Pytree of PartitionSpec mirroring ``params_shapes`` (ShapeDtypeStructs ok).

    ``fsdp_axes``: optional DP mesh axes for ZeRO-3-style parameter sharding —
    after TP assignment, the largest remaining unsharded dim of each >=2D
    weight is sharded over the DP axes (XLA inserts the FSDP all-gathers
    before use). This is what makes the 104B arch trainable on 256 x 16 GB
    chips; small models skip it to avoid per-microbatch re-gather traffic.
    """
    flat, treedef = jax.tree_util.tree_flatten_with_path(params_shapes)
    specs = []
    for path, leaf in flat:
        spec = _leaf_spec(path, leaf, model_size)
        if fsdp_axes is not None and len(leaf.shape) >= 2:
            axes = list(spec)
            while len(axes) < len(leaf.shape):
                axes.append(None)
            # largest unsharded dim that divides the DP extent
            cands = [
                (leaf.shape[i], i)
                for i in range(len(leaf.shape))
                if axes[i] is None and leaf.shape[i] % fsdp_shards == 0 and leaf.shape[i] > 1
            ]
            if cands:
                _, i = max(cands)
                axes[i] = fsdp_axes
            spec = P(*axes)
        specs.append(spec)
    return jax.tree_util.tree_unflatten(treedef, specs)


def build_cache_specs(cache_shapes, batch_axes, batch_shards: int,
                      model_size: int = 16, kv_heads: int = 0, ssm_state: int = 0):
    """Sharding specs for KV/SSM caches: batch dim on the DP axes, kv-heads /
    d_inner on "model" when divisible, slot positions/codebooks replicated.

    Cache leaf base ranks (without leading scan-stack dims):
      k/v (B, C, KV, hd) | *_idx (B, C, KV, hd/2) | *_scale (B, C, KV, 1)
      mamba h (B, di, N) | rglru h (B, di) | conv (B, cw-1, di)
    """

    def spec(path, leaf):
        names = _names_of(path)
        last = names[-1]
        shape = leaf.shape
        axes: list = [None] * len(shape)
        if last in ("slot_pos", "kv_codebook"):
            return P(*axes)
        kv_like = last in ("k", "v", "ck", "cv", "k_idx", "v_idx", "k_scale", "v_scale")
        if kv_like:
            base_rank = 4
        elif last == "conv":
            base_rank = 3
        elif last == "h":
            base_rank = 3 if (ssm_state and shape[-1] == ssm_state) else 2
        else:
            return P(*axes)
        b_dim = len(shape) - base_rank
        if (
            b_dim >= 0
            and batch_axes is not None
            and batch_shards > 1
            and shape[b_dim] % batch_shards == 0
        ):
            axes[b_dim] = batch_axes
        if kv_like:
            kv_dim = len(shape) - 2
            if kv_heads and kv_heads % model_size == 0 and shape[kv_dim] == kv_heads:
                axes[kv_dim] = "model"
            elif last in ("k", "v", "ck", "cv", "k_idx", "v_idx") and _div(shape[-1], model_size):
                # kv heads don't divide the model axis (e.g. 8 heads on 16-way
                # TP): shard head_dim instead — otherwise the cache REPLICATES
                # across the model axis (observed 49 GB/device on the 104B
                # decode cell). Attention contracts hd -> small psum.
                axes[-1] = "model"
        else:
            di_dim = len(shape) - 2 if (last == "h" and base_rank == 3) else len(shape) - 1
            if _div(shape[di_dim], model_size):
                axes[di_dim] = "model"
        return P(*axes)

    flat, treedef = jax.tree_util.tree_flatten_with_path(cache_shapes)
    return jax.tree_util.tree_unflatten(treedef, [spec(p, l) for p, l in flat])


def spec_tree_to_shardings(spec_tree, mesh):
    return jax.tree.map(
        lambda s: NamedSharding(mesh, s),
        spec_tree,
        is_leaf=lambda x: isinstance(x, P),
    )
