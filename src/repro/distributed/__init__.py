"""Distributed runtime: sharding rules, compressed collectives, fault tolerance."""
