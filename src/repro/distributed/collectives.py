"""Distributed-optimization collectives: int8 gradient compression with
error feedback.

For DP all-reduces at 1000+-node scale the gradient exchange is
interconnect-bound; blockwise int8 quantization cuts the bytes 4x (fp32
moments unaffected). Error feedback (residual carried to the next step) keeps
the compression unbiased over time — standard 1-bit-Adam/PowerSGD-family
practice.

Two entry points:
  * compress_decompress_tree — drop-in inside a pjit train step: quantize +
    dequantize the gradient BEFORE the (implicit, GSPMD-inserted) all-reduce.
    The wire format stays fp32 under pure GSPMD, but the information content
    is int8, which keeps the *semantics* testable everywhere; on clusters the
    same quantizer runs under shard_map (below) for true int8 wires.
  * compressed_psum — explicit shard_map collective: int8 payload, int32
    accumulation (no overflow up to 2^23 summands), per-block fp scales.
"""

from __future__ import annotations

import jax
import jax.numpy as jnp

__all__ = [
    "init_error_state",
    "quantize_blockwise",
    "dequantize_blockwise",
    "compress_decompress_tree",
    "compressed_psum",
]

_BLOCK = 256


def init_error_state(params):
    return jax.tree.map(lambda p: jnp.zeros(p.shape, jnp.float32), params)


def _pad_to_block(x: jax.Array):
    flat = x.reshape(-1).astype(jnp.float32)
    pad = (-flat.shape[0]) % _BLOCK
    return jnp.pad(flat, (0, pad)), pad


def quantize_blockwise(x: jax.Array):
    """fp -> (int8 values, fp32 per-block scales). Blocks of 256 elements."""
    flat, _ = _pad_to_block(x)
    blocks = flat.reshape(-1, _BLOCK)
    scale = jnp.maximum(jnp.max(jnp.abs(blocks), axis=1, keepdims=True), 1e-12) / 127.0
    q = jnp.clip(jnp.round(blocks / scale), -127, 127).astype(jnp.int8)
    return q, scale.astype(jnp.float32)


def dequantize_blockwise(q: jax.Array, scale: jax.Array, shape, dtype=jnp.float32):
    flat = (q.astype(jnp.float32) * scale).reshape(-1)
    n = 1
    for d in shape:
        n *= d
    return flat[:n].reshape(shape).astype(dtype)


def compress_decompress_tree(grads, err_state):
    """Per-leaf: q = Q(g + err); g' = deQ(q); err' = (g + err) - g'.

    Returns (compressed-then-restored grads, new error state). The round-trip
    loses <= 1/254 of each block's absmax per step; error feedback re-injects
    the loss next step (unbiased in expectation) — asserted in tests.
    """

    def one(g, e):
        tot = g.astype(jnp.float32) + e
        q, s = quantize_blockwise(tot)
        deq = dequantize_blockwise(q, s, g.shape)
        return deq.astype(g.dtype), tot - deq

    flat_g, treedef = jax.tree.flatten(grads)
    flat_e = treedef.flatten_up_to(err_state)
    pairs = [one(g, e) for g, e in zip(flat_g, flat_e)]
    return (
        jax.tree.unflatten(treedef, [p[0] for p in pairs]),
        jax.tree.unflatten(treedef, [p[1] for p in pairs]),
    )


def compressed_psum(x: jax.Array, axis_name: str):
    """int8-wire psum for use inside shard_map.

    Protocol (the order matters for exactness):
      1. agree on a SHARED per-block scale: pmax of local absmax (tiny fp32
         exchange, 1/256 of the payload)
      2. quantize locally against the shared scale
      3. psum the int8 payload with int32 accumulation (overflow-safe for
         < 2^23 ranks)
      4. dequantize once with the shared scale.
    Sum(Q_shared(x_i)) reconstructs exactly Q_shared(sum) up to per-element
    rounding <= n_ranks * scale/2 — absorbed by upstream error feedback.
    """
    flat, _ = _pad_to_block(x)
    blocks = flat.reshape(-1, _BLOCK)
    local_amax = jnp.max(jnp.abs(blocks), axis=1, keepdims=True)
    scale = jnp.maximum(jax.lax.pmax(local_amax, axis_name), 1e-12) / 127.0
    q = jnp.clip(jnp.round(blocks / scale), -127, 127).astype(jnp.int8)
    total = jax.lax.psum(q.astype(jnp.int32), axis_name)
    out = (total.astype(jnp.float32) * scale).reshape(-1)
    n = 1
    for d in x.shape:
        n *= d
    return out[:n].reshape(x.shape).astype(x.dtype)
